//! Quickstart: build an H-matrix for the BEM model problem, compress it with
//! AFLP + VALR, and compare memory and MVM time.
//!
//! Run: `cargo run --release --example quickstart`

use hmatc::bench::bench_fn;
use hmatc::prelude::*;
use hmatc::util::{fmt_bytes, fmt_secs, Rng};
use std::sync::Arc;

fn main() {
    // 1. Geometry + matrix generator: Laplace single layer potential on the
    //    unit sphere (paper §2.1), n = 5120 piecewise-constant DoF.
    let geom = hmatc::geometry::icosphere(4);
    let gen = LaplaceSlp::new(&geom);
    println!("problem: Laplace SLP on S², n = {}", gen.len());

    // 2. Cluster tree + block tree with standard admissibility (η = 2).
    let ct = Arc::new(ClusterTree::build(gen.points(), 64));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));

    // 3. H-matrix with ACA at accuracy ε = 1e-6.
    let eps = 1e-6;
    let mut h = HMatrix::build(&bt, &gen, &hmatc::lowrank::AcaOptions::with_eps(eps));
    println!("H-matrix: {} ({:.1} B/dof)", fmt_bytes(h.byte_size()), h.bytes_per_dof());

    // 4. Multiply (collision-free Algorithm 3).
    let mut rng = Rng::new(1);
    let x = rng.vector(h.ncols());
    let mut y = vec![0.0; h.nrows()];
    let t0 = bench_fn(1, 5, 0.02, || hmatc::mvm::mvm(1.0, &h, &x, &mut y, MvmAlgorithm::ClusterLists));
    println!("uncompressed MVM: {}", fmt_secs(t0.median));

    // 5. Compress (AFLP + VALR, §4) and multiply again — same API.
    let before = h.byte_size();
    h.compress(&CompressionConfig::aflp(eps));
    println!(
        "compressed:  {} ({:.2}x smaller)",
        fmt_bytes(h.byte_size()),
        before as f64 / h.byte_size() as f64
    );
    let t1 = bench_fn(1, 5, 0.02, || hmatc::mvm::mvm(1.0, &h, &x, &mut y, MvmAlgorithm::ClusterLists));
    println!("compressed MVM:  {} ({:.2}x speedup)", fmt_secs(t1.median), t0.median / t1.median);
}
