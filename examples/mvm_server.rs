//! Coordinator demo: the MVM server batches concurrent right-hand sides and
//! executes one multi-RHS product per batch. The server is generic over the
//! `HOperator` trait, so the same loop serves all three hierarchical formats
//! (H, uniform-H, H²) — here each behind a precomputed execution plan
//! (`hmatc::plan`) for zero-allocation steady-state serving. Optionally
//! offloads the dense near-field to the AOT JAX/Pallas tile kernel via PJRT.
//!
//! Run: `cargo run --release --example mvm_server -- --requests 128 --batch 8`
//! (PJRT offload check requires `make artifacts` first.)

use hmatc::coordinator::{BatchPolicy, MvmServer};
use hmatc::prelude::*;
use hmatc::util::args::Args;
use hmatc::util::{fmt_bytes, fmt_secs, Rng, Timer};
use std::sync::Arc;
use std::time::Duration;

fn serve(op: Arc<dyn HOperator>, nreq: usize, max_batch: usize) {
    let name = op.format_name();
    let n = op.ncols();
    println!("\nserving {} operator: n = {}, {}", name, n, fmt_bytes(op.byte_size()));
    let server = Arc::new(MvmServer::start(op, BatchPolicy { max_batch, linger: Duration::from_micros(300) }));

    // closed-loop clients
    let nclients = 4;
    let t = Timer::start();
    std::thread::scope(|s| {
        for c in 0..nclients {
            let server = server.clone();
            s.spawn(move || {
                let mut rng = Rng::new(500 + c as u64);
                for _ in 0..nreq / nclients {
                    let x = rng.vector(n);
                    let _ = server.call(x);
                }
            });
        }
    });
    let wall = t.elapsed();
    let m = server.metrics.snapshot();
    println!(
        "{}: {} requests in {} → {:.1} req/s | {} batches (avg size {:.2}) | p50 {} p99 {} | {:.2} GB/s effective",
        name,
        m.requests,
        fmt_secs(wall),
        m.requests as f64 / wall,
        m.batches,
        m.avg_batch,
        fmt_secs(m.p50_latency),
        fmt_secs(m.p99_latency),
        m.effective_gbs
    );
}

fn main() {
    let args = Args::from_env();
    let level = args.num_or("level", 4usize);
    let eps = args.num_or("eps", 1e-6f64);
    let nreq = args.num_or("requests", 128usize);
    let max_batch = args.num_or("batch", 8usize);

    let geom = hmatc::geometry::icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 64));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    let h = HMatrix::build(&bt, &gen, &hmatc::lowrank::AcaOptions::with_eps(eps));

    // all three formats of the same compressed operator, each behind a plan
    let cfg = CompressionConfig::aflp(eps);
    let mut uh = hmatc::uniform::build_from_h(&h, eps, hmatc::uniform::CouplingKind::Combined);
    let mut h2 = hmatc::h2::build_from_h(&h, eps);
    let mut hz = h;
    hz.compress(&cfg);
    uh.compress(&cfg);
    h2.compress(&cfg);

    // external ordering: clients submit vectors in the original point
    // ordering; the permutation fold runs inside the plan execution
    let planned = PlannedOperator::from_h(Arc::new(hz)).with_external_ordering();
    let st = planned.plan_stats();
    println!(
        "H plan: {} tasks, {} levels, ≤{} shards, {} scratch f64 (external ordering: {})",
        st.tasks,
        st.levels,
        st.max_shards,
        st.scratch_f64,
        planned.is_external_ordering()
    );
    serve(Arc::new(planned), nreq, max_batch);
    serve(Arc::new(PlannedOperator::from_uniform(Arc::new(uh)).with_external_ordering()), nreq, max_batch);
    serve(Arc::new(PlannedOperator::from_h2(Arc::new(h2)).with_external_ordering()), nreq, max_batch);

    // PJRT offload demo (dense near-field on the AOT Pallas tile kernel)
    #[cfg(feature = "pjrt")]
    {
        let geom = hmatc::geometry::icosphere(3);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), 64));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        let h_unc = HMatrix::build(&bt, &gen, &hmatc::lowrank::AcaOptions::with_eps(1e-6));
        match hmatc::runtime::TileEngine::new("artifacts", "dense_tile_mvm") {
            Ok(mut te) => {
                let mut rng = Rng::new(77);
                let x = rng.vector(h_unc.ncols());
                let mut y = vec![0.0; h_unc.nrows()];
                let t = Timer::start();
                let ntiles = te.full_mvm(1.0, &h_unc, &x, &mut y).expect("offload mvm");
                println!("\nPJRT offload: {ntiles} dense tiles on the AOT Pallas kernel in {}", fmt_secs(t.elapsed()));
                let mut yr = vec![0.0; h_unc.nrows()];
                hmatc::mvm::mvm(1.0, &h_unc, &x, &mut yr, MvmAlgorithm::Seq);
                let norm: f64 = yr.iter().map(|v| v * v).sum::<f64>().sqrt();
                let diff: f64 = yr.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
                println!("‖y_pjrt − y_rust‖/‖y‖ = {:.2e} (f32 tile path)", diff / norm);
            }
            Err(e) => println!("\nPJRT offload skipped: {e}"),
        }
    }
}
