//! Geostatistics workload (cf. Abdulah et al., ref [1] of the paper):
//! a Matérn-3/2 covariance matrix over scattered 3D points, H-compressed and
//! FP-compressed; compares codecs and VALR vs fixed precision, then draws a
//! correlated sample via CG-based Krylov filtering.
//!
//! Run: `cargo run --release --example covariance_compression -- --n 4000`

use hmatc::compress::{Codec, CompressionConfig};
use hmatc::kernelfn::Matern32Covariance;
use hmatc::prelude::*;
use hmatc::solver::cg;
use hmatc::util::args::Args;
use hmatc::util::{fmt_bytes, Rng};
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let n = args.num_or("n", 4000usize);
    let eps = args.num_or("eps", 1e-6f64);
    let mut rng = Rng::new(11);

    let pts = hmatc::geometry::random_cube(n, &mut rng);
    let mut gen = Matern32Covariance::new(pts, 0.25);
    // regularize: kriging systems carry a measurement-noise nugget; without
    // it the covariance matrix is near-singular and CG stalls
    gen.nugget = 0.05;
    let ct = Arc::new(ClusterTree::build(gen.points(), 64));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    let h = HMatrix::build(&bt, &gen, &hmatc::lowrank::AcaOptions::with_eps(eps));
    println!("covariance H-matrix: n = {n}, {} ({:.1} B/dof)", fmt_bytes(h.byte_size()), h.bytes_per_dof());
    println!("dense equivalent: {}", fmt_bytes(n * n * 8));

    // codec / VALR comparison
    println!("\ncompression at eps = {eps:.0e}:");
    for (name, cfg) in [
        ("AFLP + VALR", CompressionConfig { codec: Codec::Aflp, eps, valr: true }),
        ("AFLP fixed", CompressionConfig { codec: Codec::Aflp, eps, valr: false }),
        ("FPX + VALR", CompressionConfig { codec: Codec::Fpx, eps, valr: true }),
        ("FPX fixed", CompressionConfig { codec: Codec::Fpx, eps, valr: false }),
    ] {
        let mut hz = h.clone();
        hz.compress(&cfg);
        println!(
            "  {name:12}: {} ({:.2}x)",
            fmt_bytes(hz.byte_size()),
            h.byte_size() as f64 / hz.byte_size() as f64
        );
    }

    // kriging-style solve on the compressed operator: C x = rhs
    let mut hz = h.clone();
    hz.compress(&CompressionConfig::aflp(eps));
    let rhs = rng.vector(n);
    let op = (n, |x: &[f64], y: &mut [f64]| hmatc::mvm::mvm(1.0, &hz, x, y, MvmAlgorithm::ClusterLists));
    let (x, stats) = cg(&op, &rhs, 1e-7, 1000);
    println!(
        "\nkriging solve (compressed operator): {} iters, residual {:.2e} ({})",
        stats.iterations,
        stats.residual,
        if stats.converged { "converged" } else { "NOT converged" }
    );
    // quick consistency: apply C to the solution, compare with rhs
    let mut check = vec![0.0; n];
    hmatc::mvm::mvm(1.0, &hz, &x, &mut check, MvmAlgorithm::ClusterLists);
    let err: f64 = check.iter().zip(&rhs).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt()
        / rhs.iter().map(|v| v * v).sum::<f64>().sqrt();
    println!("‖Cx − rhs‖/‖rhs‖ = {err:.2e}");
}
