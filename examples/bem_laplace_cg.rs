//! End-to-end driver (the EXPERIMENTS.md validation run): assemble the BEM
//! Laplace SLP system on the unit sphere in all three hierarchical formats,
//! compress, and solve ∫ u/‖x−y‖ = f with CG, logging the residual curve.
//!
//! Run: `cargo run --release --example bem_laplace_cg -- --level 4 --eps 1e-6`

use hmatc::prelude::*;
use hmatc::solver::cg;
use hmatc::util::args::Args;
use hmatc::util::{fmt_bytes, fmt_secs, Timer};
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let level = args.num_or("level", 4usize);
    let eps = args.num_or("eps", 1e-6f64);
    let tol = args.num_or("tol", 1e-8f64);

    let t = Timer::start();
    let geom = hmatc::geometry::icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let n = gen.len();
    let ct = Arc::new(ClusterTree::build(gen.points(), 64));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    println!("setup: n = {n}, {}", fmt_secs(t.elapsed()));

    let t = Timer::start();
    let h = HMatrix::build(&bt, &gen, &hmatc::lowrank::AcaOptions::with_eps(eps));
    println!("H build: {} | {}", fmt_secs(t.elapsed()), fmt_bytes(h.byte_size()));

    let t = Timer::start();
    let uh = hmatc::uniform::build_from_h(&h, eps, hmatc::uniform::CouplingKind::Combined);
    println!("UH build: {} | {}", fmt_secs(t.elapsed()), fmt_bytes(uh.byte_size()));

    let t = Timer::start();
    let h2 = hmatc::h2::build_from_h(&h, eps);
    println!("H2 build: {} | {}", fmt_secs(t.elapsed()), fmt_bytes(h2.byte_size()));

    // right-hand side for f(x) ≡ 1 on Γ: Galerkin load vector b_i = ∫_πi 1 =
    // A_i, permuted to the internal (cluster tree) ordering
    let b: Vec<f64> = (0..n).map(|pos| geom.areas[ct.perm[pos]]).collect();

    // solve with each format, uncompressed and AFLP-compressed
    let solve = |name: &str, apply: &(dyn Fn(&[f64], &mut [f64]) + Sync)| {
        let op = (n, |x: &[f64], y: &mut [f64]| apply(x, y));
        let (sol, stats) = cg(&op, &b, tol, 2000);
        println!(
            "CG[{name}]: {} iters, residual {:.2e}, {} ({})",
            stats.iterations,
            stats.residual,
            fmt_secs(stats.seconds),
            if stats.converged { "converged" } else { "NOT converged" }
        );
        // residual curve, decimated
        let hist = &stats.residual_history;
        let step = (hist.len() / 8).max(1);
        let curve: Vec<String> = hist.iter().step_by(step).map(|r| format!("{r:.1e}")).collect();
        println!("  residual curve: {}", curve.join(" → "));
        sol
    };

    let x_h = solve("H uncompressed", &|x, y| hmatc::mvm::mvm(1.0, &h, x, y, MvmAlgorithm::ClusterLists));
    let x_uh = solve("UH row-wise", &|x, y| hmatc::mvm::uniform_mvm(1.0, &uh, x, y, UniMvmAlgorithm::RowWise));
    let x_h2 = solve("H2 row-wise", &|x, y| hmatc::mvm::h2_mvm(1.0, &h2, x, y, H2MvmAlgorithm::RowWise));

    let mut hz = h.clone();
    hz.compress(&CompressionConfig::aflp(eps));
    println!("compressed H: {}", fmt_bytes(hz.byte_size()));
    let x_hz = solve("H AFLP-compressed", &|x, y| hmatc::mvm::mvm(1.0, &hz, x, y, MvmAlgorithm::ClusterLists));

    // cross-check the four solutions
    let norm: f64 = x_h.iter().map(|v| v * v).sum::<f64>().sqrt();
    for (name, other) in [("UH", &x_uh), ("H2", &x_h2), ("zH", &x_hz)] {
        let d: f64 = x_h.iter().zip(other).map(|(a, c)| (a - c) * (a - c)).sum::<f64>().sqrt();
        println!("‖x_H − x_{name}‖/‖x_H‖ = {:.2e}", d / norm);
    }

    // physical sanity: for f ≡ 1 on the unit sphere, the SLP solution is the
    // constant charge density u = 1 (up to discretization error)
    let mean: f64 = x_h.iter().sum::<f64>() / n as f64;
    println!("mean(u) = {mean:.4} (analytic: 1.0 for the unit sphere)");
}
