"""AOT lowering: JAX/Pallas graphs → HLO *text* artifacts for the rust
runtime.

HLO text (NOT ``lowered.compile()``/serialized protos) is the interchange
format: jax ≥ 0.5 emits protos with 64-bit instruction ids which the
xla_extension 0.5.1 behind the published ``xla`` crate rejects; the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Usage: python -m compile.aot --outdir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# shapes baked into the artifacts (keep in sync with rust/src/runtime/tiles.rs)
BATCH = 64
TILE = 64
RANK = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all():
    """Return {artifact name: HLO text} for every compiled graph."""
    f32 = jnp.float32
    u32 = jnp.uint32
    tiles = jax.ShapeDtypeStruct((BATCH, TILE, TILE), f32)
    xs = jax.ShapeDtypeStruct((BATCH, TILE), f32)
    words = jax.ShapeDtypeStruct((BATCH, TILE * TILE // 2), u32)
    u = jax.ShapeDtypeStruct((BATCH, TILE, RANK), f32)
    v = jax.ShapeDtypeStruct((BATCH, TILE, RANK), f32)

    out = {}
    out["dense_tile_mvm"] = to_hlo_text(jax.jit(model.dense_tile_model).lower(tiles, xs))
    out["fpx_tile_mvm_b2"] = to_hlo_text(
        jax.jit(lambda w, x: model.fpx_tile_model_b2(w, x, tile=TILE)).lower(words, xs)
    )
    out["lowrank_tile_mvm"] = to_hlo_text(jax.jit(model.lowrank_tile_model).lower(u, v, xs))
    out["combined_leaf_mvm"] = to_hlo_text(
        jax.jit(model.combined_leaf_model).lower(tiles, u, v, xs, xs)
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file output (ignored name, writes all)")
    args = ap.parse_args()
    outdir = args.outdir if args.out is None else os.path.dirname(args.out) or "."
    os.makedirs(outdir, exist_ok=True)
    for name, text in lower_all().items():
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
