"""L2 — JAX compute graphs over the L1 Pallas kernels.

These are the graphs the AOT pipeline lowers to HLO for the rust runtime:
batched leaf-level H-MVM stages. Python never runs at request time; rust
feeds gathered tile batches to the compiled executables (see
rust/src/runtime/tiles.rs).
"""

import jax.numpy as jnp

from .kernels.dense import dense_tile_mvm
from .kernels.fpx import fpx2_tile_mvm
from .kernels.lowrank import lowrank_tile_mvm


def dense_tile_model(tiles, xs):
    """Batched dense near-field stage: y[b] = D[b] x[b]."""
    return (dense_tile_mvm(tiles, xs),)


def fpx_tile_model_b2(words, xs, tile=64):
    """Batched compressed near-field stage (2-byte FPX storage)."""
    return (fpx2_tile_mvm(words, xs, tile),)


def lowrank_tile_model(u, v, xs):
    """Batched far-field stage: y[b] = U[b] V[b]^T x[b]."""
    return (lowrank_tile_mvm(u, v, xs),)


def combined_leaf_model(tiles, u, v, x_dense, x_lr):
    """One leaf-level H-MVM step: dense tiles + low-rank tiles, summed where
    the rust coordinator scatters them. Demonstrates that the stages fuse
    into a single HLO module (one executable per batch shape)."""
    yd = dense_tile_mvm(tiles, x_dense)
    yl = lowrank_tile_mvm(u, v, x_lr)
    return (yd, yl, jnp.add(yd, yl))
