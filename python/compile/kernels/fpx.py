"""L1 Pallas kernel: FPX-compressed tile matvec — the paper's §4.3 memory
accessor as a TPU-style kernel.

The tile lives in HBM as *packed truncated-IEEE half-words* (2-byte FPX32,
two values per uint32 word, little-endian; same layout as the rust codec's
byte planes). The BlockSpec streams one compressed tile (T·T/2 words = half
the bytes of an f32 tile) into VMEM per grid step; integer shift/mask + a
bitcast widen it in-register; the matvec then runs at f32.

Hardware adaptation (DESIGN.md §Pallas): the paper's AVX512 byte-shuffle
decode becomes vector integer ops on the VPU — the speedup mechanism (half
the HBM traffic per tile) is preserved. ``interpret=True`` on this sandbox.
"""

import functools

import jax
import jax.lax as lax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(words_ref, x_ref, y_ref, *, tile):
    w = words_ref[0].astype(jnp.uint32)  # (T*T//2,)
    low = (w & jnp.uint32(0xFFFF)) << jnp.uint32(16)
    high = w & jnp.uint32(0xFFFF0000)
    lo_f = lax.bitcast_convert_type(low, jnp.float32)
    hi_f = lax.bitcast_convert_type(high, jnp.float32)
    vals = jnp.stack([lo_f, hi_f], axis=-1).reshape(tile, tile)  # row-major
    x = x_ref[0]
    y_ref[0, :] = jnp.dot(vals, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def fpx2_tile_mvm(words, xs, tile, interpret=True):
    """words: uint32[B, T*T//2] packed FPX-2 tiles, xs: f32[B, T] → f32[B, T]."""
    b, nw = words.shape
    assert nw == tile * tile // 2
    assert xs.shape == (b, tile)
    return pl.pallas_call(
        functools.partial(_kernel, tile=tile),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, nw), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, tile), jnp.float32),
        interpret=interpret,
    )(words, xs)
