"""L1 Pallas kernel: batched low-rank tile matvec y[b] = U[b] (V[b]^T x[b]).

The two slim contractions keep the working set at 2·T·K f32 per grid step —
the compressed-format analogue of the paper's low-rank block product
t := V^H x|σ ; y|τ += U t (Algorithm 1's admissible branch).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(u_ref, v_ref, x_ref, y_ref):
    u = u_ref[0]  # (T, K)
    v = v_ref[0]  # (T, K)
    x = x_ref[0]  # (T,)
    t = jnp.dot(v.T, x, preferred_element_type=jnp.float32)  # (K,)
    y_ref[0, :] = jnp.dot(u, t, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lowrank_tile_mvm(u, v, xs, interpret=True):
    """u, v: f32[B, T, K]; xs: f32[B, T] → f32[B, T]."""
    b, t, k = u.shape
    assert v.shape == (b, t, k) and xs.shape == (b, t)
    return pl.pallas_call(
        _kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, t, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t), jnp.float32),
        interpret=interpret,
    )(u, v, xs)
