"""L1 Pallas kernel: batched dense tile matvec y[b] = D[b] @ x[b].

One grid step per tile; the BlockSpec streams one (T, T) tile plus its (T,)
input vector into VMEM per step. On a real TPU the f32 tile (T=64 → 16 KiB)
fits VMEM trivially and the contraction maps to the MXU; on this CPU sandbox
the kernel runs with ``interpret=True`` (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(tile_ref, x_ref, y_ref):
    t = tile_ref[0]  # (T, T) row-major
    x = x_ref[0]  # (T,)
    y_ref[0, :] = jnp.dot(t, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dense_tile_mvm(tiles, xs, interpret=True):
    """tiles: f32[B, T, T] (row-major per tile), xs: f32[B, T] → f32[B, T]."""
    b, t, t2 = tiles.shape
    assert t == t2 and xs.shape == (b, t)
    return pl.pallas_call(
        _kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, t, t), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t), jnp.float32),
        interpret=interpret,
    )(tiles, xs)
