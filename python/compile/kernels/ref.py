"""Pure-jnp reference oracles for the Pallas kernels (correctness ground
truth at build time — pytest compares every kernel against these).

Also hosts the FPX byte-layout helpers shared with the rust side: a value is
the top ``b`` bytes of its IEEE-754 FP32 pattern; for b=2 two half-words are
packed little-endian into one uint32 (low half = even index), matching
``rust/src/runtime/engine.rs::execute_mixed``.
"""

import jax.lax as lax
import jax.numpy as jnp
import numpy as np


def dense_tile_mvm_ref(tiles, xs):
    """y[b] = tiles[b] @ xs[b] for row-major tiles (B, T, T), xs (B, T)."""
    return jnp.einsum("bij,bj->bi", tiles, xs)


def lowrank_tile_mvm_ref(u, v, xs):
    """y[b] = U[b] @ (V[b]^T @ xs[b]); U,V: (B, T, K), xs: (B, T)."""
    t = jnp.einsum("bjk,bj->bk", v, xs)
    return jnp.einsum("bik,bk->bi", u, t)


def fpx2_decode_ref(words, n_values):
    """Decode 2-byte FPX32 values packed two-per-uint32 word.

    words: uint32[..., W] with W = n_values // 2. Value 2w sits in the low
    16 bits, value 2w+1 in the high 16 bits; each half-word holds the top
    two bytes of an f32 (bf16-like truncation).
    """
    words = words.astype(jnp.uint32)
    low = (words & jnp.uint32(0xFFFF)) << jnp.uint32(16)
    high = words & jnp.uint32(0xFFFF0000)
    lo_f = lax.bitcast_convert_type(low, jnp.float32)
    hi_f = lax.bitcast_convert_type(high, jnp.float32)
    vals = jnp.stack([lo_f, hi_f], axis=-1)
    return vals.reshape(*words.shape[:-1], n_values)


def fpx2_tile_mvm_ref(words, xs, tile):
    """Reference for the FPX tile kernel: decode then matvec.

    words: uint32 (B, T*T//2); xs: (B, T); returns (B, T).
    """
    vals = fpx2_decode_ref(words, tile * tile)
    tiles = vals.reshape(words.shape[0], tile, tile)
    return dense_tile_mvm_ref(tiles, xs)


# ---------------------------------------------------------------------------
# numpy-side encode helpers (test/data-prep only)
# ---------------------------------------------------------------------------

def fpx2_encode_np(values):
    """Truncate float32 values to their top 2 bytes (round-to-nearest) and
    pack two per uint32 word, little-endian — the layout the rust runtime
    ships to the kernel. `values` is a flat float array of even length."""
    v = np.asarray(values, dtype=np.float32)
    assert v.size % 2 == 0, "pad to even length"
    bits = v.view(np.uint32)
    rounded = bits + np.uint32(0x8000)
    # avoid carries into inf/nan: fall back to plain truncation there
    over = ~np.isfinite(((rounded >> np.uint32(16)) << np.uint32(16)).view(np.float32))
    half = np.where(over, bits >> np.uint32(16), rounded >> np.uint32(16)).astype(np.uint32)
    lo = half[0::2]
    hi = half[1::2]
    return (lo | (hi << np.uint32(16))).astype(np.uint32)


def fpx2_decode_np(words, n_values):
    """numpy inverse of fpx2_encode_np (exact decode of the truncated data)."""
    w = np.ascontiguousarray(np.asarray(words, dtype=np.uint32))
    lo = ((w & np.uint32(0xFFFF)) << np.uint32(16)).view(np.float32)
    hi = (w & np.uint32(0xFFFF0000)).view(np.float32)
    out = np.empty(n_values, dtype=np.float32)
    out[0::2] = lo
    out[1::2] = hi
    return out
