"""L2 model shapes + AOT lowering sanity: every artifact lowers to HLO text
that the rust side's parser conventions expect (non-empty, ENTRY present,
tuple return)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref


def test_model_shapes():
    b, t, k = 4, 16, 3
    rng = np.random.default_rng(0)
    tiles = jnp.asarray(rng.standard_normal((b, t, t), dtype=np.float32))
    xs = jnp.asarray(rng.standard_normal((b, t), dtype=np.float32))
    u = jnp.asarray(rng.standard_normal((b, t, k), dtype=np.float32))
    v = jnp.asarray(rng.standard_normal((b, t, k), dtype=np.float32))

    (yd,) = model.dense_tile_model(tiles, xs)
    assert yd.shape == (b, t)
    (yl,) = model.lowrank_tile_model(u, v, xs)
    assert yl.shape == (b, t)
    yd2, yl2, ysum = model.combined_leaf_model(tiles, u, v, xs, xs)
    np.testing.assert_allclose(np.asarray(ysum), np.asarray(yd2) + np.asarray(yl2), rtol=1e-6)


def test_combined_model_is_consistent_with_refs():
    b, t, k = 2, 8, 2
    rng = np.random.default_rng(1)
    tiles = jnp.asarray(rng.standard_normal((b, t, t), dtype=np.float32))
    xs = jnp.asarray(rng.standard_normal((b, t), dtype=np.float32))
    u = jnp.asarray(rng.standard_normal((b, t, k), dtype=np.float32))
    v = jnp.asarray(rng.standard_normal((b, t, k), dtype=np.float32))
    yd, yl, _ = model.combined_leaf_model(tiles, u, v, xs, xs)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(ref.dense_tile_mvm_ref(tiles, xs)), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(yl), np.asarray(ref.lowrank_tile_mvm_ref(u, v, xs)), rtol=1e-4, atol=1e-4)


def test_aot_lowering_produces_hlo_text():
    arts = aot.lower_all()
    assert set(arts) == {"dense_tile_mvm", "fpx_tile_mvm_b2", "lowrank_tile_mvm", "combined_leaf_mvm"}
    for name, text in arts.items():
        assert "ENTRY" in text, name
        assert "ROOT" in text, name
        # tuple return (rust unwraps with to_tuple)
        assert "tuple" in text.lower(), name


def test_fpx_artifact_has_u32_parameter():
    arts = aot.lower_all()
    assert "u32[" in arts["fpx_tile_mvm_b2"], "expected uint32 packed input"
