"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
with hypothesis sweeping shapes and data distributions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dense import dense_tile_mvm
from compile.kernels.fpx import fpx2_tile_mvm
from compile.kernels.lowrank import lowrank_tile_mvm


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


# ---------------------------------------------------------------------------
# dense tile kernel
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=8),
    t=st.sampled_from([4, 8, 16, 32, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_dense_kernel_matches_ref(b, t, seed):
    tiles = rand((b, t, t), seed)
    xs = rand((b, t), seed + 1)
    got = dense_tile_mvm(tiles, xs)
    want = ref.dense_tile_mvm_ref(tiles, xs)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dense_kernel_identity():
    t = 16
    tiles = jnp.stack([jnp.eye(t, dtype=jnp.float32)] * 3)
    xs = rand((3, t), 7)
    got = dense_tile_mvm(tiles, xs)
    np.testing.assert_allclose(got, xs, rtol=1e-6)


def test_dense_kernel_zero_tiles():
    got = dense_tile_mvm(jnp.zeros((2, 8, 8), jnp.float32), rand((2, 8), 9))
    np.testing.assert_array_equal(np.asarray(got), 0.0)


# ---------------------------------------------------------------------------
# low-rank tile kernel
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=6),
    t=st.sampled_from([8, 16, 64]),
    k=st.sampled_from([1, 4, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_lowrank_kernel_matches_ref(b, t, k, seed):
    u = rand((b, t, k), seed)
    v = rand((b, t, k), seed + 1)
    xs = rand((b, t), seed + 2)
    got = lowrank_tile_mvm(u, v, xs)
    want = ref.lowrank_tile_mvm_ref(u, v, xs)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_lowrank_matches_dense_product():
    b, t, k = 2, 16, 3
    u = rand((b, t, k), 11)
    v = rand((b, t, k), 12)
    xs = rand((b, t), 13)
    dense = jnp.einsum("bik,bjk->bij", u, v)
    want = ref.dense_tile_mvm_ref(dense, xs)
    got = lowrank_tile_mvm(u, v, xs)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# FPX decode-and-multiply kernel
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=4),
    t=st.sampled_from([8, 16, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_fpx_kernel_matches_ref(b, t, seed, scale):
    rng = np.random.default_rng(seed)
    vals = rng.standard_normal((b, t * t), dtype=np.float32) * scale
    words = np.stack([ref.fpx2_encode_np(row) for row in vals])
    xs = rand((b, t), seed + 1)
    got = fpx2_tile_mvm(jnp.asarray(words), xs, t)
    want = ref.fpx2_tile_mvm_ref(jnp.asarray(words), xs, t)
    # f32 accumulation-order differences scale with the data magnitude
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3 * scale * t)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=512),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fpx_encode_decode_error_bound(n, seed):
    # encode/decode roundtrip has bf16-level relative error (≤ 2^-8 with RTN)
    rng = np.random.default_rng(seed)
    vals = rng.standard_normal(2 * n).astype(np.float32)
    words = ref.fpx2_encode_np(vals)
    dec = ref.fpx2_decode_np(words, 2 * n)
    rel = np.abs(dec - vals) / np.maximum(np.abs(vals), 1e-30)
    assert rel.max() <= 2.0**-8, rel.max()


def test_fpx_jnp_decode_matches_np():
    rng = np.random.default_rng(3)
    vals = rng.standard_normal(128).astype(np.float32)
    words = ref.fpx2_encode_np(vals)
    dec_np = ref.fpx2_decode_np(words, 128)
    dec_jnp = np.asarray(ref.fpx2_decode_ref(jnp.asarray(words), 128))
    np.testing.assert_array_equal(dec_np, dec_jnp)


def test_fpx_kernel_decodes_exactly_the_truncated_values():
    # kernel(words) must equal matvec(decoded values) bit-for-bit at f32
    t = 16
    rng = np.random.default_rng(5)
    vals = rng.standard_normal(t * t).astype(np.float32)
    words = ref.fpx2_encode_np(vals)[None, :]
    dec = ref.fpx2_decode_np(words[0], t * t).reshape(t, t)
    xs = rng.standard_normal(t).astype(np.float32)[None, :]
    got = np.asarray(fpx2_tile_mvm(jnp.asarray(words), jnp.asarray(xs), t))[0]
    want = dec @ xs[0]
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_fpx_compression_halves_bytes():
    t = 64
    n = t * t
    words = ref.fpx2_encode_np(np.ones(n, dtype=np.float32))
    assert words.nbytes == n * 2  # 2 bytes/value vs 4 for f32
