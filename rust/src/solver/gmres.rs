//! Restarted GMRES for non-symmetric operators (log-kernel, adjoint systems)
//! — complements CG as the second iterative consumer of the H-MVM kernel.

use super::{LinOp, SolveStats};
use crate::la::{blas, DMatrix};
use crate::util::Timer;

/// GMRES(m) with Givens rotations. Returns (solution, stats).
pub fn gmres(op: &dyn LinOp, b: &[f64], tol: f64, restart: usize, max_iter: usize) -> (Vec<f64>, SolveStats) {
    let n = op.dim();
    assert_eq!(b.len(), n);
    let timer = Timer::start();
    let m = restart.max(1);
    let mut x = vec![0.0; n];
    let bnorm = blas::nrm2(b).max(f64::MIN_POSITIVE);
    let mut history = Vec::new();
    let mut total_it = 0;
    let mut converged = false;

    'outer: while total_it < max_iter {
        // r = b - A x
        let mut r = vec![0.0; n];
        op.apply(&x, &mut r);
        for i in 0..n {
            r[i] = b[i] - r[i];
        }
        let beta = blas::nrm2(&r);
        history.push(beta / bnorm);
        if beta / bnorm < tol {
            converged = true;
            break;
        }

        // Arnoldi with modified Gram-Schmidt
        let mut v = DMatrix::zeros(n, m + 1);
        for i in 0..n {
            v.col_mut(0)[i] = r[i] / beta;
        }
        let mut h = DMatrix::zeros(m + 1, m);
        let mut cs = vec![0.0; m];
        let mut sn = vec![0.0; m];
        let mut g = vec![0.0; m + 1];
        g[0] = beta;

        let mut k_used = 0;
        for k in 0..m {
            if total_it >= max_iter {
                break;
            }
            total_it += 1;
            // w = A v_k
            let mut w = vec![0.0; n];
            op.apply(v.col(k), &mut w);
            for j in 0..=k {
                let hjk = blas::dot(v.col(j), &w);
                h[(j, k)] = hjk;
                blas::axpy(-hjk, v.col(j), &mut w);
            }
            let wn = blas::nrm2(&w);
            h[(k + 1, k)] = wn;
            if wn > 1e-14 {
                for i in 0..n {
                    v.col_mut(k + 1)[i] = w[i] / wn;
                }
            }
            // apply previous Givens rotations to column k
            for j in 0..k {
                let t = cs[j] * h[(j, k)] + sn[j] * h[(j + 1, k)];
                h[(j + 1, k)] = -sn[j] * h[(j, k)] + cs[j] * h[(j + 1, k)];
                h[(j, k)] = t;
            }
            // new rotation to eliminate h[k+1,k]
            let denom = (h[(k, k)] * h[(k, k)] + h[(k + 1, k)] * h[(k + 1, k)]).sqrt();
            if denom == 0.0 {
                // the Krylov direction contributed nothing (rank-deficient
                // operator): the rotation was NOT applied, so g[k+1] still
                // holds its initial 0.0 and the cheap residual estimate is
                // stale — it must not be trusted (it used to read as
                // "converged"). Leave this cycle; the outer loop recomputes
                // the true residual ‖b − A x‖.
                break;
            }
            cs[k] = h[(k, k)] / denom;
            sn[k] = h[(k + 1, k)] / denom;
            h[(k, k)] = denom;
            h[(k + 1, k)] = 0.0;
            g[k + 1] = -sn[k] * g[k];
            g[k] *= cs[k];
            k_used = k + 1;
            // the residual estimate is valid only because the rotation above
            // was applied — it is the one spot g[k+1] is written
            let rel = g[k + 1].abs() / bnorm;
            history.push(rel);
            if rel < tol {
                break;
            }
            if wn <= 1e-14 {
                break; // happy breakdown
            }
        }

        // back substitution: y = H(1:k,1:k)^{-1} g(1:k)
        let k = k_used;
        let mut yk = vec![0.0; k];
        for i in (0..k).rev() {
            let mut s = g[i];
            for j in (i + 1)..k {
                s -= h[(i, j)] * yk[j];
            }
            yk[i] = if h[(i, i)].abs() > 0.0 { s / h[(i, i)] } else { 0.0 };
        }
        for j in 0..k {
            blas::axpy(yk[j], v.col(j), &mut x);
        }
        if *history.last().unwrap() < tol {
            converged = true;
            break 'outer;
        }
    }

    let stats = SolveStats {
        iterations: total_it,
        residual: *history.last().unwrap_or(&1.0),
        residual_history: history,
        seconds: timer.elapsed(),
        converged,
    };
    (x, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::{gemv, DMatrix};
    use crate::util::Rng;

    #[test]
    fn gmres_solves_nonsymmetric_system() {
        let n = 40;
        let mut rng = Rng::new(181);
        // well-conditioned nonsymmetric: A = I + 0.3·R
        let r = DMatrix::random(n, n, &mut rng);
        let apply = move |x: &[f64], y: &mut [f64]| {
            for i in 0..n {
                y[i] += x[i];
            }
            gemv(0.3 / (n as f64).sqrt(), &r, x, y);
        };
        let op = (n, apply);
        let xstar = rng.vector(n);
        let mut b = vec![0.0; n];
        op.apply(&xstar, &mut b);
        let (x, stats) = gmres(&op, &b, 1e-10, 30, 500);
        assert!(stats.converged, "residual {}", stats.residual);
        for i in 0..n {
            assert!((x[i] - xstar[i]).abs() < 1e-7, "{} vs {}", x[i], xstar[i]);
        }
    }

    #[test]
    fn gmres_with_restart() {
        let n = 50;
        let mut rng = Rng::new(182);
        let r = DMatrix::random(n, n, &mut rng);
        let apply = move |x: &[f64], y: &mut [f64]| {
            for i in 0..n {
                y[i] += 2.0 * x[i];
            }
            gemv(0.2 / (n as f64).sqrt(), &r, x, y);
        };
        let op = (n, apply);
        let b = rng.vector(n);
        // tiny restart forces several outer cycles
        let (_, stats) = gmres(&op, &b, 1e-8, 5, 2000);
        assert!(stats.converged, "residual {}", stats.residual);
    }

    #[test]
    fn gmres_zero_operator_does_not_spuriously_converge() {
        // regression: A = 0 makes the whole Hessenberg column zero, the
        // Givens update is skipped, and the stale g[k+1] = 0.0 used to be
        // read as the residual — reporting convergence with x = 0 although
        // r = b ≠ 0
        let n = 8;
        let apply = |_x: &[f64], _y: &mut [f64]| {};
        let op = (n, apply);
        let b = vec![1.0; n];
        let (x, stats) = gmres(&op, &b, 1e-10, 5, 50);
        assert!(!stats.converged, "spurious convergence on the zero operator");
        assert!((stats.residual - 1.0).abs() < 1e-12, "residual {}", stats.residual);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gmres_rank_deficient_consistent_system_converges() {
        // A = diag(d_0..d_{n-2}, 0) with b in range(A): the Krylov space
        // stays inside the range, so GMRES must still converge after the
        // stale-residual restructuring
        let n = 12;
        let apply = move |x: &[f64], y: &mut [f64]| {
            for i in 0..n - 1 {
                y[i] += (1.0 + i as f64 / n as f64) * x[i];
            }
        };
        let op = (n, apply);
        let mut b = vec![0.0; n];
        for v in b.iter_mut().take(n - 1) {
            *v = 1.0;
        }
        let (x, stats) = gmres(&op, &b, 1e-10, n, 200);
        assert!(stats.converged, "residual {}", stats.residual);
        let mut ax = vec![0.0; n];
        op.apply(&x, &mut ax);
        for i in 0..n {
            assert!((ax[i] - b[i]).abs() < 1e-8, "row {i}: {} vs {}", ax[i], b[i]);
        }
    }

    #[test]
    fn gmres_singular_inconsistent_reports_nonconvergence() {
        // b has a component outside range(A): the residual cannot go below
        // that component's share — the solver must not claim convergence
        let n = 6;
        let apply = move |x: &[f64], y: &mut [f64]| {
            for i in 0..n - 1 {
                y[i] += x[i];
            }
        };
        let op = (n, apply);
        let mut b = vec![0.0; n];
        b[n - 1] = 1.0; // entirely outside the range
        let (_, stats) = gmres(&op, &b, 1e-10, 6, 60);
        assert!(!stats.converged, "residual {}", stats.residual);
        assert!(stats.residual > 0.5);
    }

    #[test]
    fn gmres_on_identity_converges_immediately() {
        let n = 10;
        let apply = |x: &[f64], y: &mut [f64]| {
            for i in 0..n {
                y[i] += x[i];
            }
        };
        let op = (n, apply);
        let b = vec![1.0; n];
        let (x, stats) = gmres(&op, &b, 1e-12, 10, 100);
        assert!(stats.converged);
        assert!(stats.iterations <= 2);
        for v in &x {
            assert!((v - 1.0).abs() < 1e-10);
        }
    }
}
