//! Iterative solvers on hierarchical-matrix operators — the e2e validation
//! path (the paper's motivation: MVM is the kernel of iterative methods).

mod gmres;

pub use gmres::gmres;

use crate::util::Timer;

/// A linear operator y = A x (vectors in internal ordering).
pub trait LinOp: Sync {
    fn dim(&self) -> usize;
    fn apply(&self, x: &[f64], y: &mut [f64]);
}

impl<F: Fn(&[f64], &mut [f64]) + Sync> LinOp for (usize, F) {
    fn dim(&self) -> usize {
        self.0
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        (self.1)(x, y)
    }
}

/// Convergence report of an iterative solve.
#[derive(Clone, Debug)]
pub struct SolveStats {
    pub iterations: usize,
    pub residual: f64,
    pub residual_history: Vec<f64>,
    pub seconds: f64,
    pub converged: bool,
}

/// Conjugate gradients for SPD operators. Returns the solution and stats.
pub fn cg(op: &dyn LinOp, b: &[f64], tol: f64, max_iter: usize) -> (Vec<f64>, SolveStats) {
    let n = op.dim();
    assert_eq!(b.len(), n);
    let timer = Timer::start();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let bnorm = norm(b).max(f64::MIN_POSITIVE);
    let mut rr = dot(&r, &r);
    let mut history = vec![rr.sqrt() / bnorm];
    let mut converged = false;
    let mut it = 0;
    while it < max_iter {
        ap.fill(0.0);
        op.apply(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap <= 0.0 {
            break; // not SPD (or numerical breakdown)
        }
        let alpha = rr / pap;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rr_new = dot(&r, &r);
        it += 1;
        let rel = rr_new.sqrt() / bnorm;
        history.push(rel);
        if rel < tol {
            converged = true;
            break;
        }
        let beta = rr_new / rr;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rr = rr_new;
    }
    let stats = SolveStats { iterations: it, residual: *history.last().unwrap(), residual_history: history, seconds: timer.elapsed(), converged };
    (x, stats)
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    crate::la::dot(a, b)
}

fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::{gemv, DMatrix};
    use crate::util::Rng;

    #[test]
    fn cg_solves_spd_system() {
        // SPD matrix A = Q D Q^T implicit via B^T B + I
        let n = 40;
        let mut rng = Rng::new(151);
        let b_mat = DMatrix::random(n, n, &mut rng);
        let apply = |x: &[f64], y: &mut [f64]| {
            let mut t = vec![0.0; n];
            gemv(1.0, &b_mat, x, &mut t);
            let bt = b_mat.transpose();
            gemv(1.0, &bt, &t, y);
            for i in 0..n {
                y[i] += x[i];
            }
        };
        let op = (n, apply);
        let xstar = rng.vector(n);
        let mut rhs = vec![0.0; n];
        op.apply(&xstar, &mut rhs);
        let (x, stats) = cg(&op, &rhs, 1e-12, 500);
        assert!(stats.converged, "residual {}", stats.residual);
        for i in 0..n {
            assert!((x[i] - xstar[i]).abs() < 1e-6, "{} vs {}", x[i], xstar[i]);
        }
    }

    #[test]
    fn residual_history_is_decreasing_overall() {
        let n = 30;
        let mut rng = Rng::new(152);
        let b_mat = DMatrix::random(n, n, &mut rng);
        let apply = |x: &[f64], y: &mut [f64]| {
            let mut t = vec![0.0; n];
            gemv(1.0, &b_mat, x, &mut t);
            let bt = b_mat.transpose();
            gemv(1.0, &bt, &t, y);
            for i in 0..n {
                y[i] += 0.1 * x[i];
            }
        };
        let op = (n, apply);
        let rhs = rng.vector(n);
        let (_, stats) = cg(&op, &rhs, 1e-10, 1000);
        let first = stats.residual_history[0];
        let last = *stats.residual_history.last().unwrap();
        assert!(last < first * 1e-6);
    }
}
