//! Adjoint product y += α·Mᵀ·x (Remark 3.2): the collision-free traversal of
//! Algorithm 3 applied to the *column* cluster tree — block columns play the
//! role of block rows, every leaf kernel runs transposed.

use super::kernels::apply_block_transposed;
use super::{SharedVec, SPAWN_LEVELS};
use crate::hmatrix::HMatrix;
use crate::par::ThreadPool;

/// y += alpha · Mᵀ · x, collision free over block columns.
pub fn mvm_transposed(alpha: f64, m: &HMatrix, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), m.nrows());
    assert_eq!(y.len(), m.ncols());
    let yy = SharedVec::new(y);
    let pool = ThreadPool::global();
    pool.scope(|s| rec(s, alpha, m, x, m.bt.col_ct.root(), yy, 0));
}

fn rec<'e>(s: &crate::par::Scope<'e>, alpha: f64, m: &'e HMatrix, x: &'e [f64], sigma: usize, y: SharedVec, depth: usize) {
    let bt = &m.bt;
    let ct = &bt.col_ct;
    let cr = ct.node(sigma).range();
    // SAFETY: same traversal invariant as Algorithm 3, over block columns.
    let yt = unsafe { y.range_mut(cr) };
    for &b in &bt.col_blocks[sigma] {
        let nd = bt.node(b);
        let rr = bt.row_ct.node(nd.row).range();
        let blk = m.blocks[b].as_ref().expect("missing leaf");
        apply_block_transposed(alpha, blk, &x[rr], yt);
    }
    for &c in &ct.node(sigma).children {
        if depth < SPAWN_LEVELS {
            s.spawn(move |s2| rec(s2, alpha, m, x, c, y, depth + 1));
        } else {
            rec(s, alpha, m, x, c, y, depth + 1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{BlockTree, ClusterTree, StdAdmissibility};
    use crate::compress::CompressionConfig;
    use crate::geometry::icosphere;
    use crate::kernelfn::{LaplaceSlp, MatrixGen};
    use crate::lowrank::AcaOptions;
    use crate::util::Rng;
    use std::sync::Arc;

    fn problem() -> HMatrix {
        let geom = icosphere(2);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), 16));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-8))
    }

    #[test]
    fn adjoint_matches_dense_transpose() {
        let h = problem();
        let d = h.to_dense();
        let mut rng = Rng::new(171);
        let x = rng.vector(h.nrows());
        let mut y = vec![0.0; h.ncols()];
        mvm_transposed(1.5, &h, &x, &mut y);
        let dt = d.transpose();
        let mut want = vec![0.0; h.ncols()];
        crate::la::gemv(1.5, &dt, &x, &mut want);
        for i in 0..y.len() {
            assert!((y[i] - want[i]).abs() < 1e-10, "i={i}");
        }
    }

    #[test]
    fn adjoint_of_symmetric_operator_matches_forward() {
        // Laplace SLP with symmetric quadrature: Mᵀ ≈ M
        let h = problem();
        let mut rng = Rng::new(172);
        let x = rng.vector(h.nrows());
        let mut y1 = vec![0.0; h.nrows()];
        let mut y2 = vec![0.0; h.nrows()];
        crate::mvm::mvm(1.0, &h, &x, &mut y1, crate::mvm::MvmAlgorithm::Seq);
        mvm_transposed(1.0, &h, &x, &mut y2);
        let n1: f64 = y1.iter().map(|v| v * v).sum::<f64>().sqrt();
        let d: f64 = y1.iter().zip(&y2).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        // symmetric up to the low-rank approximation error
        assert!(d < 1e-5 * n1, "d={d} n={n1}");
    }

    #[test]
    fn adjoint_works_compressed() {
        let h = problem();
        let mut hz = h.clone();
        hz.compress(&CompressionConfig::aflp(1e-10));
        let mut rng = Rng::new(173);
        let x = rng.vector(h.nrows());
        let mut y1 = vec![0.0; h.ncols()];
        let mut y2 = vec![0.0; h.ncols()];
        mvm_transposed(1.0, &h, &x, &mut y1);
        mvm_transposed(1.0, &hz, &x, &mut y2);
        let n1: f64 = y1.iter().map(|v| v * v).sum::<f64>().sqrt();
        let d: f64 = y1.iter().zip(&y2).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        assert!(d < 1e-6 * n1);
    }
}
