//! Per-block MVM kernels, uncompressed and compressed (Algorithm 8 and §4.3).
//!
//! The compressed kernels are *memory accessors*: matrix data is never fully
//! decompressed. Two execution modes exist, selected once per process
//! (`HMATC_CODEC_KERNELS`, default `fused`):
//!
//! * **fused** — a [`DecodeCursor`] resolves a blob's codec parameters once,
//!   then fused decode–FMA kernels (`dot`/`axpy` and their panel variants)
//!   keep decoded lanes in registers: no stack buffer between "decompress"
//!   and "FMA", one streaming pass per column.
//! * **blockwise** — the legacy scheme of §4.3 / Amestoy et al.: decompress
//!   up to 64 contiguous entries into a stack buffer, then a second pass for
//!   the FMA. Kept for the ablation bench (`ablation_codec_kernels`) and as
//!   a debugging fallback.
//!
//! Both modes run on the runtime-dispatched SIMD decode kernels
//! ([`crate::compress::dispatch`]); results are deterministic and bitwise
//! identical across plan executors either way.

use crate::compress::dispatch::{self, KernelMode};
use crate::compress::{Blob, DecodeCursor, ZLowRankValr};
use crate::hmatrix::{BlockData, ZDense, ZLowRankDirect};
use crate::la::{blas, DMatrix};
use crate::lowrank::LowRank;

/// Chunk length for blockwise streamed decompression (paper: up to 64
/// contiguous entries of a single column).
pub const CHUNK: usize = 64;

/// Whether the fused decode–FMA kernels are selected (vs legacy blockwise).
#[inline]
fn fused() -> bool {
    dispatch::kernel_mode() == KernelMode::Fused
}

/// Whether a panel apply should take the fused path: fused mode *and* a batch
/// narrow enough that per-RHS accumulators fit one register-resident pass
/// ([`dispatch::PANEL_FUSE_MAX`]). Wider batches decode each chunk exactly
/// once for all right-hand sides through the blockwise layout — re-decoding
/// the column per 8-RHS group would cost more than the buffer round trip.
#[inline]
fn fused_panel(nrhs: usize) -> bool {
    nrhs <= dispatch::PANEL_FUSE_MAX && fused()
}

/// y += alpha · B · x for any block representation.
///
/// Thin wrapper around [`apply_block_scratch`] that allocates the rank-sized
/// temporary itself — hot paths (the plan executor) pass a reusable buffer
/// instead.
pub fn apply_block(alpha: f64, b: &BlockData, x: &[f64], y: &mut [f64]) {
    let mut t = vec![0.0; b.rank()];
    apply_block_scratch(alpha, b, x, y, &mut t);
}

/// y += alpha · B · x with a caller-provided scratch buffer of at least
/// `b.rank()` values; performs no heap allocation for any representation.
pub fn apply_block_scratch(alpha: f64, b: &BlockData, x: &[f64], y: &mut [f64], scratch: &mut [f64]) {
    match b {
        BlockData::Dense(m) => blas::gemv(alpha, m, x, y),
        BlockData::LowRank(lr) => lowrank_mvm_scratch(alpha, lr, x, y, scratch),
        BlockData::ZDense(z) => zgemv_blocked(alpha, z, x, y),
        BlockData::ZLowRank(z) => zlowrank_mvm_scratch(alpha, z, x, y, scratch),
        BlockData::ZLowRankValr(z) => valr_mvm(alpha, z, x, y),
    }
}

/// y += alpha · Bᵀ · x (adjoint product, Remark 3.2). Thin allocating wrapper
/// around [`apply_block_transposed_scratch`].
pub fn apply_block_transposed(alpha: f64, b: &BlockData, x: &[f64], y: &mut [f64]) {
    let mut t = vec![0.0; b.rank()];
    apply_block_transposed_scratch(alpha, b, x, y, &mut t);
}

/// y += alpha · Bᵀ · x with caller-provided scratch (≥ `b.rank()` values);
/// allocation free.
pub fn apply_block_transposed_scratch(alpha: f64, b: &BlockData, x: &[f64], y: &mut [f64], scratch: &mut [f64]) {
    match b {
        BlockData::Dense(m) => blas::gemv_transposed(alpha, m, x, y),
        BlockData::LowRank(lr) => {
            // (U Vᵀ)ᵀ x = V (Uᵀ x)
            let k = lr.rank();
            if k == 0 {
                return;
            }
            let t = &mut scratch[..k];
            t.fill(0.0);
            blas::gemv_transposed(1.0, &lr.u, x, t);
            blas::gemv(alpha, &lr.v, t, y);
        }
        BlockData::ZDense(z) => zgemv_t_blocked(alpha, z, x, y),
        BlockData::ZLowRank(z) => {
            let k = z.rank;
            if k == 0 {
                return;
            }
            let t = &mut scratch[..k];
            t.fill(0.0);
            stream_dot_cols(&z.u, z.nrows, k, x, t);
            stream_axpy_cols(&z.v, z.ncols, k, alpha, t, y);
        }
        BlockData::ZLowRankValr(z) => {
            let k = z.rank();
            for i in 0..k {
                let mut s = stream_dot(&z.wcols[i], x);
                s *= z.sigma[i] * alpha;
                if s != 0.0 {
                    stream_axpy(&z.xcols[i], s, y);
                }
            }
        }
    }
}

/// y += alpha · U Vᵀ x (two slim gemvs). Thin allocating wrapper around
/// [`lowrank_mvm_scratch`].
pub fn lowrank_mvm(alpha: f64, lr: &LowRank, x: &[f64], y: &mut [f64]) {
    let mut t = vec![0.0; lr.rank()];
    lowrank_mvm_scratch(alpha, lr, x, y, &mut t);
}

/// y += alpha · U Vᵀ x with caller-provided scratch (≥ rank values).
pub fn lowrank_mvm_scratch(alpha: f64, lr: &LowRank, x: &[f64], y: &mut [f64], scratch: &mut [f64]) {
    let k = lr.rank();
    if k == 0 {
        return;
    }
    let t = &mut scratch[..k];
    t.fill(0.0);
    blas::gemv_transposed(1.0, &lr.v, x, t);
    blas::gemv(alpha, &lr.u, t, y);
}

/// Algorithm 8, *direct* variant: per-entry random-access decompression. The
/// codec parameters are resolved **once** through a [`DecodeCursor`] (the
/// old per-element `CodecParams` re-match made this kernel look worse than
/// the memory model says it should). Kept for the ablation bench.
pub fn zgemv_direct(alpha: f64, z: &ZDense, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), z.ncols);
    debug_assert_eq!(y.len(), z.nrows);
    let n = z.nrows;
    let cur = DecodeCursor::new(&z.blob);
    for j in 0..z.ncols {
        let axj = alpha * x[j];
        if axj == 0.0 {
            continue;
        }
        let base = j * n;
        for (i, yi) in y.iter_mut().enumerate() {
            *yi += cur.get(base + i) * axj;
        }
    }
}

/// Compressed gemv y += alpha · D · x: fused decode–FMA by default, legacy
/// blockwise scheme under `HMATC_CODEC_KERNELS=blockwise`.
pub fn zgemv_blocked(alpha: f64, z: &ZDense, x: &[f64], y: &mut [f64]) {
    if fused() {
        zgemv_fused(alpha, z, x, y);
    } else {
        zgemv_blockwise(alpha, z, x, y);
    }
}

/// Fused compressed gemv: one cursor resolution per matrix, one streaming
/// decode–FMA pass per column — decoded lanes never touch a buffer.
pub fn zgemv_fused(alpha: f64, z: &ZDense, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), z.ncols);
    debug_assert_eq!(y.len(), z.nrows);
    let n = z.nrows;
    let mut cur = DecodeCursor::new(&z.blob);
    for (j, &xj) in x.iter().enumerate() {
        let axj = alpha * xj;
        if axj == 0.0 {
            continue;
        }
        cur.seek(j * n);
        cur.axpy(axj, y);
    }
}

/// Algorithm 8, blockwise variant (§4.3 / Amestoy et al.): decompress up to
/// 64 contiguous entries of a column into a stack buffer, then FMA.
pub fn zgemv_blockwise(alpha: f64, z: &ZDense, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), z.ncols);
    debug_assert_eq!(y.len(), z.nrows);
    let n = z.nrows;
    let mut buf = [0.0f64; CHUNK];
    for j in 0..z.ncols {
        let axj = alpha * x[j];
        if axj == 0.0 {
            continue;
        }
        let base = j * n;
        let mut i = 0;
        while i < n {
            let len = CHUNK.min(n - i);
            z.blob.decompress_range(base + i, base + i + len, &mut buf[..len]);
            blas::axpy(axj, &buf[..len], &mut y[i..i + len]);
            i += len;
        }
    }
}

/// Transposed compressed gemv: y += alpha · Dᵀ x (mode-dispatched).
pub fn zgemv_t_blocked(alpha: f64, z: &ZDense, x: &[f64], y: &mut [f64]) {
    if fused() {
        zgemv_t_fused(alpha, z, x, y);
    } else {
        zgemv_t_blockwise(alpha, z, x, y);
    }
}

/// Fused transposed compressed gemv: one decode–dot pass per column.
pub fn zgemv_t_fused(alpha: f64, z: &ZDense, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), z.nrows);
    debug_assert_eq!(y.len(), z.ncols);
    let n = z.nrows;
    let mut cur = DecodeCursor::new(&z.blob);
    for (j, yj) in y.iter_mut().enumerate() {
        cur.seek(j * n);
        *yj += alpha * cur.dot(x);
    }
}

/// Blockwise transposed compressed gemv (legacy stack-buffer scheme).
pub fn zgemv_t_blockwise(alpha: f64, z: &ZDense, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), z.nrows);
    debug_assert_eq!(y.len(), z.ncols);
    let n = z.nrows;
    let mut buf = [0.0f64; CHUNK];
    for j in 0..z.ncols {
        let base = j * n;
        let mut acc = 0.0;
        let mut i = 0;
        while i < n {
            let len = CHUNK.min(n - i);
            z.blob.decompress_range(base + i, base + i + len, &mut buf[..len]);
            acc += blas::dot(&buf[..len], &x[i..i + len]);
            i += len;
        }
        y[j] += alpha * acc;
    }
}

/// y += alpha · U Vᵀ x with fixed-precision compressed factors, streamed.
/// Thin allocating wrapper around [`zlowrank_mvm_scratch`].
pub fn zlowrank_mvm(alpha: f64, z: &ZLowRankDirect, x: &[f64], y: &mut [f64]) {
    let mut t = vec![0.0; z.rank];
    zlowrank_mvm_scratch(alpha, z, x, y, &mut t);
}

/// Streamed compressed low-rank MVM with caller-provided scratch (≥ rank).
pub fn zlowrank_mvm_scratch(alpha: f64, z: &ZLowRankDirect, x: &[f64], y: &mut [f64], scratch: &mut [f64]) {
    let k = z.rank;
    if k == 0 {
        return;
    }
    let t = &mut scratch[..k];
    t.fill(0.0);
    stream_dot_cols(&z.v, z.ncols, k, x, t);
    stream_axpy_cols(&z.u, z.nrows, k, alpha, t, y);
}

/// y += alpha · W diag(σ) Xᵀ x with VALR storage, streamed column-wise.
pub fn valr_mvm(alpha: f64, z: &ZLowRankValr, x: &[f64], y: &mut [f64]) {
    for i in 0..z.rank() {
        let mut s = stream_dot(&z.xcols[i], x);
        s *= z.sigma[i] * alpha;
        if s != 0.0 {
            stream_axpy(&z.wcols[i], s, y);
        }
    }
}

/// t[j] += dot(col_j, x) for a column-major compressed matrix blob (one
/// cursor resolution per blob, one fused pass per column).
pub(crate) fn stream_dot_cols(blob: &Blob, nrows: usize, ncols: usize, x: &[f64], t: &mut [f64]) {
    if fused() {
        let mut cur = DecodeCursor::new(blob);
        for (j, tj) in t.iter_mut().enumerate().take(ncols) {
            cur.seek(j * nrows);
            *tj += cur.dot(x);
        }
        return;
    }
    let mut buf = [0.0f64; CHUNK];
    for j in 0..ncols {
        let base = j * nrows;
        let mut acc = 0.0;
        let mut i = 0;
        while i < nrows {
            let len = CHUNK.min(nrows - i);
            blob.decompress_range(base + i, base + i + len, &mut buf[..len]);
            acc += blas::dot(&buf[..len], &x[i..i + len]);
            i += len;
        }
        t[j] += acc;
    }
}

/// y += alpha * Σ_j t[j] * col_j for a column-major compressed matrix blob.
pub(crate) fn stream_axpy_cols(blob: &Blob, nrows: usize, ncols: usize, alpha: f64, t: &[f64], y: &mut [f64]) {
    if fused() {
        let mut cur = DecodeCursor::new(blob);
        for (j, &tj) in t.iter().enumerate().take(ncols) {
            let w = alpha * tj;
            if w == 0.0 {
                continue;
            }
            cur.seek(j * nrows);
            cur.axpy(w, y);
        }
        return;
    }
    let mut buf = [0.0f64; CHUNK];
    for j in 0..ncols {
        let w = alpha * t[j];
        if w == 0.0 {
            continue;
        }
        let base = j * nrows;
        let mut i = 0;
        while i < nrows {
            let len = CHUNK.min(nrows - i);
            blob.decompress_range(base + i, base + i + len, &mut buf[..len]);
            blas::axpy(w, &buf[..len], &mut y[i..i + len]);
            i += len;
        }
    }
}

/// dot(blob, x) over a compressed vector (used by the VALR applies and the
/// cluster-basis / nested-basis single-vector paths).
pub(crate) fn stream_dot(blob: &Blob, x: &[f64]) -> f64 {
    if fused() {
        return DecodeCursor::new(blob).dot(x);
    }
    let mut buf = [0.0f64; CHUNK];
    let n = blob.n;
    let mut acc = 0.0;
    let mut i = 0;
    while i < n {
        let len = CHUNK.min(n - i);
        blob.decompress_range(i, i + len, &mut buf[..len]);
        acc += blas::dot(&buf[..len], &x[i..i + len]);
        i += len;
    }
    acc
}

/// y += w * blob over a compressed vector.
pub(crate) fn stream_axpy(blob: &Blob, w: f64, y: &mut [f64]) {
    if fused() {
        DecodeCursor::new(blob).axpy(w, y);
        return;
    }
    let mut buf = [0.0f64; CHUNK];
    let n = blob.n;
    let mut i = 0;
    while i < n {
        let len = CHUNK.min(n - i);
        blob.decompress_range(i, i + len, &mut buf[..len]);
        blas::axpy(w, &buf[..len], &mut y[i..i + len]);
        i += len;
    }
}

// ---------------------------------------------------------------------------
// Panel (multi-RHS) kernels — gemm-shaped: every matrix byte (compressed or
// not) is loaded/decoded once and applied to all `nrhs` right-hand sides,
// raising arithmetic intensity by ~b (paper Fig. 7). The fused variants run
// one decode pass per column with per-RHS accumulators held in registers.
//
// A *panel* is a contiguous column-major multivector: `x` has `ncols × nrhs`
// values (column c at `x[c*ncols..]`), `y` has `nrows × nrhs`.
// ---------------------------------------------------------------------------

/// Y += alpha · A · X on contiguous panels: each matrix column is loaded once
/// and applied to all `nrhs` columns of X.
pub fn gemm_nn_panel(alpha: f64, a: &DMatrix, x: &[f64], y: &mut [f64], nrhs: usize) {
    let (m, n) = (a.nrows(), a.ncols());
    debug_assert_eq!(x.len(), n * nrhs);
    debug_assert_eq!(y.len(), m * nrhs);
    for j in 0..n {
        let col = a.col(j);
        for c in 0..nrhs {
            let w = alpha * x[c * n + j];
            if w != 0.0 {
                blas::axpy(w, col, &mut y[c * m..c * m + m]);
            }
        }
    }
}

/// Y += alpha · Aᵀ · X on contiguous panels (X: nrows×nrhs, Y: ncols×nrhs).
pub fn gemm_tn_panel(alpha: f64, a: &DMatrix, x: &[f64], y: &mut [f64], nrhs: usize) {
    let (m, n) = (a.nrows(), a.ncols());
    debug_assert_eq!(x.len(), m * nrhs);
    debug_assert_eq!(y.len(), n * nrhs);
    for j in 0..n {
        let col = a.col(j);
        for c in 0..nrhs {
            y[c * n + j] += alpha * blas::dot(col, &x[c * m..c * m + m]);
        }
    }
}

/// Y += alpha · D · X with compressed dense D (mode-dispatched): each column
/// is decoded once and FMA'd into all `nrhs` output columns.
pub fn zgemm_blocked_panel(alpha: f64, z: &ZDense, x: &[f64], y: &mut [f64], nrhs: usize) {
    let (m, n) = (z.nrows, z.ncols);
    debug_assert_eq!(x.len(), n * nrhs);
    debug_assert_eq!(y.len(), m * nrhs);
    if fused_panel(nrhs) {
        let mut cur = DecodeCursor::new(&z.blob);
        for j in 0..n {
            if (0..nrhs).all(|c| alpha * x[c * n + j] == 0.0) {
                continue;
            }
            cur.seek(j * m);
            cur.axpy_panel(m, alpha, &x[j..], n, nrhs, y, m);
        }
        return;
    }
    let mut buf = [0.0f64; CHUNK];
    for j in 0..n {
        if (0..nrhs).all(|c| x[c * n + j] == 0.0) {
            continue;
        }
        let base = j * m;
        let mut i = 0;
        while i < m {
            let len = CHUNK.min(m - i);
            z.blob.decompress_range(base + i, base + i + len, &mut buf[..len]);
            for c in 0..nrhs {
                let axj = alpha * x[c * n + j];
                if axj != 0.0 {
                    blas::axpy(axj, &buf[..len], &mut y[c * m + i..c * m + i + len]);
                }
            }
            i += len;
        }
    }
}

/// Y += alpha · Dᵀ · X with compressed dense D (X: nrows×nrhs, Y: ncols×nrhs);
/// one decode pass over D serves all `nrhs` columns (mode-dispatched).
pub fn zgemm_t_blocked_panel(alpha: f64, z: &ZDense, x: &[f64], y: &mut [f64], nrhs: usize) {
    let (m, n) = (z.nrows, z.ncols);
    debug_assert_eq!(x.len(), m * nrhs);
    debug_assert_eq!(y.len(), n * nrhs);
    if fused_panel(nrhs) {
        let mut cur = DecodeCursor::new(&z.blob);
        for j in 0..n {
            cur.seek(j * m);
            cur.dot_panel(m, alpha, x, m, nrhs, &mut y[j..], n);
        }
        return;
    }
    let mut buf = [0.0f64; CHUNK];
    for j in 0..n {
        let base = j * m;
        let mut i = 0;
        while i < m {
            let len = CHUNK.min(m - i);
            z.blob.decompress_range(base + i, base + i + len, &mut buf[..len]);
            for c in 0..nrhs {
                y[c * n + j] += alpha * blas::dot(&buf[..len], &x[c * m + i..c * m + i + len]);
            }
            i += len;
        }
    }
}

/// t[c*ncols + j] += dot(col_j, x_c) for a column-major compressed factor:
/// one decode pass per factor column, `nrhs` accumulators per chunk.
pub(crate) fn stream_dot_cols_panel(blob: &Blob, nrows: usize, ncols: usize, x: &[f64], nrhs: usize, t: &mut [f64]) {
    debug_assert_eq!(x.len(), nrows * nrhs);
    debug_assert!(t.len() >= ncols * nrhs);
    if fused_panel(nrhs) {
        let mut cur = DecodeCursor::new(blob);
        for j in 0..ncols {
            cur.seek(j * nrows);
            cur.dot_panel(nrows, 1.0, x, nrows, nrhs, &mut t[j..], ncols);
        }
        return;
    }
    let mut buf = [0.0f64; CHUNK];
    for j in 0..ncols {
        let base = j * nrows;
        let mut i = 0;
        while i < nrows {
            let len = CHUNK.min(nrows - i);
            blob.decompress_range(base + i, base + i + len, &mut buf[..len]);
            for c in 0..nrhs {
                t[c * ncols + j] += blas::dot(&buf[..len], &x[c * nrows + i..c * nrows + i + len]);
            }
            i += len;
        }
    }
}

/// y_c += alpha * Σ_j t[c*ncols + j] * col_j for a compressed factor: one
/// decode pass per factor column, `nrhs` axpys per chunk.
pub(crate) fn stream_axpy_cols_panel(blob: &Blob, nrows: usize, ncols: usize, alpha: f64, t: &[f64], nrhs: usize, y: &mut [f64]) {
    debug_assert!(t.len() >= ncols * nrhs);
    debug_assert_eq!(y.len(), nrows * nrhs);
    if fused_panel(nrhs) {
        let mut cur = DecodeCursor::new(blob);
        for j in 0..ncols {
            if (0..nrhs).all(|c| alpha * t[c * ncols + j] == 0.0) {
                continue;
            }
            cur.seek(j * nrows);
            cur.axpy_panel(nrows, alpha, &t[j..], ncols, nrhs, y, nrows);
        }
        return;
    }
    let mut buf = [0.0f64; CHUNK];
    for j in 0..ncols {
        if (0..nrhs).all(|c| alpha * t[c * ncols + j] == 0.0) {
            continue;
        }
        let base = j * nrows;
        let mut i = 0;
        while i < nrows {
            let len = CHUNK.min(nrows - i);
            blob.decompress_range(base + i, base + i + len, &mut buf[..len]);
            for c in 0..nrhs {
                let w = alpha * t[c * ncols + j];
                if w != 0.0 {
                    blas::axpy(w, &buf[..len], &mut y[c * nrows + i..c * nrows + i + len]);
                }
            }
            i += len;
        }
    }
}

/// acc[c*astride] += dot(blob, x[c*xstride..]) over a compressed vector with
/// caller-chosen strides (the VALR basis panel layout stores coefficient j of
/// column c at `s[c*rank + j]`), one decode pass for all right-hand sides.
pub(crate) fn stream_dot_strided_panel(blob: &Blob, x: &[f64], xstride: usize, nrhs: usize, acc: &mut [f64], astride: usize) {
    let n = blob.n;
    if fused_panel(nrhs) {
        DecodeCursor::new(blob).dot_panel(n, 1.0, x, xstride, nrhs, acc, astride);
        return;
    }
    let mut buf = [0.0f64; CHUNK];
    let mut i = 0;
    while i < n {
        let len = CHUNK.min(n - i);
        blob.decompress_range(i, i + len, &mut buf[..len]);
        for c in 0..nrhs {
            acc[c * astride] += blas::dot(&buf[..len], &x[c * xstride + i..c * xstride + i + len]);
        }
        i += len;
    }
}

/// y[c*ystride..] += alpha·wv[c*wstride] * blob over a compressed vector with
/// caller-chosen strides, one decode pass (zero weights skipped).
pub(crate) fn stream_axpy_strided_panel(blob: &Blob, alpha: f64, wv: &[f64], wstride: usize, nrhs: usize, y: &mut [f64], ystride: usize) {
    let n = blob.n;
    if fused_panel(nrhs) {
        DecodeCursor::new(blob).axpy_panel(n, alpha, wv, wstride, nrhs, y, ystride);
        return;
    }
    let mut buf = [0.0f64; CHUNK];
    let mut i = 0;
    while i < n {
        let len = CHUNK.min(n - i);
        blob.decompress_range(i, i + len, &mut buf[..len]);
        for c in 0..nrhs {
            let w = alpha * wv[c * wstride];
            if w != 0.0 {
                blas::axpy(w, &buf[..len], &mut y[c * ystride + i..c * ystride + i + len]);
            }
        }
        i += len;
    }
}

/// acc[c] += dot(blob, x_c) over a compressed vector, one decode pass
/// (the unit-stride case of [`stream_dot_strided_panel`]).
fn stream_dot_vec_panel(blob: &Blob, x: &[f64], nrhs: usize, acc: &mut [f64]) {
    debug_assert_eq!(x.len(), blob.n * nrhs);
    stream_dot_strided_panel(blob, x, blob.n, nrhs, acc, 1);
}

/// y_c += w[c] * blob over a compressed vector, one decode pass
/// (the unit-weight-stride case of [`stream_axpy_strided_panel`]).
fn stream_axpy_vec_panel(blob: &Blob, w: &[f64], nrhs: usize, y: &mut [f64]) {
    debug_assert_eq!(y.len(), blob.n * nrhs);
    stream_axpy_strided_panel(blob, 1.0, w, 1, nrhs, y, blob.n);
}

/// Panel scratch (f64 values per right-hand side) needed by
/// [`apply_block_panel`] / [`apply_block_panel_transposed`] for block `b`.
pub fn block_panel_scratch(b: &BlockData) -> usize {
    b.rank().max(1)
}

/// Y += alpha · B · X on contiguous column-major panels (X: ncols×nrhs,
/// Y: nrows×nrhs) with caller-provided scratch of at least
/// [`block_panel_scratch`]`(b) * nrhs` values. Gemm-shaped: block data —
/// compressed factors included — is streamed once and applied to all columns.
pub fn apply_block_panel(alpha: f64, b: &BlockData, x: &[f64], y: &mut [f64], nrhs: usize, scratch: &mut [f64]) {
    match b {
        BlockData::Dense(m) => gemm_nn_panel(alpha, m, x, y, nrhs),
        BlockData::LowRank(lr) => {
            let k = lr.rank();
            if k == 0 {
                return;
            }
            let t = &mut scratch[..k * nrhs];
            t.fill(0.0);
            gemm_tn_panel(1.0, &lr.v, x, t, nrhs);
            gemm_nn_panel(alpha, &lr.u, t, y, nrhs);
        }
        BlockData::ZDense(z) => zgemm_blocked_panel(alpha, z, x, y, nrhs),
        BlockData::ZLowRank(z) => {
            let k = z.rank;
            if k == 0 {
                return;
            }
            let t = &mut scratch[..k * nrhs];
            t.fill(0.0);
            stream_dot_cols_panel(&z.v, z.ncols, k, x, nrhs, t);
            stream_axpy_cols_panel(&z.u, z.nrows, k, alpha, t, nrhs, y);
        }
        BlockData::ZLowRankValr(z) => {
            let s = &mut scratch[..nrhs];
            for i in 0..z.rank() {
                s.fill(0.0);
                stream_dot_vec_panel(&z.xcols[i], x, nrhs, s);
                let mut any = false;
                for v in s.iter_mut() {
                    *v *= alpha * z.sigma[i];
                    any |= *v != 0.0;
                }
                if any {
                    stream_axpy_vec_panel(&z.wcols[i], s, nrhs, y);
                }
            }
        }
    }
}

/// Y += alpha · Bᵀ · X on contiguous panels (X: nrows×nrhs, Y: ncols×nrhs);
/// scratch as for [`apply_block_panel`].
pub fn apply_block_panel_transposed(alpha: f64, b: &BlockData, x: &[f64], y: &mut [f64], nrhs: usize, scratch: &mut [f64]) {
    match b {
        BlockData::Dense(m) => gemm_tn_panel(alpha, m, x, y, nrhs),
        BlockData::LowRank(lr) => {
            // (U Vᵀ)ᵀ X = V (Uᵀ X)
            let k = lr.rank();
            if k == 0 {
                return;
            }
            let t = &mut scratch[..k * nrhs];
            t.fill(0.0);
            gemm_tn_panel(1.0, &lr.u, x, t, nrhs);
            gemm_nn_panel(alpha, &lr.v, t, y, nrhs);
        }
        BlockData::ZDense(z) => zgemm_t_blocked_panel(alpha, z, x, y, nrhs),
        BlockData::ZLowRank(z) => {
            let k = z.rank;
            if k == 0 {
                return;
            }
            let t = &mut scratch[..k * nrhs];
            t.fill(0.0);
            stream_dot_cols_panel(&z.u, z.nrows, k, x, nrhs, t);
            stream_axpy_cols_panel(&z.v, z.ncols, k, alpha, t, nrhs, y);
        }
        BlockData::ZLowRankValr(z) => {
            let s = &mut scratch[..nrhs];
            for i in 0..z.rank() {
                s.fill(0.0);
                stream_dot_vec_panel(&z.wcols[i], x, nrhs, s);
                let mut any = false;
                for v in s.iter_mut() {
                    *v *= alpha * z.sigma[i];
                    any |= *v != 0.0;
                }
                if any {
                    stream_axpy_vec_panel(&z.xcols[i], s, nrhs, y);
                }
            }
        }
    }
}

/// Multi-RHS: Y += alpha · B · X (column-major multivectors). Thin allocating
/// wrapper around [`apply_block_panel`] — hot paths (the plan executor,
/// [`crate::mvm::h_mvm_multi`]) pass pooled panels and scratch instead.
pub fn apply_block_multi(alpha: f64, b: &BlockData, x: &DMatrix, y: &mut DMatrix) {
    debug_assert_eq!(x.ncols(), y.ncols());
    debug_assert_eq!(x.nrows(), b.ncols());
    debug_assert_eq!(y.nrows(), b.nrows());
    let nrhs = x.ncols();
    let mut scratch = vec![0.0; block_panel_scratch(b) * nrhs];
    apply_block_panel(alpha, b, x.data(), y.data_mut(), nrhs, &mut scratch);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Codec, CompressionConfig};
    use crate::util::Rng;

    fn rand_lr(m: usize, n: usize, k: usize, seed: u64) -> LowRank {
        let mut rng = Rng::new(seed);
        LowRank { u: DMatrix::random(m, k, &mut rng), v: DMatrix::random(n, k, &mut rng) }
    }

    #[test]
    fn all_representations_agree() {
        let mut rng = Rng::new(101);
        let mlr = rand_lr(40, 30, 4, 102);
        let dense = BlockData::Dense(mlr.to_dense());
        let x = rng.vector(30);
        let mut y_ref = vec![0.0; 40];
        apply_block(1.5, &dense, &x, &mut y_ref);

        let cfg_valr = CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true };
        let cfg_fixed = CompressionConfig { codec: Codec::Fpx, eps: 1e-9, valr: false };
        let reps = vec![
            BlockData::LowRank(mlr.clone()),
            dense.compress(&CompressionConfig::aflp(1e-9)),
            dense.compress(&CompressionConfig::fpx(1e-9)),
            BlockData::LowRank(mlr.clone()).compress(&cfg_valr),
            BlockData::LowRank(mlr.clone()).compress(&cfg_fixed),
        ];
        for (ri, rep) in reps.iter().enumerate() {
            let mut y = vec![0.0; 40];
            apply_block(1.5, rep, &x, &mut y);
            for i in 0..40 {
                assert!((y[i] - y_ref[i]).abs() < 1e-5 * (1.0 + y_ref[i].abs()), "rep {ri} idx {i}: {} vs {}", y[i], y_ref[i]);
            }
        }
    }

    #[test]
    fn direct_blockwise_and_fused_zgemv_agree() {
        let mut rng = Rng::new(103);
        let m = DMatrix::random(70, 50, &mut rng);
        let x = rng.vector(50);
        for codec in [Codec::Aflp, Codec::Fpx] {
            let z = ZDense::compress(&m, codec, 1e-7);
            let mut y1 = vec![0.0; 70];
            let mut y2 = vec![0.0; 70];
            let mut y3 = vec![0.0; 70];
            zgemv_direct(2.0, &z, &x, &mut y1);
            zgemv_blockwise(2.0, &z, &x, &mut y2);
            zgemv_fused(2.0, &z, &x, &mut y3);
            for i in 0..70 {
                assert!((y1[i] - y2[i]).abs() < 1e-12, "{codec:?} {i} direct vs blockwise");
                // fused axpy applies the identical per-element ops
                assert_eq!(y2[i].to_bits(), y3[i].to_bits(), "{codec:?} {i} blockwise vs fused");
            }
        }
    }

    #[test]
    fn transposed_fused_matches_blockwise() {
        let mut rng = Rng::new(113);
        let m = DMatrix::random(53, 37, &mut rng);
        let x = rng.vector(53);
        for codec in [Codec::Aflp, Codec::Fpx] {
            let z = ZDense::compress(&m, codec, 1e-8);
            let mut y1 = vec![0.0; 37];
            let mut y2 = vec![0.0; 37];
            zgemv_t_blockwise(1.5, &z, &x, &mut y1);
            zgemv_t_fused(1.5, &z, &x, &mut y2);
            let mut y_ref = vec![0.0; 37];
            blas::gemv_transposed(1.5, &z.to_dense(), &x, &mut y_ref);
            for i in 0..37 {
                assert!((y1[i] - y_ref[i]).abs() < 1e-10, "{codec:?} {i} blockwise");
                assert!((y2[i] - y_ref[i]).abs() < 1e-10, "{codec:?} {i} fused");
            }
        }
    }

    #[test]
    fn transposed_agrees_with_dense() {
        let mut rng = Rng::new(104);
        let m = DMatrix::random(25, 35, &mut rng);
        let x = rng.vector(25);
        let mut y_ref = vec![0.0; 35];
        blas::gemv_transposed(1.0, &m, &x, &mut y_ref);
        for rep in [
            BlockData::Dense(m.clone()),
            BlockData::Dense(m.clone()).compress(&CompressionConfig::aflp(1e-10)),
        ] {
            let mut y = vec![0.0; 35];
            apply_block_transposed(1.0, &rep, &x, &mut y);
            for i in 0..35 {
                assert!((y[i] - y_ref[i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn scratch_variants_match_allocating_wrappers() {
        let mut rng = Rng::new(107);
        let mlr = rand_lr(33, 27, 5, 108);
        let cfg_valr = CompressionConfig { codec: Codec::Aflp, eps: 1e-10, valr: true };
        let cfg_fixed = CompressionConfig { codec: Codec::Fpx, eps: 1e-10, valr: false };
        let reps = vec![
            BlockData::Dense(mlr.to_dense()),
            BlockData::LowRank(mlr.clone()),
            BlockData::Dense(mlr.to_dense()).compress(&CompressionConfig::aflp(1e-10)),
            BlockData::LowRank(mlr.clone()).compress(&cfg_valr),
            BlockData::LowRank(mlr.clone()).compress(&cfg_fixed),
        ];
        let x = rng.vector(27);
        let xt = rng.vector(33);
        let mut scratch = vec![0.0; 16];
        for (ri, rep) in reps.iter().enumerate() {
            let mut y1 = vec![0.0; 33];
            let mut y2 = vec![0.0; 33];
            apply_block(1.25, rep, &x, &mut y1);
            apply_block_scratch(1.25, rep, &x, &mut y2, &mut scratch);
            assert_eq!(y1, y2, "forward rep {ri}");
            let mut z1 = vec![0.0; 27];
            let mut z2 = vec![0.0; 27];
            apply_block_transposed(0.5, rep, &xt, &mut z1);
            apply_block_transposed_scratch(0.5, rep, &xt, &mut z2, &mut scratch);
            assert_eq!(z1, z2, "adjoint rep {ri}");
        }
    }

    #[test]
    fn panel_kernels_match_per_column_all_representations() {
        let mut rng = Rng::new(109);
        let mlr = rand_lr(34, 26, 5, 110);
        let cfg_valr = CompressionConfig { codec: Codec::Aflp, eps: 1e-10, valr: true };
        let cfg_fixed = CompressionConfig { codec: Codec::Fpx, eps: 1e-10, valr: false };
        let reps = vec![
            BlockData::Dense(mlr.to_dense()),
            BlockData::LowRank(mlr.clone()),
            BlockData::Dense(mlr.to_dense()).compress(&CompressionConfig::aflp(1e-10)),
            BlockData::Dense(mlr.to_dense()).compress(&CompressionConfig::fpx(1e-10)),
            BlockData::LowRank(mlr.clone()).compress(&cfg_valr),
            BlockData::LowRank(mlr.clone()).compress(&cfg_fixed),
        ];
        let nrhs = 3;
        let x = DMatrix::random(26, nrhs, &mut rng);
        let xt = DMatrix::random(34, nrhs, &mut rng);
        let mut scratch = vec![0.0; 6 * nrhs];
        for (ri, rep) in reps.iter().enumerate() {
            let mut y = vec![0.0; 34 * nrhs];
            apply_block_panel(1.25, rep, x.data(), &mut y, nrhs, &mut scratch);
            for c in 0..nrhs {
                let mut yc = vec![0.0; 34];
                apply_block(1.25, rep, x.col(c), &mut yc);
                for i in 0..34 {
                    assert!(
                        (y[c * 34 + i] - yc[i]).abs() < 1e-12,
                        "forward rep {ri} col {c} row {i}: {} vs {}",
                        y[c * 34 + i],
                        yc[i]
                    );
                }
            }
            let mut z = vec![0.0; 26 * nrhs];
            apply_block_panel_transposed(0.75, rep, xt.data(), &mut z, nrhs, &mut scratch);
            for c in 0..nrhs {
                let mut zc = vec![0.0; 26];
                apply_block_transposed(0.75, rep, xt.col(c), &mut zc);
                for i in 0..26 {
                    assert!(
                        (z[c * 26 + i] - zc[i]).abs() < 1e-12,
                        "adjoint rep {ri} col {c} row {i}: {} vs {}",
                        z[c * 26 + i],
                        zc[i]
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_panels_match_blas_gemm() {
        let mut rng = Rng::new(111);
        let a = DMatrix::random(9, 7, &mut rng);
        let x = DMatrix::random(7, 4, &mut rng);
        let mut y = DMatrix::zeros(9, 4);
        blas::gemm(2.0, &a, blas::Trans::No, &x, blas::Trans::No, &mut y);
        let mut yp = vec![0.0; 9 * 4];
        gemm_nn_panel(2.0, &a, x.data(), &mut yp, 4);
        for (i, v) in y.data().iter().enumerate() {
            assert!((yp[i] - v).abs() < 1e-13);
        }
        let xt = DMatrix::random(9, 4, &mut rng);
        let mut z = DMatrix::zeros(7, 4);
        blas::gemm(1.5, &a, blas::Trans::Yes, &xt, blas::Trans::No, &mut z);
        let mut zp = vec![0.0; 7 * 4];
        gemm_tn_panel(1.5, &a, xt.data(), &mut zp, 4);
        for (i, v) in z.data().iter().enumerate() {
            assert!((zp[i] - v).abs() < 1e-13);
        }
    }

    #[test]
    fn multi_rhs_matches_single() {
        let mut rng = Rng::new(105);
        let b = BlockData::LowRank(rand_lr(20, 15, 3, 106));
        let x = DMatrix::random(15, 4, &mut rng);
        let mut y_multi = DMatrix::zeros(20, 4);
        apply_block_multi(1.0, &b, &x, &mut y_multi);
        for c in 0..4 {
            let mut y = vec![0.0; 20];
            apply_block(1.0, &b, x.col(c), &mut y);
            for i in 0..20 {
                assert!((y_multi[(i, c)] - y[i]).abs() < 1e-12);
            }
        }
    }
}
