//! Multi-RHS H-matrix product Y += α·M·X — the coordinator's batched path.
//! Batching b requests into one traversal amortizes every matrix-data load
//! over b vectors, raising arithmetic intensity by ~b (ablation bench
//! `ablation_batching`). Compressed blocks run through the fused panel
//! kernels of [`crate::mvm::kernels`]: one decode pass per block column with
//! per-RHS accumulators kept in registers (runtime-dispatched SIMD).

use super::kernels;
use super::{SharedVec, SPAWN_LEVELS};
use crate::hmatrix::HMatrix;
use crate::la::DMatrix;
use crate::par::ThreadPool;
use crate::plan::BufferPool;

/// Y += alpha · M · X with X (ncols × b), Y (nrows × b), cluster-list
/// traversal (Algorithm 3 generalized to multivectors).
pub fn h_mvm_multi(alpha: f64, m: &HMatrix, x: &DMatrix, y: &mut DMatrix) {
    assert_eq!(x.nrows(), m.ncols());
    assert_eq!(y.nrows(), m.nrows());
    assert_eq!(x.ncols(), y.ncols());
    let b = x.ncols();
    let n = y.nrows();
    let yy = SharedVec::new(y.data_mut());
    let pool = ThreadPool::global();
    pool.scope(|s| rec(s, alpha, m, x, m.bt.row_ct.root(), yy, n, b, 0));
}

#[allow(clippy::too_many_arguments)]
fn rec<'e>(
    s: &crate::par::Scope<'e>,
    alpha: f64,
    m: &'e HMatrix,
    x: &'e DMatrix,
    tau: usize,
    y: SharedVec,
    ylen: usize,
    nrhs: usize,
    depth: usize,
) {
    let bt = &m.bt;
    let ct = &bt.row_ct;
    let rr = ct.node(tau).range();
    if !bt.row_blocks[tau].is_empty() {
        // pooled panel buffers (per-worker free lists): gather the row stripe
        // once, stream every block's data once through the gemm-shaped panel
        // kernels, scatter back — zero heap allocation in steady state
        let pool_b = BufferPool::global();
        let dl = rr.len();
        let mut ystripe = pool_b.take(dl * nrhs);
        for c in 0..nrhs {
            // SAFETY: traversal invariant (same as single-RHS Algorithm 3).
            let ycol = unsafe { y.range(c * ylen + rr.start..c * ylen + rr.end) };
            ystripe[c * dl..(c + 1) * dl].copy_from_slice(ycol);
        }
        let mut xstripe = pool_b.take(0);
        let mut scratch = pool_b.take(0);
        for &bid in &bt.row_blocks[tau] {
            let nd = bt.node(bid);
            let cr = bt.col_ct.node(nd.col).range();
            let blk = m.blocks[bid].as_ref().expect("missing leaf");
            let sl = cr.len();
            xstripe.clear();
            xstripe.resize(sl * nrhs, 0.0);
            for c in 0..nrhs {
                xstripe[c * sl..(c + 1) * sl].copy_from_slice(&x.col(c)[cr.clone()]);
            }
            let need = kernels::block_panel_scratch(blk) * nrhs;
            if scratch.len() < need {
                scratch.resize(need, 0.0);
            }
            kernels::apply_block_panel(alpha, blk, &xstripe, &mut ystripe, nrhs, &mut scratch);
        }
        for c in 0..nrhs {
            // SAFETY: as above.
            let ycol = unsafe { y.range_mut(c * ylen + rr.start..c * ylen + rr.end) };
            ycol.copy_from_slice(&ystripe[c * dl..(c + 1) * dl]);
        }
        pool_b.put(ystripe);
        pool_b.put(xstripe);
        pool_b.put(scratch);
    }
    for &child in &ct.node(tau).children {
        if depth < SPAWN_LEVELS {
            s.spawn(move |s2| rec(s2, alpha, m, x, child, y, ylen, nrhs, depth + 1));
        } else {
            rec(s, alpha, m, x, child, y, ylen, nrhs, depth + 1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{BlockTree, ClusterTree, StdAdmissibility};
    use crate::geometry::icosphere;
    use crate::kernelfn::{LaplaceSlp, MatrixGen};
    use crate::lowrank::AcaOptions;
    use crate::mvm::MvmAlgorithm;
    use crate::util::Rng;
    use std::sync::Arc;

    #[test]
    fn multi_matches_repeated_single() {
        let geom = icosphere(1);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), 8));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-8));
        let mut rng = Rng::new(141);
        let nrhs = 5;
        let x = DMatrix::random(h.ncols(), nrhs, &mut rng);
        let mut y = DMatrix::zeros(h.nrows(), nrhs);
        h_mvm_multi(1.5, &h, &x, &mut y);
        for c in 0..nrhs {
            let mut yc = vec![0.0; h.nrows()];
            crate::mvm::mvm(1.5, &h, x.col(c), &mut yc, MvmAlgorithm::Seq);
            for i in 0..h.nrows() {
                assert!((y[(i, c)] - yc[i]).abs() < 1e-10, "col {c} row {i}");
            }
        }
    }
}
