//! H²-matrix MVM (paper §3.3, Algorithms 6 & 7, Fig. 6 right).

use super::{update_chunks, SharedSlots, SharedVec, SPAWN_LEVELS};
use crate::h2::H2Matrix;
use crate::la::blas;
use crate::par::ThreadPool;
use crate::uniform::UniBlock;
use std::sync::Mutex;

/// Algorithm 6: forward transformation with nested bases — strict
/// leaves-to-root dependency (Remark 3.4), realised level-wise bottom-up
/// with parallelism inside each level.
fn forward(m: &H2Matrix, x: &[f64]) -> Vec<Vec<f64>> {
    let ct = &m.bt.col_ct;
    let nb = &m.col_basis;
    let mut s: Vec<Vec<f64>> = (0..ct.nodes.len()).map(|i| vec![0.0; nb.rank[i]]).collect();
    let pool = ThreadPool::global();
    for level in (0..ct.levels.len()).rev() {
        let slots = SharedSlots::new(&mut s);
        pool.scope(|sc| {
            for &sigma in &ct.levels[level] {
                if nb.rank[sigma] == 0 {
                    continue;
                }
                let slots = &slots;
                sc.spawn(move |_| {
                    let nd = ct.node(sigma);
                    // SAFETY: one task per slot; children slots belong to a
                    // deeper level, already complete and only read here.
                    let dst = unsafe { slots.get_mut(sigma) };
                    if nd.is_leaf() {
                        nb.leaf_apply_transposed(sigma, &x[nd.range()], dst);
                    } else {
                        for &c in &nd.children {
                            if nb.rank[c] == 0 {
                                continue;
                            }
                            let sc_child = unsafe { &*(slots.get_mut(c) as *const Vec<f64>) };
                            if let Some(e) = m.col_basis.transfer[c].as_ref() {
                                e.apply_transposed_add(sc_child, dst);
                            }
                        }
                    }
                });
            }
        });
    }
    s
}

/// Algorithm 7: combined coupling application and backward transformation,
/// collision free by root-to-leaf traversal; y is written only through
/// exclusive cluster ranges.
pub fn row_wise(alpha: f64, m: &H2Matrix, x: &[f64], y: &mut [f64]) {
    let s = forward(m, x);
    let ct = &m.bt.row_ct;
    let mut t: Vec<Vec<f64>> = (0..ct.nodes.len()).map(|i| vec![0.0; m.row_basis.rank[i]]).collect();
    let yy = SharedVec::new(y);
    let tslots = SharedSlots::new(&mut t);
    let pool = ThreadPool::global();
    pool.scope(|sc| rec_row_wise(sc, alpha, m, x, &s, &tslots, ct.root(), yy, 0));
}

#[allow(clippy::too_many_arguments)]
fn rec_row_wise<'e>(
    sc: &crate::par::Scope<'e>,
    alpha: f64,
    m: &'e H2Matrix,
    x: &'e [f64],
    s: &'e [Vec<f64>],
    t: &'e SharedSlots<Vec<f64>>,
    tau: usize,
    y: SharedVec,
    depth: usize,
) {
    let bt = &m.bt;
    let ct = &bt.row_ct;
    let nd = ct.node(tau);
    let rr = nd.range();
    // SAFETY: τ's slot is written by the parent before this task ran and by
    // this task only from here on.
    let t_tau = unsafe { t.get_mut(tau) };
    // coupling accumulation t_τ += S_b s_σ
    for &b in &bt.row_blocks[tau] {
        if let Some(UniBlock::Coupling(c)) = m.blocks[b].as_ref() {
            c.apply_add(&s[bt.node(b).col], t_tau);
        }
    }
    let has_dense = bt.row_blocks[tau].iter().any(|&b| matches!(m.blocks[b].as_ref(), Some(UniBlock::Dense(_)) | Some(UniBlock::ZDense(_))));

    if nd.is_leaf() {
        if t_tau.iter().any(|&v| v != 0.0) || has_dense {
            // SAFETY: leaf ranges are disjoint; ancestors wrote y|τ only
            // through dense blocks before spawning children.
            let yt = unsafe { y.range_mut(rr) };
            let tv: Vec<f64> = t_tau.iter().map(|&v| alpha * v).collect();
            m.row_basis.leaf_apply_add(tau, &tv, yt);
            if has_dense {
                dense_blocks(alpha, m, tau, x, yt);
            }
        }
    } else {
        // shift coefficients to the children: t_c += E_c t_τ
        for &c in &nd.children {
            if m.row_basis.rank[c] == 0 || m.row_basis.rank[tau] == 0 {
                continue;
            }
            // SAFETY: child slot not yet owned by any task.
            let t_c = unsafe { t.get_mut(c) };
            if let Some(e) = m.row_basis.transfer[c].as_ref() {
                e.apply_add(t_tau, t_c);
            }
        }
        if has_dense {
            // SAFETY: traversal invariant as in Algorithm 3.
            let yt = unsafe { y.range_mut(rr) };
            dense_blocks(alpha, m, tau, x, yt);
        }
        for &c in &nd.children {
            if depth < SPAWN_LEVELS {
                sc.spawn(move |s2| rec_row_wise(s2, alpha, m, x, s, t, c, y, depth + 1));
            } else {
                rec_row_wise(sc, alpha, m, x, s, t, c, y, depth + 1);
            }
        }
    }
}

fn dense_blocks(alpha: f64, m: &H2Matrix, tau: usize, x: &[f64], yt: &mut [f64]) {
    let bt = &m.bt;
    for &b in &bt.row_blocks[tau] {
        let cr = bt.col_ct.node(bt.node(b).col).range();
        match m.blocks[b].as_ref() {
            Some(UniBlock::Dense(d)) => blas::gemv(alpha, d, &x[cr], yt),
            Some(UniBlock::ZDense(z)) => super::kernels::zgemv_blocked(alpha, z, &x[cr], yt),
            _ => {}
        }
    }
}

/// Mutex variant: coefficient updates of Eq. (5) guarded by a mutex per t_τ,
/// followed by a top-down transfer pass and chunk-guarded dense updates.
pub fn mutex(alpha: f64, m: &H2Matrix, x: &[f64], y: &mut [f64]) {
    let s = forward(m, x);
    let bt = &m.bt;
    let ct = &bt.row_ct;
    let pool = ThreadPool::global();

    // phase 1: parallel over low-rank leaves, mutex-guarded t accumulation;
    // dense leaves via chunk updates
    let t: Vec<Mutex<Vec<f64>>> = (0..ct.nodes.len()).map(|i| Mutex::new(vec![0.0; m.row_basis.rank[i]])).collect();
    let locks: Vec<Mutex<()>> = (0..ct.nodes.len()).map(|_| Mutex::new(())).collect();
    let yy = SharedVec::new(y);
    pool.scope(|sc| {
        for &leaf in &bt.leaves {
            let t = &t;
            let locks = &locks;
            let s = &s;
            let yy = yy;
            sc.spawn(move |_| {
                let nd = bt.node(leaf);
                match m.blocks[leaf].as_ref() {
                    Some(UniBlock::Coupling(c)) => {
                        let mut guard = t[nd.row].lock().unwrap();
                        c.apply_add(&s[nd.col], &mut guard);
                    }
                    Some(UniBlock::Dense(d)) => {
                        let cr = bt.col_ct.node(nd.col).range();
                        let rr = bt.row_ct.node(nd.row).range();
                        let mut tmp = vec![0.0; rr.len()];
                        blas::gemv(alpha, d, &x[cr], &mut tmp);
                        update_chunks(ct, nd.row, rr.start, &tmp, &yy, locks);
                    }
                    Some(UniBlock::ZDense(z)) => {
                        let cr = bt.col_ct.node(nd.col).range();
                        let rr = bt.row_ct.node(nd.row).range();
                        let mut tmp = vec![0.0; rr.len()];
                        super::kernels::zgemv_blocked(alpha, z, &x[cr], &mut tmp);
                        update_chunks(ct, nd.row, rr.start, &tmp, &yy, locks);
                    }
                    _ => {}
                }
            });
        }
    });

    // phase 2: top-down transfer of coefficients, level by level
    for level in 0..ct.levels.len() {
        pool.scope(|sc| {
            for &tau in &ct.levels[level] {
                if m.row_basis.rank[tau] == 0 || ct.node(tau).is_leaf() {
                    continue;
                }
                let t = &t;
                sc.spawn(move |_| {
                    let tv = t[tau].lock().unwrap().clone();
                    if tv.iter().all(|&v| v == 0.0) {
                        return;
                    }
                    for &c in &ct.node(tau).children {
                        if m.row_basis.rank[c] == 0 {
                            continue;
                        }
                        if let Some(e) = m.row_basis.transfer[c].as_ref() {
                            let mut guard = t[c].lock().unwrap();
                            e.apply_add(&tv, &mut guard);
                        }
                    }
                });
            }
        });
    }

    // phase 3: leaf application (disjoint leaf ranges → collision free)
    pool.scope(|sc| {
        for &tau in &ct.leaves {
            if m.row_basis.rank[tau] == 0 {
                continue;
            }
            let t = &t;
            let yy = yy;
            sc.spawn(move |_| {
                let tv: Vec<f64> = t[tau].lock().unwrap().iter().map(|&v| alpha * v).collect();
                if tv.iter().all(|&v| v == 0.0) {
                    return;
                }
                // SAFETY: leaf cluster ranges are disjoint.
                let yt = unsafe { yy.range_mut(ct.node(tau).range()) };
                m.row_basis.leaf_apply_add(tau, &tv, yt);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{BlockTree, ClusterTree, StdAdmissibility};
    use crate::geometry::icosphere;
    use crate::hmatrix::HMatrix;
    use crate::kernelfn::{LaplaceSlp, MatrixGen};
    use crate::lowrank::AcaOptions;
    use crate::mvm::H2MvmAlgorithm;
    use crate::util::Rng;
    use std::sync::Arc;

    fn problem() -> (H2Matrix, crate::la::DMatrix) {
        let geom = icosphere(2);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), 16));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-7));
        let h2 = crate::h2::build_from_h(&h, 1e-7);
        let d = h2.to_dense();
        (h2, d)
    }

    #[test]
    fn algorithms_match_dense() {
        let (h2, d) = problem();
        let mut rng = Rng::new(131);
        let x = rng.vector(h2.ncols());
        let mut y_ref = vec![0.25; h2.nrows()];
        crate::la::gemv(2.0, &d, &x, &mut y_ref);
        for algo in H2MvmAlgorithm::all() {
            let mut y = vec![0.25; h2.nrows()];
            crate::mvm::h2_mvm(2.0, &h2, &x, &mut y, algo);
            let err: f64 = y.iter().zip(&y_ref).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
            assert!(err < 1e-9, "{algo:?} max err {err}");
        }
    }

    #[test]
    fn compressed_h2_mvm_agrees() {
        let (mut h2, d) = problem();
        h2.compress(&crate::compress::CompressionConfig::aflp(1e-10));
        let mut rng = Rng::new(132);
        let x = rng.vector(h2.ncols());
        let mut y_ref = vec![0.0; h2.nrows()];
        crate::la::gemv(1.0, &d, &x, &mut y_ref);
        let ynorm: f64 = y_ref.iter().map(|v| v * v).sum::<f64>().sqrt();
        for algo in H2MvmAlgorithm::all() {
            let mut y = vec![0.0; h2.nrows()];
            crate::mvm::h2_mvm(1.0, &h2, &x, &mut y, algo);
            let err: f64 = y.iter().zip(&y_ref).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
            assert!(err < 1e-6 * ynorm, "{algo:?} err {err}");
        }
    }
}
