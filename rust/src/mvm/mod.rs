//! Matrix-vector multiplication y := α·M·x + y for all hierarchical formats
//! (paper §3) and their compressed variants (§4.3).
//!
//! All vectors are in *internal* (cluster tree) ordering.

pub mod adjoint;
pub mod h2mvm;
pub mod hmvm;
pub mod kernels;
pub mod multi;
pub mod unimvm;

pub use kernels::{
    apply_block, apply_block_multi, apply_block_transposed, zgemv_blocked, zgemv_blockwise, zgemv_direct, zgemv_fused,
    zgemv_t_blocked, zgemv_t_blockwise, zgemv_t_fused,
};
pub use adjoint::mvm_transposed;
pub use multi::h_mvm_multi;

use crate::h2::H2Matrix;
use crate::hmatrix::HMatrix;
use crate::uniform::UniformHMatrix;

/// H-matrix MVM algorithm selector (paper Fig. 6 left).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MvmAlgorithm {
    /// Sequential reference (Algorithm 1).
    Seq,
    /// Task per leaf block, per-chunk mutexes (Algorithm 2, HLIBpro style).
    Chunks,
    /// Collision-free root-to-leaf block-row traversal (Algorithm 3).
    ClusterLists,
    /// Per-level stacked low-rank factors (Ltaief et al. adaptation).
    Stacked,
    /// Thread-local result vectors with a final reduction.
    ThreadLocal,
    /// Atomic per-coefficient updates (Ida et al.).
    Atomic,
    /// Precomputed execution plan: flattened level-ordered task lists with
    /// static load balancing and a reusable scratch arena ([`crate::plan`]).
    /// This variant rebuilds the plan per call; hot paths should hold a
    /// [`crate::plan::PlannedOperator`] instead.
    Plan,
}

impl MvmAlgorithm {
    pub fn name(self) -> &'static str {
        match self {
            MvmAlgorithm::Seq => "seq",
            MvmAlgorithm::Chunks => "chunks",
            MvmAlgorithm::ClusterLists => "cluster lists",
            MvmAlgorithm::Stacked => "stacked",
            MvmAlgorithm::ThreadLocal => "thread local",
            MvmAlgorithm::Atomic => "atomic",
            MvmAlgorithm::Plan => "plan",
        }
    }

    pub fn all() -> [MvmAlgorithm; 7] {
        [
            MvmAlgorithm::Seq,
            MvmAlgorithm::Chunks,
            MvmAlgorithm::ClusterLists,
            MvmAlgorithm::Stacked,
            MvmAlgorithm::ThreadLocal,
            MvmAlgorithm::Atomic,
            MvmAlgorithm::Plan,
        ]
    }
}

/// Uniform-H MVM algorithm selector (paper Fig. 6 center).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UniMvmAlgorithm {
    /// Per-block tasks, mutex-guarded coefficient updates.
    Mutex,
    /// Algorithm 5: row-wise traversal, collision free.
    RowWise,
    /// Separate row/column coupling matrices (Bruyninckx et al.).
    SepCoupling,
    /// Precomputed execution plan ([`crate::plan`], rebuilt per call here).
    Plan,
}

impl UniMvmAlgorithm {
    pub fn name(self) -> &'static str {
        match self {
            UniMvmAlgorithm::Mutex => "mutex",
            UniMvmAlgorithm::RowWise => "row wise",
            UniMvmAlgorithm::SepCoupling => "sep. coupling",
            UniMvmAlgorithm::Plan => "plan",
        }
    }

    pub fn all() -> [UniMvmAlgorithm; 4] {
        [UniMvmAlgorithm::Mutex, UniMvmAlgorithm::RowWise, UniMvmAlgorithm::SepCoupling, UniMvmAlgorithm::Plan]
    }
}

/// H² MVM algorithm selector (paper Fig. 6 right).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum H2MvmAlgorithm {
    /// Mutex-guarded coefficient accumulation.
    Mutex,
    /// Algorithm 7: combined coupling + backward transform, collision free.
    RowWise,
    /// Precomputed execution plan ([`crate::plan`], rebuilt per call here).
    Plan,
}

impl H2MvmAlgorithm {
    pub fn name(self) -> &'static str {
        match self {
            H2MvmAlgorithm::Mutex => "mutex",
            H2MvmAlgorithm::RowWise => "row wise",
            H2MvmAlgorithm::Plan => "plan",
        }
    }

    pub fn all() -> [H2MvmAlgorithm; 3] {
        [H2MvmAlgorithm::Mutex, H2MvmAlgorithm::RowWise, H2MvmAlgorithm::Plan]
    }
}

/// H-matrix product y += α·M·x.
pub fn mvm(alpha: f64, m: &HMatrix, x: &[f64], y: &mut [f64], algo: MvmAlgorithm) {
    assert_eq!(x.len(), m.ncols());
    assert_eq!(y.len(), m.nrows());
    match algo {
        MvmAlgorithm::Seq => hmvm::seq(alpha, m, x, y),
        MvmAlgorithm::Chunks => hmvm::chunks(alpha, m, x, y),
        MvmAlgorithm::ClusterLists => hmvm::cluster_lists(alpha, m, x, y),
        MvmAlgorithm::Stacked => hmvm::stacked(alpha, m, x, y),
        MvmAlgorithm::ThreadLocal => hmvm::thread_local(alpha, m, x, y),
        MvmAlgorithm::Atomic => hmvm::atomic(alpha, m, x, y),
        MvmAlgorithm::Plan => {
            let plan = crate::plan::HPlan::lazy(m);
            let mut arena = crate::plan::Arena::new();
            plan.execute(m, alpha, x, y, &mut arena);
        }
    }
}

/// Uniform-H product y += α·M·x.
pub fn uniform_mvm(alpha: f64, m: &UniformHMatrix, x: &[f64], y: &mut [f64], algo: UniMvmAlgorithm) {
    assert_eq!(x.len(), m.ncols());
    assert_eq!(y.len(), m.nrows());
    match algo {
        UniMvmAlgorithm::Mutex => unimvm::mutex(alpha, m, x, y),
        UniMvmAlgorithm::RowWise => unimvm::row_wise(alpha, m, x, y),
        UniMvmAlgorithm::SepCoupling => unimvm::sep_coupling(alpha, m, x, y),
        UniMvmAlgorithm::Plan => {
            let plan = crate::plan::UniPlan::lazy(m);
            let mut arena = crate::plan::Arena::new();
            plan.execute(m, alpha, x, y, &mut arena);
        }
    }
}

/// H² product y += α·M·x.
pub fn h2_mvm(alpha: f64, m: &H2Matrix, x: &[f64], y: &mut [f64], algo: H2MvmAlgorithm) {
    assert_eq!(x.len(), m.ncols());
    assert_eq!(y.len(), m.nrows());
    match algo {
        H2MvmAlgorithm::Mutex => h2mvm::mutex(alpha, m, x, y),
        H2MvmAlgorithm::RowWise => h2mvm::row_wise(alpha, m, x, y),
        H2MvmAlgorithm::Plan => {
            let plan = crate::plan::H2Plan::lazy(m);
            let mut arena = crate::plan::Arena::new();
            plan.execute(m, alpha, x, y, &mut arena);
        }
    }
}

/// Shared mutable vector handle for the collision-free traversals: tasks
/// write disjoint ranges, the traversal order is the safety argument
/// (paper §3.1: parents complete their block row before children start, and
/// same-level clusters are disjoint).
#[derive(Clone, Copy)]
pub(crate) struct SharedVec {
    ptr: *mut f64,
    len: usize,
}

unsafe impl Send for SharedVec {}
unsafe impl Sync for SharedVec {}

impl SharedVec {
    pub fn new(v: &mut [f64]) -> SharedVec {
        SharedVec { ptr: v.as_mut_ptr(), len: v.len() }
    }

    /// SAFETY: caller must guarantee no concurrent overlapping access.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range_mut(&self, r: std::ops::Range<usize>) -> &mut [f64] {
        debug_assert!(r.end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(r.start), r.end - r.start)
    }

    /// SAFETY: caller must guarantee no concurrent *write* to the range (the
    /// plan executor reads coefficient slots written in an earlier, already
    /// joined level).
    pub unsafe fn range(&self, r: std::ops::Range<usize>) -> &[f64] {
        debug_assert!(r.end <= self.len);
        std::slice::from_raw_parts(self.ptr.add(r.start), r.end - r.start)
    }
}

/// Shared slot array: tasks write *distinct* indices of a pre-sized Vec.
pub(crate) struct SharedSlots<T> {
    ptr: *mut T,
    len: usize,
}

unsafe impl<T: Send> Send for SharedSlots<T> {}
unsafe impl<T: Send> Sync for SharedSlots<T> {}

impl<T> SharedSlots<T> {
    pub fn new(v: &mut [T]) -> SharedSlots<T> {
        SharedSlots { ptr: v.as_mut_ptr(), len: v.len() }
    }

    /// SAFETY: caller must guarantee each index is accessed by one task at a
    /// time.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }
}

/// Spawn-depth cutoff: below this subtree level the traversals run
/// sequentially (task granularity control).
pub(crate) const SPAWN_LEVELS: usize = 6;

/// Chunk-wise scatter of a local block-row result into y (Algorithm 2): one
/// mutex per *leaf* cluster of the row cluster tree.
pub(crate) fn update_chunks(
    ct: &crate::cluster::ClusterTree,
    tau: usize,
    t_offset: usize,
    t: &[f64],
    y: &SharedVec,
    locks: &[std::sync::Mutex<()>],
) {
    let nd = ct.node(tau);
    if nd.is_leaf() {
        let _g = locks[tau].lock().unwrap();
        // SAFETY: the mutex serializes writers of this chunk; chunks are
        // disjoint leaf-cluster ranges.
        let dst = unsafe { y.range_mut(nd.range()) };
        let src = &t[nd.begin - t_offset..nd.end - t_offset];
        crate::la::axpy(1.0, src, dst);
    } else {
        for &c in &nd.children {
            update_chunks(ct, c, t_offset, t, y, locks);
        }
    }
}
