//! H-matrix MVM algorithms (paper §3.1, Fig. 6 left).

use super::kernels::{apply_block, apply_block_scratch};
use super::{update_chunks, SharedVec, SPAWN_LEVELS};
use crate::hmatrix::{BlockData, HMatrix};
use crate::la::{blas, DMatrix};
use crate::par::{as_atomic_f64, atomic_add_f64, ThreadPool};
use crate::plan::BufferPool;
use std::sync::Mutex;

/// Algorithm 1: sequential iteration over all leaf blocks.
pub fn seq(alpha: f64, m: &HMatrix, x: &[f64], y: &mut [f64]) {
    let bt = &m.bt;
    for &leaf in &bt.leaves {
        let nd = bt.node(leaf);
        let rr = bt.row_ct.node(nd.row).range();
        let cr = bt.col_ct.node(nd.col).range();
        let b = m.blocks[leaf].as_ref().expect("missing leaf");
        apply_block(alpha, b, &x[cr], &mut y[rr]);
    }
}

/// Algorithm 2: one task per leaf block; the local result is scattered into
/// `y` chunk-by-chunk (leaf clusters of the row cluster tree), each chunk
/// guarded by a mutex (HLIBpro scheme [23]). Per-task temporaries come from
/// the global [`BufferPool`] — steady state performs no heap allocation.
pub fn chunks(alpha: f64, m: &HMatrix, x: &[f64], y: &mut [f64]) {
    let bt = &m.bt;
    let ct = &bt.row_ct;
    // chunk = leaf cluster; mutex per leaf cluster id
    let locks: Vec<Mutex<()>> = (0..ct.nodes.len()).map(|_| Mutex::new(())).collect();
    let yy = SharedVec::new(y);
    let pool = ThreadPool::global();
    pool.scope(|s| {
        for &leaf in &bt.leaves {
            let locks = &locks;
            let yy = yy;
            s.spawn(move |_| {
                let nd = bt.node(leaf);
                let rr = bt.row_ct.node(nd.row).range();
                let cr = bt.col_ct.node(nd.col).range();
                let b = m.blocks[leaf].as_ref().expect("missing leaf");
                let bufs = BufferPool::global();
                let mut t = bufs.take(rr.len());
                let mut scratch = bufs.take(b.rank());
                apply_block_scratch(alpha, b, &x[cr], &mut t, &mut scratch);
                // scatter into y per leaf-cluster chunk (recursive descent)
                update_chunks(ct, nd.row, rr.start, &t, &yy, locks);
                bufs.put(t);
                bufs.put(scratch);
            });
        }
    });
}

/// Algorithm 3: collision-free cluster-list traversal — handle the full block
/// row of τ, then recurse into the children of τ in parallel.
pub fn cluster_lists(alpha: f64, m: &HMatrix, x: &[f64], y: &mut [f64]) {
    let yy = SharedVec::new(y);
    let pool = ThreadPool::global();
    pool.scope(|s| rec_cluster_lists(s, alpha, m, x, m.bt.row_ct.root(), yy, 0));
}

fn rec_cluster_lists<'e>(
    s: &crate::par::Scope<'e>,
    alpha: f64,
    m: &'e HMatrix,
    x: &'e [f64],
    tau: usize,
    y: SharedVec,
    depth: usize,
) {
    let bt = &m.bt;
    let ct = &bt.row_ct;
    let rr = ct.node(tau).range();
    // SAFETY: traversal invariant — the parent's block row is processed
    // before children run; clusters at the same level are disjoint.
    let yt = unsafe { y.range_mut(rr.clone()) };
    for &b in &bt.row_blocks[tau] {
        let nd = bt.node(b);
        let cr = bt.col_ct.node(nd.col).range();
        let blk = m.blocks[b].as_ref().expect("missing leaf");
        apply_block(alpha, blk, &x[cr], yt);
    }
    for &c in &ct.node(tau).children {
        if depth < SPAWN_LEVELS {
            s.spawn(move |s2| rec_cluster_lists(s2, alpha, m, x, c, y, depth + 1));
        } else {
            rec_cluster_lists(s, alpha, m, x, c, y, depth + 1);
        }
    }
}

/// Pre-computed per-row-cluster stacked low-rank factors (paper Fig. 4).
pub struct StackedH {
    /// For every row cluster with low-rank blocks: (cluster id, stacked U
    /// matrix, per-block (column range of x, V factor)).
    rows: Vec<(usize, DMatrix, Vec<(std::ops::Range<usize>, DMatrix)>)>,
    /// Dense leaves kept as (block id) list.
    dense: Vec<usize>,
}

impl StackedH {
    /// Build from an H-matrix. Compressed low-rank blocks are decompressed
    /// into the stacked FP64 factors (stacking is an *uncompressed-layout*
    /// optimization — the paper evaluates it without compression); dense
    /// blocks keep their representation and go through the generic kernel.
    pub fn new(m: &HMatrix) -> StackedH {
        let bt = &m.bt;
        let mut rows = Vec::new();
        let mut dense = Vec::new();
        for (tau, blocks) in bt.row_blocks.iter().enumerate() {
            let mut us: Option<DMatrix> = None;
            let mut vs: Vec<(std::ops::Range<usize>, DMatrix)> = Vec::new();
            for &b in blocks {
                let lr = match m.blocks[b].as_ref() {
                    Some(BlockData::LowRank(lr)) => Some(lr.clone()),
                    Some(BlockData::ZLowRank(z)) => Some(z.to_lowrank()),
                    Some(BlockData::ZLowRankValr(z)) => Some(z.to_lowrank()),
                    Some(BlockData::Dense(_)) | Some(BlockData::ZDense(_)) => {
                        dense.push(b);
                        None
                    }
                    None => {
                        let nd = bt.node(b);
                        panic!("stacked layout build: missing leaf data for block {b} (row cluster {}, col cluster {})", nd.row, nd.col)
                    }
                };
                if let Some(lr) = lr {
                    let cr = bt.col_ct.node(bt.node(b).col).range();
                    us = Some(match us {
                        None => lr.u.clone(),
                        Some(u) => u.hcat(&lr.u),
                    });
                    vs.push((cr, lr.v));
                }
            }
            if let Some(u) = us {
                rows.push((tau, u, vs));
            }
        }
        StackedH { rows, dense }
    }
}

/// Stacked MVM: one big gemv per block row for the low-rank parts; dense
/// parts as usual. Uses the same root-to-leaf collision-free order, realised
/// here by level-wise processing of the (disjoint) row clusters.
pub fn stacked(alpha: f64, m: &HMatrix, x: &[f64], y: &mut [f64]) {
    let st = StackedH::new(m);
    stacked_with(&st, alpha, m, x, y);
}

/// Stacked MVM with a pre-built [`StackedH`] (what a real caller does).
pub fn stacked_with(st: &StackedH, alpha: f64, m: &HMatrix, x: &[f64], y: &mut [f64]) {
    let bt = &m.bt;
    let ct = &bt.row_ct;
    let yy = SharedVec::new(y);
    let pool = ThreadPool::global();
    // level-wise: clusters on one level are disjoint → collision free
    let mut by_level: Vec<Vec<&(usize, DMatrix, Vec<(std::ops::Range<usize>, DMatrix)>)>> = vec![Vec::new(); ct.levels.len()];
    for row in &st.rows {
        by_level[ct.node(row.0).level].push(row);
    }
    for level in &by_level {
        pool.scope(|s| {
            for row in level {
                let yy = yy;
                s.spawn(move |_| {
                    let (tau, u, vs) = row;
                    let rr = ct.node(*tau).range();
                    // t = concat_b V_bᵀ x|σ_b
                    let mut t = vec![0.0; u.ncols()];
                    let mut off = 0;
                    for (cr, v) in vs {
                        blas::gemv_transposed(1.0, v, &x[cr.clone()], &mut t[off..off + v.ncols()]);
                        off += v.ncols();
                    }
                    // SAFETY: same-level clusters are disjoint.
                    let yt = unsafe { yy.range_mut(rr) };
                    blas::gemv(alpha, u, &t, yt);
                });
            }
        });
    }
    // dense blocks: same-level disjointness does not hold across (row,col)
    // pairs sharing a row cluster → group by row cluster
    let mut by_row: std::collections::BTreeMap<usize, Vec<usize>> = std::collections::BTreeMap::new();
    for &b in &st.dense {
        by_row.entry(bt.node(b).row).or_default().push(b);
    }
    let rows: Vec<(usize, Vec<usize>)> = by_row.into_iter().collect();
    pool.scope(|s| {
        for (tau, blocks) in &rows {
            let yy = yy;
            s.spawn(move |_| {
                let rr = ct.node(*tau).range();
                // SAFETY: dense leaves have leaf row clusters (disjoint).
                let yt = unsafe { yy.range_mut(rr) };
                for &b in blocks {
                    let nd = bt.node(b);
                    let cr = bt.col_ct.node(nd.col).range();
                    let blk = m.blocks[b].as_ref().unwrap();
                    apply_block(alpha, blk, &x[cr], yt);
                }
            });
        }
    });
}

/// Thread-local accumulation: the leaves are split into `num_threads` groups,
/// each writes into its own copy of y, joined by a final reduction.
pub fn thread_local(alpha: f64, m: &HMatrix, x: &[f64], y: &mut [f64]) {
    let bt = &m.bt;
    let pool = ThreadPool::global();
    let ngroups = (pool.num_threads() + 1).max(2);
    let n = y.len();
    let mut locals: Vec<Vec<f64>> = (0..ngroups).map(|_| vec![0.0; n]).collect();
    {
        let leaves = &bt.leaves;
        pool.scope(|s| {
            for (g, yloc) in locals.iter_mut().enumerate() {
                s.spawn(move |_| {
                    let mut i = g;
                    while i < leaves.len() {
                        let leaf = leaves[i];
                        let nd = bt.node(leaf);
                        let rr = bt.row_ct.node(nd.row).range();
                        let cr = bt.col_ct.node(nd.col).range();
                        let b = m.blocks[leaf].as_ref().unwrap();
                        apply_block(alpha, b, &x[cr], &mut yloc[rr]);
                        i += ngroups;
                    }
                });
            }
        });
    }
    // reduction phase (the part the paper identifies as the overhead)
    for yloc in &locals {
        blas::axpy(1.0, yloc, y);
    }
}

/// Atomic updates per coefficient (Ida et al. [21]). Pooled temporaries, as
/// in [`chunks`].
pub fn atomic(alpha: f64, m: &HMatrix, x: &[f64], y: &mut [f64]) {
    let bt = &m.bt;
    let ay = as_atomic_f64(y);
    let pool = ThreadPool::global();
    pool.scope(|s| {
        for &leaf in &bt.leaves {
            s.spawn(move |_| {
                let nd = bt.node(leaf);
                let rr = bt.row_ct.node(nd.row).range();
                let cr = bt.col_ct.node(nd.col).range();
                let b = m.blocks[leaf].as_ref().unwrap();
                let bufs = BufferPool::global();
                let mut t = bufs.take(rr.len());
                let mut scratch = bufs.take(b.rank());
                apply_block_scratch(alpha, b, &x[cr], &mut t, &mut scratch);
                for (i, v) in rr.zip(t.iter()) {
                    if *v != 0.0 {
                        atomic_add_f64(&ay[i], *v);
                    }
                }
                bufs.put(t);
                bufs.put(scratch);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{BlockTree, ClusterTree, StdAdmissibility};
    use crate::geometry::icosphere;
    use crate::kernelfn::{LaplaceSlp, MatrixGen};
    use crate::la::gemv;
    use crate::lowrank::AcaOptions;
    use crate::mvm::MvmAlgorithm;
    use crate::util::Rng;
    use std::sync::Arc;

    fn problem(level: usize) -> (HMatrix, DMatrix) {
        let geom = icosphere(level);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), 16));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-8));
        let d = h.to_dense();
        (h, d)
    }

    #[test]
    fn all_algorithms_match_dense() {
        let (h, d) = problem(2); // n = 320
        let mut rng = Rng::new(111);
        let x = rng.vector(h.ncols());
        let mut y_ref = rng.vector(h.nrows());
        let mut y0 = y_ref.clone();
        gemv(0.75, &d, &x, &mut y_ref);
        for algo in MvmAlgorithm::all() {
            let mut y = y0.clone();
            crate::mvm::mvm(0.75, &h, &x, &mut y, algo);
            let err: f64 = y.iter().zip(&y_ref).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
            assert!(err < 1e-10, "{algo:?} max err {err}");
        }
        // keep y0 alive for clarity
        y0.clear();
    }

    #[test]
    fn compressed_mvm_matches_uncompressed() {
        let (h, _) = problem(2);
        let mut hz = h.clone();
        hz.compress(&crate::compress::CompressionConfig::aflp(1e-10));
        let mut rng = Rng::new(112);
        let x = rng.vector(h.ncols());
        let mut y1 = vec![0.0; h.nrows()];
        let mut y2 = vec![0.0; h.nrows()];
        crate::mvm::mvm(1.0, &h, &x, &mut y1, MvmAlgorithm::ClusterLists);
        crate::mvm::mvm(1.0, &hz, &x, &mut y2, MvmAlgorithm::ClusterLists);
        let ynorm: f64 = y1.iter().map(|v| v * v).sum::<f64>().sqrt();
        let err: f64 = y1.iter().zip(&y2).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        assert!(err < 1e-7 * ynorm, "err {err} ynorm {ynorm}");
    }

    #[test]
    fn repeated_parallel_runs_deterministic_structure() {
        // collision-free algorithms must give bitwise identical results
        let (h, _) = problem(1);
        let mut rng = Rng::new(113);
        let x = rng.vector(h.ncols());
        let mut y1 = vec![0.0; h.nrows()];
        let mut y2 = vec![0.0; h.nrows()];
        crate::mvm::mvm(1.0, &h, &x, &mut y1, MvmAlgorithm::ClusterLists);
        crate::mvm::mvm(1.0, &h, &x, &mut y2, MvmAlgorithm::ClusterLists);
        assert_eq!(y1, y2);
    }
}
