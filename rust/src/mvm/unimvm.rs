//! Uniform H-matrix MVM (paper §3.2, Algorithms 4 & 5, Fig. 6 center).

use super::{update_chunks, SharedSlots, SharedVec, SPAWN_LEVELS};
use crate::la::blas;
use crate::par::ThreadPool;
use crate::uniform::{UniBlock, UniformHMatrix};
use std::sync::Mutex;

/// Algorithm 4: forward transformation s_σ = X_σᵀ x|σ for every column
/// cluster — trivially parallel (independent clusters).
fn forward(m: &UniformHMatrix, x: &[f64]) -> Vec<Vec<f64>> {
    let ct = &m.bt.col_ct;
    let mut s: Vec<Vec<f64>> = (0..ct.nodes.len()).map(|i| vec![0.0; m.col_basis[i].rank()]).collect();
    let slots = SharedSlots::new(&mut s);
    let pool = ThreadPool::global();
    pool.scope(|sc| {
        for sigma in 0..ct.nodes.len() {
            if m.col_basis[sigma].rank() == 0 {
                continue;
            }
            let slots = &slots;
            sc.spawn(move |_| {
                let range = ct.node(sigma).range();
                // SAFETY: one task per slot index.
                let dst = unsafe { slots.get_mut(sigma) };
                m.col_basis[sigma].apply_transposed(&x[range], dst);
            });
        }
    });
    s
}

/// Algorithm 5: row-wise collision-free traversal — accumulate coupling
/// contributions t_τ, apply the row basis once, handle dense blocks, then
/// recurse into the children in parallel.
pub fn row_wise(alpha: f64, m: &UniformHMatrix, x: &[f64], y: &mut [f64]) {
    let s = forward(m, x);
    let yy = SharedVec::new(y);
    let pool = ThreadPool::global();
    pool.scope(|sc| rec_row_wise(sc, alpha, m, x, &s, m.bt.row_ct.root(), yy, 0));
}

fn rec_row_wise<'e>(
    sc: &crate::par::Scope<'e>,
    alpha: f64,
    m: &'e UniformHMatrix,
    x: &'e [f64],
    s: &'e [Vec<f64>],
    tau: usize,
    y: SharedVec,
    depth: usize,
) {
    let bt = &m.bt;
    let ct = &bt.row_ct;
    let rr = ct.node(tau).range();
    let krow = m.row_basis[tau].rank();
    let mut t = vec![0.0; krow];
    let mut have_work = false;
    // coupling accumulation t_τ += S_b · s_σ
    for &b in &bt.row_blocks[tau] {
        if let Some(UniBlock::Coupling(c)) = m.blocks[b].as_ref() {
            let sigma = bt.node(b).col;
            c.apply_add(&s[sigma], &mut t);
            have_work = true;
        }
    }
    let has_dense = bt.row_blocks[tau].iter().any(|&b| matches!(m.blocks[b].as_ref(), Some(UniBlock::Dense(_)) | Some(UniBlock::ZDense(_))));
    if have_work || has_dense {
        // SAFETY: traversal invariant (parent before children, siblings
        // disjoint).
        let yt = unsafe { y.range_mut(rr.clone()) };
        if have_work {
            for v in t.iter_mut() {
                *v *= alpha;
            }
            m.row_basis[tau].apply_add(&t, yt);
        }
        if has_dense {
            for &b in &bt.row_blocks[tau] {
                let cr = bt.col_ct.node(bt.node(b).col).range();
                match m.blocks[b].as_ref() {
                    Some(UniBlock::Dense(d)) => blas::gemv(alpha, d, &x[cr], yt),
                    Some(UniBlock::ZDense(z)) => super::kernels::zgemv_blocked(alpha, z, &x[cr], yt),
                    _ => {}
                }
            }
        }
    }
    for &c in &ct.node(tau).children {
        if depth < SPAWN_LEVELS {
            sc.spawn(move |s2| rec_row_wise(s2, alpha, m, x, s, c, y, depth + 1));
        } else {
            rec_row_wise(sc, alpha, m, x, s, c, y, depth + 1);
        }
    }
}

/// Mutex variant: per-block tasks, t_τ accumulation and y chunk updates
/// guarded by mutexes.
pub fn mutex(alpha: f64, m: &UniformHMatrix, x: &[f64], y: &mut [f64]) {
    let s = forward(m, x);
    let bt = &m.bt;
    let ct = &bt.row_ct;
    let pool = ThreadPool::global();

    // phase 1: coupling accumulation under per-cluster mutexes; dense blocks
    // update y directly via chunk mutexes
    let t: Vec<Mutex<Vec<f64>>> = (0..ct.nodes.len()).map(|i| Mutex::new(vec![0.0; m.row_basis[i].rank()])).collect();
    let locks: Vec<Mutex<()>> = (0..ct.nodes.len()).map(|_| Mutex::new(())).collect();
    let yy = SharedVec::new(y);
    pool.scope(|sc| {
        for &leaf in &bt.leaves {
            let t = &t;
            let locks = &locks;
            let s = &s;
            let yy = yy;
            sc.spawn(move |_| {
                let nd = bt.node(leaf);
                match m.blocks[leaf].as_ref() {
                    Some(UniBlock::Coupling(c)) => {
                        let mut guard = t[nd.row].lock().unwrap();
                        c.apply_add(&s[nd.col], &mut guard);
                    }
                    Some(UniBlock::Dense(d)) => {
                        let cr = bt.col_ct.node(nd.col).range();
                        let rr = bt.row_ct.node(nd.row).range();
                        let mut tmp = vec![0.0; rr.len()];
                        blas::gemv(alpha, d, &x[cr], &mut tmp);
                        update_chunks(ct, nd.row, rr.start, &tmp, &yy, locks);
                    }
                    Some(UniBlock::ZDense(z)) => {
                        let cr = bt.col_ct.node(nd.col).range();
                        let rr = bt.row_ct.node(nd.row).range();
                        let mut tmp = vec![0.0; rr.len()];
                        super::kernels::zgemv_blocked(alpha, z, &x[cr], &mut tmp);
                        update_chunks(ct, nd.row, rr.start, &tmp, &yy, locks);
                    }
                    _ => {}
                }
            });
        }
    });

    // phase 2: backward transformation per row cluster, chunk-guarded
    pool.scope(|sc| {
        for tau in 0..ct.nodes.len() {
            if m.row_basis[tau].rank() == 0 {
                continue;
            }
            let t = &t;
            let locks = &locks;
            let yy = yy;
            sc.spawn(move |_| {
                let mut tv = t[tau].lock().unwrap().clone();
                if tv.iter().all(|&v| v == 0.0) {
                    return;
                }
                for v in tv.iter_mut() {
                    *v *= alpha;
                }
                let rr = ct.node(tau).range();
                let mut tmp = vec![0.0; rr.len()];
                m.row_basis[tau].apply_add(&tv, &mut tmp);
                update_chunks(ct, tau, rr.start, &tmp, &yy, locks);
            });
        }
    });
}

/// Separate-coupling variant (Bruyninckx et al. [13]): stage 1 computes
/// c_b = S_cᵀ s_σ independently per block; stage 2 applies S_r and the
/// backward transformation into thread-local vectors joined at the end.
pub fn sep_coupling(alpha: f64, m: &UniformHMatrix, x: &[f64], y: &mut [f64]) {
    let s = forward(m, x);
    let bt = &m.bt;
    let ct = &bt.row_ct;
    let pool = ThreadPool::global();

    // stage 1: per-block intermediate c_b
    let mut c: Vec<Vec<f64>> = vec![Vec::new(); bt.nodes.len()];
    {
        let slots = SharedSlots::new(&mut c);
        pool.scope(|sc| {
            for &leaf in &bt.leaves {
                let s = &s;
                let slots = &slots;
                sc.spawn(move |_| {
                    let nd = bt.node(leaf);
                    if let Some(UniBlock::Coupling(cm)) = m.blocks[leaf].as_ref() {
                        let sv = &s[nd.col];
                        let out = match cm.sep_parts() {
                            Some((_, scm)) => {
                                let mut cb = vec![0.0; scm.ncols()];
                                blas::gemv_transposed(1.0, scm, sv, &mut cb);
                                cb
                            }
                            // combined / compressed storage: keep s_σ, stage 2
                            // applies the full coupling
                            None => sv.clone(),
                        };
                        // SAFETY: one task per leaf slot.
                        unsafe {
                            *slots.get_mut(leaf) = out;
                        }
                    }
                });
            }
        });
    }

    // stage 2: thread-local backward transformation + dense blocks
    let ngroups = (pool.num_threads() + 1).max(2);
    let n = y.len();
    let mut locals: Vec<Vec<f64>> = (0..ngroups).map(|_| vec![0.0; n]).collect();
    {
        let c = &c;
        pool.scope(|sc| {
            for (g, yloc) in locals.iter_mut().enumerate() {
                let s = &s;
                sc.spawn(move |_| {
                    let mut tau = g;
                    while tau < ct.nodes.len() {
                        let rr = ct.node(tau).range();
                        let krow = m.row_basis[tau].rank();
                        let mut t = vec![0.0; krow];
                        let mut have = false;
                        for &b in &bt.row_blocks[tau] {
                            let nd = bt.node(b);
                            match m.blocks[b].as_ref() {
                                Some(UniBlock::Coupling(cm)) => {
                                    match cm.sep_parts() {
                                        Some((sr, _)) => blas::gemv(1.0, sr, &c[b], &mut t),
                                        None => cm.apply_add(&s[nd.col], &mut t),
                                    }
                                    have = true;
                                }
                                Some(UniBlock::Dense(d)) => {
                                    let cr = bt.col_ct.node(nd.col).range();
                                    blas::gemv(alpha, d, &x[cr], &mut yloc[rr.clone()]);
                                }
                                Some(UniBlock::ZDense(z)) => {
                                    let cr = bt.col_ct.node(nd.col).range();
                                    super::kernels::zgemv_blocked(alpha, z, &x[cr], &mut yloc[rr.clone()]);
                                }
                                _ => {}
                            }
                        }
                        if have {
                            for v in t.iter_mut() {
                                *v *= alpha;
                            }
                            m.row_basis[tau].apply_add(&t, &mut yloc[rr.clone()]);
                        }
                        tau += ngroups;
                    }
                });
            }
        });
    }
    // join thread-local results
    for yloc in &locals {
        blas::axpy(1.0, yloc, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{BlockTree, ClusterTree, StdAdmissibility};
    use crate::geometry::icosphere;
    use crate::hmatrix::HMatrix;
    use crate::kernelfn::{LaplaceSlp, MatrixGen};
    use crate::lowrank::AcaOptions;
    use crate::mvm::UniMvmAlgorithm;
    use crate::uniform::{build_from_h, CouplingKind};
    use crate::util::Rng;
    use std::sync::Arc;

    fn problem(kind: CouplingKind) -> (UniformHMatrix, crate::la::DMatrix) {
        let geom = icosphere(2);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), 16));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-7));
        let uh = build_from_h(&h, 1e-7, kind);
        let d = uh.to_dense();
        (uh, d)
    }

    #[test]
    fn all_algorithms_match_dense() {
        for kind in [CouplingKind::Combined, CouplingKind::Separate] {
            let (uh, d) = problem(kind);
            let mut rng = Rng::new(121);
            let x = rng.vector(uh.ncols());
            let mut y_ref = vec![0.5; uh.nrows()];
            crate::la::gemv(1.25, &d, &x, &mut y_ref);
            for algo in UniMvmAlgorithm::all() {
                let mut y = vec![0.5; uh.nrows()];
                crate::mvm::uniform_mvm(1.25, &uh, &x, &mut y, algo);
                let err: f64 = y.iter().zip(&y_ref).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
                assert!(err < 1e-9, "{kind:?} {algo:?} max err {err}");
            }
        }
    }

    #[test]
    fn compressed_uniform_mvm_agrees() {
        let (mut uh, d) = problem(CouplingKind::Combined);
        uh.compress(&crate::compress::CompressionConfig::aflp(1e-10));
        let mut rng = Rng::new(122);
        let x = rng.vector(uh.ncols());
        let mut y_ref = vec![0.0; uh.nrows()];
        crate::la::gemv(1.0, &d, &x, &mut y_ref);
        let ynorm: f64 = y_ref.iter().map(|v| v * v).sum::<f64>().sqrt();
        for algo in UniMvmAlgorithm::all() {
            let mut y = vec![0.0; uh.nrows()];
            crate::mvm::uniform_mvm(1.0, &uh, &x, &mut y, algo);
            let err: f64 = y.iter().zip(&y_ref).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
            assert!(err < 1e-6 * ynorm, "{algo:?}: err {err}");
        }
    }
}
