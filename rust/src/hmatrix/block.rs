//! Leaf block storage: dense / low-rank, uncompressed / compressed.

use crate::compress::{Blob, Codec, CompressionConfig, ZLowRankValr, BLOB_OVERHEAD};
use crate::la::DMatrix;
use crate::lowrank::LowRank;

/// Compressed dense matrix (column-major value order inside the blob).
#[derive(Clone, Debug)]
pub struct ZDense {
    pub nrows: usize,
    pub ncols: usize,
    pub blob: Blob,
}

impl ZDense {
    pub fn compress(m: &DMatrix, codec: Codec, eps: f64) -> ZDense {
        ZDense { nrows: m.nrows(), ncols: m.ncols(), blob: Blob::compress(codec, m.data(), eps) }
    }

    pub fn to_dense(&self) -> DMatrix {
        let mut d = DMatrix::zeros(self.nrows, self.ncols);
        self.blob.decompress_into(d.data_mut());
        d
    }

    pub fn byte_size(&self) -> usize {
        self.blob.byte_size()
    }
}

/// Fixed-precision compressed low-rank factors (non-VALR baseline).
#[derive(Clone, Debug)]
pub struct ZLowRankDirect {
    pub nrows: usize,
    pub ncols: usize,
    pub rank: usize,
    pub u: Blob,
    pub v: Blob,
}

impl ZLowRankDirect {
    pub fn compress(lr: &LowRank, codec: Codec, eps: f64) -> ZLowRankDirect {
        ZLowRankDirect {
            nrows: lr.nrows(),
            ncols: lr.ncols(),
            rank: lr.rank(),
            u: Blob::compress(codec, lr.u.data(), eps),
            v: Blob::compress(codec, lr.v.data(), eps),
        }
    }

    pub fn to_lowrank(&self) -> LowRank {
        let mut u = DMatrix::zeros(self.nrows, self.rank);
        let mut v = DMatrix::zeros(self.ncols, self.rank);
        self.u.decompress_into(u.data_mut());
        self.v.decompress_into(v.data_mut());
        LowRank { u, v }
    }

    pub fn byte_size(&self) -> usize {
        self.u.byte_size() + self.v.byte_size() + BLOB_OVERHEAD
    }
}

/// A leaf block of a hierarchical matrix.
#[derive(Clone, Debug)]
pub enum BlockData {
    /// Inadmissible: dense FP64.
    Dense(DMatrix),
    /// Admissible: factored U·Vᵀ in FP64.
    LowRank(LowRank),
    /// Inadmissible, compressed (direct compression, Alg. 8 kernels).
    ZDense(ZDense),
    /// Admissible, compressed with fixed precision.
    ZLowRank(ZLowRankDirect),
    /// Admissible, compressed with VALR (per-column accuracy).
    ZLowRankValr(ZLowRankValr),
}

impl BlockData {
    pub fn nrows(&self) -> usize {
        match self {
            BlockData::Dense(m) => m.nrows(),
            BlockData::LowRank(lr) => lr.nrows(),
            BlockData::ZDense(z) => z.nrows,
            BlockData::ZLowRank(z) => z.nrows,
            BlockData::ZLowRankValr(z) => z.nrows,
        }
    }

    pub fn ncols(&self) -> usize {
        match self {
            BlockData::Dense(m) => m.ncols(),
            BlockData::LowRank(lr) => lr.ncols(),
            BlockData::ZDense(z) => z.ncols,
            BlockData::ZLowRank(z) => z.ncols,
            BlockData::ZLowRankValr(z) => z.ncols,
        }
    }

    pub fn is_lowrank(&self) -> bool {
        matches!(self, BlockData::LowRank(_) | BlockData::ZLowRank(_) | BlockData::ZLowRankValr(_))
    }

    pub fn is_compressed(&self) -> bool {
        matches!(self, BlockData::ZDense(_) | BlockData::ZLowRank(_) | BlockData::ZLowRankValr(_))
    }

    /// Rank of low-rank blocks, 0 for dense.
    pub fn rank(&self) -> usize {
        match self {
            BlockData::LowRank(lr) => lr.rank(),
            BlockData::ZLowRank(z) => z.rank,
            BlockData::ZLowRankValr(z) => z.rank(),
            _ => 0,
        }
    }

    /// Memory footprint in bytes.
    pub fn byte_size(&self) -> usize {
        match self {
            BlockData::Dense(m) => m.byte_size(),
            BlockData::LowRank(lr) => lr.byte_size(),
            BlockData::ZDense(z) => z.byte_size(),
            BlockData::ZLowRank(z) => z.byte_size(),
            BlockData::ZLowRankValr(z) => z.byte_size(),
        }
    }

    /// Compress an uncompressed block per the config (no-op when already
    /// compressed).
    pub fn compress(&self, cfg: &CompressionConfig) -> BlockData {
        match self {
            BlockData::Dense(m) => BlockData::ZDense(ZDense::compress(m, cfg.codec, cfg.eps)),
            BlockData::LowRank(lr) => {
                if cfg.valr {
                    BlockData::ZLowRankValr(ZLowRankValr::compress_lowrank(lr, cfg.codec, cfg.eps))
                } else {
                    BlockData::ZLowRank(ZLowRankDirect::compress(lr, cfg.codec, cfg.eps))
                }
            }
            other => other.clone(),
        }
    }

    /// Visit every compressed payload blob of this block, in a fixed
    /// deterministic order (storage-tier walkers: packing, attach,
    /// residency, prefetch extents).
    pub fn for_each_blob(&self, f: &mut dyn FnMut(&Blob)) {
        match self {
            BlockData::Dense(_) | BlockData::LowRank(_) => {}
            BlockData::ZDense(z) => f(&z.blob),
            BlockData::ZLowRank(z) => {
                f(&z.u);
                f(&z.v);
            }
            BlockData::ZLowRankValr(z) => {
                for b in z.wcols.iter().chain(z.xcols.iter()) {
                    f(b);
                }
            }
        }
    }

    /// Mutable variant of [`BlockData::for_each_blob`] (same order) — used
    /// to re-point payloads into a mapped segment.
    pub fn for_each_blob_mut(&mut self, f: &mut dyn FnMut(&mut Blob)) {
        match self {
            BlockData::Dense(_) | BlockData::LowRank(_) => {}
            BlockData::ZDense(z) => f(&mut z.blob),
            BlockData::ZLowRank(z) => {
                f(&mut z.u);
                f(&mut z.v);
            }
            BlockData::ZLowRankValr(z) => {
                for b in z.wcols.iter_mut().chain(z.xcols.iter_mut()) {
                    f(b);
                }
            }
        }
    }

    /// Dense reconstruction (tests / error measurement).
    pub fn to_dense(&self) -> DMatrix {
        match self {
            BlockData::Dense(m) => m.clone(),
            BlockData::LowRank(lr) => lr.to_dense(),
            BlockData::ZDense(z) => z.to_dense(),
            BlockData::ZLowRank(z) => z.to_lowrank().to_dense(),
            BlockData::ZLowRankValr(z) => z.to_dense(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn zdense_roundtrip_error() {
        let mut rng = Rng::new(71);
        let m = DMatrix::random(32, 24, &mut rng);
        let z = ZDense::compress(&m, Codec::Aflp, 1e-7);
        let d = z.to_dense();
        let mut diff = d.clone();
        diff.add_scaled(-1.0, &m);
        assert!(diff.fro_norm() <= 1e-7 * m.fro_norm() * 4.0);
        assert!(z.byte_size() < m.byte_size());
    }

    #[test]
    fn block_compress_dispatch() {
        let mut rng = Rng::new(72);
        let dense = BlockData::Dense(DMatrix::random(16, 16, &mut rng));
        let lr = BlockData::LowRank(LowRank { u: DMatrix::random(16, 3, &mut rng), v: DMatrix::random(16, 3, &mut rng) });
        let cfg = CompressionConfig::aflp(1e-6);
        assert!(matches!(dense.compress(&cfg), BlockData::ZDense(_)));
        assert!(matches!(lr.compress(&cfg), BlockData::ZLowRankValr(_)));
        let cfg_fixed = CompressionConfig { valr: false, ..cfg };
        assert!(matches!(lr.compress(&cfg_fixed), BlockData::ZLowRank(_)));
    }

    #[test]
    fn compressed_blocks_smaller() {
        let mut rng = Rng::new(73);
        let lr = LowRank { u: DMatrix::random(64, 8, &mut rng), v: DMatrix::random(64, 8, &mut rng) };
        let b = BlockData::LowRank(lr);
        let zb = b.compress(&CompressionConfig::aflp(1e-4));
        assert!(zb.byte_size() < b.byte_size(), "{} !< {}", zb.byte_size(), b.byte_size());
    }
}
