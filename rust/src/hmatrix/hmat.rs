//! H-matrix construction and bookkeeping.

use super::block::BlockData;
use crate::cluster::BlockTree;
use crate::compress::CompressionConfig;
use crate::kernelfn::MatrixGen;
use crate::la::DMatrix;
use crate::lowrank::{aca, AcaOptions, BlockAccess};
use crate::par::ThreadPool;
use std::sync::{Arc, Mutex};

/// Hierarchical matrix: block tree + leaf data.
///
/// Vectors interacting with an `HMatrix` use the *internal* (cluster tree)
/// ordering; use [`crate::cluster::ClusterTree::to_internal`] /
/// [`crate::cluster::ClusterTree::to_external`] at the boundary.
#[derive(Clone)]
pub struct HMatrix {
    pub bt: Arc<BlockTree>,
    /// Leaf data indexed by block-tree node id.
    pub blocks: Vec<Option<BlockData>>,
}

/// Memory/structure statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct HMatrixStats {
    pub n_dense: usize,
    pub n_lowrank: usize,
    pub dense_bytes: usize,
    pub lowrank_bytes: usize,
    pub max_rank: usize,
    pub sum_rank: usize,
}

impl HMatrixStats {
    pub fn total_bytes(&self) -> usize {
        self.dense_bytes + self.lowrank_bytes
    }

    pub fn avg_rank(&self) -> f64 {
        if self.n_lowrank == 0 {
            0.0
        } else {
            self.sum_rank as f64 / self.n_lowrank as f64
        }
    }
}

impl HMatrix {
    /// Build from a generator: ACA on admissible leaves, dense assembly on
    /// inadmissible ones; leaves constructed in parallel.
    pub fn build(bt: &Arc<BlockTree>, gen: &dyn MatrixGen, opts: &AcaOptions) -> HMatrix {
        let nblocks = bt.nodes.len();
        let out: Mutex<Vec<Option<BlockData>>> = Mutex::new(vec![None; nblocks]);
        let pool = ThreadPool::global();
        let leaves = &bt.leaves;
        pool.scope(|s| {
            for &leaf in leaves {
                let out = &out;
                s.spawn(move |_| {
                    let data = build_leaf(bt, leaf, gen, opts);
                    out.lock().unwrap()[leaf] = Some(data);
                });
            }
        });
        HMatrix { bt: bt.clone(), blocks: out.into_inner().unwrap() }
    }

    pub fn nrows(&self) -> usize {
        self.bt.shape().0
    }

    pub fn ncols(&self) -> usize {
        self.bt.shape().1
    }

    /// Leaf block data for a block-tree node id.
    pub fn block(&self, id: usize) -> Option<&BlockData> {
        self.blocks[id].as_ref()
    }

    /// Compress all leaves in place (direct + VALR per the config, §4).
    pub fn compress(&mut self, cfg: &CompressionConfig) {
        let pool = ThreadPool::global();
        let blocks = std::mem::take(&mut self.blocks);
        let compressed: Mutex<Vec<Option<BlockData>>> = Mutex::new(vec![None; blocks.len()]);
        pool.scope(|s| {
            for (id, b) in blocks.iter().enumerate() {
                if let Some(data) = b {
                    let compressed = &compressed;
                    s.spawn(move |_| {
                        let z = data.compress(cfg);
                        compressed.lock().unwrap()[id] = Some(z);
                    });
                }
            }
        });
        self.blocks = compressed.into_inner().unwrap();
    }

    /// Memory statistics.
    pub fn stats(&self) -> HMatrixStats {
        let mut st = HMatrixStats::default();
        for b in self.blocks.iter().flatten() {
            if b.is_lowrank() {
                st.n_lowrank += 1;
                st.lowrank_bytes += b.byte_size();
                let r = b.rank();
                st.max_rank = st.max_rank.max(r);
                st.sum_rank += r;
            } else {
                st.n_dense += 1;
                st.dense_bytes += b.byte_size();
            }
        }
        st
    }

    /// Total bytes of leaf data.
    pub fn byte_size(&self) -> usize {
        self.stats().total_bytes()
    }

    /// Bytes per degree of freedom (paper Fig. 1 y-axis).
    pub fn bytes_per_dof(&self) -> f64 {
        self.byte_size() as f64 / self.nrows() as f64
    }

    /// Dense reconstruction in internal ordering (tests, small n only).
    pub fn to_dense(&self) -> DMatrix {
        let (m, n) = self.bt.shape();
        let mut out = DMatrix::zeros(m, n);
        for &leaf in &self.bt.leaves {
            let nd = self.bt.node(leaf);
            let rr = self.bt.row_ct.node(nd.row).range();
            let cr = self.bt.col_ct.node(nd.col).range();
            let d = self.blocks[leaf].as_ref().expect("missing leaf").to_dense();
            for (jj, j) in cr.enumerate() {
                for (ii, i) in rr.clone().enumerate() {
                    out[(i, j)] = d[(ii, jj)];
                }
            }
        }
        out
    }

    /// Frobenius norm (exact, from the block representation).
    pub fn fro_norm(&self) -> f64 {
        let mut sum = 0.0;
        for b in self.blocks.iter().flatten() {
            sum += block_fro2(b);
        }
        sum.sqrt()
    }
}

fn block_fro2(b: &BlockData) -> f64 {
    match b {
        BlockData::Dense(m) => m.fro_norm().powi(2),
        BlockData::LowRank(lr) => {
            // ||U V^T||_F^2 = trace((U^T U)(V^T V))
            let uu = crate::la::matmul(&lr.u, crate::la::Trans::Yes, &lr.u, crate::la::Trans::No);
            let vv = crate::la::matmul(&lr.v, crate::la::Trans::Yes, &lr.v, crate::la::Trans::No);
            let k = uu.nrows();
            let mut tr = 0.0;
            for i in 0..k {
                for j in 0..k {
                    tr += uu[(i, j)] * vv[(j, i)];
                }
            }
            tr
        }
        other => other.to_dense().fro_norm().powi(2),
    }
}

fn build_leaf(bt: &BlockTree, leaf: usize, gen: &dyn MatrixGen, opts: &AcaOptions) -> BlockData {
    let nd = bt.node(leaf);
    let rows = bt.row_ct.indices(nd.row);
    let cols = bt.col_ct.indices(nd.col);
    if nd.admissible {
        let lr = aca(&BlockAccess { gen, rows, cols }, opts);
        BlockData::LowRank(lr)
    } else {
        let mut m = DMatrix::zeros(rows.len(), cols.len());
        gen.fill(rows, cols, &mut m);
        BlockData::Dense(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterTree, StdAdmissibility};
    use crate::geometry::icosphere;
    use crate::kernelfn::LaplaceSlp;

    fn small_problem(level: usize, n_min: usize) -> (LaplaceSlp, Arc<BlockTree>) {
        let geom = icosphere(level);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), n_min));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        (gen, bt)
    }

    #[test]
    fn build_approximates_dense() {
        let (gen, bt) = small_problem(1, 8); // n = 80
        let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-6));
        // assemble reference in internal ordering
        let ct = &bt.row_ct;
        let n = ct.len();
        let mut dense = DMatrix::zeros(n, n);
        for j in 0..n {
            for i in 0..n {
                dense[(i, j)] = gen.entry(ct.perm[i], ct.perm[j]);
            }
        }
        let hd = h.to_dense();
        let mut diff = hd.clone();
        diff.add_scaled(-1.0, &dense);
        let rel = diff.fro_norm() / dense.fro_norm();
        assert!(rel < 1e-5, "rel err {rel}");
    }

    #[test]
    fn lowrank_blocks_save_memory() {
        let (gen, bt) = small_problem(2, 16); // n = 320
        let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-4));
        let st = h.stats();
        assert!(st.n_lowrank > 0);
        let densebytes = h.nrows() * h.ncols() * 8;
        assert!(h.byte_size() < densebytes, "H {} !< dense {}", h.byte_size(), densebytes);
    }

    #[test]
    fn compression_reduces_memory_keeps_error() {
        let (gen, bt) = small_problem(1, 8);
        let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-6));
        let before = h.byte_size();
        let dense_before = h.to_dense();
        let mut hz = h.clone();
        hz.compress(&CompressionConfig::aflp(1e-6));
        assert!(hz.byte_size() < before);
        let dense_after = hz.to_dense();
        let mut diff = dense_after.clone();
        diff.add_scaled(-1.0, &dense_before);
        let rel = diff.fro_norm() / dense_before.fro_norm();
        assert!(rel < 1e-5, "compression changed matrix too much: {rel}");
    }

    #[test]
    fn fro_norm_matches_dense() {
        let (gen, bt) = small_problem(1, 8);
        let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-8));
        let nd = h.to_dense().fro_norm();
        assert!((h.fro_norm() - nd).abs() < 1e-8 * nd);
    }

    #[test]
    fn finer_eps_higher_rank() {
        let (gen, bt) = small_problem(2, 16);
        let h4 = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-4));
        let h8 = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-8));
        assert!(h8.stats().avg_rank() > h4.stats().avg_rank());
        assert!(h8.byte_size() > h4.byte_size());
    }
}
