//! Operator-norm error estimation between two linear operators given only
//! their `apply` closures (used for Fig. 9: compressed vs reference error).

use crate::util::Rng;

/// Estimate ‖A − B‖₂ / ‖B‖₂ by power iteration on (A−B)ᵀ(A−B) using only
/// matrix-vector products. `apply_*`(x, y) must compute y = M x.
pub fn rel_spectral_error<FA, FB>(n: usize, apply_a: FA, apply_b: FB, iters: usize, seed: u64) -> f64
where
    FA: Fn(&[f64], &mut [f64]),
    FB: Fn(&[f64], &mut [f64]),
{
    let norm_b = spectral_norm(n, &apply_b, iters, seed ^ 0x9e37);
    if norm_b == 0.0 {
        return 0.0;
    }
    let diff = |x: &[f64], y: &mut [f64]| {
        let mut ya = vec![0.0; n];
        let mut yb = vec![0.0; n];
        apply_a(x, &mut ya);
        apply_b(x, &mut yb);
        for i in 0..n {
            y[i] = ya[i] - yb[i];
        }
    };
    spectral_norm(n, &diff, iters, seed) / norm_b
}

/// Spectral norm estimate of a symmetric-or-not operator by power iteration
/// on MᵀM — we only have M·x, so we use ‖Mx‖/‖x‖ maximization over iterated
/// normalized vectors (valid for symmetric M; for general M this
/// underestimates slightly, which is fine for the error *ratio* plots).
pub fn spectral_norm<F>(n: usize, apply: &F, iters: usize, seed: u64) -> f64
where
    F: Fn(&[f64], &mut [f64]),
{
    let mut rng = Rng::new(seed);
    let mut x = rng.vector(n);
    normalize(&mut x);
    let mut y = vec![0.0; n];
    let mut est = 0.0;
    for _ in 0..iters.max(2) {
        y.fill(0.0);
        apply(&x, &mut y);
        est = norm(&y);
        if est == 0.0 {
            return 0.0;
        }
        x.copy_from_slice(&y);
        normalize(&mut x);
    }
    est
}

fn norm(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

fn normalize(x: &mut [f64]) {
    let n = norm(x);
    if n > 0.0 {
        for v in x.iter_mut() {
            *v /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::{gemv, DMatrix};
    use crate::util::Rng;

    #[test]
    fn spectral_norm_of_diagonal() {
        let n = 20;
        let mut d = DMatrix::zeros(n, n);
        for i in 0..n {
            d[(i, i)] = (i + 1) as f64;
        }
        let apply = |x: &[f64], y: &mut [f64]| gemv(1.0, &d, x, y);
        let est = spectral_norm(n, &apply, 50, 1);
        assert!((est - n as f64).abs() < 0.2, "est {est}");
    }

    #[test]
    fn rel_error_of_perturbation() {
        let n = 30;
        let mut rng = Rng::new(5);
        let a = DMatrix::random(n, n, &mut rng);
        // b = a + small symmetric-ish perturbation
        let mut b = a.clone();
        b[(0, 0)] += 1e-3;
        let fa = |x: &[f64], y: &mut [f64]| gemv(1.0, &a, x, y);
        let fb = |x: &[f64], y: &mut [f64]| gemv(1.0, &b, x, y);
        let err = rel_spectral_error(n, fa, fb, 40, 2);
        assert!(err > 1e-6 && err < 1e-2, "err {err}");
    }

    #[test]
    fn identical_operators_zero_error() {
        let n = 10;
        let mut rng = Rng::new(6);
        let a = DMatrix::random(n, n, &mut rng);
        let fa = |x: &[f64], y: &mut [f64]| gemv(1.0, &a, x, y);
        let fb = |x: &[f64], y: &mut [f64]| gemv(1.0, &a, x, y);
        let err = rel_spectral_error(n, fa, fb, 20, 3);
        assert!(err < 1e-12);
    }
}
