//! H-matrices (Definition 2.3): block-tree structured storage with dense
//! inadmissible and factored low-rank admissible leaves, plus their
//! compressed representations (§4).

mod block;
mod hmat;
pub mod norms;

pub use block::{BlockData, ZDense, ZLowRankDirect};
pub use hmat::{HMatrix, HMatrixStats};
