//! Shared cluster bases W_τ (orthonormal columns) with the singular weights
//! retained for VALR compression (paper §4.2, Eq. 7).

use crate::compress::{Blob, CompressionConfig, ZLowRankValr, BLOB_OVERHEAD};
use crate::la::{blas, DMatrix};

/// Basis storage: FP64, fixed-precision compressed, or VALR compressed.
#[derive(Clone, Debug)]
pub enum BasisData {
    Plain(DMatrix),
    /// Fixed-precision direct compression of the basis matrix.
    Z { nrows: usize, ncols: usize, blob: Blob },
    /// Per-column VALR compression (uses the singular weights).
    Valr(ZLowRankValr),
}

/// A cluster basis: rank-k orthonormal matrix over the cluster's rows plus
/// the singular values of its construction (σ drives VALR accuracy).
#[derive(Clone, Debug)]
pub struct ClusterBasis {
    pub data: BasisData,
    pub sigma: Vec<f64>,
}

impl ClusterBasis {
    /// Empty basis (clusters without low-rank blocks, rank 0).
    pub fn empty(nrows: usize) -> ClusterBasis {
        ClusterBasis { data: BasisData::Plain(DMatrix::zeros(nrows, 0)), sigma: Vec::new() }
    }

    pub fn new(w: DMatrix, sigma: Vec<f64>) -> ClusterBasis {
        debug_assert_eq!(w.ncols(), sigma.len());
        ClusterBasis { data: BasisData::Plain(w), sigma }
    }

    pub fn rank(&self) -> usize {
        match &self.data {
            BasisData::Plain(w) => w.ncols(),
            BasisData::Z { ncols, .. } => *ncols,
            BasisData::Valr(z) => z.rank(),
        }
    }

    pub fn nrows(&self) -> usize {
        match &self.data {
            BasisData::Plain(w) => w.nrows(),
            BasisData::Z { nrows, .. } => *nrows,
            BasisData::Valr(z) => z.nrows,
        }
    }

    /// s = Wᵀ x (forward transformation contribution). `s` has rank() slots.
    /// Compressed storage runs on the fused decode–dot kernels (one cursor
    /// resolution per blob, decoded lanes kept in registers).
    pub fn apply_transposed(&self, x: &[f64], s: &mut [f64]) {
        debug_assert_eq!(x.len(), self.nrows());
        debug_assert_eq!(s.len(), self.rank());
        match &self.data {
            BasisData::Plain(w) => {
                for (j, sj) in s.iter_mut().enumerate() {
                    *sj += blas::dot(w.col(j), x);
                }
            }
            BasisData::Z { nrows, ncols, blob } => {
                crate::mvm::kernels::stream_dot_cols(blob, *nrows, *ncols, x, s);
            }
            BasisData::Valr(z) => {
                for (j, sj) in s.iter_mut().enumerate().take(z.rank()) {
                    *sj += crate::mvm::kernels::stream_dot(&z.wcols[j], x);
                }
            }
        }
    }

    /// y += W t (backward transformation contribution); compressed storage
    /// runs on the fused decode–axpy kernels.
    pub fn apply_add(&self, t: &[f64], y: &mut [f64]) {
        debug_assert_eq!(t.len(), self.rank());
        debug_assert_eq!(y.len(), self.nrows());
        match &self.data {
            BasisData::Plain(w) => {
                for (j, &tj) in t.iter().enumerate() {
                    if tj != 0.0 {
                        blas::axpy(tj, w.col(j), y);
                    }
                }
            }
            BasisData::Z { nrows, ncols, blob } => {
                crate::mvm::kernels::stream_axpy_cols(blob, *nrows, *ncols, 1.0, t, y);
            }
            BasisData::Valr(z) => {
                for (j, &tj) in t.iter().enumerate().take(z.rank()) {
                    if tj != 0.0 {
                        crate::mvm::kernels::stream_axpy(&z.wcols[j], tj, y);
                    }
                }
            }
        }
    }

    /// Dense copy of W.
    pub fn to_dense(&self) -> DMatrix {
        match &self.data {
            BasisData::Plain(w) => w.clone(),
            BasisData::Z { nrows, ncols, blob } => {
                let mut w = DMatrix::zeros(*nrows, *ncols);
                blob.decompress_into(w.data_mut());
                w
            }
            BasisData::Valr(z) => z.w_to_dense(),
        }
    }

    /// Panel (multi-RHS) forward transformation S += Wᵀ X on contiguous
    /// column-major panels (X: nrows×nrhs, S: rank×nrhs). Basis data —
    /// compressed included — is streamed once for all `nrhs` columns.
    pub fn apply_transposed_panel(&self, x: &[f64], s: &mut [f64], nrhs: usize) {
        debug_assert_eq!(x.len(), self.nrows() * nrhs);
        debug_assert_eq!(s.len(), self.rank() * nrhs);
        self.data.apply_transposed_panel(x, s, nrhs);
    }

    /// Panel backward transformation Y += W T (T: rank×nrhs, Y: nrows×nrhs).
    pub fn apply_add_panel(&self, t: &[f64], y: &mut [f64], nrhs: usize) {
        debug_assert_eq!(t.len(), self.rank() * nrhs);
        debug_assert_eq!(y.len(), self.nrows() * nrhs);
        self.data.apply_add_panel(t, y, nrhs);
    }

    /// Compress in place per config.
    pub fn compress(&mut self, cfg: &CompressionConfig) {
        if let BasisData::Plain(w) = &self.data {
            if w.ncols() == 0 {
                return;
            }
            self.data = if cfg.valr {
                BasisData::Valr(ZLowRankValr::compress_basis(w, &self.sigma, cfg.codec, cfg.eps))
            } else {
                BasisData::Z { nrows: w.nrows(), ncols: w.ncols(), blob: Blob::compress(cfg.codec, w.data(), cfg.eps) }
            };
        }
    }

    pub fn byte_size(&self) -> usize {
        let d = match &self.data {
            BasisData::Plain(w) => w.byte_size(),
            BasisData::Z { blob, .. } => blob.byte_size(),
            BasisData::Valr(z) => z.byte_size(),
        };
        d + self.sigma.len() * 8 + BLOB_OVERHEAD
    }
}

impl BasisData {
    /// Visit every compressed payload blob, in a fixed deterministic order
    /// (storage-tier walkers; shared by [`ClusterBasis`] and the H² nested
    /// leaf bases).
    pub fn for_each_blob(&self, f: &mut dyn FnMut(&Blob)) {
        match self {
            BasisData::Plain(_) => {}
            BasisData::Z { blob, .. } => f(blob),
            BasisData::Valr(z) => {
                for b in z.wcols.iter().chain(z.xcols.iter()) {
                    f(b);
                }
            }
        }
    }

    /// Mutable variant of [`BasisData::for_each_blob`] (same order).
    pub fn for_each_blob_mut(&mut self, f: &mut dyn FnMut(&mut Blob)) {
        match self {
            BasisData::Plain(_) => {}
            BasisData::Z { blob, .. } => f(blob),
            BasisData::Valr(z) => {
                for b in z.wcols.iter_mut().chain(z.xcols.iter_mut()) {
                    f(b);
                }
            }
        }
    }

    /// S += Wᵀ X on contiguous panels (X: nrows×nrhs, S: rank×nrhs): every
    /// basis column is decoded once per chunk and dotted with all `nrhs`
    /// input columns (shared by [`ClusterBasis`] and the H² nested-basis
    /// leaves).
    pub(crate) fn apply_transposed_panel(&self, x: &[f64], s: &mut [f64], nrhs: usize) {
        match self {
            BasisData::Plain(w) => crate::mvm::kernels::gemm_tn_panel(1.0, w, x, s, nrhs),
            BasisData::Z { nrows, ncols, blob } => {
                crate::mvm::kernels::stream_dot_cols_panel(blob, *nrows, *ncols, x, nrhs, s);
            }
            BasisData::Valr(z) => {
                let k = z.rank();
                let n = z.nrows;
                for (j, col) in z.wcols.iter().enumerate() {
                    crate::mvm::kernels::stream_dot_strided_panel(col, x, n, nrhs, &mut s[j..], k);
                }
            }
        }
    }

    /// Y += W T on contiguous panels (T: rank×nrhs, Y: nrows×nrhs).
    pub(crate) fn apply_add_panel(&self, t: &[f64], y: &mut [f64], nrhs: usize) {
        match self {
            BasisData::Plain(w) => crate::mvm::kernels::gemm_nn_panel(1.0, w, t, y, nrhs),
            BasisData::Z { nrows, ncols, blob } => {
                crate::mvm::kernels::stream_axpy_cols_panel(blob, *nrows, *ncols, 1.0, t, nrhs, y);
            }
            BasisData::Valr(z) => {
                let k = z.rank();
                let n = z.nrows;
                for (j, col) in z.wcols.iter().enumerate() {
                    if (0..nrhs).all(|c| t[c * k + j] == 0.0) {
                        continue;
                    }
                    crate::mvm::kernels::stream_axpy_strided_panel(col, 1.0, &t[j..], k, nrhs, y, n);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::util::Rng;

    fn ortho_basis(n: usize, k: usize, seed: u64) -> (DMatrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let (q, _) = crate::la::qr_thin(&DMatrix::random(n, k, &mut rng));
        let sigma: Vec<f64> = (0..k).map(|i| 0.5f64.powi(i as i32)).collect();
        (q, sigma)
    }

    #[test]
    fn apply_matches_dense_paths() {
        let (w, sigma) = ortho_basis(100, 6, 81);
        let mut rng = Rng::new(82);
        let x = rng.vector(100);
        let mut s_ref = vec![0.0; 6];
        for j in 0..6 {
            s_ref[j] = blas::dot(w.col(j), &x);
        }

        for cfg in [
            None,
            Some(CompressionConfig { codec: Codec::Aflp, eps: 1e-10, valr: false }),
            Some(CompressionConfig { codec: Codec::Aflp, eps: 1e-10, valr: true }),
            Some(CompressionConfig { codec: Codec::Fpx, eps: 1e-10, valr: true }),
        ] {
            let mut cb = ClusterBasis::new(w.clone(), sigma.clone());
            if let Some(c) = cfg {
                cb.compress(&c);
            }
            let mut s = vec![0.0; 6];
            cb.apply_transposed(&x, &mut s);
            for j in 0..6 {
                assert!((s[j] - s_ref[j]).abs() < 1e-6, "{cfg:?} s[{j}]");
            }
            // backward
            let t = vec![1.0; 6];
            let mut y = vec![0.0; 100];
            cb.apply_add(&t, &mut y);
            let mut y_ref = vec![0.0; 100];
            for j in 0..6 {
                blas::axpy(1.0, w.col(j), &mut y_ref);
            }
            for i in 0..100 {
                assert!((y[i] - y_ref[i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn panel_applies_match_per_column() {
        let (w, sigma) = ortho_basis(90, 5, 84);
        let mut rng = Rng::new(85);
        let nrhs = 3;
        let x: Vec<f64> = (0..90 * nrhs).map(|_| rng.normal()).collect();
        let t: Vec<f64> = (0..5 * nrhs).map(|_| rng.normal()).collect();
        for cfg in [
            None,
            Some(CompressionConfig { codec: Codec::Aflp, eps: 1e-10, valr: false }),
            Some(CompressionConfig { codec: Codec::Aflp, eps: 1e-10, valr: true }),
            Some(CompressionConfig { codec: Codec::Fpx, eps: 1e-10, valr: true }),
        ] {
            let mut cb = ClusterBasis::new(w.clone(), sigma.clone());
            if let Some(c) = cfg {
                cb.compress(&c);
            }
            let mut s = vec![0.0; 5 * nrhs];
            cb.apply_transposed_panel(&x, &mut s, nrhs);
            let mut y = vec![0.0; 90 * nrhs];
            cb.apply_add_panel(&t, &mut y, nrhs);
            for c in 0..nrhs {
                let mut sc = vec![0.0; 5];
                cb.apply_transposed(&x[c * 90..(c + 1) * 90], &mut sc);
                for j in 0..5 {
                    assert!((s[c * 5 + j] - sc[j]).abs() < 1e-12, "{cfg:?} fwd col {c} j {j}");
                }
                let mut yc = vec![0.0; 90];
                cb.apply_add(&t[c * 5..(c + 1) * 5], &mut yc);
                for i in 0..90 {
                    assert!((y[c * 90 + i] - yc[i]).abs() < 1e-12, "{cfg:?} bwd col {c} i {i}");
                }
            }
        }
    }

    #[test]
    fn compression_shrinks_basis() {
        let (w, sigma) = ortho_basis(512, 12, 83);
        let mut cb = ClusterBasis::new(w, sigma);
        let before = cb.byte_size();
        cb.compress(&CompressionConfig::aflp(1e-6));
        assert!(cb.byte_size() < before);
    }

    #[test]
    fn empty_basis_is_inert() {
        let cb = ClusterBasis::empty(10);
        assert_eq!(cb.rank(), 0);
        let x = vec![1.0; 10];
        let mut s: Vec<f64> = vec![];
        cb.apply_transposed(&x, &mut s);
        let mut y = vec![0.0; 10];
        cb.apply_add(&[], &mut y);
        assert!(y.iter().all(|&v| v == 0.0));
    }
}
