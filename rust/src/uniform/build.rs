//! Construction of shared cluster bases from an H-matrix (paper §2.3; basis
//! algorithm after Bruyninckx/Huybrechs/Meerbergen and Börm: per block row,
//! SVD of the weighted concatenation of the low-rank factors).

use super::basis::ClusterBasis;
use super::uhmat::{CouplingKind, CouplingMat, UniBlock, UniformHMatrix};
use crate::cluster::BlockTree;
use crate::hmatrix::{BlockData, HMatrix};
use crate::la::{blas, qr_thin, svd_adaptive, DMatrix};
use crate::par::ThreadPool;
use std::sync::{Arc, Mutex};

/// Build a uniform H-matrix from an H-matrix with basis truncation accuracy
/// `eps` (relative, per cluster).
pub fn build_from_h(h: &HMatrix, eps: f64, kind: CouplingKind) -> UniformHMatrix {
    let bt = h.bt.clone();
    let row_basis = build_bases(h, &bt, eps, true);
    let col_basis = build_bases(h, &bt, eps, false);
    let blocks = build_blocks(h, &bt, &row_basis, &col_basis, kind);
    UniformHMatrix { bt, row_basis, col_basis, blocks }
}

/// Shared basis for every cluster of the row (or column) tree.
fn build_bases(h: &HMatrix, bt: &Arc<BlockTree>, eps: f64, row_side: bool) -> Vec<ClusterBasis> {
    let ct = if row_side { &bt.row_ct } else { &bt.col_ct };
    let nclusters = ct.nodes.len();
    let out: Mutex<Vec<Option<ClusterBasis>>> = Mutex::new(vec![None; nclusters]);
    let pool = ThreadPool::global();
    pool.scope(|s| {
        for tau in 0..nclusters {
            let out = &out;
            s.spawn(move |_| {
                let basis = cluster_basis(h, bt, tau, eps, row_side);
                out.lock().unwrap()[tau] = Some(basis);
            });
        }
    });
    out.into_inner().unwrap().into_iter().map(|b| b.unwrap()).collect()
}

/// Basis of a single cluster: SVD of [U₁R₁ᵀ | U₂R₂ᵀ | …] over the low-rank
/// blocks of the block row (weighted by the QR factors of the opposite side
/// so the singular values reflect the true block norms).
fn cluster_basis(h: &HMatrix, bt: &BlockTree, tau: usize, eps: f64, row_side: bool) -> ClusterBasis {
    let ct = if row_side { &bt.row_ct } else { &bt.col_ct };
    let block_list = if row_side { &bt.row_blocks[tau] } else { &bt.col_blocks[tau] };
    let nrows = ct.node(tau).size();

    let mut pieces: Vec<DMatrix> = Vec::new();
    for &b in block_list {
        if !bt.node(b).admissible {
            continue;
        }
        if let Some(BlockData::LowRank(lr)) = h.block(b) {
            if lr.rank() == 0 {
                continue;
            }
            let (own, other) = if row_side { (&lr.u, &lr.v) } else { (&lr.v, &lr.u) };
            let (_, r) = qr_thin(other);
            // own · Rᵀ: |τ| × k, carries the block's singular weights
            pieces.push(blas::matmul(own, blas::Trans::No, &r, blas::Trans::Yes));
        }
    }
    if pieces.is_empty() {
        return ClusterBasis::empty(nrows);
    }
    let mut a = pieces[0].clone();
    for p in &pieces[1..] {
        a = a.hcat(p);
    }
    let svd = svd_adaptive(&a, eps);
    let k = svd.rank(eps).max(1);
    let t = svd.truncate(k);
    ClusterBasis::new(t.u, t.s)
}

/// Couplings S = (W_τᵀ U)(X_σᵀ V)ᵀ for all low-rank leaves, dense leaves
/// copied.
fn build_blocks(
    h: &HMatrix,
    bt: &Arc<BlockTree>,
    row_basis: &[ClusterBasis],
    col_basis: &[ClusterBasis],
    kind: CouplingKind,
) -> Vec<Option<UniBlock>> {
    let out: Mutex<Vec<Option<UniBlock>>> = Mutex::new(vec![None; bt.nodes.len()]);
    let pool = ThreadPool::global();
    pool.scope(|s| {
        for &leaf in &bt.leaves {
            let out = &out;
            s.spawn(move |_| {
                let nd = bt.node(leaf);
                let blk = match h.block(leaf) {
                    Some(BlockData::Dense(m)) => UniBlock::Dense(m.clone()),
                    Some(BlockData::LowRank(lr)) => {
                        let w = row_basis[nd.row].to_dense();
                        let x = col_basis[nd.col].to_dense();
                        // Sr = Wᵀ U (k_τ × k_b), Sc = Xᵀ V (k_σ × k_b)
                        let sr = blas::matmul(&w, blas::Trans::Yes, &lr.u, blas::Trans::No);
                        let sc = blas::matmul(&x, blas::Trans::Yes, &lr.v, blas::Trans::No);
                        match kind {
                            CouplingKind::Combined => {
                                UniBlock::Coupling(CouplingMat::Plain(blas::matmul(&sr, blas::Trans::No, &sc, blas::Trans::Yes)))
                            }
                            CouplingKind::Separate => UniBlock::Coupling(CouplingMat::SepPlain { sr, sc }),
                        }
                    }
                    other => panic!("uniform build requires an uncompressed H-matrix, got {other:?}"),
                };
                out.lock().unwrap()[leaf] = Some(blk);
            });
        }
    });
    out.into_inner().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterTree, StdAdmissibility};
    use crate::geometry::icosphere;
    use crate::kernelfn::{LaplaceSlp, MatrixGen};
    use crate::lowrank::AcaOptions;

    fn problem(level: usize, n_min: usize, eps: f64) -> (HMatrix, UniformHMatrix) {
        let geom = icosphere(level);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), n_min));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(eps));
        let uh = build_from_h(&h, eps, CouplingKind::Combined);
        (h, uh)
    }

    #[test]
    fn uniform_approximates_h() {
        let (h, uh) = problem(1, 8, 1e-6);
        let hd = h.to_dense();
        let ud = uh.to_dense();
        let mut diff = ud.clone();
        diff.add_scaled(-1.0, &hd);
        let rel = diff.fro_norm() / hd.fro_norm();
        assert!(rel < 1e-4, "rel {rel}");
    }

    #[test]
    fn coupling_storage_is_small() {
        let (h, uh) = problem(2, 16, 1e-4);
        let st = uh.stats();
        // coupling matrices are k×k — far smaller than the H low-rank factors
        assert!(st.coupling_bytes < h.stats().lowrank_bytes);
        assert!(st.basis_bytes > 0);
    }

    #[test]
    fn separate_coupling_equivalent() {
        let geom = icosphere(1);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), 8));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-6));
        let c = build_from_h(&h, 1e-6, CouplingKind::Combined).to_dense();
        let s = build_from_h(&h, 1e-6, CouplingKind::Separate).to_dense();
        let mut diff = c.clone();
        diff.add_scaled(-1.0, &s);
        assert!(diff.fro_norm() < 1e-10 * c.fro_norm().max(1.0));
    }

    #[test]
    fn bases_are_orthonormal() {
        let (_, uh) = problem(1, 8, 1e-6);
        for b in &uh.row_basis {
            if b.rank() == 0 {
                continue;
            }
            let w = b.to_dense();
            let wtw = blas::matmul(&w, blas::Trans::Yes, &w, blas::Trans::No);
            for i in 0..w.ncols() {
                for j in 0..w.ncols() {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((wtw[(i, j)] - want).abs() < 1e-8);
                }
            }
        }
    }
}
