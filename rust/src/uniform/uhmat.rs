//! Uniform H-matrix container.

use super::basis::ClusterBasis;
use crate::cluster::BlockTree;
use crate::compress::CompressionConfig;
use crate::hmatrix::ZDense;
use crate::la::{blas, DMatrix};
use crate::par::ThreadPool;
use std::sync::Arc;

/// How coupling matrices are stored.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CouplingKind {
    /// Single matrix S = Sr·Scᵀ (default).
    Combined,
    /// Separate row/column coupling Sr, Sc (Bruyninckx et al. variant,
    /// paper §3.2 "sep. coupling").
    Separate,
}

/// Coupling matrix storage.
#[derive(Clone, Debug)]
pub enum CouplingMat {
    Plain(DMatrix),
    Z(ZDense),
    SepPlain { sr: DMatrix, sc: DMatrix },
    SepZ { sr: ZDense, sc: ZDense },
}

impl CouplingMat {
    /// t += S · s  (t: row-basis rank slots, s: column coefficients). Thin
    /// allocating wrapper around [`CouplingMat::apply_add_scratch`].
    pub fn apply_add(&self, s: &[f64], t: &mut [f64]) {
        let mut tmp = vec![0.0; self.scratch_len()];
        self.apply_add_scratch(s, t, &mut tmp);
    }

    /// t += S · s with caller-provided scratch (≥ [`CouplingMat::scratch_len`]
    /// values). Compressed couplings run on the fused decode–FMA kernels
    /// (runtime-dispatched SIMD, [`crate::compress::dispatch`]) — never fully
    /// decompressed — so this performs no heap allocation.
    pub fn apply_add_scratch(&self, s: &[f64], t: &mut [f64], scratch: &mut [f64]) {
        match self {
            CouplingMat::Plain(m) => blas::gemv(1.0, m, s, t),
            CouplingMat::Z(z) => crate::mvm::kernels::zgemv_blocked(1.0, z, s, t),
            CouplingMat::SepPlain { sr, sc } => {
                // t += Sr (Scᵀ s)
                let tmp = &mut scratch[..sc.ncols()];
                tmp.fill(0.0);
                blas::gemv_transposed(1.0, sc, s, tmp);
                blas::gemv(1.0, sr, tmp, t);
            }
            CouplingMat::SepZ { sr, sc } => {
                let tmp = &mut scratch[..sc.ncols];
                tmp.fill(0.0);
                crate::mvm::kernels::zgemv_t_blocked(1.0, sc, s, tmp);
                crate::mvm::kernels::zgemv_blocked(1.0, sr, tmp, t);
            }
        }
    }

    /// t += Sᵀ · s (adjoint product: column coefficients from row
    /// coefficients). Thin allocating wrapper.
    pub fn apply_transposed_add(&self, s: &[f64], t: &mut [f64]) {
        let mut tmp = vec![0.0; self.scratch_len()];
        self.apply_transposed_add_scratch(s, t, &mut tmp);
    }

    /// t += Sᵀ · s with caller-provided scratch; Sᵀ = Sc·Srᵀ for separate
    /// coupling storage.
    pub fn apply_transposed_add_scratch(&self, s: &[f64], t: &mut [f64], scratch: &mut [f64]) {
        match self {
            CouplingMat::Plain(m) => blas::gemv_transposed(1.0, m, s, t),
            CouplingMat::Z(z) => crate::mvm::kernels::zgemv_t_blocked(1.0, z, s, t),
            CouplingMat::SepPlain { sr, sc } => {
                let tmp = &mut scratch[..sr.ncols()];
                tmp.fill(0.0);
                blas::gemv_transposed(1.0, sr, s, tmp);
                blas::gemv(1.0, sc, tmp, t);
            }
            CouplingMat::SepZ { sr, sc } => {
                let tmp = &mut scratch[..sr.ncols];
                tmp.fill(0.0);
                crate::mvm::kernels::zgemv_t_blocked(1.0, sr, s, tmp);
                crate::mvm::kernels::zgemv_blocked(1.0, sc, tmp, t);
            }
        }
    }

    /// Panel variant of [`CouplingMat::apply_add_scratch`]: T += S · Spanel on
    /// contiguous column-major panels (s: ncols×nrhs, t: nrows×nrhs), with
    /// scratch of at least [`CouplingMat::scratch_len`]` * nrhs` values.
    /// Compressed couplings are decoded once per chunk for all `nrhs` columns.
    pub fn apply_add_panel(&self, s: &[f64], t: &mut [f64], nrhs: usize, scratch: &mut [f64]) {
        use crate::mvm::kernels::{gemm_nn_panel, gemm_tn_panel, zgemm_blocked_panel, zgemm_t_blocked_panel};
        match self {
            CouplingMat::Plain(m) => gemm_nn_panel(1.0, m, s, t, nrhs),
            CouplingMat::Z(z) => zgemm_blocked_panel(1.0, z, s, t, nrhs),
            CouplingMat::SepPlain { sr, sc } => {
                let tmp = &mut scratch[..sc.ncols() * nrhs];
                tmp.fill(0.0);
                gemm_tn_panel(1.0, sc, s, tmp, nrhs);
                gemm_nn_panel(1.0, sr, tmp, t, nrhs);
            }
            CouplingMat::SepZ { sr, sc } => {
                let tmp = &mut scratch[..sc.ncols * nrhs];
                tmp.fill(0.0);
                zgemm_t_blocked_panel(1.0, sc, s, tmp, nrhs);
                zgemm_blocked_panel(1.0, sr, tmp, t, nrhs);
            }
        }
    }

    /// Panel variant of [`CouplingMat::apply_transposed_add_scratch`]:
    /// T += Sᵀ · Spanel on contiguous panels.
    pub fn apply_transposed_add_panel(&self, s: &[f64], t: &mut [f64], nrhs: usize, scratch: &mut [f64]) {
        use crate::mvm::kernels::{gemm_nn_panel, gemm_tn_panel, zgemm_blocked_panel, zgemm_t_blocked_panel};
        match self {
            CouplingMat::Plain(m) => gemm_tn_panel(1.0, m, s, t, nrhs),
            CouplingMat::Z(z) => zgemm_t_blocked_panel(1.0, z, s, t, nrhs),
            CouplingMat::SepPlain { sr, sc } => {
                let tmp = &mut scratch[..sr.ncols() * nrhs];
                tmp.fill(0.0);
                gemm_tn_panel(1.0, sr, s, tmp, nrhs);
                gemm_nn_panel(1.0, sc, tmp, t, nrhs);
            }
            CouplingMat::SepZ { sr, sc } => {
                let tmp = &mut scratch[..sr.ncols * nrhs];
                tmp.fill(0.0);
                zgemm_t_blocked_panel(1.0, sr, s, tmp, nrhs);
                zgemm_blocked_panel(1.0, sc, tmp, t, nrhs);
            }
        }
    }

    /// Scratch values needed by the `_scratch` apply variants.
    pub fn scratch_len(&self) -> usize {
        match self {
            CouplingMat::Plain(_) | CouplingMat::Z(_) => 0,
            CouplingMat::SepPlain { sr, sc } => sr.ncols().max(sc.ncols()),
            CouplingMat::SepZ { sr, sc } => sr.ncols.max(sc.ncols),
        }
    }

    /// First stage of the separate-coupling scheme: c = Scᵀ s (falls back to
    /// the full product for combined storage — used only by the sep-coupling
    /// MVM variant).
    pub fn sep_parts(&self) -> Option<(&DMatrix, &DMatrix)> {
        match self {
            CouplingMat::SepPlain { sr, sc } => Some((sr, sc)),
            _ => None,
        }
    }

    pub fn to_dense(&self) -> DMatrix {
        match self {
            CouplingMat::Plain(m) => m.clone(),
            CouplingMat::Z(z) => z.to_dense(),
            CouplingMat::SepPlain { sr, sc } => blas::matmul(sr, blas::Trans::No, sc, blas::Trans::Yes),
            CouplingMat::SepZ { sr, sc } => blas::matmul(&sr.to_dense(), blas::Trans::No, &sc.to_dense(), blas::Trans::Yes),
        }
    }

    pub fn byte_size(&self) -> usize {
        match self {
            CouplingMat::Plain(m) => m.byte_size(),
            CouplingMat::Z(z) => z.byte_size(),
            CouplingMat::SepPlain { sr, sc } => sr.byte_size() + sc.byte_size(),
            CouplingMat::SepZ { sr, sc } => sr.byte_size() + sc.byte_size(),
        }
    }

    pub fn compress(&self, cfg: &CompressionConfig) -> CouplingMat {
        match self {
            CouplingMat::Plain(m) => CouplingMat::Z(ZDense::compress(m, cfg.codec, cfg.eps)),
            CouplingMat::SepPlain { sr, sc } => {
                CouplingMat::SepZ { sr: ZDense::compress(sr, cfg.codec, cfg.eps), sc: ZDense::compress(sc, cfg.codec, cfg.eps) }
            }
            other => other.clone(),
        }
    }

    /// Visit every compressed payload blob, in a fixed deterministic order
    /// (storage-tier walkers).
    pub fn for_each_blob(&self, f: &mut dyn FnMut(&crate::compress::Blob)) {
        match self {
            CouplingMat::Plain(_) | CouplingMat::SepPlain { .. } => {}
            CouplingMat::Z(z) => f(&z.blob),
            CouplingMat::SepZ { sr, sc } => {
                f(&sr.blob);
                f(&sc.blob);
            }
        }
    }

    /// Mutable variant of [`CouplingMat::for_each_blob`] (same order).
    pub fn for_each_blob_mut(&mut self, f: &mut dyn FnMut(&mut crate::compress::Blob)) {
        match self {
            CouplingMat::Plain(_) | CouplingMat::SepPlain { .. } => {}
            CouplingMat::Z(z) => f(&mut z.blob),
            CouplingMat::SepZ { sr, sc } => {
                f(&mut sr.blob);
                f(&mut sc.blob);
            }
        }
    }
}

/// Leaf data of a uniform H-matrix.
#[derive(Clone, Debug)]
pub enum UniBlock {
    Dense(DMatrix),
    ZDense(ZDense),
    Coupling(CouplingMat),
}

impl UniBlock {
    pub fn byte_size(&self) -> usize {
        match self {
            UniBlock::Dense(m) => m.byte_size(),
            UniBlock::ZDense(z) => z.byte_size(),
            UniBlock::Coupling(c) => c.byte_size(),
        }
    }

    /// Visit every compressed payload blob (storage-tier walkers).
    pub fn for_each_blob(&self, f: &mut dyn FnMut(&crate::compress::Blob)) {
        match self {
            UniBlock::Dense(_) => {}
            UniBlock::ZDense(z) => f(&z.blob),
            UniBlock::Coupling(c) => c.for_each_blob(f),
        }
    }

    /// Mutable variant of [`UniBlock::for_each_blob`] (same order).
    pub fn for_each_blob_mut(&mut self, f: &mut dyn FnMut(&mut crate::compress::Blob)) {
        match self {
            UniBlock::Dense(_) => {}
            UniBlock::ZDense(z) => f(&mut z.blob),
            UniBlock::Coupling(c) => c.for_each_blob_mut(f),
        }
    }
}

/// Memory statistics (split into the paper's categories).
#[derive(Clone, Copy, Debug, Default)]
pub struct UniformStats {
    pub dense_bytes: usize,
    pub coupling_bytes: usize,
    pub basis_bytes: usize,
}

impl UniformStats {
    pub fn total_bytes(&self) -> usize {
        self.dense_bytes + self.coupling_bytes + self.basis_bytes
    }
}

/// Uniform H-matrix: shared row/column cluster bases + per-block couplings.
#[derive(Clone)]
pub struct UniformHMatrix {
    pub bt: Arc<BlockTree>,
    /// Per row-cluster node id.
    pub row_basis: Vec<ClusterBasis>,
    /// Per column-cluster node id.
    pub col_basis: Vec<ClusterBasis>,
    /// Per block node id (leaves only).
    pub blocks: Vec<Option<UniBlock>>,
}

impl UniformHMatrix {
    pub fn nrows(&self) -> usize {
        self.bt.shape().0
    }

    pub fn ncols(&self) -> usize {
        self.bt.shape().1
    }

    /// Compress bases, couplings and dense blocks (§4.1/4.2).
    pub fn compress(&mut self, cfg: &CompressionConfig) {
        let pool = ThreadPool::global();
        pool.scope(|s| {
            for b in self.row_basis.iter_mut().chain(self.col_basis.iter_mut()) {
                s.spawn(move |_| b.compress(cfg));
            }
        });
        let blocks = std::mem::take(&mut self.blocks);
        let out: std::sync::Mutex<Vec<Option<UniBlock>>> = std::sync::Mutex::new(vec![None; blocks.len()]);
        pool.scope(|s| {
            for (id, b) in blocks.iter().enumerate() {
                let out = &out;
                s.spawn(move |_| {
                    let z = b.as_ref().map(|blk| match blk {
                        UniBlock::Dense(m) => UniBlock::ZDense(ZDense::compress(m, cfg.codec, cfg.eps)),
                        UniBlock::Coupling(c) => UniBlock::Coupling(c.compress(cfg)),
                        other => other.clone(),
                    });
                    out.lock().unwrap()[id] = z;
                });
            }
        });
        self.blocks = out.into_inner().unwrap();
    }

    pub fn stats(&self) -> UniformStats {
        let mut st = UniformStats::default();
        for b in self.row_basis.iter().chain(self.col_basis.iter()) {
            if b.rank() > 0 {
                st.basis_bytes += b.byte_size();
            }
        }
        for b in self.blocks.iter().flatten() {
            match b {
                UniBlock::Dense(_) | UniBlock::ZDense(_) => st.dense_bytes += b.byte_size(),
                UniBlock::Coupling(_) => st.coupling_bytes += b.byte_size(),
            }
        }
        st
    }

    pub fn byte_size(&self) -> usize {
        self.stats().total_bytes()
    }

    pub fn bytes_per_dof(&self) -> f64 {
        self.byte_size() as f64 / self.nrows() as f64
    }

    /// Dense reconstruction in internal ordering (tests only).
    pub fn to_dense(&self) -> DMatrix {
        let (m, n) = self.bt.shape();
        let mut out = DMatrix::zeros(m, n);
        for &leaf in &self.bt.leaves {
            let nd = self.bt.node(leaf);
            let rr = self.bt.row_ct.node(nd.row).range();
            let cr = self.bt.col_ct.node(nd.col).range();
            let d = match self.blocks[leaf].as_ref().expect("missing leaf") {
                UniBlock::Dense(mm) => mm.clone(),
                UniBlock::ZDense(z) => z.to_dense(),
                UniBlock::Coupling(c) => {
                    let w = self.row_basis[nd.row].to_dense();
                    let x = self.col_basis[nd.col].to_dense();
                    let s = c.to_dense();
                    let ws = blas::matmul(&w, blas::Trans::No, &s, blas::Trans::No);
                    blas::matmul(&ws, blas::Trans::No, &x, blas::Trans::Yes)
                }
            };
            for (jj, j) in cr.enumerate() {
                for (ii, i) in rr.clone().enumerate() {
                    out[(i, j)] = d[(ii, jj)];
                }
            }
        }
        out
    }
}
