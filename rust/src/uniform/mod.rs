//! Uniform H-matrices (paper §2.3): one shared cluster basis per block row /
//! block column; low-rank blocks store only a small coupling matrix
//! S with M_{τ,σ} = W_τ · S_{τ,σ} · X_σᵀ.

mod basis;
mod build;
mod uhmat;

pub use basis::{BasisData, ClusterBasis};
pub use build::build_from_h;
pub use uhmat::{CouplingKind, CouplingMat, UniBlock, UniformHMatrix, UniformStats};
