//! Row-wise operator partitioning for the sharded serving tier.
//!
//! [`row_partition`] splits one [`PlannedOperator`]'s output index space into
//! `N` disjoint, contiguous row ranges. The partition seam is the cluster
//! tree's leaf boundaries — the same boundaries the plan schedules already
//! use as pairwise-disjoint write ranges — so no task's output ever has to be
//! split across shards mid-cluster. Seam placement is load-aware in the
//! MatRox style: every schedule task's modeled cost (calibrated profile
//! included, see [`crate::plan::costmodel`]) is prorated onto the leaf
//! clusters it writes, and a greedy quota walk assigns consecutive leaves to
//! shards targeting `remaining / shards_left` work each.
//!
//! A [`ShardPlan`] owns one partition member end to end: slices of the
//! parent plan's schedules (every task whose output intersects the owned
//! rows, ancestors included — see the slice builders in
//! [`crate::plan::exec`]), its own [`Executor`], scratch arena, pooled
//! output buffer, and optionally its own decode-once hot cache. It computes
//! a **full-length** partial product seeded from the caller's `y` (or
//! zeros), then exports only the owned rows. Because each output row's
//! entire accumulation chain (every level, every contributing task, in the
//! parent schedule's level order) replays inside the shard that owns the
//! row, the exported rows are **bitwise identical** to the unsharded plan's
//! — for any seed, on any executor backend. Rows outside the owned range
//! are garbage by contract (their chains are incomplete) and are never
//! exported.
//!
//! The forward and adjoint products have different output spaces, so a
//! [`ShardSpec`] carries one owned range per direction: `rows` partitions
//! `0..nrows` along the row tree (forward), `cols` partitions `0..ncols`
//! along the column tree (adjoint).
//!
//! `HMATC_SHARDS=N` ([`env_shard_count`]) routes every
//! [`PlannedOperator`] product through this path in-process — the whole test
//! suite then doubles as a sharded-equivalence suite. The scatter/gather
//! coordinator ([`crate::coordinator::MvmServer::start_sharded`]) drives the
//! same [`ShardPlan`]s from per-shard worker threads.

use super::costmodel::{Sample, TimingSink};
use super::exec::{H2Slice, HSlice, UniSlice};
use super::executor::{Executor, ExecutorKind};
use super::operator::{HOperator, Inner, PlannedOperator};
use crate::cluster::ClusterTree;
use crate::la::DMatrix;
use crate::plan::arena::Arena;
use crate::store::HotCache;
use std::ops::Range;
use std::sync::{Arc, Mutex, OnceLock, RwLock};

/// Shard count requested via `HMATC_SHARDS` (cached after the first read;
/// unset or invalid values mean 1 — unsharded).
pub fn env_shard_count() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| match std::env::var("HMATC_SHARDS") {
        Err(_) => 1,
        Ok(v) => v.trim().parse::<usize>().ok().filter(|&n| n >= 1).unwrap_or_else(|| {
            eprintln!("hmatc: ignoring invalid HMATC_SHARDS={v:?} (want an integer >= 1)");
            1
        }),
    })
}

/// One member of a row partition: which contiguous output rows the shard
/// owns, per product direction, and its modeled share of the forward work.
#[derive(Clone, Debug)]
pub struct ShardSpec {
    /// Shard position in the fixed gather order.
    pub index: usize,
    /// Total shards in the partition.
    pub count: usize,
    /// Owned forward-output rows (internal ordering), a union of row-tree
    /// leaf ranges. May be empty when there are fewer leaves than shards.
    pub rows: Range<usize>,
    /// Owned adjoint-output rows (= owned columns), a union of column-tree
    /// leaf ranges.
    pub cols: Range<usize>,
    /// Modeled share of the forward output-pass work assigned to this shard.
    pub cost: f64,
}

/// Sorted leaf index ranges of a cluster tree: the partition seam candidates.
fn leaf_ranges(ct: &ClusterTree) -> Vec<Range<usize>> {
    let mut v: Vec<Range<usize>> = ct.leaves.iter().map(|&id| ct.node(id).range()).collect();
    v.sort_by_key(|r| r.start);
    v
}

/// Prorate each task's modeled cost onto the leaves its output overlaps,
/// proportionally to the overlap length. Leaves must be sorted by start.
fn prorated_leaf_loads(leaves: &[Range<usize>], loads: &[(Range<usize>, f64)]) -> Vec<f64> {
    let mut out = vec![0.0; leaves.len()];
    for (dst, c) in loads {
        if dst.is_empty() {
            continue;
        }
        let mut li = leaves.partition_point(|l| l.end <= dst.start);
        while li < leaves.len() && leaves[li].start < dst.end {
            let lo = dst.start.max(leaves[li].start);
            let hi = dst.end.min(leaves[li].end);
            out[li] += c * (hi - lo) as f64 / dst.len() as f64;
            li += 1;
        }
    }
    out
}

/// Greedy quota split of consecutive leaves into `count` contiguous ranges:
/// each shard takes leaves until it would exceed its quota, the last shard
/// takes the rest. With `weights: None` every shard targets
/// `remaining / shards_left` (the historical equal split, bit-exact); with
/// weights, shard `s` targets `remaining · w[s] / Σ w[s..]` so capacity-
/// heavy NUMA nodes absorb proportionally more rows. Shards past the leaf
/// supply get empty ranges pinned at `domain` so owned ranges stay pairwise
/// disjoint.
fn split_quota(leaves: &[Range<usize>], leaf_load: &[f64], count: usize, domain: usize, weights: Option<&[f64]>) -> Vec<(Range<usize>, f64)> {
    let mut remaining: f64 = leaf_load.iter().sum();
    let mut wleft: f64 = weights.map_or(0.0, |w| w.iter().sum());
    let mut parts = Vec::with_capacity(count);
    let mut li = 0usize;
    for s in 0..count {
        let ws = weights.map_or(0.0, |w| w[s]);
        if li >= leaves.len() {
            wleft -= ws;
            parts.push((domain..domain, 0.0));
            continue;
        }
        let target = match weights {
            Some(_) if wleft > 0.0 => remaining * (ws / wleft),
            _ => remaining / (count - s) as f64,
        };
        wleft -= ws;
        let start = leaves[li].start;
        let mut acc = 0.0;
        while li < leaves.len() {
            let taken_some = leaves[li].start > start;
            if s + 1 < count && taken_some && acc + leaf_load[li] > target {
                break;
            }
            acc += leaf_load[li];
            li += 1;
        }
        remaining -= acc;
        parts.push((start..leaves[li - 1].end, acc));
    }
    parts
}

/// Per-shard capacity weights when the machine exposes more than one NUMA
/// node with *unequal* memory sizes: shard `s` inherits the relative memory
/// capacity of its home node (`s % nodes`, matching [`ShardPlan`] home
/// assignment). Symmetric machines and single-node fallbacks return `None`
/// — the partition then takes the historical equal-split path bit-for-bit.
fn node_capacity_weights(count: usize) -> Option<Vec<f64>> {
    let topo = crate::par::Topology::get();
    let mems = topo.node_mem();
    if mems.len() < 2 || mems.iter().any(|&m| m == 0) || mems.windows(2).all(|w| w[0] == w[1]) {
        return None;
    }
    Some((0..count).map(|s| mems[s % mems.len()] as f64).collect())
}

/// Split the operator's output index space into `count` disjoint, contiguous
/// [`ShardSpec`]s along cluster-tree leaf boundaries, balancing the modeled
/// (calibrated, when a profile is active) per-task output work. Errors on a
/// zero shard count or an operator without partitionable leaves.
pub fn row_partition(op: &PlannedOperator, count: usize) -> Result<Vec<ShardSpec>, String> {
    if count == 0 {
        return Err("shard count must be at least 1".to_string());
    }
    let (row_ct, col_ct) = op.cluster_trees();
    let rl = leaf_ranges(&row_ct);
    let cl = leaf_ranges(&col_ct);
    if rl.is_empty() || cl.is_empty() {
        return Err("operator has no cluster-tree leaves to partition".to_string());
    }
    let weights = node_capacity_weights(count);
    let fwd = split_quota(&rl, &prorated_leaf_loads(&rl, &op.output_loads(false)), count, op.nrows(), weights.as_deref());
    let adj = split_quota(&cl, &prorated_leaf_loads(&cl, &op.output_loads(true)), count, op.ncols(), weights.as_deref());
    Ok((0..count)
        .map(|i| ShardSpec { index: i, count, rows: fwd[i].0.clone(), cols: adj[i].0.clone(), cost: fwd[i].1 })
        .collect())
}

/// Per-direction schedule slices for one shard, matching the operator format.
enum Slices {
    H { fwd: HSlice, adj: HSlice },
    Uniform { fwd: UniSlice, adj: UniSlice },
    H2 { fwd: H2Slice, adj: H2Slice },
}

/// One shard of a row-partitioned operator: schedule slices covering every
/// task whose output intersects the owned rows, plus the shard's own
/// executor, arena, pooled output buffer and (optional) hot cache. See the
/// module docs for the seeding/bitwise contract. All vectors are in the
/// plan's internal ordering — the external-ordering fold stays with the
/// unsharded front ([`PlannedOperator::with_external_ordering`]).
pub struct ShardPlan {
    inner: Arc<Inner>,
    spec: ShardSpec,
    exec: Arc<dyn Executor>,
    slices: Slices,
    arena: Mutex<Arena>,
    /// Shard-local decode-once cache. When `None`, applies fall back to the
    /// parent plan's (shared) cache so `HMATC_SHARDS` routing preserves
    /// [`PlannedOperator::set_hot_cache`] semantics transparently. Per-shard
    /// caches double as per-NUMA-node hot blob replicas: each shard decodes
    /// into memory its own worker thread first-touched on its home node.
    hot: RwLock<Option<Arc<HotCache>>>,
    /// NUMA node this shard's worker/arena/output memory should live on
    /// (round-robin over discovered nodes; `None` on single-node machines).
    home: Option<usize>,
    ybuf: Mutex<Vec<f64>>,
}

impl ShardPlan {
    /// Slice the operator's plan down to `spec`'s owned rows (both
    /// directions) and give the shard its own executor of the given kind.
    pub fn build(op: &PlannedOperator, spec: ShardSpec, kind: ExecutorKind) -> ShardPlan {
        let exec = kind.build();
        let n = exec.shard_count();
        let p = exec.pool_count();
        let inner = op.inner().clone();
        let slices = match &*inner {
            Inner::H { m, plan } => {
                Slices::H { fwd: plan.slice(m, false, &spec.rows, n, p), adj: plan.slice(m, true, &spec.cols, n, p) }
            }
            Inner::Uniform { m, plan } => {
                Slices::Uniform { fwd: plan.slice(m, false, &spec.rows, n, p), adj: plan.slice(m, true, &spec.cols, n, p) }
            }
            Inner::H2 { m, plan } => {
                Slices::H2 { fwd: plan.slice(m, false, &spec.rows, n, p), adj: plan.slice(m, true, &spec.cols, n, p) }
            }
        };
        let topo = crate::par::Topology::get();
        let nn = topo.num_nodes();
        let home = if nn > 1 { Some(topo.nodes()[spec.index % nn].id) } else { None };
        ShardPlan {
            inner,
            spec,
            exec,
            slices,
            arena: Mutex::new(Arena::new()),
            hot: RwLock::new(None),
            home,
            ybuf: Mutex::new(Vec::new()),
        }
    }

    /// The NUMA node this shard's memory and worker should live on, when the
    /// machine has more than one.
    pub fn home_node(&self) -> Option<usize> {
        self.home
    }

    /// The partition member this shard executes.
    pub fn spec(&self) -> &ShardSpec {
        &self.spec
    }

    /// Shard position in the fixed gather order.
    pub fn index(&self) -> usize {
        self.spec.index
    }

    /// Modeled share of the forward output work (seam placement input).
    pub fn cost(&self) -> f64 {
        self.spec.cost
    }

    /// Owned output rows of the given product direction.
    pub fn owned(&self, adjoint: bool) -> Range<usize> {
        if adjoint {
            self.spec.cols.clone()
        } else {
            self.spec.rows.clone()
        }
    }

    /// Name of this shard's own execution backend.
    pub fn executor_name(&self) -> String {
        self.exec.name()
    }

    /// Install (or clear) a shard-local decode-once hot cache. Cleared,
    /// applies fall back to the parent plan's cache.
    pub fn set_hot_cache(&self, cache: Option<Arc<HotCache>>) {
        *self.hot.write().unwrap_or_else(|p| p.into_inner()) = cache;
    }

    /// `(hits, misses)` of the shard-local cache; `None` when the shard runs
    /// on the parent plan's shared cache (counted there instead).
    pub fn cache_counters(&self) -> Option<(u64, u64)> {
        self.hot.read().unwrap_or_else(|p| p.into_inner()).as_ref().map(|c| c.counters())
    }

    fn dims(&self) -> (usize, usize) {
        match &*self.inner {
            Inner::H { m, .. } => (m.nrows(), m.ncols()),
            Inner::Uniform { m, .. } => (m.nrows(), m.ncols()),
            Inner::H2 { m, .. } => (m.nrows(), m.ncols()),
        }
    }

    fn active_hot(&self) -> Option<Arc<HotCache>> {
        let own = self.hot.read().unwrap_or_else(|p| p.into_inner()).clone();
        own.or_else(|| match &*self.inner {
            Inner::H { plan, .. } => plan.hot_cache(),
            Inner::Uniform { plan, .. } => plan.hot_cache(),
            Inner::H2 { plan, .. } => plan.hot_cache(),
        })
    }

    /// `out = (seed + alpha · op(x))[owned rows]`, bitwise identical to the
    /// rows the unsharded plan would produce from the same seed (zeros when
    /// `None`). `out.len()` must equal the owned range's length; `x` and the
    /// seed are full-length internal-ordering vectors.
    pub fn apply_owned(&self, adjoint: bool, alpha: f64, x: &[f64], seed: Option<&[f64]>, out: &mut [f64]) {
        let rows = self.owned(adjoint);
        let (nr, nc) = self.dims();
        let (ylen, xlen) = if adjoint { (nc, nr) } else { (nr, nc) };
        assert_eq!(x.len(), xlen, "input length mismatch");
        assert_eq!(out.len(), rows.len(), "owned output length mismatch");
        let hot = self.active_hot();
        let mut ybuf = self.ybuf.lock().unwrap_or_else(|p| p.into_inner());
        ybuf.clear();
        if let Some(s) = seed {
            assert_eq!(s.len(), ylen, "seed length mismatch");
            ybuf.extend_from_slice(s);
        }
        ybuf.resize(ylen, 0.0);
        {
            let mut arena = self.arena.lock().unwrap_or_else(|p| p.into_inner());
            match (&*self.inner, &self.slices) {
                (Inner::H { m, plan }, Slices::H { fwd, adj }) => {
                    let sl = if adjoint { adj } else { fwd };
                    plan.execute_slice(m, sl, alpha, x, &mut ybuf, &mut arena, self.exec.as_ref(), hot.as_ref());
                }
                (Inner::Uniform { m, plan }, Slices::Uniform { fwd, adj }) => {
                    let sl = if adjoint { adj } else { fwd };
                    plan.execute_slice(m, sl, alpha, x, &mut ybuf, &mut arena, self.exec.as_ref(), hot.as_ref());
                }
                (Inner::H2 { m, plan }, Slices::H2 { fwd, adj }) => {
                    let sl = if adjoint { adj } else { fwd };
                    plan.execute_slice(m, sl, alpha, x, &mut ybuf, &mut arena, self.exec.as_ref(), hot.as_ref());
                }
                _ => unreachable!("slice format matches the operator format by construction"),
            }
        }
        out.copy_from_slice(&ybuf[rows]);
    }

    /// Batched [`ShardPlan::apply_owned`]: `out` is `owned.len() × nrhs`,
    /// seeded from the full-height `seed` panel (zeros when `None`).
    pub fn apply_multi_owned(&self, adjoint: bool, alpha: f64, x: &DMatrix, seed: Option<&DMatrix>, out: &mut DMatrix) {
        self.apply_multi_owned_rec(adjoint, alpha, x, seed, out, None);
    }

    /// Forward [`ShardPlan::apply_multi_owned`] with per-chunk wall times
    /// recorded into `sink` (slots are parent-plan task ids; size it with
    /// [`ShardPlan::timing_slots`]). Times run WITH the active hot cache —
    /// the online window models what is resident under live traffic.
    pub fn apply_multi_owned_timed(&self, alpha: f64, x: &DMatrix, seed: Option<&DMatrix>, out: &mut DMatrix, sink: &TimingSink) {
        self.apply_multi_owned_rec(false, alpha, x, seed, out, Some(sink));
    }

    /// Per-task timing slots of the parent forward schedule (shared across
    /// all shards of one operator — slices index parent task ids).
    pub fn timing_slots(&self) -> usize {
        match &*self.inner {
            Inner::H { m, plan } => plan.timing_slots(m),
            Inner::Uniform { m, plan } => plan.timing_slots(m),
            Inner::H2 { m, plan } => plan.timing_slots(m),
        }
    }

    /// Fold a timed forward batch into `out` as fit samples (only the
    /// slice's retained tasks) and return the slice packing's (predicted,
    /// measured) makespan; predicted is 0.0 until a profile is active.
    pub fn observe_multi(&self, sink: &TimingSink, nrhs: usize, out: &mut Vec<Sample>) -> (f64, f64) {
        match (&*self.inner, &self.slices) {
            (Inner::H { m, plan }, Slices::H { fwd, .. }) => plan.observe_multi_slice(m, fwd, sink, nrhs, out),
            (Inner::Uniform { m, plan }, Slices::Uniform { fwd, .. }) => plan.observe_multi_slice(m, fwd, sink, nrhs, out),
            (Inner::H2 { m, plan }, Slices::H2 { fwd, .. }) => plan.observe_multi_slice(m, fwd, sink, nrhs, out),
            _ => unreachable!("slice format matches the operator format by construction"),
        }
    }

    fn apply_multi_owned_rec(&self, adjoint: bool, alpha: f64, x: &DMatrix, seed: Option<&DMatrix>, out: &mut DMatrix, rec: Option<&TimingSink>) {
        let rows = self.owned(adjoint);
        let (nr, nc) = self.dims();
        let (ylen, xlen) = if adjoint { (nc, nr) } else { (nr, nc) };
        let nrhs = x.ncols();
        assert_eq!(x.nrows(), xlen, "input height mismatch");
        assert_eq!(out.nrows(), rows.len(), "owned output height mismatch");
        assert_eq!(out.ncols(), nrhs, "output width mismatch");
        let hot = self.active_hot();
        let mut ybuf = self.ybuf.lock().unwrap_or_else(|p| p.into_inner());
        ybuf.clear();
        if let Some(s) = seed {
            assert_eq!(s.nrows(), ylen, "seed height mismatch");
            assert_eq!(s.ncols(), nrhs, "seed width mismatch");
            ybuf.extend_from_slice(s.data());
        }
        ybuf.resize(ylen * nrhs, 0.0);
        let mut ym = DMatrix::from_vec(ylen, nrhs, std::mem::take(&mut *ybuf));
        {
            let mut arena = self.arena.lock().unwrap_or_else(|p| p.into_inner());
            match (&*self.inner, &self.slices) {
                (Inner::H { m, plan }, Slices::H { fwd, adj }) => {
                    let sl = if adjoint { adj } else { fwd };
                    plan.execute_multi_slice(m, sl, alpha, x, &mut ym, &mut arena, self.exec.as_ref(), rec, hot.as_ref());
                }
                (Inner::Uniform { m, plan }, Slices::Uniform { fwd, adj }) => {
                    let sl = if adjoint { adj } else { fwd };
                    plan.execute_multi_slice(m, sl, alpha, x, &mut ym, &mut arena, self.exec.as_ref(), rec, hot.as_ref());
                }
                (Inner::H2 { m, plan }, Slices::H2 { fwd, adj }) => {
                    let sl = if adjoint { adj } else { fwd };
                    plan.execute_multi_slice(m, sl, alpha, x, &mut ym, &mut arena, self.exec.as_ref(), rec, hot.as_ref());
                }
                _ => unreachable!("slice format matches the operator format by construction"),
            }
        }
        let ydata = ym.into_vec();
        for c in 0..nrhs {
            out.col_mut(c).copy_from_slice(&ydata[c * ylen + rows.start..c * ylen + rows.end]);
        }
        *ybuf = ydata;
    }
}
