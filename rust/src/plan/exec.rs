//! Per-format execution plans: flattened level-ordered schedules plus the
//! zero-allocation executors for single-vector, adjoint and multi-RHS
//! products.
//!
//! Correctness argument (same as the collision-free traversals of §3, made
//! static): clusters of one tree level have pairwise disjoint index ranges,
//! so all tasks of a level may write `y` (or their coefficient slots)
//! concurrently without synchronization; consecutive levels are separated by
//! fork-join barriers, which realises the parent-before-children ordering the
//! recursive traversals obtain implicitly.
//!
//! **Multi-RHS** products run through *gemm-shaped* variants of the same
//! schedules: each task gathers its disjoint write range into a contiguous
//! `n×b` panel from the scratch arena, streams every block's matrix data —
//! compressed CouplingMat/TransferMat included — exactly once, and applies it
//! to all `b` columns (panel kernels in [`crate::mvm::kernels`]). Task costs
//! are rescaled by `b` for LPT balancing (matrix bytes amortize across the
//! batch, vector traffic scales with it); the per-width shard packings are
//! cached, so steady-state batched execution allocates nothing.
//!
//! **Execution backends**: *how* a level's shards are mapped onto threads is
//! delegated to the plan's [`Executor`] (static LPT shards, work stealing, or
//! K sharded sub-pools — see [`super::executor`]). The schedules are built
//! *for* their executor: the shard/chunk count comes from
//! [`Executor::shard_count`], so the packing each backend executes is
//! precomputed and steady-state products allocate nothing on any backend.

use super::arena::Arena;
use super::costmodel::{self, basis_data_feats, basis_feats, block_feats, transfer_feats, uni_block_feats, CostProfile, CostSource, Sample, TaskFeats, TimingSink};
use super::executor::{Executor, ExecutorKind, TaskFn};
use super::schedule::{balance, balance_level, block_cost_split, uni_block_cost_split, Shard};
use crate::h2::H2Matrix;
use crate::hmatrix::HMatrix;
use crate::la::{blas, DMatrix};
use crate::mvm::{kernels, SharedVec};
use crate::store::prefetch::{PrefetchBuilder, PrefetchPlan};
use crate::store::{hot, HotCache};
use crate::uniform::{UniBlock, UniformHMatrix};
use crate::util::{Rng, Timer};
use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

/// Summary of a built plan (diagnostics / logging).
#[derive(Clone, Debug, Default)]
pub struct PlanStats {
    /// Flattened tasks over all schedules (forward + adjoint).
    pub tasks: usize,
    /// Barrier-separated levels of the forward schedule.
    pub levels: usize,
    /// Maximum concurrently running shards.
    pub max_shards: usize,
    /// Per-shard kernel scratch (f64 values, single-RHS packing).
    pub scratch_f64: usize,
    /// Coefficient slots (f64 values, forward + backward, single-RHS).
    pub coeff_f64: usize,
    /// Codec-kernel selection the compressed applies run on, e.g.
    /// `"fused+avx2"` ([`crate::compress::dispatch::kernels_label`]).
    pub decode_kernels: &'static str,
    /// Where the active LPT costs came from: the static byte model, a
    /// profile file (`HMATC_COSTS` / `--costs`), or an in-process
    /// calibration.
    pub cost_source: CostSource,
    /// Modeled makespan (seconds) of the re-balanced forward packing under
    /// the calibrated coefficients; 0.0 while the static costs are active.
    pub predicted_makespan: f64,
    /// Measured makespan (seconds) of the forward schedule recorded by the
    /// last in-process calibration (the packing that was live during the
    /// timed rounds); 0.0 if never calibrated in process.
    pub measured_makespan: f64,
    /// Per-executor-sub-pool coefficient source of the active profile
    /// (`"per-pool"` where a NUMA overlay fit is applied, `"global"` where
    /// the pooled fit fills in); empty on single-pool backends or while no
    /// profile is active.
    pub pool_cost_sources: Vec<&'static str>,
}

/// Atomically swappable shard packing: a re-balance publishes a new
/// task→shard partition while in-flight products keep executing the `Arc`
/// they loaded at entry (the task list itself never changes, so either
/// packing computes bitwise-identical results).
struct Packing<T> {
    inner: RwLock<Arc<T>>,
}

impl<T> Packing<T> {
    fn new(v: T) -> Packing<T> {
        Packing { inner: RwLock::new(Arc::new(v)) }
    }

    fn load(&self) -> Arc<T> {
        self.inner.read().unwrap().clone()
    }

    fn store(&self, v: T) {
        *self.inner.write().unwrap() = Arc::new(v);
    }
}

/// Calibration state a plan reports through [`PlanStats`].
#[derive(Clone, Debug, Default)]
struct CalibInfo {
    source: CostSource,
    predicted: f64,
    measured: f64,
}

/// Per-task model costs at batch width `nrhs`: the static split model
/// (`fixed + nrhs · per_rhs` bytes), or the calibrated profile when one is
/// active.
///
/// A usable profile can still model *this* schedule's tasks degenerately —
/// e.g. a profile fitted on compressed data whose only nonzero coefficients
/// are decode classes, applied to an uncompressed matrix: every task costs
/// 0, and LPT over all-zero costs collapses a level into one shard. Such
/// cost vectors (any non-finite/negative entry, or no positive entry) fall
/// back to the static model, which is positive by construction.
fn model_costs(feats: &[TaskFeats], fixed: &[f64], per_rhs: &[f64], profile: Option<&CostProfile>, nrhs: usize) -> Vec<f64> {
    if let Some(p) = profile {
        let costs: Vec<f64> = feats.iter().map(|ft| p.cost(ft, nrhs)).collect();
        if costmodel::usable_costs(&costs) {
            return costs;
        }
    }
    fixed.iter().zip(per_rhs).map(|(f, v)| f + nrhs as f64 * v).collect()
}

/// Per-task packing costs: one global vector, or one vector per executor
/// sub-pool when the backend has several pools (`sharded:K` on a multi-node
/// machine) AND the active profile carries usable per-pool coefficients.
/// Pool-aware packing prices each bin under the coefficients of the sub-pool
/// that will run it ([`costmodel::pool_of_shard`]), so a slower socket is
/// handed proportionally fewer bytes. Either variant only changes the
/// task→shard partition, never task bodies, so outputs stay bitwise
/// identical.
enum LevelCosts {
    Global(Vec<f64>),
    PerPool(Vec<Vec<f64>>),
}

impl LevelCosts {
    fn compute(feats: &[TaskFeats], fixed: &[f64], per_rhs: &[f64], profile: Option<&CostProfile>, nrhs: usize, npools: usize) -> LevelCosts {
        if let Some(p) = profile {
            if npools > 1 && p.has_pool_coeffs() {
                let per: Vec<Vec<f64>> = (0..npools).map(|pool| feats.iter().map(|ft| p.pool_cost(pool, ft, nrhs)).collect()).collect();
                if per.iter().all(|c| costmodel::usable_costs(c)) {
                    return LevelCosts::PerPool(per);
                }
            }
        }
        LevelCosts::Global(model_costs(feats, fixed, per_rhs, profile, nrhs))
    }

    /// LPT-pack one level (`scratch` indexed by global task id, like
    /// [`balance_level`]).
    fn balance_level(&self, ids: &[usize], scratch: &[usize], nshards: usize) -> Vec<Shard> {
        match self {
            LevelCosts::Global(c) => balance_level(ids, c, scratch, nshards),
            LevelCosts::PerPool(pp) => costmodel::balance_level_pools(ids, pp, scratch, nshards),
        }
    }

    /// Pack every level for batch width `nrhs` (shard scratch = per-RHS
    /// panel scratch · nrhs, as in [`balance_levels_for`]).
    fn balance_levels_for(&self, level_ids: &[Vec<usize>], pscratch: &[usize], nrhs: usize, nshards: usize) -> Vec<Vec<Shard>> {
        let scratch: Vec<usize> = pscratch.iter().map(|s| s * nrhs).collect();
        level_ids.iter().map(|ids| self.balance_level(ids, &scratch, nshards)).collect()
    }

    /// Never-worse re-partition of `old` (see [`costmodel::rebalance_levels`]
    /// / [`costmodel::rebalance_levels_pools`]).
    fn rebalance(&self, old: &[Vec<Shard>], level_ids: &[Vec<usize>], scratch: &[usize], nshards: usize) -> Vec<Vec<Shard>> {
        match self {
            LevelCosts::Global(c) => costmodel::rebalance_levels(old, level_ids, c, scratch, nshards),
            LevelCosts::PerPool(pp) => costmodel::rebalance_levels_pools(old, level_ids, pp, scratch, nshards),
        }
    }

    /// Modeled makespan of a level-ordered packing under these costs.
    fn makespan(&self, levels: &[Vec<Shard>]) -> f64 {
        match self {
            LevelCosts::Global(c) => costmodel::makespan(levels, c),
            LevelCosts::PerPool(pp) => costmodel::makespan_pools(levels, pp),
        }
    }
}

/// Overlay `map[task] = pool` for every task of `levels`: shard position
/// within its level maps onto the executor's sub-pools exactly the way the
/// `sharded:K` runtime assigns shards ([`costmodel::pool_of_shard`]). Used
/// to tag timing samples with the pool that ran them.
fn fill_pool_tags(levels: &[Vec<Shard>], npools: usize, map: &mut [usize]) {
    for level in levels {
        for (si, sh) in level.iter().enumerate() {
            let p = costmodel::pool_of_shard(si, level.len(), npools);
            for &t in &sh.tasks {
                if let Some(slot) = map.get_mut(t) {
                    *slot = p;
                }
            }
        }
    }
}

/// Run one level, optionally timing each chunk into `rec = (sink, slot
/// base)`. The wrapper times at the chunk boundary inside whatever executor
/// slot runs it — identical instrumentation for all three backends (`lpt`,
/// `steal`, `sharded:K`) — and the sink slots are preallocated, so timed
/// steady-state execution allocates nothing. Accumulators are read back only
/// after the level barrier has joined.
///
/// When `hot` carries a decode-once cache it is installed as the calling
/// thread's cache ([`hot::scope`]) around each chunk — the install must
/// happen *inside* the executor callback because the chunk may run on a pool
/// worker thread, not the thread that entered `exec`. The cache only changes
/// which load path decodes a blob, never the decoded values (see
/// [`crate::compress::dispatch`]), so timed chunks stay comparable and
/// outputs stay bitwise identical.
fn run_level_rec(exec: &dyn Executor, level: &[Shard], bufs: &mut [Vec<f64>], rec: Option<(&TimingSink, usize)>, hot: Option<&Arc<HotCache>>, run: &TaskFn) {
    match (rec, hot) {
        (None, None) => exec.run_level(level, bufs, run),
        (Some((sink, base)), None) => exec.run_level(level, bufs, &|ti, buf| {
            let t = Timer::start();
            run(ti, buf);
            sink.add(base + ti, t.elapsed());
        }),
        (None, Some(c)) => exec.run_level(level, bufs, &|ti, buf| hot::scope(c, || run(ti, buf))),
        (Some((sink, base)), Some(c)) => exec.run_level(level, bufs, &|ti, buf| {
            let t = Timer::start();
            hot::scope(c, || run(ti, buf));
            sink.add(base + ti, t.elapsed());
        }),
    }
}

/// Batch width of the multi-RHS calibration rounds: mixing b = 1 and
/// b = [`CALIB_RHS`] samples lets the least-squares fit separate the
/// per-batch matrix terms from the per-RHS flop/vector terms.
pub const CALIB_RHS: usize = 4;

fn max_shard_stats(levels: &[Vec<Shard>]) -> (usize, usize) {
    let mut max_shards = 0;
    let mut scratch = 0;
    for level in levels {
        max_shards = max_shards.max(level.len());
        for s in level {
            scratch = scratch.max(s.scratch);
        }
    }
    (max_shards, scratch)
}

/// Shard packings per batch width, built on first use: LPT is re-run with
/// per-task costs rescaled by the number of right-hand sides `b` (matrix
/// bytes amortize across the batch, vector traffic and panel scratch scale
/// with it). A serving deployment sees a handful of distinct widths, so the
/// cache stays tiny; it is capped to keep pathological clients bounded.
///
/// Entries are tagged with the cost-model **generation** they were packed
/// for (a schedule bumps its generation on every re-balance): a caller
/// passing a newer generation drops every older entry, so a packing built
/// from pre-re-balance costs that races the swap can be *served* at most to
/// callers that also started before the swap — it can never be pinned past
/// the first post-swap product of its width.
struct MultiCache<T> {
    cache: Mutex<(u64, Vec<(usize, Arc<T>)>)>,
}

impl<T> MultiCache<T> {
    fn new() -> MultiCache<T> {
        MultiCache { cache: Mutex::new((0, Vec::new())) }
    }

    fn get(&self, gen: u64, nrhs: usize, build: impl FnOnce() -> T) -> Arc<T> {
        let mut g = self.cache.lock().unwrap();
        if gen > g.0 {
            // first caller after a re-balance: drop every older packing
            g.0 = gen;
            g.1.clear();
        }
        if gen == g.0 {
            if let Some((_, l)) = g.1.iter().find(|(b, _)| *b == nrhs) {
                return l.clone();
            }
        }
        let l = Arc::new(build());
        // a caller that raced a re-balance (gen < g.0) keeps its packing
        // private — never cache a packing under a generation it wasn't
        // built for
        if gen == g.0 && g.1.len() < 32 {
            g.1.push((nrhs, l.clone()));
        }
        l
    }
}

/// Gather rows `rows` of every column of `x` into the contiguous column-major
/// panel `xp` (rows.len() × x.ncols()).
fn gather_panel(x: &DMatrix, rows: &Range<usize>, xp: &mut [f64]) {
    let l = rows.len();
    for c in 0..x.ncols() {
        xp[c * l..(c + 1) * l].copy_from_slice(&x.col(c)[rows.clone()]);
    }
}

/// True iff the half-open ranges overlap.
fn ranges_intersect(a: &Range<usize>, b: &Range<usize>) -> bool {
    a.start < b.end && b.start < a.end
}

/// Filter each level's task ids by a predicate, PRESERVING the level count
/// (empty levels stay): a slice must keep the parent schedule's barrier
/// structure so prefetch group indices line up with the shared
/// [`PrefetchPlan`].
fn filter_level_ids(level_ids: &[Vec<usize>], keep: impl Fn(usize) -> bool) -> Vec<Vec<usize>> {
    level_ids.iter().map(|ids| ids.iter().copied().filter(|&id| keep(id)).collect()).collect()
}

// ---------------------------------------------------------------------------
// H-matrix plan
// ---------------------------------------------------------------------------

/// One block row (forward) or block column (adjoint): the full list of leaf
/// blocks writing into one cluster's disjoint range.
struct HTask {
    /// Write range in `y`.
    dst: Range<usize>,
    /// (block id, read range in `x`) per leaf block.
    blocks: Vec<(usize, Range<usize>)>,
}

struct HSchedule {
    tasks: Vec<HTask>,
    /// Task ids of each (non-empty) cluster-tree level, root level first.
    level_ids: Vec<Vec<usize>>,
    /// Split cost model per task: matrix bytes / vector bytes per RHS.
    fixed: Vec<f64>,
    per_rhs: Vec<f64>,
    /// Per-task kernel-class features (calibrated cost model inputs).
    feats: Vec<TaskFeats>,
    /// Per-task single-RHS kernel scratch (for re-balancing).
    scratch1: Vec<usize>,
    /// Per-RHS panel scratch per task (y panel + x stripe + kernel scratch).
    pscratch: Vec<usize>,
    /// Execution order for single-vector products: root level first.
    /// Swappable: `rebalance` publishes a re-partition of the same tasks.
    levels: Packing<Vec<Vec<Shard>>>,
    /// Per-batch-width panel shard packings.
    multi: MultiCache<Vec<Vec<Shard>>>,
    /// Active calibrated profile (None = static byte costs).
    profile: RwLock<Option<Arc<CostProfile>>>,
    /// Cost-model generation, bumped by every re-balance **after** the
    /// profile is published (tags [`MultiCache`] entries).
    profile_gen: AtomicU64,
    /// Shard/chunk bin count the packings were built for (from the
    /// executor; reused for the cached per-width packings).
    nshards: usize,
    /// Executor sub-pool count ([`Executor::pool_count`]); > 1 only for
    /// `sharded:K`, where it enables pool-aware packing and sample tagging.
    npools: usize,
    /// High-water shard count over every packing published so far (arena
    /// buffer sizing only grows).
    max_shards: AtomicUsize,
    scratch: usize,
    /// Mapped extents read by each barrier level (empty for in-memory
    /// operators): level `i+1` is queued on the prefetch thread while level
    /// `i` executes.
    prefetch: PrefetchPlan,
}

impl HSchedule {
    fn build(m: &HMatrix, adjoint: bool, exec: &dyn Executor) -> HSchedule {
        let bt = &m.bt;
        let (ct, other_ct, lists) = if adjoint {
            (&bt.col_ct, &bt.row_ct, &bt.col_blocks)
        } else {
            (&bt.row_ct, &bt.col_ct, &bt.row_blocks)
        };
        let mut tasks = Vec::new();
        let mut fixed = Vec::new();
        let mut per_rhs = Vec::new();
        let mut feats = Vec::new();
        let mut scratch1 = Vec::new();
        let mut pscratch = Vec::new();
        let mut level_ids: Vec<Vec<usize>> = vec![Vec::new(); ct.levels.len()];
        for (tau, blocks) in lists.iter().enumerate() {
            if blocks.is_empty() {
                continue;
            }
            let mut refs = Vec::with_capacity(blocks.len());
            let mut fx = 0.0;
            let mut vr = 0.0;
            let mut tf = TaskFeats::default();
            let mut scr = 0usize;
            let mut pan = 0usize;
            for &b in blocks {
                let nd = bt.node(b);
                let src = if adjoint { other_ct.node(nd.row).range() } else { other_ct.node(nd.col).range() };
                let blk = m.blocks[b].as_ref().unwrap_or_else(|| {
                    panic!("H plan build: missing leaf data for block {b} (row cluster {}, col cluster {})", nd.row, nd.col)
                });
                let (f, v) = block_cost_split(blk);
                fx += f;
                vr += v;
                tf.merge(&block_feats(blk));
                scr = scr.max(blk.rank());
                pan = pan.max(src.len() + kernels::block_panel_scratch(blk));
                refs.push((b, src));
            }
            let dst = ct.node(tau).range();
            pan += dst.len();
            let id = tasks.len();
            tasks.push(HTask { dst, blocks: refs });
            fixed.push(fx);
            per_rhs.push(vr);
            feats.push(tf);
            scratch1.push(scr);
            pscratch.push(pan);
            level_ids[ct.node(tau).level].push(id);
        }
        let level_ids: Vec<Vec<usize>> = level_ids.into_iter().filter(|ids| !ids.is_empty()).collect();
        let mut pb = PrefetchBuilder::default();
        for (li, ids) in level_ids.iter().enumerate() {
            for &id in ids {
                for (b, _) in &tasks[id].blocks {
                    m.blocks[*b].as_ref().expect("missing leaf").for_each_blob(&mut |blob| pb.add(li, blob));
                }
            }
        }
        let nshards = exec.shard_count();
        let costs: Vec<f64> = fixed.iter().zip(&per_rhs).map(|(f, v)| f + v).collect();
        let levels: Vec<Vec<Shard>> =
            level_ids.iter().map(|ids| balance_level(ids, &costs, &scratch1, nshards)).collect();
        let (max_shards, scratch) = max_shard_stats(&levels);
        HSchedule {
            tasks,
            level_ids,
            fixed,
            per_rhs,
            feats,
            scratch1,
            pscratch,
            levels: Packing::new(levels),
            multi: MultiCache::new(),
            profile: RwLock::new(None),
            profile_gen: AtomicU64::new(0),
            nshards,
            npools: exec.pool_count(),
            max_shards: AtomicUsize::new(max_shards),
            scratch,
            prefetch: pb.finish(),
        }
    }

    /// Re-partition every level with profile-modeled costs (never increasing
    /// the modeled makespan — see [`costmodel::rebalance_levels`]) and bump
    /// the cost-model generation so per-width packings re-pack with the new
    /// costs. Returns the modeled makespan (seconds) of the active packing
    /// at b = 1.
    fn rebalance(&self, profile: &Arc<CostProfile>) -> f64 {
        let costs = LevelCosts::compute(&self.feats, &self.fixed, &self.per_rhs, Some(profile.as_ref()), 1, self.npools);
        let old = self.levels.load();
        let new = costs.rebalance(&old, &self.level_ids, &self.scratch1, self.nshards);
        let ms = costs.makespan(&new);
        let (mx, _) = max_shard_stats(&new);
        self.max_shards.fetch_max(mx, Ordering::Relaxed);
        self.levels.store(new);
        *self.profile.write().unwrap() = Some(profile.clone());
        self.profile_gen.fetch_add(1, Ordering::Release);
        ms
    }

    /// The cached width-`nrhs` panel packing (built on first use under the
    /// current cost-model generation) — the single source of the per-width
    /// packing for execution, observation and pool tagging.
    fn multi_packing(&self, nrhs: usize) -> Arc<Vec<Vec<Shard>>> {
        let gen = self.profile_gen.load(Ordering::Acquire);
        let prof = self.profile.read().unwrap().clone();
        self.multi.get(gen, nrhs, || {
            LevelCosts::compute(&self.feats, &self.fixed, &self.per_rhs, prof.as_deref(), nrhs, self.npools)
                .balance_levels_for(&self.level_ids, &self.pscratch, nrhs, self.nshards)
        })
    }

    /// Turn accumulated per-task times into fit samples (secs averaged over
    /// `rounds` timed products at batch width `nrhs`), each tagged with the
    /// executor sub-pool that ran it — `multi` selects the packing the timed
    /// run actually used (the swappable single-RHS packing, or the cached
    /// width-`nrhs` panel packing).
    fn push_samples(&self, sink: &TimingSink, nrhs: usize, rounds: usize, multi: bool, out: &mut Vec<Sample>) {
        let inv = 1.0 / rounds.max(1) as f64;
        let mut tags = vec![0usize; self.tasks.len()];
        if self.npools > 1 {
            if multi {
                fill_pool_tags(&self.multi_packing(nrhs), self.npools, &mut tags);
            } else {
                fill_pool_tags(&self.levels.load(), self.npools, &mut tags);
            }
        }
        for (ti, ft) in self.feats.iter().enumerate() {
            out.push(Sample { feats: ft.clone(), nrhs, pool: tags[ti], secs: sink.secs(ti) * inv });
        }
    }

    /// (predicted, measured) makespan in seconds of the width-`nrhs` packing
    /// a just-timed batch ran on. The packing is re-fetched from the
    /// per-width cache, so a rebalance racing between execution and
    /// observation can skew one observation — never outputs; the online
    /// calibrator's hysteresis absorbs it. `predicted` is 0.0 until a
    /// profile is active (static costs are byte units, not seconds).
    fn observe_multi(&self, sink: &TimingSink, nrhs: usize) -> (f64, f64) {
        let levels = self.multi_packing(nrhs);
        let prof = self.profile.read().unwrap().clone();
        let predicted = match prof.as_deref() {
            Some(p) => LevelCosts::compute(&self.feats, &self.fixed, &self.per_rhs, Some(p), nrhs, self.npools).makespan(&levels),
            None => 0.0,
        };
        (predicted, costmodel::sink_makespan(&levels, 0, sink))
    }

    /// Summed (fixed, per-RHS) seconds of a batch under the active profile,
    /// prorated by executor width: modeled batch cost ≈ fixed + b·per_rhs.
    /// `None` until a profile is active.
    fn panel_terms(&self) -> Option<(f64, f64)> {
        let prof = self.profile.read().unwrap().clone()?;
        let c1: f64 = model_costs(&self.feats, &self.fixed, &self.per_rhs, Some(prof.as_ref()), 1).iter().sum();
        let c2: f64 = model_costs(&self.feats, &self.fixed, &self.per_rhs, Some(prof.as_ref()), 2).iter().sum();
        let per = (c2 - c1).max(0.0);
        let w = self.nshards.max(1) as f64;
        Some((((c1 - per).max(0.0)) / w, per / w))
    }

    #[allow(clippy::too_many_arguments)]
    fn exec(&self, m: &HMatrix, adjoint: bool, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let levels = self.levels.load();
        self.exec_on(&levels, self.max_shards.load(Ordering::Relaxed), self.scratch, m, adjoint, alpha, x, y, arena, exec, rec, hot);
    }

    /// Run an explicit level packing — the schedule's own, or a
    /// row-restricted [`HSlice`] of it. Task bodies are identical either way,
    /// so any packing of the same task set computes bitwise-identical rows.
    #[allow(clippy::too_many_arguments)]
    fn exec_on(&self, levels: &[Vec<Shard>], max_shards: usize, scratch: usize, m: &HMatrix, adjoint: bool, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        arena.ensure(exec.buffers_needed(max_shards), scratch, 0, 0);
        let (bufs, _, _) = arena.split();
        let yy = SharedVec::new(y);
        self.prefetch.issue(0);
        for (li, level) in levels.iter().enumerate() {
            self.prefetch.issue(li + 1);
            run_level_rec(exec, level, bufs, rec.map(|s| (s, 0)), hot, &|ti, buf| {
                let task = &self.tasks[ti];
                // SAFETY: same-level clusters are disjoint; levels are
                // separated by join barriers (parents first).
                let yt = unsafe { yy.range_mut(task.dst.clone()) };
                for (b, src) in &task.blocks {
                    let blk = m.blocks[*b].as_ref().expect("missing leaf");
                    if adjoint {
                        kernels::apply_block_transposed_scratch(alpha, blk, &x[src.clone()], yt, buf);
                    } else {
                        kernels::apply_block_scratch(alpha, blk, &x[src.clone()], yt, buf);
                    }
                }
            });
        }
    }

    /// Gemm-shaped batched execution: every task gathers its disjoint y rows
    /// into a contiguous `rows×b` panel, each block's (possibly compressed)
    /// data is streamed once and applied to all `b` columns.
    #[allow(clippy::too_many_arguments)]
    fn exec_multi(&self, m: &HMatrix, adjoint: bool, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let levels = self.multi_packing(y.ncols());
        self.exec_multi_on(&levels, m, adjoint, alpha, x, y, arena, exec, rec, hot);
    }

    /// Batched execution of an explicit level packing (see [`Self::exec_on`]).
    #[allow(clippy::too_many_arguments)]
    fn exec_multi_on(&self, levels: &[Vec<Shard>], m: &HMatrix, adjoint: bool, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let ylen = y.nrows();
        let nrhs = y.ncols();
        let (max_shards, scratch) = max_shard_stats(levels);
        arena.ensure(exec.buffers_needed(max_shards), scratch, 0, 0);
        let (bufs, _, _) = arena.split();
        let yy = SharedVec::new(y.data_mut());
        self.prefetch.issue(0);
        for (li, level) in levels.iter().enumerate() {
            self.prefetch.issue(li + 1);
            run_level_rec(exec, level, bufs, rec.map(|s| (s, 0)), hot, &|ti, buf| {
                let task = &self.tasks[ti];
                let dl = task.dst.len();
                let (yp, rest) = buf.split_at_mut(dl * nrhs);
                // gather the task's disjoint y rows into a panel
                for c in 0..nrhs {
                    // SAFETY: same-level clusters are disjoint; levels are
                    // barrier separated (per column).
                    let src = unsafe { yy.range(c * ylen + task.dst.start..c * ylen + task.dst.end) };
                    yp[c * dl..(c + 1) * dl].copy_from_slice(src);
                }
                for (b, src) in &task.blocks {
                    let blk = m.blocks[*b].as_ref().expect("missing leaf");
                    let sl = src.len();
                    let (xp, kscratch) = rest.split_at_mut(sl * nrhs);
                    gather_panel(x, src, xp);
                    if adjoint {
                        kernels::apply_block_panel_transposed(alpha, blk, xp, yp, nrhs, kscratch);
                    } else {
                        kernels::apply_block_panel(alpha, blk, xp, yp, nrhs, kscratch);
                    }
                }
                for c in 0..nrhs {
                    // SAFETY: as above.
                    let dst = unsafe { yy.range_mut(c * ylen + task.dst.start..c * ylen + task.dst.end) };
                    dst.copy_from_slice(&yp[c * dl..(c + 1) * dl]);
                }
            });
        }
    }
}

/// Row-restricted view of one H-schedule half: the task ids whose write
/// ranges intersect one shard's owned rows, re-packed for the shard's own
/// executor. The slice holds NO task data — it indexes into the parent
/// schedule — and its level count matches the parent's, so the shared
/// prefetch plan and barrier structure are unchanged. A shard executes every
/// retained task in full (ancestor tasks redundantly, into a full-length
/// local y), which is what makes the harvested owned rows bitwise equal to
/// the unsharded product: each row's accumulation chain is replayed
/// identically, never re-associated.
pub(crate) struct HSlice {
    adjoint: bool,
    level_ids: Vec<Vec<usize>>,
    levels: Packing<Vec<Vec<Shard>>>,
    multi: MultiCache<Vec<Vec<Shard>>>,
    nshards: usize,
    /// Sub-pool count of the SHARD's executor (not the parent plan's).
    npools: usize,
}

impl HSchedule {
    fn slice(&self, adjoint: bool, rows: &Range<usize>, nshards: usize, npools: usize) -> HSlice {
        let level_ids = filter_level_ids(&self.level_ids, |id| ranges_intersect(&self.tasks[id].dst, rows));
        let prof = self.profile.read().unwrap().clone();
        let costs = LevelCosts::compute(&self.feats, &self.fixed, &self.per_rhs, prof.as_deref(), 1, npools);
        let levels: Vec<Vec<Shard>> = level_ids.iter().map(|ids| costs.balance_level(ids, &self.scratch1, nshards)).collect();
        HSlice { adjoint, level_ids, levels: Packing::new(levels), multi: MultiCache::new(), nshards, npools }
    }

    /// The slice's cached width-`nrhs` packing, keyed by the PARENT's cost
    /// generation (a rebalance invalidates the slice's cached per-width
    /// packings exactly like the parent's own).
    fn slice_multi_packing(&self, sl: &HSlice, nrhs: usize) -> Arc<Vec<Vec<Shard>>> {
        let gen = self.profile_gen.load(Ordering::Acquire);
        let prof = self.profile.read().unwrap().clone();
        sl.multi.get(gen, nrhs, || {
            LevelCosts::compute(&self.feats, &self.fixed, &self.per_rhs, prof.as_deref(), nrhs, sl.npools)
                .balance_levels_for(&sl.level_ids, &self.pscratch, nrhs, sl.nshards)
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_slice(&self, sl: &HSlice, m: &HMatrix, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena, exec: &dyn Executor, hot: Option<&Arc<HotCache>>) {
        let levels = sl.levels.load();
        let (mx, scr) = max_shard_stats(&levels);
        self.exec_on(&levels, mx, scr, m, sl.adjoint, alpha, x, y, arena, exec, None, hot);
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_multi_slice(&self, sl: &HSlice, m: &HMatrix, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let levels = self.slice_multi_packing(sl, y.ncols());
        self.exec_multi_on(&levels, m, sl.adjoint, alpha, x, y, arena, exec, rec, hot);
    }

    /// Slice-restricted sample harvest: sink slots are parent task ids, so
    /// only the slice's retained tasks carry times. Samples are tagged with
    /// the sub-pool of the SHARD's executor that ran them (slices only time
    /// batched products, so the width-`nrhs` packing is the one that ran).
    fn push_samples_slice(&self, sl: &HSlice, sink: &TimingSink, nrhs: usize, out: &mut Vec<Sample>) {
        let mut tags = vec![0usize; self.tasks.len()];
        if sl.npools > 1 {
            fill_pool_tags(&self.slice_multi_packing(sl, nrhs), sl.npools, &mut tags);
        }
        for ids in &sl.level_ids {
            for &ti in ids {
                out.push(Sample { feats: self.feats[ti].clone(), nrhs, pool: tags[ti], secs: sink.secs(ti) });
            }
        }
    }

    /// [`Self::observe_multi`] on a slice's own width-`nrhs` packing.
    fn observe_multi_slice(&self, sl: &HSlice, sink: &TimingSink, nrhs: usize) -> (f64, f64) {
        let levels = self.slice_multi_packing(sl, nrhs);
        let prof = self.profile.read().unwrap().clone();
        let predicted = match prof.as_deref() {
            Some(p) => LevelCosts::compute(&self.feats, &self.fixed, &self.per_rhs, Some(p), nrhs, sl.npools).makespan(&levels),
            None => 0.0,
        };
        (predicted, costmodel::sink_makespan(&levels, 0, sink))
    }
}

/// Precomputed execution plan for an [`HMatrix`]. The forward and adjoint
/// schedules are independent halves, built on first use — [`HPlan::build`]
/// pre-builds the forward half (the serving hot path), [`HPlan::lazy`]
/// builds nothing until executed (the one-shot dispatch paths).
///
/// The plan owns its [`Executor`]; schedules are packed for that backend at
/// build time ([`HPlan::build_with`] / [`HPlan::lazy_with`] select one, the
/// plain constructors take [`ExecutorKind::from_env`]).
pub struct HPlan {
    exec: Arc<dyn Executor>,
    fwd: OnceLock<HSchedule>,
    adj: OnceLock<HSchedule>,
    /// Active calibrated profile, also applied to halves built later.
    profile: Mutex<Option<Arc<CostProfile>>>,
    calib: Mutex<CalibInfo>,
    /// Decode-once hot-panel cache installed around every product
    /// (`HMATC_CACHE_BYTES` by default, swappable at runtime).
    hot: RwLock<Option<Arc<HotCache>>>,
    nrows: usize,
    ncols: usize,
}

impl HPlan {
    pub fn build(m: &HMatrix) -> HPlan {
        HPlan::build_with(m, ExecutorKind::from_env().build())
    }

    /// Build the forward half up front on the given backend.
    pub fn build_with(m: &HMatrix, exec: Arc<dyn Executor>) -> HPlan {
        let plan = HPlan::lazy_with(m, exec);
        plan.fwd.get_or_init(|| HSchedule::build(m, false, &*plan.exec));
        plan
    }

    /// A plan whose schedule halves are built on first execution.
    pub fn lazy(m: &HMatrix) -> HPlan {
        HPlan::lazy_with(m, ExecutorKind::from_env().build())
    }

    /// Lazy plan on the given backend.
    pub fn lazy_with(m: &HMatrix, exec: Arc<dyn Executor>) -> HPlan {
        HPlan { exec, fwd: OnceLock::new(), adj: OnceLock::new(), profile: Mutex::new(None), calib: Mutex::new(CalibInfo::default()), hot: RwLock::new(HotCache::from_env()), nrows: m.nrows(), ncols: m.ncols() }
    }

    /// Backend name (logs / bench rows).
    pub fn executor_name(&self) -> String {
        self.exec.name()
    }

    /// Install (or clear with `None`) the decode-once hot cache; in-flight
    /// products keep the cache they loaded at entry. Outputs are bitwise
    /// identical with or without a cache.
    pub fn set_hot_cache(&self, cache: Option<Arc<HotCache>>) {
        *self.hot.write().unwrap() = cache;
    }

    /// The active hot cache, if any (for residency stats / counters).
    pub fn hot_cache(&self) -> Option<Arc<HotCache>> {
        self.hot.read().unwrap().clone()
    }

    fn fwd(&self, m: &HMatrix) -> &HSchedule {
        let s = self.fwd.get_or_init(|| HSchedule::build(m, false, &*self.exec));
        self.sync_profile(s, true);
        s
    }

    fn adj(&self, m: &HMatrix) -> &HSchedule {
        let s = self.adj.get_or_init(|| HSchedule::build(m, true, &*self.exec));
        self.sync_profile(s, false);
        s
    }

    /// Apply the plan's active profile to a schedule half if it does not
    /// carry it yet. Checked on every access (one mutex + one RwLock read)
    /// rather than only inside the `OnceLock` initializer, so a `rebalance`
    /// that raced a half's in-flight lazy build — where `get()` still
    /// returned `None` — is healed on the very next product instead of being
    /// silently dropped. Healing the forward half also records the predicted
    /// makespan that the original `rebalance` could not compute.
    fn sync_profile(&self, s: &HSchedule, is_fwd: bool) {
        let Some(want) = self.profile.lock().unwrap().clone() else {
            return;
        };
        let stale = {
            let cur = s.profile.read().unwrap();
            !cur.as_ref().is_some_and(|c| Arc::ptr_eq(c, &want))
        };
        if stale {
            let predicted = s.rebalance(&want);
            if is_fwd {
                self.calib.lock().unwrap().predicted = predicted;
            }
        }
    }

    /// y += alpha · M · x.
    pub fn execute(&self, m: &HMatrix, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        let hot = self.hot_cache();
        self.fwd(m).exec(m, false, alpha, x, y, arena, &*self.exec, None, hot.as_ref());
    }

    /// y += alpha · Mᵀ · x.
    pub fn execute_adjoint(&self, m: &HMatrix, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        assert_eq!(x.len(), self.nrows);
        assert_eq!(y.len(), self.ncols);
        let hot = self.hot_cache();
        self.adj(m).exec(m, true, alpha, x, y, arena, &*self.exec, None, hot.as_ref());
    }

    /// Y += alpha · M · X (column-major multivectors, gemm-shaped tasks).
    pub fn execute_multi(&self, m: &HMatrix, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena) {
        assert_eq!(x.nrows(), self.ncols);
        assert_eq!(y.nrows(), self.nrows);
        assert_eq!(x.ncols(), y.ncols());
        let hot = self.hot_cache();
        self.fwd(m).exec_multi(m, false, alpha, x, y, arena, &*self.exec, None, hot.as_ref());
    }

    /// Y += alpha · Mᵀ · X (column-major multivectors, gemm-shaped tasks).
    pub fn execute_multi_adjoint(&self, m: &HMatrix, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena) {
        assert_eq!(x.nrows(), self.nrows);
        assert_eq!(y.nrows(), self.ncols);
        assert_eq!(x.ncols(), y.ncols());
        let hot = self.hot_cache();
        self.adj(m).exec_multi(m, true, alpha, x, y, arena, &*self.exec, None, hot.as_ref());
    }

    /// Row-restricted slice of one schedule half for a shard owning output
    /// rows `rows` (forward) / output cols (adjoint), packed for a
    /// `nshards`-wide, `npools`-pool executor.
    pub(crate) fn slice(&self, m: &HMatrix, adjoint: bool, rows: &Range<usize>, nshards: usize, npools: usize) -> HSlice {
        if adjoint {
            self.adj(m).slice(true, rows, nshards, npools)
        } else {
            self.fwd(m).slice(false, rows, nshards, npools)
        }
    }

    /// Per-task (write range, modeled cost at b = 1) of one schedule half —
    /// the row partitioner prorates these onto the leaf-cluster seam.
    pub(crate) fn task_loads(&self, m: &HMatrix, adjoint: bool) -> Vec<(Range<usize>, f64)> {
        let s = if adjoint { self.adj(m) } else { self.fwd(m) };
        let prof = s.profile.read().unwrap().clone();
        let costs = model_costs(&s.feats, &s.fixed, &s.per_rhs, prof.as_deref(), 1);
        s.tasks.iter().zip(&costs).map(|(t, &c)| (t.dst.clone(), c)).collect()
    }

    /// Execute a slice into a FULL-length `y` (the shard harvests its owned
    /// rows afterwards) on the shard's own executor.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn execute_slice(&self, m: &HMatrix, sl: &HSlice, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena, exec: &dyn Executor, hot: Option<&Arc<HotCache>>) {
        let s = if sl.adjoint { self.adj(m) } else { self.fwd(m) };
        s.exec_slice(sl, m, alpha, x, y, arena, exec, hot);
    }

    /// Batched variant of [`Self::execute_slice`] (full-height `y` panel);
    /// `rec` records per-chunk wall times into parent-task-id slots.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn execute_multi_slice(&self, m: &HMatrix, sl: &HSlice, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let s = if sl.adjoint { self.adj(m) } else { self.fwd(m) };
        s.exec_multi_slice(sl, m, alpha, x, y, arena, exec, rec, hot);
    }

    /// Fold a timed slice batch into `out` as fit samples and return the
    /// slice packing's (predicted, measured) makespan (seconds; predicted
    /// 0.0 until a profile is active).
    pub(crate) fn observe_multi_slice(&self, m: &HMatrix, sl: &HSlice, sink: &TimingSink, nrhs: usize, out: &mut Vec<Sample>) -> (f64, f64) {
        let s = if sl.adjoint { self.adj(m) } else { self.fwd(m) };
        s.push_samples_slice(sl, sink, nrhs, out);
        s.observe_multi_slice(sl, sink, nrhs)
    }

    /// Re-run LPT partitioning of every built schedule half with costs from
    /// `profile`, atomically swapping in the new packings (in-flight products
    /// finish on the packing they started with). The task lists are
    /// untouched, so outputs are bitwise identical before and after; halves
    /// built later inherit the profile. Unusable profiles (no positive
    /// finite coefficient) are ignored.
    pub fn rebalance(&self, profile: &CostProfile) {
        if !profile.is_usable() {
            return;
        }
        let p = Arc::new(profile.clone());
        *self.profile.lock().unwrap() = Some(p.clone());
        let mut predicted = 0.0;
        if let Some(s) = self.fwd.get() {
            predicted = s.rebalance(&p);
        }
        if let Some(s) = self.adj.get() {
            s.rebalance(&p);
        }
        let mut c = self.calib.lock().unwrap();
        c.source = profile.source.clone();
        c.predicted = predicted;
    }

    /// Measure per-chunk wall times over `warmup_batches` timed products
    /// (single-RHS and width-[`CALIB_RHS`] batches), fit per-kernel-class
    /// coefficients and re-balance the plan with them. Returns the fitted
    /// profile (save it with [`CostProfile::save`] / `hmatc calibrate`).
    pub fn calibrate(&self, m: &HMatrix, warmup_batches: usize) -> CostProfile {
        let rounds = warmup_batches.max(1);
        let sched = self.fwd(m);
        let sink = TimingSink::new(sched.tasks.len());
        let mut arena = Arena::new();
        let mut rng = Rng::new(0xCA11B);
        let x = rng.vector(self.ncols);
        let mut y = vec![0.0; self.nrows];
        // calibrate without a hot cache: coefficients must model the real
        // decode cost, not cache hits
        sched.exec(m, false, 1.0, &x, &mut y, &mut arena, &*self.exec, None, None); // warmup
        for _ in 0..rounds {
            sched.exec(m, false, 1.0, &x, &mut y, &mut arena, &*self.exec, Some(&sink), None);
        }
        let mut samples = Vec::new();
        sched.push_samples(&sink, 1, rounds, false, &mut samples);
        let measured = costmodel::sink_makespan(&sched.levels.load(), 0, &sink) / rounds as f64;
        let xm = DMatrix::random(self.ncols, CALIB_RHS, &mut rng);
        let mut ym = DMatrix::zeros(self.nrows, CALIB_RHS);
        sched.exec_multi(m, false, 1.0, &xm, &mut ym, &mut arena, &*self.exec, None, None); // warmup
        sink.reset();
        for _ in 0..rounds {
            sched.exec_multi(m, false, 1.0, &xm, &mut ym, &mut arena, &*self.exec, Some(&sink), None);
        }
        sched.push_samples(&sink, CALIB_RHS, rounds, true, &mut samples);
        let profile = costmodel::fit_pools(&samples, sched.npools).unwrap_or_default();
        self.rebalance(&profile);
        self.calib.lock().unwrap().measured = measured;
        profile
    }

    /// Per-task timing slots of the forward half — size the [`TimingSink`]
    /// passed to [`Self::execute_multi_timed`] with this.
    pub fn timing_slots(&self, m: &HMatrix) -> usize {
        self.fwd(m).tasks.len()
    }

    /// [`Self::execute_multi`] with per-chunk wall times recorded into
    /// `sink`. Unlike [`Self::calibrate`] this times WITH the live hot
    /// cache: the online window models what is actually resident and hot
    /// under real traffic, not cold decode cost.
    pub fn execute_multi_timed(&self, m: &HMatrix, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, sink: &TimingSink) {
        assert_eq!(x.nrows(), self.ncols);
        assert_eq!(y.nrows(), self.nrows);
        assert_eq!(x.ncols(), y.ncols());
        let hot = self.hot_cache();
        self.fwd(m).exec_multi(m, false, alpha, x, y, arena, &*self.exec, Some(sink), hot.as_ref());
    }

    /// Fold a timed forward batch into `out` as fit samples and return the
    /// (predicted, measured) makespan (seconds) of the width-`nrhs` packing
    /// it ran on; predicted is 0.0 until a profile is active.
    pub fn observe_multi(&self, m: &HMatrix, sink: &TimingSink, nrhs: usize, out: &mut Vec<Sample>) -> (f64, f64) {
        let sched = self.fwd(m);
        sched.push_samples(sink, nrhs, 1, true, out);
        sched.observe_multi(sink, nrhs)
    }

    /// Forward-half (fixed, per-RHS) seconds per batch under the active
    /// profile — the continuous batcher's deadline model. `None` until a
    /// profile is active.
    pub fn panel_cost_model(&self, m: &HMatrix) -> Option<(f64, f64)> {
        self.fwd(m).panel_terms()
    }

    /// Aggregate over the schedule halves built so far.
    pub fn stats(&self) -> PlanStats {
        let mut st = PlanStats { decode_kernels: crate::compress::dispatch::kernels_label(), ..PlanStats::default() };
        for sched in [self.fwd.get(), self.adj.get()].into_iter().flatten() {
            st.tasks += sched.tasks.len();
            st.max_shards = st.max_shards.max(sched.max_shards.load(Ordering::Relaxed));
            st.scratch_f64 = st.scratch_f64.max(sched.scratch);
        }
        if let Some(f) = self.fwd.get() {
            st.levels = f.level_ids.len();
        }
        if let Some(p) = self.profile.lock().unwrap().as_deref() {
            st.pool_cost_sources = p.pool_source_labels();
        }
        let c = self.calib.lock().unwrap();
        st.cost_source = c.source.clone();
        st.predicted_makespan = c.predicted;
        st.measured_makespan = c.measured;
        st
    }
}

// ---------------------------------------------------------------------------
// Shared pieces of the uniform / H² schedules
// ---------------------------------------------------------------------------

/// Reference from a coupling block into the flat forward-coefficient buffer
/// (offsets in rank units; the panel executors scale by the batch width).
struct CRef {
    block: usize,
    off: usize,
    len: usize,
}

fn apply_dense_oriented(m_blocks: &[Option<UniBlock>], b: usize, adjoint: bool, alpha: f64, xs: &[f64], yt: &mut [f64]) {
    match m_blocks[b].as_ref() {
        Some(UniBlock::Dense(d)) => {
            if adjoint {
                blas::gemv_transposed(alpha, d, xs, yt);
            } else {
                blas::gemv(alpha, d, xs, yt);
            }
        }
        Some(UniBlock::ZDense(z)) => {
            if adjoint {
                kernels::zgemv_t_blocked(alpha, z, xs, yt);
            } else {
                kernels::zgemv_blocked(alpha, z, xs, yt);
            }
        }
        _ => {}
    }
}

/// Panel variant of [`apply_dense_oriented`]: contiguous column-major panels,
/// matrix data streamed once for all columns.
fn apply_dense_oriented_panel(m_blocks: &[Option<UniBlock>], b: usize, adjoint: bool, alpha: f64, xs: &[f64], yt: &mut [f64], nrhs: usize) {
    match m_blocks[b].as_ref() {
        Some(UniBlock::Dense(d)) => {
            if adjoint {
                kernels::gemm_tn_panel(alpha, d, xs, yt, nrhs);
            } else {
                kernels::gemm_nn_panel(alpha, d, xs, yt, nrhs);
            }
        }
        Some(UniBlock::ZDense(z)) => {
            if adjoint {
                kernels::zgemm_t_blocked_panel(alpha, z, xs, yt, nrhs);
            } else {
                kernels::zgemm_blocked_panel(alpha, z, xs, yt, nrhs);
            }
        }
        _ => {}
    }
}

// ---------------------------------------------------------------------------
// Uniform-H plan
// ---------------------------------------------------------------------------

/// Forward-transform task: one input cluster's coefficient slot.
struct CoeffTask {
    cluster: usize,
    src: Range<usize>,
    off: usize,
    len: usize,
}

/// Output-side task: couplings into a local rank buffer, one basis apply,
/// dense blocks straight into `y`.
struct UniRowTask {
    cluster: usize,
    dst: Range<usize>,
    rank: usize,
    /// Coupling scratch (f64 per RHS) needed by the task's couplings.
    cscratch: usize,
    couplings: Vec<CRef>,
    dense: Vec<(usize, Range<usize>)>,
}

struct UniSchedule {
    ftasks: Vec<CoeffTask>,
    ffixed: Vec<f64>,
    fper_rhs: Vec<f64>,
    ffeats: Vec<TaskFeats>,
    fpscratch: Vec<usize>,
    /// Forward-transform shard packing (one barrier "level"); swappable.
    fshards: Packing<Vec<Shard>>,
    tasks: Vec<UniRowTask>,
    level_ids: Vec<Vec<usize>>,
    fixed: Vec<f64>,
    per_rhs: Vec<f64>,
    feats: Vec<TaskFeats>,
    scratch1: Vec<usize>,
    pscratch: Vec<usize>,
    /// Output-pass packings, root level first; swappable.
    levels: Packing<Vec<Vec<Shard>>>,
    /// Per-batch-width (forward shards, level shards) packings.
    multi: MultiCache<(Vec<Shard>, Vec<Vec<Shard>>)>,
    /// Active calibrated profile (None = static byte costs).
    profile: RwLock<Option<Arc<CostProfile>>>,
    /// Cost-model generation (see [`HSchedule`]).
    profile_gen: AtomicU64,
    /// Shard/chunk bin count the packings were built for.
    nshards: usize,
    /// Executor sub-pool count (see [`HSchedule::npools`]).
    npools: usize,
    s_len: usize,
    max_shards: AtomicUsize,
    scratch: usize,
    /// Mapped extents per barrier group: group 0 is the forward transform,
    /// group `1+li` output level `li`.
    prefetch: PrefetchPlan,
}

impl UniSchedule {
    fn build(m: &UniformHMatrix, adjoint: bool, exec: &dyn Executor) -> UniSchedule {
        let bt = &m.bt;
        let (in_ct, in_basis, out_ct, out_basis, out_lists) = if adjoint {
            (&bt.row_ct, &m.row_basis, &bt.col_ct, &m.col_basis, &bt.col_blocks)
        } else {
            (&bt.col_ct, &m.col_basis, &bt.row_ct, &m.row_basis, &bt.row_blocks)
        };

        // forward coefficient slots, one per input cluster with rank > 0
        let mut s_off = vec![0usize; in_ct.nodes.len()];
        let mut s_len = 0usize;
        let mut ftasks = Vec::new();
        let mut ffixed = Vec::new();
        let mut fper_rhs = Vec::new();
        let mut ffeats = Vec::new();
        let mut fpscratch = Vec::new();
        for (sigma, basis) in in_basis.iter().enumerate() {
            let k = basis.rank();
            s_off[sigma] = s_len;
            if k == 0 {
                continue;
            }
            let src = in_ct.node(sigma).range();
            ffixed.push(basis.byte_size() as f64);
            fper_rhs.push((8 * (src.len() + k)) as f64);
            ffeats.push(basis_feats(basis));
            fpscratch.push(src.len());
            ftasks.push(CoeffTask { cluster: sigma, src, off: s_len, len: k });
            s_len += k;
        }
        let nshards = exec.shard_count();
        let fscratch = vec![0usize; ffixed.len()];
        let fcosts: Vec<f64> = ffixed.iter().zip(&fper_rhs).map(|(f, v)| f + v).collect();
        let fshards = balance(&fcosts, &fscratch, nshards);

        // output-side tasks, level ordered
        let mut tasks = Vec::new();
        let mut fixed = Vec::new();
        let mut per_rhs = Vec::new();
        let mut feats = Vec::new();
        let mut scratch1 = Vec::new();
        let mut pscratch = Vec::new();
        let mut level_ids: Vec<Vec<usize>> = vec![Vec::new(); out_ct.levels.len()];
        for (tau, blocks) in out_lists.iter().enumerate() {
            if blocks.is_empty() {
                continue;
            }
            let rank = out_basis[tau].rank();
            let mut couplings = Vec::new();
            let mut dense = Vec::new();
            let mut fx = 0.0;
            let mut vr = 0.0;
            let mut tf = TaskFeats::default();
            let mut scr = rank;
            let mut csl = 0usize;
            let mut xmax = 0usize;
            for &b in blocks {
                let nd = bt.node(b);
                let in_cluster = if adjoint { nd.row } else { nd.col };
                let blk = m.blocks[b].as_ref().unwrap_or_else(|| {
                    panic!("UH plan build: missing leaf data for block {b} (row cluster {}, col cluster {})", nd.row, nd.col)
                });
                let (f, v) = uni_block_cost_split(blk);
                tf.merge(&uni_block_feats(blk));
                match blk {
                    UniBlock::Coupling(c) => {
                        scr = scr.max(rank + c.scratch_len());
                        csl = csl.max(c.scratch_len());
                        fx += f;
                        vr += v;
                        couplings.push(CRef { block: b, off: s_off[in_cluster], len: in_basis[in_cluster].rank() });
                    }
                    _ => {
                        fx += f;
                        vr += v;
                        let src = if adjoint { bt.row_ct.node(nd.row).range() } else { bt.col_ct.node(nd.col).range() };
                        xmax = xmax.max(src.len());
                        dense.push((b, src));
                    }
                }
            }
            if couplings.is_empty() && dense.is_empty() {
                continue;
            }
            let dst = out_ct.node(tau).range();
            if !couplings.is_empty() {
                fx += out_basis[tau].byte_size() as f64;
                vr += (8 * dst.len()) as f64;
                tf.merge(&basis_feats(&out_basis[tau]));
            }
            let id = tasks.len();
            pscratch.push(rank + csl + dst.len() + xmax);
            tasks.push(UniRowTask { cluster: tau, dst, rank, cscratch: csl, couplings, dense });
            fixed.push(fx);
            per_rhs.push(vr);
            feats.push(tf);
            scratch1.push(scr);
            level_ids[out_ct.node(tau).level].push(id);
        }
        let level_ids: Vec<Vec<usize>> = level_ids.into_iter().filter(|ids| !ids.is_empty()).collect();
        let mut pb = PrefetchBuilder::default();
        for t in &ftasks {
            in_basis[t.cluster].data.for_each_blob(&mut |blob| pb.add(0, blob));
        }
        for (li, ids) in level_ids.iter().enumerate() {
            for &id in ids {
                let task = &tasks[id];
                for cr in &task.couplings {
                    if let Some(blk) = m.blocks[cr.block].as_ref() {
                        blk.for_each_blob(&mut |blob| pb.add(1 + li, blob));
                    }
                }
                if !task.couplings.is_empty() {
                    out_basis[task.cluster].data.for_each_blob(&mut |blob| pb.add(1 + li, blob));
                }
                for (b, _) in &task.dense {
                    if let Some(blk) = m.blocks[*b].as_ref() {
                        blk.for_each_blob(&mut |blob| pb.add(1 + li, blob));
                    }
                }
            }
        }
        let costs: Vec<f64> = fixed.iter().zip(&per_rhs).map(|(f, v)| f + v).collect();
        let levels: Vec<Vec<Shard>> =
            level_ids.iter().map(|ids| balance_level(ids, &costs, &scratch1, nshards)).collect();
        let (max_shards, scratch) = max_shard_stats(&levels);
        let max_shards = max_shards.max(fshards.len());
        UniSchedule {
            ftasks,
            ffixed,
            fper_rhs,
            ffeats,
            fpscratch,
            fshards: Packing::new(fshards),
            tasks,
            level_ids,
            fixed,
            per_rhs,
            feats,
            scratch1,
            pscratch,
            levels: Packing::new(levels),
            multi: MultiCache::new(),
            profile: RwLock::new(None),
            profile_gen: AtomicU64::new(0),
            nshards,
            npools: exec.pool_count(),
            s_len,
            max_shards: AtomicUsize::new(max_shards),
            scratch,
            prefetch: pb.finish(),
        }
    }

    /// Re-partition the forward-transform shards and every output level with
    /// profile-modeled costs (never increasing the modeled makespan); drops
    /// the per-width packings. Returns the modeled makespan at b = 1.
    fn rebalance(&self, profile: &Arc<CostProfile>) -> f64 {
        let fcosts = LevelCosts::compute(&self.ffeats, &self.ffixed, &self.fper_rhs, Some(profile.as_ref()), 1, self.npools);
        let fscratch = vec![0usize; self.ftasks.len()];
        let fids: Vec<usize> = (0..self.ftasks.len()).collect();
        let old_f = self.fshards.load();
        let new_f = fcosts.rebalance(std::slice::from_ref(old_f.as_ref()), std::slice::from_ref(&fids), &fscratch, self.nshards).pop().unwrap_or_default();
        let costs = LevelCosts::compute(&self.feats, &self.fixed, &self.per_rhs, Some(profile.as_ref()), 1, self.npools);
        let old = self.levels.load();
        let new = costs.rebalance(&old, &self.level_ids, &self.scratch1, self.nshards);
        let ms = fcosts.makespan(std::slice::from_ref(&new_f)) + costs.makespan(&new);
        let (mx, _) = max_shard_stats(&new);
        self.max_shards.fetch_max(mx.max(new_f.len()), Ordering::Relaxed);
        self.fshards.store(new_f);
        self.levels.store(new);
        *self.profile.write().unwrap() = Some(profile.clone());
        self.profile_gen.fetch_add(1, Ordering::Release);
        ms
    }

    /// The cached width-`nrhs` (forward shards, level shards) packing (see
    /// [`HSchedule::multi_packing`]).
    fn multi_packing(&self, nrhs: usize) -> Arc<(Vec<Shard>, Vec<Vec<Shard>>)> {
        let gen = self.profile_gen.load(Ordering::Acquire);
        let prof = self.profile.read().unwrap().clone();
        self.multi.get(gen, nrhs, || {
            let fcosts = LevelCosts::compute(&self.ffeats, &self.ffixed, &self.fper_rhs, prof.as_deref(), nrhs, self.npools);
            let fscratch: Vec<usize> = self.fpscratch.iter().map(|s| s * nrhs).collect();
            let fids: Vec<usize> = (0..self.ftasks.len()).collect();
            let fsh = fcosts.balance_level(&fids, &fscratch, self.nshards);
            let costs = LevelCosts::compute(&self.feats, &self.fixed, &self.per_rhs, prof.as_deref(), nrhs, self.npools);
            let lv = costs.balance_levels_for(&self.level_ids, &self.pscratch, nrhs, self.nshards);
            (fsh, lv)
        })
    }

    /// Turn accumulated per-task times into fit samples (pool-tagged; see
    /// [`HSchedule::push_samples`]); forward-transform tasks occupy sink
    /// slots `0..ftasks.len()`, output tasks follow.
    fn push_samples(&self, sink: &TimingSink, nrhs: usize, rounds: usize, multi: bool, out: &mut Vec<Sample>) {
        let inv = 1.0 / rounds.max(1) as f64;
        let mut ftags = vec![0usize; self.ftasks.len()];
        let mut otags = vec![0usize; self.tasks.len()];
        if self.npools > 1 {
            if multi {
                let packed = self.multi_packing(nrhs);
                fill_pool_tags(std::slice::from_ref(&packed.0), self.npools, &mut ftags);
                fill_pool_tags(&packed.1, self.npools, &mut otags);
            } else {
                fill_pool_tags(std::slice::from_ref(self.fshards.load().as_ref()), self.npools, &mut ftags);
                fill_pool_tags(&self.levels.load(), self.npools, &mut otags);
            }
        }
        for (ti, ft) in self.ffeats.iter().enumerate() {
            out.push(Sample { feats: ft.clone(), nrhs, pool: ftags[ti], secs: sink.secs(ti) * inv });
        }
        let base = self.ftasks.len();
        for (ti, ft) in self.feats.iter().enumerate() {
            out.push(Sample { feats: ft.clone(), nrhs, pool: otags[ti], secs: sink.secs(base + ti) * inv });
        }
    }

    /// See [`HSchedule::observe_multi`]; forward-transform shards at sink
    /// base 0, output levels at base `ftasks.len()`.
    fn observe_multi(&self, sink: &TimingSink, nrhs: usize) -> (f64, f64) {
        let packed = self.multi_packing(nrhs);
        let prof = self.profile.read().unwrap().clone();
        let predicted = match prof.as_deref() {
            Some(p) => {
                let fcosts = LevelCosts::compute(&self.ffeats, &self.ffixed, &self.fper_rhs, Some(p), nrhs, self.npools);
                let costs = LevelCosts::compute(&self.feats, &self.fixed, &self.per_rhs, Some(p), nrhs, self.npools);
                fcosts.makespan(std::slice::from_ref(&packed.0)) + costs.makespan(&packed.1)
            }
            None => 0.0,
        };
        let measured = costmodel::sink_makespan(std::slice::from_ref(&packed.0), 0, sink)
            + costmodel::sink_makespan(&packed.1, self.ftasks.len(), sink);
        (predicted, measured)
    }

    /// See [`HSchedule::panel_terms`] (both schedule phases summed).
    fn panel_terms(&self) -> Option<(f64, f64)> {
        let prof = self.profile.read().unwrap().clone()?;
        let at = |nrhs: usize| -> f64 {
            model_costs(&self.ffeats, &self.ffixed, &self.fper_rhs, Some(prof.as_ref()), nrhs).iter().sum::<f64>()
                + model_costs(&self.feats, &self.fixed, &self.per_rhs, Some(prof.as_ref()), nrhs).iter().sum::<f64>()
        };
        let (c1, c2) = (at(1), at(2));
        let per = (c2 - c1).max(0.0);
        let w = self.nshards.max(1) as f64;
        Some((((c1 - per).max(0.0)) / w, per / w))
    }

    #[allow(clippy::too_many_arguments)]
    fn exec(&self, m: &UniformHMatrix, adjoint: bool, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let fshards = self.fshards.load();
        let levels = self.levels.load();
        self.exec_on(&fshards, &levels, self.max_shards.load(Ordering::Relaxed), self.scratch, m, adjoint, alpha, x, y, arena, exec, rec, hot);
    }

    /// Run explicit packings — the schedule's own, or a row-restricted
    /// [`UniSlice`] of them (see [`HSchedule::exec_on`]). The full-length
    /// coefficient buffer is kept even for slices: a slice zeroes it, fills
    /// only the slots its retained couplings read, and unreferenced slots
    /// stay zero (never read).
    #[allow(clippy::too_many_arguments)]
    fn exec_on(&self, fshards: &[Shard], levels: &[Vec<Shard>], max_shards: usize, scratch: usize, m: &UniformHMatrix, adjoint: bool, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let (in_basis, out_basis) = if adjoint { (&m.row_basis, &m.col_basis) } else { (&m.col_basis, &m.row_basis) };
        arena.ensure(exec.buffers_needed(max_shards), scratch, self.s_len, 0);
        let (bufs, s_all, _) = arena.split();

        // phase 1: forward transformation s_σ = Bᵀ x|σ (independent slots)
        self.prefetch.issue(0);
        {
            s_all[..self.s_len].fill(0.0);
            let slots = SharedVec::new(&mut s_all[..self.s_len]);
            self.prefetch.issue(1);
            run_level_rec(exec, fshards, bufs, rec.map(|s| (s, 0)), hot, &|ti, _buf| {
                let t = &self.ftasks[ti];
                // SAFETY: one task per disjoint slot range.
                let dst = unsafe { slots.range_mut(t.off..t.off + t.len) };
                in_basis[t.cluster].apply_transposed(&x[t.src.clone()], dst);
            });
        }

        // phase 2: level-ordered output pass
        let sref: &[f64] = &s_all[..self.s_len];
        let yy = SharedVec::new(y);
        for (li, level) in levels.iter().enumerate() {
            self.prefetch.issue(li + 2);
            run_level_rec(exec, level, bufs, rec.map(|s| (s, self.ftasks.len())), hot, &|ti, buf| {
                let task = &self.tasks[ti];
                // SAFETY: same-level clusters are disjoint; levels are
                // barrier separated.
                let yt = unsafe { yy.range_mut(task.dst.clone()) };
                let (tv, cscratch) = buf.split_at_mut(task.rank);
                tv.fill(0.0);
                let mut have = false;
                for cr in &task.couplings {
                    if let Some(UniBlock::Coupling(cm)) = m.blocks[cr.block].as_ref() {
                        let sv = &sref[cr.off..cr.off + cr.len];
                        if adjoint {
                            cm.apply_transposed_add_scratch(sv, tv, cscratch);
                        } else {
                            cm.apply_add_scratch(sv, tv, cscratch);
                        }
                        have = true;
                    }
                }
                if have && task.rank > 0 {
                    for v in tv.iter_mut() {
                        *v *= alpha;
                    }
                    out_basis[task.cluster].apply_add(tv, yt);
                }
                for (b, src) in &task.dense {
                    apply_dense_oriented(&m.blocks, *b, adjoint, alpha, &x[src.clone()], yt);
                }
            });
        }
    }

    /// Gemm-shaped batched execution: slot-major coefficient panels (slot σ
    /// occupies `s_off[σ]·b .. (s_off[σ]+k)·b`), y gathered per task into a
    /// contiguous `rows×b` panel, all block/basis/coupling data streamed once.
    #[allow(clippy::too_many_arguments)]
    fn exec_multi(&self, m: &UniformHMatrix, adjoint: bool, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let packed = self.multi_packing(y.ncols());
        self.exec_multi_on(&packed.0, &packed.1, m, adjoint, alpha, x, y, arena, exec, rec, hot);
    }

    /// Batched execution of explicit packings (see [`Self::exec_on`]).
    #[allow(clippy::too_many_arguments)]
    fn exec_multi_on(&self, fshards: &[Shard], levels: &[Vec<Shard>], m: &UniformHMatrix, adjoint: bool, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let (in_basis, out_basis) = if adjoint { (&m.row_basis, &m.col_basis) } else { (&m.col_basis, &m.row_basis) };
        let ylen = y.nrows();
        let nrhs = y.ncols();
        let (lmax, lscr) = max_shard_stats(levels);
        let max_shards = fshards.len().max(lmax);
        let scratch = fshards.iter().map(|s| s.scratch).max().unwrap_or(0).max(lscr);
        arena.ensure(exec.buffers_needed(max_shards), scratch, self.s_len * nrhs, 0);
        let (bufs, s_all, _) = arena.split();

        // phase 1: forward transformation panels S_σ = Bᵀ X|σ
        self.prefetch.issue(0);
        {
            s_all[..self.s_len * nrhs].fill(0.0);
            let slots = SharedVec::new(&mut s_all[..self.s_len * nrhs]);
            self.prefetch.issue(1);
            run_level_rec(exec, fshards, bufs, rec.map(|s| (s, 0)), hot, &|ti, buf| {
                let t = &self.ftasks[ti];
                let sl = t.src.len();
                let xp = &mut buf[..sl * nrhs];
                gather_panel(x, &t.src, xp);
                // SAFETY: one task per disjoint slot-panel range.
                let dst = unsafe { slots.range_mut(t.off * nrhs..(t.off + t.len) * nrhs) };
                in_basis[t.cluster].apply_transposed_panel(xp, dst, nrhs);
            });
        }

        // phase 2: level-ordered output pass on panels
        let sref: &[f64] = &s_all[..self.s_len * nrhs];
        let yy = SharedVec::new(y.data_mut());
        for (li, level) in levels.iter().enumerate() {
            self.prefetch.issue(li + 2);
            run_level_rec(exec, level, bufs, rec.map(|s| (s, self.ftasks.len())), hot, &|ti, buf| {
                let task = &self.tasks[ti];
                let dl = task.dst.len();
                let (tv, rest) = buf.split_at_mut(task.rank * nrhs);
                let (cscratch, rest) = rest.split_at_mut(task.cscratch * nrhs);
                let (yp, xarea) = rest.split_at_mut(dl * nrhs);
                for c in 0..nrhs {
                    // SAFETY: same-level clusters are disjoint; levels are
                    // barrier separated (per column).
                    let src = unsafe { yy.range(c * ylen + task.dst.start..c * ylen + task.dst.end) };
                    yp[c * dl..(c + 1) * dl].copy_from_slice(src);
                }
                if !task.couplings.is_empty() {
                    tv.fill(0.0);
                    for cr in &task.couplings {
                        if let Some(UniBlock::Coupling(cm)) = m.blocks[cr.block].as_ref() {
                            let sv = &sref[cr.off * nrhs..(cr.off + cr.len) * nrhs];
                            if adjoint {
                                cm.apply_transposed_add_panel(sv, tv, nrhs, cscratch);
                            } else {
                                cm.apply_add_panel(sv, tv, nrhs, cscratch);
                            }
                        }
                    }
                    if task.rank > 0 {
                        for v in tv.iter_mut() {
                            *v *= alpha;
                        }
                        out_basis[task.cluster].apply_add_panel(tv, yp, nrhs);
                    }
                }
                for (b, src) in &task.dense {
                    let sl = src.len();
                    let (xp, _) = xarea.split_at_mut(sl * nrhs);
                    gather_panel(x, src, xp);
                    apply_dense_oriented_panel(&m.blocks, *b, adjoint, alpha, xp, yp, nrhs);
                }
                for c in 0..nrhs {
                    // SAFETY: as above.
                    let dst = unsafe { yy.range_mut(c * ylen + task.dst.start..c * ylen + task.dst.end) };
                    dst.copy_from_slice(&yp[c * dl..(c + 1) * dl]);
                }
            });
        }
    }
}

/// Row-restricted view of one uniform-H schedule half (see [`HSlice`] for
/// the determinism contract). Output tasks are retained by `dst ∩ rows`;
/// forward-transform tasks are retained iff some retained coupling reads
/// their coefficient slot (slot offsets identify forward tasks 1:1), so a
/// shard computes exactly the coefficients it consumes.
pub(crate) struct UniSlice {
    adjoint: bool,
    fids: Vec<usize>,
    fshards: Packing<Vec<Shard>>,
    level_ids: Vec<Vec<usize>>,
    levels: Packing<Vec<Vec<Shard>>>,
    multi: MultiCache<(Vec<Shard>, Vec<Vec<Shard>>)>,
    nshards: usize,
    /// Sub-pool count of the SHARD's executor (not the parent plan's).
    npools: usize,
}

impl UniSchedule {
    fn slice(&self, adjoint: bool, rows: &Range<usize>, nshards: usize, npools: usize) -> UniSlice {
        let level_ids = filter_level_ids(&self.level_ids, |id| ranges_intersect(&self.tasks[id].dst, rows));
        // forward closure: the slot offsets read by retained couplings
        // (zero-length refs read nothing and pin no forward task)
        let mut used = std::collections::HashSet::new();
        for ids in &level_ids {
            for &id in ids {
                for cr in &self.tasks[id].couplings {
                    if cr.len > 0 {
                        used.insert(cr.off);
                    }
                }
            }
        }
        let fids: Vec<usize> = (0..self.ftasks.len()).filter(|&i| used.contains(&self.ftasks[i].off)).collect();
        let prof = self.profile.read().unwrap().clone();
        let fcosts = LevelCosts::compute(&self.ffeats, &self.ffixed, &self.fper_rhs, prof.as_deref(), 1, npools);
        let fscratch = vec![0usize; self.ftasks.len()];
        let fshards = fcosts.balance_level(&fids, &fscratch, nshards);
        let costs = LevelCosts::compute(&self.feats, &self.fixed, &self.per_rhs, prof.as_deref(), 1, npools);
        let levels: Vec<Vec<Shard>> = level_ids.iter().map(|ids| costs.balance_level(ids, &self.scratch1, nshards)).collect();
        UniSlice { adjoint, fids, fshards: Packing::new(fshards), level_ids, levels: Packing::new(levels), multi: MultiCache::new(), nshards, npools }
    }

    /// The slice's cached width-`nrhs` packing (see
    /// [`HSchedule::slice_multi_packing`]).
    fn slice_multi_packing(&self, sl: &UniSlice, nrhs: usize) -> Arc<(Vec<Shard>, Vec<Vec<Shard>>)> {
        let gen = self.profile_gen.load(Ordering::Acquire);
        let prof = self.profile.read().unwrap().clone();
        sl.multi.get(gen, nrhs, || {
            let fcosts = LevelCosts::compute(&self.ffeats, &self.ffixed, &self.fper_rhs, prof.as_deref(), nrhs, sl.npools);
            let fscratch: Vec<usize> = self.fpscratch.iter().map(|s| s * nrhs).collect();
            let fsh = fcosts.balance_level(&sl.fids, &fscratch, sl.nshards);
            let costs = LevelCosts::compute(&self.feats, &self.fixed, &self.per_rhs, prof.as_deref(), nrhs, sl.npools);
            let lv = costs.balance_levels_for(&sl.level_ids, &self.pscratch, nrhs, sl.nshards);
            (fsh, lv)
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_slice(&self, sl: &UniSlice, m: &UniformHMatrix, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena, exec: &dyn Executor, hot: Option<&Arc<HotCache>>) {
        let fshards = sl.fshards.load();
        let levels = sl.levels.load();
        let (lmax, lscr) = max_shard_stats(&levels);
        self.exec_on(&fshards, &levels, lmax.max(fshards.len()), lscr, m, sl.adjoint, alpha, x, y, arena, exec, None, hot);
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_multi_slice(&self, sl: &UniSlice, m: &UniformHMatrix, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let packed = self.slice_multi_packing(sl, y.ncols());
        self.exec_multi_on(&packed.0, &packed.1, m, sl.adjoint, alpha, x, y, arena, exec, rec, hot);
    }

    /// Slice-restricted sample harvest (sink slots are parent task ids:
    /// forward at 0.., output at base `ftasks.len()`), pool-tagged under the
    /// shard executor's sub-pools (see [`HSchedule::push_samples_slice`]).
    fn push_samples_slice(&self, sl: &UniSlice, sink: &TimingSink, nrhs: usize, out: &mut Vec<Sample>) {
        let mut ftags = vec![0usize; self.ftasks.len()];
        let mut otags = vec![0usize; self.tasks.len()];
        if sl.npools > 1 {
            let packed = self.slice_multi_packing(sl, nrhs);
            fill_pool_tags(std::slice::from_ref(&packed.0), sl.npools, &mut ftags);
            fill_pool_tags(&packed.1, sl.npools, &mut otags);
        }
        for &ti in &sl.fids {
            out.push(Sample { feats: self.ffeats[ti].clone(), nrhs, pool: ftags[ti], secs: sink.secs(ti) });
        }
        let base = self.ftasks.len();
        for ids in &sl.level_ids {
            for &ti in ids {
                out.push(Sample { feats: self.feats[ti].clone(), nrhs, pool: otags[ti], secs: sink.secs(base + ti) });
            }
        }
    }

    /// See [`HSchedule::observe_multi_slice`].
    fn observe_multi_slice(&self, sl: &UniSlice, sink: &TimingSink, nrhs: usize) -> (f64, f64) {
        let packed = self.slice_multi_packing(sl, nrhs);
        let prof = self.profile.read().unwrap().clone();
        let predicted = match prof.as_deref() {
            Some(p) => {
                let fcosts = LevelCosts::compute(&self.ffeats, &self.ffixed, &self.fper_rhs, Some(p), nrhs, sl.npools);
                let costs = LevelCosts::compute(&self.feats, &self.fixed, &self.per_rhs, Some(p), nrhs, sl.npools);
                fcosts.makespan(std::slice::from_ref(&packed.0)) + costs.makespan(&packed.1)
            }
            None => 0.0,
        };
        let measured = costmodel::sink_makespan(std::slice::from_ref(&packed.0), 0, sink)
            + costmodel::sink_makespan(&packed.1, self.ftasks.len(), sink);
        (predicted, measured)
    }
}

/// Precomputed execution plan for a [`UniformHMatrix`]; schedule halves are
/// built on first use (see [`HPlan`] for the build/lazy distinction and
/// [`HPlan::build_with`] for backend selection).
pub struct UniPlan {
    exec: Arc<dyn Executor>,
    fwd: OnceLock<UniSchedule>,
    adj: OnceLock<UniSchedule>,
    /// Active calibrated profile, also applied to halves built later.
    profile: Mutex<Option<Arc<CostProfile>>>,
    calib: Mutex<CalibInfo>,
    /// Decode-once hot-panel cache (see [`HPlan::set_hot_cache`]).
    hot: RwLock<Option<Arc<HotCache>>>,
    nrows: usize,
    ncols: usize,
}

impl UniPlan {
    pub fn build(m: &UniformHMatrix) -> UniPlan {
        UniPlan::build_with(m, ExecutorKind::from_env().build())
    }

    /// Build the forward half up front on the given backend.
    pub fn build_with(m: &UniformHMatrix, exec: Arc<dyn Executor>) -> UniPlan {
        let plan = UniPlan::lazy_with(m, exec);
        plan.fwd.get_or_init(|| UniSchedule::build(m, false, &*plan.exec));
        plan
    }

    /// A plan whose schedule halves are built on first execution.
    pub fn lazy(m: &UniformHMatrix) -> UniPlan {
        UniPlan::lazy_with(m, ExecutorKind::from_env().build())
    }

    /// Lazy plan on the given backend.
    pub fn lazy_with(m: &UniformHMatrix, exec: Arc<dyn Executor>) -> UniPlan {
        UniPlan { exec, fwd: OnceLock::new(), adj: OnceLock::new(), profile: Mutex::new(None), calib: Mutex::new(CalibInfo::default()), hot: RwLock::new(HotCache::from_env()), nrows: m.nrows(), ncols: m.ncols() }
    }

    /// Backend name (logs / bench rows).
    pub fn executor_name(&self) -> String {
        self.exec.name()
    }

    /// Install (or clear) the decode-once hot cache (see
    /// [`HPlan::set_hot_cache`]).
    pub fn set_hot_cache(&self, cache: Option<Arc<HotCache>>) {
        *self.hot.write().unwrap() = cache;
    }

    /// The active hot cache, if any.
    pub fn hot_cache(&self) -> Option<Arc<HotCache>> {
        self.hot.read().unwrap().clone()
    }

    fn fwd(&self, m: &UniformHMatrix) -> &UniSchedule {
        let s = self.fwd.get_or_init(|| UniSchedule::build(m, false, &*self.exec));
        self.sync_profile(s, true);
        s
    }

    fn adj(&self, m: &UniformHMatrix) -> &UniSchedule {
        let s = self.adj.get_or_init(|| UniSchedule::build(m, true, &*self.exec));
        self.sync_profile(s, false);
        s
    }

    /// See [`HPlan::sync_profile`]: heals a profile that raced a half's
    /// in-flight lazy build.
    fn sync_profile(&self, s: &UniSchedule, is_fwd: bool) {
        let Some(want) = self.profile.lock().unwrap().clone() else {
            return;
        };
        let stale = {
            let cur = s.profile.read().unwrap();
            !cur.as_ref().is_some_and(|c| Arc::ptr_eq(c, &want))
        };
        if stale {
            let predicted = s.rebalance(&want);
            if is_fwd {
                self.calib.lock().unwrap().predicted = predicted;
            }
        }
    }

    /// y += alpha · M · x.
    pub fn execute(&self, m: &UniformHMatrix, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        let hot = self.hot_cache();
        self.fwd(m).exec(m, false, alpha, x, y, arena, &*self.exec, None, hot.as_ref());
    }

    /// y += alpha · Mᵀ · x.
    pub fn execute_adjoint(&self, m: &UniformHMatrix, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        assert_eq!(x.len(), self.nrows);
        assert_eq!(y.len(), self.ncols);
        let hot = self.hot_cache();
        self.adj(m).exec(m, true, alpha, x, y, arena, &*self.exec, None, hot.as_ref());
    }

    /// Y += alpha · M · X: one gemm-shaped schedule pass for the whole batch
    /// (coefficient slots and couplings are streamed once per block, not once
    /// per column).
    pub fn execute_multi(&self, m: &UniformHMatrix, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena) {
        assert_eq!(x.nrows(), self.ncols);
        assert_eq!(y.nrows(), self.nrows);
        assert_eq!(x.ncols(), y.ncols());
        let hot = self.hot_cache();
        self.fwd(m).exec_multi(m, false, alpha, x, y, arena, &*self.exec, None, hot.as_ref());
    }

    /// Y += alpha · Mᵀ · X (gemm-shaped batched adjoint).
    pub fn execute_multi_adjoint(&self, m: &UniformHMatrix, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena) {
        assert_eq!(x.nrows(), self.nrows);
        assert_eq!(y.nrows(), self.ncols);
        assert_eq!(x.ncols(), y.ncols());
        let hot = self.hot_cache();
        self.adj(m).exec_multi(m, true, alpha, x, y, arena, &*self.exec, None, hot.as_ref());
    }

    /// Row-restricted slice of one schedule half (see [`HPlan::slice`]).
    pub(crate) fn slice(&self, m: &UniformHMatrix, adjoint: bool, rows: &Range<usize>, nshards: usize, npools: usize) -> UniSlice {
        if adjoint {
            self.adj(m).slice(true, rows, nshards, npools)
        } else {
            self.fwd(m).slice(false, rows, nshards, npools)
        }
    }

    /// Per-output-task (write range, modeled cost at b = 1); see
    /// [`HPlan::task_loads`]. Forward-transform cost is not prorated — it is
    /// closure-dependent, and the output pass dominates.
    pub(crate) fn task_loads(&self, m: &UniformHMatrix, adjoint: bool) -> Vec<(Range<usize>, f64)> {
        let s = if adjoint { self.adj(m) } else { self.fwd(m) };
        let prof = s.profile.read().unwrap().clone();
        let costs = model_costs(&s.feats, &s.fixed, &s.per_rhs, prof.as_deref(), 1);
        s.tasks.iter().zip(&costs).map(|(t, &c)| (t.dst.clone(), c)).collect()
    }

    /// Execute a slice into a FULL-length `y` (see [`HPlan::execute_slice`]).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn execute_slice(&self, m: &UniformHMatrix, sl: &UniSlice, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena, exec: &dyn Executor, hot: Option<&Arc<HotCache>>) {
        let s = if sl.adjoint { self.adj(m) } else { self.fwd(m) };
        s.exec_slice(sl, m, alpha, x, y, arena, exec, hot);
    }

    /// Batched variant of [`Self::execute_slice`].
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn execute_multi_slice(&self, m: &UniformHMatrix, sl: &UniSlice, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let s = if sl.adjoint { self.adj(m) } else { self.fwd(m) };
        s.exec_multi_slice(sl, m, alpha, x, y, arena, exec, rec, hot);
    }

    /// See [`HPlan::observe_multi_slice`].
    pub(crate) fn observe_multi_slice(&self, m: &UniformHMatrix, sl: &UniSlice, sink: &TimingSink, nrhs: usize, out: &mut Vec<Sample>) -> (f64, f64) {
        let s = if sl.adjoint { self.adj(m) } else { self.fwd(m) };
        s.push_samples_slice(sl, sink, nrhs, out);
        s.observe_multi_slice(sl, sink, nrhs)
    }

    /// Re-partition built schedule halves with `profile` costs (atomic swap,
    /// bitwise output-invariant; see [`HPlan::rebalance`]).
    pub fn rebalance(&self, profile: &CostProfile) {
        if !profile.is_usable() {
            return;
        }
        let p = Arc::new(profile.clone());
        *self.profile.lock().unwrap() = Some(p.clone());
        let mut predicted = 0.0;
        if let Some(s) = self.fwd.get() {
            predicted = s.rebalance(&p);
        }
        if let Some(s) = self.adj.get() {
            s.rebalance(&p);
        }
        let mut c = self.calib.lock().unwrap();
        c.source = profile.source.clone();
        c.predicted = predicted;
    }

    /// Timed calibration rounds + least-squares fit + re-balance (see
    /// [`HPlan::calibrate`]).
    pub fn calibrate(&self, m: &UniformHMatrix, warmup_batches: usize) -> CostProfile {
        let rounds = warmup_batches.max(1);
        let sched = self.fwd(m);
        let sink = TimingSink::new(sched.ftasks.len() + sched.tasks.len());
        let mut arena = Arena::new();
        let mut rng = Rng::new(0xCA11B + 1);
        let x = rng.vector(self.ncols);
        let mut y = vec![0.0; self.nrows];
        // calibrate without a hot cache (model the real decode cost)
        sched.exec(m, false, 1.0, &x, &mut y, &mut arena, &*self.exec, None, None); // warmup
        for _ in 0..rounds {
            sched.exec(m, false, 1.0, &x, &mut y, &mut arena, &*self.exec, Some(&sink), None);
        }
        let mut samples = Vec::new();
        sched.push_samples(&sink, 1, rounds, false, &mut samples);
        let fsh = sched.fshards.load();
        let lv = sched.levels.load();
        let measured = (costmodel::sink_makespan(std::slice::from_ref(fsh.as_ref()), 0, &sink) + costmodel::sink_makespan(&lv, sched.ftasks.len(), &sink)) / rounds as f64;
        let xm = DMatrix::random(self.ncols, CALIB_RHS, &mut rng);
        let mut ym = DMatrix::zeros(self.nrows, CALIB_RHS);
        sched.exec_multi(m, false, 1.0, &xm, &mut ym, &mut arena, &*self.exec, None, None); // warmup
        sink.reset();
        for _ in 0..rounds {
            sched.exec_multi(m, false, 1.0, &xm, &mut ym, &mut arena, &*self.exec, Some(&sink), None);
        }
        sched.push_samples(&sink, CALIB_RHS, rounds, true, &mut samples);
        let profile = costmodel::fit_pools(&samples, sched.npools).unwrap_or_default();
        self.rebalance(&profile);
        self.calib.lock().unwrap().measured = measured;
        profile
    }

    /// See [`HPlan::timing_slots`] (forward-transform + output tasks).
    pub fn timing_slots(&self, m: &UniformHMatrix) -> usize {
        let s = self.fwd(m);
        s.ftasks.len() + s.tasks.len()
    }

    /// See [`HPlan::execute_multi_timed`].
    pub fn execute_multi_timed(&self, m: &UniformHMatrix, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, sink: &TimingSink) {
        assert_eq!(x.nrows(), self.ncols);
        assert_eq!(y.nrows(), self.nrows);
        assert_eq!(x.ncols(), y.ncols());
        let hot = self.hot_cache();
        self.fwd(m).exec_multi(m, false, alpha, x, y, arena, &*self.exec, Some(sink), hot.as_ref());
    }

    /// See [`HPlan::observe_multi`].
    pub fn observe_multi(&self, m: &UniformHMatrix, sink: &TimingSink, nrhs: usize, out: &mut Vec<Sample>) -> (f64, f64) {
        let sched = self.fwd(m);
        sched.push_samples(sink, nrhs, 1, true, out);
        sched.observe_multi(sink, nrhs)
    }

    /// See [`HPlan::panel_cost_model`].
    pub fn panel_cost_model(&self, m: &UniformHMatrix) -> Option<(f64, f64)> {
        self.fwd(m).panel_terms()
    }

    /// Aggregate over the schedule halves built so far.
    pub fn stats(&self) -> PlanStats {
        let mut st = PlanStats { decode_kernels: crate::compress::dispatch::kernels_label(), ..PlanStats::default() };
        for sched in [self.fwd.get(), self.adj.get()].into_iter().flatten() {
            st.tasks += sched.ftasks.len() + sched.tasks.len();
            st.max_shards = st.max_shards.max(sched.max_shards.load(Ordering::Relaxed));
            st.scratch_f64 = st.scratch_f64.max(sched.scratch);
            st.coeff_f64 = st.coeff_f64.max(sched.s_len);
        }
        if let Some(f) = self.fwd.get() {
            st.levels = f.level_ids.len() + 1;
        }
        if let Some(p) = self.profile.lock().unwrap().as_deref() {
            st.pool_cost_sources = p.pool_source_labels();
        }
        let c = self.calib.lock().unwrap();
        st.cost_source = c.source.clone();
        st.predicted_makespan = c.predicted;
        st.measured_makespan = c.measured;
        st
    }
}

// ---------------------------------------------------------------------------
// H² plan
// ---------------------------------------------------------------------------

/// Upward-pass task: one input cluster's coefficient slot, computed from the
/// leaf basis or from already-complete child slots through transfer matrices.
struct UpTask {
    cluster: usize,
    off: usize,
    len: usize,
    leaf: bool,
    src: Range<usize>,
    /// (child cluster id, child slot offset, child rank).
    children: Vec<(usize, usize, usize)>,
}

/// Downward-pass task: couplings into the cluster's backward slot, transfer
/// to child slots (interior) or basis application into `y` (leaf), plus dense
/// blocks.
struct DownTask {
    cluster: usize,
    dst: Range<usize>,
    t_off: usize,
    rank: usize,
    leaf: bool,
    /// Coupling scratch (f64 per RHS) needed by the task's couplings.
    cscratch: usize,
    couplings: Vec<CRef>,
    dense: Vec<(usize, Range<usize>)>,
    /// (child cluster id, child slot offset, child rank).
    children: Vec<(usize, usize, usize)>,
}

struct H2Schedule {
    up_tasks: Vec<UpTask>,
    up_level_ids: Vec<Vec<usize>>,
    up_fixed: Vec<f64>,
    up_per_rhs: Vec<f64>,
    up_feats: Vec<TaskFeats>,
    up_pscratch: Vec<usize>,
    /// Execution order: deepest level first (children before parents).
    /// Swappable: `rebalance` publishes a re-partition of the same tasks.
    up_levels: Packing<Vec<Vec<Shard>>>,
    down_tasks: Vec<DownTask>,
    down_level_ids: Vec<Vec<usize>>,
    down_fixed: Vec<f64>,
    down_per_rhs: Vec<f64>,
    down_feats: Vec<TaskFeats>,
    down_scratch1: Vec<usize>,
    down_pscratch: Vec<usize>,
    /// Execution order: root level first (parents before children).
    down_levels: Packing<Vec<Vec<Shard>>>,
    /// Per-batch-width (up levels, down levels) packings.
    multi: MultiCache<(Vec<Vec<Shard>>, Vec<Vec<Shard>>)>,
    /// Active calibrated profile (None = static byte costs).
    profile: RwLock<Option<Arc<CostProfile>>>,
    /// Cost-model generation (see [`HSchedule`]).
    profile_gen: AtomicU64,
    /// Shard/chunk bin count the packings were built for.
    nshards: usize,
    /// Executor sub-pool count ([`Executor::pool_count`]); >1 only for
    /// `sharded:K`, where shard *i* of *n* runs on pool `i*K/n`.
    npools: usize,
    s_len: usize,
    t_len: usize,
    max_shards: AtomicUsize,
    scratch: usize,
    /// Mapped extents per barrier group: up levels first (deepest level =
    /// group 0), then down levels.
    prefetch: PrefetchPlan,
}

impl H2Schedule {
    fn build(m: &H2Matrix, adjoint: bool, exec: &dyn Executor) -> H2Schedule {
        let bt = &m.bt;
        let (in_ct, in_nb, out_ct, out_nb, out_lists) = if adjoint {
            (&bt.row_ct, &m.row_basis, &bt.col_ct, &m.col_basis, &bt.col_blocks)
        } else {
            (&bt.col_ct, &m.col_basis, &bt.row_ct, &m.row_basis, &bt.row_blocks)
        };
        let nshards = exec.shard_count();

        // ---- upward pass over the input tree ----
        let mut s_off = vec![0usize; in_ct.nodes.len()];
        let mut s_len = 0usize;
        for sigma in 0..in_ct.nodes.len() {
            s_off[sigma] = s_len;
            s_len += in_nb.rank[sigma];
        }
        let mut up_tasks = Vec::new();
        let mut up_fixed = Vec::new();
        let mut up_per_rhs = Vec::new();
        let mut up_feats = Vec::new();
        let mut up_pscratch = Vec::new();
        let mut up_level_ids = Vec::new();
        for lvl in (0..in_ct.levels.len()).rev() {
            let mut ids = Vec::new();
            for &sigma in &in_ct.levels[lvl] {
                let k = in_nb.rank[sigma];
                if k == 0 {
                    continue;
                }
                let nd = in_ct.node(sigma);
                let mut tf = TaskFeats::default();
                let (children, fx, vr, pan) = if nd.is_leaf() {
                    if let Some(leaf) = in_nb.leaf[sigma].as_ref() {
                        tf.merge(&basis_data_feats(leaf));
                    }
                    (Vec::new(), (8 * nd.size() * k) as f64, (8 * (nd.size() + k)) as f64, nd.size())
                } else {
                    let mut ch = Vec::new();
                    let mut fx = 0.0;
                    let mut vr = 0.0;
                    for &c in &nd.children {
                        if in_nb.rank[c] == 0 || in_nb.transfer[c].is_none() {
                            continue;
                        }
                        fx += in_nb.transfer[c].as_ref().unwrap().byte_size() as f64;
                        vr += (8 * (in_nb.rank[c] + k)) as f64;
                        tf.merge(&transfer_feats(in_nb.transfer[c].as_ref().unwrap()));
                        ch.push((c, s_off[c], in_nb.rank[c]));
                    }
                    (ch, fx, vr, 0)
                };
                ids.push(up_tasks.len());
                up_tasks.push(UpTask { cluster: sigma, off: s_off[sigma], len: k, leaf: nd.is_leaf(), src: nd.range(), children });
                up_fixed.push(fx);
                up_per_rhs.push(vr);
                up_feats.push(tf);
                up_pscratch.push(pan);
            }
            if !ids.is_empty() {
                up_level_ids.push(ids);
            }
        }
        let up_scratch = vec![0usize; up_tasks.len()];
        let up_costs: Vec<f64> = up_fixed.iter().zip(&up_per_rhs).map(|(f, v)| f + v).collect();
        let up_levels: Vec<Vec<Shard>> =
            up_level_ids.iter().map(|ids| balance_level(ids, &up_costs, &up_scratch, nshards)).collect();

        // ---- downward pass over the output tree ----
        let mut t_off = vec![0usize; out_ct.nodes.len()];
        let mut t_len = 0usize;
        for tau in 0..out_ct.nodes.len() {
            t_off[tau] = t_len;
            t_len += out_nb.rank[tau];
        }
        let mut down_tasks = Vec::new();
        let mut down_fixed = Vec::new();
        let mut down_per_rhs = Vec::new();
        let mut down_feats = Vec::new();
        let mut down_scratch = Vec::new();
        let mut down_pscratch = Vec::new();
        let mut down_level_ids = Vec::new();
        for lvl in 0..out_ct.levels.len() {
            let mut ids = Vec::new();
            for &tau in &out_ct.levels[lvl] {
                let rank = out_nb.rank[tau];
                let nd = out_ct.node(tau);
                let mut couplings = Vec::new();
                let mut dense = Vec::new();
                let mut fx = 0.0;
                let mut vr = 0.0;
                let mut tf = TaskFeats::default();
                let mut scr = rank;
                let mut csl = 0usize;
                let mut xmax = 0usize;
                for &b in &out_lists[tau] {
                    let bn = bt.node(b);
                    let in_cluster = if adjoint { bn.row } else { bn.col };
                    let blk = m.blocks[b].as_ref().unwrap_or_else(|| {
                        panic!("H2 plan build: missing leaf data for block {b} (row cluster {}, col cluster {})", bn.row, bn.col)
                    });
                    let (f, v) = uni_block_cost_split(blk);
                    tf.merge(&uni_block_feats(blk));
                    match blk {
                        UniBlock::Coupling(c) => {
                            scr = scr.max(rank + c.scratch_len());
                            csl = csl.max(c.scratch_len());
                            fx += f;
                            vr += v;
                            couplings.push(CRef { block: b, off: s_off[in_cluster], len: in_nb.rank[in_cluster] });
                        }
                        _ => {
                            fx += f;
                            vr += v;
                            let src = if adjoint { bt.row_ct.node(bn.row).range() } else { bt.col_ct.node(bn.col).range() };
                            xmax = xmax.max(src.len());
                            dense.push((b, src));
                        }
                    }
                }
                let mut children = Vec::new();
                if !nd.is_leaf() && rank > 0 {
                    for &c in &nd.children {
                        if out_nb.rank[c] == 0 || out_nb.transfer[c].is_none() {
                            continue;
                        }
                        fx += out_nb.transfer[c].as_ref().unwrap().byte_size() as f64;
                        vr += (8 * (rank + out_nb.rank[c])) as f64;
                        tf.merge(&transfer_feats(out_nb.transfer[c].as_ref().unwrap()));
                        children.push((c, t_off[c], out_nb.rank[c]));
                    }
                }
                if nd.is_leaf() && rank > 0 {
                    fx += (8 * nd.size() * rank) as f64;
                    vr += (8 * nd.size()) as f64;
                    if let Some(leaf) = out_nb.leaf[tau].as_ref() {
                        tf.merge(&basis_data_feats(leaf));
                    }
                }
                // a task is needed to relay or apply coefficients, or for
                // dense blocks — skip clusters with nothing to do
                if rank == 0 && dense.is_empty() {
                    continue;
                }
                ids.push(down_tasks.len());
                down_pscratch.push(rank + csl + nd.size() + xmax);
                down_tasks.push(DownTask {
                    cluster: tau,
                    dst: nd.range(),
                    t_off: t_off[tau],
                    rank,
                    leaf: nd.is_leaf(),
                    cscratch: csl,
                    couplings,
                    dense,
                    children,
                });
                down_fixed.push(fx);
                down_per_rhs.push(vr);
                down_feats.push(tf);
                down_scratch.push(scr);
            }
            if !ids.is_empty() {
                down_level_ids.push(ids);
            }
        }
        let down_costs: Vec<f64> = down_fixed.iter().zip(&down_per_rhs).map(|(f, v)| f + v).collect();
        let down_levels: Vec<Vec<Shard>> =
            down_level_ids.iter().map(|ids| balance_level(ids, &down_costs, &down_scratch, nshards)).collect();

        let mut pb = PrefetchBuilder::default();
        for (li, ids) in up_level_ids.iter().enumerate() {
            for &id in ids {
                let t = &up_tasks[id];
                if t.leaf {
                    if let Some(leaf) = in_nb.leaf[t.cluster].as_ref() {
                        leaf.for_each_blob(&mut |blob| pb.add(li, blob));
                    }
                } else {
                    for &(c, _, _) in &t.children {
                        if let Some(e) = in_nb.transfer[c].as_ref() {
                            e.for_each_blob(&mut |blob| pb.add(li, blob));
                        }
                    }
                }
            }
        }
        let dbase = up_level_ids.len();
        for (li, ids) in down_level_ids.iter().enumerate() {
            for &id in ids {
                let task = &down_tasks[id];
                for cr in &task.couplings {
                    if let Some(blk) = m.blocks[cr.block].as_ref() {
                        blk.for_each_blob(&mut |blob| pb.add(dbase + li, blob));
                    }
                }
                for (b, _) in &task.dense {
                    if let Some(blk) = m.blocks[*b].as_ref() {
                        blk.for_each_blob(&mut |blob| pb.add(dbase + li, blob));
                    }
                }
                if task.leaf {
                    if let Some(leaf) = out_nb.leaf[task.cluster].as_ref() {
                        leaf.for_each_blob(&mut |blob| pb.add(dbase + li, blob));
                    }
                } else {
                    for &(c, _, _) in &task.children {
                        if let Some(e) = out_nb.transfer[c].as_ref() {
                            e.for_each_blob(&mut |blob| pb.add(dbase + li, blob));
                        }
                    }
                }
            }
        }

        let (up_max, _) = max_shard_stats(&up_levels);
        let (down_max, scratch) = max_shard_stats(&down_levels);
        H2Schedule {
            up_tasks,
            up_level_ids,
            up_fixed,
            up_per_rhs,
            up_feats,
            up_pscratch,
            up_levels: Packing::new(up_levels),
            down_tasks,
            down_level_ids,
            down_fixed,
            down_per_rhs,
            down_feats,
            down_scratch1: down_scratch,
            down_pscratch,
            down_levels: Packing::new(down_levels),
            multi: MultiCache::new(),
            profile: RwLock::new(None),
            profile_gen: AtomicU64::new(0),
            nshards,
            npools: exec.pool_count(),
            s_len,
            t_len,
            max_shards: AtomicUsize::new(up_max.max(down_max)),
            scratch,
            prefetch: pb.finish(),
        }
    }

    /// Re-partition both passes with profile-modeled costs (never increasing
    /// the modeled makespan); drops the per-width packings. Returns the
    /// modeled makespan at b = 1 (up + down, levels are barriers).
    fn rebalance(&self, profile: &Arc<CostProfile>) -> f64 {
        let up_costs = LevelCosts::compute(&self.up_feats, &self.up_fixed, &self.up_per_rhs, Some(profile.as_ref()), 1, self.npools);
        let up_scratch = vec![0usize; self.up_tasks.len()];
        let old_up = self.up_levels.load();
        let new_up = up_costs.rebalance(&old_up, &self.up_level_ids, &up_scratch, self.nshards);
        let down_costs = LevelCosts::compute(&self.down_feats, &self.down_fixed, &self.down_per_rhs, Some(profile.as_ref()), 1, self.npools);
        let old_down = self.down_levels.load();
        let new_down = down_costs.rebalance(&old_down, &self.down_level_ids, &self.down_scratch1, self.nshards);
        let ms = up_costs.makespan(&new_up) + down_costs.makespan(&new_down);
        let (up_max, _) = max_shard_stats(&new_up);
        let (down_max, _) = max_shard_stats(&new_down);
        self.max_shards.fetch_max(up_max.max(down_max), Ordering::Relaxed);
        self.up_levels.store(new_up);
        self.down_levels.store(new_down);
        *self.profile.write().unwrap() = Some(profile.clone());
        self.profile_gen.fetch_add(1, Ordering::Release);
        ms
    }

    /// Fetch (or build) the width-`nrhs` (up levels, down levels) packing
    /// pair (see [`HSchedule::multi_packing`] for the generation protocol).
    fn multi_packing(&self, nrhs: usize) -> Arc<(Vec<Vec<Shard>>, Vec<Vec<Shard>>)> {
        let gen = self.profile_gen.load(Ordering::Acquire);
        let prof = self.profile.read().unwrap().clone();
        self.multi.get(gen, nrhs, || {
            let up_costs = LevelCosts::compute(&self.up_feats, &self.up_fixed, &self.up_per_rhs, prof.as_deref(), nrhs, self.npools);
            let down_costs = LevelCosts::compute(&self.down_feats, &self.down_fixed, &self.down_per_rhs, prof.as_deref(), nrhs, self.npools);
            (
                up_costs.balance_levels_for(&self.up_level_ids, &self.up_pscratch, nrhs, self.nshards),
                down_costs.balance_levels_for(&self.down_level_ids, &self.down_pscratch, nrhs, self.nshards),
            )
        })
    }

    /// Turn accumulated per-task times into fit samples (pool-tagged; see
    /// [`HSchedule::push_samples`]); upward-pass tasks occupy sink slots
    /// `0..up_tasks.len()`, downward-pass tasks follow.
    fn push_samples(&self, sink: &TimingSink, nrhs: usize, rounds: usize, multi: bool, out: &mut Vec<Sample>) {
        let inv = 1.0 / rounds.max(1) as f64;
        let mut utags = vec![0usize; self.up_tasks.len()];
        let mut dtags = vec![0usize; self.down_tasks.len()];
        if self.npools > 1 {
            if multi {
                let packed = self.multi_packing(nrhs);
                fill_pool_tags(&packed.0, self.npools, &mut utags);
                fill_pool_tags(&packed.1, self.npools, &mut dtags);
            } else {
                fill_pool_tags(&self.up_levels.load(), self.npools, &mut utags);
                fill_pool_tags(&self.down_levels.load(), self.npools, &mut dtags);
            }
        }
        for (ti, ft) in self.up_feats.iter().enumerate() {
            out.push(Sample { feats: ft.clone(), nrhs, pool: utags[ti], secs: sink.secs(ti) * inv });
        }
        let base = self.up_tasks.len();
        for (ti, ft) in self.down_feats.iter().enumerate() {
            out.push(Sample { feats: ft.clone(), nrhs, pool: dtags[ti], secs: sink.secs(base + ti) * inv });
        }
    }

    /// See [`HSchedule::observe_multi`]; upward pass at sink base 0,
    /// downward pass at base `up_tasks.len()`.
    fn observe_multi(&self, sink: &TimingSink, nrhs: usize) -> (f64, f64) {
        let packed = self.multi_packing(nrhs);
        let prof = self.profile.read().unwrap().clone();
        let predicted = match prof.as_deref() {
            Some(p) => {
                let up_costs = LevelCosts::compute(&self.up_feats, &self.up_fixed, &self.up_per_rhs, Some(p), nrhs, self.npools);
                let down_costs = LevelCosts::compute(&self.down_feats, &self.down_fixed, &self.down_per_rhs, Some(p), nrhs, self.npools);
                up_costs.makespan(&packed.0) + down_costs.makespan(&packed.1)
            }
            None => 0.0,
        };
        let measured = costmodel::sink_makespan(&packed.0, 0, sink)
            + costmodel::sink_makespan(&packed.1, self.up_tasks.len(), sink);
        (predicted, measured)
    }

    /// See [`HSchedule::panel_terms`] (both passes summed).
    fn panel_terms(&self) -> Option<(f64, f64)> {
        let prof = self.profile.read().unwrap().clone()?;
        let at = |nrhs: usize| -> f64 {
            model_costs(&self.up_feats, &self.up_fixed, &self.up_per_rhs, Some(prof.as_ref()), nrhs).iter().sum::<f64>()
                + model_costs(&self.down_feats, &self.down_fixed, &self.down_per_rhs, Some(prof.as_ref()), nrhs).iter().sum::<f64>()
        };
        let (c1, c2) = (at(1), at(2));
        let per = (c2 - c1).max(0.0);
        let w = self.nshards.max(1) as f64;
        Some((((c1 - per).max(0.0)) / w, per / w))
    }

    #[allow(clippy::too_many_arguments)]
    fn exec(&self, m: &H2Matrix, adjoint: bool, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let up_levels = self.up_levels.load();
        let down_levels = self.down_levels.load();
        self.exec_on(&up_levels, &down_levels, self.max_shards.load(Ordering::Relaxed), self.scratch, m, adjoint, alpha, x, y, arena, exec, rec, hot);
    }

    /// Run explicit up/down packings — the schedule's own, or a
    /// row-restricted [`H2Slice`] of them (see [`HSchedule::exec_on`]). Both
    /// coefficient buffers stay full length: a slice zeroes them, and every
    /// slot a retained task reads was filled by a retained task (the up
    /// closure / parent-chain retention guarantee); unharvested writes into
    /// off-shard child slots are dead stores.
    #[allow(clippy::too_many_arguments)]
    fn exec_on(&self, up_levels: &[Vec<Shard>], down_levels: &[Vec<Shard>], max_shards: usize, scratch: usize, m: &H2Matrix, adjoint: bool, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let (in_nb, out_nb) = if adjoint { (&m.row_basis, &m.col_basis) } else { (&m.col_basis, &m.row_basis) };
        arena.ensure(exec.buffers_needed(max_shards), scratch, self.s_len, self.t_len);
        let (bufs, s_all, t_all) = arena.split();

        // upward pass: forward transformation, children before parents
        self.prefetch.issue(0);
        {
            s_all[..self.s_len].fill(0.0);
            let slots = SharedVec::new(&mut s_all[..self.s_len]);
            for (li, level) in up_levels.iter().enumerate() {
                self.prefetch.issue(li + 1);
                run_level_rec(exec, level, bufs, rec.map(|s| (s, 0)), hot, &|ti, _buf| {
                    let t = &self.up_tasks[ti];
                    // SAFETY: one slot per cluster; child slots were filled
                    // in an earlier, already joined level.
                    let dst = unsafe { slots.range_mut(t.off..t.off + t.len) };
                    if t.leaf {
                        in_nb.leaf_apply_transposed(t.cluster, &x[t.src.clone()], dst);
                    } else {
                        for &(c, coff, clen) in &t.children {
                            let sc_child = unsafe { slots.range(coff..coff + clen) };
                            if let Some(e) = in_nb.transfer[c].as_ref() {
                                e.apply_transposed_add(sc_child, dst);
                            }
                        }
                    }
                });
            }
        }

        // downward pass: couplings + transfer to children + leaf application
        let sref: &[f64] = &s_all[..self.s_len];
        t_all[..self.t_len].fill(0.0);
        let tslots = SharedVec::new(&mut t_all[..self.t_len]);
        let yy = SharedVec::new(y);
        let dbase = self.up_level_ids.len();
        for (li, level) in down_levels.iter().enumerate() {
            self.prefetch.issue(dbase + li + 1);
            run_level_rec(exec, level, bufs, rec.map(|s| (s, self.up_tasks.len())), hot, &|ti, buf| {
                let task = &self.down_tasks[ti];
                // SAFETY: τ's slot was written only by its parent in an
                // earlier level; same-level clusters are disjoint.
                let tv = unsafe { tslots.range_mut(task.t_off..task.t_off + task.rank) };
                let (sbuf, cscratch) = buf.split_at_mut(task.rank);
                for cr in &task.couplings {
                    if let Some(UniBlock::Coupling(cm)) = m.blocks[cr.block].as_ref() {
                        let sv = &sref[cr.off..cr.off + cr.len];
                        if adjoint {
                            cm.apply_transposed_add_scratch(sv, tv, cscratch);
                        } else {
                            cm.apply_add_scratch(sv, tv, cscratch);
                        }
                    }
                }
                if task.leaf {
                    if task.rank > 0 && tv.iter().any(|&v| v != 0.0) {
                        for (d, &v) in sbuf.iter_mut().zip(tv.iter()) {
                            *d = alpha * v;
                        }
                        // SAFETY: leaf ranges are disjoint; ancestor dense
                        // writes happened in earlier levels.
                        let yt = unsafe { yy.range_mut(task.dst.clone()) };
                        out_nb.leaf_apply_add(task.cluster, sbuf, yt);
                    }
                } else {
                    for &(c, ctoff, crank) in &task.children {
                        // SAFETY: each child has exactly one parent.
                        let tc = unsafe { tslots.range_mut(ctoff..ctoff + crank) };
                        if let Some(e) = out_nb.transfer[c].as_ref() {
                            e.apply_add(tv, tc);
                        }
                    }
                }
                if !task.dense.is_empty() {
                    // SAFETY: same disjointness/barrier argument.
                    let yt = unsafe { yy.range_mut(task.dst.clone()) };
                    for (b, src) in &task.dense {
                        apply_dense_oriented(&m.blocks, *b, adjoint, alpha, &x[src.clone()], yt);
                    }
                }
            });
        }
    }

    /// Gemm-shaped batched execution: slot-major coefficient panels for both
    /// transform directions, leaf/dense y rows gathered into contiguous
    /// panels; transfer and coupling matrices are streamed once per batch.
    #[allow(clippy::too_many_arguments)]
    fn exec_multi(&self, m: &H2Matrix, adjoint: bool, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let packed = self.multi_packing(y.ncols());
        self.exec_multi_on(&packed.0, &packed.1, m, adjoint, alpha, x, y, arena, exec, rec, hot);
    }

    /// Batched execution of explicit up/down packings (see [`Self::exec_on`]).
    #[allow(clippy::too_many_arguments)]
    fn exec_multi_on(&self, up_levels: &[Vec<Shard>], down_levels: &[Vec<Shard>], m: &H2Matrix, adjoint: bool, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let (in_nb, out_nb) = if adjoint { (&m.row_basis, &m.col_basis) } else { (&m.col_basis, &m.row_basis) };
        let ylen = y.nrows();
        let nrhs = y.ncols();
        let (umax, uscr) = max_shard_stats(up_levels);
        let (dmax, dscr) = max_shard_stats(down_levels);
        arena.ensure(exec.buffers_needed(umax.max(dmax)), uscr.max(dscr), self.s_len * nrhs, self.t_len * nrhs);
        let (bufs, s_all, t_all) = arena.split();

        // upward pass: forward transformation panels, children before parents
        self.prefetch.issue(0);
        {
            s_all[..self.s_len * nrhs].fill(0.0);
            let slots = SharedVec::new(&mut s_all[..self.s_len * nrhs]);
            for (li, level) in up_levels.iter().enumerate() {
                self.prefetch.issue(li + 1);
                run_level_rec(exec, level, bufs, rec.map(|s| (s, 0)), hot, &|ti, buf| {
                    let t = &self.up_tasks[ti];
                    // SAFETY: one slot panel per cluster; child slots joined
                    // in an earlier level.
                    let dst = unsafe { slots.range_mut(t.off * nrhs..(t.off + t.len) * nrhs) };
                    if t.leaf {
                        let sl = t.src.len();
                        let xp = &mut buf[..sl * nrhs];
                        gather_panel(x, &t.src, xp);
                        in_nb.leaf_apply_transposed_panel(t.cluster, xp, dst, nrhs);
                    } else {
                        for &(c, coff, clen) in &t.children {
                            let sc_child = unsafe { slots.range(coff * nrhs..(coff + clen) * nrhs) };
                            if let Some(e) = in_nb.transfer[c].as_ref() {
                                e.apply_transposed_add_panel(sc_child, dst, nrhs);
                            }
                        }
                    }
                });
            }
        }

        // downward pass on panels
        let sref: &[f64] = &s_all[..self.s_len * nrhs];
        t_all[..self.t_len * nrhs].fill(0.0);
        let tslots = SharedVec::new(&mut t_all[..self.t_len * nrhs]);
        let yy = SharedVec::new(y.data_mut());
        let dbase = self.up_level_ids.len();
        for (li, level) in down_levels.iter().enumerate() {
            self.prefetch.issue(dbase + li + 1);
            run_level_rec(exec, level, bufs, rec.map(|s| (s, self.up_tasks.len())), hot, &|ti, buf| {
                let task = &self.down_tasks[ti];
                let dl = task.dst.len();
                // SAFETY: τ's slot panel was written only by its parent in
                // an earlier level.
                let tv = unsafe { tslots.range_mut(task.t_off * nrhs..(task.t_off + task.rank) * nrhs) };
                let (sbuf, rest) = buf.split_at_mut(task.rank * nrhs);
                let (cscratch, rest) = rest.split_at_mut(task.cscratch * nrhs);
                let (yp, xarea) = rest.split_at_mut(dl * nrhs);
                for cr in &task.couplings {
                    if let Some(UniBlock::Coupling(cm)) = m.blocks[cr.block].as_ref() {
                        let sv = &sref[cr.off * nrhs..(cr.off + cr.len) * nrhs];
                        if adjoint {
                            cm.apply_transposed_add_panel(sv, tv, nrhs, cscratch);
                        } else {
                            cm.apply_add_panel(sv, tv, nrhs, cscratch);
                        }
                    }
                }
                let leaf_write = task.leaf && task.rank > 0 && tv.iter().any(|&v| v != 0.0);
                let need_y = leaf_write || !task.dense.is_empty();
                if need_y {
                    for c in 0..nrhs {
                        // SAFETY: leaf/dense ranges are disjoint within a
                        // level; levels are barriers.
                        let src = unsafe { yy.range(c * ylen + task.dst.start..c * ylen + task.dst.end) };
                        yp[c * dl..(c + 1) * dl].copy_from_slice(src);
                    }
                }
                if task.leaf {
                    if leaf_write {
                        for (d, &v) in sbuf.iter_mut().zip(tv.iter()) {
                            *d = alpha * v;
                        }
                        out_nb.leaf_apply_add_panel(task.cluster, sbuf, yp, nrhs);
                    }
                } else {
                    for &(c, ctoff, crank) in &task.children {
                        // SAFETY: each child has exactly one parent.
                        let tc = unsafe { tslots.range_mut(ctoff * nrhs..(ctoff + crank) * nrhs) };
                        if let Some(e) = out_nb.transfer[c].as_ref() {
                            e.apply_add_panel(tv, tc, nrhs);
                        }
                    }
                }
                for (b, src) in &task.dense {
                    let sl = src.len();
                    let (xp, _) = xarea.split_at_mut(sl * nrhs);
                    gather_panel(x, src, xp);
                    apply_dense_oriented_panel(&m.blocks, *b, adjoint, alpha, xp, yp, nrhs);
                }
                if need_y {
                    for c in 0..nrhs {
                        // SAFETY: as above.
                        let dst = unsafe { yy.range_mut(c * ylen + task.dst.start..c * ylen + task.dst.end) };
                        dst.copy_from_slice(&yp[c * dl..(c + 1) * dl]);
                    }
                }
            });
        }
    }
}

/// Row-restricted view of one H² schedule half (see [`HSlice`] for the
/// determinism contract). Down tasks are retained by `dst ∩ rows` — every
/// ancestor of a retained task intersects too (its range contains the
/// descendant's), so the parent-before-child t-slot relay chain is complete.
/// Up tasks are the transitive closure of the coefficient slots the retained
/// couplings read: the slot's own task plus, recursively, the child slots it
/// is assembled from.
pub(crate) struct H2Slice {
    adjoint: bool,
    up_level_ids: Vec<Vec<usize>>,
    up_levels: Packing<Vec<Vec<Shard>>>,
    down_level_ids: Vec<Vec<usize>>,
    down_levels: Packing<Vec<Vec<Shard>>>,
    multi: MultiCache<(Vec<Vec<Shard>>, Vec<Vec<Shard>>)>,
    nshards: usize,
    /// Sub-pool count of the executor the slice is packed for (the SHARD
    /// executor, not the parent plan's).
    npools: usize,
}

impl H2Schedule {
    fn slice(&self, adjoint: bool, rows: &Range<usize>, nshards: usize, npools: usize) -> H2Slice {
        let down_level_ids = filter_level_ids(&self.down_level_ids, |id| ranges_intersect(&self.down_tasks[id].dst, rows));
        // upward closure over slot offsets (offsets identify up tasks 1:1)
        let mut by_off = std::collections::HashMap::new();
        for (id, t) in self.up_tasks.iter().enumerate() {
            by_off.insert(t.off, id);
        }
        let mut needed = vec![false; self.up_tasks.len()];
        let mut stack = Vec::new();
        for ids in &down_level_ids {
            for &id in ids {
                for cr in &self.down_tasks[id].couplings {
                    if cr.len > 0 {
                        stack.push(cr.off);
                    }
                }
            }
        }
        while let Some(off) = stack.pop() {
            if let Some(&id) = by_off.get(&off) {
                if !needed[id] {
                    needed[id] = true;
                    for &(_, coff, clen) in &self.up_tasks[id].children {
                        if clen > 0 {
                            stack.push(coff);
                        }
                    }
                }
            }
        }
        let up_level_ids = filter_level_ids(&self.up_level_ids, |id| needed[id]);
        let prof = self.profile.read().unwrap().clone();
        let up_costs = LevelCosts::compute(&self.up_feats, &self.up_fixed, &self.up_per_rhs, prof.as_deref(), 1, npools);
        let up_scratch = vec![0usize; self.up_tasks.len()];
        let up_levels: Vec<Vec<Shard>> =
            up_level_ids.iter().map(|ids| up_costs.balance_level(ids, &up_scratch, nshards)).collect();
        let down_costs = LevelCosts::compute(&self.down_feats, &self.down_fixed, &self.down_per_rhs, prof.as_deref(), 1, npools);
        let down_levels: Vec<Vec<Shard>> =
            down_level_ids.iter().map(|ids| down_costs.balance_level(ids, &self.down_scratch1, nshards)).collect();
        H2Slice {
            adjoint,
            up_level_ids,
            up_levels: Packing::new(up_levels),
            down_level_ids,
            down_levels: Packing::new(down_levels),
            multi: MultiCache::new(),
            nshards,
            npools,
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_slice(&self, sl: &H2Slice, m: &H2Matrix, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena, exec: &dyn Executor, hot: Option<&Arc<HotCache>>) {
        let up_levels = sl.up_levels.load();
        let down_levels = sl.down_levels.load();
        let (umax, _) = max_shard_stats(&up_levels);
        let (dmax, scr) = max_shard_stats(&down_levels);
        self.exec_on(&up_levels, &down_levels, umax.max(dmax), scr, m, sl.adjoint, alpha, x, y, arena, exec, None, hot);
    }

    /// Fetch (or build) a slice's width-`nrhs` (up, down) packing pair under
    /// the shard executor's sub-pools.
    fn slice_multi_packing(&self, sl: &H2Slice, nrhs: usize) -> Arc<(Vec<Vec<Shard>>, Vec<Vec<Shard>>)> {
        let gen = self.profile_gen.load(Ordering::Acquire);
        let prof = self.profile.read().unwrap().clone();
        sl.multi.get(gen, nrhs, || {
            let up_costs = LevelCosts::compute(&self.up_feats, &self.up_fixed, &self.up_per_rhs, prof.as_deref(), nrhs, sl.npools);
            let down_costs = LevelCosts::compute(&self.down_feats, &self.down_fixed, &self.down_per_rhs, prof.as_deref(), nrhs, sl.npools);
            (
                up_costs.balance_levels_for(&sl.up_level_ids, &self.up_pscratch, nrhs, sl.nshards),
                down_costs.balance_levels_for(&sl.down_level_ids, &self.down_pscratch, nrhs, sl.nshards),
            )
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_multi_slice(&self, sl: &H2Slice, m: &H2Matrix, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let packed = self.slice_multi_packing(sl, y.ncols());
        self.exec_multi_on(&packed.0, &packed.1, m, sl.adjoint, alpha, x, y, arena, exec, rec, hot);
    }

    /// Slice-restricted sample harvest (sink slots are parent task ids: up
    /// at 0.., down at base `up_tasks.len()`), pool-tagged under the shard
    /// executor's sub-pools (see [`HSchedule::push_samples_slice`]).
    fn push_samples_slice(&self, sl: &H2Slice, sink: &TimingSink, nrhs: usize, out: &mut Vec<Sample>) {
        let mut utags = vec![0usize; self.up_tasks.len()];
        let mut dtags = vec![0usize; self.down_tasks.len()];
        if sl.npools > 1 {
            let packed = self.slice_multi_packing(sl, nrhs);
            fill_pool_tags(&packed.0, sl.npools, &mut utags);
            fill_pool_tags(&packed.1, sl.npools, &mut dtags);
        }
        for ids in &sl.up_level_ids {
            for &ti in ids {
                out.push(Sample { feats: self.up_feats[ti].clone(), nrhs, pool: utags[ti], secs: sink.secs(ti) });
            }
        }
        let base = self.up_tasks.len();
        for ids in &sl.down_level_ids {
            for &ti in ids {
                out.push(Sample { feats: self.down_feats[ti].clone(), nrhs, pool: dtags[ti], secs: sink.secs(base + ti) });
            }
        }
    }

    /// See [`HSchedule::observe_multi_slice`].
    fn observe_multi_slice(&self, sl: &H2Slice, sink: &TimingSink, nrhs: usize) -> (f64, f64) {
        let packed = self.slice_multi_packing(sl, nrhs);
        let prof = self.profile.read().unwrap().clone();
        let predicted = match prof.as_deref() {
            Some(p) => {
                let up_costs = LevelCosts::compute(&self.up_feats, &self.up_fixed, &self.up_per_rhs, Some(p), nrhs, sl.npools);
                let down_costs = LevelCosts::compute(&self.down_feats, &self.down_fixed, &self.down_per_rhs, Some(p), nrhs, sl.npools);
                up_costs.makespan(&packed.0) + down_costs.makespan(&packed.1)
            }
            None => 0.0,
        };
        let measured = costmodel::sink_makespan(&packed.0, 0, sink)
            + costmodel::sink_makespan(&packed.1, self.up_tasks.len(), sink);
        (predicted, measured)
    }
}

/// Precomputed execution plan for an [`H2Matrix`]; schedule halves are built
/// on first use (see [`HPlan`] for the build/lazy distinction and
/// [`HPlan::build_with`] for backend selection).
pub struct H2Plan {
    exec: Arc<dyn Executor>,
    fwd: OnceLock<H2Schedule>,
    adj: OnceLock<H2Schedule>,
    /// Active calibrated profile, also applied to halves built later.
    profile: Mutex<Option<Arc<CostProfile>>>,
    calib: Mutex<CalibInfo>,
    /// Decode-once hot-panel cache (see [`HPlan::set_hot_cache`]).
    hot: RwLock<Option<Arc<HotCache>>>,
    nrows: usize,
    ncols: usize,
}

impl H2Plan {
    pub fn build(m: &H2Matrix) -> H2Plan {
        H2Plan::build_with(m, ExecutorKind::from_env().build())
    }

    /// Build the forward half up front on the given backend.
    pub fn build_with(m: &H2Matrix, exec: Arc<dyn Executor>) -> H2Plan {
        let plan = H2Plan::lazy_with(m, exec);
        plan.fwd.get_or_init(|| H2Schedule::build(m, false, &*plan.exec));
        plan
    }

    /// A plan whose schedule halves are built on first execution.
    pub fn lazy(m: &H2Matrix) -> H2Plan {
        H2Plan::lazy_with(m, ExecutorKind::from_env().build())
    }

    /// Lazy plan on the given backend.
    pub fn lazy_with(m: &H2Matrix, exec: Arc<dyn Executor>) -> H2Plan {
        H2Plan { exec, fwd: OnceLock::new(), adj: OnceLock::new(), profile: Mutex::new(None), calib: Mutex::new(CalibInfo::default()), hot: RwLock::new(HotCache::from_env()), nrows: m.nrows(), ncols: m.ncols() }
    }

    /// Backend name (logs / bench rows).
    pub fn executor_name(&self) -> String {
        self.exec.name()
    }

    /// Install (or clear) the decode-once hot cache (see
    /// [`HPlan::set_hot_cache`]).
    pub fn set_hot_cache(&self, cache: Option<Arc<HotCache>>) {
        *self.hot.write().unwrap() = cache;
    }

    /// The active hot cache, if any.
    pub fn hot_cache(&self) -> Option<Arc<HotCache>> {
        self.hot.read().unwrap().clone()
    }

    fn fwd(&self, m: &H2Matrix) -> &H2Schedule {
        let s = self.fwd.get_or_init(|| H2Schedule::build(m, false, &*self.exec));
        self.sync_profile(s, true);
        s
    }

    fn adj(&self, m: &H2Matrix) -> &H2Schedule {
        let s = self.adj.get_or_init(|| H2Schedule::build(m, true, &*self.exec));
        self.sync_profile(s, false);
        s
    }

    /// See [`HPlan::sync_profile`]: heals a profile that raced a half's
    /// in-flight lazy build.
    fn sync_profile(&self, s: &H2Schedule, is_fwd: bool) {
        let Some(want) = self.profile.lock().unwrap().clone() else {
            return;
        };
        let stale = {
            let cur = s.profile.read().unwrap();
            !cur.as_ref().is_some_and(|c| Arc::ptr_eq(c, &want))
        };
        if stale {
            let predicted = s.rebalance(&want);
            if is_fwd {
                self.calib.lock().unwrap().predicted = predicted;
            }
        }
    }

    /// y += alpha · M · x.
    pub fn execute(&self, m: &H2Matrix, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        let hot = self.hot_cache();
        self.fwd(m).exec(m, false, alpha, x, y, arena, &*self.exec, None, hot.as_ref());
    }

    /// y += alpha · Mᵀ · x.
    pub fn execute_adjoint(&self, m: &H2Matrix, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        assert_eq!(x.len(), self.nrows);
        assert_eq!(y.len(), self.ncols);
        let hot = self.hot_cache();
        self.adj(m).exec(m, true, alpha, x, y, arena, &*self.exec, None, hot.as_ref());
    }

    /// Y += alpha · M · X: one gemm-shaped schedule pass for the whole batch.
    pub fn execute_multi(&self, m: &H2Matrix, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena) {
        assert_eq!(x.nrows(), self.ncols);
        assert_eq!(y.nrows(), self.nrows);
        assert_eq!(x.ncols(), y.ncols());
        let hot = self.hot_cache();
        self.fwd(m).exec_multi(m, false, alpha, x, y, arena, &*self.exec, None, hot.as_ref());
    }

    /// Y += alpha · Mᵀ · X (gemm-shaped batched adjoint).
    pub fn execute_multi_adjoint(&self, m: &H2Matrix, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena) {
        assert_eq!(x.nrows(), self.nrows);
        assert_eq!(y.nrows(), self.ncols);
        assert_eq!(x.ncols(), y.ncols());
        let hot = self.hot_cache();
        self.adj(m).exec_multi(m, true, alpha, x, y, arena, &*self.exec, None, hot.as_ref());
    }

    /// Row-restricted slice of one schedule half (see [`HPlan::slice`]).
    pub(crate) fn slice(&self, m: &H2Matrix, adjoint: bool, rows: &Range<usize>, nshards: usize, npools: usize) -> H2Slice {
        if adjoint {
            self.adj(m).slice(true, rows, nshards, npools)
        } else {
            self.fwd(m).slice(false, rows, nshards, npools)
        }
    }

    /// Per-down-task (write range, modeled cost at b = 1); see
    /// [`HPlan::task_loads`].
    pub(crate) fn task_loads(&self, m: &H2Matrix, adjoint: bool) -> Vec<(Range<usize>, f64)> {
        let s = if adjoint { self.adj(m) } else { self.fwd(m) };
        let prof = s.profile.read().unwrap().clone();
        let costs = model_costs(&s.down_feats, &s.down_fixed, &s.down_per_rhs, prof.as_deref(), 1);
        s.down_tasks.iter().zip(&costs).map(|(t, &c)| (t.dst.clone(), c)).collect()
    }

    /// Execute a slice into a FULL-length `y` (see [`HPlan::execute_slice`]).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn execute_slice(&self, m: &H2Matrix, sl: &H2Slice, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena, exec: &dyn Executor, hot: Option<&Arc<HotCache>>) {
        let s = if sl.adjoint { self.adj(m) } else { self.fwd(m) };
        s.exec_slice(sl, m, alpha, x, y, arena, exec, hot);
    }

    /// Batched variant of [`Self::execute_slice`].
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn execute_multi_slice(&self, m: &H2Matrix, sl: &H2Slice, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, exec: &dyn Executor, rec: Option<&TimingSink>, hot: Option<&Arc<HotCache>>) {
        let s = if sl.adjoint { self.adj(m) } else { self.fwd(m) };
        s.exec_multi_slice(sl, m, alpha, x, y, arena, exec, rec, hot);
    }

    /// See [`HPlan::observe_multi_slice`].
    pub(crate) fn observe_multi_slice(&self, m: &H2Matrix, sl: &H2Slice, sink: &TimingSink, nrhs: usize, out: &mut Vec<Sample>) -> (f64, f64) {
        let s = if sl.adjoint { self.adj(m) } else { self.fwd(m) };
        s.push_samples_slice(sl, sink, nrhs, out);
        s.observe_multi_slice(sl, sink, nrhs)
    }

    /// Re-partition built schedule halves with `profile` costs (atomic swap,
    /// bitwise output-invariant; see [`HPlan::rebalance`]).
    pub fn rebalance(&self, profile: &CostProfile) {
        if !profile.is_usable() {
            return;
        }
        let p = Arc::new(profile.clone());
        *self.profile.lock().unwrap() = Some(p.clone());
        let mut predicted = 0.0;
        if let Some(s) = self.fwd.get() {
            predicted = s.rebalance(&p);
        }
        if let Some(s) = self.adj.get() {
            s.rebalance(&p);
        }
        let mut c = self.calib.lock().unwrap();
        c.source = profile.source.clone();
        c.predicted = predicted;
    }

    /// Timed calibration rounds + least-squares fit + re-balance (see
    /// [`HPlan::calibrate`]).
    pub fn calibrate(&self, m: &H2Matrix, warmup_batches: usize) -> CostProfile {
        let rounds = warmup_batches.max(1);
        let sched = self.fwd(m);
        let sink = TimingSink::new(sched.up_tasks.len() + sched.down_tasks.len());
        let mut arena = Arena::new();
        let mut rng = Rng::new(0xCA11B + 2);
        let x = rng.vector(self.ncols);
        let mut y = vec![0.0; self.nrows];
        // calibrate without a hot cache (model the real decode cost)
        sched.exec(m, false, 1.0, &x, &mut y, &mut arena, &*self.exec, None, None); // warmup
        for _ in 0..rounds {
            sched.exec(m, false, 1.0, &x, &mut y, &mut arena, &*self.exec, Some(&sink), None);
        }
        let mut samples = Vec::new();
        sched.push_samples(&sink, 1, rounds, false, &mut samples);
        let up = sched.up_levels.load();
        let down = sched.down_levels.load();
        let measured = (costmodel::sink_makespan(&up, 0, &sink) + costmodel::sink_makespan(&down, sched.up_tasks.len(), &sink)) / rounds as f64;
        let xm = DMatrix::random(self.ncols, CALIB_RHS, &mut rng);
        let mut ym = DMatrix::zeros(self.nrows, CALIB_RHS);
        sched.exec_multi(m, false, 1.0, &xm, &mut ym, &mut arena, &*self.exec, None, None); // warmup
        sink.reset();
        for _ in 0..rounds {
            sched.exec_multi(m, false, 1.0, &xm, &mut ym, &mut arena, &*self.exec, Some(&sink), None);
        }
        sched.push_samples(&sink, CALIB_RHS, rounds, true, &mut samples);
        let profile = costmodel::fit_pools(&samples, sched.npools).unwrap_or_default();
        self.rebalance(&profile);
        self.calib.lock().unwrap().measured = measured;
        profile
    }

    /// See [`HPlan::timing_slots`] (upward + downward pass tasks).
    pub fn timing_slots(&self, m: &H2Matrix) -> usize {
        let s = self.fwd(m);
        s.up_tasks.len() + s.down_tasks.len()
    }

    /// See [`HPlan::execute_multi_timed`].
    pub fn execute_multi_timed(&self, m: &H2Matrix, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, sink: &TimingSink) {
        assert_eq!(x.nrows(), self.ncols);
        assert_eq!(y.nrows(), self.nrows);
        assert_eq!(x.ncols(), y.ncols());
        let hot = self.hot_cache();
        self.fwd(m).exec_multi(m, false, alpha, x, y, arena, &*self.exec, Some(sink), hot.as_ref());
    }

    /// See [`HPlan::observe_multi`].
    pub fn observe_multi(&self, m: &H2Matrix, sink: &TimingSink, nrhs: usize, out: &mut Vec<Sample>) -> (f64, f64) {
        let sched = self.fwd(m);
        sched.push_samples(sink, nrhs, 1, true, out);
        sched.observe_multi(sink, nrhs)
    }

    /// See [`HPlan::panel_cost_model`].
    pub fn panel_cost_model(&self, m: &H2Matrix) -> Option<(f64, f64)> {
        self.fwd(m).panel_terms()
    }

    /// Aggregate over the schedule halves built so far.
    pub fn stats(&self) -> PlanStats {
        let mut st = PlanStats { decode_kernels: crate::compress::dispatch::kernels_label(), ..PlanStats::default() };
        for sched in [self.fwd.get(), self.adj.get()].into_iter().flatten() {
            st.tasks += sched.up_tasks.len() + sched.down_tasks.len();
            st.max_shards = st.max_shards.max(sched.max_shards.load(Ordering::Relaxed));
            st.scratch_f64 = st.scratch_f64.max(sched.scratch);
            st.coeff_f64 = st.coeff_f64.max(sched.s_len + sched.t_len);
        }
        if let Some(f) = self.fwd.get() {
            st.levels = f.up_level_ids.len() + f.down_level_ids.len();
        }
        if let Some(p) = self.profile.lock().unwrap().as_deref() {
            st.pool_cost_sources = p.pool_source_labels();
        }
        let c = self.calib.lock().unwrap();
        st.cost_source = c.source.clone();
        st.predicted_makespan = c.predicted;
        st.measured_makespan = c.measured;
        st
    }
}
