//! Per-format execution plans: flattened level-ordered schedules plus the
//! zero-allocation executors for single-vector, adjoint and multi-RHS
//! products.
//!
//! Correctness argument (same as the collision-free traversals of §3, made
//! static): clusters of one tree level have pairwise disjoint index ranges,
//! so all tasks of a level may write `y` (or their coefficient slots)
//! concurrently without synchronization; consecutive levels are separated by
//! fork-join barriers, which realises the parent-before-children ordering the
//! recursive traversals obtain implicitly.

use super::arena::Arena;
use super::schedule::{balance, block_cost, default_shards, uni_block_cost, Shard};
use crate::h2::H2Matrix;
use crate::hmatrix::HMatrix;
use crate::la::{blas, DMatrix};
use crate::mvm::{kernels, SharedVec};
use crate::par::ThreadPool;
use crate::uniform::{UniBlock, UniformHMatrix};
use std::ops::Range;
use std::sync::OnceLock;

/// Summary of a built plan (diagnostics / logging).
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanStats {
    /// Flattened tasks over all schedules (forward + adjoint).
    pub tasks: usize,
    /// Barrier-separated levels of the forward schedule.
    pub levels: usize,
    /// Maximum concurrently running shards.
    pub max_shards: usize,
    /// Per-shard kernel scratch (f64 values).
    pub scratch_f64: usize,
    /// Coefficient slots (f64 values, forward + backward).
    pub coeff_f64: usize,
}

/// Balance one level's task ids by their costs, remapping shard-local indices
/// back to schedule-global task ids.
fn balance_level(ids: &[usize], costs: &[f64], scratch: &[usize], nshards: usize) -> Vec<Shard> {
    let local_costs: Vec<f64> = ids.iter().map(|&i| costs[i]).collect();
    let local_scratch: Vec<usize> = ids.iter().map(|&i| scratch[i]).collect();
    let mut shards = balance(&local_costs, &local_scratch, nshards);
    for s in &mut shards {
        for t in &mut s.tasks {
            *t = ids[*t];
        }
    }
    shards
}

fn max_shard_stats(levels: &[Vec<Shard>]) -> (usize, usize) {
    let mut max_shards = 0;
    let mut scratch = 0;
    for level in levels {
        max_shards = max_shards.max(level.len());
        for s in level {
            scratch = scratch.max(s.scratch);
        }
    }
    (max_shards, scratch)
}

// ---------------------------------------------------------------------------
// H-matrix plan
// ---------------------------------------------------------------------------

/// One block row (forward) or block column (adjoint): the full list of leaf
/// blocks writing into one cluster's disjoint range.
struct HTask {
    /// Write range in `y`.
    dst: Range<usize>,
    /// (block id, read range in `x`) per leaf block.
    blocks: Vec<(usize, Range<usize>)>,
}

struct HSchedule {
    tasks: Vec<HTask>,
    /// Execution order: root level first.
    levels: Vec<Vec<Shard>>,
    max_shards: usize,
    scratch: usize,
}

impl HSchedule {
    fn build(m: &HMatrix, adjoint: bool) -> HSchedule {
        let bt = &m.bt;
        let (ct, other_ct, lists) = if adjoint {
            (&bt.col_ct, &bt.row_ct, &bt.col_blocks)
        } else {
            (&bt.row_ct, &bt.col_ct, &bt.row_blocks)
        };
        let mut tasks = Vec::new();
        let mut costs = Vec::new();
        let mut scratch = Vec::new();
        let mut level_ids: Vec<Vec<usize>> = vec![Vec::new(); ct.levels.len()];
        for (tau, blocks) in lists.iter().enumerate() {
            if blocks.is_empty() {
                continue;
            }
            let mut refs = Vec::with_capacity(blocks.len());
            let mut cost = 0.0;
            let mut scr = 0usize;
            for &b in blocks {
                let nd = bt.node(b);
                let src = if adjoint { other_ct.node(nd.row).range() } else { other_ct.node(nd.col).range() };
                let blk = m.blocks[b].as_ref().expect("missing leaf");
                cost += block_cost(blk);
                scr = scr.max(blk.rank());
                refs.push((b, src));
            }
            let id = tasks.len();
            tasks.push(HTask { dst: ct.node(tau).range(), blocks: refs });
            costs.push(cost);
            scratch.push(scr);
            level_ids[ct.node(tau).level].push(id);
        }
        let nshards = default_shards();
        let levels: Vec<Vec<Shard>> = level_ids
            .iter()
            .filter(|ids| !ids.is_empty())
            .map(|ids| balance_level(ids, &costs, &scratch, nshards))
            .collect();
        let (max_shards, scratch) = max_shard_stats(&levels);
        HSchedule { tasks, levels, max_shards, scratch }
    }

    fn exec(&self, m: &HMatrix, adjoint: bool, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        arena.ensure(self.max_shards, self.scratch, 0, 0);
        let (bufs, _, _) = arena.split();
        let yy = SharedVec::new(y);
        let pool = ThreadPool::global();
        for level in &self.levels {
            pool.scope(|s| {
                for (shard, buf) in level.iter().zip(bufs.iter_mut()) {
                    let yy = yy;
                    s.spawn(move |_| {
                        for &ti in &shard.tasks {
                            let task = &self.tasks[ti];
                            // SAFETY: same-level clusters are disjoint; levels
                            // are separated by join barriers (parents first).
                            let yt = unsafe { yy.range_mut(task.dst.clone()) };
                            for (b, src) in &task.blocks {
                                let blk = m.blocks[*b].as_ref().expect("missing leaf");
                                if adjoint {
                                    kernels::apply_block_transposed_scratch(alpha, blk, &x[src.clone()], yt, buf);
                                } else {
                                    kernels::apply_block_scratch(alpha, blk, &x[src.clone()], yt, buf);
                                }
                            }
                        }
                    });
                }
            });
        }
    }

    fn exec_multi(&self, m: &HMatrix, adjoint: bool, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena) {
        let ylen = y.nrows();
        let nrhs = y.ncols();
        arena.ensure(self.max_shards, self.scratch, 0, 0);
        let (bufs, _, _) = arena.split();
        let yy = SharedVec::new(y.data_mut());
        let pool = ThreadPool::global();
        for level in &self.levels {
            pool.scope(|s| {
                for (shard, buf) in level.iter().zip(bufs.iter_mut()) {
                    let yy = yy;
                    s.spawn(move |_| {
                        for &ti in &shard.tasks {
                            let task = &self.tasks[ti];
                            for (b, src) in &task.blocks {
                                let blk = m.blocks[*b].as_ref().expect("missing leaf");
                                for c in 0..nrhs {
                                    // SAFETY: per-column copies of the same
                                    // disjoint range argument.
                                    let yt = unsafe {
                                        yy.range_mut(c * ylen + task.dst.start..c * ylen + task.dst.end)
                                    };
                                    let xc = &x.col(c)[src.clone()];
                                    if adjoint {
                                        kernels::apply_block_transposed_scratch(alpha, blk, xc, yt, buf);
                                    } else {
                                        kernels::apply_block_scratch(alpha, blk, xc, yt, buf);
                                    }
                                }
                            }
                        }
                    });
                }
            });
        }
    }
}

/// Precomputed execution plan for an [`HMatrix`]. The forward and adjoint
/// schedules are independent halves, built on first use — [`HPlan::build`]
/// pre-builds the forward half (the serving hot path), [`HPlan::lazy`]
/// builds nothing until executed (the one-shot dispatch paths).
pub struct HPlan {
    fwd: OnceLock<HSchedule>,
    adj: OnceLock<HSchedule>,
    nrows: usize,
    ncols: usize,
}

impl HPlan {
    pub fn build(m: &HMatrix) -> HPlan {
        let plan = HPlan::lazy(m);
        plan.fwd.get_or_init(|| HSchedule::build(m, false));
        plan
    }

    /// A plan whose schedule halves are built on first execution.
    pub fn lazy(m: &HMatrix) -> HPlan {
        HPlan { fwd: OnceLock::new(), adj: OnceLock::new(), nrows: m.nrows(), ncols: m.ncols() }
    }

    fn fwd(&self, m: &HMatrix) -> &HSchedule {
        self.fwd.get_or_init(|| HSchedule::build(m, false))
    }

    fn adj(&self, m: &HMatrix) -> &HSchedule {
        self.adj.get_or_init(|| HSchedule::build(m, true))
    }

    /// y += alpha · M · x.
    pub fn execute(&self, m: &HMatrix, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        self.fwd(m).exec(m, false, alpha, x, y, arena);
    }

    /// y += alpha · Mᵀ · x.
    pub fn execute_adjoint(&self, m: &HMatrix, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        assert_eq!(x.len(), self.nrows);
        assert_eq!(y.len(), self.ncols);
        self.adj(m).exec(m, true, alpha, x, y, arena);
    }

    /// Y += alpha · M · X (column-major multivectors).
    pub fn execute_multi(&self, m: &HMatrix, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena) {
        assert_eq!(x.nrows(), self.ncols);
        assert_eq!(y.nrows(), self.nrows);
        assert_eq!(x.ncols(), y.ncols());
        self.fwd(m).exec_multi(m, false, alpha, x, y, arena);
    }

    /// Aggregate over the schedule halves built so far.
    pub fn stats(&self) -> PlanStats {
        let mut st = PlanStats::default();
        for sched in [self.fwd.get(), self.adj.get()].into_iter().flatten() {
            st.tasks += sched.tasks.len();
            st.max_shards = st.max_shards.max(sched.max_shards);
            st.scratch_f64 = st.scratch_f64.max(sched.scratch);
        }
        if let Some(f) = self.fwd.get() {
            st.levels = f.levels.len();
        }
        st
    }
}

// ---------------------------------------------------------------------------
// Shared pieces of the uniform / H² schedules
// ---------------------------------------------------------------------------

/// Reference from a coupling block into the flat forward-coefficient buffer.
struct CRef {
    block: usize,
    off: usize,
    len: usize,
}

fn apply_dense_oriented(m_blocks: &[Option<UniBlock>], b: usize, adjoint: bool, alpha: f64, xs: &[f64], yt: &mut [f64]) {
    match m_blocks[b].as_ref() {
        Some(UniBlock::Dense(d)) => {
            if adjoint {
                blas::gemv_transposed(alpha, d, xs, yt);
            } else {
                blas::gemv(alpha, d, xs, yt);
            }
        }
        Some(UniBlock::ZDense(z)) => {
            if adjoint {
                kernels::zgemv_t_blocked(alpha, z, xs, yt);
            } else {
                kernels::zgemv_blocked(alpha, z, xs, yt);
            }
        }
        _ => {}
    }
}

// ---------------------------------------------------------------------------
// Uniform-H plan
// ---------------------------------------------------------------------------

/// Forward-transform task: one input cluster's coefficient slot.
struct CoeffTask {
    cluster: usize,
    src: Range<usize>,
    off: usize,
    len: usize,
}

/// Output-side task: couplings into a local rank buffer, one basis apply,
/// dense blocks straight into `y`.
struct UniRowTask {
    cluster: usize,
    dst: Range<usize>,
    rank: usize,
    couplings: Vec<CRef>,
    dense: Vec<(usize, Range<usize>)>,
}

struct UniSchedule {
    ftasks: Vec<CoeffTask>,
    fshards: Vec<Shard>,
    tasks: Vec<UniRowTask>,
    levels: Vec<Vec<Shard>>,
    s_len: usize,
    max_shards: usize,
    scratch: usize,
}

impl UniSchedule {
    fn build(m: &UniformHMatrix, adjoint: bool) -> UniSchedule {
        let bt = &m.bt;
        let (in_ct, in_basis, out_ct, out_basis, out_lists) = if adjoint {
            (&bt.row_ct, &m.row_basis, &bt.col_ct, &m.col_basis, &bt.col_blocks)
        } else {
            (&bt.col_ct, &m.col_basis, &bt.row_ct, &m.row_basis, &bt.row_blocks)
        };

        // forward coefficient slots, one per input cluster with rank > 0
        let mut s_off = vec![0usize; in_ct.nodes.len()];
        let mut s_len = 0usize;
        let mut ftasks = Vec::new();
        let mut fcosts = Vec::new();
        for (sigma, basis) in in_basis.iter().enumerate() {
            let k = basis.rank();
            s_off[sigma] = s_len;
            if k == 0 {
                continue;
            }
            ftasks.push(CoeffTask { cluster: sigma, src: in_ct.node(sigma).range(), off: s_len, len: k });
            fcosts.push(basis.byte_size() as f64);
            s_len += k;
        }
        let nshards = default_shards();
        let fscratch = vec![0usize; fcosts.len()];
        let fshards = balance(&fcosts, &fscratch, nshards);

        // output-side tasks, level ordered
        let mut tasks = Vec::new();
        let mut costs = Vec::new();
        let mut scratch = Vec::new();
        let mut level_ids: Vec<Vec<usize>> = vec![Vec::new(); out_ct.levels.len()];
        for (tau, blocks) in out_lists.iter().enumerate() {
            if blocks.is_empty() {
                continue;
            }
            let rank = out_basis[tau].rank();
            let mut couplings = Vec::new();
            let mut dense = Vec::new();
            let mut cost = 0.0;
            let mut scr = rank;
            for &b in blocks {
                let nd = bt.node(b);
                let in_cluster = if adjoint { nd.row } else { nd.col };
                match m.blocks[b].as_ref() {
                    Some(UniBlock::Coupling(c)) => {
                        scr = scr.max(rank + c.scratch_len());
                        cost += uni_block_cost(m.blocks[b].as_ref().unwrap());
                        couplings.push(CRef { block: b, off: s_off[in_cluster], len: in_basis[in_cluster].rank() });
                    }
                    Some(_) => {
                        cost += uni_block_cost(m.blocks[b].as_ref().unwrap());
                        let src = if adjoint { bt.row_ct.node(nd.row).range() } else { bt.col_ct.node(nd.col).range() };
                        dense.push((b, src));
                    }
                    None => panic!("missing leaf"),
                }
            }
            if couplings.is_empty() && dense.is_empty() {
                continue;
            }
            if !couplings.is_empty() {
                cost += out_basis[tau].byte_size() as f64;
            }
            let id = tasks.len();
            tasks.push(UniRowTask { cluster: tau, dst: out_ct.node(tau).range(), rank, couplings, dense });
            costs.push(cost);
            scratch.push(scr);
            level_ids[out_ct.node(tau).level].push(id);
        }
        let levels: Vec<Vec<Shard>> = level_ids
            .iter()
            .filter(|ids| !ids.is_empty())
            .map(|ids| balance_level(ids, &costs, &scratch, nshards))
            .collect();
        let (max_shards, scratch) = max_shard_stats(&levels);
        UniSchedule { ftasks, fshards, tasks, levels, s_len, max_shards: max_shards.max(fshards.len()), scratch }
    }

    fn exec(&self, m: &UniformHMatrix, adjoint: bool, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        let (in_basis, out_basis) = if adjoint { (&m.row_basis, &m.col_basis) } else { (&m.col_basis, &m.row_basis) };
        arena.ensure(self.max_shards, self.scratch, self.s_len, 0);
        let (bufs, s_all, _) = arena.split();
        let pool = ThreadPool::global();

        // phase 1: forward transformation s_σ = Bᵀ x|σ (independent slots)
        {
            s_all[..self.s_len].fill(0.0);
            let slots = SharedVec::new(&mut s_all[..self.s_len]);
            pool.scope(|sc| {
                for shard in &self.fshards {
                    let slots = slots;
                    sc.spawn(move |_| {
                        for &ti in &shard.tasks {
                            let t = &self.ftasks[ti];
                            // SAFETY: one task per disjoint slot range.
                            let dst = unsafe { slots.range_mut(t.off..t.off + t.len) };
                            in_basis[t.cluster].apply_transposed(&x[t.src.clone()], dst);
                        }
                    });
                }
            });
        }

        // phase 2: level-ordered output pass
        let sref: &[f64] = &s_all[..self.s_len];
        let yy = SharedVec::new(y);
        for level in &self.levels {
            pool.scope(|sc| {
                for (shard, buf) in level.iter().zip(bufs.iter_mut()) {
                    let yy = yy;
                    sc.spawn(move |_| {
                        for &ti in &shard.tasks {
                            let task = &self.tasks[ti];
                            // SAFETY: same-level clusters are disjoint; levels
                            // are barrier separated.
                            let yt = unsafe { yy.range_mut(task.dst.clone()) };
                            let (tv, cscratch) = buf.split_at_mut(task.rank);
                            tv.fill(0.0);
                            let mut have = false;
                            for cr in &task.couplings {
                                if let Some(UniBlock::Coupling(cm)) = m.blocks[cr.block].as_ref() {
                                    let sv = &sref[cr.off..cr.off + cr.len];
                                    if adjoint {
                                        cm.apply_transposed_add_scratch(sv, tv, cscratch);
                                    } else {
                                        cm.apply_add_scratch(sv, tv, cscratch);
                                    }
                                    have = true;
                                }
                            }
                            if have && task.rank > 0 {
                                for v in tv.iter_mut() {
                                    *v *= alpha;
                                }
                                out_basis[task.cluster].apply_add(tv, yt);
                            }
                            for (b, src) in &task.dense {
                                apply_dense_oriented(&m.blocks, *b, adjoint, alpha, &x[src.clone()], yt);
                            }
                        }
                    });
                }
            });
        }
    }
}

/// Precomputed execution plan for a [`UniformHMatrix`]; schedule halves are
/// built on first use (see [`HPlan`] for the build/lazy distinction).
pub struct UniPlan {
    fwd: OnceLock<UniSchedule>,
    adj: OnceLock<UniSchedule>,
    nrows: usize,
    ncols: usize,
}

impl UniPlan {
    pub fn build(m: &UniformHMatrix) -> UniPlan {
        let plan = UniPlan::lazy(m);
        plan.fwd.get_or_init(|| UniSchedule::build(m, false));
        plan
    }

    /// A plan whose schedule halves are built on first execution.
    pub fn lazy(m: &UniformHMatrix) -> UniPlan {
        UniPlan { fwd: OnceLock::new(), adj: OnceLock::new(), nrows: m.nrows(), ncols: m.ncols() }
    }

    fn fwd(&self, m: &UniformHMatrix) -> &UniSchedule {
        self.fwd.get_or_init(|| UniSchedule::build(m, false))
    }

    fn adj(&self, m: &UniformHMatrix) -> &UniSchedule {
        self.adj.get_or_init(|| UniSchedule::build(m, true))
    }

    /// y += alpha · M · x.
    pub fn execute(&self, m: &UniformHMatrix, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        self.fwd(m).exec(m, false, alpha, x, y, arena);
    }

    /// y += alpha · Mᵀ · x.
    pub fn execute_adjoint(&self, m: &UniformHMatrix, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        assert_eq!(x.len(), self.nrows);
        assert_eq!(y.len(), self.ncols);
        self.adj(m).exec(m, true, alpha, x, y, arena);
    }

    /// Y += alpha · M · X, one schedule pass per column over the reused
    /// coefficient buffers.
    pub fn execute_multi(&self, m: &UniformHMatrix, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena) {
        assert_eq!(x.nrows(), self.ncols);
        assert_eq!(y.nrows(), self.nrows);
        assert_eq!(x.ncols(), y.ncols());
        let sched = self.fwd(m);
        for c in 0..x.ncols() {
            sched.exec(m, false, alpha, x.col(c), y.col_mut(c), arena);
        }
    }

    /// Aggregate over the schedule halves built so far.
    pub fn stats(&self) -> PlanStats {
        let mut st = PlanStats::default();
        for sched in [self.fwd.get(), self.adj.get()].into_iter().flatten() {
            st.tasks += sched.ftasks.len() + sched.tasks.len();
            st.max_shards = st.max_shards.max(sched.max_shards);
            st.scratch_f64 = st.scratch_f64.max(sched.scratch);
            st.coeff_f64 = st.coeff_f64.max(sched.s_len);
        }
        if let Some(f) = self.fwd.get() {
            st.levels = f.levels.len() + 1;
        }
        st
    }
}

// ---------------------------------------------------------------------------
// H² plan
// ---------------------------------------------------------------------------

/// Upward-pass task: one input cluster's coefficient slot, computed from the
/// leaf basis or from already-complete child slots through transfer matrices.
struct UpTask {
    cluster: usize,
    off: usize,
    len: usize,
    leaf: bool,
    src: Range<usize>,
    /// (child cluster id, child slot offset, child rank).
    children: Vec<(usize, usize, usize)>,
}

/// Downward-pass task: couplings into the cluster's backward slot, transfer
/// to child slots (interior) or basis application into `y` (leaf), plus dense
/// blocks.
struct DownTask {
    cluster: usize,
    dst: Range<usize>,
    t_off: usize,
    rank: usize,
    leaf: bool,
    couplings: Vec<CRef>,
    dense: Vec<(usize, Range<usize>)>,
    /// (child cluster id, child slot offset, child rank).
    children: Vec<(usize, usize, usize)>,
}

struct H2Schedule {
    up_tasks: Vec<UpTask>,
    /// Execution order: deepest level first (children before parents).
    up_levels: Vec<Vec<Shard>>,
    down_tasks: Vec<DownTask>,
    /// Execution order: root level first (parents before children).
    down_levels: Vec<Vec<Shard>>,
    s_len: usize,
    t_len: usize,
    max_shards: usize,
    scratch: usize,
}

impl H2Schedule {
    fn build(m: &H2Matrix, adjoint: bool) -> H2Schedule {
        let bt = &m.bt;
        let (in_ct, in_nb, out_ct, out_nb, out_lists) = if adjoint {
            (&bt.row_ct, &m.row_basis, &bt.col_ct, &m.col_basis, &bt.col_blocks)
        } else {
            (&bt.col_ct, &m.col_basis, &bt.row_ct, &m.row_basis, &bt.row_blocks)
        };
        let nshards = default_shards();

        // ---- upward pass over the input tree ----
        let mut s_off = vec![0usize; in_ct.nodes.len()];
        let mut s_len = 0usize;
        for sigma in 0..in_ct.nodes.len() {
            s_off[sigma] = s_len;
            s_len += in_nb.rank[sigma];
        }
        let mut up_tasks = Vec::new();
        let mut up_costs = Vec::new();
        let mut up_levels = Vec::new();
        for lvl in (0..in_ct.levels.len()).rev() {
            let mut ids = Vec::new();
            for &sigma in &in_ct.levels[lvl] {
                let k = in_nb.rank[sigma];
                if k == 0 {
                    continue;
                }
                let nd = in_ct.node(sigma);
                let (children, cost) = if nd.is_leaf() {
                    (Vec::new(), (8 * nd.size() * k) as f64)
                } else {
                    let mut ch = Vec::new();
                    let mut cost = 0.0;
                    for &c in &nd.children {
                        if in_nb.rank[c] == 0 || in_nb.transfer[c].is_none() {
                            continue;
                        }
                        cost += in_nb.transfer[c].as_ref().unwrap().byte_size() as f64;
                        ch.push((c, s_off[c], in_nb.rank[c]));
                    }
                    (ch, cost)
                };
                ids.push(up_tasks.len());
                up_tasks.push(UpTask { cluster: sigma, off: s_off[sigma], len: k, leaf: nd.is_leaf(), src: nd.range(), children });
                up_costs.push(cost);
            }
            if !ids.is_empty() {
                up_levels.push(ids);
            }
        }
        let up_scratch = vec![0usize; up_tasks.len()];
        let up_levels: Vec<Vec<Shard>> =
            up_levels.iter().map(|ids| balance_level(ids, &up_costs, &up_scratch, nshards)).collect();

        // ---- downward pass over the output tree ----
        let mut t_off = vec![0usize; out_ct.nodes.len()];
        let mut t_len = 0usize;
        for tau in 0..out_ct.nodes.len() {
            t_off[tau] = t_len;
            t_len += out_nb.rank[tau];
        }
        let mut down_tasks = Vec::new();
        let mut down_costs = Vec::new();
        let mut down_scratch = Vec::new();
        let mut down_levels = Vec::new();
        for lvl in 0..out_ct.levels.len() {
            let mut ids = Vec::new();
            for &tau in &out_ct.levels[lvl] {
                let rank = out_nb.rank[tau];
                let nd = out_ct.node(tau);
                let mut couplings = Vec::new();
                let mut dense = Vec::new();
                let mut cost = 0.0;
                let mut scr = rank;
                for &b in &out_lists[tau] {
                    let bn = bt.node(b);
                    let in_cluster = if adjoint { bn.row } else { bn.col };
                    match m.blocks[b].as_ref() {
                        Some(UniBlock::Coupling(c)) => {
                            scr = scr.max(rank + c.scratch_len());
                            cost += uni_block_cost(m.blocks[b].as_ref().unwrap());
                            couplings.push(CRef { block: b, off: s_off[in_cluster], len: in_nb.rank[in_cluster] });
                        }
                        Some(_) => {
                            cost += uni_block_cost(m.blocks[b].as_ref().unwrap());
                            let src = if adjoint { bt.row_ct.node(bn.row).range() } else { bt.col_ct.node(bn.col).range() };
                            dense.push((b, src));
                        }
                        None => panic!("missing leaf"),
                    }
                }
                let mut children = Vec::new();
                if !nd.is_leaf() && rank > 0 {
                    for &c in &nd.children {
                        if out_nb.rank[c] == 0 || out_nb.transfer[c].is_none() {
                            continue;
                        }
                        cost += out_nb.transfer[c].as_ref().unwrap().byte_size() as f64;
                        children.push((c, t_off[c], out_nb.rank[c]));
                    }
                }
                if nd.is_leaf() && rank > 0 {
                    cost += (8 * nd.size() * rank) as f64;
                }
                // a task is needed to relay or apply coefficients, or for
                // dense blocks — skip clusters with nothing to do
                if rank == 0 && dense.is_empty() {
                    continue;
                }
                ids.push(down_tasks.len());
                down_tasks.push(DownTask { cluster: tau, dst: nd.range(), t_off: t_off[tau], rank, leaf: nd.is_leaf(), couplings, dense, children });
                down_costs.push(cost);
                down_scratch.push(scr);
            }
            if !ids.is_empty() {
                down_levels.push(ids);
            }
        }
        let down_levels: Vec<Vec<Shard>> =
            down_levels.iter().map(|ids| balance_level(ids, &down_costs, &down_scratch, nshards)).collect();

        let (up_max, _) = max_shard_stats(&up_levels);
        let (down_max, scratch) = max_shard_stats(&down_levels);
        H2Schedule {
            up_tasks,
            up_levels,
            down_tasks,
            down_levels,
            s_len,
            t_len,
            max_shards: up_max.max(down_max),
            scratch,
        }
    }

    fn exec(&self, m: &H2Matrix, adjoint: bool, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        let (in_nb, out_nb) = if adjoint { (&m.row_basis, &m.col_basis) } else { (&m.col_basis, &m.row_basis) };
        arena.ensure(self.max_shards, self.scratch, self.s_len, self.t_len);
        let (bufs, s_all, t_all) = arena.split();
        let pool = ThreadPool::global();

        // upward pass: forward transformation, children before parents
        {
            s_all[..self.s_len].fill(0.0);
            let slots = SharedVec::new(&mut s_all[..self.s_len]);
            for level in &self.up_levels {
                pool.scope(|sc| {
                    for shard in level {
                        let slots = slots;
                        sc.spawn(move |_| {
                            for &ti in &shard.tasks {
                                let t = &self.up_tasks[ti];
                                // SAFETY: one slot per cluster; child slots were
                                // filled in an earlier, already joined level.
                                let dst = unsafe { slots.range_mut(t.off..t.off + t.len) };
                                if t.leaf {
                                    in_nb.leaf_apply_transposed(t.cluster, &x[t.src.clone()], dst);
                                } else {
                                    for &(c, coff, clen) in &t.children {
                                        let sc_child = unsafe { slots.range(coff..coff + clen) };
                                        if let Some(e) = in_nb.transfer[c].as_ref() {
                                            e.apply_transposed_add(sc_child, dst);
                                        }
                                    }
                                }
                            }
                        });
                    }
                });
            }
        }

        // downward pass: couplings + transfer to children + leaf application
        let sref: &[f64] = &s_all[..self.s_len];
        t_all[..self.t_len].fill(0.0);
        let tslots = SharedVec::new(&mut t_all[..self.t_len]);
        let yy = SharedVec::new(y);
        for level in &self.down_levels {
            pool.scope(|sc| {
                for (shard, buf) in level.iter().zip(bufs.iter_mut()) {
                    let yy = yy;
                    let tslots = tslots;
                    sc.spawn(move |_| {
                        for &ti in &shard.tasks {
                            let task = &self.down_tasks[ti];
                            // SAFETY: τ's slot was written only by its parent in
                            // an earlier level; same-level clusters are disjoint.
                            let tv = unsafe { tslots.range_mut(task.t_off..task.t_off + task.rank) };
                            let (sbuf, cscratch) = buf.split_at_mut(task.rank);
                            for cr in &task.couplings {
                                if let Some(UniBlock::Coupling(cm)) = m.blocks[cr.block].as_ref() {
                                    let sv = &sref[cr.off..cr.off + cr.len];
                                    if adjoint {
                                        cm.apply_transposed_add_scratch(sv, tv, cscratch);
                                    } else {
                                        cm.apply_add_scratch(sv, tv, cscratch);
                                    }
                                }
                            }
                            if task.leaf {
                                if task.rank > 0 && tv.iter().any(|&v| v != 0.0) {
                                    for (d, &v) in sbuf.iter_mut().zip(tv.iter()) {
                                        *d = alpha * v;
                                    }
                                    // SAFETY: leaf ranges are disjoint; ancestor
                                    // dense writes happened in earlier levels.
                                    let yt = unsafe { yy.range_mut(task.dst.clone()) };
                                    out_nb.leaf_apply_add(task.cluster, sbuf, yt);
                                }
                            } else {
                                for &(c, ctoff, crank) in &task.children {
                                    // SAFETY: each child has exactly one parent.
                                    let tc = unsafe { tslots.range_mut(ctoff..ctoff + crank) };
                                    if let Some(e) = out_nb.transfer[c].as_ref() {
                                        e.apply_add(tv, tc);
                                    }
                                }
                            }
                            if !task.dense.is_empty() {
                                // SAFETY: same disjointness/barrier argument.
                                let yt = unsafe { yy.range_mut(task.dst.clone()) };
                                for (b, src) in &task.dense {
                                    apply_dense_oriented(&m.blocks, *b, adjoint, alpha, &x[src.clone()], yt);
                                }
                            }
                        }
                    });
                }
            });
        }
    }
}

/// Precomputed execution plan for an [`H2Matrix`]; schedule halves are built
/// on first use (see [`HPlan`] for the build/lazy distinction).
pub struct H2Plan {
    fwd: OnceLock<H2Schedule>,
    adj: OnceLock<H2Schedule>,
    nrows: usize,
    ncols: usize,
}

impl H2Plan {
    pub fn build(m: &H2Matrix) -> H2Plan {
        let plan = H2Plan::lazy(m);
        plan.fwd.get_or_init(|| H2Schedule::build(m, false));
        plan
    }

    /// A plan whose schedule halves are built on first execution.
    pub fn lazy(m: &H2Matrix) -> H2Plan {
        H2Plan { fwd: OnceLock::new(), adj: OnceLock::new(), nrows: m.nrows(), ncols: m.ncols() }
    }

    fn fwd(&self, m: &H2Matrix) -> &H2Schedule {
        self.fwd.get_or_init(|| H2Schedule::build(m, false))
    }

    fn adj(&self, m: &H2Matrix) -> &H2Schedule {
        self.adj.get_or_init(|| H2Schedule::build(m, true))
    }

    /// y += alpha · M · x.
    pub fn execute(&self, m: &H2Matrix, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        self.fwd(m).exec(m, false, alpha, x, y, arena);
    }

    /// y += alpha · Mᵀ · x.
    pub fn execute_adjoint(&self, m: &H2Matrix, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        assert_eq!(x.len(), self.nrows);
        assert_eq!(y.len(), self.ncols);
        self.adj(m).exec(m, true, alpha, x, y, arena);
    }

    /// Y += alpha · M · X, one schedule pass per column over the reused
    /// coefficient buffers.
    pub fn execute_multi(&self, m: &H2Matrix, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena) {
        assert_eq!(x.nrows(), self.ncols);
        assert_eq!(y.nrows(), self.nrows);
        assert_eq!(x.ncols(), y.ncols());
        let sched = self.fwd(m);
        for c in 0..x.ncols() {
            sched.exec(m, false, alpha, x.col(c), y.col_mut(c), arena);
        }
    }

    /// Aggregate over the schedule halves built so far.
    pub fn stats(&self) -> PlanStats {
        let mut st = PlanStats::default();
        for sched in [self.fwd.get(), self.adj.get()].into_iter().flatten() {
            st.tasks += sched.up_tasks.len() + sched.down_tasks.len();
            st.max_shards = st.max_shards.max(sched.max_shards);
            st.scratch_f64 = st.scratch_f64.max(sched.scratch);
            st.coeff_f64 = st.coeff_f64.max(sched.s_len + sched.t_len);
        }
        if let Some(f) = self.fwd.get() {
            st.levels = f.up_levels.len() + f.down_levels.len();
        }
        st
    }
}
