//! Schedule primitives shared by the per-format plan builders: the cost
//! model and the static load balancer.
//!
//! The cost model is deliberately simple: MVM is bandwidth bound (paper §3,
//! Fig. 7), so the cost of applying a leaf block is dominated by the bytes of
//! matrix data streamed plus the vector traffic of its row/column ranges.
//! That estimate is exact enough for static balancing — the imbalance left
//! over is far below the per-task spawn overhead it replaces.

use crate::hmatrix::BlockData;
use crate::uniform::UniBlock;

/// A shard: the subset of one level's tasks executed by a single spawned
/// task, plus its aggregate cost and the scratch it needs.
#[derive(Clone, Debug, Default)]
pub struct Shard {
    /// Indices into the schedule's task array.
    pub tasks: Vec<usize>,
    /// Sum of task costs (model bytes).
    pub cost: f64,
    /// Max scratch length (f64 values) over the shard's tasks.
    pub scratch: usize,
}

/// Pack `costs.len()` tasks into at most `nshards` shards, balancing the
/// total cost per shard: longest-processing-time-first greedy (sort by cost
/// descending, always append to the currently lightest shard; cost ties are
/// broken by task count, so runs of equal — including all-zero, as a
/// degenerate calibrated model can produce for one level — costs spread
/// round-robin instead of collapsing into shard 0). `scratch[i]` is the
/// per-task scratch requirement folded into `Shard::scratch`.
pub fn balance(costs: &[f64], scratch: &[usize], nshards: usize) -> Vec<Shard> {
    let n = costs.len();
    if n == 0 {
        return Vec::new();
    }
    let k = nshards.max(1).min(n);
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| costs[b].partial_cmp(&costs[a]).unwrap_or(std::cmp::Ordering::Equal));
    let mut shards: Vec<Shard> = (0..k).map(|_| Shard::default()).collect();
    for i in order {
        let mut lightest = 0;
        for j in 1..k {
            if (shards[j].cost, shards[j].tasks.len()) < (shards[lightest].cost, shards[lightest].tasks.len()) {
                lightest = j;
            }
        }
        let sh = &mut shards[lightest];
        sh.tasks.push(i);
        sh.cost += costs[i];
        sh.scratch = sh.scratch.max(scratch[i]);
    }
    shards.retain(|s| !s.tasks.is_empty());
    shards
}

/// Balance one level's task ids by their costs, remapping shard-local
/// indices back to schedule-global task ids. `costs`/`scratch` are indexed
/// by global task id. Shared by the plan builders (static costs) and the
/// calibration re-balancer ([`super::costmodel::rebalance_levels`]).
pub fn balance_level(ids: &[usize], costs: &[f64], scratch: &[usize], nshards: usize) -> Vec<Shard> {
    let local_costs: Vec<f64> = ids.iter().map(|&i| costs[i]).collect();
    let local_scratch: Vec<usize> = ids.iter().map(|&i| scratch[i]).collect();
    let mut shards = balance(&local_costs, &local_scratch, nshards);
    for s in &mut shards {
        for t in &mut s.tasks {
            *t = ids[*t];
        }
    }
    shards
}

/// Default shard count: pool workers plus the helping scope thread. This is
/// the per-level bin count of the static backends; the stealing backend
/// multiplies it by [`STEAL_CHUNKS_PER_SLOT`] (see
/// [`super::Executor::shard_count`]).
pub fn default_shards() -> usize {
    crate::par::num_threads() + 1
}

/// Chunking oversubscription for the work-stealing backend: each worker slot
/// is seeded with about this many (LPT-packed, byte-cost-balanced) chunks, so
/// idle slots always find something to steal while per-chunk dispatch
/// overhead stays amortized.
pub const STEAL_CHUNKS_PER_SLOT: usize = 4;

/// Contiguous partition of `n` shards across `k` parts: part `p` gets
/// `part_range(n, k, p)`. Deterministic, so a shard (and thus every task in
/// it) is pinned to the same part on every execution — the affinity the
/// `sharded:K` backend relies on for per-pool arena locality.
pub fn part_range(n: usize, k: usize, p: usize) -> std::ops::Range<usize> {
    let k = k.max(1);
    (p * n / k)..((p + 1) * n / k)
}

/// Model cost of one H-matrix leaf block, split into (matrix bytes, vector
/// bytes per right-hand side). A batch of `b` RHS streams the matrix data
/// once but the vector traffic `b` times, so the cost at batch width `b` is
/// `fixed + b · per_rhs` — the rescaling the multi-RHS schedules balance
/// with.
pub fn block_cost_split(b: &BlockData) -> (f64, f64) {
    (b.byte_size() as f64, (8 * (b.nrows() + b.ncols())) as f64)
}

/// Split model cost of one uniform/H² leaf (coupling or dense block); see
/// [`block_cost_split`]. The single-vector cost is `fixed + per_rhs`.
pub fn uni_block_cost_split(b: &UniBlock) -> (f64, f64) {
    let vec_traffic = match b {
        UniBlock::Dense(m) => 8 * (m.nrows() + m.ncols()),
        UniBlock::ZDense(z) => 8 * (z.nrows + z.ncols),
        UniBlock::Coupling(_) => 0, // coefficient slots, tiny
    };
    (b.byte_size() as f64, vec_traffic as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balance_covers_all_tasks_once() {
        let costs: Vec<f64> = (0..97).map(|i| (i % 13) as f64 + 1.0).collect();
        let scratch = vec![0usize; costs.len()];
        let shards = balance(&costs, &scratch, 8);
        assert!(shards.len() <= 8);
        let mut seen = vec![false; costs.len()];
        for s in &shards {
            for &t in &s.tasks {
                assert!(!seen[t], "task {t} scheduled twice");
                seen[t] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn balance_is_roughly_even() {
        let costs = vec![5.0, 4.0, 3.0, 3.0, 2.0, 2.0, 1.0];
        let scratch = vec![0usize; 7];
        let shards = balance(&costs, &scratch, 2);
        assert_eq!(shards.len(), 2);
        let (a, b) = (shards[0].cost, shards[1].cost);
        // LPT guarantees ≤ 4/3 · OPT for 2 machines on this instance
        assert!((a - b).abs() <= 2.0, "{a} vs {b}");
    }

    #[test]
    fn balance_tracks_scratch_max() {
        let costs = vec![1.0, 1.0, 1.0];
        let scratch = vec![4, 9, 2];
        let shards = balance(&costs, &scratch, 1);
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].scratch, 9);
    }

    #[test]
    fn balance_spreads_equal_and_zero_costs() {
        // all-equal (incl. all-zero) costs must not collapse into one shard:
        // the task-count tie-break keeps every bin populated
        for cost in [0.0, 1.0] {
            let costs = vec![cost; 12];
            let scratch = vec![0usize; 12];
            let shards = balance(&costs, &scratch, 4);
            assert_eq!(shards.len(), 4, "cost {cost}");
            for s in &shards {
                assert_eq!(s.tasks.len(), 3, "cost {cost}");
            }
        }
    }

    #[test]
    fn balance_empty_and_single() {
        assert!(balance(&[], &[], 4).is_empty());
        let shards = balance(&[1.0], &[3], 4);
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].tasks, vec![0]);
    }

    #[test]
    fn part_range_covers_exactly() {
        for n in 0..40usize {
            for k in 1..8usize {
                let mut total = 0;
                for p in 0..k {
                    let r = part_range(n, k, p);
                    assert!(r.end <= n);
                    if p > 0 {
                        assert_eq!(r.start, part_range(n, k, p - 1).end, "gap at n={n} k={k} p={p}");
                    }
                    total += r.len();
                }
                assert_eq!(total, n, "n={n} k={k}");
                assert_eq!(part_range(n, k, k - 1).end, n);
            }
        }
    }
}
