//! Reusable scratch storage for plan execution, plus a per-worker buffer
//! pool for the legacy per-task traversals.
//!
//! [`Arena`] buffers only ever grow ([`Arena::ensure`]), so after the first
//! product on a given plan, steady-state execution performs zero heap
//! allocations.

use std::cell::RefCell;

/// Scratch storage reused across plan executions: per-shard kernel scratch
/// plus flat coefficient buffers for the forward (`s`) and backward (`t`)
/// transform slots of the uniform/H² schedules.
#[derive(Default)]
pub struct Arena {
    shard: Vec<Vec<f64>>,
    s: Vec<f64>,
    t: Vec<f64>,
    /// External-ordering staging buffers (input / output side); capacity is
    /// retained across calls like every other arena buffer.
    xio: Vec<f64>,
    yio: Vec<f64>,
}

impl Arena {
    pub fn new() -> Arena {
        Arena::default()
    }

    /// Grow (never shrink) to at least `nshards` shard buffers of `scratch`
    /// values each, an `s` buffer of `s_len` and a `t` buffer of `t_len`.
    pub fn ensure(&mut self, nshards: usize, scratch: usize, s_len: usize, t_len: usize) {
        if self.shard.len() < nshards {
            self.shard.resize_with(nshards, Vec::new);
        }
        for b in &mut self.shard {
            if b.len() < scratch {
                b.resize(scratch, 0.0);
            }
        }
        if self.s.len() < s_len {
            self.s.resize(s_len, 0.0);
        }
        if self.t.len() < t_len {
            self.t.resize(t_len, 0.0);
        }
    }

    /// Disjoint mutable views of (shard buffers, s slots, t slots).
    pub fn split(&mut self) -> (&mut [Vec<f64>], &mut [f64], &mut [f64]) {
        (&mut self.shard, &mut self.s, &mut self.t)
    }

    /// Take the external-ordering staging buffers out of the arena so they
    /// can be used alongside a plan execution that itself borrows the arena.
    /// Return them with [`Arena::put_io`] — their capacity is what makes the
    /// permutation fold allocation free in steady state.
    pub fn take_io(&mut self) -> (Vec<f64>, Vec<f64>) {
        (std::mem::take(&mut self.xio), std::mem::take(&mut self.yio))
    }

    /// Hand the staging buffers back (pairs with [`Arena::take_io`]).
    pub fn put_io(&mut self, x: Vec<f64>, y: Vec<f64>) {
        self.xio = x;
        self.yio = y;
    }

    /// Currently reserved f64 values (diagnostics).
    pub fn reserved(&self) -> usize {
        self.shard.iter().map(|b| b.len()).sum::<usize>()
            + self.s.len()
            + self.t.len()
            + self.xio.len()
            + self.yio.len()
    }
}

/// A pool of reusable `Vec<f64>` buffers for transient per-task temporaries
/// in the legacy traversals (`chunks`, `atomic`). Free lists are
/// **per worker thread** (the pool's workers are long-lived), so check-out /
/// check-in touch no shared lock — a global mutex here would serialize
/// exactly the fine-grained parallel loops this pool serves. Buffers are
/// recycled with their capacity, so the steady state allocates nothing.
pub struct BufferPool {
    _priv: (),
}

thread_local! {
    static FREE: RefCell<Vec<Vec<f64>>> = const { RefCell::new(Vec::new()) };
}

/// Per-thread bound on pooled buffers — beyond this, returned buffers are
/// dropped (bounds memory under bursty task counts).
const POOL_CAP: usize = 32;

impl BufferPool {
    pub fn global() -> &'static BufferPool {
        static POOL: BufferPool = BufferPool { _priv: () };
        &POOL
    }

    /// Check out a zeroed buffer of exactly `len` values.
    pub fn take(&self, len: usize) -> Vec<f64> {
        let mut v = FREE.with(|f| f.borrow_mut().pop()).unwrap_or_default();
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// Return a buffer to this thread's free list.
    pub fn put(&self, v: Vec<f64>) {
        FREE.with(|f| {
            let mut g = f.borrow_mut();
            if g.len() < POOL_CAP {
                g.push(v);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_only_grows() {
        let mut a = Arena::new();
        a.ensure(4, 16, 100, 50);
        let r = a.reserved();
        assert_eq!(r, 4 * 16 + 100 + 50);
        a.ensure(2, 8, 10, 5); // smaller request: no shrink
        assert_eq!(a.reserved(), r);
        a.ensure(4, 32, 100, 50);
        assert_eq!(a.reserved(), 4 * 32 + 100 + 50);
    }

    #[test]
    fn arena_split_disjoint() {
        let mut a = Arena::new();
        a.ensure(2, 4, 8, 8);
        let (sh, s, t) = a.split();
        sh[0][0] = 1.0;
        s[0] = 2.0;
        t[0] = 3.0;
        assert_eq!(sh[1][0], 0.0);
    }

    #[test]
    fn buffer_pool_recycles_capacity() {
        let pool = BufferPool::global();
        let mut v = pool.take(100);
        v[99] = 7.0;
        let cap = v.capacity();
        pool.put(v);
        // same thread → same free list; the recycled buffer keeps capacity
        let v2 = pool.take(50);
        assert!(v2.capacity() >= cap.min(50));
        assert!(v2.iter().all(|&x| x == 0.0), "buffer not zeroed");
    }
}
