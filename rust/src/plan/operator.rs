//! The format-agnostic operator trait and the planned-operator wrapper.
//!
//! [`HOperator`] is object safe: the coordinator holds `Arc<dyn HOperator>`
//! and serves any hierarchical format, compressed or not. The direct trait
//! impls on the matrix types use the collision-free recursive traversals (or
//! one-shot plans for the batched paths); [`PlannedOperator`] pairs a matrix
//! with its precomputed plan schedules ([`HPlan`]/[`UniPlan`]/[`H2Plan`]) and
//! a reusable arena — the steady-state serving configuration.
//!
//! [`PlannedOperator::with_external_ordering`] folds the
//! [`crate::cluster::ClusterTree`] `to_internal`/`to_external` permutations
//! into the execution as a gather first level and a scatter-add last level
//! over pooled staging buffers, so the serving stack can accept batches in
//! the original (external) point ordering without per-call allocation.
//!
//! The `*_with` constructors pick the plan-execution backend
//! ([`ExecutorKind`]: static LPT, work stealing, or sharded sub-pools); the
//! plain constructors read `HMATC_EXEC`. Results are bitwise identical
//! across backends — only the thread mapping changes.

use super::arena::Arena;
use super::costmodel::{self, CostProfile, Sample, TimingSink};
use super::exec::{H2Plan, HPlan, PlanStats, UniPlan};
use super::executor::ExecutorKind;
use super::partition::{env_shard_count, row_partition, ShardPlan};
use crate::cluster::ClusterTree;
use crate::h2::H2Matrix;
use crate::hmatrix::HMatrix;
use crate::la::DMatrix;
use crate::mvm;
use crate::uniform::UniformHMatrix;
use std::ops::Range;
use std::sync::{Arc, Mutex, OnceLock};

/// A hierarchical matrix operator: the common surface of H, uniform-H and H²
/// matrices (compressed or not) that the serving stack programs against.
pub trait HOperator: Send + Sync {
    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;
    /// Memory footprint of the operator data (effective-bandwidth metrics).
    fn byte_size(&self) -> usize;
    fn format_name(&self) -> &'static str;
    /// y += alpha · M · x (internal ordering).
    fn apply(&self, alpha: f64, x: &[f64], y: &mut [f64]);
    /// y += alpha · Mᵀ · x.
    fn apply_adjoint(&self, alpha: f64, x: &[f64], y: &mut [f64]);
    /// Y += alpha · M · X (column-major multivectors, batched serving path).
    fn apply_multi(&self, alpha: f64, x: &DMatrix, y: &mut DMatrix);
    /// Y += alpha · Mᵀ · X (column-major multivectors). Default: per-column
    /// loop; [`PlannedOperator`] overrides with gemm-shaped plan schedules.
    fn apply_multi_adjoint(&self, alpha: f64, x: &DMatrix, y: &mut DMatrix) {
        assert_eq!(x.ncols(), y.ncols());
        for c in 0..x.ncols() {
            self.apply_adjoint(alpha, x.col(c), y.col_mut(c));
        }
    }

    /// Cumulative `(hits, misses)` of the decode-once hot cache, if the
    /// operator runs with one ([`PlannedOperator::set_hot_cache`]); `None`
    /// when no cache is installed. Serving metrics poll this.
    fn cache_counters(&self) -> Option<(u64, u64)> {
        None
    }
}

impl HOperator for HMatrix {
    fn nrows(&self) -> usize {
        HMatrix::nrows(self)
    }

    fn ncols(&self) -> usize {
        HMatrix::ncols(self)
    }

    fn byte_size(&self) -> usize {
        HMatrix::byte_size(self)
    }

    fn format_name(&self) -> &'static str {
        "H"
    }

    fn apply(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        mvm::mvm(alpha, self, x, y, mvm::MvmAlgorithm::ClusterLists);
    }

    fn apply_adjoint(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        mvm::mvm_transposed(alpha, self, x, y);
    }

    fn apply_multi(&self, alpha: f64, x: &DMatrix, y: &mut DMatrix) {
        mvm::h_mvm_multi(alpha, self, x, y);
    }
}

impl HOperator for UniformHMatrix {
    fn nrows(&self) -> usize {
        UniformHMatrix::nrows(self)
    }

    fn ncols(&self) -> usize {
        UniformHMatrix::ncols(self)
    }

    fn byte_size(&self) -> usize {
        UniformHMatrix::byte_size(self)
    }

    fn format_name(&self) -> &'static str {
        "UH"
    }

    fn apply(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        mvm::uniform_mvm(alpha, self, x, y, mvm::UniMvmAlgorithm::RowWise);
    }

    fn apply_adjoint(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        // one-shot plan (adjoint half only): hot paths hold a PlannedOperator
        let plan = UniPlan::lazy(self);
        let mut arena = Arena::new();
        plan.execute_adjoint(self, alpha, x, y, &mut arena);
    }

    fn apply_multi(&self, alpha: f64, x: &DMatrix, y: &mut DMatrix) {
        // one-shot gemm-shaped plan pass: one traversal for the whole batch.
        // Deliberately NOT cached inside the matrix: UniformHMatrix is Clone
        // and mutable (compress() changes block representations), so an
        // embedded plan could go stale — repeat callers hold a
        // PlannedOperator, which owns plan + arena for the matrix snapshot.
        let plan = UniPlan::lazy(self);
        let mut arena = Arena::new();
        plan.execute_multi(self, alpha, x, y, &mut arena);
    }

    fn apply_multi_adjoint(&self, alpha: f64, x: &DMatrix, y: &mut DMatrix) {
        let plan = UniPlan::lazy(self);
        let mut arena = Arena::new();
        plan.execute_multi_adjoint(self, alpha, x, y, &mut arena);
    }
}

impl HOperator for H2Matrix {
    fn nrows(&self) -> usize {
        H2Matrix::nrows(self)
    }

    fn ncols(&self) -> usize {
        H2Matrix::ncols(self)
    }

    fn byte_size(&self) -> usize {
        H2Matrix::byte_size(self)
    }

    fn format_name(&self) -> &'static str {
        "H2"
    }

    fn apply(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        mvm::h2_mvm(alpha, self, x, y, mvm::H2MvmAlgorithm::RowWise);
    }

    fn apply_adjoint(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        let plan = H2Plan::lazy(self);
        let mut arena = Arena::new();
        plan.execute_adjoint(self, alpha, x, y, &mut arena);
    }

    fn apply_multi(&self, alpha: f64, x: &DMatrix, y: &mut DMatrix) {
        let plan = H2Plan::lazy(self);
        let mut arena = Arena::new();
        plan.execute_multi(self, alpha, x, y, &mut arena);
    }

    fn apply_multi_adjoint(&self, alpha: f64, x: &DMatrix, y: &mut DMatrix) {
        let plan = H2Plan::lazy(self);
        let mut arena = Arena::new();
        plan.execute_multi_adjoint(self, alpha, x, y, &mut arena);
    }
}

pub(crate) enum Inner {
    H { m: Arc<HMatrix>, plan: HPlan },
    Uniform { m: Arc<UniformHMatrix>, plan: UniPlan },
    H2 { m: Arc<H2Matrix>, plan: H2Plan },
}

/// Row/column cluster trees whose permutations are folded into execution.
struct ExtOrder {
    row: Arc<ClusterTree>,
    col: Arc<ClusterTree>,
}

/// A matrix paired with its precomputed execution plan and a reusable scratch
/// arena: single-vector, adjoint and multi-RHS products all run through the
/// flattened schedules with zero steady-state allocation. Multi-RHS products
/// use gemm-shaped panel tasks (one decode of every block for the whole
/// batch).
///
/// Build it **after** compressing the matrix — schedules record block ranks
/// and scratch sizes of the representation they were built from.
pub struct PlannedOperator {
    inner: Arc<Inner>,
    arena: Mutex<Arena>,
    bytes: usize,
    external: Option<ExtOrder>,
    /// `HMATC_SHARDS` row partition, built lazily on first product: `None`
    /// once initialized means the env asked for 1 shard (or was unset) and
    /// products run the whole-plan schedules directly.
    shards: OnceLock<Option<Vec<ShardPlan>>>,
}

impl PlannedOperator {
    /// Backend from `HMATC_EXEC`, LPT costs from `HMATC_COSTS` when it names
    /// a valid profile (see [`ExecutorKind::from_env`] /
    /// [`costmodel::costs_from_env`]). The fully explicit `*_with`
    /// constructors read no environment.
    pub fn from_h(m: Arc<HMatrix>) -> PlannedOperator {
        PlannedOperator::from_h_with(m, ExecutorKind::from_env()).with_env_costs()
    }

    /// Build the plan for the given execution backend — the schedules are
    /// packed for it, so the choice is per operator and fixed at build time.
    pub fn from_h_with(m: Arc<HMatrix>, kind: ExecutorKind) -> PlannedOperator {
        let plan = HPlan::build_with(&m, kind.build());
        let bytes = m.byte_size();
        PlannedOperator::wrap(Inner::H { m, plan }, bytes)
    }

    /// Backend from `HMATC_EXEC`, costs from `HMATC_COSTS` (see
    /// [`PlannedOperator::from_h`]).
    pub fn from_uniform(m: Arc<UniformHMatrix>) -> PlannedOperator {
        PlannedOperator::from_uniform_with(m, ExecutorKind::from_env()).with_env_costs()
    }

    /// Uniform-H plan on the given execution backend.
    pub fn from_uniform_with(m: Arc<UniformHMatrix>, kind: ExecutorKind) -> PlannedOperator {
        let plan = UniPlan::build_with(&m, kind.build());
        let bytes = m.byte_size();
        PlannedOperator::wrap(Inner::Uniform { m, plan }, bytes)
    }

    /// Backend from `HMATC_EXEC`, costs from `HMATC_COSTS` (see
    /// [`PlannedOperator::from_h`]).
    pub fn from_h2(m: Arc<H2Matrix>) -> PlannedOperator {
        PlannedOperator::from_h2_with(m, ExecutorKind::from_env()).with_env_costs()
    }

    /// H² plan on the given execution backend.
    pub fn from_h2_with(m: Arc<H2Matrix>, kind: ExecutorKind) -> PlannedOperator {
        let plan = H2Plan::build_with(&m, kind.build());
        let bytes = m.byte_size();
        PlannedOperator::wrap(Inner::H2 { m, plan }, bytes)
    }

    fn wrap(inner: Inner, bytes: usize) -> PlannedOperator {
        PlannedOperator {
            inner: Arc::new(inner),
            arena: Mutex::new(Arena::new()),
            bytes,
            external: None,
            shards: OnceLock::new(),
        }
    }

    /// Apply the `HMATC_COSTS` profile if the variable names a valid file;
    /// invalid files warn and leave the static costs active.
    fn with_env_costs(self) -> PlannedOperator {
        if let Some(p) = costmodel::costs_from_env() {
            self.rebalance(&p);
        }
        self
    }

    /// Re-run the LPT partitioning of this operator's plan with calibrated
    /// per-task costs and atomically swap in the new schedule. The task
    /// lists (and hence every write range and summation order) are
    /// untouched, so products are **bitwise identical** before and after —
    /// only the task→shard mapping changes. The profile source lands in
    /// [`PlanStats::cost_source`].
    pub fn rebalance(&self, profile: &CostProfile) {
        match &*self.inner {
            Inner::H { plan, .. } => plan.rebalance(profile),
            Inner::Uniform { plan, .. } => plan.rebalance(profile),
            Inner::H2 { plan, .. } => plan.rebalance(profile),
        }
    }

    /// Run `warmup_batches` timed products (single-RHS and batched), fit
    /// per-kernel-class cost coefficients from the per-chunk wall times, and
    /// re-balance the plan with them (`cost_source` becomes `online`).
    /// Returns the fitted profile for saving/inspection.
    pub fn calibrate(&self, warmup_batches: usize) -> CostProfile {
        match &*self.inner {
            Inner::H { m, plan } => plan.calibrate(m, warmup_batches),
            Inner::Uniform { m, plan } => plan.calibrate(m, warmup_batches),
            Inner::H2 { m, plan } => plan.calibrate(m, warmup_batches),
        }
    }

    /// A second operator over the SAME matrix (shared `Arc`) with its own
    /// plan packed for `kind` — the adaptive server's per-request-class
    /// routing builds its narrow-batch backend this way. The decode-once hot
    /// cache (shared `Arc`) and the external-ordering mode are inherited, so
    /// both operators serve bitwise-identical products (executor backends
    /// only change the thread mapping, never the summation order).
    pub fn rebuilt_with(&self, kind: ExecutorKind) -> PlannedOperator {
        let op = match &*self.inner {
            Inner::H { m, .. } => PlannedOperator::from_h_with(m.clone(), kind),
            Inner::Uniform { m, .. } => PlannedOperator::from_uniform_with(m.clone(), kind),
            Inner::H2 { m, .. } => PlannedOperator::from_h2_with(m.clone(), kind),
        };
        op.set_hot_cache(self.hot_cache());
        if self.external.is_some() {
            op.with_external_ordering()
        } else {
            op
        }
    }

    /// Per-task timing slots of the forward plan half — size the
    /// [`TimingSink`] passed to [`Self::apply_multi_timed`] with this.
    pub fn timing_slots(&self) -> usize {
        match &*self.inner {
            Inner::H { m, plan } => plan.timing_slots(m),
            Inner::Uniform { m, plan } => plan.timing_slots(m),
            Inner::H2 { m, plan } => plan.timing_slots(m),
        }
    }

    /// Forward [`HOperator::apply_multi`] with per-chunk wall times recorded
    /// into `sink`. Always runs the whole-plan schedules — never the
    /// `HMATC_SHARDS` in-process partition (the sharded serving tier does
    /// its own per-shard timing) — which is output-equivalent: sharded and
    /// unsharded products are bitwise identical. Unlike [`Self::calibrate`]
    /// this times WITH the live hot cache.
    pub fn apply_multi_timed(&self, alpha: f64, x: &DMatrix, y: &mut DMatrix, sink: &TimingSink) {
        if self.external.is_some() {
            return self.apply_multi_external_rec(false, alpha, x, y, Some(sink));
        }
        let mut arena = self.arena.lock().unwrap();
        self.run_multi_rec(false, alpha, x, y, &mut arena, Some(sink));
    }

    /// Fold a timed forward batch into `out` as fit samples and return the
    /// (predicted, measured) makespan in seconds of the width-`nrhs` packing
    /// it ran on; predicted is 0.0 until a profile is active.
    pub fn observe_multi(&self, sink: &TimingSink, nrhs: usize, out: &mut Vec<Sample>) -> (f64, f64) {
        match &*self.inner {
            Inner::H { m, plan } => plan.observe_multi(m, sink, nrhs, out),
            Inner::Uniform { m, plan } => plan.observe_multi(m, sink, nrhs, out),
            Inner::H2 { m, plan } => plan.observe_multi(m, sink, nrhs, out),
        }
    }

    /// Forward-half (fixed, per-RHS) modeled seconds per batch under the
    /// active profile — the continuous batcher's deadline model. `None`
    /// until a profile is active.
    pub fn panel_cost_model(&self) -> Option<(f64, f64)> {
        match &*self.inner {
            Inner::H { m, plan } => plan.panel_cost_model(m),
            Inner::Uniform { m, plan } => plan.panel_cost_model(m),
            Inner::H2 { m, plan } => plan.panel_cost_model(m),
        }
    }

    /// Name of the execution backend this operator's plan runs on.
    pub fn executor_name(&self) -> String {
        match &*self.inner {
            Inner::H { plan, .. } => plan.executor_name(),
            Inner::Uniform { plan, .. } => plan.executor_name(),
            Inner::H2 { plan, .. } => plan.executor_name(),
        }
    }

    /// Codec-kernel selection the compressed applies run on (also carried in
    /// [`PlanStats::decode_kernels`]), e.g. `"fused+avx2"` — fused decode–FMA
    /// kernels on the runtime-dispatched ISA level.
    pub fn decode_kernels(&self) -> &'static str {
        crate::compress::dispatch::kernels_label()
    }

    /// Accept and produce vectors in *external* (original point) ordering:
    /// the cluster-tree permutations are folded into execution as a gather
    /// first level and a scatter-add last level over pooled staging buffers,
    /// so callers (e.g. [`crate::coordinator::MvmServer`] clients) never run
    /// `ClusterTree::to_internal`/`to_external` themselves.
    pub fn with_external_ordering(mut self) -> PlannedOperator {
        let (row, col) = self.cluster_trees();
        self.external = Some(ExtOrder { row, col });
        self
    }

    /// Row/column cluster trees of the underlying matrix — the partition
    /// seams of [`row_partition`] and the external-ordering permutations.
    pub(crate) fn cluster_trees(&self) -> (Arc<ClusterTree>, Arc<ClusterTree>) {
        match &*self.inner {
            Inner::H { m, .. } => (m.bt.row_ct.clone(), m.bt.col_ct.clone()),
            Inner::Uniform { m, .. } => (m.bt.row_ct.clone(), m.bt.col_ct.clone()),
            Inner::H2 { m, .. } => (m.bt.row_ct.clone(), m.bt.col_ct.clone()),
        }
    }

    /// The shared matrix+plan pair, for [`ShardPlan`]s that slice it.
    pub(crate) fn inner(&self) -> &Arc<Inner> {
        &self.inner
    }

    /// Per-task `(output range, modeled cost)` of the plan's output pass in
    /// the given direction, with the calibrated profile applied when one is
    /// active — the load input of [`row_partition`]'s seam placement.
    pub(crate) fn output_loads(&self, adjoint: bool) -> Vec<(Range<usize>, f64)> {
        match &*self.inner {
            Inner::H { m, plan } => plan.task_loads(m, adjoint),
            Inner::Uniform { m, plan } => plan.task_loads(m, adjoint),
            Inner::H2 { m, plan } => plan.task_loads(m, adjoint),
        }
    }

    /// Whether this operator expects external-ordering vectors.
    pub fn is_external_ordering(&self) -> bool {
        self.external.is_some()
    }

    /// Schedule summary (task/level/shard counts, scratch sizes).
    pub fn plan_stats(&self) -> PlanStats {
        match &*self.inner {
            Inner::H { plan, .. } => plan.stats(),
            Inner::Uniform { plan, .. } => plan.stats(),
            Inner::H2 { plan, .. } => plan.stats(),
        }
    }

    /// Install (or clear with `None`) the decode-once hot-panel cache used by
    /// subsequent products. Plans default to `HMATC_CACHE_BYTES`; this
    /// overrides per operator. Outputs are bitwise identical with or without
    /// a cache (see [`crate::store::hot`]).
    pub fn set_hot_cache(&self, cache: Option<Arc<crate::store::HotCache>>) {
        match &*self.inner {
            Inner::H { plan, .. } => plan.set_hot_cache(cache),
            Inner::Uniform { plan, .. } => plan.set_hot_cache(cache),
            Inner::H2 { plan, .. } => plan.set_hot_cache(cache),
        }
    }

    /// The active hot cache, if any.
    pub fn hot_cache(&self) -> Option<Arc<crate::store::HotCache>> {
        match &*self.inner {
            Inner::H { plan, .. } => plan.hot_cache(),
            Inner::Uniform { plan, .. } => plan.hot_cache(),
            Inner::H2 { plan, .. } => plan.hot_cache(),
        }
    }

    /// Storage residency of the operator's blob bytes: segment count,
    /// anonymous vs memory-mapped footprint, hot-cache occupancy/hit rate
    /// (`hmatc info` / serve logs).
    pub fn residency(&self) -> crate::store::Residency {
        match &*self.inner {
            Inner::H { m, plan } => crate::store::residency_h(m, plan.hot_cache().as_deref()),
            Inner::Uniform { m, plan } => crate::store::residency_uh(m, plan.hot_cache().as_deref()),
            Inner::H2 { m, plan } => crate::store::residency_h2(m, plan.hot_cache().as_deref()),
        }
    }

    /// The `HMATC_SHARDS` partition of this operator, built on first use;
    /// `None` when the env asks for one shard (or partitioning fails, e.g. a
    /// leafless degenerate tree — products then just run unsharded).
    fn env_shards(&self) -> Option<&[ShardPlan]> {
        self.shards
            .get_or_init(|| {
                let count = env_shard_count();
                if count <= 1 {
                    return None;
                }
                let specs = row_partition(self, count).ok()?;
                let kind = ExecutorKind::from_env();
                Some(specs.into_iter().map(|spec| ShardPlan::build(self, spec, kind)).collect())
            })
            .as_deref()
    }

    /// Sequential in-process scatter/gather over the row shards: each shard
    /// computes its seeded full-length partial product, then its owned rows
    /// land in `y` in fixed shard order. Owned ranges are pairwise disjoint,
    /// so later shards seeding from the updated `y` see exactly the rows the
    /// unsharded plan would have left there — bitwise identical output.
    fn run_sharded(&self, shards: &[ShardPlan], adjoint: bool, alpha: f64, x: &[f64], y: &mut [f64]) {
        let mut out = Vec::new();
        for sp in shards {
            let rows = sp.owned(adjoint);
            if rows.is_empty() {
                continue;
            }
            out.clear();
            out.resize(rows.len(), 0.0);
            sp.apply_owned(adjoint, alpha, x, Some(&*y), &mut out);
            y[rows].copy_from_slice(&out);
        }
    }

    fn run_multi_sharded(&self, shards: &[ShardPlan], adjoint: bool, alpha: f64, x: &DMatrix, y: &mut DMatrix) {
        for sp in shards {
            let rows = sp.owned(adjoint);
            if rows.is_empty() {
                continue;
            }
            let mut out = DMatrix::zeros(rows.len(), y.ncols());
            sp.apply_multi_owned(adjoint, alpha, x, Some(&*y), &mut out);
            for c in 0..y.ncols() {
                y.col_mut(c)[rows.clone()].copy_from_slice(out.col(c));
            }
        }
    }

    fn run(&self, adjoint: bool, alpha: f64, x: &[f64], y: &mut [f64], arena: &mut Arena) {
        if let Some(shards) = self.env_shards() {
            return self.run_sharded(shards, adjoint, alpha, x, y);
        }
        match (&*self.inner, adjoint) {
            (Inner::H { m, plan }, false) => plan.execute(m, alpha, x, y, arena),
            (Inner::H { m, plan }, true) => plan.execute_adjoint(m, alpha, x, y, arena),
            (Inner::Uniform { m, plan }, false) => plan.execute(m, alpha, x, y, arena),
            (Inner::Uniform { m, plan }, true) => plan.execute_adjoint(m, alpha, x, y, arena),
            (Inner::H2 { m, plan }, false) => plan.execute(m, alpha, x, y, arena),
            (Inner::H2 { m, plan }, true) => plan.execute_adjoint(m, alpha, x, y, arena),
        }
    }

    /// `rec = Some(sink)` forces the whole-plan timed forward path (see
    /// [`Self::apply_multi_timed`]); `None` is the ordinary dispatch,
    /// including `HMATC_SHARDS` routing.
    fn run_multi_rec(&self, adjoint: bool, alpha: f64, x: &DMatrix, y: &mut DMatrix, arena: &mut Arena, rec: Option<&TimingSink>) {
        if let Some(sink) = rec {
            debug_assert!(!adjoint, "timed products are forward-only");
            return match &*self.inner {
                Inner::H { m, plan } => plan.execute_multi_timed(m, alpha, x, y, arena, sink),
                Inner::Uniform { m, plan } => plan.execute_multi_timed(m, alpha, x, y, arena, sink),
                Inner::H2 { m, plan } => plan.execute_multi_timed(m, alpha, x, y, arena, sink),
            };
        }
        if let Some(shards) = self.env_shards() {
            return self.run_multi_sharded(shards, adjoint, alpha, x, y);
        }
        match (&*self.inner, adjoint) {
            (Inner::H { m, plan }, false) => plan.execute_multi(m, alpha, x, y, arena),
            (Inner::H { m, plan }, true) => plan.execute_multi_adjoint(m, alpha, x, y, arena),
            (Inner::Uniform { m, plan }, false) => plan.execute_multi(m, alpha, x, y, arena),
            (Inner::Uniform { m, plan }, true) => plan.execute_multi_adjoint(m, alpha, x, y, arena),
            (Inner::H2 { m, plan }, false) => plan.execute_multi(m, alpha, x, y, arena),
            (Inner::H2 { m, plan }, true) => plan.execute_multi_adjoint(m, alpha, x, y, arena),
        }
    }

    /// Single-vector product with the permutation fold: gather x into
    /// internal ordering, execute, scatter-add back. `in_perm`/`out_perm`
    /// are the cluster-tree permutations of the input/output side.
    fn apply_external(&self, adjoint: bool, alpha: f64, x: &[f64], y: &mut [f64]) {
        let ext = self.external.as_ref().expect("external ordering not enabled");
        let (in_perm, out_perm) =
            if adjoint { (&ext.row.perm, &ext.col.perm) } else { (&ext.col.perm, &ext.row.perm) };
        assert_eq!(x.len(), in_perm.len());
        assert_eq!(y.len(), out_perm.len());
        let mut arena = self.arena.lock().unwrap();
        let (mut xi, mut yi) = arena.take_io();
        xi.clear();
        xi.resize(x.len(), 0.0);
        yi.clear();
        yi.resize(y.len(), 0.0);
        for (pos, &e) in in_perm.iter().enumerate() {
            xi[pos] = x[e];
        }
        self.run(adjoint, alpha, &xi, &mut yi, &mut arena);
        for (pos, &e) in out_perm.iter().enumerate() {
            y[e] += yi[pos];
        }
        arena.put_io(xi, yi);
    }

    /// Batched product with the permutation fold over pooled panels.
    fn apply_multi_external_rec(&self, adjoint: bool, alpha: f64, x: &DMatrix, y: &mut DMatrix, rec: Option<&TimingSink>) {
        let ext = self.external.as_ref().expect("external ordering not enabled");
        let (in_perm, out_perm) =
            if adjoint { (&ext.row.perm, &ext.col.perm) } else { (&ext.col.perm, &ext.row.perm) };
        let (n_in, n_out, nrhs) = (x.nrows(), y.nrows(), x.ncols());
        assert_eq!(n_in, in_perm.len());
        assert_eq!(n_out, out_perm.len());
        assert_eq!(nrhs, y.ncols());
        let mut arena = self.arena.lock().unwrap();
        let (mut xi, mut yi) = arena.take_io();
        xi.clear();
        xi.resize(n_in * nrhs, 0.0);
        yi.clear();
        yi.resize(n_out * nrhs, 0.0);
        for c in 0..nrhs {
            let xc = x.col(c);
            let dst = &mut xi[c * n_in..(c + 1) * n_in];
            for (pos, &e) in in_perm.iter().enumerate() {
                dst[pos] = xc[e];
            }
        }
        let xm = DMatrix::from_vec(n_in, nrhs, xi);
        let mut ym = DMatrix::from_vec(n_out, nrhs, yi);
        self.run_multi_rec(adjoint, alpha, &xm, &mut ym, &mut arena, rec);
        let yi = ym.into_vec();
        for c in 0..nrhs {
            let yc = y.col_mut(c);
            let src = &yi[c * n_out..(c + 1) * n_out];
            for (pos, &e) in out_perm.iter().enumerate() {
                yc[e] += src[pos];
            }
        }
        arena.put_io(xm.into_vec(), yi);
    }
}

impl HOperator for PlannedOperator {
    fn nrows(&self) -> usize {
        match &*self.inner {
            Inner::H { m, .. } => m.nrows(),
            Inner::Uniform { m, .. } => m.nrows(),
            Inner::H2 { m, .. } => m.nrows(),
        }
    }

    fn ncols(&self) -> usize {
        match &*self.inner {
            Inner::H { m, .. } => m.ncols(),
            Inner::Uniform { m, .. } => m.ncols(),
            Inner::H2 { m, .. } => m.ncols(),
        }
    }

    fn byte_size(&self) -> usize {
        self.bytes
    }

    fn format_name(&self) -> &'static str {
        match &*self.inner {
            Inner::H { .. } => "H+plan",
            Inner::Uniform { .. } => "UH+plan",
            Inner::H2 { .. } => "H2+plan",
        }
    }

    fn apply(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        if self.external.is_some() {
            return self.apply_external(false, alpha, x, y);
        }
        let mut arena = self.arena.lock().unwrap();
        self.run(false, alpha, x, y, &mut arena);
    }

    fn apply_adjoint(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        if self.external.is_some() {
            return self.apply_external(true, alpha, x, y);
        }
        let mut arena = self.arena.lock().unwrap();
        self.run(true, alpha, x, y, &mut arena);
    }

    fn apply_multi(&self, alpha: f64, x: &DMatrix, y: &mut DMatrix) {
        if self.external.is_some() {
            return self.apply_multi_external_rec(false, alpha, x, y, None);
        }
        let mut arena = self.arena.lock().unwrap();
        self.run_multi_rec(false, alpha, x, y, &mut arena, None);
    }

    fn apply_multi_adjoint(&self, alpha: f64, x: &DMatrix, y: &mut DMatrix) {
        if self.external.is_some() {
            return self.apply_multi_external_rec(true, alpha, x, y, None);
        }
        let mut arena = self.arena.lock().unwrap();
        self.run_multi_rec(true, alpha, x, y, &mut arena, None);
    }

    fn cache_counters(&self) -> Option<(u64, u64)> {
        // with an active HMATC_SHARDS partition, shard-local caches (if any
        // were installed) are summed; shards without their own cache fall
        // back to the parent plan's shared cache, counted once below
        if let Some(Some(shards)) = self.shards.get() {
            let mut total: Option<(u64, u64)> = None;
            for sp in shards {
                if let Some((h, m)) = sp.cache_counters() {
                    let t = total.get_or_insert((0, 0));
                    t.0 += h;
                    t.1 += m;
                }
            }
            if total.is_some() {
                return total;
            }
        }
        self.hot_cache().map(|c| c.counters())
    }
}
