//! The format-agnostic operator trait and the planned-operator wrapper.
//!
//! [`HOperator`] is object safe: the coordinator holds `Arc<dyn HOperator>`
//! and serves any hierarchical format, compressed or not. The direct trait
//! impls on the matrix types use the collision-free recursive traversals;
//! [`PlannedOperator`] pairs a matrix with its precomputed plan schedules
//! ([`HPlan`]/[`UniPlan`]/[`H2Plan`]) and a reusable arena — the
//! steady-state serving configuration.

use super::arena::Arena;
use super::exec::{H2Plan, HPlan, PlanStats, UniPlan};
use crate::h2::H2Matrix;
use crate::hmatrix::HMatrix;
use crate::la::DMatrix;
use crate::mvm;
use crate::uniform::UniformHMatrix;
use std::sync::{Arc, Mutex};

/// A hierarchical matrix operator: the common surface of H, uniform-H and H²
/// matrices (compressed or not) that the serving stack programs against.
pub trait HOperator: Send + Sync {
    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;
    /// Memory footprint of the operator data (effective-bandwidth metrics).
    fn byte_size(&self) -> usize;
    fn format_name(&self) -> &'static str;
    /// y += alpha · M · x (internal ordering).
    fn apply(&self, alpha: f64, x: &[f64], y: &mut [f64]);
    /// y += alpha · Mᵀ · x.
    fn apply_adjoint(&self, alpha: f64, x: &[f64], y: &mut [f64]);
    /// Y += alpha · M · X (column-major multivectors, batched serving path).
    fn apply_multi(&self, alpha: f64, x: &DMatrix, y: &mut DMatrix);
}

impl HOperator for HMatrix {
    fn nrows(&self) -> usize {
        HMatrix::nrows(self)
    }

    fn ncols(&self) -> usize {
        HMatrix::ncols(self)
    }

    fn byte_size(&self) -> usize {
        HMatrix::byte_size(self)
    }

    fn format_name(&self) -> &'static str {
        "H"
    }

    fn apply(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        mvm::mvm(alpha, self, x, y, mvm::MvmAlgorithm::ClusterLists);
    }

    fn apply_adjoint(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        mvm::mvm_transposed(alpha, self, x, y);
    }

    fn apply_multi(&self, alpha: f64, x: &DMatrix, y: &mut DMatrix) {
        mvm::h_mvm_multi(alpha, self, x, y);
    }
}

impl HOperator for UniformHMatrix {
    fn nrows(&self) -> usize {
        UniformHMatrix::nrows(self)
    }

    fn ncols(&self) -> usize {
        UniformHMatrix::ncols(self)
    }

    fn byte_size(&self) -> usize {
        UniformHMatrix::byte_size(self)
    }

    fn format_name(&self) -> &'static str {
        "UH"
    }

    fn apply(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        mvm::uniform_mvm(alpha, self, x, y, mvm::UniMvmAlgorithm::RowWise);
    }

    fn apply_adjoint(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        // one-shot plan (adjoint half only): hot paths hold a PlannedOperator
        let plan = UniPlan::lazy(self);
        let mut arena = Arena::new();
        plan.execute_adjoint(self, alpha, x, y, &mut arena);
    }

    fn apply_multi(&self, alpha: f64, x: &DMatrix, y: &mut DMatrix) {
        assert_eq!(x.ncols(), y.ncols());
        for c in 0..x.ncols() {
            mvm::uniform_mvm(alpha, self, x.col(c), y.col_mut(c), mvm::UniMvmAlgorithm::RowWise);
        }
    }
}

impl HOperator for H2Matrix {
    fn nrows(&self) -> usize {
        H2Matrix::nrows(self)
    }

    fn ncols(&self) -> usize {
        H2Matrix::ncols(self)
    }

    fn byte_size(&self) -> usize {
        H2Matrix::byte_size(self)
    }

    fn format_name(&self) -> &'static str {
        "H2"
    }

    fn apply(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        mvm::h2_mvm(alpha, self, x, y, mvm::H2MvmAlgorithm::RowWise);
    }

    fn apply_adjoint(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        let plan = H2Plan::lazy(self);
        let mut arena = Arena::new();
        plan.execute_adjoint(self, alpha, x, y, &mut arena);
    }

    fn apply_multi(&self, alpha: f64, x: &DMatrix, y: &mut DMatrix) {
        assert_eq!(x.ncols(), y.ncols());
        for c in 0..x.ncols() {
            mvm::h2_mvm(alpha, self, x.col(c), y.col_mut(c), mvm::H2MvmAlgorithm::RowWise);
        }
    }
}

enum Inner {
    H { m: Arc<HMatrix>, plan: HPlan },
    Uniform { m: Arc<UniformHMatrix>, plan: UniPlan },
    H2 { m: Arc<H2Matrix>, plan: H2Plan },
}

/// A matrix paired with its precomputed execution plan and a reusable scratch
/// arena: single-vector, adjoint and multi-RHS products all run through the
/// flattened schedules with zero steady-state allocation.
///
/// Build it **after** compressing the matrix — schedules record block ranks
/// and scratch sizes of the representation they were built from.
pub struct PlannedOperator {
    inner: Inner,
    arena: Mutex<Arena>,
    bytes: usize,
}

impl PlannedOperator {
    pub fn from_h(m: Arc<HMatrix>) -> PlannedOperator {
        let plan = HPlan::build(&m);
        let bytes = m.byte_size();
        PlannedOperator { inner: Inner::H { m, plan }, arena: Mutex::new(Arena::new()), bytes }
    }

    pub fn from_uniform(m: Arc<UniformHMatrix>) -> PlannedOperator {
        let plan = UniPlan::build(&m);
        let bytes = m.byte_size();
        PlannedOperator { inner: Inner::Uniform { m, plan }, arena: Mutex::new(Arena::new()), bytes }
    }

    pub fn from_h2(m: Arc<H2Matrix>) -> PlannedOperator {
        let plan = H2Plan::build(&m);
        let bytes = m.byte_size();
        PlannedOperator { inner: Inner::H2 { m, plan }, arena: Mutex::new(Arena::new()), bytes }
    }

    /// Schedule summary (task/level/shard counts, scratch sizes).
    pub fn plan_stats(&self) -> PlanStats {
        match &self.inner {
            Inner::H { plan, .. } => plan.stats(),
            Inner::Uniform { plan, .. } => plan.stats(),
            Inner::H2 { plan, .. } => plan.stats(),
        }
    }
}

impl HOperator for PlannedOperator {
    fn nrows(&self) -> usize {
        match &self.inner {
            Inner::H { m, .. } => m.nrows(),
            Inner::Uniform { m, .. } => m.nrows(),
            Inner::H2 { m, .. } => m.nrows(),
        }
    }

    fn ncols(&self) -> usize {
        match &self.inner {
            Inner::H { m, .. } => m.ncols(),
            Inner::Uniform { m, .. } => m.ncols(),
            Inner::H2 { m, .. } => m.ncols(),
        }
    }

    fn byte_size(&self) -> usize {
        self.bytes
    }

    fn format_name(&self) -> &'static str {
        match &self.inner {
            Inner::H { .. } => "H+plan",
            Inner::Uniform { .. } => "UH+plan",
            Inner::H2 { .. } => "H2+plan",
        }
    }

    fn apply(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        let mut arena = self.arena.lock().unwrap();
        match &self.inner {
            Inner::H { m, plan } => plan.execute(m, alpha, x, y, &mut arena),
            Inner::Uniform { m, plan } => plan.execute(m, alpha, x, y, &mut arena),
            Inner::H2 { m, plan } => plan.execute(m, alpha, x, y, &mut arena),
        }
    }

    fn apply_adjoint(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        let mut arena = self.arena.lock().unwrap();
        match &self.inner {
            Inner::H { m, plan } => plan.execute_adjoint(m, alpha, x, y, &mut arena),
            Inner::Uniform { m, plan } => plan.execute_adjoint(m, alpha, x, y, &mut arena),
            Inner::H2 { m, plan } => plan.execute_adjoint(m, alpha, x, y, &mut arena),
        }
    }

    fn apply_multi(&self, alpha: f64, x: &DMatrix, y: &mut DMatrix) {
        let mut arena = self.arena.lock().unwrap();
        match &self.inner {
            Inner::H { m, plan } => plan.execute_multi(m, alpha, x, y, &mut arena),
            Inner::Uniform { m, plan } => plan.execute_multi(m, alpha, x, y, &mut arena),
            Inner::H2 { m, plan } => plan.execute_multi(m, alpha, x, y, &mut arena),
        }
    }
}
