//! Unified MVM execution-plan layer.
//!
//! The recursive traversals in [`crate::mvm`] re-walk the block tree on every
//! product and allocate per-block temporaries inside the hot loop. Since the
//! paper's central observation is that (compressed) H-MVM is *memory-bandwidth
//! bound*, that bookkeeping directly eats the bandwidth win. This module
//! flattens each format's traversal **once per matrix** into an MvmPlan
//! ([`HPlan`], [`UniPlan`], [`H2Plan`]):
//!
//! * **level-ordered task lists** — tasks at one cluster-tree level have
//!   pairwise disjoint write ranges (clusters of a level partition disjoint
//!   index sets), levels are separated by fork-join barriers, so execution is
//!   collision free without locks or atomics, exactly like the collision-free
//!   traversals of §3 but without the per-call tree walk;
//! * **a cost model + static load balancing** — every task carries an
//!   estimated cost (bytes of matrix data streamed plus vector traffic) and
//!   the tasks of a level are packed into `num_threads + 1` shards by
//!   longest-processing-time-first scheduling ([`schedule::balance`]), so one
//!   spawn per shard replaces one spawn per block;
//! * **a reusable scratch [`Arena`]** — coefficient buffers (forward/backward
//!   transform slots for UH/H²) and per-shard kernel scratch are sized at
//!   plan-build time and reused across calls: steady-state execution performs
//!   zero heap allocations;
//! * **gemm-shaped multi-RHS schedules** — batched products execute the same
//!   level-ordered task lists over contiguous `rows×b` panels: each block's
//!   matrix data (compressed coupling/transfer matrices included) is decoded
//!   once and applied to all `b` columns, per-task costs are rescaled by `b`
//!   for LPT balancing, and per-width shard packings are cached;
//! * **pluggable execution backends** — *how* a level's shards run is an
//!   [`Executor`] chosen per plan ([`ExecutorKind`]: `lpt` static shards,
//!   `steal` work-stealing deques over finer chunks, `sharded:K` sub-pools
//!   with pinned affinity; `HMATC_EXEC` / `--executor`). All backends
//!   produce bitwise-identical results — disjoint write ranges and level
//!   barriers are preserved; only the thread mapping changes.
//! * **row-sharded partitions** — [`partition::row_partition`] splits an
//!   operator's output rows into N disjoint [`ShardPlan`]s along the same
//!   cluster-leaf write boundaries, each owning sliced schedules, its own
//!   executor/arena/hot-cache; the scatter/gather coordinator tier (and
//!   `HMATC_SHARDS=N` in-process routing) reassembles their owned rows in
//!   fixed shard order, bitwise identical to the unsharded plan.
//!
//! The [`HOperator`] trait makes all three formats (compressed or not)
//! interchangeable behind one object-safe interface — the batching
//! [`crate::coordinator::MvmServer`] is generic over `Arc<dyn HOperator>`.
//! [`PlannedOperator`] pairs a matrix with its plan and serves single-vector,
//! multi-RHS (forward and adjoint) products through the same schedules, and
//! can fold the cluster-tree permutations into execution
//! ([`PlannedOperator::with_external_ordering`]) so clients work entirely in
//! the original point ordering.
//!
//! Build plans **after** compressing a matrix: schedules record block ranks
//! and scratch sizes of the representation they were built from.
//!
//! **Cost-model calibration** ([`costmodel`]): the static byte costs can be
//! replaced by coefficients fitted from measured per-chunk wall times —
//! [`PlannedOperator::calibrate`] times a few warmup batches and re-balances
//! in place; `hmatc calibrate` writes the fitted [`CostProfile`] to a
//! versioned JSON file that `HMATC_COSTS` / `--costs` load back.
//! Re-balancing only re-partitions the same task lists, so products stay
//! bitwise identical on every backend; [`PlanStats::cost_source`] records
//! which cost model is active.

pub mod arena;
pub mod costmodel;
pub mod exec;
pub mod executor;
pub mod operator;
pub mod partition;
pub mod schedule;

pub use arena::{Arena, BufferPool};
pub use costmodel::{CostProfile, CostSource, KernelClass, TimingSink};
pub use exec::{H2Plan, HPlan, PlanStats, UniPlan};
pub use executor::{Executor, ExecutorKind, ShardedExec, StaticLptExec, WorkStealingExec};
pub use operator::{HOperator, PlannedOperator};
pub use partition::{env_shard_count, row_partition, ShardPlan, ShardSpec};
