//! Pluggable plan-execution backends behind one [`Executor`] seam.
//!
//! A plan schedule says *what* to run (level-ordered task lists with disjoint
//! write ranges); the executor says *how* a level's tasks are mapped onto
//! threads. All backends preserve the plan's correctness contract — every
//! task runs exactly once per level, levels are fork-join barriers, and
//! concurrent tasks get distinct scratch buffers — so results are **bitwise
//! identical** across backends (each task writes only its own disjoint range,
//! in its own fixed internal order).
//!
//! Three backends ship:
//!
//! * [`StaticLptExec`] (`lpt`) — the baseline: one spawned task per
//!   LPT-packed shard on the global work-sharing pool. Cheapest dispatch;
//!   static balancing only.
//! * [`WorkStealingExec`] (`steal`) — the level's tasks are chunked finer
//!   (≈[`super::schedule::STEAL_CHUNKS_PER_SLOT`] chunks per worker slot,
//!   packed by the same per-task byte costs) and seeded into per-slot
//!   Chase–Lev deques ([`crate::par::StealSet`]); idle slots steal. Absorbs
//!   the *dynamic* imbalance of variable codec decode times that a static
//!   packing cannot see.
//! * [`ShardedExec`] (`sharded:K`) — the level's shards are partitioned
//!   contiguously across `K` sub-pools with pinned shard→pool affinity and
//!   per-shard scratch buffers grouped per pool: the NUMA layout. Each
//!   sub-pool is homed on a NUMA node by [`crate::par::Topology`]
//!   (round-robin across nodes, contiguous core slices within a node) and
//!   its workers pin themselves with `sched_setaffinity` unless `HMATC_PIN=0`
//!   or discovery fell back to the synthetic single node. The per-pool
//!   node ids feed the per-pool cost coefficients
//!   ([`super::costmodel::CostProfile`]) so packing sees each socket's own
//!   decode/stream/flop rates.
//!
//! Selection: [`ExecutorKind::from_env`] reads `HMATC_EXEC`
//! (`lpt|steal|sharded:K`, default `lpt`); the CLI forwards `--executor`.
//! Executors are chosen **per plan** at build time because the shard packing
//! (bin count, chunking) is precomputed into the schedules — steady-state
//! products stay zero-allocation on every backend.

use super::schedule::{default_shards, part_range, Shard, STEAL_CHUNKS_PER_SLOT};
use crate::mvm::SharedSlots;
use crate::par::{StealSet, ThreadPool, Topology};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex, OnceLock};

/// The task body an executor drives: `run(task_id, scratch)`. The caller
/// guarantees same-level tasks write disjoint ranges; the executor guarantees
/// concurrent invocations receive distinct scratch buffers.
pub type TaskFn<'a> = dyn Fn(usize, &mut [f64]) + Sync + 'a;

/// How one barrier-separated level of a plan schedule is executed.
///
/// Contract, relied on for bitwise-identical results across backends:
/// `run_level` invokes `run(t, buf)` exactly once for every task `t` of every
/// shard, does not return before all invocations completed, and never runs
/// two invocations concurrently on the same buffer.
///
/// The same contract is what makes the calibration instrumentation
/// ([`super::costmodel::TimingSink`]) backend-agnostic: the plan layer wraps
/// `run` with a per-chunk timer writing one atomic accumulator slot per task
/// — exactly-once invocation means one sample per task per product, and the
/// barrier means accumulators are only read after all writers finished. An
/// executor must therefore never merge, split or re-issue task invocations.
pub trait Executor: Send + Sync {
    /// Backend name for logs/bench rows (e.g. `"sharded:4"`).
    fn name(&self) -> String;

    /// Upper bound on concurrently running task bodies.
    fn concurrency(&self) -> usize;

    /// How many shards a level's tasks should be packed into for this
    /// backend (LPT bins for the static backends, finer chunks for
    /// stealing). Plan builders call this once at schedule-build time.
    fn shard_count(&self) -> usize;

    /// Execute one level: shards carry indices into the schedule's task
    /// array. `bufs` must hold at least [`Executor::buffers_needed`] entries
    /// (each sized for the worst task).
    fn run_level(&self, shards: &[Shard], bufs: &mut [Vec<f64>], run: &TaskFn);

    /// Scratch buffers required for a schedule whose largest level has
    /// `max_shards` shards. Static backends pin one buffer per shard
    /// (default); the stealing backend overrides with one per worker slot.
    fn buffers_needed(&self, max_shards: usize) -> usize {
        max_shards.max(1)
    }

    /// Number of distinct execution pools. Shard `s` of an `n`-shard level
    /// runs on the pool whose [`part_range`] contains `s`, so this is the
    /// granularity at which per-pool cost coefficients
    /// ([`super::costmodel::CostProfile::pool_coeff`]) apply. Backends with a
    /// single undifferentiated pool report 1.
    fn pool_count(&self) -> usize {
        1
    }

    /// NUMA node hosting pool `p` (sysfs id), when the backend placed it.
    fn pool_node(&self, _p: usize) -> Option<usize> {
        None
    }

    /// Whether pool `p`'s workers currently hold a cpu affinity.
    fn pool_pinned(&self, _p: usize) -> bool {
        false
    }
}

/// Backend selector, parsed from `--executor` / `HMATC_EXEC`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Static LPT shards on the global work-sharing pool (baseline).
    StaticLpt,
    /// Chase–Lev deques with chunked tasks and idle-slot stealing.
    WorkStealing,
    /// K sub-pools with pinned shard→pool affinity.
    Sharded(usize),
}

impl ExecutorKind {
    /// Read `HMATC_EXEC` (`lpt|steal|sharded:K`); unset or invalid values
    /// fall back to [`ExecutorKind::StaticLpt`] (invalid ones with a
    /// warning, so a typo in a job script is visible).
    pub fn from_env() -> ExecutorKind {
        match std::env::var("HMATC_EXEC") {
            Err(_) => ExecutorKind::StaticLpt,
            Ok(s) => s.parse().unwrap_or_else(|e| {
                eprintln!("HMATC_EXEC: {e}; using lpt");
                ExecutorKind::StaticLpt
            }),
        }
    }

    /// Instantiate the backend (sub-pools for `sharded:K` are created once
    /// per `K` and shared process-wide).
    pub fn build(self) -> Arc<dyn Executor> {
        match self {
            ExecutorKind::StaticLpt => Arc::new(StaticLptExec::new()),
            ExecutorKind::WorkStealing => Arc::new(WorkStealingExec::new()),
            ExecutorKind::Sharded(k) => Arc::new(ShardedExec::new(k)),
        }
    }

    /// All kinds at a given shard count (benches, equivalence tests).
    pub fn all(sharded_k: usize) -> [ExecutorKind; 3] {
        [ExecutorKind::StaticLpt, ExecutorKind::WorkStealing, ExecutorKind::Sharded(sharded_k)]
    }
}

impl std::str::FromStr for ExecutorKind {
    type Err = String;

    fn from_str(s: &str) -> Result<ExecutorKind, String> {
        let s = s.to_ascii_lowercase();
        match s.as_str() {
            "lpt" | "static" => Ok(ExecutorKind::StaticLpt),
            "steal" | "ws" => Ok(ExecutorKind::WorkStealing),
            "sharded" => Ok(ExecutorKind::Sharded(2)),
            other => match other.strip_prefix("sharded:") {
                Some(k) => match k.parse::<usize>() {
                    Ok(k) if k >= 1 => Ok(ExecutorKind::Sharded(k)),
                    _ => Err(format!("bad shard count '{k}' (sharded:K, K ≥ 1)")),
                },
                None => Err(format!("unknown executor '{other}' (lpt|steal|sharded:K)")),
            },
        }
    }
}

impl std::fmt::Display for ExecutorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecutorKind::StaticLpt => write!(f, "lpt"),
            ExecutorKind::WorkStealing => write!(f, "steal"),
            ExecutorKind::Sharded(k) => write!(f, "sharded:{k}"),
        }
    }
}

/// Total execution slots of the global pool: its workers plus the helping
/// scope thread (the historical `default_shards`).
fn global_slots() -> usize {
    default_shards()
}

// ---------------------------------------------------------------------------
// StaticLpt — the baseline, extracted unchanged from the pre-seam exec paths
// ---------------------------------------------------------------------------

/// One spawned task per precomputed LPT shard; shard `i` owns `bufs[i]`.
pub struct StaticLptExec {
    slots: usize,
}

impl StaticLptExec {
    pub fn new() -> StaticLptExec {
        StaticLptExec { slots: global_slots() }
    }
}

impl Default for StaticLptExec {
    fn default() -> Self {
        Self::new()
    }
}

impl Executor for StaticLptExec {
    fn name(&self) -> String {
        "lpt".into()
    }

    fn concurrency(&self) -> usize {
        self.slots
    }

    fn shard_count(&self) -> usize {
        self.slots
    }

    fn run_level(&self, shards: &[Shard], bufs: &mut [Vec<f64>], run: &TaskFn) {
        assert!(bufs.len() >= shards.len(), "lpt: {} shards, {} buffers", shards.len(), bufs.len());
        ThreadPool::global().scope(|s| {
            for (shard, buf) in shards.iter().zip(bufs.iter_mut()) {
                s.spawn(move |_| {
                    for &ti in &shard.tasks {
                        run(ti, buf);
                    }
                });
            }
        });
    }
}

// ---------------------------------------------------------------------------
// WorkStealing — chunked tasks on per-slot Chase–Lev deques
// ---------------------------------------------------------------------------

/// Dynamic rebalancing: the level's (finer) chunks are seeded round-robin
/// into per-slot deques; each slot drains its own, then steals. Slot `i`
/// owns `bufs[i]` for whatever chunk it executes.
pub struct WorkStealingExec {
    slots: usize,
    set: Mutex<StealSet>,
}

impl WorkStealingExec {
    pub fn new() -> WorkStealingExec {
        WorkStealingExec { slots: global_slots(), set: Mutex::new(StealSet::new()) }
    }
}

impl Default for WorkStealingExec {
    fn default() -> Self {
        Self::new()
    }
}

impl Executor for WorkStealingExec {
    fn name(&self) -> String {
        "steal".into()
    }

    fn concurrency(&self) -> usize {
        self.slots
    }

    fn shard_count(&self) -> usize {
        self.slots * STEAL_CHUNKS_PER_SLOT
    }

    fn buffers_needed(&self, max_shards: usize) -> usize {
        // chunks outnumber slots by design; any chunk may run on any slot,
        // so one buffer per slot suffices
        self.concurrency().min(max_shards).max(1)
    }

    fn run_level(&self, shards: &[Shard], bufs: &mut [Vec<f64>], run: &TaskFn) {
        if shards.is_empty() {
            return;
        }
        let nslots = self.slots.min(shards.len()).min(bufs.len()).max(1);
        // executions of one plan are serialized by its arena; the lock only
        // guards against two *plans* sharing an executor instance
        let mut set = self.set.lock().unwrap();
        let slots = SharedSlots::new(bufs);
        set.run(ThreadPool::global(), nslots, shards.len(), |slot, chunk| {
            // SAFETY: StealSet never runs two invocations with the same slot
            // id concurrently, and slot < nslots ≤ bufs.len().
            let buf = unsafe { slots.get_mut(slot) };
            for &ti in &shards[chunk].tasks {
                run(ti, buf);
            }
        });
    }
}

// ---------------------------------------------------------------------------
// Sharded — K sub-pools, pinned shard→pool affinity, per-pool arena slices
// ---------------------------------------------------------------------------

/// Sub-pool sets are created once per `K` and shared by every `sharded:K`
/// executor in the process (a pool set owns OS threads).
///
/// Pool `p` gets the `part_range(global_slots(), k, p)` share of the
/// machine's execution slots (so the sum never exceeds the
/// `available_parallelism`-derived total — `K` pools of `ceil(slots/K)`
/// workers used to oversubscribe containers), is homed on the node
/// [`Topology::pool_placement`] assigns, and pins its workers to that
/// placement's cpu slice when pinning is enabled. On the fallback topology
/// the cpu slice is empty and the pools spawn unpinned, exactly as before.
fn sharded_pools(k: usize) -> Arc<Vec<ThreadPool>> {
    static CACHE: OnceLock<Mutex<Vec<(usize, Arc<Vec<ThreadPool>>)>>> = OnceLock::new();
    let mut cache = CACHE.get_or_init(|| Mutex::new(Vec::new())).lock().unwrap();
    if let Some((_, pools)) = cache.iter().find(|(kk, _)| *kk == k) {
        return pools.clone();
    }
    let topo = Topology::get();
    let slots = global_slots();
    let pools = Arc::new(
        (0..k)
            .map(|p| {
                let workers = part_range(slots, k, p).len().max(1);
                let (node, cpus) = topo.pool_placement(k, p);
                let cpus = if topo.pin_enabled() { cpus } else { Vec::new() };
                ThreadPool::with_affinity(workers, node, &cpus)
            })
            .collect::<Vec<_>>(),
    );
    cache.push((k, pools.clone()));
    pools
}

/// The level's shard list is split into K contiguous parts
/// ([`part_range`]); part `p` always runs on pool `p` (pinned affinity) with
/// the matching contiguous slice of the scratch buffers (per-pool arena
/// slice). Within a part, it is the baseline one-task-per-shard dispatch.
pub struct ShardedExec {
    pools: Arc<Vec<ThreadPool>>,
    slots: usize,
}

impl ShardedExec {
    pub fn new(k: usize) -> ShardedExec {
        let k = k.max(1);
        let pools = sharded_pools(k);
        // total slots = the machine share actually spawned (K > cores still
        // oversubscribes minimally: one worker per pool)
        let slots = pools.iter().map(|p| p.num_threads()).sum::<usize>().max(1);
        ShardedExec { pools, slots }
    }

    pub fn k(&self) -> usize {
        self.pools.len()
    }
}

impl Executor for ShardedExec {
    fn name(&self) -> String {
        format!("sharded:{}", self.pools.len())
    }

    fn concurrency(&self) -> usize {
        self.slots
    }

    fn shard_count(&self) -> usize {
        self.slots
    }

    fn pool_count(&self) -> usize {
        self.pools.len()
    }

    fn pool_node(&self, p: usize) -> Option<usize> {
        self.pools.get(p).and_then(|pool| pool.node())
    }

    fn pool_pinned(&self, p: usize) -> bool {
        self.pools.get(p).is_some_and(|pool| pool.is_pinned())
    }

    fn run_level(&self, shards: &[Shard], bufs: &mut [Vec<f64>], run: &TaskFn) {
        if shards.is_empty() {
            return;
        }
        assert!(bufs.len() >= shards.len(), "sharded: {} shards, {} buffers", shards.len(), bufs.len());
        run_parts(&self.pools, shards, &mut bufs[..shards.len()], run);
    }
}

/// Nested-scope fan-out: spawn part `p` into pool `p`, recursing *inside*
/// the scope so all parts are in flight before any barrier wait begins; the
/// scopes then join innermost-first. Each scope's waiter helps only its own
/// pool, so affinity is preserved and help-first waiting keeps this
/// deadlock-free even on zero-worker pools. A panic in an inner pool is
/// caught and re-raised only after this pool's scope has joined, so no scope
/// unwinds while tasks borrowing the stack are still in flight.
fn run_parts(pools: &[ThreadPool], shards: &[Shard], bufs: &mut [Vec<f64>], run: &TaskFn) {
    let Some((pool, rest)) = pools.split_first() else {
        return;
    };
    let k = pools.len();
    let cut = part_range(shards.len(), k, 0).end;
    let (mine, other_shards) = shards.split_at(cut);
    let (my_bufs, other_bufs) = bufs.split_at_mut(cut);
    let mut inner_panic = None;
    pool.scope(|s| {
        for (shard, buf) in mine.iter().zip(my_bufs.iter_mut()) {
            s.spawn(move |_| {
                for &ti in &shard.tasks {
                    run(ti, buf);
                }
            });
        }
        if !rest.is_empty() {
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| run_parts(rest, other_shards, other_bufs, run))) {
                inner_panic = Some(p);
            }
        }
    });
    if let Some(p) = inner_panic {
        std::panic::resume_unwind(p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn shards_of(tasks_per_shard: &[usize]) -> Vec<Shard> {
        let mut next = 0usize;
        tasks_per_shard
            .iter()
            .map(|&n| {
                let tasks: Vec<usize> = (next..next + n).collect();
                next += n;
                Shard { tasks, cost: n as f64, scratch: 4 }
            })
            .collect()
    }

    fn check_executor(exec: &dyn Executor) {
        let shards = shards_of(&[3, 1, 4, 2, 5, 1, 1, 7]);
        let ntasks = 24;
        let mut bufs: Vec<Vec<f64>> = (0..exec.buffers_needed(shards.len())).map(|_| vec![0.0; 4]).collect();
        let hits: Vec<AtomicUsize> = (0..ntasks).map(|_| AtomicUsize::new(0)).collect();
        exec.run_level(&shards, &mut bufs, &|ti, buf| {
            assert_eq!(buf.len(), 4, "scratch buffer not sized");
            buf[0] += 1.0; // scratch is writable and private
            hits[ti].fetch_add(1, Ordering::Relaxed);
        });
        for (t, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {t} on {}", exec.name());
        }
        // empty level is a no-op
        exec.run_level(&[], &mut bufs, &|_, _| panic!("ran a task of an empty level"));
    }

    #[test]
    fn all_backends_run_each_task_once() {
        check_executor(&StaticLptExec::new());
        check_executor(&WorkStealingExec::new());
        check_executor(&ShardedExec::new(1));
        check_executor(&ShardedExec::new(3));
    }

    #[test]
    fn kind_parsing_round_trips() {
        for (s, k) in [
            ("lpt", ExecutorKind::StaticLpt),
            ("steal", ExecutorKind::WorkStealing),
            ("sharded:2", ExecutorKind::Sharded(2)),
            ("sharded:16", ExecutorKind::Sharded(16)),
        ] {
            assert_eq!(s.parse::<ExecutorKind>().unwrap(), k);
            assert_eq!(k.to_string(), s);
        }
        assert_eq!("sharded".parse::<ExecutorKind>().unwrap(), ExecutorKind::Sharded(2));
        assert!("sharded:0".parse::<ExecutorKind>().is_err());
        assert!("bogus".parse::<ExecutorKind>().is_err());
    }

    #[test]
    fn sharded_exposes_pools_and_never_oversubscribes() {
        let e = ShardedExec::new(3);
        assert_eq!(e.pool_count(), 3);
        // total workers never exceed the machine share (satellite: K pools of
        // ceil(slots/K) used to spawn up to K-1 extra threads)
        assert!(e.concurrency() <= global_slots().max(3), "{} slots for {} global", e.concurrency(), global_slots());
        // every pool reports a home node on any topology (real or fallback)
        for p in 0..e.pool_count() {
            assert!(e.pool_node(p).is_some() || Topology::get().num_nodes() == 0);
        }
        assert_eq!(e.pool_node(99), None);
        assert!(!e.pool_pinned(99));
        // single-pool backends report the trait defaults
        let lpt = StaticLptExec::new();
        assert_eq!(lpt.pool_count(), 1);
        assert_eq!(lpt.pool_node(0), None);
        assert!(!lpt.pool_pinned(0));
    }

    #[test]
    fn stealing_needs_fewer_buffers_than_chunks() {
        let e = WorkStealingExec::new();
        assert!(e.shard_count() >= e.concurrency() * STEAL_CHUNKS_PER_SLOT);
        assert_eq!(e.buffers_needed(1000), e.concurrency());
        assert_eq!(e.buffers_needed(1), 1);
    }
}
