//! Measurement-driven cost-model calibration for the plan schedules.
//!
//! The static LPT packings weight tasks with hand-tuned byte costs
//! ([`super::schedule::block_cost_split`]). That model is blind to the fact
//! that a byte of AFLP-4 decode, a byte of dense FP64 stream and a byte of
//! coupling data do not cost the same wall time — which is exactly where the
//! predicted-vs-achieved throughput gap on skewed block-size distributions
//! comes from. This module closes the loop:
//!
//! 1. **Instrumentation** — plan executions can be timed per chunk
//!    ([`TimingSink`]: one atomic nanosecond accumulator per task, written by
//!    whichever executor slot ran the chunk, read back once the level
//!    barrier has joined; the slots are preallocated, so steady-state timed
//!    execution allocates nothing).
//! 2. **Fitting** — the recorded `(features, batch width, seconds)` samples
//!    ([`Sample`]) are fitted by least squares ([`fit`]) to per-kernel-class
//!    coefficients ([`KernelClass`]): decode seconds-per-byte per
//!    `(codec, width)`, uncompressed-stream seconds-per-byte, dense and
//!    low-rank seconds-per-flop, and the panel-width scaling of the vector
//!    traffic (the flop/vector terms are multiplied by the batch width, the
//!    matrix-stream terms are not — matrix data is decoded once per batch).
//! 3. **Re-balancing** — [`rebalance_levels`] re-runs the LPT packing with
//!    the calibrated per-task costs and keeps, per level, whichever packing
//!    (incumbent or candidate) has the smaller modeled makespan, so a
//!    calibrated plan never models worse than the packing it replaces. The
//!    task list itself is untouched — only the task→shard partition changes —
//!    which is why re-balancing is bitwise output-invariant on every backend.
//!
//! Profiles serialize to a versioned JSON document (`hmatc calibrate --out
//! costs.json`) and load through `HMATC_COSTS` / `--costs`; hostile inputs
//! (truncated files, NaN or negative coefficients, unknown kernel-class
//! keys, version mismatches) are rejected with errors — never panics — and
//! the plan falls back to the static costs.

use super::schedule::{balance_level, Shard};
use crate::compress::{Blob, CodecParams};
use crate::h2::TransferMat;
use crate::hmatrix::BlockData;
use crate::uniform::{BasisData, ClusterBasis, CouplingMat, UniBlock};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Version stamped into (and required from) profile JSON documents.
pub const PROFILE_VERSION: u32 = 1;

/// Codec family of a decode kernel class (the byte width is separate: each
/// `(family, width)` pair has its own dispatch kernel and its own decode
/// rate).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum CodecFamily {
    Aflp,
    Fpx32,
    Fpx64,
}

impl CodecFamily {
    pub fn name(self) -> &'static str {
        match self {
            CodecFamily::Aflp => "aflp",
            CodecFamily::Fpx32 => "fpx32",
            CodecFamily::Fpx64 => "fpx64",
        }
    }
}

/// One kernel class of the calibrated cost model. A task's model cost is
/// `Σ coeff(class) · amount · (nrhs if the class scales with the batch)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum KernelClass {
    /// Compressed payload bytes decoded by the `(codec, width)` dispatch
    /// kernel. Amount: blob payload bytes. Streamed once per batch.
    Decode(CodecFamily, u8),
    /// Uncompressed matrix bytes streamed from memory (dense blocks,
    /// low-rank factors, plain couplings/bases). Once per batch.
    MatBytes,
    /// Dense-kernel flops (gemv/gemm on dense or ZDense blocks). Per RHS.
    DenseFlop,
    /// Low-rank-shaped flops (factor, coupling, transfer and basis applies).
    /// Per RHS.
    LowRankFlop,
    /// Vector/panel traffic bytes — the panel-width scaling term. Per RHS.
    PanelVec,
    /// Residency feature of the storage tier: compressed payload bytes
    /// resolved from a *mapped* segment rather than anonymous memory.
    /// Additive on top of the decode classes, so calibration can price a
    /// cold-mapped decode (page-in) differently from a hot one. Amount:
    /// mapped payload bytes. Once per batch.
    MappedBytes,
}

impl KernelClass {
    /// Whether the class amount is multiplied by the batch width: matrix
    /// data (compressed or not) is streamed once per batch; flops and vector
    /// traffic scale with it.
    pub fn scales_with_rhs(self) -> bool {
        !matches!(self, KernelClass::Decode(_, _) | KernelClass::MatBytes | KernelClass::MappedBytes)
    }

    /// Stable JSON key, e.g. `decode:aflp:4`, `dense_flop`.
    pub fn key(self) -> String {
        match self {
            KernelClass::Decode(fam, w) => format!("decode:{}:{w}", fam.name()),
            KernelClass::MatBytes => "mat_bytes".to_string(),
            KernelClass::DenseFlop => "dense_flop".to_string(),
            KernelClass::LowRankFlop => "lowrank_flop".to_string(),
            KernelClass::PanelVec => "panel_vec".to_string(),
            KernelClass::MappedBytes => "mapped_bytes".to_string(),
        }
    }

    /// Parse a JSON key back into a class; unknown keys are errors (a
    /// profile written by a different model version must not be silently
    /// half-applied).
    pub fn parse(key: &str) -> Result<KernelClass, String> {
        match key {
            "mat_bytes" => return Ok(KernelClass::MatBytes),
            "dense_flop" => return Ok(KernelClass::DenseFlop),
            "lowrank_flop" => return Ok(KernelClass::LowRankFlop),
            "panel_vec" => return Ok(KernelClass::PanelVec),
            "mapped_bytes" => return Ok(KernelClass::MappedBytes),
            _ => {}
        }
        let rest = key.strip_prefix("decode:").ok_or_else(|| format!("unknown kernel class '{key}'"))?;
        let (fam, w) = rest.split_once(':').ok_or_else(|| format!("bad decode class '{key}' (decode:<codec>:<width>)"))?;
        let fam = match fam {
            "aflp" => CodecFamily::Aflp,
            "fpx32" => CodecFamily::Fpx32,
            "fpx64" => CodecFamily::Fpx64,
            other => return Err(format!("unknown codec family '{other}' in '{key}'")),
        };
        let w: u8 = w.parse().map_err(|_| format!("bad byte width in '{key}'"))?;
        if w == 0 || w > 8 {
            return Err(format!("byte width {w} out of range in '{key}'"));
        }
        Ok(KernelClass::Decode(fam, w))
    }
}

/// Per-task feature vector: amount per kernel class, built once at plan
/// (re)build time by walking the task's blocks.
#[derive(Clone, Debug, Default)]
pub struct TaskFeats {
    terms: Vec<(KernelClass, f64)>,
}

impl TaskFeats {
    /// Accumulate `amount` onto `class`.
    pub fn add(&mut self, class: KernelClass, amount: f64) {
        if amount == 0.0 {
            return;
        }
        match self.terms.iter_mut().find(|(c, _)| *c == class) {
            Some((_, a)) => *a += amount,
            None => self.terms.push((class, amount)),
        }
    }

    /// Accumulate the decode class of a compressed blob (payload bytes).
    pub fn add_blob(&mut self, blob: &Blob) {
        let class = match blob.params {
            CodecParams::Aflp { bytes_per, .. } => KernelClass::Decode(CodecFamily::Aflp, bytes_per),
            CodecParams::Fpx32 { bytes_per } => KernelClass::Decode(CodecFamily::Fpx32, bytes_per),
            CodecParams::Fpx64 { bytes_per } => KernelClass::Decode(CodecFamily::Fpx64, bytes_per),
            CodecParams::Zero => return,
        };
        self.add(class, blob.bytes.len() as f64);
        if blob.bytes.is_mapped() {
            self.add(KernelClass::MappedBytes, blob.bytes.len() as f64);
        }
    }

    /// Fold another feature vector into this one.
    pub fn merge(&mut self, other: &TaskFeats) {
        for &(c, a) in &other.terms {
            self.add(c, a);
        }
    }

    /// The accumulated `(class, amount)` terms.
    pub fn terms(&self) -> &[(KernelClass, f64)] {
        &self.terms
    }
}

// ---------------------------------------------------------------------------
// Feature extraction per block kind
// ---------------------------------------------------------------------------

/// Features of one H-matrix leaf block (matches the kernels
/// `apply_block_scratch` dispatches to).
pub fn block_feats(b: &BlockData) -> TaskFeats {
    let (m, n) = (b.nrows(), b.ncols());
    let mut f = TaskFeats::default();
    f.add(KernelClass::PanelVec, (8 * (m + n)) as f64);
    match b {
        BlockData::Dense(d) => {
            f.add(KernelClass::MatBytes, d.byte_size() as f64);
            f.add(KernelClass::DenseFlop, (2 * m * n) as f64);
        }
        BlockData::LowRank(lr) => {
            f.add(KernelClass::MatBytes, lr.byte_size() as f64);
            f.add(KernelClass::LowRankFlop, (2 * lr.rank() * (m + n)) as f64);
        }
        BlockData::ZDense(z) => {
            f.add_blob(&z.blob);
            f.add(KernelClass::DenseFlop, (2 * m * n) as f64);
        }
        BlockData::ZLowRank(z) => {
            f.add_blob(&z.u);
            f.add_blob(&z.v);
            f.add(KernelClass::LowRankFlop, (2 * z.rank * (m + n)) as f64);
        }
        BlockData::ZLowRankValr(z) => {
            for c in z.wcols.iter().chain(z.xcols.iter()) {
                f.add_blob(c);
            }
            f.add(KernelClass::LowRankFlop, (2 * z.rank() * (m + n)) as f64);
        }
    }
    f
}

/// Features of one coupling matrix apply (rank-space product).
pub fn coupling_feats(c: &CouplingMat) -> TaskFeats {
    let mut f = TaskFeats::default();
    match c {
        CouplingMat::Plain(m) => {
            f.add(KernelClass::MatBytes, m.byte_size() as f64);
            f.add(KernelClass::LowRankFlop, (2 * m.nrows() * m.ncols()) as f64);
        }
        CouplingMat::Z(z) => {
            f.add_blob(&z.blob);
            f.add(KernelClass::LowRankFlop, (2 * z.nrows * z.ncols) as f64);
        }
        CouplingMat::SepPlain { sr, sc } => {
            f.add(KernelClass::MatBytes, (sr.byte_size() + sc.byte_size()) as f64);
            f.add(KernelClass::LowRankFlop, (2 * (sr.nrows() * sr.ncols() + sc.nrows() * sc.ncols())) as f64);
        }
        CouplingMat::SepZ { sr, sc } => {
            f.add_blob(&sr.blob);
            f.add_blob(&sc.blob);
            f.add(KernelClass::LowRankFlop, (2 * (sr.nrows * sr.ncols + sc.nrows * sc.ncols)) as f64);
        }
    }
    f
}

/// Features of one basis-matrix apply (forward or backward transform slot).
pub fn basis_data_feats(d: &BasisData) -> TaskFeats {
    let mut f = TaskFeats::default();
    let (nrows, rank) = match d {
        BasisData::Plain(w) => (w.nrows(), w.ncols()),
        BasisData::Z { nrows, ncols, .. } => (*nrows, *ncols),
        BasisData::Valr(z) => (z.nrows, z.rank()),
    };
    f.add(KernelClass::PanelVec, (8 * (nrows + rank)) as f64);
    f.add(KernelClass::LowRankFlop, (2 * nrows * rank) as f64);
    match d {
        BasisData::Plain(w) => f.add(KernelClass::MatBytes, w.byte_size() as f64),
        BasisData::Z { blob, .. } => f.add_blob(blob),
        BasisData::Valr(z) => {
            for c in &z.wcols {
                f.add_blob(c);
            }
        }
    }
    f
}

/// Features of one cluster-basis apply.
pub fn basis_feats(b: &ClusterBasis) -> TaskFeats {
    basis_data_feats(&b.data)
}

/// Features of one transfer-matrix apply (H² up/down relays).
pub fn transfer_feats(t: &TransferMat) -> TaskFeats {
    let mut f = TaskFeats::default();
    f.add(KernelClass::PanelVec, (8 * (t.nrows() + t.ncols())) as f64);
    f.add(KernelClass::LowRankFlop, (2 * t.nrows() * t.ncols()) as f64);
    match t {
        TransferMat::Plain(m) => f.add(KernelClass::MatBytes, m.byte_size() as f64),
        TransferMat::Z { blob, .. } => f.add_blob(blob),
    }
    f
}

/// Features of one uniform/H² leaf block (coupling or dense).
pub fn uni_block_feats(b: &UniBlock) -> TaskFeats {
    match b {
        UniBlock::Coupling(c) => coupling_feats(c),
        UniBlock::Dense(d) => {
            let mut f = TaskFeats::default();
            f.add(KernelClass::PanelVec, (8 * (d.nrows() + d.ncols())) as f64);
            f.add(KernelClass::MatBytes, d.byte_size() as f64);
            f.add(KernelClass::DenseFlop, (2 * d.nrows() * d.ncols()) as f64);
            f
        }
        UniBlock::ZDense(z) => {
            let mut f = TaskFeats::default();
            f.add(KernelClass::PanelVec, (8 * (z.nrows + z.ncols)) as f64);
            f.add_blob(&z.blob);
            f.add(KernelClass::DenseFlop, (2 * z.nrows * z.ncols) as f64);
            f
        }
    }
}

// ---------------------------------------------------------------------------
// Cost profile
// ---------------------------------------------------------------------------

/// Where a plan's active LPT costs came from (recorded in
/// [`super::PlanStats::cost_source`] and bench rows).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum CostSource {
    /// The hand-tuned byte model of [`super::schedule`].
    #[default]
    Static,
    /// A profile loaded from a file (`HMATC_COSTS` / `--costs`).
    Calibrated(String),
    /// A profile fitted in-process by `calibrate()`.
    Online,
}

impl std::fmt::Display for CostSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CostSource::Static => write!(f, "static"),
            CostSource::Calibrated(path) => write!(f, "calibrated({path})"),
            CostSource::Online => write!(f, "online"),
        }
    }
}

/// Fitted per-kernel-class coefficients (seconds per unit amount), plus the
/// provenance the plan layer reports. The serialized form carries only the
/// version and the coefficients.
#[derive(Clone, Debug, Default)]
pub struct CostProfile {
    coeffs: BTreeMap<KernelClass, f64>,
    /// Provenance (not serialized — derived from how the profile was made).
    pub source: CostSource,
}

impl CostProfile {
    /// Build a profile from explicit coefficients (tests, synthetic models).
    pub fn from_coeffs(pairs: &[(KernelClass, f64)]) -> CostProfile {
        CostProfile { coeffs: pairs.iter().copied().collect(), source: CostSource::Online }
    }

    /// The fitted coefficients.
    pub fn coeffs(&self) -> &BTreeMap<KernelClass, f64> {
        &self.coeffs
    }

    /// A profile is usable for re-balancing only if it has at least one
    /// strictly positive, finite coefficient — an all-zero fit (e.g. from a
    /// clock with too little resolution) carries no load-balance signal.
    pub fn is_usable(&self) -> bool {
        usable_values(self.coeffs.values())
    }

    fn coeff(&self, class: KernelClass) -> f64 {
        if let Some(v) = self.coeffs.get(&class) {
            return *v;
        }
        // a decode width the fit never saw: use the mean decode rate, else
        // the uncompressed stream rate — bytes are bytes to first order
        if let KernelClass::Decode(_, _) = class {
            let dec: Vec<f64> = self.coeffs.iter().filter(|(c, _)| matches!(c, KernelClass::Decode(_, _))).map(|(_, v)| *v).collect();
            if !dec.is_empty() {
                return dec.iter().sum::<f64>() / dec.len() as f64;
            }
            return self.coeffs.get(&KernelClass::MatBytes).copied().unwrap_or(0.0);
        }
        0.0
    }

    /// Modeled seconds of one task at batch width `nrhs`.
    pub fn cost(&self, feats: &TaskFeats, nrhs: usize) -> f64 {
        feats.terms().iter().map(|&(c, a)| self.coeff(c) * a * if c.scales_with_rhs() { nrhs as f64 } else { 1.0 }).sum()
    }

    /// Serialize to the versioned profile document.
    pub fn to_json(&self) -> Json {
        let coeffs = Json::Obj(self.coeffs.iter().map(|(c, v)| (c.key(), Json::Num(*v))).collect());
        Json::obj(vec![("version", Json::Num(PROFILE_VERSION as f64)), ("kind", "hmatc cost profile".into()), ("coeffs", coeffs)])
    }

    /// Parse and validate a profile document. Rejects (with errors, not
    /// panics): version mismatches, unknown kernel-class keys, and NaN /
    /// infinite / negative coefficients.
    pub fn from_json(doc: &Json) -> Result<CostProfile, String> {
        let version = doc.get("version").and_then(Json::as_f64).ok_or("missing numeric 'version' field")?;
        if version != PROFILE_VERSION as f64 {
            return Err(format!("profile version {version} != supported {PROFILE_VERSION}"));
        }
        if let Some(kind) = doc.get("kind") {
            if kind.as_str() != Some("hmatc cost profile") {
                return Err("'kind' is not 'hmatc cost profile'".to_string());
            }
        }
        let coeffs = match doc.get("coeffs") {
            Some(Json::Obj(m)) => m,
            _ => return Err("missing 'coeffs' object".to_string()),
        };
        let mut out = BTreeMap::new();
        for (k, v) in coeffs {
            let class = KernelClass::parse(k)?;
            let val = v.as_f64().ok_or_else(|| format!("coefficient '{k}' is not a number"))?;
            if !val.is_finite() || val < 0.0 {
                return Err(format!("coefficient '{k}' = {val} is not finite and non-negative"));
            }
            out.insert(class, val);
        }
        Ok(CostProfile { coeffs: out, source: CostSource::Online })
    }

    /// Parse a profile from JSON text.
    pub fn parse(text: &str) -> Result<CostProfile, String> {
        CostProfile::from_json(&Json::parse(text)?)
    }

    /// Load (and validate) a profile file; the result's source is
    /// `calibrated(<path>)`.
    pub fn load(path: &str) -> Result<CostProfile, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("cannot read: {e}"))?;
        let mut p = CostProfile::parse(&text)?;
        p.source = CostSource::Calibrated(path.to_string());
        Ok(p)
    }

    /// Write the profile document to `path`.
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }
}

/// The one shared usability rule for a set of cost values (profile
/// coefficients or modeled per-task costs): every value finite and
/// non-negative, at least one strictly positive. All-zero or poisoned sets
/// carry no load-balance signal and callers fall back to the static model.
pub fn usable_costs(costs: &[f64]) -> bool {
    usable_values(costs.iter())
}

fn usable_values<'a>(values: impl Iterator<Item = &'a f64> + Clone) -> bool {
    values.clone().all(|v| v.is_finite() && *v >= 0.0) && values.into_iter().any(|v| *v > 0.0)
}

/// The label a profile option presents to users (serve banner, `hmatc
/// info`, bench `cost_source` stamps): the profile's source when it would
/// actually be applied ([`CostProfile::is_usable`]), else `static` — the
/// label must never claim a profile that re-balancing ignores.
pub fn source_label(profile: Option<&CostProfile>) -> String {
    match profile {
        Some(p) if p.is_usable() => p.source.to_string(),
        _ => "static".to_string(),
    }
}

/// Load the profile named by `HMATC_COSTS` (if set). A missing or invalid
/// file **warns and returns None** — the caller keeps the static costs; a
/// bad profile must never take a serving process down. The load is cached
/// per path value (operators and bench stamps call this repeatedly), but a
/// *changed* variable re-loads, so tests and long-lived tools see updates.
pub fn costs_from_env() -> Option<CostProfile> {
    static CACHE: OnceLock<Mutex<Option<(String, Option<CostProfile>)>>> = OnceLock::new();
    let path = std::env::var("HMATC_COSTS").ok()?;
    if path.is_empty() {
        return None;
    }
    let mut cache = CACHE.get_or_init(|| Mutex::new(None)).lock().unwrap();
    if let Some((cached_path, cached)) = cache.as_ref() {
        if *cached_path == path {
            return cached.clone();
        }
    }
    let loaded = match CostProfile::load(&path) {
        Ok(p) => Some(p),
        Err(e) => {
            eprintln!("HMATC_COSTS={path}: {e}; falling back to static costs");
            None
        }
    };
    *cache = Some((path, loaded.clone()));
    loaded
}

// ---------------------------------------------------------------------------
// Timing instrumentation
// ---------------------------------------------------------------------------

/// Per-chunk wall-time accumulators for plan execution: one atomic
/// nanosecond slot per task, preallocated at arm time (zero steady-state
/// allocation). Whichever executor slot runs a chunk adds its elapsed time;
/// `fetch_add` keeps the samples tear-free even if concurrent writers race a
/// slot (the stealing backend may run chunks of one level on any worker).
/// Per-shard and per-level totals are read back after the level barrier has
/// joined, so reads never race writes of the same product.
pub struct TimingSink {
    slots: Vec<AtomicU64>,
}

impl TimingSink {
    /// A sink with one accumulator per task.
    pub fn new(ntasks: usize) -> TimingSink {
        TimingSink { slots: (0..ntasks).map(|_| AtomicU64::new(0)).collect() }
    }

    /// Number of task slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Zero all accumulators (between calibration phases).
    pub fn reset(&self) {
        for s in &self.slots {
            s.store(0, Ordering::Relaxed);
        }
    }

    /// Add `secs` of wall time to task `task`'s accumulator.
    pub fn add(&self, task: usize, secs: f64) {
        let nanos = (secs * 1e9).max(0.0).round() as u64;
        self.slots[task].fetch_add(nanos, Ordering::Relaxed);
    }

    /// Accumulated seconds of task `task`.
    pub fn secs(&self, task: usize) -> f64 {
        self.slots[task].load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Sum over all task accumulators.
    pub fn total(&self) -> f64 {
        self.slots.iter().map(|s| s.load(Ordering::Relaxed)).sum::<u64>() as f64 * 1e-9
    }
}

/// Measured makespan of a packing: per level, the largest per-shard sum of
/// recorded task times (`base` offsets shard-local task ids into the sink's
/// slot space); levels are summed — they are barrier separated.
pub fn sink_makespan(levels: &[Vec<Shard>], base: usize, sink: &TimingSink) -> f64 {
    levels.iter().map(|lv| lv.iter().map(|s| s.tasks.iter().map(|&t| sink.secs(base + t)).sum::<f64>()).fold(0.0, f64::max)).sum()
}

// ---------------------------------------------------------------------------
// Fitting
// ---------------------------------------------------------------------------

/// One calibration sample: a task's features, the batch width it ran at and
/// the measured wall seconds.
#[derive(Clone, Debug)]
pub struct Sample {
    pub feats: TaskFeats,
    pub nrhs: usize,
    pub secs: f64,
}

/// Least-squares fit of per-kernel-class coefficients over the samples
/// (normal equations with a tiny relative ridge for collinear classes;
/// negative solutions are clamped to zero — a kernel class cannot speed a
/// task up). Errors on empty/degenerate inputs instead of panicking.
pub fn fit(samples: &[Sample]) -> Result<CostProfile, String> {
    let mut classes: Vec<KernelClass> = Vec::new();
    for s in samples {
        for &(c, _) in s.feats.terms() {
            if !classes.contains(&c) {
                classes.push(c);
            }
        }
    }
    classes.sort();
    if samples.is_empty() || classes.is_empty() {
        return Err("no calibration samples".to_string());
    }
    let k = classes.len();
    let mut ata = vec![0.0f64; k * k];
    let mut atb = vec![0.0f64; k];
    let mut row = vec![0.0f64; k];
    for s in samples {
        row.fill(0.0);
        for &(c, a) in s.feats.terms() {
            let j = classes.iter().position(|&x| x == c).unwrap();
            row[j] += a * if c.scales_with_rhs() { s.nrhs as f64 } else { 1.0 };
        }
        for i in 0..k {
            if row[i] == 0.0 {
                continue;
            }
            atb[i] += row[i] * s.secs;
            for j in 0..k {
                ata[i * k + j] += row[i] * row[j];
            }
        }
    }
    // relative ridge keeps near-collinear feature columns (e.g. dense flops
    // vs dense bytes) from blowing the solve up
    let trace: f64 = (0..k).map(|i| ata[i * k + i]).sum();
    let ridge = 1e-9 * (trace / k as f64).max(1e-300);
    for i in 0..k {
        ata[i * k + i] += ridge;
    }
    let x = solve_dense(&mut ata, &mut atb, k).ok_or("singular normal equations")?;
    let coeffs: BTreeMap<KernelClass, f64> = classes.iter().zip(&x).map(|(&c, &v)| (c, v.max(0.0))).collect();
    Ok(CostProfile { coeffs, source: CostSource::Online })
}

/// Gauss-Jordan with partial pivoting on a dense k×k system (k is the number
/// of kernel classes — a dozen at most).
fn solve_dense(a: &mut [f64], b: &mut [f64], k: usize) -> Option<Vec<f64>> {
    for col in 0..k {
        let mut piv = col;
        for r in col + 1..k {
            if a[r * k + col].abs() > a[piv * k + col].abs() {
                piv = r;
            }
        }
        if a[piv * k + col].abs() < 1e-300 {
            return None;
        }
        if piv != col {
            for c in 0..k {
                a.swap(piv * k + c, col * k + c);
            }
            b.swap(piv, col);
        }
        let d = a[col * k + col];
        for r in 0..k {
            if r == col {
                continue;
            }
            let f = a[r * k + col] / d;
            if f != 0.0 {
                for c in col..k {
                    a[r * k + c] -= f * a[col * k + c];
                }
                b[r] -= f * b[col];
            }
        }
    }
    Some((0..k).map(|i| b[i] / a[i * k + i]).collect())
}

// ---------------------------------------------------------------------------
// Re-balancing
// ---------------------------------------------------------------------------

/// Modeled makespan of a level-ordered packing under per-task `costs`:
/// per level the heaviest shard, levels summed (barrier separated).
pub fn makespan(levels: &[Vec<Shard>], costs: &[f64]) -> f64 {
    levels.iter().map(|lv| level_makespan(lv, costs)).sum()
}

fn level_makespan(level: &[Shard], costs: &[f64]) -> f64 {
    level.iter().map(|s| s.tasks.iter().map(|&t| costs[t]).sum::<f64>()).fold(0.0, f64::max)
}

/// Relative drift of a measured makespan from the model's prediction:
/// `|measured − predicted| / predicted`. Returns 0.0 when `predicted` is not
/// finite-positive (no usable prediction yet — never a division by zero) or
/// `measured` is not finite (torn/empty timing read).
pub fn drift(predicted: f64, measured: f64) -> f64 {
    if !(predicted.is_finite() && predicted > 0.0) || !measured.is_finite() {
        return 0.0;
    }
    (measured - predicted).abs() / predicted
}

/// Re-run the LPT packing of every level with (calibrated) `costs`, keeping
/// per level whichever packing — incumbent or candidate — has the smaller
/// modeled makespan. LPT is a 4/3-approximation, not an optimum, so the
/// explicit comparison is what guarantees that re-balancing **never
/// increases** the modeled makespan. Kept incumbent levels get their shard
/// cost/scratch bookkeeping refreshed to the new model. Costs that are not
/// finite-positive anywhere leave the incumbent untouched.
pub fn rebalance_levels(old: &[Vec<Shard>], level_ids: &[Vec<usize>], costs: &[f64], scratch: &[usize], nshards: usize) -> Vec<Vec<Shard>> {
    debug_assert_eq!(old.len(), level_ids.len());
    if !usable_costs(costs) {
        return old.to_vec();
    }
    old.iter()
        .zip(level_ids)
        .map(|(incumbent, ids)| {
            let candidate = balance_level(ids, costs, scratch, nshards);
            if level_makespan(&candidate, costs) <= level_makespan(incumbent, costs) {
                candidate
            } else {
                let mut kept = incumbent.clone();
                for sh in &mut kept {
                    sh.cost = sh.tasks.iter().map(|&t| costs[t]).sum();
                    sh.scratch = sh.tasks.iter().map(|&t| scratch[t]).max().unwrap_or(0);
                }
                kept
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn kernel_class_keys_round_trip() {
        let classes = [
            KernelClass::Decode(CodecFamily::Aflp, 4),
            KernelClass::Decode(CodecFamily::Fpx32, 2),
            KernelClass::Decode(CodecFamily::Fpx64, 7),
            KernelClass::MatBytes,
            KernelClass::DenseFlop,
            KernelClass::LowRankFlop,
            KernelClass::PanelVec,
            KernelClass::MappedBytes,
        ];
        for c in classes {
            assert_eq!(KernelClass::parse(&c.key()).unwrap(), c);
        }
        assert!(KernelClass::parse("decode:zfp:3").is_err());
        assert!(KernelClass::parse("decode:aflp:0").is_err());
        assert!(KernelClass::parse("decode:aflp:9").is_err());
        assert!(KernelClass::parse("warp_speed").is_err());
    }

    #[test]
    fn profile_cost_scales_flops_not_bytes() {
        let p = CostProfile::from_coeffs(&[(KernelClass::Decode(CodecFamily::Aflp, 4), 2.0), (KernelClass::DenseFlop, 3.0)]);
        let mut f = TaskFeats::default();
        f.add(KernelClass::Decode(CodecFamily::Aflp, 4), 10.0);
        f.add(KernelClass::DenseFlop, 5.0);
        assert_eq!(p.cost(&f, 1), 2.0 * 10.0 + 3.0 * 5.0);
        assert_eq!(p.cost(&f, 4), 2.0 * 10.0 + 4.0 * 3.0 * 5.0);
    }

    #[test]
    fn unknown_decode_width_falls_back_to_mean_decode_rate() {
        let p = CostProfile::from_coeffs(&[(KernelClass::Decode(CodecFamily::Aflp, 2), 1.0), (KernelClass::Decode(CodecFamily::Aflp, 4), 3.0)]);
        let mut f = TaskFeats::default();
        f.add(KernelClass::Decode(CodecFamily::Fpx64, 6), 1.0);
        assert_eq!(p.cost(&f, 1), 2.0);
    }

    #[test]
    fn fit_recovers_planted_coefficients() {
        // synthetic tasks with known per-class rates; exact linear model
        let c_dec = 3e-9;
        let c_flop = 5e-11;
        let c_vec = 1e-10;
        let mut rng = Rng::new(42);
        let mut samples = Vec::new();
        for _ in 0..200 {
            let mut f = TaskFeats::default();
            let dec = (rng.uniform() * 4000.0).floor() + 1.0;
            let flops = (rng.uniform() * 200_000.0).floor() + 1.0;
            let vecb = (rng.uniform() * 10_000.0).floor() + 1.0;
            f.add(KernelClass::Decode(CodecFamily::Aflp, 4), dec);
            f.add(KernelClass::DenseFlop, flops);
            f.add(KernelClass::PanelVec, vecb);
            for nrhs in [1usize, 4] {
                let secs = c_dec * dec + (c_flop * flops + c_vec * vecb) * nrhs as f64;
                samples.push(Sample { feats: f.clone(), nrhs, secs });
            }
        }
        let p = fit(&samples).unwrap();
        let got_dec = p.coeffs()[&KernelClass::Decode(CodecFamily::Aflp, 4)];
        let got_flop = p.coeffs()[&KernelClass::DenseFlop];
        let got_vec = p.coeffs()[&KernelClass::PanelVec];
        assert!((got_dec - c_dec).abs() / c_dec < 1e-3, "{got_dec} vs {c_dec}");
        assert!((got_flop - c_flop).abs() / c_flop < 1e-3, "{got_flop} vs {c_flop}");
        assert!((got_vec - c_vec).abs() / c_vec < 1e-3, "{got_vec} vs {c_vec}");
        assert!(p.is_usable());
    }

    #[test]
    fn fit_rejects_empty() {
        assert!(fit(&[]).is_err());
    }

    #[test]
    fn rebalance_never_increases_level_makespan() {
        let mut rng = Rng::new(7);
        for trial in 0..12 {
            let n = 30 + trial * 11;
            // skewed "true" costs vs the uniform costs the incumbent saw
            let static_costs: Vec<f64> = (0..n).map(|_| 1.0 + rng.uniform()).collect();
            let true_costs: Vec<f64> = static_costs.iter().map(|c| c * 10f64.powf(rng.range(-1.5, 1.5))).collect();
            let scratch = vec![0usize; n];
            let ids: Vec<usize> = (0..n).collect();
            let (a, b) = ids.split_at(n / 3);
            let level_ids = vec![a.to_vec(), b.to_vec()];
            let old: Vec<Vec<Shard>> = level_ids.iter().map(|ids| balance_level(ids, &static_costs, &scratch, 6)).collect();
            let new = rebalance_levels(&old, &level_ids, &true_costs, &scratch, 6);
            assert!(makespan(&new, &true_costs) <= makespan(&old, &true_costs) + 1e-12, "trial {trial}");
        }
    }

    #[test]
    fn rebalance_keeps_incumbent_on_degenerate_costs() {
        let ids = vec![vec![0usize, 1, 2]];
        let costs = vec![1.0, 2.0, 3.0];
        let scratch = vec![0usize; 3];
        let old = vec![balance_level(&ids[0], &costs, &scratch, 2)];
        let zero = vec![0.0; 3];
        assert_eq!(rebalance_levels(&old, &ids, &zero, &scratch, 2).len(), old.len());
        let nan = vec![f64::NAN; 3];
        let kept = rebalance_levels(&old, &ids, &nan, &scratch, 2);
        assert_eq!(kept[0].len(), old[0].len());
    }

    #[test]
    fn timing_sink_accumulates_exact_nanos() {
        let sink = TimingSink::new(3);
        sink.add(0, 5e-9);
        sink.add(0, 7e-9);
        sink.add(2, 1e-9);
        // both sides compute k_nanos as f64 * 1e-9, so equality is exact
        assert_eq!(sink.secs(0), 12.0 * 1e-9);
        assert_eq!(sink.secs(1), 0.0);
        assert!((sink.total() - 13.0 * 1e-9).abs() < 1e-15);
        sink.reset();
        assert_eq!(sink.total(), 0.0);
    }

    #[test]
    fn drift_guards_degenerate_inputs() {
        assert_eq!(drift(0.0, 1.0), 0.0); // no prediction yet
        assert_eq!(drift(-1.0, 1.0), 0.0);
        assert_eq!(drift(f64::NAN, 1.0), 0.0);
        assert_eq!(drift(1.0, f64::INFINITY), 0.0);
        assert!((drift(2.0, 3.0) - 0.5).abs() < 1e-15);
        assert!((drift(2.0, 1.0) - 0.5).abs() < 1e-15);
    }

    #[test]
    fn profile_json_round_trip() {
        let p = CostProfile::from_coeffs(&[
            (KernelClass::Decode(CodecFamily::Aflp, 3), 1.25e-10),
            (KernelClass::MatBytes, 9.5e-11),
            (KernelClass::DenseFlop, 4e-11),
        ]);
        let text = p.to_json().to_string();
        let q = CostProfile::parse(&text).unwrap();
        assert_eq!(q.to_json().to_string(), text);
    }

    #[test]
    fn profile_rejects_hostile_documents() {
        // truncated
        assert!(CostProfile::parse("{\"version\":1,\"coeffs\":{\"dense_f").is_err());
        // version mismatch / missing
        assert!(CostProfile::parse("{\"version\":99,\"coeffs\":{}}").is_err());
        assert!(CostProfile::parse("{\"coeffs\":{}}").is_err());
        // unknown kernel class
        assert!(CostProfile::parse("{\"version\":1,\"coeffs\":{\"warp_speed\":1.0}}").is_err());
        // non-numeric / negative coefficients
        assert!(CostProfile::parse("{\"version\":1,\"coeffs\":{\"dense_flop\":null}}").is_err());
        assert!(CostProfile::parse("{\"version\":1,\"coeffs\":{\"dense_flop\":-1.0}}").is_err());
        // wrong kind
        assert!(CostProfile::parse("{\"version\":1,\"kind\":\"something else\",\"coeffs\":{}}").is_err());
    }
}
