//! Measurement-driven cost-model calibration for the plan schedules.
//!
//! The static LPT packings weight tasks with hand-tuned byte costs
//! ([`super::schedule::block_cost_split`]). That model is blind to the fact
//! that a byte of AFLP-4 decode, a byte of dense FP64 stream and a byte of
//! coupling data do not cost the same wall time — which is exactly where the
//! predicted-vs-achieved throughput gap on skewed block-size distributions
//! comes from. This module closes the loop:
//!
//! 1. **Instrumentation** — plan executions can be timed per chunk
//!    ([`TimingSink`]: one atomic nanosecond accumulator per task, written by
//!    whichever executor slot ran the chunk, read back once the level
//!    barrier has joined; the slots are preallocated, so steady-state timed
//!    execution allocates nothing).
//! 2. **Fitting** — the recorded `(features, batch width, seconds)` samples
//!    ([`Sample`]) are fitted by least squares ([`fit`]) to per-kernel-class
//!    coefficients ([`KernelClass`]): decode seconds-per-byte per
//!    `(codec, width)`, uncompressed-stream seconds-per-byte, dense and
//!    low-rank seconds-per-flop, and the panel-width scaling of the vector
//!    traffic (the flop/vector terms are multiplied by the batch width, the
//!    matrix-stream terms are not — matrix data is decoded once per batch).
//! 3. **Re-balancing** — [`rebalance_levels`] re-runs the LPT packing with
//!    the calibrated per-task costs and keeps, per level, whichever packing
//!    (incumbent or candidate) has the smaller modeled makespan, so a
//!    calibrated plan never models worse than the packing it replaces. The
//!    task list itself is untouched — only the task→shard partition changes —
//!    which is why re-balancing is bitwise output-invariant on every backend.
//! 4. **Per-pool coefficients (NUMA)** — samples are tagged with the sub-pool
//!    that executed them ([`Sample::pool`]); [`fit_pools`] fits one overlay
//!    coefficient set per pool on top of the pooled global fit (a pool with
//!    fewer than [`POOL_SAMPLE_FLOOR`] samples falls back to the global
//!    coefficients), and [`rebalance_levels_pools`] packs each level against
//!    the rates of the pool that will actually run each shard (the
//!    `sharded:K` backend's contiguous [`part_range`] affinity), so a slower
//!    socket is handed proportionally fewer bytes. Profiles optionally carry
//!    the topology they were calibrated on ([`TopologyMeta`]); loading a
//!    per-pool profile on a different topology warns and keeps only the
//!    global coefficients.
//!
//! Profiles serialize to a versioned JSON document (`hmatc calibrate --out
//! costs.json`) and load through `HMATC_COSTS` / `--costs`; hostile inputs
//! (truncated files, NaN or negative coefficients, unknown kernel-class
//! keys, version mismatches) are rejected with errors — never panics — and
//! the plan falls back to the static costs.

use super::schedule::{balance_level, part_range, Shard};
use crate::compress::{Blob, CodecParams};
use crate::h2::TransferMat;
use crate::hmatrix::BlockData;
use crate::par::Topology;
use crate::uniform::{BasisData, ClusterBasis, CouplingMat, UniBlock};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Version stamped into (and required from) profile JSON documents.
pub const PROFILE_VERSION: u32 = 1;

/// Codec family of a decode kernel class (the byte width is separate: each
/// `(family, width)` pair has its own dispatch kernel and its own decode
/// rate).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum CodecFamily {
    Aflp,
    Fpx32,
    Fpx64,
}

impl CodecFamily {
    pub fn name(self) -> &'static str {
        match self {
            CodecFamily::Aflp => "aflp",
            CodecFamily::Fpx32 => "fpx32",
            CodecFamily::Fpx64 => "fpx64",
        }
    }
}

/// One kernel class of the calibrated cost model. A task's model cost is
/// `Σ coeff(class) · amount · (nrhs if the class scales with the batch)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum KernelClass {
    /// Compressed payload bytes decoded by the `(codec, width)` dispatch
    /// kernel. Amount: blob payload bytes. Streamed once per batch.
    Decode(CodecFamily, u8),
    /// Uncompressed matrix bytes streamed from memory (dense blocks,
    /// low-rank factors, plain couplings/bases). Once per batch.
    MatBytes,
    /// Dense-kernel flops (gemv/gemm on dense or ZDense blocks). Per RHS.
    DenseFlop,
    /// Low-rank-shaped flops (factor, coupling, transfer and basis applies).
    /// Per RHS.
    LowRankFlop,
    /// Vector/panel traffic bytes — the panel-width scaling term. Per RHS.
    PanelVec,
    /// Residency feature of the storage tier: compressed payload bytes
    /// resolved from a *mapped* segment rather than anonymous memory.
    /// Additive on top of the decode classes, so calibration can price a
    /// cold-mapped decode (page-in) differently from a hot one. Amount:
    /// mapped payload bytes. Once per batch.
    MappedBytes,
}

impl KernelClass {
    /// Whether the class amount is multiplied by the batch width: matrix
    /// data (compressed or not) is streamed once per batch; flops and vector
    /// traffic scale with it.
    pub fn scales_with_rhs(self) -> bool {
        !matches!(self, KernelClass::Decode(_, _) | KernelClass::MatBytes | KernelClass::MappedBytes)
    }

    /// Stable JSON key, e.g. `decode:aflp:4`, `dense_flop`.
    pub fn key(self) -> String {
        match self {
            KernelClass::Decode(fam, w) => format!("decode:{}:{w}", fam.name()),
            KernelClass::MatBytes => "mat_bytes".to_string(),
            KernelClass::DenseFlop => "dense_flop".to_string(),
            KernelClass::LowRankFlop => "lowrank_flop".to_string(),
            KernelClass::PanelVec => "panel_vec".to_string(),
            KernelClass::MappedBytes => "mapped_bytes".to_string(),
        }
    }

    /// Parse a JSON key back into a class; unknown keys are errors (a
    /// profile written by a different model version must not be silently
    /// half-applied).
    pub fn parse(key: &str) -> Result<KernelClass, String> {
        match key {
            "mat_bytes" => return Ok(KernelClass::MatBytes),
            "dense_flop" => return Ok(KernelClass::DenseFlop),
            "lowrank_flop" => return Ok(KernelClass::LowRankFlop),
            "panel_vec" => return Ok(KernelClass::PanelVec),
            "mapped_bytes" => return Ok(KernelClass::MappedBytes),
            _ => {}
        }
        let rest = key.strip_prefix("decode:").ok_or_else(|| format!("unknown kernel class '{key}'"))?;
        let (fam, w) = rest.split_once(':').ok_or_else(|| format!("bad decode class '{key}' (decode:<codec>:<width>)"))?;
        let fam = match fam {
            "aflp" => CodecFamily::Aflp,
            "fpx32" => CodecFamily::Fpx32,
            "fpx64" => CodecFamily::Fpx64,
            other => return Err(format!("unknown codec family '{other}' in '{key}'")),
        };
        let w: u8 = w.parse().map_err(|_| format!("bad byte width in '{key}'"))?;
        if w == 0 || w > 8 {
            return Err(format!("byte width {w} out of range in '{key}'"));
        }
        Ok(KernelClass::Decode(fam, w))
    }
}

/// Per-task feature vector: amount per kernel class, built once at plan
/// (re)build time by walking the task's blocks.
#[derive(Clone, Debug, Default)]
pub struct TaskFeats {
    terms: Vec<(KernelClass, f64)>,
}

impl TaskFeats {
    /// Accumulate `amount` onto `class`.
    pub fn add(&mut self, class: KernelClass, amount: f64) {
        if amount == 0.0 {
            return;
        }
        match self.terms.iter_mut().find(|(c, _)| *c == class) {
            Some((_, a)) => *a += amount,
            None => self.terms.push((class, amount)),
        }
    }

    /// Accumulate the decode class of a compressed blob (payload bytes).
    pub fn add_blob(&mut self, blob: &Blob) {
        let class = match blob.params {
            CodecParams::Aflp { bytes_per, .. } => KernelClass::Decode(CodecFamily::Aflp, bytes_per),
            CodecParams::Fpx32 { bytes_per } => KernelClass::Decode(CodecFamily::Fpx32, bytes_per),
            CodecParams::Fpx64 { bytes_per } => KernelClass::Decode(CodecFamily::Fpx64, bytes_per),
            CodecParams::Zero => return,
        };
        self.add(class, blob.bytes.len() as f64);
        if blob.bytes.is_mapped() {
            self.add(KernelClass::MappedBytes, blob.bytes.len() as f64);
        }
    }

    /// Fold another feature vector into this one.
    pub fn merge(&mut self, other: &TaskFeats) {
        for &(c, a) in &other.terms {
            self.add(c, a);
        }
    }

    /// The accumulated `(class, amount)` terms.
    pub fn terms(&self) -> &[(KernelClass, f64)] {
        &self.terms
    }
}

// ---------------------------------------------------------------------------
// Feature extraction per block kind
// ---------------------------------------------------------------------------

/// Features of one H-matrix leaf block (matches the kernels
/// `apply_block_scratch` dispatches to).
pub fn block_feats(b: &BlockData) -> TaskFeats {
    let (m, n) = (b.nrows(), b.ncols());
    let mut f = TaskFeats::default();
    f.add(KernelClass::PanelVec, (8 * (m + n)) as f64);
    match b {
        BlockData::Dense(d) => {
            f.add(KernelClass::MatBytes, d.byte_size() as f64);
            f.add(KernelClass::DenseFlop, (2 * m * n) as f64);
        }
        BlockData::LowRank(lr) => {
            f.add(KernelClass::MatBytes, lr.byte_size() as f64);
            f.add(KernelClass::LowRankFlop, (2 * lr.rank() * (m + n)) as f64);
        }
        BlockData::ZDense(z) => {
            f.add_blob(&z.blob);
            f.add(KernelClass::DenseFlop, (2 * m * n) as f64);
        }
        BlockData::ZLowRank(z) => {
            f.add_blob(&z.u);
            f.add_blob(&z.v);
            f.add(KernelClass::LowRankFlop, (2 * z.rank * (m + n)) as f64);
        }
        BlockData::ZLowRankValr(z) => {
            for c in z.wcols.iter().chain(z.xcols.iter()) {
                f.add_blob(c);
            }
            f.add(KernelClass::LowRankFlop, (2 * z.rank() * (m + n)) as f64);
        }
    }
    f
}

/// Features of one coupling matrix apply (rank-space product).
pub fn coupling_feats(c: &CouplingMat) -> TaskFeats {
    let mut f = TaskFeats::default();
    match c {
        CouplingMat::Plain(m) => {
            f.add(KernelClass::MatBytes, m.byte_size() as f64);
            f.add(KernelClass::LowRankFlop, (2 * m.nrows() * m.ncols()) as f64);
        }
        CouplingMat::Z(z) => {
            f.add_blob(&z.blob);
            f.add(KernelClass::LowRankFlop, (2 * z.nrows * z.ncols) as f64);
        }
        CouplingMat::SepPlain { sr, sc } => {
            f.add(KernelClass::MatBytes, (sr.byte_size() + sc.byte_size()) as f64);
            f.add(KernelClass::LowRankFlop, (2 * (sr.nrows() * sr.ncols() + sc.nrows() * sc.ncols())) as f64);
        }
        CouplingMat::SepZ { sr, sc } => {
            f.add_blob(&sr.blob);
            f.add_blob(&sc.blob);
            f.add(KernelClass::LowRankFlop, (2 * (sr.nrows * sr.ncols + sc.nrows * sc.ncols)) as f64);
        }
    }
    f
}

/// Features of one basis-matrix apply (forward or backward transform slot).
pub fn basis_data_feats(d: &BasisData) -> TaskFeats {
    let mut f = TaskFeats::default();
    let (nrows, rank) = match d {
        BasisData::Plain(w) => (w.nrows(), w.ncols()),
        BasisData::Z { nrows, ncols, .. } => (*nrows, *ncols),
        BasisData::Valr(z) => (z.nrows, z.rank()),
    };
    f.add(KernelClass::PanelVec, (8 * (nrows + rank)) as f64);
    f.add(KernelClass::LowRankFlop, (2 * nrows * rank) as f64);
    match d {
        BasisData::Plain(w) => f.add(KernelClass::MatBytes, w.byte_size() as f64),
        BasisData::Z { blob, .. } => f.add_blob(blob),
        BasisData::Valr(z) => {
            for c in &z.wcols {
                f.add_blob(c);
            }
        }
    }
    f
}

/// Features of one cluster-basis apply.
pub fn basis_feats(b: &ClusterBasis) -> TaskFeats {
    basis_data_feats(&b.data)
}

/// Features of one transfer-matrix apply (H² up/down relays).
pub fn transfer_feats(t: &TransferMat) -> TaskFeats {
    let mut f = TaskFeats::default();
    f.add(KernelClass::PanelVec, (8 * (t.nrows() + t.ncols())) as f64);
    f.add(KernelClass::LowRankFlop, (2 * t.nrows() * t.ncols()) as f64);
    match t {
        TransferMat::Plain(m) => f.add(KernelClass::MatBytes, m.byte_size() as f64),
        TransferMat::Z { blob, .. } => f.add_blob(blob),
    }
    f
}

/// Features of one uniform/H² leaf block (coupling or dense).
pub fn uni_block_feats(b: &UniBlock) -> TaskFeats {
    match b {
        UniBlock::Coupling(c) => coupling_feats(c),
        UniBlock::Dense(d) => {
            let mut f = TaskFeats::default();
            f.add(KernelClass::PanelVec, (8 * (d.nrows() + d.ncols())) as f64);
            f.add(KernelClass::MatBytes, d.byte_size() as f64);
            f.add(KernelClass::DenseFlop, (2 * d.nrows() * d.ncols()) as f64);
            f
        }
        UniBlock::ZDense(z) => {
            let mut f = TaskFeats::default();
            f.add(KernelClass::PanelVec, (8 * (z.nrows + z.ncols)) as f64);
            f.add_blob(&z.blob);
            f.add(KernelClass::DenseFlop, (2 * z.nrows * z.ncols) as f64);
            f
        }
    }
}

// ---------------------------------------------------------------------------
// Cost profile
// ---------------------------------------------------------------------------

/// Where a plan's active LPT costs came from (recorded in
/// [`super::PlanStats::cost_source`] and bench rows).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum CostSource {
    /// The hand-tuned byte model of [`super::schedule`].
    #[default]
    Static,
    /// A profile loaded from a file (`HMATC_COSTS` / `--costs`).
    Calibrated(String),
    /// A profile fitted in-process by `calibrate()`.
    Online,
}

impl std::fmt::Display for CostSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CostSource::Static => write!(f, "static"),
            CostSource::Calibrated(path) => write!(f, "calibrated({path})"),
            CostSource::Online => write!(f, "online"),
        }
    }
}

/// Topology fingerprint a per-pool profile was calibrated on. Serialized
/// into the profile document so a profile calibrated on one box is not
/// silently applied per-pool on a differently shaped one.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TopologyMeta {
    /// NUMA nodes with at least one usable cpu.
    pub nodes: usize,
    /// Largest per-node cpu count (0 on the fallback topology).
    pub cores_per_node: usize,
    /// Whether pool pinning was enabled (`HMATC_PIN`).
    pub pinned: bool,
}

impl TopologyMeta {
    /// The running process's topology fingerprint.
    pub fn current() -> TopologyMeta {
        let t = Topology::get();
        TopologyMeta { nodes: t.num_nodes(), cores_per_node: t.cores_per_node(), pinned: t.pin_enabled() }
    }
}

/// Fitted per-kernel-class coefficients (seconds per unit amount), plus the
/// provenance the plan layer reports. The serialized form carries the
/// version, the coefficients, and — when per-pool fits exist — the per-pool
/// overlays and the topology fingerprint they were calibrated on.
#[derive(Clone, Debug, Default)]
pub struct CostProfile {
    coeffs: BTreeMap<KernelClass, f64>,
    /// Per-pool overlay coefficient sets (index = sub-pool id of the
    /// `sharded:K` backend). An empty map means "use the global
    /// coefficients for this pool" — the below-sample-floor fallback.
    pools: Vec<BTreeMap<KernelClass, f64>>,
    /// Topology the per-pool overlays were fitted on, when recorded.
    pub topology: Option<TopologyMeta>,
    /// Provenance (not serialized — derived from how the profile was made).
    pub source: CostSource,
}

impl CostProfile {
    /// Build a profile from explicit coefficients (tests, synthetic models).
    pub fn from_coeffs(pairs: &[(KernelClass, f64)]) -> CostProfile {
        CostProfile { coeffs: pairs.iter().copied().collect(), source: CostSource::Online, ..Default::default() }
    }

    /// The fitted coefficients.
    pub fn coeffs(&self) -> &BTreeMap<KernelClass, f64> {
        &self.coeffs
    }

    /// Install per-pool overlay coefficient sets (tests, [`fit_pools`]).
    pub fn with_pools(mut self, pools: Vec<BTreeMap<KernelClass, f64>>) -> CostProfile {
        self.pools = pools;
        self
    }

    /// The per-pool overlays (empty when only a global fit exists).
    pub fn pools(&self) -> &[BTreeMap<KernelClass, f64>] {
        &self.pools
    }

    /// Whether any pool has its own (non-empty) overlay coefficient set.
    pub fn has_pool_coeffs(&self) -> bool {
        self.pools.iter().any(|m| !m.is_empty())
    }

    /// Source label per pool: `"per-pool"` where an overlay fit exists,
    /// `"global"` where the pool fell back (sample floor / topology
    /// mismatch). Empty when the profile has no pool dimension at all.
    pub fn pool_source_labels(&self) -> Vec<&'static str> {
        self.pools.iter().map(|m| if m.is_empty() { "global" } else { "per-pool" }).collect()
    }

    /// A profile is usable for re-balancing only if it has at least one
    /// strictly positive, finite coefficient — an all-zero fit (e.g. from a
    /// clock with too little resolution) carries no load-balance signal.
    pub fn is_usable(&self) -> bool {
        usable_values(self.coeffs.values())
    }

    fn coeff(&self, class: KernelClass) -> f64 {
        if let Some(v) = self.coeffs.get(&class) {
            return *v;
        }
        // a decode width the fit never saw: use the mean decode rate, else
        // the uncompressed stream rate — bytes are bytes to first order
        if let KernelClass::Decode(_, _) = class {
            let dec: Vec<f64> = self.coeffs.iter().filter(|(c, _)| matches!(c, KernelClass::Decode(_, _))).map(|(_, v)| *v).collect();
            if !dec.is_empty() {
                return dec.iter().sum::<f64>() / dec.len() as f64;
            }
            return self.coeffs.get(&KernelClass::MatBytes).copied().unwrap_or(0.0);
        }
        0.0
    }

    /// Modeled seconds of one task at batch width `nrhs`.
    pub fn cost(&self, feats: &TaskFeats, nrhs: usize) -> f64 {
        feats.terms().iter().map(|&(c, a)| self.coeff(c) * a * if c.scales_with_rhs() { nrhs as f64 } else { 1.0 }).sum()
    }

    /// Coefficient of `class` as pool `pool` sees it: the pool's overlay fit
    /// when it has one (with the overlay's own unknown-decode-width mean
    /// fallback), else the global [`CostProfile::coeff`].
    pub fn pool_coeff(&self, pool: usize, class: KernelClass) -> f64 {
        let Some(overlay) = self.pools.get(pool).filter(|m| !m.is_empty()) else {
            return self.coeff(class);
        };
        if let Some(v) = overlay.get(&class) {
            return *v;
        }
        if let KernelClass::Decode(_, _) = class {
            let dec: Vec<f64> = overlay.iter().filter(|(c, _)| matches!(c, KernelClass::Decode(_, _))).map(|(_, v)| *v).collect();
            if !dec.is_empty() {
                return dec.iter().sum::<f64>() / dec.len() as f64;
            }
            if let Some(v) = overlay.get(&KernelClass::MatBytes) {
                return *v;
            }
        }
        self.coeff(class)
    }

    /// Modeled seconds of one task at batch width `nrhs` on pool `pool`.
    pub fn pool_cost(&self, pool: usize, feats: &TaskFeats, nrhs: usize) -> f64 {
        feats
            .terms()
            .iter()
            .map(|&(c, a)| self.pool_coeff(pool, c) * a * if c.scales_with_rhs() { nrhs as f64 } else { 1.0 })
            .sum()
    }

    /// Serialize to the versioned profile document. Per-pool overlays and
    /// the topology fingerprint are written only when present; the added
    /// top-level keys are ignored by pre-NUMA readers (unknown top-level
    /// keys always were), so the version stays [`PROFILE_VERSION`].
    pub fn to_json(&self) -> Json {
        let coeff_obj = |m: &BTreeMap<KernelClass, f64>| Json::Obj(m.iter().map(|(c, v)| (c.key(), Json::Num(*v))).collect());
        let mut fields = vec![
            ("version", Json::Num(PROFILE_VERSION as f64)),
            ("kind", "hmatc cost profile".into()),
            ("coeffs", coeff_obj(&self.coeffs)),
        ];
        if self.has_pool_coeffs() {
            fields.push(("pools", Json::Arr(self.pools.iter().map(coeff_obj).collect())));
        }
        if let Some(t) = self.topology {
            fields.push((
                "topology",
                Json::obj(vec![
                    ("nodes", Json::Num(t.nodes as f64)),
                    ("cores_per_node", Json::Num(t.cores_per_node as f64)),
                    ("pinned", Json::Bool(t.pinned)),
                ]),
            ));
        }
        Json::obj(fields)
    }

    /// Parse and validate a profile document. Rejects (with errors, not
    /// panics): version mismatches, unknown kernel-class keys, and NaN /
    /// infinite / negative coefficients — in the global set and in every
    /// per-pool overlay.
    pub fn from_json(doc: &Json) -> Result<CostProfile, String> {
        let version = doc.get("version").and_then(Json::as_f64).ok_or("missing numeric 'version' field")?;
        if version != PROFILE_VERSION as f64 {
            return Err(format!("profile version {version} != supported {PROFILE_VERSION}"));
        }
        if let Some(kind) = doc.get("kind") {
            if kind.as_str() != Some("hmatc cost profile") {
                return Err("'kind' is not 'hmatc cost profile'".to_string());
            }
        }
        let coeffs = match doc.get("coeffs") {
            Some(obj) => parse_coeff_map(obj, "'coeffs'")?,
            None => return Err("missing 'coeffs' object".to_string()),
        };
        let pools = match doc.get("pools") {
            None => Vec::new(),
            Some(Json::Arr(arr)) => {
                let mut out = Vec::with_capacity(arr.len());
                for (i, entry) in arr.iter().enumerate() {
                    out.push(parse_coeff_map(entry, &format!("'pools[{i}]'"))?);
                }
                out
            }
            Some(_) => return Err("'pools' is not an array".to_string()),
        };
        let topology = match doc.get("topology") {
            None => None,
            Some(t) => {
                let dim = |key: &str| {
                    t.get(key)
                        .and_then(Json::as_f64)
                        .filter(|v| v.is_finite() && *v >= 0.0 && *v <= 1e9)
                        .map(|v| v as usize)
                        .ok_or_else(|| format!("'topology.{key}' is not a non-negative number"))
                };
                let pinned = match t.get("pinned") {
                    Some(Json::Bool(b)) => *b,
                    _ => return Err("'topology.pinned' is not a bool".to_string()),
                };
                Some(TopologyMeta { nodes: dim("nodes")?, cores_per_node: dim("cores_per_node")?, pinned })
            }
        };
        Ok(CostProfile { coeffs, pools, topology, source: CostSource::Online })
    }

    /// Parse a profile from JSON text.
    pub fn parse(text: &str) -> Result<CostProfile, String> {
        CostProfile::from_json(&Json::parse(text)?)
    }

    /// Load (and validate) a profile file; the result's source is
    /// `calibrated(<path>)`. A profile with per-pool overlays calibrated on
    /// a **different topology** (or with none recorded) keeps only its
    /// global coefficients, with a warning — stale per-pool rates from
    /// another box must never silently skew packing here.
    pub fn load(path: &str) -> Result<CostProfile, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("cannot read: {e}"))?;
        let mut p = CostProfile::parse(&text)?;
        p.source = CostSource::Calibrated(path.to_string());
        if p.has_pool_coeffs() {
            let here = TopologyMeta::current();
            match p.topology {
                Some(meta) if meta == here => {}
                recorded => {
                    let rec = recorded
                        .map(|m| format!("{} node(s) × {} cpus, pinned={}", m.nodes, m.cores_per_node, m.pinned))
                        .unwrap_or_else(|| "no topology recorded".to_string());
                    eprintln!(
                        "cost profile {path}: per-pool coefficients do not match this machine ({rec}; here: {} node(s) × {} cpus, pinned={}); applying the global fit only",
                        here.nodes, here.cores_per_node, here.pinned
                    );
                    p.pools.clear();
                }
            }
        }
        Ok(p)
    }

    /// Write the profile document to `path`.
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }
}

/// Parse one JSON object of `kernel-class key → coefficient`, validating
/// keys and values exactly like the global coefficient set always was.
fn parse_coeff_map(obj: &Json, what: &str) -> Result<BTreeMap<KernelClass, f64>, String> {
    let Json::Obj(m) = obj else {
        return Err(format!("{what} is not an object"));
    };
    let mut out = BTreeMap::new();
    for (k, v) in m {
        let class = KernelClass::parse(k)?;
        let val = v.as_f64().ok_or_else(|| format!("coefficient '{k}' in {what} is not a number"))?;
        if !val.is_finite() || val < 0.0 {
            return Err(format!("coefficient '{k}' = {val} in {what} is not finite and non-negative"));
        }
        out.insert(class, val);
    }
    Ok(out)
}

/// The one shared usability rule for a set of cost values (profile
/// coefficients or modeled per-task costs): every value finite and
/// non-negative, at least one strictly positive. All-zero or poisoned sets
/// carry no load-balance signal and callers fall back to the static model.
pub fn usable_costs(costs: &[f64]) -> bool {
    usable_values(costs.iter())
}

fn usable_values<'a>(values: impl Iterator<Item = &'a f64> + Clone) -> bool {
    values.clone().all(|v| v.is_finite() && *v >= 0.0) && values.into_iter().any(|v| *v > 0.0)
}

/// The label a profile option presents to users (serve banner, `hmatc
/// info`, bench `cost_source` stamps): the profile's source when it would
/// actually be applied ([`CostProfile::is_usable`]), else `static` — the
/// label must never claim a profile that re-balancing ignores.
pub fn source_label(profile: Option<&CostProfile>) -> String {
    match profile {
        Some(p) if p.is_usable() => p.source.to_string(),
        _ => "static".to_string(),
    }
}

/// Load the profile named by `HMATC_COSTS` (if set). A missing or invalid
/// file **warns and returns None** — the caller keeps the static costs; a
/// bad profile must never take a serving process down. The load is cached
/// per path value (operators and bench stamps call this repeatedly), but a
/// *changed* variable re-loads, so tests and long-lived tools see updates.
pub fn costs_from_env() -> Option<CostProfile> {
    static CACHE: OnceLock<Mutex<Option<(String, Option<CostProfile>)>>> = OnceLock::new();
    let path = std::env::var("HMATC_COSTS").ok()?;
    if path.is_empty() {
        return None;
    }
    let mut cache = CACHE.get_or_init(|| Mutex::new(None)).lock().unwrap();
    if let Some((cached_path, cached)) = cache.as_ref() {
        if *cached_path == path {
            return cached.clone();
        }
    }
    let loaded = match CostProfile::load(&path) {
        Ok(p) => Some(p),
        Err(e) => {
            eprintln!("HMATC_COSTS={path}: {e}; falling back to static costs");
            None
        }
    };
    *cache = Some((path, loaded.clone()));
    loaded
}

// ---------------------------------------------------------------------------
// Timing instrumentation
// ---------------------------------------------------------------------------

/// Per-chunk wall-time accumulators for plan execution: one atomic
/// nanosecond slot per task, preallocated at arm time (zero steady-state
/// allocation). Whichever executor slot runs a chunk adds its elapsed time;
/// `fetch_add` keeps the samples tear-free even if concurrent writers race a
/// slot (the stealing backend may run chunks of one level on any worker).
/// Per-shard and per-level totals are read back after the level barrier has
/// joined, so reads never race writes of the same product.
pub struct TimingSink {
    slots: Vec<AtomicU64>,
}

impl TimingSink {
    /// A sink with one accumulator per task.
    pub fn new(ntasks: usize) -> TimingSink {
        TimingSink { slots: (0..ntasks).map(|_| AtomicU64::new(0)).collect() }
    }

    /// Number of task slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Zero all accumulators (between calibration phases).
    pub fn reset(&self) {
        for s in &self.slots {
            s.store(0, Ordering::Relaxed);
        }
    }

    /// Add `secs` of wall time to task `task`'s accumulator.
    pub fn add(&self, task: usize, secs: f64) {
        let nanos = (secs * 1e9).max(0.0).round() as u64;
        self.slots[task].fetch_add(nanos, Ordering::Relaxed);
    }

    /// Accumulated seconds of task `task`.
    pub fn secs(&self, task: usize) -> f64 {
        self.slots[task].load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Sum over all task accumulators.
    pub fn total(&self) -> f64 {
        self.slots.iter().map(|s| s.load(Ordering::Relaxed)).sum::<u64>() as f64 * 1e-9
    }
}

/// Measured makespan of a packing: per level, the largest per-shard sum of
/// recorded task times (`base` offsets shard-local task ids into the sink's
/// slot space); levels are summed — they are barrier separated.
pub fn sink_makespan(levels: &[Vec<Shard>], base: usize, sink: &TimingSink) -> f64 {
    levels.iter().map(|lv| lv.iter().map(|s| s.tasks.iter().map(|&t| sink.secs(base + t)).sum::<f64>()).fold(0.0, f64::max)).sum()
}

// ---------------------------------------------------------------------------
// Fitting
// ---------------------------------------------------------------------------

/// One calibration sample: a task's features, the batch width it ran at, the
/// executing sub-pool and the measured wall seconds.
#[derive(Clone, Debug)]
pub struct Sample {
    pub feats: TaskFeats,
    pub nrhs: usize,
    /// Sub-pool of the executor that ran the chunk (0 on single-pool
    /// backends). Feeds the per-pool overlay fits of [`fit_pools`].
    pub pool: usize,
    pub secs: f64,
}

/// Least-squares fit of per-kernel-class coefficients over the samples
/// (normal equations with a tiny relative ridge for collinear classes;
/// negative solutions are clamped to zero — a kernel class cannot speed a
/// task up). Errors on empty/degenerate inputs instead of panicking.
pub fn fit(samples: &[Sample]) -> Result<CostProfile, String> {
    let mut classes: Vec<KernelClass> = Vec::new();
    for s in samples {
        for &(c, _) in s.feats.terms() {
            if !classes.contains(&c) {
                classes.push(c);
            }
        }
    }
    classes.sort();
    if samples.is_empty() || classes.is_empty() {
        return Err("no calibration samples".to_string());
    }
    let k = classes.len();
    let mut ata = vec![0.0f64; k * k];
    let mut atb = vec![0.0f64; k];
    let mut row = vec![0.0f64; k];
    for s in samples {
        row.fill(0.0);
        for &(c, a) in s.feats.terms() {
            let j = classes.iter().position(|&x| x == c).unwrap();
            row[j] += a * if c.scales_with_rhs() { s.nrhs as f64 } else { 1.0 };
        }
        for i in 0..k {
            if row[i] == 0.0 {
                continue;
            }
            atb[i] += row[i] * s.secs;
            for j in 0..k {
                ata[i * k + j] += row[i] * row[j];
            }
        }
    }
    // relative ridge keeps near-collinear feature columns (e.g. dense flops
    // vs dense bytes) from blowing the solve up
    let trace: f64 = (0..k).map(|i| ata[i * k + i]).sum();
    let ridge = 1e-9 * (trace / k as f64).max(1e-300);
    for i in 0..k {
        ata[i * k + i] += ridge;
    }
    let x = solve_dense(&mut ata, &mut atb, k).ok_or("singular normal equations")?;
    let coeffs: BTreeMap<KernelClass, f64> = classes.iter().zip(&x).map(|(&c, &v)| (c, v.max(0.0))).collect();
    Ok(CostProfile { coeffs, source: CostSource::Online, ..Default::default() })
}

/// Minimum samples a sub-pool must contribute before it earns its own
/// overlay fit; below the floor the pool uses the pooled global coefficients
/// (a handful of timings cannot distinguish a slow socket from noise).
pub const POOL_SAMPLE_FLOOR: usize = 64;

/// Fit the pooled global profile plus one overlay coefficient set per
/// sub-pool. A pool with fewer than [`POOL_SAMPLE_FLOOR`] samples — or whose
/// own fit is degenerate/unusable — falls back to the global coefficients
/// (an empty overlay map). Errors only when the *global* fit does: per-pool
/// fitting can degrade but never lose calibration entirely.
pub fn fit_pools(samples: &[Sample], npools: usize) -> Result<CostProfile, String> {
    let mut profile = fit(samples)?;
    if npools <= 1 {
        return Ok(profile);
    }
    let mut pools = Vec::with_capacity(npools);
    let mut subset: Vec<Sample> = Vec::new();
    for p in 0..npools {
        subset.clear();
        subset.extend(samples.iter().filter(|s| s.pool == p).cloned());
        let overlay = if subset.len() >= POOL_SAMPLE_FLOOR {
            match fit(&subset) {
                Ok(fp) if fp.is_usable() => fp.coeffs,
                _ => BTreeMap::new(),
            }
        } else {
            BTreeMap::new()
        };
        pools.push(overlay);
    }
    profile.pools = pools;
    Ok(profile)
}

/// Gauss-Jordan with partial pivoting on a dense k×k system (k is the number
/// of kernel classes — a dozen at most).
fn solve_dense(a: &mut [f64], b: &mut [f64], k: usize) -> Option<Vec<f64>> {
    for col in 0..k {
        let mut piv = col;
        for r in col + 1..k {
            if a[r * k + col].abs() > a[piv * k + col].abs() {
                piv = r;
            }
        }
        if a[piv * k + col].abs() < 1e-300 {
            return None;
        }
        if piv != col {
            for c in 0..k {
                a.swap(piv * k + c, col * k + c);
            }
            b.swap(piv, col);
        }
        let d = a[col * k + col];
        for r in 0..k {
            if r == col {
                continue;
            }
            let f = a[r * k + col] / d;
            if f != 0.0 {
                for c in col..k {
                    a[r * k + c] -= f * a[col * k + c];
                }
                b[r] -= f * b[col];
            }
        }
    }
    Some((0..k).map(|i| b[i] / a[i * k + i]).collect())
}

// ---------------------------------------------------------------------------
// Re-balancing
// ---------------------------------------------------------------------------

/// Modeled makespan of a level-ordered packing under per-task `costs`:
/// per level the heaviest shard, levels summed (barrier separated).
pub fn makespan(levels: &[Vec<Shard>], costs: &[f64]) -> f64 {
    levels.iter().map(|lv| level_makespan(lv, costs)).sum()
}

fn level_makespan(level: &[Shard], costs: &[f64]) -> f64 {
    level.iter().map(|s| s.tasks.iter().map(|&t| costs[t]).sum::<f64>()).fold(0.0, f64::max)
}

/// Relative drift of a measured makespan from the model's prediction:
/// `|measured − predicted| / predicted`. Returns 0.0 when `predicted` is not
/// finite-positive (no usable prediction yet — never a division by zero) or
/// `measured` is not finite (torn/empty timing read).
pub fn drift(predicted: f64, measured: f64) -> f64 {
    if !(predicted.is_finite() && predicted > 0.0) || !measured.is_finite() {
        return 0.0;
    }
    (measured - predicted).abs() / predicted
}

/// Re-run the LPT packing of every level with (calibrated) `costs`, keeping
/// per level whichever packing — incumbent or candidate — has the smaller
/// modeled makespan. LPT is a 4/3-approximation, not an optimum, so the
/// explicit comparison is what guarantees that re-balancing **never
/// increases** the modeled makespan. Kept incumbent levels get their shard
/// cost/scratch bookkeeping refreshed to the new model. Costs that are not
/// finite-positive anywhere leave the incumbent untouched.
pub fn rebalance_levels(old: &[Vec<Shard>], level_ids: &[Vec<usize>], costs: &[f64], scratch: &[usize], nshards: usize) -> Vec<Vec<Shard>> {
    debug_assert_eq!(old.len(), level_ids.len());
    if !usable_costs(costs) {
        return old.to_vec();
    }
    old.iter()
        .zip(level_ids)
        .map(|(incumbent, ids)| {
            let candidate = balance_level(ids, costs, scratch, nshards);
            if level_makespan(&candidate, costs) <= level_makespan(incumbent, costs) {
                candidate
            } else {
                let mut kept = incumbent.clone();
                for sh in &mut kept {
                    sh.cost = sh.tasks.iter().map(|&t| costs[t]).sum();
                    sh.scratch = sh.tasks.iter().map(|&t| scratch[t]).max().unwrap_or(0);
                }
                kept
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Pool-aware re-balancing (NUMA)
// ---------------------------------------------------------------------------

/// The sub-pool that executes shard `shard` of an `nshards`-long level: the
/// inverse of the contiguous [`part_range`] shard→pool affinity of the
/// `sharded:K` backend. Single-pool backends map everything to pool 0.
pub fn pool_of_shard(shard: usize, nshards: usize, npools: usize) -> usize {
    let k = npools.max(1);
    let n = nshards.max(1);
    let s = shard.min(n - 1);
    let mut p = (s * k) / n;
    while p + 1 < k && part_range(n, k, p).end <= s {
        p += 1;
    }
    while p > 0 && part_range(n, k, p).start > s {
        p -= 1;
    }
    p
}

/// Modeled makespan of one level under per-pool task costs: shard `i` is
/// priced by the pool [`pool_of_shard`] assigns it under the level's
/// **actual** shard count (an incumbent packing may be shorter than the
/// requested bin count, and the runtime mapping is positional).
pub fn level_makespan_pools(level: &[Shard], costs_pp: &[Vec<f64>]) -> f64 {
    if costs_pp.is_empty() {
        return 0.0;
    }
    let n = level.len();
    level
        .iter()
        .enumerate()
        .map(|(i, sh)| {
            let c = &costs_pp[pool_of_shard(i, n, costs_pp.len())];
            sh.tasks.iter().map(|&t| c[t]).sum::<f64>()
        })
        .fold(0.0, f64::max)
}

/// Modeled makespan of a level-ordered packing under per-pool task costs.
pub fn makespan_pools(levels: &[Vec<Shard>], costs_pp: &[Vec<f64>]) -> f64 {
    levels.iter().map(|lv| level_makespan_pools(lv, costs_pp)).sum()
}

/// Pool-aware LPT for one level. Bin `b` of the packed level runs on pool
/// [`pool_of_shard`]`(b, k, npools)` (`k` = packed length), so each task's
/// insertion is priced under the coefficients of the bin's own pool: a
/// slower pool's bins fill up (in modeled seconds) sooner and end up with
/// proportionally fewer bytes. Tasks are ordered by pool-averaged cost
/// (heaviest first, ties by position) and appended to the bin with the
/// smallest completion time after insertion (ties: fewer tasks, lower bin).
/// All `min(nshards, ids.len())` bins are kept, **including empty ones** —
/// the runtime pool mapping is positional, so bins must not be dropped (an
/// empty bin on a slow pool is the balancer working, not an artifact).
pub fn balance_level_pools(ids: &[usize], costs_pp: &[Vec<f64>], scratch: &[usize], nshards: usize) -> Vec<Shard> {
    let n = ids.len();
    if n == 0 {
        return Vec::new();
    }
    if costs_pp.is_empty() {
        return balance_level(ids, &vec![1.0; scratch.len()], scratch, nshards);
    }
    let npools = costs_pp.len();
    let k = nshards.max(1).min(n);
    let bin_pool: Vec<usize> = (0..k).map(|b| pool_of_shard(b, k, npools)).collect();
    let avg: Vec<f64> = ids.iter().map(|&g| costs_pp.iter().map(|c| c[g]).sum::<f64>() / npools as f64).collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| avg[b].partial_cmp(&avg[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b)));
    let mut shards: Vec<Shard> = (0..k).map(|_| Shard::default()).collect();
    for li in order {
        let g = ids[li];
        let mut best = 0usize;
        let mut best_key = (f64::INFINITY, usize::MAX);
        for (b, sh) in shards.iter().enumerate() {
            let key = (sh.cost + costs_pp[bin_pool[b]][g], sh.tasks.len());
            if key < best_key {
                best_key = key;
                best = b;
            }
        }
        let sh = &mut shards[best];
        sh.tasks.push(g);
        sh.cost += costs_pp[bin_pool[best]][g];
        sh.scratch = sh.scratch.max(scratch[g]);
    }
    shards
}

/// Per-pool variant of [`rebalance_levels`]: packs every level with
/// [`balance_level_pools`] and keeps, per level, whichever packing —
/// incumbent or candidate — models the smaller makespan under the per-pool
/// costs (each packing priced under its own length's pool mapping), so the
/// never-worse guarantee carries over. Kept incumbents get their
/// cost/scratch bookkeeping refreshed under their own mapping. Degenerate
/// inputs (no pools, or any pool's cost vector unusable) leave the
/// incumbent untouched.
pub fn rebalance_levels_pools(
    old: &[Vec<Shard>],
    level_ids: &[Vec<usize>],
    costs_pp: &[Vec<f64>],
    scratch: &[usize],
    nshards: usize,
) -> Vec<Vec<Shard>> {
    debug_assert_eq!(old.len(), level_ids.len());
    if costs_pp.is_empty() || costs_pp.iter().any(|c| !usable_costs(c)) {
        return old.to_vec();
    }
    old.iter()
        .zip(level_ids)
        .map(|(incumbent, ids)| {
            let candidate = balance_level_pools(ids, costs_pp, scratch, nshards);
            if level_makespan_pools(&candidate, costs_pp) <= level_makespan_pools(incumbent, costs_pp) {
                candidate
            } else {
                let n = incumbent.len();
                let mut kept = incumbent.clone();
                for (i, sh) in kept.iter_mut().enumerate() {
                    let c = &costs_pp[pool_of_shard(i, n, costs_pp.len())];
                    sh.cost = sh.tasks.iter().map(|&t| c[t]).sum();
                    sh.scratch = sh.tasks.iter().map(|&t| scratch[t]).max().unwrap_or(0);
                }
                kept
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn kernel_class_keys_round_trip() {
        let classes = [
            KernelClass::Decode(CodecFamily::Aflp, 4),
            KernelClass::Decode(CodecFamily::Fpx32, 2),
            KernelClass::Decode(CodecFamily::Fpx64, 7),
            KernelClass::MatBytes,
            KernelClass::DenseFlop,
            KernelClass::LowRankFlop,
            KernelClass::PanelVec,
            KernelClass::MappedBytes,
        ];
        for c in classes {
            assert_eq!(KernelClass::parse(&c.key()).unwrap(), c);
        }
        assert!(KernelClass::parse("decode:zfp:3").is_err());
        assert!(KernelClass::parse("decode:aflp:0").is_err());
        assert!(KernelClass::parse("decode:aflp:9").is_err());
        assert!(KernelClass::parse("warp_speed").is_err());
    }

    #[test]
    fn profile_cost_scales_flops_not_bytes() {
        let p = CostProfile::from_coeffs(&[(KernelClass::Decode(CodecFamily::Aflp, 4), 2.0), (KernelClass::DenseFlop, 3.0)]);
        let mut f = TaskFeats::default();
        f.add(KernelClass::Decode(CodecFamily::Aflp, 4), 10.0);
        f.add(KernelClass::DenseFlop, 5.0);
        assert_eq!(p.cost(&f, 1), 2.0 * 10.0 + 3.0 * 5.0);
        assert_eq!(p.cost(&f, 4), 2.0 * 10.0 + 4.0 * 3.0 * 5.0);
    }

    #[test]
    fn unknown_decode_width_falls_back_to_mean_decode_rate() {
        let p = CostProfile::from_coeffs(&[(KernelClass::Decode(CodecFamily::Aflp, 2), 1.0), (KernelClass::Decode(CodecFamily::Aflp, 4), 3.0)]);
        let mut f = TaskFeats::default();
        f.add(KernelClass::Decode(CodecFamily::Fpx64, 6), 1.0);
        assert_eq!(p.cost(&f, 1), 2.0);
    }

    #[test]
    fn fit_recovers_planted_coefficients() {
        // synthetic tasks with known per-class rates; exact linear model
        let c_dec = 3e-9;
        let c_flop = 5e-11;
        let c_vec = 1e-10;
        let mut rng = Rng::new(42);
        let mut samples = Vec::new();
        for _ in 0..200 {
            let mut f = TaskFeats::default();
            let dec = (rng.uniform() * 4000.0).floor() + 1.0;
            let flops = (rng.uniform() * 200_000.0).floor() + 1.0;
            let vecb = (rng.uniform() * 10_000.0).floor() + 1.0;
            f.add(KernelClass::Decode(CodecFamily::Aflp, 4), dec);
            f.add(KernelClass::DenseFlop, flops);
            f.add(KernelClass::PanelVec, vecb);
            for nrhs in [1usize, 4] {
                let secs = c_dec * dec + (c_flop * flops + c_vec * vecb) * nrhs as f64;
                samples.push(Sample { feats: f.clone(), nrhs, pool: 0, secs });
            }
        }
        let p = fit(&samples).unwrap();
        let got_dec = p.coeffs()[&KernelClass::Decode(CodecFamily::Aflp, 4)];
        let got_flop = p.coeffs()[&KernelClass::DenseFlop];
        let got_vec = p.coeffs()[&KernelClass::PanelVec];
        assert!((got_dec - c_dec).abs() / c_dec < 1e-3, "{got_dec} vs {c_dec}");
        assert!((got_flop - c_flop).abs() / c_flop < 1e-3, "{got_flop} vs {c_flop}");
        assert!((got_vec - c_vec).abs() / c_vec < 1e-3, "{got_vec} vs {c_vec}");
        assert!(p.is_usable());
    }

    #[test]
    fn fit_rejects_empty() {
        assert!(fit(&[]).is_err());
    }

    #[test]
    fn rebalance_never_increases_level_makespan() {
        let mut rng = Rng::new(7);
        for trial in 0..12 {
            let n = 30 + trial * 11;
            // skewed "true" costs vs the uniform costs the incumbent saw
            let static_costs: Vec<f64> = (0..n).map(|_| 1.0 + rng.uniform()).collect();
            let true_costs: Vec<f64> = static_costs.iter().map(|c| c * 10f64.powf(rng.range(-1.5, 1.5))).collect();
            let scratch = vec![0usize; n];
            let ids: Vec<usize> = (0..n).collect();
            let (a, b) = ids.split_at(n / 3);
            let level_ids = vec![a.to_vec(), b.to_vec()];
            let old: Vec<Vec<Shard>> = level_ids.iter().map(|ids| balance_level(ids, &static_costs, &scratch, 6)).collect();
            let new = rebalance_levels(&old, &level_ids, &true_costs, &scratch, 6);
            assert!(makespan(&new, &true_costs) <= makespan(&old, &true_costs) + 1e-12, "trial {trial}");
        }
    }

    #[test]
    fn rebalance_keeps_incumbent_on_degenerate_costs() {
        let ids = vec![vec![0usize, 1, 2]];
        let costs = vec![1.0, 2.0, 3.0];
        let scratch = vec![0usize; 3];
        let old = vec![balance_level(&ids[0], &costs, &scratch, 2)];
        let zero = vec![0.0; 3];
        assert_eq!(rebalance_levels(&old, &ids, &zero, &scratch, 2).len(), old.len());
        let nan = vec![f64::NAN; 3];
        let kept = rebalance_levels(&old, &ids, &nan, &scratch, 2);
        assert_eq!(kept[0].len(), old[0].len());
    }

    #[test]
    fn timing_sink_accumulates_exact_nanos() {
        let sink = TimingSink::new(3);
        sink.add(0, 5e-9);
        sink.add(0, 7e-9);
        sink.add(2, 1e-9);
        // both sides compute k_nanos as f64 * 1e-9, so equality is exact
        assert_eq!(sink.secs(0), 12.0 * 1e-9);
        assert_eq!(sink.secs(1), 0.0);
        assert!((sink.total() - 13.0 * 1e-9).abs() < 1e-15);
        sink.reset();
        assert_eq!(sink.total(), 0.0);
    }

    #[test]
    fn drift_guards_degenerate_inputs() {
        assert_eq!(drift(0.0, 1.0), 0.0); // no prediction yet
        assert_eq!(drift(-1.0, 1.0), 0.0);
        assert_eq!(drift(f64::NAN, 1.0), 0.0);
        assert_eq!(drift(1.0, f64::INFINITY), 0.0);
        assert!((drift(2.0, 3.0) - 0.5).abs() < 1e-15);
        assert!((drift(2.0, 1.0) - 0.5).abs() < 1e-15);
    }

    #[test]
    fn profile_json_round_trip() {
        let p = CostProfile::from_coeffs(&[
            (KernelClass::Decode(CodecFamily::Aflp, 3), 1.25e-10),
            (KernelClass::MatBytes, 9.5e-11),
            (KernelClass::DenseFlop, 4e-11),
        ]);
        let text = p.to_json().to_string();
        let q = CostProfile::parse(&text).unwrap();
        assert_eq!(q.to_json().to_string(), text);
    }

    #[test]
    fn profile_rejects_hostile_documents() {
        // truncated
        assert!(CostProfile::parse("{\"version\":1,\"coeffs\":{\"dense_f").is_err());
        // version mismatch / missing
        assert!(CostProfile::parse("{\"version\":99,\"coeffs\":{}}").is_err());
        assert!(CostProfile::parse("{\"coeffs\":{}}").is_err());
        // unknown kernel class
        assert!(CostProfile::parse("{\"version\":1,\"coeffs\":{\"warp_speed\":1.0}}").is_err());
        // non-numeric / negative coefficients
        assert!(CostProfile::parse("{\"version\":1,\"coeffs\":{\"dense_flop\":null}}").is_err());
        assert!(CostProfile::parse("{\"version\":1,\"coeffs\":{\"dense_flop\":-1.0}}").is_err());
        // wrong kind
        assert!(CostProfile::parse("{\"version\":1,\"kind\":\"something else\",\"coeffs\":{}}").is_err());
        // hostile per-pool overlays / topology metadata
        assert!(CostProfile::parse("{\"version\":1,\"coeffs\":{},\"pools\":{}}").is_err());
        assert!(CostProfile::parse("{\"version\":1,\"coeffs\":{},\"pools\":[{\"warp_speed\":1.0}]}").is_err());
        assert!(CostProfile::parse("{\"version\":1,\"coeffs\":{},\"pools\":[{\"dense_flop\":-2.0}]}").is_err());
        assert!(CostProfile::parse("{\"version\":1,\"coeffs\":{},\"topology\":{\"nodes\":1,\"cores_per_node\":4}}").is_err());
        assert!(CostProfile::parse("{\"version\":1,\"coeffs\":{},\"topology\":{\"nodes\":-1,\"cores_per_node\":4,\"pinned\":true}}").is_err());
    }

    #[test]
    fn pool_of_shard_inverts_part_range() {
        for n in 1..40usize {
            for k in 1..8usize {
                for p in 0..k {
                    for s in part_range(n, k, p) {
                        assert_eq!(pool_of_shard(s, n, k), p, "s={s} n={n} k={k}");
                    }
                }
            }
        }
        assert_eq!(pool_of_shard(0, 1, 1), 0);
        assert_eq!(pool_of_shard(5, 3, 2), pool_of_shard(2, 3, 2)); // clamped
    }

    #[test]
    fn fit_pools_respects_sample_floor() {
        // pool 0: plenty of samples at a slow rate; pool 1: too few samples
        let mut samples = Vec::new();
        let mut rng = Rng::new(99);
        for i in 0..(POOL_SAMPLE_FLOOR * 2) {
            let mut f = TaskFeats::default();
            let bytes = (rng.uniform() * 5000.0).floor() + 1.0;
            f.add(KernelClass::MatBytes, bytes);
            // pool 0 streams at half the speed of pool 1
            let (pool, rate) = if i < POOL_SAMPLE_FLOOR { (0, 2e-9) } else if i < POOL_SAMPLE_FLOOR + 8 { (1, 1e-9) } else { (0, 2e-9) };
            samples.push(Sample { feats: f, nrhs: 1, pool, secs: bytes * rate });
        }
        let p = fit_pools(&samples, 2).unwrap();
        assert!(p.has_pool_coeffs());
        assert_eq!(p.pools().len(), 2);
        assert!(!p.pools()[0].is_empty(), "pool 0 is above the floor");
        assert!(p.pools()[1].is_empty(), "pool 1 is below the floor and must fall back");
        assert_eq!(p.pool_source_labels(), vec!["per-pool", "global"]);
        // pool 0's overlay rate ≈ 2e-9; pool 1 falls back to the global fit
        let c0 = p.pool_coeff(0, KernelClass::MatBytes);
        assert!((c0 - 2e-9).abs() / 2e-9 < 1e-2, "{c0}");
        assert_eq!(p.pool_coeff(1, KernelClass::MatBytes), p.coeff(KernelClass::MatBytes));
        // out-of-range pool ids behave like the global fit
        assert_eq!(p.pool_coeff(7, KernelClass::MatBytes), p.coeff(KernelClass::MatBytes));
    }

    #[test]
    fn fit_pools_single_pool_matches_global_fit() {
        let mut f = TaskFeats::default();
        f.add(KernelClass::MatBytes, 100.0);
        let samples: Vec<Sample> = (0..4).map(|_| Sample { feats: f.clone(), nrhs: 1, pool: 0, secs: 1e-7 }).collect();
        let p = fit_pools(&samples, 1).unwrap();
        assert!(!p.has_pool_coeffs());
        assert!(p.pools().is_empty());
    }

    #[test]
    fn profile_json_round_trips_pools_and_topology() {
        let overlay0: BTreeMap<KernelClass, f64> = [(KernelClass::MatBytes, 2e-9), (KernelClass::DenseFlop, 5e-11)].into_iter().collect();
        let mut p = CostProfile::from_coeffs(&[(KernelClass::MatBytes, 1e-9), (KernelClass::DenseFlop, 4e-11)])
            .with_pools(vec![overlay0, BTreeMap::new()]);
        p.topology = Some(TopologyMeta { nodes: 2, cores_per_node: 8, pinned: true });
        let text = p.to_json().to_string();
        let q = CostProfile::parse(&text).unwrap();
        assert_eq!(q.to_json().to_string(), text);
        assert!(q.has_pool_coeffs());
        assert_eq!(q.pools().len(), 2);
        assert_eq!(q.topology, Some(TopologyMeta { nodes: 2, cores_per_node: 8, pinned: true }));
        assert_eq!(q.pool_coeff(0, KernelClass::MatBytes), 2e-9);
        assert_eq!(q.pool_coeff(1, KernelClass::MatBytes), 1e-9);
        // overlay's unknown decode width: falls back to overlay MatBytes, not global
        assert_eq!(q.pool_coeff(0, KernelClass::Decode(CodecFamily::Aflp, 4)), 2e-9);
        // a pre-NUMA document (no pools/topology) still parses
        let old = CostProfile::parse("{\"version\":1,\"coeffs\":{\"mat_bytes\":1e-9}}").unwrap();
        assert!(!old.has_pool_coeffs());
        assert_eq!(old.topology, None);
    }

    #[test]
    fn load_drops_pools_on_topology_mismatch() {
        let overlay: BTreeMap<KernelClass, f64> = [(KernelClass::MatBytes, 2e-9)].into_iter().collect();
        let mut p = CostProfile::from_coeffs(&[(KernelClass::MatBytes, 1e-9)]).with_pools(vec![overlay]);
        // a shape no real test box has, so it always mismatches here
        p.topology = Some(TopologyMeta { nodes: 7, cores_per_node: 3, pinned: true });
        let path = std::env::temp_dir().join(format!("hmatc-prof-{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        p.save(&path).unwrap();
        let loaded = CostProfile::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(!loaded.has_pool_coeffs(), "mismatched per-pool overlays must be dropped");
        assert!(loaded.is_usable(), "the global fit survives");
        assert_eq!(loaded.pool_coeff(0, KernelClass::MatBytes), 1e-9);
    }

    #[test]
    fn balance_level_pools_starves_the_slow_pool() {
        // 2 pools, 4 bins (bins 0-1 → pool 0, bins 2-3 → pool 1); pool 1 is
        // 4x slower, so it must receive well under half the bytes
        let n = 64usize;
        let ids: Vec<usize> = (0..n).collect();
        let fast: Vec<f64> = (0..n).map(|i| 1.0 + (i % 5) as f64).collect();
        let slow: Vec<f64> = fast.iter().map(|c| c * 4.0).collect();
        let scratch = vec![0usize; n];
        let costs_pp = vec![fast.clone(), slow];
        let shards = balance_level_pools(&ids, &costs_pp, &scratch, 4);
        assert_eq!(shards.len(), 4);
        // every task exactly once
        let mut seen = vec![false; n];
        for s in &shards {
            for &t in &s.tasks {
                assert!(!seen[t]);
                seen[t] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        let fast_work: f64 = shards[..2].iter().flat_map(|s| &s.tasks).map(|&t| fast[t]).sum();
        let slow_work: f64 = shards[2..].iter().flat_map(|s| &s.tasks).map(|&t| fast[t]).sum();
        assert!(slow_work < fast_work / 2.0, "slow pool got {slow_work} of {} total", fast_work + slow_work);
    }

    #[test]
    fn rebalance_levels_pools_never_increases_makespan() {
        let mut rng = Rng::new(17);
        for trial in 0..10 {
            let n = 24 + trial * 9;
            let static_costs: Vec<f64> = (0..n).map(|_| 1.0 + rng.uniform()).collect();
            let scratch = vec![0usize; n];
            let ids: Vec<usize> = (0..n).collect();
            let (a, b) = ids.split_at(n / 2);
            let level_ids = vec![a.to_vec(), b.to_vec()];
            let old: Vec<Vec<Shard>> = level_ids.iter().map(|ids| balance_level(ids, &static_costs, &scratch, 6)).collect();
            let costs_pp: Vec<Vec<f64>> = (0..3)
                .map(|_| {
                    let scale = 10f64.powf(rng.range(-1.0, 1.0));
                    static_costs.iter().map(|c| c * scale * (1.0 + rng.uniform())).collect()
                })
                .collect();
            let new = rebalance_levels_pools(&old, &level_ids, &costs_pp, &scratch, 6);
            assert!(
                makespan_pools(&new, &costs_pp) <= makespan_pools(&old, &costs_pp) + 1e-12,
                "trial {trial}"
            );
        }
    }

    #[test]
    fn rebalance_levels_pools_keeps_incumbent_on_degenerate_costs() {
        let ids = vec![vec![0usize, 1, 2]];
        let costs = vec![1.0, 2.0, 3.0];
        let scratch = vec![0usize; 3];
        let old = vec![balance_level(&ids[0], &costs, &scratch, 2)];
        // one poisoned pool vector disables the whole per-pool rebalance
        let poisoned = vec![costs.clone(), vec![f64::NAN; 3]];
        let kept = rebalance_levels_pools(&old, &ids, &poisoned, &scratch, 2);
        assert_eq!(kept[0].len(), old[0].len());
        assert!(rebalance_levels_pools(&old, &ids, &[], &scratch, 2).len() == old.len());
    }
}
