//! Axis-aligned bounding boxes for clusters.

use crate::geometry::Point3;

/// Axis-aligned bounding box in R³.
#[derive(Clone, Copy, Debug)]
pub struct BBox {
    pub lo: Point3,
    pub hi: Point3,
}

impl BBox {
    /// Empty box (inverted bounds).
    pub fn empty() -> Self {
        BBox {
            lo: Point3::new(f64::INFINITY, f64::INFINITY, f64::INFINITY),
            hi: Point3::new(f64::NEG_INFINITY, f64::NEG_INFINITY, f64::NEG_INFINITY),
        }
    }

    /// Bounding box of a point set.
    pub fn of(points: &[Point3]) -> Self {
        let mut b = Self::empty();
        for p in points {
            b.insert(*p);
        }
        b
    }

    /// Expand to contain `p`.
    pub fn insert(&mut self, p: Point3) {
        self.lo = Point3::new(self.lo.x.min(p.x), self.lo.y.min(p.y), self.lo.z.min(p.z));
        self.hi = Point3::new(self.hi.x.max(p.x), self.hi.y.max(p.y), self.hi.z.max(p.z));
    }

    /// Box diameter (diagonal length).
    pub fn diameter(&self) -> f64 {
        if self.lo.x > self.hi.x {
            return 0.0;
        }
        self.hi.sub(self.lo).norm()
    }

    /// Minimal distance between two boxes (0 if they intersect/touch).
    pub fn distance(&self, o: &BBox) -> f64 {
        let d = |alo: f64, ahi: f64, blo: f64, bhi: f64| -> f64 {
            if ahi < blo {
                blo - ahi
            } else if bhi < alo {
                alo - bhi
            } else {
                0.0
            }
        };
        let dx = d(self.lo.x, self.hi.x, o.lo.x, o.hi.x);
        let dy = d(self.lo.y, self.hi.y, o.lo.y, o.hi.y);
        let dz = d(self.lo.z, self.hi.z, o.lo.z, o.hi.z);
        (dx * dx + dy * dy + dz * dz).sqrt()
    }

    /// Index of the longest axis (0/1/2).
    pub fn longest_axis(&self) -> usize {
        let e = self.hi.sub(self.lo);
        if e.x >= e.y && e.x >= e.z {
            0
        } else if e.y >= e.z {
            1
        } else {
            2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn of_points_and_diameter() {
        let b = BBox::of(&[Point3::new(0.0, 0.0, 0.0), Point3::new(1.0, 2.0, 2.0)]);
        assert_eq!(b.diameter(), 3.0);
        assert_eq!(b.longest_axis(), 1); // y and z tie at 2.0 → y wins
    }

    #[test]
    fn distance_disjoint_and_overlap() {
        let a = BBox::of(&[Point3::new(0.0, 0.0, 0.0), Point3::new(1.0, 1.0, 1.0)]);
        let b = BBox::of(&[Point3::new(2.0, 0.0, 0.0), Point3::new(3.0, 1.0, 1.0)]);
        assert_eq!(a.distance(&b), 1.0);
        let c = BBox::of(&[Point3::new(0.5, 0.5, 0.5), Point3::new(2.0, 2.0, 2.0)]);
        assert_eq!(a.distance(&c), 0.0);
    }

    #[test]
    fn empty_box() {
        assert_eq!(BBox::empty().diameter(), 0.0);
    }
}
