//! Admissibility conditions (paper §2.2): standard, weak and off-diagonal
//! (HODLR/BLR).

use super::tree::ClusterTree;

/// Decides whether a block (τ, σ) can be approximated in low rank.
pub trait Admissibility: Sync {
    /// `rt`/`ct` are the row/column cluster trees, `r`/`c` node ids.
    fn admissible(&self, rt: &ClusterTree, r: usize, ct: &ClusterTree, c: usize) -> bool;

    /// Short name for reports.
    fn name(&self) -> &'static str;
}

/// Standard admissibility: min(diam τ, diam σ) ≤ η · dist(τ, σ).
#[derive(Clone, Copy, Debug)]
pub struct StdAdmissibility {
    pub eta: f64,
}

impl StdAdmissibility {
    pub fn new(eta: f64) -> Self {
        StdAdmissibility { eta }
    }
}

impl Admissibility for StdAdmissibility {
    fn admissible(&self, rt: &ClusterTree, r: usize, ct: &ClusterTree, c: usize) -> bool {
        let br = &rt.node(r).bbox;
        let bc = &ct.node(c).bbox;
        let dist = br.distance(bc);
        dist > 0.0 && br.diameter().min(bc.diameter()) <= self.eta * dist
    }

    fn name(&self) -> &'static str {
        "standard"
    }
}

/// Weak admissibility (Hackbusch/Khoromskij/Kriemann 2004): clusters merely
/// need positive distance.
#[derive(Clone, Copy, Debug, Default)]
pub struct WeakAdmissibility;

impl Admissibility for WeakAdmissibility {
    fn admissible(&self, rt: &ClusterTree, r: usize, ct: &ClusterTree, c: usize) -> bool {
        rt.node(r).bbox.distance(&ct.node(c).bbox) > 0.0
    }

    fn name(&self) -> &'static str {
        "weak"
    }
}

/// Off-diagonal admissibility: τ and σ are disjoint index ranges of the
/// *same* tree. With a deep binary tree this yields HODLR, with a flat tree
/// BLR (Remark 2.4).
#[derive(Clone, Copy, Debug, Default)]
pub struct OffDiagAdmissibility;

impl Admissibility for OffDiagAdmissibility {
    fn admissible(&self, rt: &ClusterTree, r: usize, _ct: &ClusterTree, c: usize) -> bool {
        let a = rt.node(r);
        let b = rt.node(c);
        a.end <= b.begin || b.end <= a.begin
    }

    fn name(&self) -> &'static str {
        "off-diagonal"
    }
}

/// HODLR admissibility = off-diagonal on a deep binary tree.
pub type HodlrAdmissibility = OffDiagAdmissibility;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::fibonacci_sphere;

    #[test]
    fn std_adm_diagonal_blocks_inadmissible() {
        let pts = fibonacci_sphere(256);
        let ct = ClusterTree::build(&pts, 16);
        let adm = StdAdmissibility::new(2.0);
        // a node against itself: distance 0 → inadmissible
        for id in 0..ct.nodes.len() {
            assert!(!adm.admissible(&ct, id, &ct, id));
        }
    }

    #[test]
    fn std_adm_far_blocks_admissible() {
        let pts = fibonacci_sphere(512);
        let ct = ClusterTree::build(&pts, 16);
        let adm = StdAdmissibility::new(2.0);
        // find two deep leaves with large distance
        let mut found = false;
        for &a in &ct.leaves {
            for &b in &ct.leaves {
                let d = ct.node(a).bbox.distance(&ct.node(b).bbox);
                if d > 1.0 {
                    assert!(adm.admissible(&ct, a, &ct, b));
                    found = true;
                }
            }
        }
        assert!(found);
    }

    #[test]
    fn offdiag_adm_by_ranges() {
        let pts = fibonacci_sphere(128);
        let ct = ClusterTree::build(&pts, 16);
        let adm = OffDiagAdmissibility;
        let root = ct.root();
        let c = &ct.node(root).children;
        assert!(c.len() == 2);
        assert!(adm.admissible(&ct, c[0], &ct, c[1]));
        assert!(!adm.admissible(&ct, root, &ct, c[0])); // overlapping ranges
    }

    #[test]
    fn weak_weaker_than_standard() {
        let pts = fibonacci_sphere(512);
        let ct = ClusterTree::build(&pts, 16);
        let weak = WeakAdmissibility;
        let std = StdAdmissibility::new(2.0);
        for &a in &ct.leaves {
            for &b in &ct.leaves {
                if std.admissible(&ct, a, &ct, b) {
                    assert!(weak.admissible(&ct, a, &ct, b));
                }
            }
        }
    }
}
