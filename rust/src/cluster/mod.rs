//! Cluster trees, block trees and admissibility conditions (paper §2.2).

mod admissibility;
mod bbox;
mod block;
mod tree;

pub use admissibility::{Admissibility, HodlrAdmissibility, OffDiagAdmissibility, StdAdmissibility, WeakAdmissibility};
pub use bbox::BBox;
pub use block::{BlockNode, BlockTree};
pub use tree::{ClusterNode, ClusterTree};

/// Alias kept for BLR construction: with a flat (depth-1) cluster tree, the
/// off-diagonal condition yields exactly the BLR p×q block partition.
pub type BlkAdmissibility = OffDiagAdmissibility;
