//! Block tree (Definition 2.2) over a pair of cluster trees.

use super::admissibility::Admissibility;
use super::tree::ClusterTree;
use std::sync::Arc;

/// A block (τ, σ) in the block tree.
#[derive(Clone, Debug)]
pub struct BlockNode {
    /// Row cluster node id.
    pub row: usize,
    /// Column cluster node id.
    pub col: usize,
    /// Child block ids.
    pub children: Vec<usize>,
    /// Whether the admissibility condition held (leaf → low-rank block).
    pub admissible: bool,
    /// Level (distance from the root block).
    pub level: usize,
}

impl BlockNode {
    pub fn is_leaf(&self) -> bool {
        self.children.is_empty()
    }
}

/// The block tree T_{I×J}.
#[derive(Clone, Debug)]
pub struct BlockTree {
    pub row_ct: Arc<ClusterTree>,
    pub col_ct: Arc<ClusterTree>,
    /// Node storage; node 0 is the root block (I, J).
    pub nodes: Vec<BlockNode>,
    /// Leaf block ids.
    pub leaves: Vec<usize>,
    /// Leaf block ids per *row cluster* node id: the sets M_τ^r (Def. 2.5).
    pub row_blocks: Vec<Vec<usize>>,
    /// Leaf block ids per *column cluster* node id: the sets M_σ^c.
    pub col_blocks: Vec<Vec<usize>>,
}

impl BlockTree {
    /// Build from cluster trees and an admissibility condition.
    pub fn build(row_ct: &Arc<ClusterTree>, col_ct: &Arc<ClusterTree>, adm: &dyn Admissibility) -> BlockTree {
        let mut nodes: Vec<BlockNode> = Vec::new();
        nodes.push(BlockNode { row: row_ct.root(), col: col_ct.root(), children: vec![], admissible: false, level: 0 });
        let mut stack = vec![0usize];
        while let Some(id) = stack.pop() {
            let (r, c, level) = {
                let nd = &nodes[id];
                (nd.row, nd.col, nd.level)
            };
            let is_adm = adm.admissible(row_ct, r, col_ct, c);
            nodes[id].admissible = is_adm;
            let rleaf = row_ct.node(r).is_leaf();
            let cleaf = col_ct.node(c).is_leaf();
            if is_adm || rleaf || cleaf {
                continue; // leaf block
            }
            for &rc in &row_ct.node(r).children {
                for &cc in &col_ct.node(c).children {
                    let cid = nodes.len();
                    nodes.push(BlockNode { row: rc, col: cc, children: vec![], admissible: false, level: level + 1 });
                    nodes[id].children.push(cid);
                    stack.push(cid);
                }
            }
        }

        let leaves: Vec<usize> = (0..nodes.len()).filter(|&i| nodes[i].is_leaf()).collect();
        let mut row_blocks = vec![Vec::new(); row_ct.nodes.len()];
        let mut col_blocks = vec![Vec::new(); col_ct.nodes.len()];
        for &l in &leaves {
            row_blocks[nodes[l].row].push(l);
            col_blocks[nodes[l].col].push(l);
        }
        BlockTree { row_ct: row_ct.clone(), col_ct: col_ct.clone(), nodes, leaves, row_blocks, col_blocks }
    }

    /// Matrix dimensions (nrows, ncols).
    pub fn shape(&self) -> (usize, usize) {
        (self.row_ct.len(), self.col_ct.len())
    }

    /// Node accessor.
    pub fn node(&self, id: usize) -> &BlockNode {
        &self.nodes[id]
    }

    /// Number of admissible (low-rank) leaves.
    pub fn num_admissible(&self) -> usize {
        self.leaves.iter().filter(|&&l| self.nodes[l].admissible).count()
    }

    /// Number of dense (inadmissible) leaves.
    pub fn num_dense(&self) -> usize {
        self.leaves.len() - self.num_admissible()
    }

    /// Maximum block level.
    pub fn depth(&self) -> usize {
        self.nodes.iter().map(|n| n.level).max().unwrap_or(0)
    }

    /// Check that the leaves tile the full I×J product (used by tests).
    pub fn validate_partition(&self) -> Result<(), String> {
        let (m, n) = self.shape();
        let mut cover = vec![0u8; m * n];
        for &l in &self.leaves {
            let nd = &self.nodes[l];
            let rr = self.row_ct.node(nd.row).range();
            let cr = self.col_ct.node(nd.col).range();
            for j in cr {
                for i in rr.clone() {
                    let idx = j * m + i;
                    if cover[idx] != 0 {
                        return Err(format!("position ({i},{j}) covered twice"));
                    }
                    cover[idx] = 1;
                }
            }
        }
        if cover.iter().any(|&c| c == 0) {
            return Err("partition does not cover I×J".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::admissibility::{OffDiagAdmissibility, StdAdmissibility};
    use crate::geometry::fibonacci_sphere;

    fn sphere_tree(n: usize, n_min: usize) -> Arc<ClusterTree> {
        Arc::new(ClusterTree::build(&fibonacci_sphere(n), n_min))
    }

    #[test]
    fn leaves_partition_product() {
        let ct = sphere_tree(200, 16);
        let bt = BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0));
        bt.validate_partition().unwrap();
    }

    #[test]
    fn has_admissible_and_dense_blocks() {
        let ct = sphere_tree(400, 16);
        let bt = BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0));
        assert!(bt.num_admissible() > 0, "no low-rank blocks");
        assert!(bt.num_dense() > 0, "no dense blocks");
    }

    #[test]
    fn hodlr_structure() {
        // off-diagonal admissibility: every leaf off the diagonal is
        // admissible, diagonal leaves are dense
        let ct = sphere_tree(256, 32);
        let bt = BlockTree::build(&ct, &ct, &OffDiagAdmissibility);
        bt.validate_partition().unwrap();
        for &l in &bt.leaves {
            let nd = bt.node(l);
            if nd.admissible {
                let a = ct.node(nd.row);
                let b = ct.node(nd.col);
                assert!(a.end <= b.begin || b.end <= a.begin);
            } else {
                assert_eq!(nd.row, nd.col); // diagonal
            }
        }
    }

    #[test]
    fn row_block_lists_consistent() {
        let ct = sphere_tree(300, 16);
        let bt = BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0));
        let total: usize = bt.row_blocks.iter().map(|v| v.len()).sum();
        assert_eq!(total, bt.leaves.len());
        for (tau, blocks) in bt.row_blocks.iter().enumerate() {
            for &b in blocks {
                assert_eq!(bt.node(b).row, tau);
            }
        }
    }
}
