//! Cluster tree (Definition 2.1): hierarchical disjoint partition of the
//! index set, built by cardinality-balanced bisection along the longest
//! bounding-box axis.

use super::bbox::BBox;
use crate::geometry::Point3;

/// A cluster: contiguous range of *internal* (permuted) positions.
#[derive(Clone, Debug)]
pub struct ClusterNode {
    /// Half-open range in the permuted ordering.
    pub begin: usize,
    pub end: usize,
    /// Bounding box of the cluster's points.
    pub bbox: BBox,
    /// Child node ids (empty for leaves).
    pub children: Vec<usize>,
    /// Distance from the root.
    pub level: usize,
    /// Parent node id (root: usize::MAX).
    pub parent: usize,
}

impl ClusterNode {
    /// Number of indices in the cluster.
    pub fn size(&self) -> usize {
        self.end - self.begin
    }

    pub fn is_leaf(&self) -> bool {
        self.children.is_empty()
    }

    /// Internal index range.
    pub fn range(&self) -> std::ops::Range<usize> {
        self.begin..self.end
    }
}

/// Cluster tree over an index set with geometry.
#[derive(Clone, Debug)]
pub struct ClusterTree {
    /// Node storage; node 0 is the root.
    pub nodes: Vec<ClusterNode>,
    /// perm[internal position] = external (original) index.
    pub perm: Vec<usize>,
    /// inv_perm[external index] = internal position.
    pub inv_perm: Vec<usize>,
    /// Leaf node ids.
    pub leaves: Vec<usize>,
    /// Node ids grouped by level.
    pub levels: Vec<Vec<usize>>,
}

impl ClusterTree {
    /// Build by recursive median bisection until clusters have ≤ `n_min`
    /// indices.
    pub fn build(points: &[Point3], n_min: usize) -> ClusterTree {
        Self::build_with_depth(points, n_min, usize::MAX)
    }

    /// Build a flat (BLR) clustering: order the indices geometrically, then
    /// cut the root into equal chunks of ≈`block_size` — a depth-1 tree.
    pub fn build_blr(points: &[Point3], block_size: usize) -> ClusterTree {
        // Geometric ordering from a deep tree, then re-chunk.
        let deep = Self::build(points, block_size.max(1));
        let n = points.len();
        let perm = deep.perm.clone();
        let mut inv_perm = vec![0; n];
        for (pos, &e) in perm.iter().enumerate() {
            inv_perm[e] = pos;
        }
        let mut nodes = Vec::new();
        let root_bbox = BBox::of(points);
        nodes.push(ClusterNode { begin: 0, end: n, bbox: root_bbox, children: vec![], level: 0, parent: usize::MAX });
        let nblocks = n.div_ceil(block_size.max(1));
        let mut leaves = Vec::new();
        for b in 0..nblocks {
            let begin = b * block_size;
            let end = ((b + 1) * block_size).min(n);
            let bbox = BBox::of(&perm[begin..end].iter().map(|&e| points[e]).collect::<Vec<_>>());
            let id = nodes.len();
            nodes.push(ClusterNode { begin, end, bbox, children: vec![], level: 1, parent: 0 });
            nodes[0].children.push(id);
            leaves.push(id);
        }
        let levels = vec![vec![0], leaves.clone()];
        ClusterTree { nodes, perm, inv_perm, leaves, levels }
    }

    /// Build with a maximum depth (used in tests and HODLR setups).
    pub fn build_with_depth(points: &[Point3], n_min: usize, max_depth: usize) -> ClusterTree {
        let n = points.len();
        assert!(n > 0, "empty point set");
        let n_min = n_min.max(1);
        let mut perm: Vec<usize> = (0..n).collect();
        let mut nodes: Vec<ClusterNode> = Vec::new();

        // Iterative recursion with an explicit stack: (node id to fill).
        struct Work {
            id: usize,
            begin: usize,
            end: usize,
            level: usize,
        }
        let bbox = BBox::of(points);
        nodes.push(ClusterNode { begin: 0, end: n, bbox, children: vec![], level: 0, parent: usize::MAX });
        let mut stack = vec![Work { id: 0, begin: 0, end: n, level: 0 }];
        while let Some(w) = stack.pop() {
            let size = w.end - w.begin;
            if size <= n_min || w.level >= max_depth {
                continue; // leaf
            }
            // Median split along longest axis of the node's bbox.
            let axis = nodes[w.id].bbox.longest_axis();
            let mid = w.begin + size / 2;
            perm[w.begin..w.end].select_nth_unstable_by(mid - w.begin, |&a, &b| {
                points[a].coord(axis).partial_cmp(&points[b].coord(axis)).unwrap()
            });
            for (b, e) in [(w.begin, mid), (mid, w.end)] {
                if b == e {
                    continue;
                }
                let cb = BBox::of(&perm[b..e].iter().map(|&i| points[i]).collect::<Vec<_>>());
                let cid = nodes.len();
                nodes.push(ClusterNode { begin: b, end: e, bbox: cb, children: vec![], level: w.level + 1, parent: w.id });
                nodes[w.id].children.push(cid);
                stack.push(Work { id: cid, begin: b, end: e, level: w.level + 1 });
            }
        }

        let mut inv_perm = vec![0; n];
        for (pos, &e) in perm.iter().enumerate() {
            inv_perm[e] = pos;
        }
        let leaves: Vec<usize> = (0..nodes.len()).filter(|&i| nodes[i].is_leaf()).collect();
        let depth = nodes.iter().map(|nd| nd.level).max().unwrap_or(0);
        let mut levels = vec![Vec::new(); depth + 1];
        for (i, nd) in nodes.iter().enumerate() {
            levels[nd.level].push(i);
        }
        ClusterTree { nodes, perm, inv_perm, leaves, levels }
    }

    /// Number of indices.
    pub fn len(&self) -> usize {
        self.nodes[0].size()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Tree depth (levels - 1).
    pub fn depth(&self) -> usize {
        self.levels.len() - 1
    }

    /// Root node id.
    pub fn root(&self) -> usize {
        0
    }

    /// Node accessor.
    pub fn node(&self, id: usize) -> &ClusterNode {
        &self.nodes[id]
    }

    /// External indices covered by a node, in internal order.
    pub fn indices(&self, id: usize) -> &[usize] {
        let nd = &self.nodes[id];
        &self.perm[nd.begin..nd.end]
    }

    /// Permute an external-ordering vector into internal ordering.
    pub fn to_internal(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.len());
        (0..x.len()).map(|pos| x[self.perm[pos]]).collect()
    }

    /// Permute an internal-ordering vector back to external ordering.
    pub fn to_external(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.len());
        let mut out = vec![0.0; x.len()];
        for (pos, &e) in self.perm.iter().enumerate() {
            out[e] = x[pos];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::fibonacci_sphere;
    use crate::util::Rng;

    #[test]
    fn partition_property() {
        // every node is the disjoint union of its children (Def. 2.1)
        let pts = fibonacci_sphere(500);
        let ct = ClusterTree::build(&pts, 32);
        for nd in &ct.nodes {
            if nd.is_leaf() {
                continue;
            }
            let mut covered: Vec<std::ops::Range<usize>> = nd.children.iter().map(|&c| ct.nodes[c].range()).collect();
            covered.sort_by_key(|r| r.start);
            assert_eq!(covered.first().unwrap().start, nd.begin);
            assert_eq!(covered.last().unwrap().end, nd.end);
            for w in covered.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
    }

    #[test]
    fn perm_is_permutation() {
        let pts = fibonacci_sphere(300);
        let ct = ClusterTree::build(&pts, 16);
        let mut seen = vec![false; 300];
        for &e in &ct.perm {
            assert!(!seen[e]);
            seen[e] = true;
        }
        assert!(seen.iter().all(|&s| s));
        for e in 0..300 {
            assert_eq!(ct.perm[ct.inv_perm[e]], e);
        }
    }

    #[test]
    fn leaves_small() {
        let pts = fibonacci_sphere(1000);
        let ct = ClusterTree::build(&pts, 64);
        for &l in &ct.leaves {
            assert!(ct.nodes[l].size() <= 64);
            assert!(ct.nodes[l].size() > 0);
        }
    }

    #[test]
    fn permutation_roundtrip() {
        let pts = fibonacci_sphere(128);
        let ct = ClusterTree::build(&pts, 8);
        let mut rng = Rng::new(1);
        let x = rng.vector(128);
        let xi = ct.to_internal(&x);
        let xe = ct.to_external(&xi);
        assert_eq!(x, xe);
    }

    #[test]
    fn blr_is_flat() {
        let pts = fibonacci_sphere(520);
        let ct = ClusterTree::build_blr(&pts, 64);
        assert_eq!(ct.depth(), 1);
        assert_eq!(ct.leaves.len(), 520usize.div_ceil(64));
        let total: usize = ct.leaves.iter().map(|&l| ct.nodes[l].size()).sum();
        assert_eq!(total, 520);
    }

    #[test]
    fn bbox_contains_points() {
        let pts = fibonacci_sphere(200);
        let ct = ClusterTree::build(&pts, 20);
        for (id, nd) in ct.nodes.iter().enumerate() {
            for &e in ct.indices(id) {
                let p = pts[e];
                assert!(p.x >= nd.bbox.lo.x - 1e-12 && p.x <= nd.bbox.hi.x + 1e-12);
            }
        }
    }
}
