//! Column-major dense matrix.

use crate::util::Rng;

/// Dense matrix, column-major storage (like Fortran/BLAS).
#[derive(Clone, Debug, PartialEq)]
pub struct DMatrix {
    nrows: usize,
    ncols: usize,
    data: Vec<f64>,
}

impl DMatrix {
    /// All-zero matrix.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        DMatrix { nrows, ncols, data: vec![0.0; nrows * ncols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a generator f(i, j).
    pub fn from_fn(nrows: usize, ncols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(nrows, ncols);
        for j in 0..ncols {
            for i in 0..nrows {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Wrap existing column-major data.
    pub fn from_vec(nrows: usize, ncols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), nrows * ncols, "data length mismatch");
        DMatrix { nrows, ncols, data }
    }

    /// Random matrix with standard normal entries.
    pub fn random(nrows: usize, ncols: usize, rng: &mut Rng) -> Self {
        let mut m = Self::zeros(nrows, ncols);
        rng.fill_normal(&mut m.data);
        m
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Underlying column-major data.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable underlying data.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Column `j` as a slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.ncols);
        &self.data[j * self.nrows..(j + 1) * self.nrows]
    }

    /// Column `j` as a mutable slice.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.ncols);
        &mut self.data[j * self.nrows..(j + 1) * self.nrows]
    }

    /// Two distinct mutable columns (for Jacobi rotations).
    pub fn cols_mut2(&mut self, j0: usize, j1: usize) -> (&mut [f64], &mut [f64]) {
        assert!(j0 < j1 && j1 < self.ncols);
        let (a, b) = self.data.split_at_mut(j1 * self.nrows);
        (&mut a[j0 * self.nrows..(j0 + 1) * self.nrows], &mut b[..self.nrows])
    }

    /// Transposed copy.
    pub fn transpose(&self) -> DMatrix {
        let mut t = DMatrix::zeros(self.ncols, self.nrows);
        for j in 0..self.ncols {
            for i in 0..self.nrows {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Scale all entries.
    pub fn scale(&mut self, a: f64) {
        for v in &mut self.data {
            *v *= a;
        }
    }

    /// self += a * other (same shape).
    pub fn add_scaled(&mut self, a: f64, other: &DMatrix) {
        assert_eq!((self.nrows, self.ncols), (other.nrows, other.ncols));
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x += a * y;
        }
    }

    /// Copy of the sub-matrix rows×cols given by half-open ranges.
    pub fn sub(&self, rows: std::ops::Range<usize>, cols: std::ops::Range<usize>) -> DMatrix {
        let mut m = DMatrix::zeros(rows.len(), cols.len());
        for (jj, j) in cols.clone().enumerate() {
            let src = &self.col(j)[rows.clone()];
            m.col_mut(jj).copy_from_slice(src);
        }
        m
    }

    /// Keep only the first `k` columns.
    pub fn take_cols(mut self, k: usize) -> DMatrix {
        assert!(k <= self.ncols);
        self.data.truncate(k * self.nrows);
        self.ncols = k;
        self
    }

    /// Horizontal concatenation [self | other].
    pub fn hcat(&self, other: &DMatrix) -> DMatrix {
        assert_eq!(self.nrows, other.nrows);
        let mut data = Vec::with_capacity((self.ncols + other.ncols) * self.nrows);
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        DMatrix { nrows: self.nrows, ncols: self.ncols + other.ncols, data }
    }

    /// Vertical concatenation [self; other].
    pub fn vcat(&self, other: &DMatrix) -> DMatrix {
        assert_eq!(self.ncols, other.ncols);
        let mut m = DMatrix::zeros(self.nrows + other.nrows, self.ncols);
        for j in 0..self.ncols {
            m.col_mut(j)[..self.nrows].copy_from_slice(self.col(j));
            m.col_mut(j)[self.nrows..].copy_from_slice(other.col(j));
        }
        m
    }

    /// Recover the underlying column-major storage (buffer reuse in pooled
    /// paths: wrap with [`DMatrix::from_vec`], unwrap with this).
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Number of stored bytes (FP64).
    pub fn byte_size(&self) -> usize {
        self.data.len() * std::mem::size_of::<f64>()
    }

    /// Max-abs entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, x| m.max(x.abs()))
    }
}

impl std::ops::Index<(usize, usize)> for DMatrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.nrows && j < self.ncols);
        &self.data[j * self.nrows + i]
    }
}

impl std::ops::IndexMut<(usize, usize)> for DMatrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.nrows && j < self.ncols);
        &mut self.data[j * self.nrows + i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_col_major() {
        let m = DMatrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(1, 0)], 2.0);
        assert_eq!(m[(0, 1)], 3.0);
        assert_eq!(m[(1, 1)], 4.0);
        assert_eq!(m.col(1), &[3.0, 4.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let m = DMatrix::random(5, 3, &mut rng);
        let t = m.transpose().transpose();
        assert_eq!(m, t);
    }

    #[test]
    fn concat_shapes() {
        let a = DMatrix::zeros(3, 2);
        let b = DMatrix::zeros(3, 4);
        assert_eq!(a.hcat(&b).ncols(), 6);
        let c = DMatrix::zeros(5, 2);
        assert_eq!(a.vcat(&c).nrows(), 8);
    }

    #[test]
    fn vcat_values() {
        let a = DMatrix::from_vec(1, 2, vec![1.0, 2.0]);
        let b = DMatrix::from_vec(2, 2, vec![3.0, 4.0, 5.0, 6.0]);
        let v = a.vcat(&b);
        assert_eq!(v[(0, 0)], 1.0);
        assert_eq!(v[(1, 0)], 3.0);
        assert_eq!(v[(2, 0)], 4.0);
        assert_eq!(v[(0, 1)], 2.0);
        assert_eq!(v[(2, 1)], 6.0);
    }

    #[test]
    fn sub_block() {
        let m = DMatrix::from_fn(4, 4, |i, j| (i * 10 + j) as f64);
        let s = m.sub(1..3, 2..4);
        assert_eq!(s[(0, 0)], 12.0);
        assert_eq!(s[(1, 1)], 23.0);
    }

    #[test]
    fn eye_and_norm() {
        let i = DMatrix::eye(4);
        assert_eq!(i.fro_norm(), 2.0);
    }
}
