//! Thin Householder QR for tall-skinny matrices (low-rank factors).

use super::{blas, DMatrix};

/// Thin QR factorization A = Q·R with Q (m×k) having orthonormal columns and
/// R (k×k) upper triangular, k = min(m, n) = n for our tall-skinny uses.
///
/// Classical Householder with explicit Q accumulation; m and n are small
/// (n ≤ a few hundred) in all call sites.
pub fn qr_thin(a: &DMatrix) -> (DMatrix, DMatrix) {
    let m = a.nrows();
    let n = a.ncols();
    let k = m.min(n);
    let mut r = a.clone();
    // Householder vectors stored per step.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(k);

    for j in 0..k {
        // Build Householder vector for column j, rows j..m.
        let col = &r.col(j)[j..m];
        let alpha = blas::nrm2(col);
        if alpha == 0.0 {
            vs.push(vec![0.0; m - j]);
            continue;
        }
        let mut v: Vec<f64> = col.to_vec();
        let beta = if v[0] >= 0.0 { -alpha } else { alpha };
        v[0] -= beta;
        let vnorm = blas::nrm2(&v);
        if vnorm > 0.0 {
            for x in &mut v {
                *x /= vnorm;
            }
        }
        // Apply H = I - 2 v v^T to R[j.., j..].
        for jj in j..n {
            let cjj = &mut r.col_mut(jj)[j..m];
            let w = 2.0 * blas::dot(&v, cjj);
            for (ci, vi) in cjj.iter_mut().zip(&v) {
                *ci -= w * vi;
            }
        }
        vs.push(v);
    }

    // Zero strictly-lower part of R, keep top k rows.
    let mut rk = DMatrix::zeros(k, n);
    for j in 0..n {
        for i in 0..k.min(j + 1) {
            rk[(i, j)] = r[(i, j)];
        }
    }

    // Accumulate Q = H_0 H_1 ... H_{k-1} * [I_k; 0].
    let mut q = DMatrix::zeros(m, k);
    for i in 0..k {
        q[(i, i)] = 1.0;
    }
    for j in (0..k).rev() {
        let v = &vs[j];
        if v.iter().all(|x| *x == 0.0) {
            continue;
        }
        for jj in 0..k {
            let cjj = &mut q.col_mut(jj)[j..m];
            let w = 2.0 * blas::dot(v, cjj);
            for (ci, vi) in cjj.iter_mut().zip(v) {
                *ci -= w * vi;
            }
        }
    }
    (q, rk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::blas::{matmul, Trans};
    use crate::util::Rng;

    fn check_qr(m: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = DMatrix::random(m, n, &mut rng);
        let (q, r) = qr_thin(&a);
        let k = m.min(n);
        assert_eq!(q.ncols(), k);
        assert_eq!(r.nrows(), k);
        // Q^T Q = I
        let qtq = matmul(&q, Trans::Yes, &q, Trans::No);
        for i in 0..k {
            for j in 0..k {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq[(i, j)] - want).abs() < 1e-10, "qtq({i},{j})={}", qtq[(i, j)]);
            }
        }
        // QR = A
        let qr = matmul(&q, Trans::No, &r, Trans::No);
        for j in 0..n {
            for i in 0..m {
                assert!((qr[(i, j)] - a[(i, j)]).abs() < 1e-10);
            }
        }
        // R upper triangular
        for j in 0..n {
            for i in (j + 1)..k {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn qr_tall() {
        check_qr(20, 5, 1);
    }

    #[test]
    fn qr_square() {
        check_qr(8, 8, 2);
    }

    #[test]
    fn qr_wide() {
        check_qr(4, 9, 3);
    }

    #[test]
    fn qr_rank_deficient() {
        // Two identical columns.
        let mut rng = Rng::new(4);
        let c = rng.vector(10);
        let mut a = DMatrix::zeros(10, 2);
        a.col_mut(0).copy_from_slice(&c);
        a.col_mut(1).copy_from_slice(&c);
        let (q, r) = qr_thin(&a);
        let qr = matmul(&q, Trans::No, &r, Trans::No);
        for j in 0..2 {
            for i in 0..10 {
                assert!((qr[(i, j)] - a[(i, j)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn qr_zero_matrix() {
        let a = DMatrix::zeros(6, 3);
        let (q, r) = qr_thin(&a);
        assert_eq!(q.nrows(), 6);
        assert_eq!(r.fro_norm(), 0.0);
    }
}
