//! Hand-written BLAS-like kernels (levels 1–3), column-major.
//!
//! These replace oneMKL from the paper's testbed. The MVM hot path only needs
//! `gemv` on column-major data — which is the axpy-per-column form below and
//! auto-vectorizes with `target-cpu=native`. `gemm` is used at construction
//! time (basis products, recompression) and by the multi-RHS coordinator path.

use super::DMatrix;

/// y += a * x (slices of equal length).
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += a * x[i];
    }
}

/// Dot product.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    // 4-way unrolled to break the fp-add dependency chain.
    let n = x.len();
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let mut i = 0;
    while i + 4 <= n {
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
        i += 4;
    }
    while i < n {
        s0 += x[i] * y[i];
        i += 1;
    }
    (s0 + s1) + (s2 + s3)
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// y += alpha * A * x  (A: nrows×ncols column-major).
pub fn gemv(alpha: f64, a: &DMatrix, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), a.ncols());
    debug_assert_eq!(y.len(), a.nrows());
    for j in 0..a.ncols() {
        let axj = alpha * x[j];
        if axj != 0.0 {
            axpy(axj, a.col(j), y);
        }
    }
}

/// y += alpha * A^T * x  (A: nrows×ncols column-major, y has ncols entries).
pub fn gemv_transposed(alpha: f64, a: &DMatrix, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), a.nrows());
    debug_assert_eq!(y.len(), a.ncols());
    for j in 0..a.ncols() {
        y[j] += alpha * dot(a.col(j), x);
    }
}

/// Transpose flag for [`gemm`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Trans {
    No,
    Yes,
}

/// C += alpha * op(A) * op(B). Shapes: op(A) m×k, op(B) k×n, C m×n.
pub fn gemm(alpha: f64, a: &DMatrix, ta: Trans, b: &DMatrix, tb: Trans, c: &mut DMatrix) {
    let (m, ka) = match ta {
        Trans::No => (a.nrows(), a.ncols()),
        Trans::Yes => (a.ncols(), a.nrows()),
    };
    let (kb, n) = match tb {
        Trans::No => (b.nrows(), b.ncols()),
        Trans::Yes => (b.ncols(), b.nrows()),
    };
    assert_eq!(ka, kb, "gemm inner dimension mismatch");
    assert_eq!(c.nrows(), m);
    assert_eq!(c.ncols(), n);
    let k = ka;
    match (ta, tb) {
        (Trans::No, Trans::No) => {
            // C(:,j) += alpha * sum_l A(:,l) * B(l,j)
            for j in 0..n {
                let bcol = b.col(j);
                let ccol = c.col_mut(j);
                for l in 0..k {
                    let w = alpha * bcol[l];
                    if w != 0.0 {
                        axpy(w, a.col(l), ccol);
                    }
                }
            }
        }
        (Trans::Yes, Trans::No) => {
            // C(i,j) += alpha * dot(A(:,i), B(:,j))
            for j in 0..n {
                let bcol = b.col(j);
                let ccol = c.col_mut(j);
                for i in 0..m {
                    ccol[i] += alpha * dot(a.col(i), bcol);
                }
            }
        }
        (Trans::No, Trans::Yes) => {
            // C(:,j) += alpha * sum_l A(:,l) * B(j,l)
            for j in 0..n {
                let ccol = c.col_mut(j);
                for l in 0..k {
                    let w = alpha * b[(j, l)];
                    if w != 0.0 {
                        axpy(w, a.col(l), ccol);
                    }
                }
            }
        }
        (Trans::Yes, Trans::Yes) => {
            for j in 0..n {
                let ccol = c.col_mut(j);
                for i in 0..m {
                    let acol = a.col(i);
                    let mut s = 0.0;
                    for l in 0..k {
                        s += acol[l] * b[(j, l)];
                    }
                    ccol[i] += alpha * s;
                }
            }
        }
    }
}

/// Convenience: C = op(A)*op(B) freshly allocated.
pub fn matmul(a: &DMatrix, ta: Trans, b: &DMatrix, tb: Trans) -> DMatrix {
    let m = match ta {
        Trans::No => a.nrows(),
        Trans::Yes => a.ncols(),
    };
    let n = match tb {
        Trans::No => b.ncols(),
        Trans::Yes => b.nrows(),
    };
    let mut c = DMatrix::zeros(m, n);
    gemm(1.0, a, ta, b, tb, &mut c);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_mm(a: &DMatrix, b: &DMatrix) -> DMatrix {
        let mut c = DMatrix::zeros(a.nrows(), b.ncols());
        for i in 0..a.nrows() {
            for j in 0..b.ncols() {
                let mut s = 0.0;
                for l in 0..a.ncols() {
                    s += a[(i, l)] * b[(l, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    fn assert_close(a: &DMatrix, b: &DMatrix, tol: f64) {
        assert_eq!((a.nrows(), a.ncols()), (b.nrows(), b.ncols()));
        for j in 0..a.ncols() {
            for i in 0..a.nrows() {
                assert!((a[(i, j)] - b[(i, j)]).abs() < tol, "({i},{j}): {} vs {}", a[(i, j)], b[(i, j)]);
            }
        }
    }

    #[test]
    fn dot_and_axpy() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut y = [1.0; 5];
        assert_eq!(dot(&x, &x), 55.0);
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0, 9.0, 11.0]);
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn gemv_matches_naive() {
        let mut rng = Rng::new(3);
        let a = DMatrix::random(7, 5, &mut rng);
        let x = rng.vector(5);
        let mut y = rng.vector(7);
        let mut y2 = y.clone();
        gemv(1.5, &a, &x, &mut y);
        for i in 0..7 {
            let mut s = 0.0;
            for j in 0..5 {
                s += a[(i, j)] * x[j];
            }
            y2[i] += 1.5 * s;
        }
        for i in 0..7 {
            assert!((y[i] - y2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn gemv_t_matches_naive() {
        let mut rng = Rng::new(4);
        let a = DMatrix::random(7, 5, &mut rng);
        let x = rng.vector(7);
        let mut y = vec![0.0; 5];
        gemv_transposed(2.0, &a, &x, &mut y);
        for j in 0..5 {
            let mut s = 0.0;
            for i in 0..7 {
                s += a[(i, j)] * x[i];
            }
            assert!((y[j] - 2.0 * s).abs() < 1e-12);
        }
    }

    #[test]
    fn gemm_all_transpose_combos() {
        let mut rng = Rng::new(5);
        let a = DMatrix::random(4, 6, &mut rng);
        let b = DMatrix::random(6, 3, &mut rng);
        assert_close(&matmul(&a, Trans::No, &b, Trans::No), &naive_mm(&a, &b), 1e-12);

        let at = a.transpose();
        assert_close(&matmul(&at, Trans::Yes, &b, Trans::No), &naive_mm(&a, &b), 1e-12);

        let bt = b.transpose();
        assert_close(&matmul(&a, Trans::No, &bt, Trans::Yes), &naive_mm(&a, &b), 1e-12);
        assert_close(&matmul(&at, Trans::Yes, &bt, Trans::Yes), &naive_mm(&a, &b), 1e-12);
    }
}
