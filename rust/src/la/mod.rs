//! Dense linear algebra substrate (no BLAS/LAPACK in the sandbox).
//!
//! Column-major [`DMatrix`], hand-written level-1/2/3 kernels ([`blas`]),
//! Householder QR ([`qr`]) and one-sided Jacobi SVD ([`svd`]) — everything the
//! hierarchical formats need: the matrices involved are either tall-skinny
//! low-rank factors or small (≤ a few hundred) square coupling blocks, for
//! which Jacobi SVD is accurate and fast enough.

pub mod blas;
pub mod matrix;
pub mod qr;
pub mod svd;

pub use blas::{axpy, dot, gemm, gemv, gemv_transposed, matmul, nrm2, Trans};
pub use matrix::DMatrix;
pub use qr::qr_thin;
pub use svd::{svd_adaptive, svd_jacobi, svd_of_product, Svd};
