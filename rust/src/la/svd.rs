//! One-sided Jacobi SVD.
//!
//! Accurate for the small/skinny matrices appearing in low-rank arithmetic
//! (coupling blocks, k×k products of QR factors). For tall matrices we first
//! reduce with a thin QR so Jacobi operates on a k×k matrix.

use super::{blas, qr::qr_thin, DMatrix};

/// Singular value decomposition A = U · diag(s) · Vᵀ with U (m×k), V (n×k),
/// k = min(m,n), singular values descending.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: DMatrix,
    pub s: Vec<f64>,
    pub v: DMatrix,
}

impl Svd {
    /// Rank for relative tolerance `eps`: smallest r with s[r] <= eps * s[0].
    pub fn rank(&self, eps: f64) -> usize {
        if self.s.is_empty() || self.s[0] == 0.0 {
            return 0;
        }
        let cutoff = eps * self.s[0];
        self.s.iter().take_while(|&&x| x > cutoff).count()
    }

    /// Truncate to the first `k` singular triplets.
    pub fn truncate(mut self, k: usize) -> Svd {
        let k = k.min(self.s.len());
        self.s.truncate(k);
        Svd { u: self.u.take_cols(k), s: self.s, v: self.v.take_cols(k) }
    }
}

/// One-sided Jacobi on a square-ish matrix: returns SVD of `a`.
/// For m > 2n, a thin QR reduction is applied first.
pub fn svd_jacobi(a: &DMatrix) -> Svd {
    let m = a.nrows();
    let n = a.ncols();
    if m < n {
        // SVD of transpose, swap factors.
        let t = svd_jacobi(&a.transpose());
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    if m > 2 * n && n > 0 {
        // QR reduction: A = Q R, SVD(R) = Ur S V^T, U = Q Ur.
        let (q, r) = qr_thin(a);
        let inner = svd_jacobi(&r);
        let u = blas::matmul(&q, blas::Trans::No, &inner.u, blas::Trans::No);
        return Svd { u, s: inner.s, v: inner.v };
    }

    // Work matrix W := A; accumulate V as product of rotations.
    let mut w = a.clone();
    let mut v = DMatrix::eye(n);
    let eps = 1e-15;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // 2x2 Gram sub-matrix of W^T W.
                let (wp, wq) = w.cols_mut2(p, q);
                let app = blas::dot(wp, wp);
                let aqq = blas::dot(wq, wq);
                let apq = blas::dot(wp, wq);
                if apq.abs() <= eps * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(f64::MIN_POSITIVE));
                // Jacobi rotation zeroing apq.
                let zeta = (aqq - app) / (2.0 * apq);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wi = wp[i];
                    let wj = wq[i];
                    wp[i] = c * wi - s * wj;
                    wq[i] = s * wi + c * wj;
                }
                let (vp, vq) = v.cols_mut2(p, q);
                for i in 0..n {
                    let vi = vp[i];
                    let vj = vq[i];
                    vp[i] = c * vi - s * vj;
                    vq[i] = s * vi + c * vj;
                }
            }
        }
        if off < 1e-14 {
            break;
        }
    }

    // Singular values = column norms of W; U = W / s.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n).map(|j| blas::nrm2(w.col(j))).collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());
    let mut u = DMatrix::zeros(m, n);
    let mut vv = DMatrix::zeros(n, n);
    let mut s = vec![0.0; n];
    for (jj, &j) in order.iter().enumerate() {
        s[jj] = norms[j];
        if norms[j] > 0.0 {
            let src = w.col(j);
            let dst = u.col_mut(jj);
            for i in 0..m {
                dst[i] = src[i] / norms[j];
            }
        }
        vv.col_mut(jj).copy_from_slice(v.col(j));
    }
    Svd { u, s, v: vv }
}

/// Accuracy-aware SVD for tall concatenations (basis construction): exact
/// Jacobi for small problems, randomized range finder with one power
/// iteration for large ones, with an exact fallback when the requested
/// accuracy would exhaust the sample space.
pub fn svd_adaptive(a: &DMatrix, eps: f64) -> Svd {
    let m = a.nrows();
    let c = a.ncols();
    if c <= 128 || m <= 2 * c {
        return svd_jacobi(a);
    }
    let s = (c / 4).max(96).min(c);
    let mut rng = crate::util::Rng::new(0x5eed ^ ((m as u64) << 20) ^ c as u64);
    let omega = DMatrix::random(c, s, &mut rng);
    // Y = A Ω, one power iteration: Q = qr(A · qr(Aᵀ · qr(Y).Q).Q)
    let y = blas::matmul(a, blas::Trans::No, &omega, blas::Trans::No);
    let (q0, _) = qr_thin(&y);
    let z = blas::matmul(a, blas::Trans::Yes, &q0, blas::Trans::No);
    let (q1, _) = qr_thin(&z);
    let y2 = blas::matmul(a, blas::Trans::No, &q1, blas::Trans::No);
    let (q, _) = qr_thin(&y2);
    // B = Qᵀ A (s×c), small SVD
    let b = blas::matmul(&q, blas::Trans::Yes, a, blas::Trans::No);
    let inner = svd_jacobi(&b);
    // if the eps-rank saturates the sample, the sketch may be lossy: redo exact
    if inner.rank(eps) * 10 >= s * 9 {
        return svd_jacobi(a);
    }
    let u = blas::matmul(&q, blas::Trans::No, &inner.u, blas::Trans::No);
    Svd { u, s: inner.s, v: inner.v }
}

/// SVD of a low-rank product U·Vᵀ without forming it: QR both factors, Jacobi
/// on the small k×k core. Returns (W, s, X) with U·Vᵀ = W·diag(s)·Xᵀ.
pub fn svd_of_product(u: &DMatrix, v: &DMatrix) -> Svd {
    assert_eq!(u.ncols(), v.ncols());
    if u.ncols() == 0 {
        return Svd { u: DMatrix::zeros(u.nrows(), 0), s: vec![], v: DMatrix::zeros(v.nrows(), 0) };
    }
    let (qu, ru) = qr_thin(u);
    let (qv, rv) = qr_thin(v);
    // core = R_u * R_v^T  (k×k)
    let core = blas::matmul(&ru, blas::Trans::No, &rv, blas::Trans::Yes);
    let inner = svd_jacobi(&core);
    let w = blas::matmul(&qu, blas::Trans::No, &inner.u, blas::Trans::No);
    let x = blas::matmul(&qv, blas::Trans::No, &inner.v, blas::Trans::No);
    Svd { u: w, s: inner.s, v: x }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::blas::{matmul, Trans};
    use crate::util::Rng;

    fn reconstruct(svd: &Svd) -> DMatrix {
        let mut us = svd.u.clone();
        for j in 0..svd.s.len() {
            let sj = svd.s[j];
            for x in us.col_mut(j) {
                *x *= sj;
            }
        }
        matmul(&us, Trans::No, &svd.v, Trans::Yes)
    }

    fn check_svd(a: &DMatrix, tol: f64) {
        let svd = svd_jacobi(a);
        // descending singular values
        for i in 1..svd.s.len() {
            assert!(svd.s[i - 1] >= svd.s[i] - 1e-14);
        }
        // reconstruction
        let r = reconstruct(&svd);
        for j in 0..a.ncols() {
            for i in 0..a.nrows() {
                assert!((r[(i, j)] - a[(i, j)]).abs() < tol, "({i},{j}) {} vs {}", r[(i, j)], a[(i, j)]);
            }
        }
        // orthogonality of V
        let vtv = matmul(&svd.v, Trans::Yes, &svd.v, Trans::No);
        for i in 0..vtv.nrows() {
            for j in 0..vtv.ncols() {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv[(i, j)] - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn svd_random_square() {
        let mut rng = Rng::new(11);
        check_svd(&DMatrix::random(8, 8, &mut rng), 1e-9);
    }

    #[test]
    fn svd_tall_with_qr_reduction() {
        let mut rng = Rng::new(12);
        check_svd(&DMatrix::random(50, 6, &mut rng), 1e-9);
    }

    #[test]
    fn svd_wide() {
        let mut rng = Rng::new(13);
        check_svd(&DMatrix::random(5, 12, &mut rng), 1e-9);
    }

    #[test]
    fn svd_known_singular_values() {
        // diag(3, 2, 1) has singular values 3, 2, 1.
        let mut a = DMatrix::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 2.0;
        a[(2, 2)] = 1.0;
        let svd = svd_jacobi(&a);
        assert!((svd.s[0] - 3.0).abs() < 1e-12);
        assert!((svd.s[1] - 2.0).abs() < 1e-12);
        assert!((svd.s[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn svd_rank_and_truncate() {
        // rank-2 matrix from outer products
        let mut rng = Rng::new(14);
        let u = DMatrix::random(10, 2, &mut rng);
        let v = DMatrix::random(7, 2, &mut rng);
        let a = matmul(&u, Trans::No, &v, Trans::Yes);
        let svd = svd_jacobi(&a);
        assert_eq!(svd.rank(1e-10), 2);
        let t = svd.truncate(2);
        let r = reconstruct(&t);
        for j in 0..7 {
            for i in 0..10 {
                assert!((r[(i, j)] - a[(i, j)]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn svd_of_product_matches_direct() {
        let mut rng = Rng::new(15);
        let u = DMatrix::random(20, 4, &mut rng);
        let v = DMatrix::random(15, 4, &mut rng);
        let direct = matmul(&u, Trans::No, &v, Trans::Yes);
        let svd = svd_of_product(&u, &v);
        let r = reconstruct(&svd);
        for j in 0..15 {
            for i in 0..20 {
                assert!((r[(i, j)] - direct[(i, j)]).abs() < 1e-9);
            }
        }
        let dsvd = svd_jacobi(&direct);
        for i in 0..4 {
            assert!((svd.s[i] - dsvd.s[i]).abs() < 1e-9 * dsvd.s[0].max(1.0));
        }
    }

    #[test]
    fn svd_zero_matrix() {
        let a = DMatrix::zeros(4, 3);
        let svd = svd_jacobi(&a);
        assert!(svd.s.iter().all(|&x| x == 0.0));
        assert_eq!(svd.rank(1e-10), 0);
    }
}
