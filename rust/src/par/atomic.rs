//! Atomic f64 accumulation, used by the "atomic updates" MVM variant
//! (Ida et al. [21] in the paper).

use std::sync::atomic::{AtomicU64, Ordering};

/// Add `val` to the f64 stored in `slot` with a CAS loop.
#[inline]
pub fn atomic_add_f64(slot: &AtomicU64, val: f64) {
    let mut cur = slot.load(Ordering::Relaxed);
    loop {
        let new = f64::from_bits(cur) + val;
        match slot.compare_exchange_weak(cur, new.to_bits(), Ordering::AcqRel, Ordering::Relaxed) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

/// Reinterpret an exclusive f64 slice as atomic words for concurrent
/// accumulation. Sound: `AtomicU64` has the same size/alignment as `u64`/`f64`
/// and the exclusive borrow guarantees no other non-atomic access.
pub fn as_atomic_f64(xs: &mut [f64]) -> &[AtomicU64] {
    unsafe { std::slice::from_raw_parts(xs.as_mut_ptr() as *const AtomicU64, xs.len()) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::pool::parallel_for;

    #[test]
    fn atomic_add_basic() {
        let slot = AtomicU64::new(1.5f64.to_bits());
        atomic_add_f64(&slot, 2.25);
        assert_eq!(f64::from_bits(slot.load(Ordering::Relaxed)), 3.75);
    }

    #[test]
    fn concurrent_accumulation_is_exact_for_integers() {
        let mut y = vec![0.0f64; 8];
        {
            let ay = as_atomic_f64(&mut y);
            parallel_for(0..10_000, 64, |i| {
                atomic_add_f64(&ay[i % 8], 1.0);
            });
        }
        for v in &y {
            assert_eq!(*v, 1250.0);
        }
    }
}
