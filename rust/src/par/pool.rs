//! **Work-sharing** fork-join thread pool with a scoped spawn API, plus the
//! work-stealing execution layer built on top of it.
//!
//! [`ThreadPool`] itself is deliberately a *shared-queue* (work-sharing)
//! pool: one global injector queue (mutex + condvar) served by N workers.
//! [`ThreadPool::scope`] provides structured parallelism: tasks may borrow
//! from the enclosing stack frame because `scope` does not return until every
//! spawned task has completed. While waiting, the scoping thread *helps*:
//! it pops and runs queued tasks, so even `ThreadPool::new(0)` makes progress
//! and recursive spawns cannot deadlock. The queue lock is not a bottleneck
//! below ~10⁶ tasks/s — and the plan executors spawn only one task per shard
//! or per worker slot, far below that.
//!
//! **Work stealing** is layered on top as [`StealSet`]: per-slot Chase–Lev
//! deques ([`crate::par::deque`]) seeded with precomputed chunk indices, and
//! one long-running *worker-loop task per slot* spawned into a
//! `ThreadPool::scope`. Each loop drains its own deque bottom-first, then
//! steals from the other slots' tops — real dynamic rebalancing for workloads
//! whose per-chunk runtimes vary (codec decode times do), not just a shared
//! queue. The plan layer selects between the static and stealing backends
//! through [`crate::plan::Executor`] (`HMATC_EXEC` / `--executor`).
//!
//! The cost-model calibration layer ([`crate::plan::costmodel`]) times work
//! at the `f(slot, item)` boundary and relies on exactly the guarantees
//! documented here: every item runs **exactly once** per [`StealSet::run`]
//! (so a per-item accumulator slot receives one sample per run, whichever
//! slot stole the item), and `run` does not return before all items
//! completed (so accumulators are only read back after the barrier).

use super::deque::{Steal, WorkDeque};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Task>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// A fixed-size worker pool.
pub struct ThreadPool {
    shared: std::sync::Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    nthreads: usize,
    /// NUMA node this pool is homed on (`None`: unplaced).
    node: Option<usize>,
    /// Whether every worker is pinned to the requested cpu set. Workers pin
    /// themselves at startup and clear this on failure, so it can transition
    /// `true → false` shortly after construction (pinning is best-effort).
    pinned: std::sync::Arc<AtomicBool>,
}

impl ThreadPool {
    /// Create a pool with `n` workers (0 is allowed: all work is done by
    /// scoping threads).
    pub fn new(n: usize) -> Self {
        ThreadPool::with_affinity(n, None, &[])
    }

    /// Create a pool homed on NUMA node `node` whose workers pin themselves
    /// to `cpus` via `sched_setaffinity` before entering the worker loop.
    /// An empty `cpus` list spawns a plain unpinned pool; a pin failure on
    /// any worker degrades the whole pool to "unpinned" (see
    /// [`ThreadPool::is_pinned`]) but never fails construction.
    pub fn with_affinity(n: usize, node: Option<usize>, cpus: &[usize]) -> Self {
        let shared = std::sync::Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let want_pin = !cpus.is_empty() && n > 0;
        let pinned = std::sync::Arc::new(AtomicBool::new(want_pin));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let sh = shared.clone();
            let cpus: Vec<usize> = cpus.to_vec();
            let pinned = pinned.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("hmatc-worker-{i}"))
                    .spawn(move || {
                        if !cpus.is_empty() && !super::topology::pin_current_thread(&cpus) {
                            pinned.store(false, Ordering::Release);
                        }
                        worker_loop(&sh)
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { shared, workers: Mutex::new(workers), nthreads: n, node, pinned }
    }

    /// NUMA node this pool was homed on at construction, if any.
    pub fn node(&self) -> Option<usize> {
        self.node
    }

    /// Whether all workers hold their requested cpu affinity. `false` for
    /// pools built without affinity and for pools that degraded because
    /// `sched_setaffinity` failed. Workers pin asynchronously at startup, so
    /// a failure may surface only after construction returns.
    pub fn is_pinned(&self) -> bool {
        self.pinned.load(Ordering::Acquire)
    }

    /// The process-wide pool. Worker count from `HMATC_THREADS` or the number
    /// of available cores minus one (the scoping thread helps).
    pub fn global() -> &'static ThreadPool {
        static POOL: OnceLock<ThreadPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let n = std::env::var("HMATC_THREADS")
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
                .unwrap_or_else(|| std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4));
            ThreadPool::new(n.saturating_sub(1))
        })
    }

    /// Number of worker threads (excluding helping scope threads).
    pub fn num_threads(&self) -> usize {
        self.nthreads
    }

    fn push_task(&self, t: Task) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(t);
        drop(q);
        self.shared.cv.notify_one();
    }

    fn try_pop(&self) -> Option<Task> {
        self.shared.queue.lock().unwrap().pop_front()
    }

    /// Structured fork-join: run `f` with a [`Scope`] handle; returns after
    /// all tasks spawned into the scope (transitively) have finished.
    /// Panics in tasks are surfaced as a panic here.
    pub fn scope<'env, F, R>(&'env self, f: F) -> R
    where
        F: FnOnce(&Scope<'env>) -> R,
    {
        let scope = Scope {
            pool: self,
            pending: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            _env: std::marker::PhantomData,
        };
        let r = f(&scope);
        scope.wait();
        if scope.panicked.load(Ordering::Acquire) {
            panic!("a task spawned in ThreadPool::scope panicked");
        }
        r
    }

    /// Run two closures potentially in parallel, returning both results.
    pub fn join<RA, RB>(&self, a: impl FnOnce() -> RA + Send, b: impl FnOnce() -> RB + Send) -> (RA, RB)
    where
        RA: Send,
        RB: Send,
    {
        let mut rb: Option<RB> = None;
        let ra = self.scope(|s| {
            s.spawn(|_| rb = Some(b()));
            a()
        });
        (ra, rb.expect("join: task b did not run"))
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cv.notify_all();
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(sh: &Shared) {
    loop {
        let task = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break Some(t);
                }
                if sh.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        match task {
            Some(t) => t(),
            None => return,
        }
    }
}

/// Handle for spawning borrowing tasks inside [`ThreadPool::scope`].
pub struct Scope<'env> {
    pool: &'env ThreadPool,
    pending: AtomicUsize,
    panicked: AtomicBool,
    _env: std::marker::PhantomData<fn(&'env ()) -> &'env ()>,
}

/// Raw pointer wrapper so the task closure (which must be `Send`) can carry
/// the scope address across threads. Safe because `scope` outlives all tasks.
struct SendPtr<T>(*const T);
unsafe impl<T: Sync> Send for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Method (not field) access so closures capture the whole wrapper —
    /// capturing the raw-pointer *field* would lose the `Send` impl.
    fn get(&self) -> *const T {
        self.0
    }
}

impl<'env> Scope<'env> {
    /// Spawn a task that may borrow the environment of the scope and may
    /// itself spawn further tasks into the same scope.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce(&Scope<'env>) + Send + 'env,
    {
        self.pending.fetch_add(1, Ordering::AcqRel);
        let ptr = SendPtr(self as *const Scope<'env>);
        let task: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            // SAFETY: `scope` blocks in `wait()` until pending == 0, so the
            // Scope outlives this task; the decrement below is the last
            // access this task makes to the scope.
            let scope: &Scope<'env> = unsafe { &*ptr.get() };
            let result = catch_unwind(AssertUnwindSafe(|| f(scope)));
            if result.is_err() {
                scope.panicked.store(true, Ordering::Release);
            }
            scope.pending.fetch_sub(1, Ordering::AcqRel);
        });
        // SAFETY: lifetime erasure to 'static. Sound because `wait()` ensures
        // the task has finished before any 'env borrow expires.
        let task: Task = unsafe { std::mem::transmute(task) };
        self.pool.push_task(task);
    }

    /// Help-first wait: execute queued tasks until this scope drains.
    fn wait(&self) {
        let mut idle_spins = 0u32;
        while self.pending.load(Ordering::Acquire) > 0 {
            if let Some(t) = self.pool.try_pop() {
                t();
                idle_spins = 0;
            } else {
                idle_spins += 1;
                if idle_spins < 64 {
                    std::thread::yield_now();
                } else {
                    // Tasks are in flight on workers; nap briefly.
                    std::thread::sleep(std::time::Duration::from_micros(50));
                }
            }
        }
    }
}

/// Parallel loop over `range` with grain size `grain`, executed on the global
/// pool. `f` is called once per index, in unspecified order.
pub fn parallel_for<F>(range: std::ops::Range<usize>, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let grain = grain.max(1);
    let pool = ThreadPool::global();
    pool.scope(|s| split_range(s, range, grain, &f));
}

fn split_range<'env, F>(s: &Scope<'env>, range: std::ops::Range<usize>, grain: usize, f: &'env F)
where
    F: Fn(usize) + Sync,
{
    let len = range.end.saturating_sub(range.start);
    if len <= grain {
        for i in range {
            f(i);
        }
    } else {
        let mid = range.start + len / 2;
        let right = mid..range.end;
        s.spawn(move |s2| split_range(s2, right, grain, f));
        split_range(s, range.start..mid, grain, f);
    }
}

/// A reusable set of per-slot work-stealing deques plus the stealing worker
/// loops that drain them.
///
/// [`StealSet::run`] executes items `0..nitems` exactly once each on `pool`,
/// with up to `nslots` concurrently running worker loops. Items are seeded
/// round-robin across the slots' deques; a loop that drains its own deque
/// steals from the others, so dynamic imbalance (variable per-item runtimes)
/// is absorbed without a shared queue. `f(slot, item)` receives the worker
/// slot id so callers can hand each slot private scratch storage.
///
/// Deques are retained (and only ever grow) across calls: steady-state
/// execution allocates nothing.
#[derive(Default)]
pub struct StealSet {
    deques: Vec<WorkDeque>,
}

impl StealSet {
    pub fn new() -> StealSet {
        StealSet::default()
    }

    /// Run `f(slot, item)` for every `item` in `0..nitems`, each exactly
    /// once, with at most `nslots` concurrent invocations; invocations with
    /// the same `slot` never run concurrently. Returns after all items have
    /// completed (fork-join barrier). Takes `&mut self` so one `StealSet` is
    /// never shared by two overlapping runs.
    pub fn run(&mut self, pool: &ThreadPool, nslots: usize, nitems: usize, f: impl Fn(usize, usize) + Sync) {
        if nitems == 0 {
            return;
        }
        let nslots = nslots.clamp(1, nitems);
        let per_slot = nitems.div_ceil(nslots);
        if self.deques.len() < nslots {
            self.deques.resize_with(nslots, || WorkDeque::with_capacity(per_slot));
        }
        for d in &mut self.deques[..nslots] {
            if d.capacity() < per_slot {
                *d = WorkDeque::with_capacity(per_slot);
            }
        }
        // seed round-robin: LPT packing gives the caller's items roughly
        // equal costs, so this starts every slot with a comparable share
        // before any stealing (no ordering contract on the items themselves)
        for d in &self.deques[..nslots] {
            d.reset();
        }
        for item in 0..nitems {
            self.deques[item % nslots].push(item);
        }
        let deques: &[WorkDeque] = &self.deques[..nslots];
        let f = &f;
        pool.scope(|s| {
            // every slot is a pool task (panics stay inside the scope); the
            // scoping thread picks one up through help-first waiting, so a
            // zero-worker pool still progresses
            for slot in 0..nslots {
                s.spawn(move |_| steal_loop(deques, slot, f));
            }
        });
    }
}

/// One stealing worker loop: drain the own deque, then sweep the other slots
/// for steals; exit when every deque is observed empty with no lost race.
fn steal_loop(deques: &[WorkDeque], slot: usize, f: &(impl Fn(usize, usize) + Sync)) {
    let n = deques.len();
    loop {
        while let Some(item) = deques[slot].pop() {
            f(slot, item);
        }
        let mut stolen = None;
        let mut raced = false;
        for off in 1..n {
            match deques[(slot + off) % n].steal() {
                Steal::Taken(item) => {
                    stolen = Some(item);
                    break;
                }
                Steal::Retry => raced = true,
                Steal::Empty => {}
            }
        }
        match stolen {
            Some(item) => f(slot, item),
            // a lost CAS race means another thread is still making progress —
            // the item it took may spawn nothing, but its deque sibling might
            // still hold work; spin once more
            None if raced => std::thread::yield_now(),
            // every deque empty and no race lost: the level is drained (items
            // are only seeded before the loops start, never re-pushed)
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn scope_runs_all_tasks() {
        let pool = ThreadPool::new(4);
        let counter = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..100 {
                s.spawn(|_| {
                    counter.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn plain_pool_is_unplaced_and_unpinned() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.node(), None);
        assert!(!pool.is_pinned());
        let pinned = ThreadPool::with_affinity(0, Some(3), &[0]);
        assert_eq!(pinned.node(), Some(3));
        assert!(!pinned.is_pinned(), "zero workers: nothing to pin");
    }

    #[test]
    fn affinity_pool_degrades_on_pin_failure() {
        // cpu 1023 fits in the affinity mask but is (almost certainly) not an
        // online cpu here, so sched_setaffinity rejects the set and the pool
        // must degrade to unpinned instead of failing or wedging.
        let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let pool = ThreadPool::with_affinity(2, Some(0), &[1023]);
        // workers pin asynchronously at startup: poll for the degradation
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        while pool.is_pinned() && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let counter = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..16 {
                s.spawn(|_| {
                    counter.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 16, "degraded pool must still execute");
        if cfg!(target_os = "linux") && avail < 512 {
            assert!(!pool.is_pinned(), "pin to an offline cpu should report unpinned");
        }
    }

    #[test]
    fn zero_worker_pool_progresses() {
        let pool = ThreadPool::new(0);
        let counter = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..10 {
                s.spawn(|_| {
                    counter.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn recursive_spawn() {
        let pool = ThreadPool::new(2);
        let counter = AtomicUsize::new(0);
        fn rec<'e>(s: &Scope<'e>, depth: usize, c: &'e AtomicUsize) {
            c.fetch_add(1, Ordering::Relaxed);
            if depth > 0 {
                s.spawn(move |s2| rec(s2, depth - 1, c));
                s.spawn(move |s2| rec(s2, depth - 1, c));
            }
        }
        pool.scope(|s| rec(s, 6, &counter));
        assert_eq!(counter.load(Ordering::Relaxed), (1 << 7) - 1);
    }

    #[test]
    fn borrows_stack_data() {
        let pool = ThreadPool::new(3);
        let mut out = vec![0usize; 64];
        {
            let chunks: Vec<&mut [usize]> = out.chunks_mut(8).collect();
            pool.scope(|s| {
                for (i, chunk) in chunks.into_iter().enumerate() {
                    s.spawn(move |_| {
                        for (j, v) in chunk.iter_mut().enumerate() {
                            *v = i * 8 + j;
                        }
                    });
                }
            });
        }
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i);
        }
    }

    #[test]
    fn join_returns_both() {
        let pool = ThreadPool::new(2);
        let (a, b) = pool.join(|| 1 + 1, || "x".to_string());
        assert_eq!(a, 2);
        assert_eq!(b, "x");
    }

    #[test]
    fn parallel_for_covers_range() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(0..1000, 16, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn panic_propagates() {
        let pool = ThreadPool::new(2);
        pool.scope(|s| {
            s.spawn(|_| panic!("boom"));
        });
    }

    #[test]
    fn steal_set_runs_every_item_once() {
        let pool = ThreadPool::new(3);
        let mut set = StealSet::new();
        for &(nslots, nitems) in &[(1usize, 1usize), (4, 7), (4, 100), (8, 3)] {
            let hits: Vec<AtomicUsize> = (0..nitems).map(|_| AtomicUsize::new(0)).collect();
            set.run(&pool, nslots, nitems, |_slot, item| {
                hits[item].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "item {i} ({nslots} slots, {nitems} items)");
            }
        }
    }

    #[test]
    fn steal_set_slots_never_overlap() {
        // per-slot counters are mutated WITHOUT atomics through raw pointers:
        // any two concurrent invocations with the same slot id would race and
        // lose increments (caught under sum check below, and by miri/tsan)
        let pool = ThreadPool::new(4);
        let nslots = 6usize;
        let mut per_slot = vec![0u64; nslots];
        struct Cell(*mut u64);
        unsafe impl Send for Cell {}
        unsafe impl Sync for Cell {}
        let cells: Vec<Cell> = per_slot.iter_mut().map(|c| Cell(c as *mut u64)).collect();
        let mut set = StealSet::new();
        set.run(&pool, nslots, 500, |slot, _item| {
            // SAFETY: StealSet guarantees one live invocation per slot
            unsafe { *cells[slot].0 += 1 };
        });
        drop(cells);
        assert_eq!(per_slot.iter().sum::<u64>(), 500);
    }

    #[test]
    fn steal_set_zero_worker_pool_progresses() {
        let pool = ThreadPool::new(0);
        let mut set = StealSet::new();
        let count = AtomicUsize::new(0);
        set.run(&pool, 5, 37, |_s, _i| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 37);
    }
}
