//! Work-sharing fork-join thread pool with a scoped spawn API.
//!
//! Design: one global injector deque (mutex + condvar) served by N workers.
//! [`ThreadPool::scope`] provides structured parallelism: tasks may borrow
//! from the enclosing stack frame because `scope` does not return until every
//! spawned task has completed. While waiting, the scoping thread *helps*:
//! it pops and runs queued tasks, so even `ThreadPool::new(0)` makes progress
//! and recursive spawns cannot deadlock.
//!
//! Granularity guidance: tasks should be ≥ a few µs (one H-matrix block row
//! easily qualifies); the queue lock is not a bottleneck below ~10⁶ tasks/s.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Task>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// A fixed-size worker pool.
pub struct ThreadPool {
    shared: std::sync::Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    nthreads: usize,
}

impl ThreadPool {
    /// Create a pool with `n` workers (0 is allowed: all work is done by
    /// scoping threads).
    pub fn new(n: usize) -> Self {
        let shared = std::sync::Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let sh = shared.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("hmatc-worker-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn worker"),
            );
        }
        ThreadPool { shared, workers: Mutex::new(workers), nthreads: n }
    }

    /// The process-wide pool. Worker count from `HMATC_THREADS` or the number
    /// of available cores minus one (the scoping thread helps).
    pub fn global() -> &'static ThreadPool {
        static POOL: OnceLock<ThreadPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let n = std::env::var("HMATC_THREADS")
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
                .unwrap_or_else(|| std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4));
            ThreadPool::new(n.saturating_sub(1))
        })
    }

    /// Number of worker threads (excluding helping scope threads).
    pub fn num_threads(&self) -> usize {
        self.nthreads
    }

    fn push_task(&self, t: Task) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(t);
        drop(q);
        self.shared.cv.notify_one();
    }

    fn try_pop(&self) -> Option<Task> {
        self.shared.queue.lock().unwrap().pop_front()
    }

    /// Structured fork-join: run `f` with a [`Scope`] handle; returns after
    /// all tasks spawned into the scope (transitively) have finished.
    /// Panics in tasks are surfaced as a panic here.
    pub fn scope<'env, F, R>(&'env self, f: F) -> R
    where
        F: FnOnce(&Scope<'env>) -> R,
    {
        let scope = Scope {
            pool: self,
            pending: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            _env: std::marker::PhantomData,
        };
        let r = f(&scope);
        scope.wait();
        if scope.panicked.load(Ordering::Acquire) {
            panic!("a task spawned in ThreadPool::scope panicked");
        }
        r
    }

    /// Run two closures potentially in parallel, returning both results.
    pub fn join<RA, RB>(&self, a: impl FnOnce() -> RA + Send, b: impl FnOnce() -> RB + Send) -> (RA, RB)
    where
        RA: Send,
        RB: Send,
    {
        let mut rb: Option<RB> = None;
        let ra = self.scope(|s| {
            s.spawn(|_| rb = Some(b()));
            a()
        });
        (ra, rb.expect("join: task b did not run"))
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cv.notify_all();
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(sh: &Shared) {
    loop {
        let task = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break Some(t);
                }
                if sh.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        match task {
            Some(t) => t(),
            None => return,
        }
    }
}

/// Handle for spawning borrowing tasks inside [`ThreadPool::scope`].
pub struct Scope<'env> {
    pool: &'env ThreadPool,
    pending: AtomicUsize,
    panicked: AtomicBool,
    _env: std::marker::PhantomData<fn(&'env ()) -> &'env ()>,
}

/// Raw pointer wrapper so the task closure (which must be `Send`) can carry
/// the scope address across threads. Safe because `scope` outlives all tasks.
struct SendPtr<T>(*const T);
unsafe impl<T: Sync> Send for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Method (not field) access so closures capture the whole wrapper —
    /// capturing the raw-pointer *field* would lose the `Send` impl.
    fn get(&self) -> *const T {
        self.0
    }
}

impl<'env> Scope<'env> {
    /// Spawn a task that may borrow the environment of the scope and may
    /// itself spawn further tasks into the same scope.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce(&Scope<'env>) + Send + 'env,
    {
        self.pending.fetch_add(1, Ordering::AcqRel);
        let ptr = SendPtr(self as *const Scope<'env>);
        let task: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            // SAFETY: `scope` blocks in `wait()` until pending == 0, so the
            // Scope outlives this task; the decrement below is the last
            // access this task makes to the scope.
            let scope: &Scope<'env> = unsafe { &*ptr.get() };
            let result = catch_unwind(AssertUnwindSafe(|| f(scope)));
            if result.is_err() {
                scope.panicked.store(true, Ordering::Release);
            }
            scope.pending.fetch_sub(1, Ordering::AcqRel);
        });
        // SAFETY: lifetime erasure to 'static. Sound because `wait()` ensures
        // the task has finished before any 'env borrow expires.
        let task: Task = unsafe { std::mem::transmute(task) };
        self.pool.push_task(task);
    }

    /// Help-first wait: execute queued tasks until this scope drains.
    fn wait(&self) {
        let mut idle_spins = 0u32;
        while self.pending.load(Ordering::Acquire) > 0 {
            if let Some(t) = self.pool.try_pop() {
                t();
                idle_spins = 0;
            } else {
                idle_spins += 1;
                if idle_spins < 64 {
                    std::thread::yield_now();
                } else {
                    // Tasks are in flight on workers; nap briefly.
                    std::thread::sleep(std::time::Duration::from_micros(50));
                }
            }
        }
    }
}

/// Parallel loop over `range` with grain size `grain`, executed on the global
/// pool. `f` is called once per index, in unspecified order.
pub fn parallel_for<F>(range: std::ops::Range<usize>, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let grain = grain.max(1);
    let pool = ThreadPool::global();
    pool.scope(|s| split_range(s, range, grain, &f));
}

fn split_range<'env, F>(s: &Scope<'env>, range: std::ops::Range<usize>, grain: usize, f: &'env F)
where
    F: Fn(usize) + Sync,
{
    let len = range.end.saturating_sub(range.start);
    if len <= grain {
        for i in range {
            f(i);
        }
    } else {
        let mid = range.start + len / 2;
        let right = mid..range.end;
        s.spawn(move |s2| split_range(s2, right, grain, f));
        split_range(s, range.start..mid, grain, f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn scope_runs_all_tasks() {
        let pool = ThreadPool::new(4);
        let counter = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..100 {
                s.spawn(|_| {
                    counter.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn zero_worker_pool_progresses() {
        let pool = ThreadPool::new(0);
        let counter = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..10 {
                s.spawn(|_| {
                    counter.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn recursive_spawn() {
        let pool = ThreadPool::new(2);
        let counter = AtomicUsize::new(0);
        fn rec<'e>(s: &Scope<'e>, depth: usize, c: &'e AtomicUsize) {
            c.fetch_add(1, Ordering::Relaxed);
            if depth > 0 {
                s.spawn(move |s2| rec(s2, depth - 1, c));
                s.spawn(move |s2| rec(s2, depth - 1, c));
            }
        }
        pool.scope(|s| rec(s, 6, &counter));
        assert_eq!(counter.load(Ordering::Relaxed), (1 << 7) - 1);
    }

    #[test]
    fn borrows_stack_data() {
        let pool = ThreadPool::new(3);
        let mut out = vec![0usize; 64];
        {
            let chunks: Vec<&mut [usize]> = out.chunks_mut(8).collect();
            pool.scope(|s| {
                for (i, chunk) in chunks.into_iter().enumerate() {
                    s.spawn(move |_| {
                        for (j, v) in chunk.iter_mut().enumerate() {
                            *v = i * 8 + j;
                        }
                    });
                }
            });
        }
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i);
        }
    }

    #[test]
    fn join_returns_both() {
        let pool = ThreadPool::new(2);
        let (a, b) = pool.join(|| 1 + 1, || "x".to_string());
        assert_eq!(a, 2);
        assert_eq!(b, "x");
    }

    #[test]
    fn parallel_for_covers_range() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(0..1000, 16, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn panic_propagates() {
        let pool = ThreadPool::new(2);
        pool.scope(|s| {
            s.spawn(|_| panic!("boom"));
        });
    }
}
