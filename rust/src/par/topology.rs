//! CPU/NUMA topology discovery and placement primitives.
//!
//! **Discovery contract.** [`Topology::get`] inspects the machine exactly once
//! per process (the result is cached in a `OnceLock`):
//!
//! * On Linux with `HMATC_NUMA` unset or truthy, nodes are read from sysfs
//!   (`/sys/devices/system/node/node*/`): a node's cpu set comes from its
//!   `cpulist` file (`"0-3,8,10-11"` format) and its capacity from the
//!   `Node N MemTotal:` line of its `meminfo`. Memory-only nodes (empty
//!   `cpulist`) are skipped; nodes are sorted by id. Cpu lists are then
//!   intersected with the process's allowed cpuset (`sched_getaffinity`), so
//!   a container restricted to a cpu subset neither pins to nor counts cpus
//!   it cannot run on; if the intersection empties every node, discovery
//!   falls back as below.
//! * Everywhere else — non-Linux hosts, containers without sysfs, or
//!   `HMATC_NUMA=0` — discovery **falls back to a single synthetic node with
//!   an empty cpu list**. An empty cpu list is the "don't pin" sentinel: only
//!   cpu ids actually read from sysfs are ever passed to `sched_setaffinity`,
//!   so macOS/CI degrade gracefully to today's unpinned behaviour.
//!
//! **Pinning contract.** `HMATC_PIN=0` disables thread pinning (and node-local
//! memory binding) without affecting discovery, so per-node accounting (pool →
//! node ids, per-pool cost coefficients) keeps working unpinned. Pinning
//! failures — e.g. `sched_setaffinity` returning `EPERM` under a restrictive
//! seccomp/cpuset — are reported to the caller ([`pin_current_thread`] returns
//! `false`) and degrade to unpinned pools; they are never fatal.
//!
//! Placement only moves *threads and pages*: plan outputs stay bitwise
//! identical with pinning on or off, which `tests/calibration_invariance.rs`
//! pins.

use std::sync::OnceLock;

/// One NUMA node: its sysfs id, the cpu ids it owns, and its memory capacity.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeInfo {
    /// Sysfs node id (`nodeN`). Not necessarily dense.
    pub id: usize,
    /// Cpu ids local to this node, ascending. Empty on the fallback node —
    /// an empty list means "never pin".
    pub cpus: Vec<usize>,
    /// `MemTotal` of the node in bytes (0 when unknown).
    pub mem_bytes: u64,
}

/// The machine topology used for pool pinning and memory placement.
#[derive(Clone, Debug)]
pub struct Topology {
    nodes: Vec<NodeInfo>,
    pinning: bool,
}

impl Topology {
    /// The process-wide topology (discovered once; see module docs for the
    /// discovery/fallback contract). `HMATC_NUMA=0` forces the single-node
    /// fallback, `HMATC_PIN=0` disables pinning.
    pub fn get() -> &'static Topology {
        static TOPO: OnceLock<Topology> = OnceLock::new();
        TOPO.get_or_init(|| Topology::detect(env_flag("HMATC_NUMA", true), env_flag("HMATC_PIN", true)))
    }

    /// Detect the topology with explicit switches (testable without env vars).
    /// Discovered cpu lists are intersected with the process's allowed cpuset
    /// (`sched_getaffinity`), so containers restricted to a cpu subset never
    /// pin to — or count — cpus they cannot run on.
    pub fn detect(numa_enabled: bool, pinning: bool) -> Topology {
        let nodes = if numa_enabled {
            discover(SYSFS_NODE_ROOT)
                .map(|mut ns| {
                    if let Some(mask) = allowed_cpu_mask() {
                        for n in &mut ns {
                            n.cpus.retain(|&c| c <= MAX_CPU_ID && (mask[c / 64] >> (c % 64)) & 1 == 1);
                        }
                        ns.retain(|n| !n.cpus.is_empty());
                    }
                    ns
                })
                .filter(|ns| !ns.is_empty())
        } else {
            None
        };
        Topology { nodes: nodes.unwrap_or_else(fallback_nodes), pinning }
    }

    /// Build a topology from explicit nodes (tests).
    pub fn from_nodes(nodes: Vec<NodeInfo>, pinning: bool) -> Topology {
        let nodes = if nodes.is_empty() { fallback_nodes() } else { nodes };
        Topology { nodes, pinning }
    }

    pub fn nodes(&self) -> &[NodeInfo] {
        &self.nodes
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Whether thread pinning / memory binding is enabled (`HMATC_PIN`).
    pub fn pin_enabled(&self) -> bool {
        self.pinning
    }

    /// Largest per-node cpu count (0 on the fallback topology).
    pub fn cores_per_node(&self) -> usize {
        self.nodes.iter().map(|n| n.cpus.len()).max().unwrap_or(0)
    }

    /// Per-node memory capacities in bytes, in node order.
    pub fn node_mem(&self) -> Vec<u64> {
        self.nodes.iter().map(|n| n.mem_bytes).collect()
    }

    /// Placement for sub-pool `p` of `k`: the node it lives on (sysfs id) and
    /// the cpu ids its workers should pin to.
    ///
    /// Pools are dealt round-robin across nodes (`p % nodes`), and the pools
    /// that share a node split that node's cpu list into contiguous
    /// `part_range`-style slices, so distinct pools get distinct core sets
    /// even on a single-node box. When a node hosts more pools than it has
    /// cpus, the overflow pools share the whole node's cpu list (node-local,
    /// not core-exclusive). The fallback topology returns an empty cpu list:
    /// never pin on synthetic nodes.
    pub fn pool_placement(&self, k: usize, p: usize) -> (Option<usize>, Vec<usize>) {
        let nn = self.nodes.len();
        if nn == 0 || k == 0 || p >= k {
            return (None, Vec::new());
        }
        let ni = p % nn;
        let node = &self.nodes[ni];
        if node.cpus.is_empty() {
            return (Some(node.id), Vec::new());
        }
        // pools p' < k with p' % nn == ni, and this pool's ordinal among them
        let on_node = (k - ni).div_ceil(nn);
        let q = p / nn;
        let len = node.cpus.len();
        let (lo, hi) = (q * len / on_node, (q + 1) * len / on_node);
        if lo >= hi {
            return (Some(node.id), node.cpus.clone());
        }
        (Some(node.id), node.cpus[lo..hi].to_vec())
    }

    /// One-line human summary (the `hmatc info` topology line).
    pub fn summary(&self) -> String {
        let cpus: Vec<String> = self.nodes.iter().map(|n| n.cpus.len().to_string()).collect();
        let kind = if self.nodes.iter().all(|n| n.cpus.is_empty()) { " (fallback)" } else { "" };
        format!(
            "{} node(s){}, cpus/node [{}], pinning {}",
            self.nodes.len(),
            kind,
            cpus.join(","),
            if self.pinning { "on" } else { "off" }
        )
    }
}

const SYSFS_NODE_ROOT: &str = "/sys/devices/system/node";

fn fallback_nodes() -> Vec<NodeInfo> {
    vec![NodeInfo { id: 0, cpus: Vec::new(), mem_bytes: 0 }]
}

/// Read `true`/`false` style env flags; anything but `0|off|false|no` is on.
fn env_flag(name: &str, default: bool) -> bool {
    match std::env::var(name) {
        Ok(v) => !matches!(v.trim().to_ascii_lowercase().as_str(), "0" | "off" | "false" | "no"),
        Err(_) => default,
    }
}

/// Discover NUMA nodes under a sysfs-style directory (path-injectable for
/// tests). Returns `None` when the directory is missing or holds no node with
/// at least one cpu, so callers fall back to the synthetic single node.
pub fn discover(root: &str) -> Option<Vec<NodeInfo>> {
    let entries = std::fs::read_dir(root).ok()?;
    let mut nodes = Vec::new();
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let Some(idstr) = name.strip_prefix("node") else { continue };
        let Ok(id) = idstr.parse::<usize>() else { continue };
        let dir = entry.path();
        let cpus = std::fs::read_to_string(dir.join("cpulist"))
            .ok()
            .map(|s| parse_cpulist(&s))
            .unwrap_or_default();
        if cpus.is_empty() {
            continue; // memory-only node: no pool lives there
        }
        let mem_bytes = std::fs::read_to_string(dir.join("meminfo")).ok().map(|s| parse_meminfo_total(&s)).unwrap_or(0);
        nodes.push(NodeInfo { id, cpus, mem_bytes });
    }
    if nodes.is_empty() {
        return None;
    }
    nodes.sort_by_key(|n| n.id);
    Some(nodes)
}

/// Parse a sysfs cpulist (`"0-3,8,10-11"`) into ascending cpu ids.
pub fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((lo, hi)) = part.split_once('-') {
            if let (Ok(lo), Ok(hi)) = (lo.trim().parse::<usize>(), hi.trim().parse::<usize>()) {
                if lo <= hi && hi - lo < 4096 {
                    out.extend(lo..=hi);
                }
            }
        } else if let Ok(c) = part.parse::<usize>() {
            out.push(c);
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// Extract the `MemTotal:` kilobyte figure from a node `meminfo`, in bytes.
fn parse_meminfo_total(s: &str) -> u64 {
    for line in s.lines() {
        if let Some(pos) = line.find("MemTotal:") {
            let rest = &line[pos + "MemTotal:".len()..];
            if let Some(kb) = rest.split_whitespace().next().and_then(|t| t.parse::<u64>().ok()) {
                return kb.saturating_mul(1024);
            }
        }
    }
    0
}

// Raw Linux placement syscalls. std already links libc, so plain `extern "C"`
// declarations suffice — same pattern as `store::sys` for mmap/madvise.
#[cfg(target_os = "linux")]
mod sys {
    extern "C" {
        pub fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
        pub fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
    }
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    extern "C" {
        pub fn syscall(num: std::os::raw::c_long, ...) -> std::os::raw::c_long;
        pub fn getpagesize() -> i32;
    }
    #[cfg(target_arch = "x86_64")]
    pub const NR_MBIND: std::os::raw::c_long = 237;
    #[cfg(target_arch = "aarch64")]
    pub const NR_MBIND: std::os::raw::c_long = 235;
}

/// Maximum cpu id representable in the affinity mask ([u64; 16] = 1024 bits).
pub const MAX_CPU_ID: usize = 1023;

/// The calling thread's allowed-cpu mask, when the kernel reports one.
#[cfg(target_os = "linux")]
fn allowed_cpu_mask() -> Option<[u64; 16]> {
    let mut mask = [0u64; 16];
    let rc = unsafe { sys::sched_getaffinity(0, std::mem::size_of_val(&mask), mask.as_mut_ptr()) };
    (rc == 0).then_some(mask)
}

#[cfg(not(target_os = "linux"))]
fn allowed_cpu_mask() -> Option<[u64; 16]> {
    None
}

/// Pin the calling thread to `cpus`. Returns `false` — leaving the thread
/// unpinned — on an empty/unrepresentable cpu set, on kernel rejection
/// (`EPERM`/`EINVAL`, e.g. offline cpus or a restrictive cpuset), and always
/// on non-Linux targets. Never panics: pinning is strictly best-effort.
#[cfg(target_os = "linux")]
pub fn pin_current_thread(cpus: &[usize]) -> bool {
    if cpus.is_empty() {
        return false;
    }
    let mut mask = [0u64; 16];
    let mut any = false;
    for &c in cpus {
        if c <= MAX_CPU_ID {
            mask[c / 64] |= 1u64 << (c % 64);
            any = true;
        }
    }
    if !any {
        return false;
    }
    // pid 0 = the calling thread
    unsafe { sys::sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(_cpus: &[usize]) -> bool {
    false
}

/// Advise the kernel to place (and migrate, `MPOL_MF_MOVE`) the pages backing
/// `ptr..ptr+len` on `node` (`mbind` with `MPOL_PREFERRED`). The range is
/// widened to page boundaries. Returns `false` — leaving placement to the
/// default policy — when the node id is unrepresentable, the syscall is
/// unavailable (non-Linux / unsupported arch), or the kernel refuses.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn bind_region(ptr: *const u8, len: usize, node: usize) -> bool {
    const MPOL_PREFERRED: usize = 1;
    const MPOL_MF_MOVE: usize = 1 << 1;
    if len == 0 || node >= 64 {
        return false;
    }
    let page = unsafe { sys::getpagesize() } as usize;
    if page == 0 || !page.is_power_of_two() {
        return false;
    }
    let start = (ptr as usize) & !(page - 1);
    let end = (ptr as usize).saturating_add(len);
    let end = end.checked_add(page - 1).map(|e| e & !(page - 1)).unwrap_or(end);
    let mask: u64 = 1u64 << node;
    let rc = unsafe {
        sys::syscall(
            sys::NR_MBIND,
            start as std::os::raw::c_long,
            (end - start) as std::os::raw::c_long,
            MPOL_PREFERRED as std::os::raw::c_long,
            (&mask as *const u64) as std::os::raw::c_long,
            64 as std::os::raw::c_long,
            MPOL_MF_MOVE as std::os::raw::c_long,
        )
    };
    rc == 0
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn bind_region(_ptr: *const u8, _len: usize, _node: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_parses_ranges_and_singles() {
        assert_eq!(parse_cpulist("0-3,8,10-11\n"), vec![0, 1, 2, 3, 8, 10, 11]);
        assert_eq!(parse_cpulist("5"), vec![5]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist(" 2 - 4 , 1 "), vec![1, 2, 3, 4]);
        assert_eq!(parse_cpulist("3-1"), Vec::<usize>::new()); // inverted range
        assert_eq!(parse_cpulist("0,0,1-2,2"), vec![0, 1, 2]); // dedup
    }

    #[test]
    fn meminfo_total_parses() {
        let s = "Node 0 MemTotal:       16309972 kB\nNode 0 MemFree:         12 kB\n";
        assert_eq!(parse_meminfo_total(s), 16309972 * 1024);
        assert_eq!(parse_meminfo_total("no such line"), 0);
    }

    #[test]
    fn numa_disabled_falls_back_to_single_unpinnable_node() {
        let t = Topology::detect(false, true);
        assert_eq!(t.num_nodes(), 1);
        assert!(t.nodes()[0].cpus.is_empty());
        let (node, cpus) = t.pool_placement(4, 1);
        assert_eq!(node, Some(0));
        assert!(cpus.is_empty(), "fallback node must never yield pinnable cpus");
    }

    #[test]
    fn discover_missing_root_is_none() {
        assert!(discover("/nonexistent/hmatc-test-path").is_none());
    }

    #[test]
    fn discover_reads_synthetic_sysfs_tree() {
        let root = std::env::temp_dir().join(format!("hmatc-topo-{}", std::process::id()));
        let mk = |n: &str, cpulist: &str, mem: &str| {
            let d = root.join(n);
            std::fs::create_dir_all(&d).unwrap();
            std::fs::write(d.join("cpulist"), cpulist).unwrap();
            std::fs::write(d.join("meminfo"), mem).unwrap();
        };
        mk("node1", "4-7\n", "Node 1 MemTotal: 2048 kB\n");
        mk("node0", "0-3\n", "Node 0 MemTotal: 1024 kB\n");
        mk("node2", "\n", "Node 2 MemTotal: 4096 kB\n"); // memory-only: skipped
        std::fs::create_dir_all(root.join("power")).unwrap(); // non-node entry
        let nodes = discover(root.to_str().unwrap()).unwrap();
        std::fs::remove_dir_all(&root).ok();
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[0], NodeInfo { id: 0, cpus: vec![0, 1, 2, 3], mem_bytes: 1024 * 1024 });
        assert_eq!(nodes[1], NodeInfo { id: 1, cpus: vec![4, 5, 6, 7], mem_bytes: 2048 * 1024 });
    }

    fn two_node_topo() -> Topology {
        Topology::from_nodes(
            vec![
                NodeInfo { id: 0, cpus: vec![0, 1, 2, 3], mem_bytes: 1 },
                NodeInfo { id: 1, cpus: vec![4, 5, 6, 7], mem_bytes: 1 },
            ],
            true,
        )
    }

    #[test]
    fn placement_round_robins_nodes_and_splits_cores() {
        let t = two_node_topo();
        // k=2: one pool per node, each takes the whole node
        assert_eq!(t.pool_placement(2, 0), (Some(0), vec![0, 1, 2, 3]));
        assert_eq!(t.pool_placement(2, 1), (Some(1), vec![4, 5, 6, 7]));
        // k=4: two pools per node, contiguous halves
        assert_eq!(t.pool_placement(4, 0), (Some(0), vec![0, 1]));
        assert_eq!(t.pool_placement(4, 1), (Some(1), vec![4, 5]));
        assert_eq!(t.pool_placement(4, 2), (Some(0), vec![2, 3]));
        assert_eq!(t.pool_placement(4, 3), (Some(1), vec![6, 7]));
        // k=3: node 0 hosts pools 0 and 2, node 1 hosts pool 1 whole
        assert_eq!(t.pool_placement(3, 0), (Some(0), vec![0, 1]));
        assert_eq!(t.pool_placement(3, 1), (Some(1), vec![4, 5, 6, 7]));
        assert_eq!(t.pool_placement(3, 2), (Some(0), vec![2, 3]));
    }

    #[test]
    fn placement_oversubscribed_pools_share_the_node() {
        let t = Topology::from_nodes(vec![NodeInfo { id: 0, cpus: vec![0, 1], mem_bytes: 0 }], true);
        // 4 pools on a 2-cpu node: every pool stays node-local, slices that
        // would be empty widen to the whole node
        for p in 0..4 {
            let (node, cpus) = t.pool_placement(4, p);
            assert_eq!(node, Some(0));
            assert!(!cpus.is_empty());
            assert!(cpus.iter().all(|c| *c <= 1));
        }
    }

    #[test]
    fn placement_out_of_range_is_empty() {
        let t = two_node_topo();
        assert_eq!(t.pool_placement(0, 0), (None, vec![]));
        assert_eq!(t.pool_placement(2, 5), (None, vec![]));
    }

    #[test]
    fn pin_rejects_empty_and_unrepresentable_sets() {
        assert!(!pin_current_thread(&[]));
        assert!(!pin_current_thread(&[MAX_CPU_ID + 1]));
    }

    #[test]
    fn bind_region_rejects_bad_node() {
        let buf = vec![0u8; 16];
        assert!(!bind_region(buf.as_ptr(), buf.len(), 64));
        assert!(!bind_region(buf.as_ptr(), 0, 0));
    }

    #[test]
    fn summary_mentions_pinning_state() {
        let t = Topology::detect(false, false);
        let s = t.summary();
        assert!(s.contains("pinning off"), "{s}");
        assert!(s.contains("fallback"), "{s}");
    }
}
