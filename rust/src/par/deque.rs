//! Chase–Lev-style work-stealing deque over plan-chunk indices.
//!
//! The stealing executor never migrates *closures* — a level's work is a
//! precomputed list of task chunks, so the unit of stealing is just a `usize`
//! chunk index. That keeps the deque a fixed array of atomics (no boxed jobs,
//! no garbage): the owner pushes all indices up front, pops from the bottom,
//! thieves take from the top with a CAS. Memory ordering follows the C11
//! formulation of Lê, Pop, Cohen, Nardelli, *"Correct and Efficient
//! Work-Stealing for Weak Memory Models"* (PPoPP 2013).

use std::sync::atomic::{fence, AtomicIsize, AtomicUsize, Ordering};

/// Result of a steal attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Steal {
    /// Took this item from the top.
    Taken(usize),
    /// Deque observed empty.
    Empty,
    /// Lost a race with the owner or another thief; worth retrying.
    Retry,
}

/// A fixed-capacity work-stealing deque of `usize` items.
///
/// Ownership protocol: exactly one thread (the *owner*) calls [`WorkDeque::push`]
/// and [`WorkDeque::pop`]; any thread may call [`WorkDeque::steal`].
/// [`WorkDeque::reset`] requires external synchronization (no concurrent
/// access) — the executor resets between barrier-separated levels, after all
/// workers of the previous level have joined.
pub struct WorkDeque {
    buf: Box<[AtomicUsize]>,
    mask: usize,
    top: AtomicIsize,
    bottom: AtomicIsize,
}

impl WorkDeque {
    /// A deque able to hold at least `cap` items (rounded up to a power of
    /// two; the buffer never grows — size for the largest level up front).
    pub fn with_capacity(cap: usize) -> WorkDeque {
        let cap = cap.next_power_of_two().max(4);
        let buf: Vec<AtomicUsize> = (0..cap).map(|_| AtomicUsize::new(0)).collect();
        WorkDeque { buf: buf.into_boxed_slice(), mask: cap - 1, top: AtomicIsize::new(0), bottom: AtomicIsize::new(0) }
    }

    /// Maximum number of items the deque can hold.
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Empty the deque. Caller must guarantee no concurrent access (between
    /// levels, all workers joined).
    pub fn reset(&self) {
        self.top.store(0, Ordering::Relaxed);
        self.bottom.store(0, Ordering::Relaxed);
    }

    /// Owner-side push onto the bottom. Panics if the deque is full — the
    /// executor sizes deques for the whole level before seeding.
    pub fn push(&self, item: usize) {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        assert!((b - t) < self.buf.len() as isize, "WorkDeque overflow (capacity {})", self.buf.len());
        self.buf[(b as usize) & self.mask].store(item, Ordering::Relaxed);
        fence(Ordering::Release);
        self.bottom.store(b + 1, Ordering::Release);
    }

    /// Owner-side pop from the bottom (LIFO: best cache locality for the
    /// owner's own chunks).
    pub fn pop(&self) -> Option<usize> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        self.bottom.store(b, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            let item = self.buf[(b as usize) & self.mask].load(Ordering::Relaxed);
            if t == b {
                // last item: race against thieves for it
                let won = self.top.compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed).is_ok();
                self.bottom.store(b + 1, Ordering::Relaxed);
                if won {
                    Some(item)
                } else {
                    None
                }
            } else {
                Some(item)
            }
        } else {
            self.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// Thief-side steal from the top (FIFO: takes the chunk the owner would
    /// reach last).
    pub fn steal(&self) -> Steal {
        let t = self.top.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t < b {
            let item = self.buf[(t as usize) & self.mask].load(Ordering::Relaxed);
            if self.top.compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed).is_ok() {
                Steal::Taken(item)
            } else {
                Steal::Retry
            }
        } else {
            Steal::Empty
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize as Counter;

    #[test]
    fn owner_lifo_order() {
        let d = WorkDeque::with_capacity(8);
        for i in 0..5 {
            d.push(i);
        }
        for want in (0..5).rev() {
            assert_eq!(d.pop(), Some(want));
        }
        assert_eq!(d.pop(), None);
        assert_eq!(d.pop(), None); // empty pop is idempotent
    }

    #[test]
    fn thief_fifo_order() {
        let d = WorkDeque::with_capacity(8);
        for i in 0..5 {
            d.push(i);
        }
        for want in 0..5 {
            assert_eq!(d.steal(), Steal::Taken(want));
        }
        assert_eq!(d.steal(), Steal::Empty);
    }

    #[test]
    fn reset_reuses_buffer() {
        let d = WorkDeque::with_capacity(4);
        d.push(1);
        d.push(2);
        assert_eq!(d.pop(), Some(2));
        d.reset();
        assert_eq!(d.pop(), None);
        d.push(9);
        assert_eq!(d.steal(), Steal::Taken(9));
    }

    #[test]
    fn concurrent_pop_and_steal_take_each_item_once() {
        // hammer the owner-vs-thief race: every item taken exactly once
        for round in 0..50 {
            let n = 64 + round;
            let d = WorkDeque::with_capacity(n);
            for i in 0..n {
                d.push(i);
            }
            let seen: Vec<Counter> = (0..n).map(|_| Counter::new(0)).collect();
            std::thread::scope(|s| {
                // two thieves
                for _ in 0..2 {
                    s.spawn(|| loop {
                        match d.steal() {
                            Steal::Taken(i) => {
                                seen[i].fetch_add(1, Ordering::Relaxed);
                            }
                            Steal::Retry => std::hint::spin_loop(),
                            Steal::Empty => break,
                        }
                    });
                }
                // the owner pops
                while let Some(i) = d.pop() {
                    seen[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            for (i, c) in seen.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "item {i} taken {} times", c.load(Ordering::Relaxed));
            }
        }
    }
}
