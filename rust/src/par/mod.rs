//! Fork-join task parallelism substrate.
//!
//! The sandbox has no rayon/TBB, and the paper's parallel MVM algorithms
//! (Alg. 3, 5, 7) are precisely *task scheduling* algorithms, so the pool is a
//! first-class substrate here. Two layers:
//!
//! * [`ThreadPool`] — a **work-sharing** pool: a fixed set of workers, one
//!   shared injector queue, and a help-first scoped fork-join API (waiters
//!   execute queued tasks instead of blocking, so recursive spawning can
//!   never deadlock).
//! * [`StealSet`] + [`deque::WorkDeque`] — a **work-stealing** layer on top:
//!   per-slot Chase–Lev deques of precomputed chunk indices drained by one
//!   worker loop per slot, with top-end steals for dynamic rebalancing.
//!
//! Which layer executes a plan is chosen per operator through
//! [`crate::plan::Executor`] (`HMATC_EXEC` / `--executor`).

pub mod atomic;
pub mod deque;
pub mod pool;
pub mod topology;

pub use atomic::{as_atomic_f64, atomic_add_f64};
pub use deque::{Steal, WorkDeque};
pub use pool::{parallel_for, Scope, StealSet, ThreadPool};
pub use topology::{NodeInfo, Topology};

/// Number of worker threads used by the global pool.
pub fn num_threads() -> usize {
    ThreadPool::global().num_threads()
}
