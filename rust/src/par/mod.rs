//! Fork-join task parallelism substrate.
//!
//! The sandbox has no rayon/TBB, and the paper's parallel MVM algorithms
//! (Alg. 3, 5, 7) are precisely *task scheduling* algorithms, so the pool is a
//! first-class substrate here: a fixed set of workers, a shared injector
//! queue, and a help-first scoped fork-join API (waiters execute queued tasks
//! instead of blocking, so recursive spawning can never deadlock).

pub mod atomic;
pub mod pool;

pub use atomic::{as_atomic_f64, atomic_add_f64};
pub use pool::{parallel_for, Scope, ThreadPool};

/// Number of worker threads used by the global pool.
pub fn num_threads() -> usize {
    ThreadPool::global().num_threads()
}
