//! # hmatc — compressed hierarchical matrix formats and fast MVM
//!
//! Reproduction of R. Kriemann, *"Floating Point Compression of Hierarchical
//! Matrix Formats and its Impact on Matrix-Vector Multiplication"*.
//!
//! The crate implements, from scratch:
//!
//! * the three hierarchical matrix formats of the paper — [`hmatrix`] (H),
//!   [`uniform`] (uniform-H with shared cluster bases) and [`h2`] (H² with
//!   nested bases) — over geometric cluster trees ([`cluster`]) built for a
//!   BEM model problem ([`geometry`], [`kernelfn`]);
//! * the error-adaptive floating point codecs of §4 — AFLP, FPX and the
//!   per-column VALR scheme — in [`compress`], backed by the out-of-core
//!   [`store`] tier (reference-counted segments, `hmatc pack` + mmap-served
//!   operators, level-pipelined prefetch, decode-once hot cache);
//! * every matrix-vector multiplication algorithm of §3/§4 (Algorithms 1–8)
//!   in [`mvm`], running on a custom fork-join substrate ([`par`]): a
//!   work-sharing scoped thread pool plus a Chase–Lev-deque work-stealing
//!   layer on top;
//! * a format-agnostic execution-[`plan`] layer: an operator trait over all
//!   three formats plus precomputed task schedules with zero steady-state
//!   allocation, executed by a pluggable backend
//!   ([`plan::Executor`]: static LPT `lpt`, work-stealing `steal`, or
//!   sub-pool `sharded:K` — `HMATC_EXEC` / `--executor`), with
//!   measurement-driven cost-model calibration ([`plan::costmodel`]:
//!   per-chunk wall times fitted to per-kernel-class coefficients that
//!   re-balance the LPT packings bitwise-invariantly — `hmatc calibrate`,
//!   `HMATC_COSTS` / `--costs`);
//! * a PJRT [`runtime`] that executes AOT-lowered JAX/Pallas tile kernels and
//!   a request-batching MVM server in [`coordinator`] — optionally a
//!   scatter/gather tier over a row-sharded operator partition
//!   ([`plan::row_partition`] / [`plan::ShardPlan`], `serve --shards N` /
//!   `HMATC_SHARDS`, bitwise identical to unsharded serving);
//! * the measurement substrate ([`bench`]) used by the per-figure benchmark
//!   binaries under `rust/benches/`.
//!
//! ## Quickstart
//!
//! ```no_run
//! use hmatc::prelude::*;
//! use std::sync::Arc;
//!
//! // BEM model problem: Laplace SLP on the unit sphere, n = 1280 triangles.
//! let geom = hmatc::geometry::icosphere(3);
//! let gen = hmatc::kernelfn::LaplaceSlp::new(&geom);
//! let ct = Arc::new(ClusterTree::build(gen.points(), 64));
//! let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
//! let mut h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-6));
//!
//! // Compress with AFLP + VALR and multiply.
//! h.compress(&CompressionConfig::aflp(1e-6));
//! let x = vec![1.0; h.ncols()];
//! let mut y = vec![0.0; h.nrows()];
//! hmatc::mvm::mvm(1.0, &h, &x, &mut y, MvmAlgorithm::ClusterLists);
//! ```
#![allow(clippy::needless_range_loop)]

pub mod util;
pub mod par;
pub mod la;
pub mod geometry;
pub mod cluster;
pub mod kernelfn;
pub mod lowrank;
pub mod compress;
pub mod store;
pub mod hmatrix;
pub mod uniform;
pub mod h2;
pub mod mvm;
pub mod plan;
pub mod solver;
pub mod bench;
pub mod coordinator;
#[cfg(feature = "pjrt")]
pub mod runtime;

/// Commonly used types, re-exported for examples and benches.
pub mod prelude {
    pub use crate::cluster::{Admissibility, BlkAdmissibility, BlockTree, ClusterTree, HodlrAdmissibility, StdAdmissibility, WeakAdmissibility};
    pub use crate::compress::{Codec, CompressionConfig};
    pub use crate::geometry::{icosphere, Geometry};
    pub use crate::h2::H2Matrix;
    pub use crate::hmatrix::HMatrix;
    pub use crate::kernelfn::{LaplaceSlp, MatrixGen};
    pub use crate::la::DMatrix;
    pub use crate::lowrank::AcaOptions;
    pub use crate::mvm::{mvm, H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
    pub use crate::plan::{HOperator, PlannedOperator};
    pub use crate::solver::cg;
    pub use crate::uniform::UniformHMatrix;
}
