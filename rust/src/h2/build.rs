//! H² construction from an H-matrix (paper §2.4; Börm-style bottom-up
//! compression with top-down accumulated block rows).
//!
//! Phase A (top-down): per cluster τ an *explicit* total basis Ŵ_τ is the
//! truncated SVD basis of [own-level low-rank factors | σ-scaled parent
//! basis restricted to τ] — the restriction carries all ancestor block rows.
//! Phase B (bottom-up): nested conversion, E_c = W_cᵀ·Ŵ_τ|rows(c).
//! Phase C: couplings S = (W̃_τᵀ U)(X̃_σᵀ V)ᵀ against the *nested* bases so
//! format and data are consistent.

use super::nested::{NestedBasis, TransferMat};
use super::H2Matrix;
use crate::cluster::BlockTree;
use crate::hmatrix::{BlockData, HMatrix};
use crate::la::{blas, qr_thin, svd_adaptive, DMatrix};
use crate::par::ThreadPool;
use crate::uniform::{BasisData, CouplingMat, UniBlock};
use std::sync::{Arc, Mutex};

/// Build an H²-matrix from an (uncompressed) H-matrix with basis accuracy
/// `eps`.
pub fn build_from_h(h: &HMatrix, eps: f64) -> H2Matrix {
    let bt = h.bt.clone();
    let (row_w, row_sigma) = accumulated_bases(h, &bt, eps, true);
    let (col_w, col_sigma) = accumulated_bases(h, &bt, eps, false);
    let row_basis = nest(&bt, &row_w, row_sigma, true);
    let col_basis = nest(&bt, &col_w, col_sigma, false);
    // consistent couplings against the nested (projected) bases
    let row_nested = row_basis.expand(&bt.row_ct);
    let col_nested = col_basis.expand(&bt.col_ct);
    let blocks = build_blocks(h, &bt, &row_nested, &col_nested);
    H2Matrix { bt, row_basis, col_basis, blocks }
}

/// Phase A: explicit accumulated bases, top-down by level.
fn accumulated_bases(h: &HMatrix, bt: &Arc<BlockTree>, eps: f64, row_side: bool) -> (Vec<DMatrix>, Vec<Vec<f64>>) {
    let ct = if row_side { &bt.row_ct } else { &bt.col_ct };
    let nc = ct.nodes.len();
    let w: Mutex<Vec<Option<(DMatrix, Vec<f64>)>>> = Mutex::new(vec![None; nc]);
    let pool = ThreadPool::global();

    for level in 0..ct.levels.len() {
        // parents of this level are complete; process the level in parallel
        pool.scope(|s| {
            for &tau in &ct.levels[level] {
                let w = &w;
                s.spawn(move |_| {
                    let nd = ct.node(tau);
                    let mut pieces: Vec<DMatrix> = Vec::new();
                    // own-level admissible blocks
                    let list = if row_side { &bt.row_blocks[tau] } else { &bt.col_blocks[tau] };
                    for &b in list {
                        if !bt.node(b).admissible {
                            continue;
                        }
                        if let Some(BlockData::LowRank(lr)) = h.block(b) {
                            if lr.rank() == 0 {
                                continue;
                            }
                            let (own, other) = if row_side { (&lr.u, &lr.v) } else { (&lr.v, &lr.u) };
                            let (_, r) = qr_thin(other);
                            pieces.push(blas::matmul(own, blas::Trans::No, &r, blas::Trans::Yes));
                        }
                    }
                    // inherited: parent basis restricted to τ, σ-scaled
                    if nd.parent != usize::MAX {
                        let guard = w.lock().unwrap();
                        if let Some((wp, sp)) = guard[nd.parent].as_ref() {
                            if wp.ncols() > 0 {
                                let pnd = ct.node(nd.parent);
                                let off = nd.begin - pnd.begin;
                                let mut restr = wp.sub(off..off + nd.size(), 0..wp.ncols());
                                for (j, &sj) in sp.iter().enumerate() {
                                    for x in restr.col_mut(j) {
                                        *x *= sj;
                                    }
                                }
                                drop(guard);
                                pieces.push(restr);
                            }
                        }
                    }
                    let result = if pieces.is_empty() {
                        (DMatrix::zeros(nd.size(), 0), Vec::new())
                    } else {
                        let mut a = pieces[0].clone();
                        for p in &pieces[1..] {
                            a = a.hcat(p);
                        }
                        let svd = svd_adaptive(&a, eps);
                        let k = svd.rank(eps).max(1).min(svd.s.len());
                        let t = svd.truncate(k);
                        (t.u, t.s)
                    };
                    w.lock().unwrap()[tau] = Some(result);
                });
            }
        });
    }

    let all = w.into_inner().unwrap();
    let mut ws = Vec::with_capacity(nc);
    let mut sigmas = Vec::with_capacity(nc);
    for entry in all {
        let (wm, s) = entry.expect("basis not computed");
        ws.push(wm);
        sigmas.push(s);
    }
    (ws, sigmas)
}

/// Phase B: convert explicit bases to nested form.
fn nest(bt: &Arc<BlockTree>, explicit: &[DMatrix], sigma: Vec<Vec<f64>>, row_side: bool) -> NestedBasis {
    let ct = if row_side { &bt.row_ct } else { &bt.col_ct };
    let mut nb = NestedBasis::empty(ct.nodes.len());
    nb.sigma = sigma;
    for (tau, nd) in ct.nodes.iter().enumerate() {
        let k = explicit[tau].ncols();
        nb.rank[tau] = k;
        if nd.is_leaf() {
            if k > 0 {
                nb.leaf[tau] = Some(BasisData::Plain(explicit[tau].clone()));
            }
        } else if k > 0 {
            for &c in &nd.children {
                let kc = explicit[c].ncols();
                if kc == 0 {
                    nb.transfer[c] = Some(TransferMat::Plain(DMatrix::zeros(0, k)));
                    continue;
                }
                let off = ct.node(c).begin - nd.begin;
                let restr = explicit[tau].sub(off..off + ct.node(c).size(), 0..k);
                let e = blas::matmul(&explicit[c], blas::Trans::Yes, &restr, blas::Trans::No);
                nb.transfer[c] = Some(TransferMat::Plain(e));
            }
        } else {
            for &c in &nd.children {
                nb.transfer[c] = Some(TransferMat::Plain(DMatrix::zeros(explicit[c].ncols(), 0)));
            }
        }
    }
    nb
}

/// Phase C: couplings against the nested bases; dense leaves copied.
fn build_blocks(h: &HMatrix, bt: &Arc<BlockTree>, row_w: &[DMatrix], col_w: &[DMatrix]) -> Vec<Option<UniBlock>> {
    let out: Mutex<Vec<Option<UniBlock>>> = Mutex::new(vec![None; bt.nodes.len()]);
    let pool = ThreadPool::global();
    pool.scope(|s| {
        for &leaf in &bt.leaves {
            let out = &out;
            s.spawn(move |_| {
                let nd = bt.node(leaf);
                let blk = match h.block(leaf) {
                    Some(BlockData::Dense(m)) => UniBlock::Dense(m.clone()),
                    Some(BlockData::LowRank(lr)) => {
                        let w = &row_w[nd.row];
                        let x = &col_w[nd.col];
                        let sr = blas::matmul(w, blas::Trans::Yes, &lr.u, blas::Trans::No);
                        let sc = blas::matmul(x, blas::Trans::Yes, &lr.v, blas::Trans::No);
                        UniBlock::Coupling(CouplingMat::Plain(blas::matmul(&sr, blas::Trans::No, &sc, blas::Trans::Yes)))
                    }
                    other => panic!("H2 build requires an uncompressed H-matrix, got {other:?}"),
                };
                out.lock().unwrap()[leaf] = Some(blk);
            });
        }
    });
    out.into_inner().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterTree, StdAdmissibility};
    use crate::geometry::icosphere;
    use crate::kernelfn::{LaplaceSlp, MatrixGen};
    use crate::lowrank::AcaOptions;

    fn problem(level: usize, n_min: usize, eps: f64) -> (HMatrix, H2Matrix) {
        let geom = icosphere(level);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), n_min));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(eps));
        let h2 = build_from_h(&h, eps);
        (h, h2)
    }

    #[test]
    fn h2_approximates_h() {
        let (h, h2) = problem(1, 8, 1e-6);
        let hd = h.to_dense();
        let hd2 = h2.to_dense();
        let mut diff = hd2.clone();
        diff.add_scaled(-1.0, &hd);
        let rel = diff.fro_norm() / hd.fro_norm();
        assert!(rel < 1e-4, "rel {rel}");
    }

    #[test]
    fn h2_basis_is_nested_only() {
        let (_, h2) = problem(2, 16, 1e-4);
        let ct = &h2.bt.row_ct;
        for (tau, nd) in ct.nodes.iter().enumerate() {
            if nd.is_leaf() {
                assert!(h2.row_basis.transfer[tau].is_some() || nd.parent == usize::MAX || h2.row_basis.rank[ct.nodes[tau].parent] == 0 || h2.row_basis.rank[tau] == 0);
            } else {
                // inner clusters never hold explicit bases
                assert!(h2.row_basis.leaf[tau].is_none());
            }
        }
    }

    #[test]
    fn h2_storage_leq_h_for_larger_problems() {
        let (h, h2) = problem(2, 16, 1e-4);
        // H² per-dof storage should not exceed H (usually much smaller)
        assert!(h2.byte_size() as f64 <= 1.1 * h.byte_size() as f64, "h2 {} vs h {}", h2.byte_size(), h.byte_size());
    }

    #[test]
    fn compression_keeps_accuracy() {
        let (_, mut h2) = problem(1, 8, 1e-6);
        let before = h2.to_dense();
        let bytes_before = h2.byte_size();
        h2.compress(&crate::compress::CompressionConfig::aflp(1e-6));
        let after = h2.to_dense();
        assert!(h2.byte_size() < bytes_before);
        let mut diff = after.clone();
        diff.add_scaled(-1.0, &before);
        let rel = diff.fro_norm() / before.fro_norm();
        assert!(rel < 1e-5, "rel {rel}");
    }
}
