//! H²-matrices (paper §2.4): nested cluster bases — explicit bases only at
//! leaf clusters, transfer matrices E everywhere else — giving O(n) storage.

mod build;
mod nested;

pub use build::build_from_h;
pub use nested::{NestedBasis, TransferMat};

use crate::cluster::BlockTree;
use crate::compress::CompressionConfig;
use crate::hmatrix::ZDense;
use crate::la::{blas, DMatrix};
use crate::uniform::UniBlock;
use std::sync::Arc;

/// Memory statistics for the H² format.
#[derive(Clone, Copy, Debug, Default)]
pub struct H2Stats {
    pub dense_bytes: usize,
    pub coupling_bytes: usize,
    pub basis_bytes: usize,
}

impl H2Stats {
    pub fn total_bytes(&self) -> usize {
        self.dense_bytes + self.coupling_bytes + self.basis_bytes
    }
}

/// H²-matrix: nested row/column bases + couplings + dense near field.
#[derive(Clone)]
pub struct H2Matrix {
    pub bt: Arc<BlockTree>,
    pub row_basis: NestedBasis,
    pub col_basis: NestedBasis,
    /// Per block node id: dense or coupling leaves (same container as UH).
    pub blocks: Vec<Option<UniBlock>>,
}

impl H2Matrix {
    pub fn nrows(&self) -> usize {
        self.bt.shape().0
    }

    pub fn ncols(&self) -> usize {
        self.bt.shape().1
    }

    /// Compress leaf bases (VALR), transfer matrices, couplings and dense
    /// blocks (direct) — §4.1/§4.2: for H² only the leaf bases admit VALR.
    pub fn compress(&mut self, cfg: &CompressionConfig) {
        self.row_basis.compress(cfg);
        self.col_basis.compress(cfg);
        for b in self.blocks.iter_mut() {
            if let Some(blk) = b.take() {
                *b = Some(match blk {
                    UniBlock::Dense(m) => UniBlock::ZDense(ZDense::compress(&m, cfg.codec, cfg.eps)),
                    UniBlock::Coupling(c) => UniBlock::Coupling(c.compress(cfg)),
                    other => other,
                });
            }
        }
    }

    pub fn stats(&self) -> H2Stats {
        let mut st = H2Stats { basis_bytes: self.row_basis.byte_size() + self.col_basis.byte_size(), ..Default::default() };
        for b in self.blocks.iter().flatten() {
            match b {
                UniBlock::Dense(_) | UniBlock::ZDense(_) => st.dense_bytes += b.byte_size(),
                UniBlock::Coupling(_) => st.coupling_bytes += b.byte_size(),
            }
        }
        st
    }

    pub fn byte_size(&self) -> usize {
        self.stats().total_bytes()
    }

    pub fn bytes_per_dof(&self) -> f64 {
        self.byte_size() as f64 / self.nrows() as f64
    }

    /// Dense reconstruction in internal ordering (tests only). Expands the
    /// nested bases to explicit per-cluster matrices first.
    pub fn to_dense(&self) -> DMatrix {
        let (m, n) = self.bt.shape();
        let wr = self.row_basis.expand(&self.bt.row_ct);
        let wc = self.col_basis.expand(&self.bt.col_ct);
        let mut out = DMatrix::zeros(m, n);
        for &leaf in &self.bt.leaves {
            let nd = self.bt.node(leaf);
            let rr = self.bt.row_ct.node(nd.row).range();
            let cr = self.bt.col_ct.node(nd.col).range();
            let d = match self.blocks[leaf].as_ref().expect("missing leaf") {
                UniBlock::Dense(mm) => mm.clone(),
                UniBlock::ZDense(z) => z.to_dense(),
                UniBlock::Coupling(c) => {
                    let w = &wr[nd.row];
                    let x = &wc[nd.col];
                    let s = c.to_dense();
                    let ws = blas::matmul(w, blas::Trans::No, &s, blas::Trans::No);
                    blas::matmul(&ws, blas::Trans::No, x, blas::Trans::Yes)
                }
            };
            for (jj, j) in cr.enumerate() {
                for (ii, i) in rr.clone().enumerate() {
                    out[(i, j)] = d[(ii, jj)];
                }
            }
        }
        out
    }
}
