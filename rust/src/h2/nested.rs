//! Nested cluster basis: explicit matrices at the leaves, transfer matrices
//! E_{τ'} (k_{τ'} × k_τ) linking each child τ' to its parent τ:
//!
//!   W_τ = [ W_{τ₀} E_{τ₀} ; W_{τ₁} E_{τ₁} ]   (paper §2.4)

use crate::cluster::ClusterTree;
use crate::compress::{Blob, Codec, CompressionConfig, ZLowRankValr, BLOB_OVERHEAD};
use crate::la::{blas, DMatrix};
use crate::uniform::BasisData;

/// A (possibly compressed) transfer matrix.
#[derive(Clone, Debug)]
pub enum TransferMat {
    Plain(DMatrix),
    Z { nrows: usize, ncols: usize, blob: Blob },
}

impl TransferMat {
    pub fn nrows(&self) -> usize {
        match self {
            TransferMat::Plain(m) => m.nrows(),
            TransferMat::Z { nrows, .. } => *nrows,
        }
    }

    pub fn ncols(&self) -> usize {
        match self {
            TransferMat::Plain(m) => m.ncols(),
            TransferMat::Z { ncols, .. } => *ncols,
        }
    }

    pub fn to_dense(&self) -> DMatrix {
        match self {
            TransferMat::Plain(m) => m.clone(),
            TransferMat::Z { nrows, ncols, blob } => {
                let mut m = DMatrix::zeros(*nrows, *ncols);
                blob.decompress_into(m.data_mut());
                m
            }
        }
    }

    /// out += Eᵀ s (forward transformation: child coefficients → parent).
    /// Compressed transfers run on the fused decode–dot kernels; no heap
    /// allocation.
    pub fn apply_transposed_add(&self, s: &[f64], out: &mut [f64]) {
        match self {
            TransferMat::Plain(m) => blas::gemv_transposed(1.0, m, s, out),
            TransferMat::Z { nrows, ncols, blob } => {
                crate::mvm::kernels::stream_dot_cols(blob, *nrows, *ncols, s, out);
            }
        }
    }

    /// out += E t (backward transformation: parent coefficients → child).
    /// Compressed transfers run on the fused decode–axpy kernels; no heap
    /// allocation.
    pub fn apply_add(&self, t: &[f64], out: &mut [f64]) {
        match self {
            TransferMat::Plain(m) => blas::gemv(1.0, m, t, out),
            TransferMat::Z { nrows, ncols, blob } => {
                crate::mvm::kernels::stream_axpy_cols(blob, *nrows, *ncols, 1.0, t, out);
            }
        }
    }

    /// Panel variant of [`TransferMat::apply_transposed_add`]: OUT += Eᵀ S on
    /// contiguous column-major panels (s: nrows×nrhs, out: ncols×nrhs), one
    /// decode pass for all `nrhs` columns.
    pub fn apply_transposed_add_panel(&self, s: &[f64], out: &mut [f64], nrhs: usize) {
        match self {
            TransferMat::Plain(m) => crate::mvm::kernels::gemm_tn_panel(1.0, m, s, out, nrhs),
            TransferMat::Z { nrows, ncols, blob } => {
                crate::mvm::kernels::stream_dot_cols_panel(blob, *nrows, *ncols, s, nrhs, out);
            }
        }
    }

    /// Panel variant of [`TransferMat::apply_add`]: OUT += E T on contiguous
    /// panels (t: ncols×nrhs, out: nrows×nrhs).
    pub fn apply_add_panel(&self, t: &[f64], out: &mut [f64], nrhs: usize) {
        match self {
            TransferMat::Plain(m) => crate::mvm::kernels::gemm_nn_panel(1.0, m, t, out, nrhs),
            TransferMat::Z { nrows, ncols, blob } => {
                crate::mvm::kernels::stream_axpy_cols_panel(blob, *nrows, *ncols, 1.0, t, nrhs, out);
            }
        }
    }

    pub fn byte_size(&self) -> usize {
        match self {
            TransferMat::Plain(m) => m.byte_size(),
            TransferMat::Z { blob, .. } => blob.byte_size(),
        }
    }

    /// Visit the compressed payload blob, if any (storage-tier walkers).
    pub fn for_each_blob(&self, f: &mut dyn FnMut(&Blob)) {
        if let TransferMat::Z { blob, .. } = self {
            f(blob);
        }
    }

    /// Mutable variant of [`TransferMat::for_each_blob`].
    pub fn for_each_blob_mut(&mut self, f: &mut dyn FnMut(&mut Blob)) {
        if let TransferMat::Z { blob, .. } = self {
            f(blob);
        }
    }
}

/// Nested basis over a cluster tree.
#[derive(Clone)]
pub struct NestedBasis {
    /// Rank k_τ per cluster node id.
    pub rank: Vec<usize>,
    /// Explicit leaf bases (per cluster id, leaves only).
    pub leaf: Vec<Option<BasisData>>,
    /// Transfer matrix E_τ (k_τ × k_parent) per non-root cluster id.
    pub transfer: Vec<Option<TransferMat>>,
    /// Construction singular values per cluster (drives VALR of leaf bases).
    pub sigma: Vec<Vec<f64>>,
}

impl NestedBasis {
    pub fn empty(nclusters: usize) -> NestedBasis {
        NestedBasis { rank: vec![0; nclusters], leaf: vec![None; nclusters], transfer: vec![None; nclusters], sigma: vec![Vec::new(); nclusters] }
    }

    /// s += Wᵀ x for a *leaf* cluster (explicit basis). Compressed leaves run
    /// on the fused decode–dot kernels (one cursor resolution per blob).
    pub fn leaf_apply_transposed(&self, tau: usize, x: &[f64], s: &mut [f64]) {
        match self.leaf[tau].as_ref() {
            None => {}
            Some(BasisData::Plain(w)) => {
                for (j, sj) in s.iter_mut().enumerate().take(w.ncols()) {
                    *sj += blas::dot(w.col(j), x);
                }
            }
            Some(BasisData::Z { nrows, ncols, blob }) => {
                crate::mvm::kernels::stream_dot_cols(blob, *nrows, *ncols, x, s);
            }
            Some(BasisData::Valr(z)) => {
                for (j, sj) in s.iter_mut().enumerate().take(z.rank()) {
                    *sj += crate::mvm::kernels::stream_dot(&z.wcols[j], x);
                }
            }
        }
    }

    /// y += W t for a *leaf* cluster (fused decode–axpy for compressed
    /// leaves).
    pub fn leaf_apply_add(&self, tau: usize, t: &[f64], y: &mut [f64]) {
        match self.leaf[tau].as_ref() {
            None => {}
            Some(BasisData::Plain(w)) => {
                for (j, &tj) in t.iter().enumerate().take(w.ncols()) {
                    if tj != 0.0 {
                        blas::axpy(tj, w.col(j), y);
                    }
                }
            }
            Some(BasisData::Z { nrows, ncols, blob }) => {
                crate::mvm::kernels::stream_axpy_cols(blob, *nrows, *ncols, 1.0, t, y);
            }
            Some(BasisData::Valr(z)) => {
                for (j, &tj) in t.iter().enumerate().take(z.rank()) {
                    if tj != 0.0 {
                        crate::mvm::kernels::stream_axpy(&z.wcols[j], tj, y);
                    }
                }
            }
        }
    }

    /// Panel variant of [`NestedBasis::leaf_apply_transposed`]: S += Wᵀ X on
    /// contiguous panels for a *leaf* cluster.
    pub fn leaf_apply_transposed_panel(&self, tau: usize, x: &[f64], s: &mut [f64], nrhs: usize) {
        if let Some(data) = self.leaf[tau].as_ref() {
            data.apply_transposed_panel(x, s, nrhs);
        }
    }

    /// Panel variant of [`NestedBasis::leaf_apply_add`]: Y += W T on
    /// contiguous panels for a *leaf* cluster.
    pub fn leaf_apply_add_panel(&self, tau: usize, t: &[f64], y: &mut [f64], nrhs: usize) {
        if let Some(data) = self.leaf[tau].as_ref() {
            data.apply_add_panel(t, y, nrhs);
        }
    }

    /// Expand to explicit per-cluster bases (tests / coupling construction).
    pub fn expand(&self, ct: &ClusterTree) -> Vec<DMatrix> {
        let mut out: Vec<DMatrix> = vec![DMatrix::zeros(0, 0); ct.nodes.len()];
        // bottom-up over levels
        for level in (0..ct.levels.len()).rev() {
            for &tau in &ct.levels[level] {
                let nd = ct.node(tau);
                if nd.is_leaf() {
                    out[tau] = match self.leaf[tau].as_ref() {
                        None => DMatrix::zeros(nd.size(), 0),
                        Some(BasisData::Plain(w)) => w.clone(),
                        Some(BasisData::Z { nrows, ncols, blob }) => {
                            let mut m = DMatrix::zeros(*nrows, *ncols);
                            blob.decompress_into(m.data_mut());
                            m
                        }
                        Some(BasisData::Valr(z)) => z.w_to_dense(),
                    };
                } else {
                    let k = self.rank[tau];
                    let mut w = DMatrix::zeros(nd.size(), k);
                    if k > 0 {
                        for &c in &nd.children {
                            let e = match self.transfer[c].as_ref() {
                                Some(t) => t.to_dense(),
                                None => continue,
                            };
                            let child_w = &out[c];
                            // rows of child within parent
                            let off = ct.node(c).begin - nd.begin;
                            let piece = blas::matmul(child_w, blas::Trans::No, &e, blas::Trans::No);
                            for j in 0..k {
                                let dst = &mut w.col_mut(j)[off..off + piece.nrows()];
                                for (d, s) in dst.iter_mut().zip(piece.col(j)) {
                                    *d += s;
                                }
                            }
                        }
                    }
                    out[tau] = w;
                }
            }
        }
        out
    }

    /// Compress leaf bases (VALR when configured) and transfer matrices
    /// (direct).
    pub fn compress(&mut self, cfg: &CompressionConfig) {
        for (tau, l) in self.leaf.iter_mut().enumerate() {
            if let Some(BasisData::Plain(w)) = l {
                if w.ncols() == 0 {
                    continue;
                }
                *l = Some(if cfg.valr {
                    BasisData::Valr(ZLowRankValr::compress_basis(w, &self.sigma[tau], cfg.codec, cfg.eps))
                } else {
                    BasisData::Z { nrows: w.nrows(), ncols: w.ncols(), blob: Blob::compress(cfg.codec, w.data(), cfg.eps) }
                });
            }
        }
        for t in self.transfer.iter_mut() {
            if let Some(TransferMat::Plain(m)) = t {
                if m.nrows() * m.ncols() == 0 {
                    continue;
                }
                *t = Some(TransferMat::Z { nrows: m.nrows(), ncols: m.ncols(), blob: compress_mat(m, cfg.codec, cfg.eps) });
            }
        }
    }

    pub fn byte_size(&self) -> usize {
        let mut b = 0;
        for l in self.leaf.iter().flatten() {
            b += match l {
                BasisData::Plain(w) => w.byte_size(),
                BasisData::Z { blob, .. } => blob.byte_size(),
                BasisData::Valr(z) => z.byte_size(),
            } + BLOB_OVERHEAD;
        }
        for t in self.transfer.iter().flatten() {
            b += t.byte_size() + BLOB_OVERHEAD;
        }
        b
    }
}

fn compress_mat(m: &DMatrix, codec: Codec, eps: f64) -> Blob {
    Blob::compress(codec, m.data(), eps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::fibonacci_sphere;
    use crate::util::Rng;

    #[test]
    fn expand_reconstructs_nested_product() {
        // two-level tree: root with two leaf children; W_root = diag(W_c) E
        let pts = fibonacci_sphere(32);
        let ct = ClusterTree::build_with_depth(&pts, 16, 1);
        assert_eq!(ct.depth(), 1);
        let mut nb = NestedBasis::empty(ct.nodes.len());
        let mut rng = Rng::new(91);
        let kids = ct.node(0).children.clone();
        let k = 3;
        nb.rank[0] = k;
        for &c in &kids {
            let n = ct.node(c).size();
            let (q, _) = crate::la::qr_thin(&DMatrix::random(n, k, &mut rng));
            nb.rank[c] = k;
            nb.leaf[c] = Some(BasisData::Plain(q));
            nb.transfer[c] = Some(TransferMat::Plain(DMatrix::random(k, k, &mut rng)));
        }
        let expanded = nb.expand(&ct);
        // manual: root basis = [W0 E0; W1 E1]
        let w0 = nb.leaf[kids[0]].as_ref().map(|b| match b {
            BasisData::Plain(w) => w.clone(),
            _ => unreachable!(),
        }).unwrap();
        let e0 = nb.transfer[kids[0]].as_ref().unwrap().to_dense();
        let top = blas::matmul(&w0, blas::Trans::No, &e0, blas::Trans::No);
        for j in 0..k {
            for i in 0..top.nrows() {
                assert!((expanded[0][(i, j)] - top[(i, j)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn transfer_apply_matches_dense() {
        let mut rng = Rng::new(92);
        let e = DMatrix::random(4, 3, &mut rng);
        let t = TransferMat::Plain(e.clone());
        let s = rng.vector(4);
        let mut out = vec![0.0; 3];
        t.apply_transposed_add(&s, &mut out);
        for j in 0..3 {
            let want = blas::dot(e.col(j), &s);
            assert!((out[j] - want).abs() < 1e-12);
        }
        let tvec = rng.vector(3);
        let mut y = vec![0.0; 4];
        t.apply_add(&tvec, &mut y);
        let mut want = vec![0.0; 4];
        blas::gemv(1.0, &e, &tvec, &mut want);
        for i in 0..4 {
            assert!((y[i] - want[i]).abs() < 1e-12);
        }
    }
}
