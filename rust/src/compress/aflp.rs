//! AFLP — adaptive floating point compression (paper §4.1, Fig. 8 left).
//!
//! Layout per value (little-endian words of 1..8 bytes):
//!
//! ```text
//!   bit 8B-1 : sign
//!   bits e..8B-2 : mantissa (m' = 8B − 1 − e_bits bits, hidden leading 1)
//!   bits 0..e : biased exponent (value scaled by 1/v_min so exponent ≥ 0)
//! ```
//!
//! The exponent field value `(1<<e_bits)−1` is reserved as the zero marker.
//! Rounding is round-to-nearest on the mantissa with carry into the exponent.

use super::formats::{exponent_bits_for, mantissa_bits_for};
use super::{Blob, CodecParams};

/// Compress with relative per-value accuracy ≤ `eps`.
pub fn compress(data: &[f64], eps: f64) -> Blob {
    let n = data.len();
    // dynamic range over nonzero magnitudes
    let mut vmin = f64::INFINITY;
    let mut vmax = 0.0f64;
    for &x in data {
        let a = x.abs();
        if a > 0.0 {
            vmin = vmin.min(a);
            vmax = vmax.max(a);
        }
    }
    if vmax == 0.0 {
        return Blob { params: CodecParams::Zero, n, bytes: Vec::new().into() };
    }

    let e_bits = exponent_bits_for(vmin, vmax);
    let m_eps = mantissa_bits_for(eps.clamp(f64::MIN_POSITIVE, 0.5)) + 1; // +1: RTN gives u = 2^-(m+1)
    // byte-align: 1 + m' + e_bits multiple of 8
    let total_bits = (1 + m_eps + e_bits).div_ceil(8) * 8;
    let total_bits = total_bits.min(64);
    let bytes_per = (total_bits / 8) as u8;
    let m_bits = total_bits - 1 - e_bits;

    let zero_marker: u64 = (1u64 << e_bits) - 1;
    let e_max = zero_marker - 1; // largest storable exponent
    let mant_max: u64 = if m_bits >= 64 { u64::MAX } else { (1u64 << m_bits) - 1 };

    let mut bytes = vec![0u8; n * bytes_per as usize];
    let inv_scale = 1.0 / vmin;
    // extreme dynamic range: the scaled value v/v_min (and 2^e) can overflow
    // an f64, so the normalized fraction must be computed stepwise; a
    // subnormal v_min would likewise overflow 1/v_min
    let wide = vmax.log2() - vmin.log2() > 1020.0 || vmin < f64::MIN_POSITIVE;
    for (i, &x) in data.iter().enumerate() {
        let word: u64 = if x == 0.0 {
            zero_marker
        } else {
            let sign = if x < 0.0 { 1u64 } else { 0 };
            let a = x.abs();
            // fraction a / (v_min · 2^e) ∈ [1, 2): direct on the common path,
            // bounded power-of-two steps on the wide path (e may exceed 1023)
            let frac_at = |e: u64| -> f64 {
                if wide {
                    // build v_min·2^e upward (stays normal, exact powers of
                    // two), then divide: scaling `a` *down* instead would
                    // round it onto the subnormal grid when v_min is
                    // subnormal and destroy the fraction
                    let mut s = vmin;
                    let mut rem = e;
                    while rem > 0 {
                        let step = rem.min(512);
                        s *= f64::powi(2.0, step as i32);
                        rem -= step;
                    }
                    a / s
                } else {
                    a * inv_scale / f64::powi(2.0, e as i32)
                }
            };
            let mut e = if wide {
                (a.log2() - vmin.log2()).floor().max(0.0) as u64
            } else {
                (a * inv_scale).log2().floor().max(0.0) as u64
            };
            let mut frac = frac_at(e);
            // guard against log/pow edge cases
            if frac < 1.0 {
                if e > 0 {
                    e -= 1;
                }
                frac = frac_at(e);
            } else if frac >= 2.0 {
                e += 1;
                frac = frac_at(e);
            }
            // round-to-nearest mantissa
            let mut mant = ((frac - 1.0) * (mant_max as f64 + 1.0)).round() as u64;
            if mant > mant_max {
                mant = 0;
                e += 1;
            }
            if e > e_max {
                e = e_max;
                mant = mant_max;
            }
            (sign << (total_bits - 1)) | (mant << e_bits) | e
        };
        let off = i * bytes_per as usize;
        bytes[off..off + bytes_per as usize].copy_from_slice(&word.to_le_bytes()[..bytes_per as usize]);
    }

    Blob { params: CodecParams::Aflp { bytes_per, e_bits: e_bits as u8, scale: vmin }, n, bytes: bytes.into() }
}

/// Bulk decode.
pub fn decompress_into(blob: &Blob, out: &mut [f64]) {
    decompress_range(blob, 0, blob.n, out);
}

/// Decode values [begin, end) — branchless direct IEEE-754 bit assembly: the
/// stored mantissa becomes the f64 fraction field, the (non-negative) stored
/// exponent is rebiased, one multiply applies the block scale; no
/// transcendentals on the decode path. The kernel (AVX2 gather bit assembly
/// vs scalar; extreme-dynamic-range fallback for e_bits ≥ 11 or m_bits > 52)
/// is picked by the runtime ISA dispatch ([`super::dispatch`]), with all
/// codec parameters resolved once per call.
pub fn decompress_range(blob: &Blob, begin: usize, end: usize, out: &mut [f64]) {
    debug_assert!(matches!(blob.params, CodecParams::Aflp { .. }), "not an AFLP blob");
    super::dispatch::range(&blob.params, &blob.bytes, begin, end, out);
}

/// Random access (resolves codec parameters per call — hot loops hold a
/// [`super::dispatch::DecodeCursor`] instead).
#[inline]
pub fn get(blob: &Blob, i: usize) -> f64 {
    debug_assert!(matches!(blob.params, CodecParams::Aflp { .. }), "not an AFLP blob");
    super::dispatch::get(&blob.params, &blob.bytes, i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::max_rel_error;
    use crate::util::Rng;

    #[test]
    fn accuracy_across_eps() {
        let mut rng = Rng::new(41);
        let data: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
        for eps in [1e-1, 1e-3, 1e-5, 1e-7, 1e-9, 1e-12] {
            let blob = compress(&data, eps);
            assert!(max_rel_error(&blob, &data) <= eps, "eps {eps}");
        }
    }

    #[test]
    fn narrow_range_small_exponent() {
        let data: Vec<f64> = (0..100).map(|i| 1.0 + i as f64 / 100.0).collect();
        let blob = compress(&data, 1e-6);
        match blob.params {
            CodecParams::Aflp { e_bits, bytes_per, .. } => {
                assert!(e_bits <= 2, "e_bits {e_bits}");
                assert!(bytes_per <= 3);
            }
            _ => panic!("wrong params"),
        }
    }

    #[test]
    fn wide_dynamic_range() {
        let data: Vec<f64> = (0..200).map(|i| 2f64.powi(i - 100) * 1.3).collect();
        let blob = compress(&data, 1e-4);
        assert!(max_rel_error(&blob, &data) <= 1e-4);
    }

    #[test]
    fn extreme_dynamic_range_roundtrip() {
        // forces e_bits ≥ 11 (stored exponents beyond 1023) — regression for
        // the decode fallback that formed 2^e directly (inf) and for the
        // encoder's overflowing v/v_min normalization
        let data = vec![1e-250, -3.7e-120, 1.0, 4.2e80, -9.9e249, 1e250];
        let blob = compress(&data, 1e-3);
        match blob.params {
            CodecParams::Aflp { e_bits, .. } => assert!(e_bits >= 11, "e_bits {e_bits}"),
            _ => panic!("wrong params"),
        }
        let err = max_rel_error(&blob, &data);
        assert!(err <= 1e-3, "err {err}");
        // sign survives the fallback path
        let dec = blob.to_vec();
        for (d, o) in dec.iter().zip(&data) {
            assert_eq!(d.signum(), o.signum());
        }
        // random access must agree with bulk decode on the fallback path
        for i in 0..data.len() {
            assert_eq!(blob.get(i), dec[i], "idx {i}");
        }
    }

    #[test]
    fn subnormal_vmin_roundtrip() {
        // a subnormal v_min must not destroy the other values' fractions:
        // the encoder builds v_min·2^e upward instead of scaling the value
        // down onto the subnormal grid (and 1/v_min would overflow to inf)
        let data = vec![5e-324, 1.5, -2.25e10, 7.0e-310];
        let blob = compress(&data, 1e-6);
        let dec = blob.to_vec();
        // the subnormal anchor itself decodes exactly (frac = 1, e = 0)
        assert_eq!(dec[0], 5e-324);
        // normal-range values keep the eps guarantee
        for (d, o) in dec.iter().zip(&data).skip(1) {
            assert!((d - o).abs() <= 1e-6 * o.abs(), "{d:e} vs {o:e}");
        }
        for i in 0..data.len() {
            assert_eq!(blob.get(i), dec[i], "idx {i}");
        }
    }

    #[test]
    fn wide_mantissa_roundtrip() {
        // eps at the FP64 limit with a tiny dynamic range → more than 52
        // stored mantissa bits; pins the m_bits > 52 down-shift in both
        // decode paths
        let data: Vec<f64> = (0..64).map(|i| 1.0 + i as f64 / 64.0).collect();
        let blob = compress(&data, 1e-16);
        match blob.params {
            CodecParams::Aflp { bytes_per, e_bits, .. } => {
                let m_bits = 8 * bytes_per as u32 - 1 - e_bits as u32;
                assert!(m_bits > 52, "m_bits {m_bits}");
            }
            _ => panic!("wrong params"),
        }
        let err = max_rel_error(&blob, &data);
        assert!(err <= 1e-15, "err {err}");
        let dec = blob.to_vec();
        for i in 0..data.len() {
            assert_eq!(blob.get(i), dec[i], "idx {i}");
        }
    }

    #[test]
    fn negative_values() {
        let data = vec![-1.5, 2.5, -3.25, 4.125];
        let blob = compress(&data, 1e-8);
        let dec = blob.to_vec();
        for (d, o) in dec.iter().zip(&data) {
            assert!((d - o).abs() <= 1e-8 * o.abs());
            assert_eq!(d.signum(), o.signum());
        }
    }

    #[test]
    fn coarse_eps_small_footprint() {
        let mut rng = Rng::new(42);
        let data: Vec<f64> = (0..1024).map(|_| rng.normal()).collect();
        let blob = compress(&data, 1e-2);
        // 1 sign + 8 mantissa-ish + few exponent bits → ≤ 2 bytes/value
        assert!(blob.bytes.len() <= 2 * data.len(), "{} bytes", blob.bytes.len());
    }

    #[test]
    fn boundary_magnitudes_roundtrip() {
        // exactly vmin and vmax must decode within eps
        let data = vec![0.001, 1000.0, -0.001, -1000.0, 0.5];
        let blob = compress(&data, 1e-6);
        assert!(max_rel_error(&blob, &data) <= 1e-6);
    }
}
