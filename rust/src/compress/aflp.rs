//! AFLP — adaptive floating point compression (paper §4.1, Fig. 8 left).
//!
//! Layout per value (little-endian words of 1..8 bytes):
//!
//! ```text
//!   bit 8B-1 : sign
//!   bits e..8B-2 : mantissa (m' = 8B − 1 − e_bits bits, hidden leading 1)
//!   bits 0..e : biased exponent (value scaled by 1/v_min so exponent ≥ 0)
//! ```
//!
//! The exponent field value `(1<<e_bits)−1` is reserved as the zero marker.
//! Rounding is round-to-nearest on the mantissa with carry into the exponent.

use super::formats::{exponent_bits_for, mantissa_bits_for};
use super::{Blob, CodecParams};

/// Compress with relative per-value accuracy ≤ `eps`.
pub fn compress(data: &[f64], eps: f64) -> Blob {
    let n = data.len();
    // dynamic range over nonzero magnitudes
    let mut vmin = f64::INFINITY;
    let mut vmax = 0.0f64;
    for &x in data {
        let a = x.abs();
        if a > 0.0 {
            vmin = vmin.min(a);
            vmax = vmax.max(a);
        }
    }
    if vmax == 0.0 {
        return Blob { params: CodecParams::Zero, n, bytes: Vec::new() };
    }

    let e_bits = exponent_bits_for(vmin, vmax);
    let m_eps = mantissa_bits_for(eps.clamp(f64::MIN_POSITIVE, 0.5)) + 1; // +1: RTN gives u = 2^-(m+1)
    // byte-align: 1 + m' + e_bits multiple of 8
    let total_bits = (1 + m_eps + e_bits).div_ceil(8) * 8;
    let total_bits = total_bits.min(64);
    let bytes_per = (total_bits / 8) as u8;
    let m_bits = total_bits - 1 - e_bits;

    let zero_marker: u64 = (1u64 << e_bits) - 1;
    let e_max = zero_marker - 1; // largest storable exponent
    let mant_max: u64 = if m_bits >= 64 { u64::MAX } else { (1u64 << m_bits) - 1 };

    let mut bytes = vec![0u8; n * bytes_per as usize];
    let inv_scale = 1.0 / vmin;
    // extreme dynamic range: the scaled value v/v_min (and 2^e) can overflow
    // an f64, so the normalized fraction must be computed stepwise; a
    // subnormal v_min would likewise overflow 1/v_min
    let wide = vmax.log2() - vmin.log2() > 1020.0 || vmin < f64::MIN_POSITIVE;
    for (i, &x) in data.iter().enumerate() {
        let word: u64 = if x == 0.0 {
            zero_marker
        } else {
            let sign = if x < 0.0 { 1u64 } else { 0 };
            let a = x.abs();
            // fraction a / (v_min · 2^e) ∈ [1, 2): direct on the common path,
            // bounded power-of-two steps on the wide path (e may exceed 1023)
            let frac_at = |e: u64| -> f64 {
                if wide {
                    // build v_min·2^e upward (stays normal, exact powers of
                    // two), then divide: scaling `a` *down* instead would
                    // round it onto the subnormal grid when v_min is
                    // subnormal and destroy the fraction
                    let mut s = vmin;
                    let mut rem = e;
                    while rem > 0 {
                        let step = rem.min(512);
                        s *= f64::powi(2.0, step as i32);
                        rem -= step;
                    }
                    a / s
                } else {
                    a * inv_scale / f64::powi(2.0, e as i32)
                }
            };
            let mut e = if wide {
                (a.log2() - vmin.log2()).floor().max(0.0) as u64
            } else {
                (a * inv_scale).log2().floor().max(0.0) as u64
            };
            let mut frac = frac_at(e);
            // guard against log/pow edge cases
            if frac < 1.0 {
                if e > 0 {
                    e -= 1;
                }
                frac = frac_at(e);
            } else if frac >= 2.0 {
                e += 1;
                frac = frac_at(e);
            }
            // round-to-nearest mantissa
            let mut mant = ((frac - 1.0) * (mant_max as f64 + 1.0)).round() as u64;
            if mant > mant_max {
                mant = 0;
                e += 1;
            }
            if e > e_max {
                e = e_max;
                mant = mant_max;
            }
            (sign << (total_bits - 1)) | (mant << e_bits) | e
        };
        let off = i * bytes_per as usize;
        bytes[off..off + bytes_per as usize].copy_from_slice(&word.to_le_bytes()[..bytes_per as usize]);
    }

    Blob { params: CodecParams::Aflp { bytes_per, e_bits: e_bits as u8, scale: vmin }, n, bytes }
}

/// Decode one packed word by direct IEEE-754 bit assembly: the stored
/// mantissa becomes the f64 fraction field, the (non-negative) stored
/// exponent is rebiased, one multiply applies the block scale. No
/// transcendentals on the decode path (this is the MVM hot loop).
#[inline(always)]
fn decode_word(word: u64, e_bits: u32, total_bits: u32, scale: f64, zero_marker: u64) -> f64 {
    let e = word & zero_marker; // zero_marker == exponent mask
    if e == zero_marker {
        return 0.0;
    }
    let m_bits = total_bits - 1 - e_bits;
    let mant = (word >> e_bits) & ((1u64 << m_bits) - 1);
    let sign = (word >> (total_bits - 1)) & 1;
    if e <= 1023 {
        // common case: assemble the f64 directly
        let frac_bits = if m_bits <= 52 { mant << (52 - m_bits) } else { mant >> (m_bits - 52) };
        let bits = (sign << 63) | ((1023 + e) << 52) | frac_bits;
        f64::from_bits(bits) * scale
    } else {
        // extreme dynamic range (e > 1023): 2^e itself overflows an f64, so
        // fold the exponent into the block scale in bounded steps; the
        // mantissa is scaled by its true width 2^-m_bits (a plain division
        // by 2^min(m_bits,52) produced wrong magnitudes for m_bits > 52)
        let frac = 1.0 + mant as f64 * 0.5f64.powi(m_bits as i32);
        let mut sc = scale;
        let mut rem = e;
        while rem > 0 {
            let step = rem.min(512);
            sc *= f64::powi(2.0, step as i32);
            rem -= step;
        }
        let v = frac * sc;
        if sign == 1 {
            -v
        } else {
            v
        }
    }
}

fn params(blob: &Blob) -> (usize, u32, f64) {
    match blob.params {
        CodecParams::Aflp { bytes_per, e_bits, scale } => (bytes_per as usize, e_bits as u32, scale),
        _ => unreachable!("not an AFLP blob"),
    }
}

/// Bulk decode.
pub fn decompress_into(blob: &Blob, out: &mut [f64]) {
    decompress_range(blob, 0, blob.n, out);
}

/// Decode values [begin, end) — branchless direct bit assembly on the fast
/// path (8-byte masked loads, arithmetic zero-select) so the compiler can
/// vectorize; byte-assembled tail + rare-parameter fallback via
/// [`decode_word`].
pub fn decompress_range(blob: &Blob, begin: usize, end: usize, out: &mut [f64]) {
    let (b, e_bits, scale) = params(blob);
    let total_bits = (b * 8) as u32;
    let m_bits = total_bits - 1 - e_bits;
    let zero_marker = (1u64 << e_bits) - 1;
    let bytes = &blob.bytes;
    let n = end - begin;
    debug_assert_eq!(out.len(), n);

    if e_bits >= 11 || m_bits > 52 {
        // extreme dynamic range / over-wide mantissa: generic path
        let mut it = out.iter_mut();
        crate::compress::for_each_word(bytes, b, begin, end, |w| {
            *it.next().unwrap() = decode_word(w, e_bits, total_bits, scale, zero_marker);
        });
        return;
    }

    let word_mask: u64 = if b >= 8 { u64::MAX } else { (1u64 << (8 * b)) - 1 };
    let mant_mask: u64 = (1u64 << m_bits) - 1;
    let mshift = 52 - m_bits;
    // values whose 8-byte load stays in bounds
    let fast_total = if bytes.len() >= 8 { (bytes.len() - 8) / b + 1 } else { 0 };
    let fast = fast_total.min(end).saturating_sub(begin);

    let mut k0 = 0usize;
    #[cfg(all(target_arch = "x86_64", target_feature = "avx2"))]
    {
        // SIMD decode, 4 values per iteration (the CPU analogue of the
        // paper's AVX512 conversion kernels): byte-offset gather, vector
        // mask/shift bit assembly, one mul_pd for the block scale.
        use std::arch::x86_64::*;
        unsafe {
            let base = bytes.as_ptr() as *const i64;
            let wmask_v = _mm256_set1_epi64x(word_mask as i64);
            let emask_v = _mm256_set1_epi64x(zero_marker as i64);
            let mantmask_v = _mm256_set1_epi64x(mant_mask as i64);
            let c1023 = _mm256_set1_epi64x(1023);
            let scale_v = _mm256_set1_pd(scale);
            let cnt_e = _mm_cvtsi32_si128(e_bits as i32);
            let cnt_top = _mm_cvtsi32_si128(total_bits as i32 - 1);
            let cnt_63 = _mm_cvtsi32_si128(63);
            let cnt_52 = _mm_cvtsi32_si128(52);
            let cnt_m = _mm_cvtsi32_si128(mshift as i32);
            let step = _mm256_set1_epi64x(4 * b as i64);
            let mut off_v = _mm256_setr_epi64x(
                (begin * b) as i64,
                ((begin + 1) * b) as i64,
                ((begin + 2) * b) as i64,
                ((begin + 3) * b) as i64,
            );
            while k0 + 4 <= fast {
                let w = _mm256_and_si256(_mm256_i64gather_epi64::<1>(base, off_v), wmask_v);
                let e = _mm256_and_si256(w, emask_v);
                let is_zero = _mm256_cmpeq_epi64(e, emask_v);
                let mant = _mm256_and_si256(_mm256_srl_epi64(w, cnt_e), mantmask_v);
                let sign = _mm256_sll_epi64(_mm256_srl_epi64(w, cnt_top), cnt_63);
                let expf = _mm256_sll_epi64(_mm256_add_epi64(e, c1023), cnt_52);
                let frac = _mm256_sll_epi64(mant, cnt_m);
                let bits = _mm256_andnot_si256(is_zero, _mm256_or_si256(sign, _mm256_or_si256(expf, frac)));
                let v = _mm256_mul_pd(_mm256_castsi256_pd(bits), scale_v);
                _mm256_storeu_pd(out.as_mut_ptr().add(k0), v);
                off_v = _mm256_add_epi64(off_v, step);
                k0 += 4;
            }
        }
    }

    for (k, o) in out[k0..fast].iter_mut().enumerate() {
        let off = (begin + k0 + k) * b;
        let arr: [u8; 8] = unsafe { bytes.get_unchecked(off..off + 8) }.try_into().unwrap();
        let w = u64::from_le_bytes(arr) & word_mask;
        let e = w & zero_marker;
        let mant = (w >> e_bits) & mant_mask;
        let sign = w >> (total_bits - 1);
        let keep = ((e != zero_marker) as u64).wrapping_neg();
        let bits = ((sign << 63) | ((1023 + e) << 52) | (mant << mshift)) & keep;
        *o = f64::from_bits(bits) * scale;
    }
    for (k, o) in out[fast..n].iter_mut().enumerate() {
        let i = begin + fast + k;
        let mut buf = [0u8; 8];
        buf[..b].copy_from_slice(&bytes[i * b..i * b + b]);
        *o = decode_word(u64::from_le_bytes(buf), e_bits, total_bits, scale, zero_marker);
    }
}

/// Random access.
#[inline]
pub fn get(blob: &Blob, i: usize) -> f64 {
    let (b, e_bits, scale) = params(blob);
    let total_bits = (b * 8) as u32;
    let zero_marker = (1u64 << e_bits) - 1;
    let w = crate::compress::load_word_at(&blob.bytes, b, i);
    decode_word(w, e_bits, total_bits, scale, zero_marker)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::max_rel_error;
    use crate::util::Rng;

    #[test]
    fn accuracy_across_eps() {
        let mut rng = Rng::new(41);
        let data: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
        for eps in [1e-1, 1e-3, 1e-5, 1e-7, 1e-9, 1e-12] {
            let blob = compress(&data, eps);
            assert!(max_rel_error(&blob, &data) <= eps, "eps {eps}");
        }
    }

    #[test]
    fn narrow_range_small_exponent() {
        let data: Vec<f64> = (0..100).map(|i| 1.0 + i as f64 / 100.0).collect();
        let blob = compress(&data, 1e-6);
        match blob.params {
            CodecParams::Aflp { e_bits, bytes_per, .. } => {
                assert!(e_bits <= 2, "e_bits {e_bits}");
                assert!(bytes_per <= 3);
            }
            _ => panic!("wrong params"),
        }
    }

    #[test]
    fn wide_dynamic_range() {
        let data: Vec<f64> = (0..200).map(|i| 2f64.powi(i - 100) * 1.3).collect();
        let blob = compress(&data, 1e-4);
        assert!(max_rel_error(&blob, &data) <= 1e-4);
    }

    #[test]
    fn extreme_dynamic_range_roundtrip() {
        // forces e_bits ≥ 11 (stored exponents beyond 1023) — regression for
        // the decode fallback that formed 2^e directly (inf) and for the
        // encoder's overflowing v/v_min normalization
        let data = vec![1e-250, -3.7e-120, 1.0, 4.2e80, -9.9e249, 1e250];
        let blob = compress(&data, 1e-3);
        match blob.params {
            CodecParams::Aflp { e_bits, .. } => assert!(e_bits >= 11, "e_bits {e_bits}"),
            _ => panic!("wrong params"),
        }
        let err = max_rel_error(&blob, &data);
        assert!(err <= 1e-3, "err {err}");
        // sign survives the fallback path
        let dec = blob.to_vec();
        for (d, o) in dec.iter().zip(&data) {
            assert_eq!(d.signum(), o.signum());
        }
        // random access must agree with bulk decode on the fallback path
        for i in 0..data.len() {
            assert_eq!(blob.get(i), dec[i], "idx {i}");
        }
    }

    #[test]
    fn subnormal_vmin_roundtrip() {
        // a subnormal v_min must not destroy the other values' fractions:
        // the encoder builds v_min·2^e upward instead of scaling the value
        // down onto the subnormal grid (and 1/v_min would overflow to inf)
        let data = vec![5e-324, 1.5, -2.25e10, 7.0e-310];
        let blob = compress(&data, 1e-6);
        let dec = blob.to_vec();
        // the subnormal anchor itself decodes exactly (frac = 1, e = 0)
        assert_eq!(dec[0], 5e-324);
        // normal-range values keep the eps guarantee
        for (d, o) in dec.iter().zip(&data).skip(1) {
            assert!((d - o).abs() <= 1e-6 * o.abs(), "{d:e} vs {o:e}");
        }
        for i in 0..data.len() {
            assert_eq!(blob.get(i), dec[i], "idx {i}");
        }
    }

    #[test]
    fn wide_mantissa_roundtrip() {
        // eps at the FP64 limit with a tiny dynamic range → more than 52
        // stored mantissa bits; pins the m_bits > 52 down-shift in both
        // decode paths
        let data: Vec<f64> = (0..64).map(|i| 1.0 + i as f64 / 64.0).collect();
        let blob = compress(&data, 1e-16);
        match blob.params {
            CodecParams::Aflp { bytes_per, e_bits, .. } => {
                let m_bits = 8 * bytes_per as u32 - 1 - e_bits as u32;
                assert!(m_bits > 52, "m_bits {m_bits}");
            }
            _ => panic!("wrong params"),
        }
        let err = max_rel_error(&blob, &data);
        assert!(err <= 1e-15, "err {err}");
        let dec = blob.to_vec();
        for i in 0..data.len() {
            assert_eq!(blob.get(i), dec[i], "idx {i}");
        }
    }

    #[test]
    fn negative_values() {
        let data = vec![-1.5, 2.5, -3.25, 4.125];
        let blob = compress(&data, 1e-8);
        let dec = blob.to_vec();
        for (d, o) in dec.iter().zip(&data) {
            assert!((d - o).abs() <= 1e-8 * o.abs());
            assert_eq!(d.signum(), o.signum());
        }
    }

    #[test]
    fn coarse_eps_small_footprint() {
        let mut rng = Rng::new(42);
        let data: Vec<f64> = (0..1024).map(|_| rng.normal()).collect();
        let blob = compress(&data, 1e-2);
        // 1 sign + 8 mantissa-ish + few exponent bits → ≤ 2 bytes/value
        assert!(blob.bytes.len() <= 2 * data.len(), "{} bytes", blob.bytes.len());
    }

    #[test]
    fn boundary_magnitudes_roundtrip() {
        // exactly vmin and vmax must decode within eps
        let data = vec![0.001, 1000.0, -0.001, -1000.0, 0.5];
        let blob = compress(&data, 1e-6);
        assert!(max_rel_error(&blob, &data) <= 1e-6);
    }
}
