//! FPX — byte-aligned truncated IEEE-754 compression (paper §4.1, Fig. 8
//! right; format of Amestoy et al. 2025 with round-to-nearest as in the
//! paper).
//!
//! A value is stored as the top `B` bytes of its FP32 (B ∈ {2,3,4}) or FP64
//! (B ∈ {3..8}) bit pattern, rounded to nearest at the truncation point.
//! Decompression is a byte shift + bitcast — no arithmetic — which is why the
//! paper observes up to 50 % faster decode than AFLP (Remark 4.1).

use super::formats::mantissa_bits_for;
use super::{Blob, CodecParams};

/// Compress with relative per-value accuracy ≤ `eps`.
pub fn compress(data: &[f64], eps: f64) -> Blob {
    let n = data.len();
    let mut vmax = 0.0f64;
    let mut vmin = f64::INFINITY;
    for &x in data {
        let a = x.abs();
        if a > 0.0 {
            vmax = vmax.max(a);
            vmin = vmin.min(a);
        }
    }
    if vmax == 0.0 {
        return Blob { params: CodecParams::Zero, n, bytes: Vec::new().into() };
    }

    let m = mantissa_bits_for(eps.clamp(f64::MIN_POSITIVE, 0.5));
    // FP32 base format feasible: mantissa fits and values are normal in f32
    let fp32_ok = m <= 23 && vmax < f32::MAX as f64 / 2.0 && vmin > 2.0 * f32::MIN_POSITIVE as f64;
    if fp32_ok {
        // widen on a rejected rounding carry: keeping the unrounded bits
        // would silently degrade RTN to truncation (error up to 1 ulp where
        // bytes_per was sized for 0.5 ulp), so retry at the next byte width
        let mut bytes_per = (9 + m).div_ceil(8).max(2) as usize; // sign+8 exp+m mantissa
        while bytes_per <= 4 {
            if let Some(bytes) = pack32(data, bytes_per) {
                return Blob { params: CodecParams::Fpx32 { bytes_per: bytes_per as u8 }, n, bytes: bytes.into() };
            }
            bytes_per += 1;
        }
        // unreachable in practice (bytes_per = 4 has no rounding step) —
        // fall through to the FP64 path for safety
    }
    let mut bytes_per = (12 + m).div_ceil(8).clamp(3, 8) as usize; // sign+11 exp+m mantissa
    loop {
        if let Some(bytes) = pack64(data, bytes_per) {
            return Blob { params: CodecParams::Fpx64 { bytes_per: bytes_per as u8 }, n, bytes: bytes.into() };
        }
        bytes_per += 1; // bytes_per = 8 has no rounding step, so this ends
    }
}

/// Pack the top `bytes_per` bytes of the FP32 patterns with RTN; `None` when
/// some value's rounding carry would overflow into inf/nan at this width
/// (the caller widens instead of silently truncating).
fn pack32(data: &[f64], bytes_per: usize) -> Option<Vec<u8>> {
    let shift = 32 - 8 * bytes_per as u32;
    let mut bytes = vec![0u8; data.len() * bytes_per];
    for (i, &x) in data.iter().enumerate() {
        let f = x as f32; // RTN to FP32 first
        let mut bits = f.to_bits();
        if shift > 0 {
            let rounded = bits.wrapping_add(1u32 << (shift - 1));
            if !f32::from_bits((rounded >> shift) << shift).is_finite() {
                return None;
            }
            bits = rounded;
        }
        let word = bits >> shift;
        let off = i * bytes_per;
        bytes[off..off + bytes_per].copy_from_slice(&word.to_le_bytes()[..bytes_per]);
    }
    Some(bytes)
}

/// FP64 analogue of [`pack32`].
fn pack64(data: &[f64], bytes_per: usize) -> Option<Vec<u8>> {
    let shift = 64 - 8 * bytes_per as u32;
    let mut bytes = vec![0u8; data.len() * bytes_per];
    for (i, &x) in data.iter().enumerate() {
        let mut bits = x.to_bits();
        if shift > 0 {
            let rounded = bits.wrapping_add(1u64 << (shift - 1));
            if !f64::from_bits((rounded >> shift) << shift).is_finite() {
                return None;
            }
            bits = rounded;
        }
        let word = bits >> shift;
        let off = i * bytes_per;
        bytes[off..off + bytes_per].copy_from_slice(&word.to_le_bytes()[..bytes_per]);
    }
    Some(bytes)
}

/// Bulk decode.
pub fn decompress_into(blob: &Blob, out: &mut [f64]) {
    decompress_range(blob, 0, blob.n, out);
}

/// Decode values [begin, end) — pure shift + bitcast (the property that
/// makes FPX decode cheaper than AFLP, Remark 4.1). The actual kernel is
/// picked by the runtime ISA dispatch ([`super::dispatch`]): AVX2
/// gather/shift in every release build on capable CPUs, scalar otherwise.
pub fn decompress_range(blob: &Blob, begin: usize, end: usize, out: &mut [f64]) {
    debug_assert!(matches!(blob.params, CodecParams::Fpx32 { .. } | CodecParams::Fpx64 { .. }), "not an FPX blob");
    super::dispatch::range(&blob.params, &blob.bytes, begin, end, out);
}

/// Random access (resolves codec parameters per call — hot loops hold a
/// [`super::dispatch::DecodeCursor`] instead).
#[inline]
pub fn get(blob: &Blob, i: usize) -> f64 {
    debug_assert!(matches!(blob.params, CodecParams::Fpx32 { .. } | CodecParams::Fpx64 { .. }), "not an FPX blob");
    super::dispatch::get(&blob.params, &blob.bytes, i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::max_rel_error;
    use crate::util::Rng;

    #[test]
    fn fp32_path_for_coarse_eps() {
        let mut rng = Rng::new(51);
        let data: Vec<f64> = (0..512).map(|_| rng.normal()).collect();
        let blob = compress(&data, 1e-4);
        assert!(matches!(blob.params, CodecParams::Fpx32 { .. }));
        assert!(max_rel_error(&blob, &data) <= 1e-4);
    }

    #[test]
    fn fp64_path_for_fine_eps() {
        let mut rng = Rng::new(52);
        let data: Vec<f64> = (0..512).map(|_| rng.normal()).collect();
        let blob = compress(&data, 1e-10);
        assert!(matches!(blob.params, CodecParams::Fpx64 { .. }));
        assert!(max_rel_error(&blob, &data) <= 1e-10);
    }

    #[test]
    fn bf16_like_two_bytes() {
        let mut rng = Rng::new(53);
        let data: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
        let blob = compress(&data, 1e-2);
        assert_eq!(blob.bytes_per_value(), 2);
        assert!(max_rel_error(&blob, &data) <= 1e-2);
    }

    #[test]
    fn huge_dynamic_range_forces_fp64() {
        let data = vec![1e-60, 1.0, 1e60];
        let blob = compress(&data, 1e-3);
        assert!(matches!(blob.params, CodecParams::Fpx64 { .. }));
        assert!(max_rel_error(&blob, &data) <= 1e-3);
    }

    #[test]
    fn exact_at_full_width() {
        let mut rng = Rng::new(54);
        let data: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        let blob = compress(&data, 1e-15);
        assert_eq!(blob.bytes_per_value(), 8);
        assert_eq!(blob.to_vec(), data); // full FP64: lossless
    }

    #[test]
    fn rounding_is_to_nearest() {
        // value exactly between two representable truncations rounds away
        // from truncation (i.e. error strictly less than one ulp of the
        // truncated format)
        let data = vec![1.0 + 2f64.powi(-9)]; // needs 9 mantissa bits
        let blob = compress(&data, 1e-2); // 2 bytes: bf16-like, 7 mantissa bits
        let dec = blob.to_vec()[0];
        assert!((dec - data[0]).abs() <= 2f64.powi(-8), "dec {dec}");
    }

    #[test]
    fn near_f32_max_no_overflow() {
        let data = vec![3.0e38, -3.0e38, 1.0];
        let blob = compress(&data, 1e-3);
        let dec = blob.to_vec();
        assert!(dec.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn rounding_guard_widens_at_format_max() {
        // regression: values within half a stored-ulp of the format maximum
        // hit the rounding-overflow guard, which used to keep the unrounded
        // bits — silently degrading RTN to truncation with error ≈ 1 ulp of
        // the stored width (double the 0.5-ulp budget the width was sized
        // for). The fix widens to the next byte width, so the error must now
        // be strictly better than the truncation fallback (~eps/2).
        let eps = 2f64.powi(-12); // → 3 bytes on the FP64 path, 12 stored mantissa bits
        let data = vec![f64::MAX, -f64::MAX, 3.4e38, -3.4e38, 1.0];
        let blob = compress(&data, eps);
        let dec = blob.to_vec();
        assert!(dec.iter().all(|v| v.is_finite()));
        let err = max_rel_error(&blob, &data);
        assert!(err <= eps / 4.0, "err {err} vs eps/4 {}", eps / 4.0);
    }

    #[test]
    fn no_widening_when_guard_never_trips() {
        // sanity: ordinary data keeps the eps-derived byte width
        let mut rng = Rng::new(55);
        let data: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
        let blob = compress(&data, 2f64.powi(-12));
        assert_eq!(blob.bytes_per_value(), 3);
        assert!(max_rel_error(&blob, &data) <= 2f64.powi(-12));
    }
}
