//! VALR — Variable Accuracy per Low-Rank column (paper §4.2, Eq. 6/7).
//!
//! A low-rank block M ≈ U·Vᵀ is re-factored as W·diag(σ)·Xᵀ with orthonormal
//! W, X (SVD of the factored product). Column i of W and X is then compressed
//! with its *own* accuracy δᵢ = δ/(k·σᵢ): directions with small singular
//! values tolerate coarse storage, so the total footprint is far below a
//! fixed-precision encoding at the same block error δ.

use super::{Blob, Codec, BLOB_OVERHEAD};
use crate::la::DMatrix;
use crate::lowrank::LowRank;

/// VALR-compressed low-rank block (or cluster basis): per-column blobs plus
/// FP64 singular values.
#[derive(Clone, Debug)]
pub struct ZLowRankValr {
    pub nrows: usize,
    pub ncols: usize,
    /// Singular values σ₀ ≥ σ₁ ≥ … (kept in FP64: k values are negligible).
    pub sigma: Vec<f64>,
    /// Columns of W (nrows each), compressed with accuracy δ/(k·σᵢ).
    pub wcols: Vec<Blob>,
    /// Columns of X (ncols each).
    pub xcols: Vec<Blob>,
}

impl ZLowRankValr {
    /// Compress a factored block with total accuracy `eps` relative to the
    /// block's spectral norm (σ₀).
    pub fn compress_lowrank(lr: &LowRank, codec: Codec, eps: f64) -> ZLowRankValr {
        let svd = crate::lowrank::truncated_svd_of_product(lr, eps);
        Self::from_svd_parts(&svd.u, &svd.s, &svd.v, codec, eps)
    }

    /// Compress explicit orthonormal factors W (m×k), X (n×k) with weights σ.
    pub fn from_svd_parts(w: &DMatrix, sigma: &[f64], x: &DMatrix, codec: Codec, eps: f64) -> ZLowRankValr {
        let k = sigma.len();
        assert_eq!(w.ncols(), k);
        assert_eq!(x.ncols(), k);
        let s0 = sigma.first().copied().unwrap_or(0.0);
        let mut wcols = Vec::with_capacity(k);
        let mut xcols = Vec::with_capacity(k);
        for i in 0..k {
            // per-column accuracy δ_i = ε σ₀ / (2k σ_i); the 2k compensates the
            // error accumulation of Eq. (7) over both factors.
            let delta_i = if sigma[i] > 0.0 && s0 > 0.0 {
                (eps * s0 / (2.0 * k as f64 * sigma[i])).clamp(1e-16, 0.25)
            } else {
                0.25
            };
            wcols.push(Blob::compress(codec, w.col(i), delta_i));
            xcols.push(Blob::compress(codec, x.col(i), delta_i));
        }
        ZLowRankValr { nrows: w.nrows(), ncols: x.nrows(), sigma: sigma.to_vec(), wcols, xcols }
    }

    /// Compress a *cluster basis* (orthonormal columns with singular weights):
    /// only one factor, same per-column rule (Eq. 7).
    pub fn compress_basis(w: &DMatrix, sigma: &[f64], codec: Codec, eps: f64) -> ZLowRankValr {
        let k = w.ncols();
        let s0 = sigma.first().copied().unwrap_or(0.0);
        let mut wcols = Vec::with_capacity(k);
        for i in 0..k {
            let si = sigma.get(i).copied().unwrap_or(s0);
            let delta_i = if si > 0.0 && s0 > 0.0 { (eps * s0 / (k as f64 * si)).clamp(1e-16, 0.25) } else { 0.25 };
            wcols.push(Blob::compress(codec, w.col(i), delta_i));
        }
        ZLowRankValr { nrows: w.nrows(), ncols: 0, sigma: sigma.to_vec(), wcols, xcols: Vec::new() }
    }

    pub fn rank(&self) -> usize {
        self.wcols.len()
    }

    /// Reconstruct the dense block W·diag(σ)·Xᵀ (tests / error measurement).
    pub fn to_dense(&self) -> DMatrix {
        let mut out = DMatrix::zeros(self.nrows, self.ncols);
        let mut wbuf = vec![0.0; self.nrows];
        let mut xbuf = vec![0.0; self.ncols];
        for i in 0..self.rank() {
            self.wcols[i].decompress_into(&mut wbuf);
            self.xcols[i].decompress_into(&mut xbuf);
            for j in 0..self.ncols {
                let sx = self.sigma[i] * xbuf[j];
                if sx != 0.0 {
                    let col = out.col_mut(j);
                    for r in 0..self.nrows {
                        col[r] += wbuf[r] * sx;
                    }
                }
            }
        }
        out
    }

    /// Decompress to factored form U·Vᵀ with σ folded into V (for algorithms
    /// that need explicit factors, e.g. the stacked MVM).
    pub fn to_lowrank(&self) -> LowRank {
        let u = self.w_to_dense();
        let mut v = DMatrix::zeros(self.ncols, self.rank());
        for i in 0..self.rank() {
            self.xcols[i].decompress_into(v.col_mut(i));
            let s = self.sigma[i];
            for x in v.col_mut(i) {
                *x *= s;
            }
        }
        LowRank { u, v }
    }

    /// Decompress the basis factor W (as a matrix, σ NOT applied).
    pub fn w_to_dense(&self) -> DMatrix {
        let mut w = DMatrix::zeros(self.nrows, self.rank());
        for i in 0..self.rank() {
            self.wcols[i].decompress_into(w.col_mut(i));
        }
        w
    }

    /// Memory footprint.
    pub fn byte_size(&self) -> usize {
        let cols: usize = self.wcols.iter().chain(self.xcols.iter()).map(|b| b.byte_size()).sum();
        cols + self.sigma.len() * 8 + BLOB_OVERHEAD
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::{matmul, Trans};
    use crate::util::Rng;

    /// A low-rank block with prescribed singular value decay σ_i = decay^i.
    fn decaying_block(m: usize, n: usize, k: usize, decay: f64, seed: u64) -> LowRank {
        let mut rng = Rng::new(seed);
        let (qu, _) = crate::la::qr_thin(&DMatrix::random(m, k, &mut rng));
        let (qv, _) = crate::la::qr_thin(&DMatrix::random(n, k, &mut rng));
        let mut v = qv;
        for i in 0..k {
            let s = decay.powi(i as i32);
            for x in v.col_mut(i) {
                *x *= s;
            }
        }
        LowRank { u: qu, v }
    }

    #[test]
    fn valr_meets_block_accuracy() {
        let lr = decaying_block(60, 50, 10, 0.3, 61);
        let dense = lr.to_dense();
        for codec in [Codec::Aflp, Codec::Fpx] {
            for eps in [1e-4, 1e-6, 1e-8] {
                let z = ZLowRankValr::compress_lowrank(&lr, codec, eps);
                let mut d = z.to_dense();
                d.add_scaled(-1.0, &dense);
                let err = d.fro_norm() / dense.fro_norm();
                assert!(err <= eps, "{codec:?} eps={eps} err={err}");
            }
        }
    }

    #[test]
    fn valr_smaller_than_fixed_precision() {
        // strong decay → the tail columns cost almost nothing under VALR
        let lr = decaying_block(256, 256, 20, 0.25, 62);
        let eps = 1e-8;
        let z = ZLowRankValr::compress_lowrank(&lr, Codec::Aflp, eps);
        // fixed-precision alternative: both factors at eps
        let svd = crate::lowrank::truncated_svd_of_product(&lr, eps);
        let fixed = Blob::compress(Codec::Aflp, svd.u.data(), eps).byte_size()
            + Blob::compress(Codec::Aflp, svd.v.data(), eps).byte_size()
            + svd.s.len() * 8;
        assert!(z.byte_size() < fixed, "valr {} !< fixed {}", z.byte_size(), fixed);
    }

    #[test]
    fn tail_columns_coarser_than_head() {
        let lr = decaying_block(128, 128, 12, 0.2, 63);
        let z = ZLowRankValr::compress_lowrank(&lr, Codec::Aflp, 1e-10);
        let first = z.wcols.first().unwrap().bytes_per_value();
        let last = z.wcols.last().unwrap().bytes_per_value();
        assert!(last < first, "head {first} tail {last}");
    }

    #[test]
    fn basis_compression_error_bound() {
        // Eq. (7): ‖WΣ − W̃Σ‖_F ≤ Σ δ_i σ_i ≤ ε σ₀
        let mut rng = Rng::new(64);
        let (w, _) = crate::la::qr_thin(&DMatrix::random(80, 8, &mut rng));
        let sigma: Vec<f64> = (0..8).map(|i| 0.4f64.powi(i)).collect();
        let eps = 1e-6;
        let z = ZLowRankValr::compress_basis(&w, &sigma, Codec::Aflp, eps);
        let wd = z.w_to_dense();
        // scaled difference
        let mut diff = 0.0f64;
        for i in 0..8 {
            let mut col_err2 = 0.0;
            for r in 0..80 {
                let d = w[(r, i)] - wd[(r, i)];
                col_err2 += d * d;
            }
            diff += col_err2.sqrt() * sigma[i];
        }
        assert!(diff <= eps * sigma[0] * 1.001, "diff {diff}");
    }

    #[test]
    fn to_dense_matches_factored_product() {
        let lr = decaying_block(30, 25, 5, 0.5, 65);
        let z = ZLowRankValr::compress_lowrank(&lr, Codec::Fpx, 1e-12);
        let svd = crate::lowrank::truncated_svd_of_product(&lr, 1e-12);
        let mut us = svd.u.clone();
        for (j, &s) in svd.s.iter().enumerate() {
            for x in us.col_mut(j) {
                *x *= s;
            }
        }
        let direct = matmul(&us, Trans::No, &svd.v, Trans::Yes);
        let zd = z.to_dense();
        for j in 0..25 {
            for i in 0..30 {
                assert!((zd[(i, j)] - direct[(i, j)]).abs() < 1e-9);
            }
        }
    }
}
