//! IEEE-754 (and related) format parameters — paper Table 1.

/// Unit roundoff u = 2^−(m+1) for a format with `m` stored mantissa bits
/// (round to nearest).
pub fn unit_roundoff(mantissa_bits: u32) -> f64 {
    0.5f64.powi(mantissa_bits as i32 + 1)
}

/// Mantissa bits of the named formats from Table 1.
pub mod mantissa_bits {
    pub const FP64: u32 = 52;
    pub const FP32: u32 = 23;
    pub const TF32: u32 = 10;
    pub const BF16: u32 = 7;
    pub const FP16: u32 = 10;
    /// FP8 in the E4M3 variant.
    pub const FP8_E4M3: u32 = 3;
}

/// Number of mantissa bits needed for accuracy ε: m_ε = ⌈−log₂ ε⌉ (paper §4.1).
pub fn mantissa_bits_for(eps: f64) -> u32 {
    assert!(eps > 0.0 && eps < 1.0, "eps must be in (0,1), got {eps}");
    (-eps.log2()).ceil() as u32
}

/// Number of exponent bits needed for a dynamic range v_max/v_min:
/// e_dr = ⌈log₂ log₂ (v_max/v_min)⌉ — we additionally guarantee that the
/// value range 0..=E+1 (E = ⌊log₂(v_max/v_min)⌋, +1 rounding margin) plus a
/// zero marker fits, which is the operational requirement.
pub fn exponent_bits_for(vmin: f64, vmax: f64) -> u32 {
    debug_assert!(vmin > 0.0 && vmax >= vmin);
    let e_max = (vmax / vmin).log2().floor() as i64 + 1; // +1 rounding margin
    // values 0..=e_max plus reserved zero marker must fit in e_bits
    let needed = (e_max + 2) as u64;
    (64 - needed.leading_zeros()).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Validates Table 1 of the paper.
    #[test]
    fn table1_unit_roundoffs() {
        let close = |a: f64, b: f64| (a - b).abs() < 0.01 * b;
        assert!(close(unit_roundoff(mantissa_bits::FP64), 1.11e-16));
        assert!(close(unit_roundoff(mantissa_bits::FP32), 5.96e-8));
        assert!(close(unit_roundoff(mantissa_bits::TF32), 4.88e-4));
        assert!(close(unit_roundoff(mantissa_bits::BF16), 3.91e-3));
        assert!(close(unit_roundoff(mantissa_bits::FP16), 4.88e-4));
        assert!(close(unit_roundoff(mantissa_bits::FP8_E4M3), 6.25e-2));
    }

    #[test]
    fn mantissa_bits_monotone() {
        assert_eq!(mantissa_bits_for(0.5), 1);
        assert!(mantissa_bits_for(1e-4) < mantissa_bits_for(1e-8));
        assert_eq!(mantissa_bits_for(2f64.powi(-20)), 20);
    }

    #[test]
    fn exponent_bits_cover_range() {
        // single magnitude: minimal bits
        assert!(exponent_bits_for(1.0, 1.0) >= 1);
        // wide range needs more bits
        assert!(exponent_bits_for(1e-10, 1e10) > exponent_bits_for(0.5, 2.0));
        // e_bits for range 2^40: E=41, need ceil(log2(43)) = 6
        assert_eq!(exponent_bits_for(1.0, 2f64.powi(40)), 6);
    }
}
