//! Codec-kernel subsystem: runtime ISA dispatch, streaming decode cursors and
//! fused decode–FMA kernels (the paper's Remark 4.1 made a first-class
//! execution mode).
//!
//! Three pieces, layered:
//!
//! 1. **Runtime SIMD dispatch.** The AVX2 decode paths used to be gated
//!    behind compile-time `target_feature=+avx2`, so a plain
//!    `cargo build --release` silently fell back to scalar decode. Here the
//!    ISA level is detected once at runtime (`is_x86_feature_detected!`,
//!    overridable with `HMATC_SIMD=scalar` for debugging) and resolved into a
//!    per-`(codec, width)` [`KernelTable`] of function pointers — SIMD decode
//!    is active in every release build.
//!
//! 2. **Resolved codec parameters.** [`Resolved`] holds everything a decode
//!    needs (byte width, shift counts, field masks, block scale), computed
//!    *once per blob* instead of re-matched per `decompress_range` call. The
//!    [`DecodeCursor`] pairs a resolved blob with a position, so streamed
//!    apply paths pay the codec setup once and then just yield chunks.
//!
//! 3. **Fused decode–FMA kernels.** `dot`/`axpy` (and the `*_panel` variants
//!    for gemm-shaped multi-RHS tasks) keep decoded lanes in registers and
//!    combine them with the vector data directly — no round trip through a
//!    stack buffer between "decompress" and "FMA".
//!
//! Determinism contract (what keeps `tests/executor_equivalence.rs` bitwise
//! green and results independent of the machine the build lands on):
//!
//! * range decode and `axpy` are **bitwise identical** between the scalar and
//!   AVX2 kernels (pure bit assembly plus at most one multiply per element);
//! * `dot` accumulates stride-4 lane sums over the values whose unaligned
//!   8-byte load stays in bounds, folds the remaining values serially into
//!   lane 0, and reduces as `(s0+s1)+(s2+s3)` — the SIMD and scalar kernels
//!   perform the identical sequence of rounded operations. (This is the same
//!   *style* as [`crate::la::blas::dot`] but not bit-equal to decode-then-dot:
//!   the unrolled span ends at the 8-byte-load window, not at `n & !3`.);
//! * the panel kernels run the same per-column operation sequence as the
//!   single-vector kernels, so batched and per-column products agree bitwise
//!   for batch widths up to [`PANEL_FUSE_MAX`] (beyond that the apply helpers
//!   switch to the decode-once blockwise layout — see below).

use super::{Blob, CodecParams};
use std::sync::atomic::{AtomicU8, Ordering};

// ---------------------------------------------------------------------------
// Runtime ISA + kernel-mode selection
// ---------------------------------------------------------------------------

/// Instruction-set level the decode kernels dispatch to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar kernels (also the forced-debug mode).
    Scalar,
    /// AVX2 gather/shift kernels (x86-64, detected at runtime).
    Avx2,
}

/// How the compressed apply kernels execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// Fused decode–FMA: decoded lanes stay in registers (default).
    Fused,
    /// Legacy blockwise scheme: 64-entry stack buffer between decode and FMA
    /// (kept for the ablation bench and as a debugging fallback).
    Blockwise,
}

// 0 = unresolved, 1 = scalar, 2 = avx2
static SIMD: AtomicU8 = AtomicU8::new(0);
// 0 = unresolved, 1 = fused, 2 = blockwise
static MODE: AtomicU8 = AtomicU8::new(0);

fn detect() -> u8 {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return 2;
        }
    }
    1
}

/// The dispatched ISA level, resolved once from the CPU (and `HMATC_SIMD`:
/// `scalar` forces the portable kernels, anything else auto-detects).
pub fn simd_level() -> SimdLevel {
    match SIMD.load(Ordering::Relaxed) {
        2 => SimdLevel::Avx2,
        1 => SimdLevel::Scalar,
        _ => {
            let v = match std::env::var("HMATC_SIMD").ok().as_deref() {
                Some("scalar") => 1,
                Some("avx2") | Some("auto") | None => detect(),
                Some(other) => {
                    eprintln!("hmatc: unknown HMATC_SIMD '{other}' (scalar|avx2|auto) — auto-detecting");
                    detect()
                }
            };
            SIMD.store(v, Ordering::Relaxed);
            if v == 2 {
                SimdLevel::Avx2
            } else {
                SimdLevel::Scalar
            }
        }
    }
}

/// Force an ISA level (tests / benches); `None` re-resolves from the
/// environment and CPU on next use. Forcing `Avx2` on a CPU without it falls
/// back to scalar.
pub fn force_simd(level: Option<SimdLevel>) {
    let v = match level {
        None => 0,
        Some(SimdLevel::Scalar) => 1,
        Some(SimdLevel::Avx2) => detect(),
    };
    SIMD.store(v, Ordering::Relaxed);
}

/// Name of the dispatched ISA level (logs, `hmatc info`, bench rows).
pub fn simd_name() -> &'static str {
    match simd_level() {
        SimdLevel::Avx2 => "avx2",
        SimdLevel::Scalar => "scalar",
    }
}

/// The selected kernel mode, resolved once from `HMATC_CODEC_KERNELS`
/// (`fused` | `blockwise`, default `fused`).
pub fn kernel_mode() -> KernelMode {
    match MODE.load(Ordering::Relaxed) {
        1 => KernelMode::Fused,
        2 => KernelMode::Blockwise,
        _ => {
            let v = match std::env::var("HMATC_CODEC_KERNELS").ok().as_deref() {
                Some("blockwise") => 2,
                Some("fused") | None => 1,
                Some(other) => {
                    eprintln!("hmatc: unknown HMATC_CODEC_KERNELS '{other}' (fused|blockwise) — using fused");
                    1
                }
            };
            MODE.store(v, Ordering::Relaxed);
            if v == 2 {
                KernelMode::Blockwise
            } else {
                KernelMode::Fused
            }
        }
    }
}

/// Force a kernel mode (tests / the ablation bench); `None` re-resolves from
/// the environment on next use.
pub fn set_kernel_mode(mode: Option<KernelMode>) {
    let v = match mode {
        None => 0,
        Some(KernelMode::Fused) => 1,
        Some(KernelMode::Blockwise) => 2,
    };
    MODE.store(v, Ordering::Relaxed);
}

/// Name of the selected kernel mode.
pub fn kernel_mode_name() -> &'static str {
    match kernel_mode() {
        KernelMode::Fused => "fused",
        KernelMode::Blockwise => "blockwise",
    }
}

/// Combined label recorded in plan metadata and bench rows, e.g.
/// `"fused+avx2"`.
pub fn kernels_label() -> &'static str {
    match (kernel_mode(), simd_level()) {
        (KernelMode::Fused, SimdLevel::Avx2) => "fused+avx2",
        (KernelMode::Fused, SimdLevel::Scalar) => "fused+scalar",
        (KernelMode::Blockwise, SimdLevel::Avx2) => "blockwise+avx2",
        (KernelMode::Blockwise, SimdLevel::Scalar) => "blockwise+scalar",
    }
}

// ---------------------------------------------------------------------------
// Resolved per-blob decode parameters
// ---------------------------------------------------------------------------

/// Decode parameters resolved once per blob: byte width, shift counts, field
/// masks and the block scale. All kernels take this by reference — nothing is
/// re-derived per chunk or per element.
#[derive(Clone, Copy, Debug)]
pub struct Resolved {
    /// Bytes per value (0 for the zero codec).
    pub(crate) b: usize,
    /// FPX: left shift restoring the IEEE bit position.
    pub(crate) shift: u32,
    /// AFLP: mask selecting the stored word's bits.
    pub(crate) word_mask: u64,
    /// AFLP: exponent mask == reserved zero marker.
    pub(crate) zero_marker: u64,
    /// AFLP: mantissa mask (m_bits wide).
    pub(crate) mant_mask: u64,
    /// AFLP: exponent field width.
    pub(crate) e_bits: u32,
    /// AFLP: stored word width in bits (8·b).
    pub(crate) total_bits: u32,
    /// AFLP fast path: 52 − m_bits (mantissa up-shift into the f64 fraction).
    pub(crate) mshift: u32,
    /// AFLP: block scale (v_min).
    pub(crate) scale: f64,
}

const ZERO_RESOLVED: Resolved = Resolved {
    b: 0,
    shift: 0,
    word_mask: 0,
    zero_marker: 0,
    mant_mask: 0,
    e_bits: 0,
    total_bits: 0,
    mshift: 0,
    scale: 0.0,
};

/// One decoded-value transform: packed little-endian word → f64. The word may
/// carry a neighbour's bytes above the value width — every decoder masks or
/// shifts them away itself.
trait Decode: Copy {
    fn decode(r: &Resolved, w: u64) -> f64;
}

/// FPX over FP32: truncate to the low 4 loaded bytes, shift the stored bytes
/// to the top, bitcast, widen.
#[derive(Clone, Copy)]
struct DFpx32;

impl Decode for DFpx32 {
    #[inline(always)]
    fn decode(r: &Resolved, w: u64) -> f64 {
        f32::from_bits((w as u32) << r.shift) as f64
    }
}

/// FPX over FP64: shift the stored bytes to the top, bitcast.
#[derive(Clone, Copy)]
struct DFpx64;

impl Decode for DFpx64 {
    #[inline(always)]
    fn decode(r: &Resolved, w: u64) -> f64 {
        f64::from_bits(w << r.shift)
    }
}

/// AFLP fast path (e_bits < 11, m_bits ≤ 52): branchless direct IEEE-754 bit
/// assembly with an arithmetic zero-select, then one multiply for the scale.
#[derive(Clone, Copy)]
struct DAflp;

impl Decode for DAflp {
    #[inline(always)]
    fn decode(r: &Resolved, w: u64) -> f64 {
        let w = w & r.word_mask;
        let e = w & r.zero_marker;
        let mant = (w >> r.e_bits) & r.mant_mask;
        let sign = w >> (r.total_bits - 1);
        let keep = ((e != r.zero_marker) as u64).wrapping_neg();
        let bits = ((sign << 63) | ((1023 + e) << 52) | (mant << r.mshift)) & keep;
        f64::from_bits(bits) * r.scale
    }
}

/// AFLP generic path (extreme dynamic range or over-wide mantissa): stored
/// exponents may exceed 1023, so 2^e is folded into the scale in bounded
/// power-of-two steps.
#[derive(Clone, Copy)]
struct DAflpWide;

impl Decode for DAflpWide {
    #[inline(always)]
    fn decode(r: &Resolved, w: u64) -> f64 {
        let w = w & r.word_mask;
        let e = w & r.zero_marker;
        if e == r.zero_marker {
            return 0.0;
        }
        let m_bits = r.total_bits - 1 - r.e_bits;
        let mant = (w >> r.e_bits) & r.mant_mask;
        let sign = (w >> (r.total_bits - 1)) & 1;
        if e <= 1023 {
            let frac_bits = if m_bits <= 52 { mant << (52 - m_bits) } else { mant >> (m_bits - 52) };
            let bits = (sign << 63) | ((1023 + e) << 52) | frac_bits;
            f64::from_bits(bits) * r.scale
        } else {
            let frac = 1.0 + mant as f64 * 0.5f64.powi(m_bits as i32);
            let mut sc = r.scale;
            let mut rem = e;
            while rem > 0 {
                let step = rem.min(512);
                sc *= f64::powi(2.0, step as i32);
                rem -= step;
            }
            let v = frac * sc;
            if sign == 1 {
                -v
            } else {
                v
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Word loads
// ---------------------------------------------------------------------------

/// Unaligned 8-byte load (fast path); caller guarantees `off + 8` in bounds.
#[inline(always)]
fn load8(bytes: &[u8], off: usize) -> u64 {
    let arr: [u8; 8] = bytes[off..off + 8].try_into().unwrap();
    u64::from_le_bytes(arr)
}

/// Byte-assembled load for the last values of a buffer (const width).
#[inline(always)]
fn load_tail<const B: usize>(bytes: &[u8], off: usize) -> u64 {
    let mut buf = [0u8; 8];
    buf[..B].copy_from_slice(&bytes[off..off + B]);
    u64::from_le_bytes(buf)
}

/// Per-value load picking the fast or tail path (const width).
#[inline(always)]
fn load_at<const B: usize>(bytes: &[u8], i: usize) -> u64 {
    let off = i * B;
    if off + 8 <= bytes.len() {
        load8(bytes, off)
    } else {
        load_tail::<B>(bytes, off)
    }
}

/// Runtime-width variant of [`load_at`] (AVX2 kernel tails, random access).
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
#[inline(always)]
fn load_at_rt(bytes: &[u8], b: usize, i: usize) -> u64 {
    let off = i * b;
    if off + 8 <= bytes.len() {
        load8(bytes, off)
    } else {
        let mut buf = [0u8; 8];
        buf[..b].copy_from_slice(&bytes[off..off + b]);
        u64::from_le_bytes(buf)
    }
}

/// Number of values in `[begin, begin + n)` whose unaligned 8-byte load stays
/// inside the buffer.
#[inline(always)]
fn fast8(bytes_len: usize, b: usize, begin: usize, n: usize) -> usize {
    let fast_total = if bytes_len >= 8 { (bytes_len - 8) / b + 1 } else { 0 };
    fast_total.min(begin + n).saturating_sub(begin)
}

/// Right-hand sides processed per fused panel pass (bounds the accumulator
/// footprint; larger batches run in groups).
const PANEL_GROUP: usize = 8;

/// Largest batch width for which the fused panel kernels are a win: one
/// decode pass with per-RHS accumulators in registers. Beyond this the fused
/// kernels would re-decode the column once per [`PANEL_GROUP`]-sized group,
/// so the apply helpers in [`crate::mvm::kernels`] switch to the blockwise
/// layout instead (decode each chunk exactly once for all right-hand sides).
pub const PANEL_FUSE_MAX: usize = PANEL_GROUP;

// ---------------------------------------------------------------------------
// Scalar kernel engine (monomorphized per codec family × byte width)
// ---------------------------------------------------------------------------

fn s_range<D: Decode, const B: usize>(r: &Resolved, bytes: &[u8], begin: usize, end: usize, out: &mut [f64]) {
    let n = end - begin;
    debug_assert_eq!(out.len(), n);
    let fast = fast8(bytes.len(), B, begin, n);
    for (k, o) in out[..fast].iter_mut().enumerate() {
        *o = D::decode(r, load8(bytes, (begin + k) * B));
    }
    for (k, o) in out[fast..n].iter_mut().enumerate() {
        *o = D::decode(r, load_tail::<B>(bytes, (begin + fast + k) * B));
    }
}

fn s_get<D: Decode, const B: usize>(r: &Resolved, bytes: &[u8], i: usize) -> f64 {
    D::decode(r, load_at::<B>(bytes, i))
}

fn s_dot<D: Decode, const B: usize>(r: &Resolved, bytes: &[u8], begin: usize, x: &[f64]) -> f64 {
    let n = x.len();
    let fast = fast8(bytes.len(), B, begin, n);
    let mut s0 = 0.0f64;
    let mut s1 = 0.0f64;
    let mut s2 = 0.0f64;
    let mut s3 = 0.0f64;
    let mut i = 0usize;
    while i + 4 <= fast {
        let off = (begin + i) * B;
        s0 += D::decode(r, load8(bytes, off)) * x[i];
        s1 += D::decode(r, load8(bytes, off + B)) * x[i + 1];
        s2 += D::decode(r, load8(bytes, off + 2 * B)) * x[i + 2];
        s3 += D::decode(r, load8(bytes, off + 3 * B)) * x[i + 3];
        i += 4;
    }
    while i < n {
        s0 += D::decode(r, load_at::<B>(bytes, begin + i)) * x[i];
        i += 1;
    }
    (s0 + s1) + (s2 + s3)
}

fn s_axpy<D: Decode, const B: usize>(r: &Resolved, bytes: &[u8], begin: usize, w: f64, y: &mut [f64]) {
    let n = y.len();
    let fast = fast8(bytes.len(), B, begin, n);
    for (k, o) in y[..fast].iter_mut().enumerate() {
        *o += w * D::decode(r, load8(bytes, (begin + k) * B));
    }
    for (k, o) in y[fast..n].iter_mut().enumerate() {
        *o += w * D::decode(r, load_tail::<B>(bytes, (begin + fast + k) * B));
    }
}

#[allow(clippy::too_many_arguments)]
fn s_dot_panel<D: Decode, const B: usize>(
    r: &Resolved,
    bytes: &[u8],
    begin: usize,
    len: usize,
    alpha: f64,
    x: &[f64],
    xstride: usize,
    nrhs: usize,
    acc: &mut [f64],
    astride: usize,
) {
    let fast = fast8(bytes.len(), B, begin, len);
    let mut c0 = 0usize;
    while c0 < nrhs {
        let g = PANEL_GROUP.min(nrhs - c0);
        let mut s = [[0.0f64; 4]; PANEL_GROUP];
        let mut i = 0usize;
        while i + 4 <= fast {
            let off = (begin + i) * B;
            let v0 = D::decode(r, load8(bytes, off));
            let v1 = D::decode(r, load8(bytes, off + B));
            let v2 = D::decode(r, load8(bytes, off + 2 * B));
            let v3 = D::decode(r, load8(bytes, off + 3 * B));
            for (ci, sc) in s[..g].iter_mut().enumerate() {
                let xc = &x[(c0 + ci) * xstride..];
                sc[0] += v0 * xc[i];
                sc[1] += v1 * xc[i + 1];
                sc[2] += v2 * xc[i + 2];
                sc[3] += v3 * xc[i + 3];
            }
            i += 4;
        }
        while i < len {
            let v = D::decode(r, load_at::<B>(bytes, begin + i));
            for (ci, sc) in s[..g].iter_mut().enumerate() {
                sc[0] += v * x[(c0 + ci) * xstride + i];
            }
            i += 1;
        }
        for (ci, sc) in s[..g].iter().enumerate() {
            acc[(c0 + ci) * astride] += alpha * ((sc[0] + sc[1]) + (sc[2] + sc[3]));
        }
        c0 += g;
    }
}

#[allow(clippy::too_many_arguments)]
fn s_axpy_panel<D: Decode, const B: usize>(
    r: &Resolved,
    bytes: &[u8],
    begin: usize,
    len: usize,
    alpha: f64,
    wv: &[f64],
    wstride: usize,
    nrhs: usize,
    y: &mut [f64],
    ystride: usize,
) {
    let fast = fast8(bytes.len(), B, begin, len);
    let mut c0 = 0usize;
    while c0 < nrhs {
        let g = PANEL_GROUP.min(nrhs - c0);
        let mut w = [0.0f64; PANEL_GROUP];
        let mut any = false;
        for (ci, wc) in w[..g].iter_mut().enumerate() {
            *wc = alpha * wv[(c0 + ci) * wstride];
            any |= *wc != 0.0;
        }
        if !any {
            c0 += g;
            continue;
        }
        let mut i = 0usize;
        while i + 4 <= fast {
            let off = (begin + i) * B;
            let v0 = D::decode(r, load8(bytes, off));
            let v1 = D::decode(r, load8(bytes, off + B));
            let v2 = D::decode(r, load8(bytes, off + 2 * B));
            let v3 = D::decode(r, load8(bytes, off + 3 * B));
            for (ci, &wc) in w[..g].iter().enumerate() {
                if wc == 0.0 {
                    continue;
                }
                let yc = &mut y[(c0 + ci) * ystride + i..];
                yc[0] += wc * v0;
                yc[1] += wc * v1;
                yc[2] += wc * v2;
                yc[3] += wc * v3;
            }
            i += 4;
        }
        while i < len {
            let v = D::decode(r, load_at::<B>(bytes, begin + i));
            for (ci, &wc) in w[..g].iter().enumerate() {
                if wc != 0.0 {
                    y[(c0 + ci) * ystride + i] += wc * v;
                }
            }
            i += 1;
        }
        c0 += g;
    }
}

// ---------------------------------------------------------------------------
// Hot-panel kernels (cached decoded values)
// ---------------------------------------------------------------------------
//
// When the storage tier's hot cache holds a blob's fully decoded panel, the
// cursor serves from these instead of decoding. They MUST reproduce the
// fused kernels' floating-point operation order bitwise: the scalar and
// AVX2 kernels both accumulate stride-4 lanes over the [`fast8`] window,
// run the tail serially into lane 0, and reduce as `(s0+s1)+(s2+s3)` — so
// one hot kernel parameterized by the original blob's `fast8` boundary is
// bit-identical to either ISA level. (axpy/range are elementwise, where
// order per output element is trivially preserved.) Pinned by the
// `hot_cache_*_bitwise` tests below and `tests/store_roundtrip.rs`.

fn hot_dot(vals: &[f64], fast: usize, begin: usize, x: &[f64]) -> f64 {
    let n = x.len();
    let mut s0 = 0.0f64;
    let mut s1 = 0.0f64;
    let mut s2 = 0.0f64;
    let mut s3 = 0.0f64;
    let mut i = 0usize;
    while i + 4 <= fast {
        s0 += vals[begin + i] * x[i];
        s1 += vals[begin + i + 1] * x[i + 1];
        s2 += vals[begin + i + 2] * x[i + 2];
        s3 += vals[begin + i + 3] * x[i + 3];
        i += 4;
    }
    while i < n {
        s0 += vals[begin + i] * x[i];
        i += 1;
    }
    (s0 + s1) + (s2 + s3)
}

fn hot_axpy(vals: &[f64], begin: usize, w: f64, y: &mut [f64]) {
    for (k, o) in y.iter_mut().enumerate() {
        *o += w * vals[begin + k];
    }
}

#[allow(clippy::too_many_arguments)]
fn hot_dot_panel(vals: &[f64], fast: usize, begin: usize, len: usize, alpha: f64, x: &[f64], xstride: usize, nrhs: usize, acc: &mut [f64], astride: usize) {
    let mut c0 = 0usize;
    while c0 < nrhs {
        let g = PANEL_GROUP.min(nrhs - c0);
        let mut s = [[0.0f64; 4]; PANEL_GROUP];
        let mut i = 0usize;
        while i + 4 <= fast {
            let v0 = vals[begin + i];
            let v1 = vals[begin + i + 1];
            let v2 = vals[begin + i + 2];
            let v3 = vals[begin + i + 3];
            for (ci, sc) in s[..g].iter_mut().enumerate() {
                let xc = &x[(c0 + ci) * xstride..];
                sc[0] += v0 * xc[i];
                sc[1] += v1 * xc[i + 1];
                sc[2] += v2 * xc[i + 2];
                sc[3] += v3 * xc[i + 3];
            }
            i += 4;
        }
        while i < len {
            let v = vals[begin + i];
            for (ci, sc) in s[..g].iter_mut().enumerate() {
                sc[0] += v * x[(c0 + ci) * xstride + i];
            }
            i += 1;
        }
        for (ci, sc) in s[..g].iter().enumerate() {
            acc[(c0 + ci) * astride] += alpha * ((sc[0] + sc[1]) + (sc[2] + sc[3]));
        }
        c0 += g;
    }
}

#[allow(clippy::too_many_arguments)]
fn hot_axpy_panel(vals: &[f64], begin: usize, len: usize, alpha: f64, wv: &[f64], wstride: usize, nrhs: usize, y: &mut [f64], ystride: usize) {
    let mut c0 = 0usize;
    while c0 < nrhs {
        let g = PANEL_GROUP.min(nrhs - c0);
        let mut w = [0.0f64; PANEL_GROUP];
        let mut any = false;
        for (ci, wc) in w[..g].iter_mut().enumerate() {
            *wc = alpha * wv[(c0 + ci) * wstride];
            any |= *wc != 0.0;
        }
        if !any {
            c0 += g;
            continue;
        }
        for i in 0..len {
            let v = vals[begin + i];
            for (ci, &wc) in w[..g].iter().enumerate() {
                if wc != 0.0 {
                    y[(c0 + ci) * ystride + i] += wc * v;
                }
            }
        }
        c0 += g;
    }
}

// ---------------------------------------------------------------------------
// Zero-codec kernels
// ---------------------------------------------------------------------------

fn z_range(_r: &Resolved, _bytes: &[u8], _begin: usize, _end: usize, out: &mut [f64]) {
    out.fill(0.0);
}

fn z_get(_r: &Resolved, _bytes: &[u8], _i: usize) -> f64 {
    0.0
}

fn z_dot(_r: &Resolved, _bytes: &[u8], _begin: usize, _x: &[f64]) -> f64 {
    0.0
}

fn z_axpy(_r: &Resolved, _bytes: &[u8], _begin: usize, _w: f64, _y: &mut [f64]) {}

#[allow(clippy::too_many_arguments)]
fn z_dot_panel(
    _r: &Resolved,
    _bytes: &[u8],
    _begin: usize,
    _len: usize,
    _alpha: f64,
    _x: &[f64],
    _xstride: usize,
    _nrhs: usize,
    _acc: &mut [f64],
    _astride: usize,
) {
}

#[allow(clippy::too_many_arguments)]
fn z_axpy_panel(
    _r: &Resolved,
    _bytes: &[u8],
    _begin: usize,
    _len: usize,
    _alpha: f64,
    _wv: &[f64],
    _wstride: usize,
    _nrhs: usize,
    _y: &mut [f64],
    _ystride: usize,
) {
}

// ---------------------------------------------------------------------------
// AVX2 kernel engine (x86-64, installed only after runtime detection)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{fast8, load_at_rt, Decode, Resolved, DAflp, DFpx32, DFpx64, PANEL_GROUP};
    use std::arch::x86_64::*;

    /// Decode values `idx..idx+4` of an FPX32 blob: 4-byte gathers, vector
    /// shift, cvt ps→pd. Caller guarantees 4-byte loads stay in bounds.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn dec4_fpx32(r: &Resolved, bytes: &[u8], idx: usize) -> __m256d {
        let b = r.b;
        let off0 = (idx * b) as i32;
        let off = _mm_setr_epi32(off0, off0 + b as i32, off0 + 2 * b as i32, off0 + 3 * b as i32);
        let w = _mm_i32gather_epi32::<1>(bytes.as_ptr() as *const i32, off);
        let hi = _mm_sll_epi32(w, _mm_cvtsi32_si128(r.shift as i32));
        _mm256_cvtps_pd(_mm_castsi128_ps(hi))
    }

    /// Decode values `idx..idx+4` of an FPX64 blob: 8-byte gathers + vector
    /// shift. Caller guarantees 8-byte loads stay in bounds.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn dec4_fpx64(r: &Resolved, bytes: &[u8], idx: usize) -> __m256d {
        let b = r.b as i64;
        let off0 = idx as i64 * b;
        let off = _mm256_setr_epi64x(off0, off0 + b, off0 + 2 * b, off0 + 3 * b);
        let w = _mm256_i64gather_epi64::<1>(bytes.as_ptr() as *const i64, off);
        _mm256_castsi256_pd(_mm256_sll_epi64(w, _mm_cvtsi32_si128(r.shift as i32)))
    }

    /// Decode values `idx..idx+4` of an AFLP fast-path blob: gather, vector
    /// mask/shift bit assembly, one mul_pd for the block scale. Caller
    /// guarantees 8-byte loads stay in bounds.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn dec4_aflp(r: &Resolved, bytes: &[u8], idx: usize) -> __m256d {
        let b = r.b as i64;
        let off0 = idx as i64 * b;
        let off = _mm256_setr_epi64x(off0, off0 + b, off0 + 2 * b, off0 + 3 * b);
        let w = _mm256_and_si256(
            _mm256_i64gather_epi64::<1>(bytes.as_ptr() as *const i64, off),
            _mm256_set1_epi64x(r.word_mask as i64),
        );
        let emask = _mm256_set1_epi64x(r.zero_marker as i64);
        let e = _mm256_and_si256(w, emask);
        let is_zero = _mm256_cmpeq_epi64(e, emask);
        let mant = _mm256_and_si256(_mm256_srl_epi64(w, _mm_cvtsi32_si128(r.e_bits as i32)), _mm256_set1_epi64x(r.mant_mask as i64));
        let sign = _mm256_sll_epi64(_mm256_srl_epi64(w, _mm_cvtsi32_si128(r.total_bits as i32 - 1)), _mm_cvtsi32_si128(63));
        let expf = _mm256_sll_epi64(_mm256_add_epi64(e, _mm256_set1_epi64x(1023)), _mm_cvtsi32_si128(52));
        let frac = _mm256_sll_epi64(mant, _mm_cvtsi32_si128(r.mshift as i32));
        let bits = _mm256_andnot_si256(is_zero, _mm256_or_si256(sign, _mm256_or_si256(expf, frac)));
        _mm256_mul_pd(_mm256_castsi256_pd(bits), _mm256_set1_pd(r.scale))
    }

    /// Extract the four lane sums of a vector accumulator (lane k holds the
    /// stride-4 partial sum s_k).
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn lanes(acc: __m256d) -> [f64; 4] {
        let lo = _mm256_castpd256_pd128(acc);
        let hi = _mm256_extractf128_pd::<1>(acc);
        [
            _mm_cvtsd_f64(lo),
            _mm_cvtsd_f64(_mm_unpackhi_pd(lo, lo)),
            _mm_cvtsd_f64(hi),
            _mm_cvtsd_f64(_mm_unpackhi_pd(hi, hi)),
        ]
    }

    macro_rules! avx2_family {
        ($range:ident, $dot:ident, $axpy:ident, $dotp:ident, $axpyp:ident, $dec:ty, $dec4:ident, $vec_bound:ident) => {
            #[target_feature(enable = "avx2")]
            pub(super) unsafe fn $range(r: &Resolved, bytes: &[u8], begin: usize, end: usize, out: &mut [f64]) {
                let n = end - begin;
                debug_assert_eq!(out.len(), n);
                let vb = $vec_bound(bytes.len(), r.b, begin, n);
                let mut i = 0usize;
                while i + 4 <= vb {
                    let v = $dec4(r, bytes, begin + i);
                    _mm256_storeu_pd(out.as_mut_ptr().add(i), v);
                    i += 4;
                }
                for (k, o) in out[i..n].iter_mut().enumerate() {
                    *o = <$dec>::decode(r, load_at_rt(bytes, r.b, begin + i + k));
                }
            }

            #[target_feature(enable = "avx2")]
            pub(super) unsafe fn $dot(r: &Resolved, bytes: &[u8], begin: usize, x: &[f64]) -> f64 {
                let n = x.len();
                let fast = fast8(bytes.len(), r.b, begin, n);
                let mut accv = _mm256_setzero_pd();
                let mut i = 0usize;
                while i + 4 <= fast {
                    let v = $dec4(r, bytes, begin + i);
                    let xv = _mm256_loadu_pd(x.as_ptr().add(i));
                    accv = _mm256_add_pd(accv, _mm256_mul_pd(v, xv));
                    i += 4;
                }
                let l = lanes(accv);
                let mut s0 = l[0];
                while i < n {
                    s0 += <$dec>::decode(r, load_at_rt(bytes, r.b, begin + i)) * x[i];
                    i += 1;
                }
                (s0 + l[1]) + (l[2] + l[3])
            }

            #[target_feature(enable = "avx2")]
            pub(super) unsafe fn $axpy(r: &Resolved, bytes: &[u8], begin: usize, w: f64, y: &mut [f64]) {
                let n = y.len();
                let fast = fast8(bytes.len(), r.b, begin, n);
                let wv = _mm256_set1_pd(w);
                let mut i = 0usize;
                while i + 4 <= fast {
                    let v = $dec4(r, bytes, begin + i);
                    let yp = y.as_mut_ptr().add(i);
                    let yv = _mm256_loadu_pd(yp);
                    _mm256_storeu_pd(yp, _mm256_add_pd(yv, _mm256_mul_pd(wv, v)));
                    i += 4;
                }
                while i < n {
                    y[i] += w * <$dec>::decode(r, load_at_rt(bytes, r.b, begin + i));
                    i += 1;
                }
            }

            #[allow(clippy::too_many_arguments)]
            #[target_feature(enable = "avx2")]
            pub(super) unsafe fn $dotp(
                r: &Resolved,
                bytes: &[u8],
                begin: usize,
                len: usize,
                alpha: f64,
                x: &[f64],
                xstride: usize,
                nrhs: usize,
                acc: &mut [f64],
                astride: usize,
            ) {
                let fast = fast8(bytes.len(), r.b, begin, len);
                let mut c0 = 0usize;
                while c0 < nrhs {
                    let g = PANEL_GROUP.min(nrhs - c0);
                    let mut sv = [_mm256_setzero_pd(); PANEL_GROUP];
                    let mut i = 0usize;
                    while i + 4 <= fast {
                        let v = $dec4(r, bytes, begin + i);
                        for (ci, s) in sv[..g].iter_mut().enumerate() {
                            let xv = _mm256_loadu_pd(x.as_ptr().add((c0 + ci) * xstride + i));
                            *s = _mm256_add_pd(*s, _mm256_mul_pd(v, xv));
                        }
                        i += 4;
                    }
                    let mut s = [[0.0f64; 4]; PANEL_GROUP];
                    for (ci, v) in sv[..g].iter().enumerate() {
                        s[ci] = lanes(*v);
                    }
                    while i < len {
                        let v = <$dec>::decode(r, load_at_rt(bytes, r.b, begin + i));
                        for (ci, sc) in s[..g].iter_mut().enumerate() {
                            sc[0] += v * x[(c0 + ci) * xstride + i];
                        }
                        i += 1;
                    }
                    for (ci, sc) in s[..g].iter().enumerate() {
                        acc[(c0 + ci) * astride] += alpha * ((sc[0] + sc[1]) + (sc[2] + sc[3]));
                    }
                    c0 += g;
                }
            }

            #[allow(clippy::too_many_arguments)]
            #[target_feature(enable = "avx2")]
            pub(super) unsafe fn $axpyp(
                r: &Resolved,
                bytes: &[u8],
                begin: usize,
                len: usize,
                alpha: f64,
                wvals: &[f64],
                wstride: usize,
                nrhs: usize,
                y: &mut [f64],
                ystride: usize,
            ) {
                let fast = fast8(bytes.len(), r.b, begin, len);
                let mut c0 = 0usize;
                while c0 < nrhs {
                    let g = PANEL_GROUP.min(nrhs - c0);
                    let mut w = [0.0f64; PANEL_GROUP];
                    let mut any = false;
                    for (ci, wc) in w[..g].iter_mut().enumerate() {
                        *wc = alpha * wvals[(c0 + ci) * wstride];
                        any |= *wc != 0.0;
                    }
                    if !any {
                        c0 += g;
                        continue;
                    }
                    let mut i = 0usize;
                    while i + 4 <= fast {
                        let v = $dec4(r, bytes, begin + i);
                        for (ci, &wc) in w[..g].iter().enumerate() {
                            if wc == 0.0 {
                                continue;
                            }
                            let yp = y.as_mut_ptr().add((c0 + ci) * ystride + i);
                            let yv = _mm256_loadu_pd(yp);
                            _mm256_storeu_pd(yp, _mm256_add_pd(yv, _mm256_mul_pd(_mm256_set1_pd(wc), v)));
                        }
                        i += 4;
                    }
                    while i < len {
                        let v = <$dec>::decode(r, load_at_rt(bytes, r.b, begin + i));
                        for (ci, &wc) in w[..g].iter().enumerate() {
                            if wc != 0.0 {
                                y[(c0 + ci) * ystride + i] += wc * v;
                            }
                        }
                        i += 1;
                    }
                    c0 += g;
                }
            }
        };
    }

    /// Vectorization bound for FPX32 range decode: the 32-bit gather reads
    /// only 4 bytes per lane, so it may run further than the 8-byte window.
    fn fast4(bytes_len: usize, b: usize, begin: usize, n: usize) -> usize {
        let fast_total = if bytes_len >= 4 { (bytes_len - 4) / b + 1 } else { 0 };
        fast_total.min(begin + n).saturating_sub(begin)
    }

    avx2_family!(fpx32_range, fpx32_dot, fpx32_axpy, fpx32_dot_panel, fpx32_axpy_panel, DFpx32, dec4_fpx32, fast4);
    avx2_family!(fpx64_range, fpx64_dot, fpx64_axpy, fpx64_dot_panel, fpx64_axpy_panel, DFpx64, dec4_fpx64, fast8);
    avx2_family!(aflp_range, aflp_dot, aflp_axpy, aflp_dot_panel, aflp_axpy_panel, DAflp, dec4_aflp, fast8);
}

// Safe wrappers installing the AVX2 kernels into the dispatch tables. The
// wrappers are reachable only through tables selected after a successful
// runtime `is_x86_feature_detected!("avx2")`, which is the safety argument.
#[cfg(target_arch = "x86_64")]
macro_rules! avx2_wrap {
    ($range:ident, $dot:ident, $axpy:ident, $dotp:ident, $axpyp:ident) => {
        mod $range {
            use super::Resolved;

            pub(super) fn range(r: &Resolved, bytes: &[u8], begin: usize, end: usize, out: &mut [f64]) {
                unsafe { super::avx2::$range(r, bytes, begin, end, out) }
            }

            pub(super) fn dot(r: &Resolved, bytes: &[u8], begin: usize, x: &[f64]) -> f64 {
                unsafe { super::avx2::$dot(r, bytes, begin, x) }
            }

            pub(super) fn axpy(r: &Resolved, bytes: &[u8], begin: usize, w: f64, y: &mut [f64]) {
                unsafe { super::avx2::$axpy(r, bytes, begin, w, y) }
            }

            #[allow(clippy::too_many_arguments)]
            pub(super) fn dot_panel(
                r: &Resolved,
                bytes: &[u8],
                begin: usize,
                len: usize,
                alpha: f64,
                x: &[f64],
                xstride: usize,
                nrhs: usize,
                acc: &mut [f64],
                astride: usize,
            ) {
                unsafe { super::avx2::$dotp(r, bytes, begin, len, alpha, x, xstride, nrhs, acc, astride) }
            }

            #[allow(clippy::too_many_arguments)]
            pub(super) fn axpy_panel(
                r: &Resolved,
                bytes: &[u8],
                begin: usize,
                len: usize,
                alpha: f64,
                wvals: &[f64],
                wstride: usize,
                nrhs: usize,
                y: &mut [f64],
                ystride: usize,
            ) {
                unsafe { super::avx2::$axpyp(r, bytes, begin, len, alpha, wvals, wstride, nrhs, y, ystride) }
            }
        }
    };
}

#[cfg(target_arch = "x86_64")]
avx2_wrap!(fpx32_range, fpx32_dot, fpx32_axpy, fpx32_dot_panel, fpx32_axpy_panel);
#[cfg(target_arch = "x86_64")]
avx2_wrap!(fpx64_range, fpx64_dot, fpx64_axpy, fpx64_dot_panel, fpx64_axpy_panel);
#[cfg(target_arch = "x86_64")]
avx2_wrap!(aflp_range, aflp_dot, aflp_axpy, aflp_dot_panel, aflp_axpy_panel);

// ---------------------------------------------------------------------------
// Dispatch tables
// ---------------------------------------------------------------------------

type RangeFn = fn(&Resolved, &[u8], usize, usize, &mut [f64]);
type GetFn = fn(&Resolved, &[u8], usize) -> f64;
type DotFn = fn(&Resolved, &[u8], usize, &[f64]) -> f64;
type AxpyFn = fn(&Resolved, &[u8], usize, f64, &mut [f64]);
type DotPanelFn = fn(&Resolved, &[u8], usize, usize, f64, &[f64], usize, usize, &mut [f64], usize);
type AxpyPanelFn = fn(&Resolved, &[u8], usize, usize, f64, &[f64], usize, usize, &mut [f64], usize);

/// One resolved kernel set: every decode/fused op for one
/// `(codec family, byte width, ISA level)` combination.
pub struct KernelTable {
    pub(crate) range: RangeFn,
    pub(crate) get: GetFn,
    pub(crate) dot: DotFn,
    pub(crate) axpy: AxpyFn,
    pub(crate) dot_panel: DotPanelFn,
    pub(crate) axpy_panel: AxpyPanelFn,
    /// Human-readable kernel id, e.g. `"fpx64/5+avx2"`.
    pub(crate) name: &'static str,
}

macro_rules! scalar_table {
    ($dec:ty, $b:literal, $name:literal) => {
        KernelTable {
            range: s_range::<$dec, $b>,
            get: s_get::<$dec, $b>,
            dot: s_dot::<$dec, $b>,
            axpy: s_axpy::<$dec, $b>,
            dot_panel: s_dot_panel::<$dec, $b>,
            axpy_panel: s_axpy_panel::<$dec, $b>,
            name: $name,
        }
    };
}

static FPX32_S: [KernelTable; 4] = [
    scalar_table!(DFpx32, 1, "fpx32/1+scalar"),
    scalar_table!(DFpx32, 2, "fpx32/2+scalar"),
    scalar_table!(DFpx32, 3, "fpx32/3+scalar"),
    scalar_table!(DFpx32, 4, "fpx32/4+scalar"),
];

static FPX64_S: [KernelTable; 8] = [
    scalar_table!(DFpx64, 1, "fpx64/1+scalar"),
    scalar_table!(DFpx64, 2, "fpx64/2+scalar"),
    scalar_table!(DFpx64, 3, "fpx64/3+scalar"),
    scalar_table!(DFpx64, 4, "fpx64/4+scalar"),
    scalar_table!(DFpx64, 5, "fpx64/5+scalar"),
    scalar_table!(DFpx64, 6, "fpx64/6+scalar"),
    scalar_table!(DFpx64, 7, "fpx64/7+scalar"),
    scalar_table!(DFpx64, 8, "fpx64/8+scalar"),
];

static AFLP_S: [KernelTable; 8] = [
    scalar_table!(DAflp, 1, "aflp/1+scalar"),
    scalar_table!(DAflp, 2, "aflp/2+scalar"),
    scalar_table!(DAflp, 3, "aflp/3+scalar"),
    scalar_table!(DAflp, 4, "aflp/4+scalar"),
    scalar_table!(DAflp, 5, "aflp/5+scalar"),
    scalar_table!(DAflp, 6, "aflp/6+scalar"),
    scalar_table!(DAflp, 7, "aflp/7+scalar"),
    scalar_table!(DAflp, 8, "aflp/8+scalar"),
];

static AFLP_WIDE_S: [KernelTable; 8] = [
    scalar_table!(DAflpWide, 1, "aflp-wide/1+scalar"),
    scalar_table!(DAflpWide, 2, "aflp-wide/2+scalar"),
    scalar_table!(DAflpWide, 3, "aflp-wide/3+scalar"),
    scalar_table!(DAflpWide, 4, "aflp-wide/4+scalar"),
    scalar_table!(DAflpWide, 5, "aflp-wide/5+scalar"),
    scalar_table!(DAflpWide, 6, "aflp-wide/6+scalar"),
    scalar_table!(DAflpWide, 7, "aflp-wide/7+scalar"),
    scalar_table!(DAflpWide, 8, "aflp-wide/8+scalar"),
];

static ZERO_T: KernelTable = KernelTable {
    range: z_range,
    get: z_get,
    dot: z_dot,
    axpy: z_axpy,
    dot_panel: z_dot_panel,
    axpy_panel: z_axpy_panel,
    name: "zero",
};

// The AVX2 kernels take the byte width at runtime (gathers are offset-driven
// either way), so one table per codec family suffices; random access stays on
// the scalar path (no gather win for single values), which keeps `get`
// bitwise identical across ISA levels by construction.
#[cfg(target_arch = "x86_64")]
static FPX32_V: KernelTable = KernelTable {
    range: fpx32_range::range,
    get: s_get_rt::<DFpx32>,
    dot: fpx32_range::dot,
    axpy: fpx32_range::axpy,
    dot_panel: fpx32_range::dot_panel,
    axpy_panel: fpx32_range::axpy_panel,
    name: "fpx32+avx2",
};

#[cfg(target_arch = "x86_64")]
static FPX64_V: KernelTable = KernelTable {
    range: fpx64_range::range,
    get: s_get_rt::<DFpx64>,
    dot: fpx64_range::dot,
    axpy: fpx64_range::axpy,
    dot_panel: fpx64_range::dot_panel,
    axpy_panel: fpx64_range::axpy_panel,
    name: "fpx64+avx2",
};

#[cfg(target_arch = "x86_64")]
static AFLP_V: KernelTable = KernelTable {
    range: aflp_range::range,
    get: s_get_rt::<DAflp>,
    dot: aflp_range::dot,
    axpy: aflp_range::axpy,
    dot_panel: aflp_range::dot_panel,
    axpy_panel: aflp_range::axpy_panel,
    name: "aflp+avx2",
};

/// Runtime-width random access (AVX2 tables).
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
fn s_get_rt<D: Decode>(r: &Resolved, bytes: &[u8], i: usize) -> f64 {
    D::decode(r, load_at_rt(bytes, r.b, i))
}

#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
#[inline]
fn simd_active() -> bool {
    simd_level() == SimdLevel::Avx2
}

/// Resolve a blob's codec parameters into the flat [`Resolved`] form plus the
/// kernel table for the current ISA level. This is the *only* place codec
/// parameters are matched — everything downstream works off the result.
pub fn resolve(params: &CodecParams) -> (Resolved, &'static KernelTable) {
    match *params {
        CodecParams::Zero => (ZERO_RESOLVED, &ZERO_T),
        CodecParams::Fpx32 { bytes_per } => {
            let b = (bytes_per as usize).clamp(1, 4);
            let r = Resolved { b, shift: 32 - 8 * b as u32, ..ZERO_RESOLVED };
            #[cfg(target_arch = "x86_64")]
            if simd_active() {
                return (r, &FPX32_V);
            }
            (r, &FPX32_S[b - 1])
        }
        CodecParams::Fpx64 { bytes_per } => {
            let b = (bytes_per as usize).clamp(1, 8);
            let r = Resolved { b, shift: 64 - 8 * b as u32, ..ZERO_RESOLVED };
            #[cfg(target_arch = "x86_64")]
            if simd_active() {
                return (r, &FPX64_V);
            }
            (r, &FPX64_S[b - 1])
        }
        CodecParams::Aflp { bytes_per, e_bits, scale } => {
            let b = (bytes_per as usize).clamp(1, 8);
            let e_bits = e_bits as u32;
            let total_bits = 8 * b as u32;
            let m_bits = total_bits - 1 - e_bits;
            let word_mask: u64 = if b >= 8 { u64::MAX } else { (1u64 << (8 * b)) - 1 };
            let zero_marker: u64 = (1u64 << e_bits) - 1;
            let mant_mask: u64 = (1u64 << m_bits) - 1;
            if e_bits >= 11 || m_bits > 52 {
                let r = Resolved { b, shift: 0, word_mask, zero_marker, mant_mask, e_bits, total_bits, mshift: 0, scale };
                return (r, &AFLP_WIDE_S[b - 1]);
            }
            let r = Resolved { b, shift: 0, word_mask, zero_marker, mant_mask, e_bits, total_bits, mshift: 52 - m_bits, scale };
            #[cfg(target_arch = "x86_64")]
            if simd_active() {
                return (r, &AFLP_V);
            }
            (r, &AFLP_S[b - 1])
        }
    }
}

/// Decode the half-open value range `[begin, end)` of a packed buffer.
pub(crate) fn range(params: &CodecParams, bytes: &[u8], begin: usize, end: usize, out: &mut [f64]) {
    let (r, t) = resolve(params);
    (t.range)(&r, bytes, begin, end, out);
}

/// Random access through a one-shot resolution (callers touching many values
/// should hold a [`DecodeCursor`] instead).
pub(crate) fn get(params: &CodecParams, bytes: &[u8], i: usize) -> f64 {
    let (r, t) = resolve(params);
    (t.get)(&r, bytes, i)
}

// ---------------------------------------------------------------------------
// DecodeCursor
// ---------------------------------------------------------------------------

/// A streaming decoder over one blob: codec parameters, shift counts and the
/// kernel table are resolved **once** at construction; every subsequent chunk
/// (or fused dot/axpy) just advances a position. This replaces the
/// per-chunk `decompress_range` re-setup in all streamed apply paths.
pub struct DecodeCursor<'a> {
    bytes: &'a [u8],
    n: usize,
    pos: usize,
    r: Resolved,
    t: &'static KernelTable,
    /// Fully decoded panel from the storage tier's hot cache (when a cache
    /// scope is installed and kept this blob). Serving from it reproduces
    /// the fused kernels' operation order bitwise — see the hot kernels.
    hot: Option<std::sync::Arc<Vec<f64>>>,
}

impl<'a> DecodeCursor<'a> {
    /// Resolve `blob` for streaming from position 0. Consults the storage
    /// tier's hot cache for the calling task's scope; on a hit every decode
    /// below is replaced by cached reads (bitwise-identical results).
    pub fn new(blob: &'a Blob) -> DecodeCursor<'a> {
        let (r, t) = resolve(&blob.params);
        let hot = crate::store::hot::cached_decode(blob);
        DecodeCursor { bytes: &blob.bytes, n: blob.n, pos: 0, r, t, hot }
    }

    /// Total number of values in the underlying blob.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Current position (next value index to be decoded).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Values left between the position and the end of the blob.
    pub fn remaining(&self) -> usize {
        self.n - self.pos
    }

    /// Resolved kernel id (diagnostics), e.g. `"fpx64/5+scalar"`.
    pub fn kernel_name(&self) -> &'static str {
        self.t.name
    }

    /// Move the position (column starts in column-major blobs).
    pub fn seek(&mut self, pos: usize) {
        debug_assert!(pos <= self.n);
        self.pos = pos;
    }

    /// Random access to value `i` with the cursor's resolved parameters
    /// (does not move the position).
    #[inline]
    pub fn get(&self, i: usize) -> f64 {
        debug_assert!(i < self.n);
        if let Some(h) = &self.hot {
            return h[i];
        }
        (self.t.get)(&self.r, self.bytes, i)
    }

    /// Decode the next `out.len()` values into `out` and advance.
    pub fn next_chunk(&mut self, out: &mut [f64]) {
        let end = self.pos + out.len();
        debug_assert!(end <= self.n);
        if let Some(h) = &self.hot {
            out.copy_from_slice(&h[self.pos..end]);
        } else {
            (self.t.range)(&self.r, self.bytes, self.pos, end, out);
        }
        self.pos = end;
    }

    /// Fused decode–dot: returns `Σ_i v[pos+i]·x[i]` and advances by
    /// `x.len()`; decoded lanes never leave registers.
    #[inline]
    pub fn dot(&mut self, x: &[f64]) -> f64 {
        debug_assert!(self.pos + x.len() <= self.n);
        let s = if let Some(h) = &self.hot {
            let fast = fast8(self.bytes.len(), self.r.b, self.pos, x.len());
            hot_dot(h, fast, self.pos, x)
        } else {
            (self.t.dot)(&self.r, self.bytes, self.pos, x)
        };
        self.pos += x.len();
        s
    }

    /// Fused decode–axpy: `y[i] += w · v[pos+i]`, advancing by `y.len()`.
    #[inline]
    pub fn axpy(&mut self, w: f64, y: &mut [f64]) {
        debug_assert!(self.pos + y.len() <= self.n);
        if let Some(h) = &self.hot {
            hot_axpy(h, self.pos, w, y);
        } else {
            (self.t.axpy)(&self.r, self.bytes, self.pos, w, y);
        }
        self.pos += y.len();
    }

    /// Fused panel dot for gemm-shaped multi-RHS tasks:
    /// `acc[c·astride] += alpha · Σ_i v[pos+i]·x[c·xstride+i]` for
    /// `c < nrhs`, one decode pass for all right-hand sides; advances by
    /// `len`.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub fn dot_panel(&mut self, len: usize, alpha: f64, x: &[f64], xstride: usize, nrhs: usize, acc: &mut [f64], astride: usize) {
        debug_assert!(self.pos + len <= self.n);
        if let Some(h) = &self.hot {
            let fast = fast8(self.bytes.len(), self.r.b, self.pos, len);
            hot_dot_panel(h, fast, self.pos, len, alpha, x, xstride, nrhs, acc, astride);
        } else {
            (self.t.dot_panel)(&self.r, self.bytes, self.pos, len, alpha, x, xstride, nrhs, acc, astride);
        }
        self.pos += len;
    }

    /// Fused panel axpy: `y[c·ystride+i] += alpha·wvals[c·wstride] · v[pos+i]`
    /// for `c < nrhs` (zero weights skipped), one decode pass; advances by
    /// `len`.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub fn axpy_panel(&mut self, len: usize, alpha: f64, wvals: &[f64], wstride: usize, nrhs: usize, y: &mut [f64], ystride: usize) {
        debug_assert!(self.pos + len <= self.n);
        if let Some(h) = &self.hot {
            hot_axpy_panel(h, self.pos, len, alpha, wvals, wstride, nrhs, y, ystride);
        } else {
            (self.t.axpy_panel)(&self.r, self.bytes, self.pos, len, alpha, wvals, wstride, nrhs, y, ystride);
        }
        self.pos += len;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Blob, Codec};
    use crate::la::blas;
    use crate::util::Rng;

    fn sample(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|i| if i % 9 == 7 { 0.0 } else { rng.normal() * 10f64.powf(rng.range(-2.0, 2.0)) }).collect()
    }

    #[test]
    fn cursor_chunks_match_decompress_range_bitwise() {
        for codec in [Codec::Aflp, Codec::Fpx] {
            for &eps in &[1e-2, 1e-6, 1e-10, 1e-14] {
                let data = sample(301, 42);
                let blob = Blob::compress(codec, &data, eps);
                let mut whole = vec![0.0; blob.n];
                blob.decompress_into(&mut whole);
                let mut cur = DecodeCursor::new(&blob);
                let mut out = vec![0.0; blob.n];
                let mut pos = 0usize;
                for step in [1usize, 3, 64, 100, 7, 126] {
                    if pos >= blob.n {
                        break;
                    }
                    let len = step.min(blob.n - pos);
                    cur.next_chunk(&mut out[pos..pos + len]);
                    pos += len;
                }
                while pos < blob.n {
                    let len = 5.min(blob.n - pos);
                    cur.next_chunk(&mut out[pos..pos + len]);
                    pos += len;
                }
                for (a, b) in out.iter().zip(&whole) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{codec:?} eps={eps}");
                }
            }
        }
    }

    #[test]
    fn fused_axpy_matches_decode_then_blas_bitwise() {
        let mut rng = Rng::new(43);
        for codec in [Codec::Aflp, Codec::Fpx] {
            for &eps in &[1e-3, 1e-8, 1e-12] {
                let data = sample(157, 44);
                let blob = Blob::compress(codec, &data, eps);
                let dec = blob.to_vec();
                let mut y1: Vec<f64> = (0..157).map(|_| rng.normal()).collect();
                let mut y2 = y1.clone();
                let w = 1.7;
                blas::axpy(w, &dec, &mut y1);
                let mut cur = DecodeCursor::new(&blob);
                cur.axpy(w, &mut y2);
                for (a, b) in y1.iter().zip(&y2) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{codec:?} eps={eps}");
                }
            }
        }
    }

    #[test]
    fn fused_dot_close_to_decode_then_blas() {
        let mut rng = Rng::new(45);
        for codec in [Codec::Aflp, Codec::Fpx] {
            let data = sample(203, 46);
            let blob = Blob::compress(codec, &data, 1e-9);
            let dec = blob.to_vec();
            let x: Vec<f64> = (0..203).map(|_| rng.normal()).collect();
            let want = blas::dot(&dec, &x);
            let mut cur = DecodeCursor::new(&blob);
            let got = cur.dot(&x);
            assert!((got - want).abs() <= 1e-12 * (1.0 + want.abs()), "{codec:?}: {got} vs {want}");
        }
    }

    // NOTE: scalar-vs-AVX2 bitwise identity (the ISA half of the determinism
    // contract) is asserted in `tests/codec_simd_dispatch.rs`, which runs as
    // its own binary so the process-global ISA override cannot race other
    // tests.

    #[test]
    fn panel_ops_match_single_bitwise() {
        let mut rng = Rng::new(49);
        let n = 97;
        let nrhs = 5;
        for codec in [Codec::Aflp, Codec::Fpx] {
            let data = sample(n, 50);
            let blob = Blob::compress(codec, &data, 1e-8);
            let x: Vec<f64> = (0..n * nrhs).map(|_| rng.normal()).collect();
            // dot
            let mut acc_p = vec![0.0; nrhs];
            DecodeCursor::new(&blob).dot_panel(n, 1.25, &x, n, nrhs, &mut acc_p, 1);
            for (c, accp) in acc_p.iter().enumerate() {
                let single = 1.25 * DecodeCursor::new(&blob).dot(&x[c * n..(c + 1) * n]);
                assert_eq!(accp.to_bits(), single.to_bits(), "{codec:?} dot col {c}");
            }
            // axpy
            let w: Vec<f64> = (0..nrhs).map(|c| if c == 2 { 0.0 } else { rng.normal() }).collect();
            let y0: Vec<f64> = (0..n * nrhs).map(|_| rng.normal()).collect();
            let mut yp = y0.clone();
            DecodeCursor::new(&blob).axpy_panel(n, 2.0, &w, 1, nrhs, &mut yp, n);
            for (c, &wc) in w.iter().enumerate() {
                let mut ys = y0[c * n..(c + 1) * n].to_vec();
                if 2.0 * wc != 0.0 {
                    DecodeCursor::new(&blob).axpy(2.0 * wc, &mut ys);
                }
                for (a, b) in yp[c * n..(c + 1) * n].iter().zip(&ys) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{codec:?} axpy col {c}");
                }
            }
        }
    }

    #[test]
    fn cursor_get_matches_blob_get() {
        for codec in [Codec::Aflp, Codec::Fpx] {
            let data = sample(77, 51);
            let blob = Blob::compress(codec, &data, 1e-6);
            let cur = DecodeCursor::new(&blob);
            for i in 0..blob.n {
                assert_eq!(cur.get(i).to_bits(), blob.get(i).to_bits(), "{codec:?} idx {i}");
            }
        }
    }

    #[test]
    fn zero_blob_ops() {
        let blob = Blob::compress(Codec::Fpx, &[0.0; 33], 1e-6);
        let mut cur = DecodeCursor::new(&blob);
        assert_eq!(cur.len(), 33);
        let mut out = vec![1.0; 33];
        cur.next_chunk(&mut out);
        assert!(out.iter().all(|&v| v == 0.0));
        cur.seek(0);
        let ones = vec![1.0; 33];
        assert_eq!(cur.dot(&ones), 0.0);
        assert_eq!(cur.get(7), 0.0);
    }

    #[test]
    fn mode_and_level_labels() {
        assert!(["fused", "blockwise"].contains(&kernel_mode_name()));
        assert!(["scalar", "avx2"].contains(&simd_name()));
        let l = kernels_label();
        assert!(l.starts_with(kernel_mode_name()), "{l}");
    }

    /// Every cursor operation served from the hot cache must match the
    /// streamed fused kernels bit for bit, across codecs, widths, positions
    /// and batch shapes — the contract that makes caching a pure speed knob.
    #[test]
    fn hot_cache_cursor_ops_bitwise() {
        let cache = crate::store::HotCache::new(1 << 22);
        let mut rng = Rng::new(321);
        for codec in [Codec::Aflp, Codec::Fpx] {
            for &eps in &[1e-2, 1e-6, 1e-10, 1e-14] {
                let data = sample(173, 99);
                let blob = Blob::compress(codec, &data, eps);
                let nrhs = 11; // > PANEL_GROUP: exercises grouping
                let x: Vec<f64> = (0..blob.n * nrhs).map(|_| rng.normal()).collect();
                let wv: Vec<f64> = (0..nrhs * 3).map(|i| if i % 5 == 0 { 0.0 } else { rng.normal() }).collect();
                for begin in [0usize, 1, 7, 64, 170] {
                    let len = blob.n - begin;
                    let cold = || {
                        let mut c = DecodeCursor::new(&blob);
                        assert!(c.hot.is_none());
                        c.seek(begin);
                        c
                    };
                    let hot = || {
                        let mut c = crate::store::hot::scope(&cache, || DecodeCursor::new(&blob));
                        assert!(c.hot.is_some(), "blob must be cached");
                        c.seek(begin);
                        c
                    };
                    // get / next_chunk
                    assert_eq!(cold().get(begin).to_bits(), hot().get(begin).to_bits());
                    let (mut a, mut b) = (vec![0.0; len], vec![0.0; len]);
                    cold().next_chunk(&mut a);
                    hot().next_chunk(&mut b);
                    assert_eq!(a, b);
                    // dot / axpy
                    let d1 = cold().dot(&x[..len]);
                    let d2 = hot().dot(&x[..len]);
                    assert_eq!(d1.to_bits(), d2.to_bits(), "{codec:?} eps {eps} begin {begin}");
                    let (mut y1, mut y2) = (vec![0.1; len], vec![0.1; len]);
                    cold().axpy(1.75, &mut y1);
                    hot().axpy(1.75, &mut y2);
                    for (u, v) in y1.iter().zip(&y2) {
                        assert_eq!(u.to_bits(), v.to_bits());
                    }
                    // panel dot / axpy (strided accumulators, zero weights)
                    let (mut a1, mut a2) = (vec![0.3; nrhs * 3], vec![0.3; nrhs * 3]);
                    cold().dot_panel(len, 0.9, &x, blob.n, nrhs, &mut a1, 3);
                    hot().dot_panel(len, 0.9, &x, blob.n, nrhs, &mut a2, 3);
                    for (u, v) in a1.iter().zip(&a2) {
                        assert_eq!(u.to_bits(), v.to_bits());
                    }
                    let (mut p1, mut p2) = (vec![0.2; blob.n * nrhs], vec![0.2; blob.n * nrhs]);
                    cold().axpy_panel(len, 1.1, &wv, 3, nrhs, &mut p1, blob.n);
                    hot().axpy_panel(len, 1.1, &wv, 3, nrhs, &mut p2, blob.n);
                    for (u, v) in p1.iter().zip(&p2) {
                        assert_eq!(u.to_bits(), v.to_bits());
                    }
                }
            }
        }
    }

    /// A blob whose payload sits at an odd offset inside a shared segment
    /// (mapped-file layout) must decode bitwise-identically to the same
    /// payload in its own heap buffer: no kernel may assume aligned backing
    /// bytes. Regression for the storage tier's borrowed-slice audit.
    #[test]
    fn misaligned_backing_bytes_decode_bitwise() {
        use crate::store::{BlobBytes, Segment};
        use std::sync::Arc;
        for codec in [Codec::Aflp, Codec::Fpx] {
            for &eps in &[1e-3, 1e-7, 1e-12] {
                let data = sample(97, 5);
                let blob = Blob::compress(codec, &data, eps);
                // rebuild the payload at deliberately misaligned offsets
                for pad in [1usize, 3, 7] {
                    let mut buf = vec![0xA5u8; pad];
                    buf.extend_from_slice(&blob.bytes);
                    let len = blob.bytes.len();
                    let seg = Arc::new(Segment::Anon(buf));
                    let shifted = Blob { params: blob.params, n: blob.n, bytes: BlobBytes::new(seg, pad, len) };
                    let (a, b) = (blob.to_vec(), shifted.to_vec());
                    for (u, v) in a.iter().zip(&b) {
                        assert_eq!(u.to_bits(), v.to_bits(), "{codec:?} pad {pad}");
                    }
                    let mut rng = Rng::new(8);
                    let x = rng.vector(blob.n);
                    let d1 = DecodeCursor::new(&blob).dot(&x);
                    let d2 = DecodeCursor::new(&shifted).dot(&x);
                    assert_eq!(d1.to_bits(), d2.to_bits());
                    for i in [0usize, 13, 96] {
                        assert_eq!(blob.get(i).to_bits(), shifted.get(i).to_bits());
                    }
                }
            }
        }
    }
}
