//! Error-adaptive floating point compression (paper §4).
//!
//! Two byte-aligned codecs with *random access* to individual values — the
//! property that enables the tightly-coupled compressed MVM of §4.3:
//!
//! * [`aflp`] — **AFLP**: adaptive mantissa length `m_ε = ⌈−log₂ ε⌉` *and*
//!   adaptive exponent width from the dynamic range of the data, values
//!   scaled so the exponent is non-negative (paper Fig. 8 left, from
//!   Kriemann SISC 2025).
//! * [`fpx`] — **FPX**: byte-aligned truncation of the IEEE-754 FP32/FP64
//!   formats with round-to-nearest; decompression is pure byte shifting
//!   (paper Fig. 8 right, after Amestoy et al. 2025).
//!
//! [`valr`] implements the **VALR** scheme for low-rank data: each column of
//! the (orthogonal) factors is stored with its own accuracy δᵢ = δ/σᵢ
//! (Eq. 6/7).
//!
//! [`dispatch`] is the codec-kernel subsystem behind all decoding: runtime
//! SIMD dispatch (per-`(codec, width)` function tables, AVX2 picked by
//! `is_x86_feature_detected!` in every release build), [`DecodeCursor`]
//! streaming decoders that resolve blob parameters once, and the fused
//! decode–FMA kernels the MVM apply paths run on.

pub mod aflp;
pub mod dispatch;
pub mod formats;
pub mod fpx;
pub mod valr;

pub use dispatch::{DecodeCursor, KernelMode, SimdLevel};
pub use formats::unit_roundoff;
pub use valr::ZLowRankValr;

/// Compression codec selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// Adaptive floating point (mantissa + exponent adaptive).
    Aflp,
    /// Truncated IEEE-754 (FP32/FP64 prefix, byte aligned).
    Fpx,
}

impl Codec {
    pub fn name(self) -> &'static str {
        match self {
            Codec::Aflp => "aflp",
            Codec::Fpx => "fpx",
        }
    }
}

impl std::str::FromStr for Codec {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "aflp" => Ok(Codec::Aflp),
            "fpx" => Ok(Codec::Fpx),
            other => Err(format!("unknown codec '{other}' (aflp|fpx)")),
        }
    }
}

/// Per-blob codec parameters (the decode "header").
#[derive(Clone, Copy, Debug)]
pub enum CodecParams {
    /// AFLP: `bytes_per` value, `e_bits` exponent bits, scale = v_min.
    Aflp { bytes_per: u8, e_bits: u8, scale: f64 },
    /// FPX over FP32: top `bytes_per` bytes of the f32 pattern.
    Fpx32 { bytes_per: u8 },
    /// FPX over FP64: top `bytes_per` bytes of the f64 pattern.
    Fpx64 { bytes_per: u8 },
    /// All-zero data (no payload).
    Zero,
}

/// A compressed array of f64 values with random access.
#[derive(Clone, Debug)]
pub struct Blob {
    pub params: CodecParams,
    /// Number of values.
    pub n: usize,
    /// Packed little-endian payload, `n * bytes_per` bytes — a slice of a
    /// reference-counted [`crate::store::Segment`] (anonymous memory by
    /// default, or a shared file mapping after `store::attach_*`).
    pub bytes: crate::store::BlobBytes,
}

/// Fixed per-blob header overhead charged in memory accounting
/// (params + length + vec bookkeeping).
pub const BLOB_OVERHEAD: usize = 24;

impl Blob {
    /// Compress `data` so that the *relative* error per value is ≤ `eps`
    /// (values of magnitude far below the block maximum may carry larger
    /// relative error under FPX64 denormal-free truncation — see codec docs).
    pub fn compress(codec: Codec, data: &[f64], eps: f64) -> Blob {
        match codec {
            Codec::Aflp => aflp::compress(data, eps),
            Codec::Fpx => fpx::compress(data, eps),
        }
    }

    /// Decompress everything into `out` (len == n).
    pub fn decompress_into(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.n);
        dispatch::range(&self.params, &self.bytes, 0, self.n, out);
    }

    /// Decompress the half-open value range [begin, end) into `out` (the
    /// kernel — scalar or runtime-dispatched SIMD — comes from
    /// [`dispatch::resolve`]; streamed consumers hold a [`DecodeCursor`] so
    /// the resolution happens once per blob, not once per chunk).
    pub fn decompress_range(&self, begin: usize, end: usize, out: &mut [f64]) {
        debug_assert!(begin <= end && end <= self.n);
        debug_assert_eq!(out.len(), end - begin);
        dispatch::range(&self.params, &self.bytes, begin, end, out);
    }

    /// Random access to value `i`.
    #[inline]
    pub fn get(&self, i: usize) -> f64 {
        debug_assert!(i < self.n);
        dispatch::get(&self.params, &self.bytes, i)
    }

    /// Decompress to a fresh vector.
    pub fn to_vec(&self) -> Vec<f64> {
        let mut v = vec![0.0; self.n];
        self.decompress_into(&mut v);
        v
    }

    /// Bytes per stored value.
    pub fn bytes_per_value(&self) -> usize {
        match self.params {
            CodecParams::Aflp { bytes_per, .. } => bytes_per as usize,
            CodecParams::Fpx32 { bytes_per } | CodecParams::Fpx64 { bytes_per } => bytes_per as usize,
            CodecParams::Zero => 0,
        }
    }

    /// Memory footprint (payload + header overhead).
    pub fn byte_size(&self) -> usize {
        self.bytes.len() + BLOB_OVERHEAD
    }
}

/// How a hierarchical matrix should be compressed.
#[derive(Clone, Copy, Debug)]
pub struct CompressionConfig {
    pub codec: Codec,
    /// Block accuracy ε (drives mantissa widths).
    pub eps: f64,
    /// Use VALR (per-column adaptive accuracy) for low-rank factors and
    /// cluster bases; otherwise compress factors with fixed precision.
    pub valr: bool,
}

impl CompressionConfig {
    pub fn aflp(eps: f64) -> Self {
        CompressionConfig { codec: Codec::Aflp, eps, valr: true }
    }

    pub fn fpx(eps: f64) -> Self {
        CompressionConfig { codec: Codec::Fpx, eps, valr: true }
    }
}

/// Maximum relative error of a compressed blob vs the original data
/// (test/diagnostic helper).
pub fn max_rel_error(blob: &Blob, data: &[f64]) -> f64 {
    let dec = blob.to_vec();
    let mut worst = 0.0f64;
    for (d, o) in dec.iter().zip(data) {
        if *o != 0.0 {
            worst = worst.max((d - o).abs() / o.abs());
        } else {
            worst = worst.max(d.abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample_data(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() * 10f64.powf(rng.range(-2.0, 2.0))).collect()
    }

    #[test]
    fn both_codecs_meet_eps() {
        let data = sample_data(1000, 7);
        for codec in [Codec::Aflp, Codec::Fpx] {
            for eps in [1e-2, 1e-4, 1e-6, 1e-8, 1e-10] {
                let blob = Blob::compress(codec, &data, eps);
                let err = max_rel_error(&blob, &data);
                assert!(err <= eps, "{codec:?} eps={eps} err={err}");
            }
        }
    }

    #[test]
    fn byte_sizes_shrink_with_eps() {
        let data = sample_data(4096, 8);
        for codec in [Codec::Aflp, Codec::Fpx] {
            let coarse = Blob::compress(codec, &data, 1e-2).byte_size();
            let fine = Blob::compress(codec, &data, 1e-10).byte_size();
            assert!(coarse < fine, "{codec:?}: {coarse} !< {fine}");
            assert!(fine <= data.len() * 8 + BLOB_OVERHEAD);
        }
    }

    #[test]
    fn aflp_beats_fpx_on_narrow_range() {
        // values of similar magnitude: AFLP needs almost no exponent bits
        let mut rng = Rng::new(9);
        let data: Vec<f64> = (0..2048).map(|_| 1.0 + 0.5 * rng.uniform()).collect();
        let eps = 1e-6;
        let a = Blob::compress(Codec::Aflp, &data, eps).byte_size();
        let f = Blob::compress(Codec::Fpx, &data, eps).byte_size();
        assert!(a <= f, "aflp {a} vs fpx {f}");
    }

    #[test]
    fn random_access_matches_bulk() {
        let data = sample_data(257, 10);
        for codec in [Codec::Aflp, Codec::Fpx] {
            let blob = Blob::compress(codec, &data, 1e-6);
            let bulk = blob.to_vec();
            for i in [0usize, 1, 100, 255, 256] {
                assert_eq!(blob.get(i), bulk[i], "{codec:?} idx {i}");
            }
        }
    }

    #[test]
    fn range_decompress() {
        let data = sample_data(500, 11);
        for codec in [Codec::Aflp, Codec::Fpx] {
            let blob = Blob::compress(codec, &data, 1e-7);
            let bulk = blob.to_vec();
            let mut part = vec![0.0; 100];
            blob.decompress_range(123, 223, &mut part);
            assert_eq!(&part[..], &bulk[123..223]);
        }
    }

    #[test]
    fn zero_data() {
        let data = vec![0.0; 64];
        for codec in [Codec::Aflp, Codec::Fpx] {
            let blob = Blob::compress(codec, &data, 1e-6);
            assert_eq!(blob.to_vec(), data);
        }
    }

    #[test]
    fn handles_zeros_mixed_with_values() {
        let mut data = sample_data(100, 12);
        data[0] = 0.0;
        data[50] = 0.0;
        data[99] = 0.0;
        for codec in [Codec::Aflp, Codec::Fpx] {
            let blob = Blob::compress(codec, &data, 1e-6);
            let dec = blob.to_vec();
            assert_eq!(dec[0], 0.0, "{codec:?}");
            assert_eq!(dec[50], 0.0);
            assert_eq!(dec[99], 0.0);
            assert!(max_rel_error(&blob, &data) <= 1e-6);
        }
    }

    #[test]
    fn codec_from_str() {
        assert_eq!("aflp".parse::<Codec>().unwrap(), Codec::Aflp);
        assert_eq!("FPX".parse::<Codec>().unwrap(), Codec::Fpx);
        assert!("zfp".parse::<Codec>().is_err());
    }
}
