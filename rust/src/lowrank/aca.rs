//! Adaptive cross approximation with partial pivoting (ACA+-style restart)
//! and SVD recompression, the paper's low-rank approximation workhorse for
//! admissible blocks (accuracy-ε per Eq. 3).

use super::truncation::truncate_factors;
use super::LowRank;
use crate::kernelfn::MatrixGen;
use crate::la::DMatrix;

/// Options for low-rank approximation.
#[derive(Clone, Copy, Debug)]
pub struct AcaOptions {
    /// Relative target accuracy ε (Frobenius, per block).
    pub eps: f64,
    /// Hard cap on the rank explored by ACA.
    pub max_rank: usize,
    /// If set, truncate to exactly this rank instead of accuracy ε.
    pub fixed_rank: Option<usize>,
    /// Recompress ACA output with a truncated SVD.
    pub recompress: bool,
}

impl AcaOptions {
    /// Accuracy-driven approximation.
    pub fn with_eps(eps: f64) -> Self {
        AcaOptions { eps, max_rank: 512, fixed_rank: None, recompress: true }
    }

    /// Fixed-rank approximation.
    pub fn with_rank(k: usize) -> Self {
        AcaOptions { eps: 1e-12, max_rank: 4 * k.max(1), fixed_rank: Some(k), recompress: true }
    }
}

/// A sub-block view of a generator: external row/col index lists.
pub struct BlockAccess<'a> {
    pub gen: &'a dyn MatrixGen,
    pub rows: &'a [usize],
    pub cols: &'a [usize],
}

impl<'a> BlockAccess<'a> {
    pub fn nrows(&self) -> usize {
        self.rows.len()
    }

    pub fn ncols(&self) -> usize {
        self.cols.len()
    }

    fn row(&self, i: usize, out: &mut [f64]) {
        self.gen.fill_row(self.rows[i], self.cols, out);
    }

    fn col(&self, j: usize, out: &mut [f64]) {
        self.gen.fill_col(self.cols[j], self.rows, out);
    }

    /// Assemble the whole block (fallback for tiny blocks).
    pub fn assemble(&self) -> DMatrix {
        let mut m = DMatrix::zeros(self.nrows(), self.ncols());
        self.gen.fill(self.rows, self.cols, &mut m);
        m
    }
}

/// ACA with partial pivoting. Returns U·Vᵀ ≈ block with (estimated) relative
/// Frobenius error ≤ `opts.eps`.
pub fn aca(block: &BlockAccess, opts: &AcaOptions) -> LowRank {
    let m = block.nrows();
    let n = block.ncols();
    let kmax = opts.max_rank.min(m).min(n).max(1);

    // tiny blocks: assemble + SVD directly (more robust than ACA)
    if m.min(n) <= 8 {
        let a = block.assemble();
        let svd = crate::la::svd_jacobi(&a);
        let k = match opts.fixed_rank {
            Some(k) => k.min(svd.s.len()),
            None => svd.rank(opts.eps),
        };
        let t = svd.truncate(k.max(1));
        let mut v = t.v;
        for (j, &s) in t.s.iter().enumerate() {
            for x in v.col_mut(j) {
                *x *= s;
            }
        }
        return LowRank { u: t.u, v };
    }

    let mut us: Vec<Vec<f64>> = Vec::new();
    let mut vs: Vec<Vec<f64>> = Vec::new();
    let mut used_rows = vec![false; m];
    let mut used_cols = vec![false; n];
    let mut fro2 = 0.0f64; // running ||U V^T||_F^2 estimate
    let mut next_row = 0usize;
    let mut restarts = 3usize; // ACA+-style random-ish restarts on breakdown

    let mut row_buf = vec![0.0; n];
    let mut col_buf = vec![0.0; m];

    while us.len() < kmax {
        let i = next_row;
        used_rows[i] = true;
        // residual row i
        block.row(i, &mut row_buf);
        for (u, v) in us.iter().zip(vs.iter()) {
            let ui = u[i];
            if ui != 0.0 {
                for (r, vv) in row_buf.iter_mut().zip(v.iter()) {
                    *r -= ui * vv;
                }
            }
        }
        // pivot column
        let mut jstar = usize::MAX;
        let mut best = 0.0;
        for (j, &r) in row_buf.iter().enumerate() {
            if !used_cols[j] && r.abs() > best {
                best = r.abs();
                jstar = j;
            }
        }
        if jstar == usize::MAX || best == 0.0 {
            // breakdown: restart from an unused row or stop
            if restarts == 0 {
                break;
            }
            restarts -= 1;
            match pick_unused(&used_rows, i) {
                Some(r) => {
                    next_row = r;
                    continue;
                }
                None => break,
            }
        }
        used_cols[jstar] = true;
        let delta = row_buf[jstar];

        // residual column jstar
        block.col(jstar, &mut col_buf);
        for (u, v) in us.iter().zip(vs.iter()) {
            let vj = v[jstar];
            if vj != 0.0 {
                for (c, uu) in col_buf.iter_mut().zip(u.iter()) {
                    *c -= vj * uu;
                }
            }
        }

        // new rank-1 term: u = col/delta, v = row
        let u_new: Vec<f64> = col_buf.iter().map(|&c| c / delta).collect();
        let v_new: Vec<f64> = row_buf.clone();

        let nu: f64 = u_new.iter().map(|x| x * x).sum::<f64>();
        let nv: f64 = v_new.iter().map(|x| x * x).sum::<f64>();
        let term = (nu * nv).sqrt();

        // cross terms for the Frobenius estimate
        let mut cross = 0.0;
        for (u, v) in us.iter().zip(vs.iter()) {
            let du: f64 = u.iter().zip(&u_new).map(|(a, b)| a * b).sum();
            let dv: f64 = v.iter().zip(&v_new).map(|(a, b)| a * b).sum();
            cross += du * dv;
        }
        fro2 += 2.0 * cross + nu * nv;
        us.push(u_new);
        vs.push(v_new);

        // convergence: new term small relative to accumulated norm
        if opts.fixed_rank.is_none() && term <= opts.eps * fro2.max(f64::MIN_POSITIVE).sqrt() {
            break;
        }
        if let Some(k) = opts.fixed_rank {
            if us.len() >= k {
                break;
            }
        }

        // next row: max |u_new| among unused rows
        let mut besti = usize::MAX;
        let mut bestu = -1.0;
        for (r, &u) in us.last().unwrap().iter().enumerate() {
            if !used_rows[r] && u.abs() > bestu {
                bestu = u.abs();
                besti = r;
            }
        }
        match besti {
            usize::MAX => break,
            r => next_row = r,
        }
    }

    let k = us.len().max(1);
    let mut u = DMatrix::zeros(m, k);
    let mut v = DMatrix::zeros(n, k);
    for (j, (uc, vc)) in us.iter().zip(vs.iter()).enumerate() {
        u.col_mut(j).copy_from_slice(uc);
        v.col_mut(j).copy_from_slice(vc);
    }
    let lr = LowRank { u, v };
    if opts.recompress {
        truncate_factors(lr, opts)
    } else {
        lr
    }
}

fn pick_unused(used: &[bool], after: usize) -> Option<usize> {
    used.iter().enumerate().cycle().skip(after + 1).take(used.len()).find(|(_, &u)| !u).map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::fibonacci_sphere;
    use crate::kernelfn::DenseGen;
    use crate::la::{matmul, DMatrix, Trans};
    use crate::util::Rng;

    fn lowrank_gen(m: usize, n: usize, k: usize, seed: u64) -> (DenseGen, DMatrix) {
        let mut rng = Rng::new(seed);
        let u = DMatrix::random(m, k, &mut rng);
        let v = DMatrix::random(n, k, &mut rng);
        let a = matmul(&u, Trans::No, &v, Trans::Yes);
        // need points for the MatrixGen trait; values irrelevant here
        let pts = fibonacci_sphere(m.max(n));
        (DenseGen::new(a.clone(), pts[..m].to_vec()), a)
    }

    #[test]
    fn aca_recovers_exact_lowrank() {
        let (gen, a) = lowrank_gen(40, 30, 5, 21);
        let rows: Vec<usize> = (0..40).collect();
        let cols: Vec<usize> = (0..30).collect();
        let lr = aca(&BlockAccess { gen: &gen, rows: &rows, cols: &cols }, &AcaOptions::with_eps(1e-10));
        assert!(lr.rank() <= 8, "rank {}", lr.rank());
        let err = {
            let mut d = lr.to_dense();
            d.add_scaled(-1.0, &a);
            d.fro_norm() / a.fro_norm()
        };
        assert!(err < 1e-9, "err {err}");
    }

    #[test]
    fn aca_eps_accuracy_smooth_kernel() {
        // smooth kernel block 1/(1+|x-y|) between two separated clusters
        let pts = fibonacci_sphere(128);
        let m = DMatrix::from_fn(64, 64, |i, j| 1.0 / (1.0 + pts[i].dist(pts[64 + j]).powi(2)));
        let gen = DenseGen::new(m.clone(), pts[..64].to_vec());
        let rows: Vec<usize> = (0..64).collect();
        let cols: Vec<usize> = (0..64).collect();
        for eps in [1e-4, 1e-6, 1e-8] {
            let lr = aca(&BlockAccess { gen: &gen, rows: &rows, cols: &cols }, &AcaOptions::with_eps(eps));
            let mut d = lr.to_dense();
            d.add_scaled(-1.0, &m);
            let err = d.fro_norm() / m.fro_norm();
            assert!(err < 10.0 * eps, "eps={eps} err={err} rank={}", lr.rank());
        }
    }

    #[test]
    fn aca_fixed_rank() {
        let (gen, _) = lowrank_gen(50, 50, 10, 22);
        let rows: Vec<usize> = (0..50).collect();
        let cols: Vec<usize> = (0..50).collect();
        let lr = aca(&BlockAccess { gen: &gen, rows: &rows, cols: &cols }, &AcaOptions::with_rank(4));
        assert_eq!(lr.rank(), 4);
    }

    #[test]
    fn aca_tiny_block_falls_back_to_svd() {
        let (gen, a) = lowrank_gen(6, 5, 2, 23);
        let rows: Vec<usize> = (0..6).collect();
        let cols: Vec<usize> = (0..5).collect();
        let lr = aca(&BlockAccess { gen: &gen, rows: &rows, cols: &cols }, &AcaOptions::with_eps(1e-10));
        let mut d = lr.to_dense();
        d.add_scaled(-1.0, &a);
        assert!(d.fro_norm() < 1e-8 * a.fro_norm().max(1.0));
    }

    #[test]
    fn aca_zero_block() {
        let pts = fibonacci_sphere(10);
        let gen = DenseGen::new(DMatrix::zeros(10, 10), pts);
        let rows: Vec<usize> = (0..10).collect();
        let cols: Vec<usize> = (0..10).collect();
        let lr = aca(&BlockAccess { gen: &gen, rows: &rows, cols: &cols }, &AcaOptions::with_eps(1e-8));
        assert!(lr.to_dense().fro_norm() == 0.0);
    }
}
