//! SVD-based recompression of factored low-rank matrices.

use super::aca::AcaOptions;
use super::LowRank;
use crate::la::{svd_of_product, Svd};

/// Recompress U·Vᵀ via QR+SVD to the accuracy / rank in `opts`.
/// The singular values are folded into V (U keeps orthonormal columns) so the
/// VALR compressor can later recover them from the column norms of V — but we
/// also return them explicitly through [`truncated_svd_of_product`] where
/// needed.
pub fn truncate_factors(lr: LowRank, opts: &AcaOptions) -> LowRank {
    if lr.rank() == 0 {
        return lr;
    }
    let svd = svd_of_product(&lr.u, &lr.v);
    let k = match opts.fixed_rank {
        Some(k) => k.min(svd.s.len()),
        None => svd.rank(opts.eps),
    }
    .max(1);
    let t = svd.truncate(k);
    let mut v = t.v;
    for (j, &s) in t.s.iter().enumerate() {
        for x in v.col_mut(j) {
            *x *= s;
        }
    }
    LowRank { u: t.u, v }
}

/// Truncated SVD of a factored product (exposed for VALR compression which
/// needs the singular values separately).
pub fn truncated_svd_of_product(lr: &LowRank, eps: f64) -> Svd {
    let svd = svd_of_product(&lr.u, &lr.v);
    let k = svd.rank(eps).max(1).min(svd.s.len().max(1));
    svd.truncate(k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::{matmul, DMatrix, Trans};
    use crate::util::Rng;

    #[test]
    fn truncation_reduces_inflated_rank() {
        // build rank-3 matrix represented with rank 10 factors
        let mut rng = Rng::new(31);
        let u3 = DMatrix::random(30, 3, &mut rng);
        let v3 = DMatrix::random(25, 3, &mut rng);
        let a = matmul(&u3, Trans::No, &v3, Trans::Yes);
        // redundant factorization: U = [u3 u3 u3 pad], V matching
        let mut u = u3.hcat(&u3).hcat(&u3);
        let mut v = v3.clone();
        let mut v2 = v3.clone();
        v2.scale(0.0);
        v = v.hcat(&v2).hcat(&v2);
        u.scale(1.0);
        let lr = LowRank { u, v };
        let t = truncate_factors(lr, &AcaOptions::with_eps(1e-10));
        assert!(t.rank() <= 3, "rank {}", t.rank());
        let mut d = t.to_dense();
        d.add_scaled(-1.0, &a);
        assert!(d.fro_norm() < 1e-8 * a.fro_norm());
    }

    #[test]
    fn svd_of_product_has_descending_values() {
        let mut rng = Rng::new(32);
        let lr = LowRank { u: DMatrix::random(20, 6, &mut rng), v: DMatrix::random(18, 6, &mut rng) };
        let svd = truncated_svd_of_product(&lr, 1e-14);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }
}
