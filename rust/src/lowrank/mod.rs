//! Low-rank approximation: adaptive cross approximation (ACA) with SVD
//! recompression.

mod aca;
mod truncation;

pub use aca::{aca, AcaOptions, BlockAccess};
pub use truncation::{truncate_factors, truncated_svd_of_product};

use crate::la::DMatrix;

/// A factored low-rank matrix M ≈ U·Vᵀ (U: m×k, V: n×k).
#[derive(Clone, Debug)]
pub struct LowRank {
    pub u: DMatrix,
    pub v: DMatrix,
}

impl LowRank {
    pub fn rank(&self) -> usize {
        self.u.ncols()
    }

    pub fn nrows(&self) -> usize {
        self.u.nrows()
    }

    pub fn ncols(&self) -> usize {
        self.v.nrows()
    }

    /// Dense reconstruction (tests / small blocks only).
    pub fn to_dense(&self) -> DMatrix {
        crate::la::matmul(&self.u, crate::la::Trans::No, &self.v, crate::la::Trans::Yes)
    }

    /// Bytes in FP64 representation.
    pub fn byte_size(&self) -> usize {
        self.u.byte_size() + self.v.byte_size()
    }
}
