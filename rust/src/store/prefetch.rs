//! Level-pipelined prefetch for mapped operators.
//!
//! The plan layer's level barriers give the prefetch horizon for free: while
//! level `i` computes, the extents of level `i+1` are handed to one shared
//! background thread that issues `madvise(WILLNEED)` plus touch reads
//! ([`super::Segment::advise_willneed`]), so page-in overlaps compute
//! instead of stalling the first task of the next level. Because the pack
//! format lays extents out level-major, each level's merged extent is one
//! contiguous file range and the readahead is sequential.
//!
//! Purely advisory: results are identical with prefetch off
//! (`HMATC_PREFETCH=0`), it only moves page faults off the critical path.
//! Operators with no mapped blobs build an empty plan and pay nothing.
//!
//! The thread is **process-shared** — one `OnceLock` inbox for every plan
//! and shard, not a thread per plan — and each wake drains the whole inbox
//! and drops duplicate `(segment, range)` extents before issuing. That
//! matters for the sharded tier: N shard plans sliced from one mapped
//! operator hit their level barriers near-simultaneously and would
//! otherwise push N identical `madvise` streams over the same file ranges;
//! deduping the drained batch collapses them to one ([`counters`] exposes
//! the issued/deduped totals).

use super::Segment;
use crate::compress::Blob;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, OnceLock};

type Extents = Vec<(Arc<Segment>, Range<usize>)>;

static ISSUED: AtomicU64 = AtomicU64::new(0);
static DEDUPED: AtomicU64 = AtomicU64::new(0);

/// Cumulative process-wide `(issued, deduped)` extent counts of the shared
/// prefetch thread — introspection for tests and the serve log.
pub fn counters() -> (u64, u64) {
    (ISSUED.load(Ordering::Relaxed), DEDUPED.load(Ordering::Relaxed))
}

/// Drop duplicate `(segment, range)` extents within one drained batch,
/// keeping first occurrences in order. Identity is the segment allocation
/// (pointer) plus the exact byte range — the shape in which shard plans
/// sharing one mapping duplicate each other's level extents.
fn dedupe_batch(batch: &mut Extents) {
    let mut seen: Vec<(usize, Range<usize>)> = Vec::with_capacity(batch.len());
    batch.retain(|(seg, range)| {
        let key = (Arc::as_ptr(seg) as usize, range.clone());
        if seen.contains(&key) {
            false
        } else {
            seen.push(key);
            true
        }
    });
}

/// Whether prefetch is on for this process (default yes; `HMATC_PREFETCH=0`
/// disables it — read once, like the other dispatch env switches).
pub fn enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| std::env::var("HMATC_PREFETCH").map(|v| v.trim() != "0").unwrap_or(true))
}

/// The shared prefetch thread's inbox (spawned on first use; a failed spawn
/// degrades to dropped sends, never an error on the compute path).
fn sender() -> &'static Mutex<Sender<Extents>> {
    static TX: OnceLock<Mutex<Sender<Extents>>> = OnceLock::new();
    TX.get_or_init(|| {
        let (tx, rx) = channel::<Extents>();
        let spawned = std::thread::Builder::new().name("hmatc-prefetch".into()).spawn(move || {
            while let Ok(job) = rx.recv() {
                // drain everything already queued: concurrent shard plans
                // over one mapping advise the same ranges at the same
                // barrier, and one pass per unique extent is enough
                let mut batch = job;
                while let Ok(more) = rx.try_recv() {
                    batch.extend(more);
                }
                let before = batch.len();
                dedupe_batch(&mut batch);
                DEDUPED.fetch_add((before - batch.len()) as u64, Ordering::Relaxed);
                ISSUED.fetch_add(batch.len() as u64, Ordering::Relaxed);
                for (seg, range) in batch {
                    seg.advise_willneed(range);
                }
            }
        });
        drop(spawned);
        Mutex::new(tx)
    })
}

/// Per-level merged mapped extents of one schedule, in the schedule's level
/// order; built once at plan build, issued at each level barrier.
#[derive(Default)]
pub struct PrefetchPlan {
    levels: Vec<Extents>,
}

impl PrefetchPlan {
    /// True when no level has any mapped extent (anon-backed operators) —
    /// callers skip issuing entirely.
    pub fn is_empty(&self) -> bool {
        self.levels.iter().all(|l| l.is_empty())
    }

    /// Number of levels recorded.
    pub fn levels(&self) -> usize {
        self.levels.len()
    }

    /// Queue level `level`'s extents on the prefetch thread (no-op when the
    /// level is out of range/empty or `HMATC_PREFETCH=0`). Asynchronous and
    /// advisory — never blocks the caller on I/O.
    pub fn issue(&self, level: usize) {
        if !enabled() {
            return;
        }
        let Some(extents) = self.levels.get(level) else {
            return;
        };
        if extents.is_empty() {
            return;
        }
        let job: Extents = extents.clone();
        let _ = sender().lock().unwrap().send(job);
    }
}

/// Accumulates blobs into a [`PrefetchPlan`], merging each level's extents
/// per segment into one min..max range (tight, because the pack layout is
/// level-major).
#[derive(Default)]
pub struct PrefetchBuilder {
    levels: Vec<Extents>,
}

impl PrefetchBuilder {
    /// Record `blob` as read by level `level` (ignored unless mapped).
    pub fn add(&mut self, level: usize, blob: &Blob) {
        if !blob.bytes.is_mapped() {
            return;
        }
        let (seg, range) = blob.bytes.extent();
        if self.levels.len() <= level {
            self.levels.resize_with(level + 1, Vec::new);
        }
        let lvl = &mut self.levels[level];
        for (s, r) in lvl.iter_mut() {
            if Arc::ptr_eq(s, seg) {
                r.start = r.start.min(range.start);
                r.end = r.end.max(range.end);
                return;
            }
        }
        lvl.push((seg.clone(), range));
    }

    pub fn finish(self) -> PrefetchPlan {
        PrefetchPlan { levels: self.levels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Blob, Codec};
    use crate::store::BlobBytes;

    #[test]
    fn anon_blobs_build_empty_plan() {
        let b = Blob::compress(Codec::Aflp, &[1.0, 2.0, 3.0], 1e-6);
        let mut pb = PrefetchBuilder::default();
        pb.add(0, &b);
        pb.add(2, &b);
        let plan = pb.finish();
        assert!(plan.is_empty());
        plan.issue(0); // must be a harmless no-op
        plan.issue(99);
    }

    #[test]
    fn mapped_extents_merge_per_level() {
        let path = std::env::temp_dir().join(format!("hmatc_pf_{}.bin", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, vec![0u8; 4096]).unwrap();
        let seg = Arc::new(Segment::map_file(&path).unwrap());
        let mk = |off: usize, nvals: usize| {
            let data = vec![0.5; nvals];
            let mut b = Blob::compress(Codec::Fpx, &data, 1e-2);
            let len = b.bytes.len();
            b.bytes = BlobBytes::new(seg.clone(), off, len);
            b
        };
        let mut pb = PrefetchBuilder::default();
        pb.add(0, &mk(100, 4));
        pb.add(0, &mk(900, 4));
        pb.add(1, &mk(2000, 8));
        let plan = pb.finish();
        assert!(!plan.is_empty());
        assert_eq!(plan.levels(), 2);
        plan.issue(0);
        plan.issue(1);
        // drain: the background thread owns Arc clones; dropping ours is fine
        drop(plan);
        std::thread::sleep(std::time::Duration::from_millis(50));
        drop(seg);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn duplicate_extents_collapse_within_a_drained_batch() {
        // two Arcs over the same file are distinct segment identities; the
        // duplicate (segment, range) pairs shard plans produce are clones of
        // ONE Arc, and only those collapse
        let path = std::env::temp_dir().join(format!("hmatc_pfdup_{}.bin", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, vec![0u8; 4096]).unwrap();
        let a = Arc::new(Segment::map_file(&path).unwrap());
        let b = Arc::new(Segment::map_file(&path).unwrap());
        let mut batch: Extents =
            vec![(a.clone(), 0..128), (a.clone(), 0..128), (a.clone(), 256..512), (b.clone(), 0..128), (a.clone(), 0..128)];
        dedupe_batch(&mut batch);
        assert_eq!(batch.len(), 3, "kept one per unique (segment, range)");
        assert!(Arc::ptr_eq(&batch[0].0, &a) && batch[0].1 == (0..128));
        assert!(Arc::ptr_eq(&batch[1].0, &a) && batch[1].1 == (256..512));
        assert!(Arc::ptr_eq(&batch[2].0, &b) && batch[2].1 == (0..128));
        drop(batch);
        drop(a);
        drop(b);
        std::fs::remove_file(&path).ok();
    }
}
