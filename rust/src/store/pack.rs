//! The `HMPK` packed-operator file: every compressed payload of an
//! operator, laid out level-major, validated on open, served by mmap.
//!
//! ```text
//!   offset  size  field
//!   0       4     magic  "HMPK"
//!   4       4     version (little-endian u32, currently 1)
//!   8       8     n_extents (u64)
//!   16      8     payload_len (u64)
//!   24      28·n  extents: { level u32, off u64, len u64, checksum u64 }
//!   24+28n  8     header checksum (FNV-1a over all preceding bytes)
//!   ...           payload (extents point into this, level-major order)
//! ```
//!
//! Extents are the operator's blob payloads in structure-traversal order,
//! stably sorted by block/cluster level — so each level occupies one
//! contiguous file range and the level-pipelined prefetcher's readahead is
//! sequential. `attach_*` re-points an *identically built* operator's blobs
//! into the mapping by replaying the same traversal: every `(level, len)`
//! pair must match one-to-one, anything else is an error. [`MappedStore::open`]
//! verifies magic, version, bounds and every checksum eagerly — truncated
//! or corrupted files are rejected up front, never UB later.

use super::{fnv1a, BlobBytes, HotCache, Residency, ResidencyScan, Segment};
use crate::compress::Blob;
use crate::h2::H2Matrix;
use crate::hmatrix::HMatrix;
use crate::uniform::UniformHMatrix;
use std::io::Write;
use std::sync::Arc;

/// Current on-disk format version.
pub const PACK_VERSION: u32 = 1;

const MAGIC: &[u8; 4] = b"HMPK";
const EXTENT_BYTES: usize = 4 + 8 + 8 + 8;
const FIXED_HEADER: usize = 4 + 4 + 8 + 8;

/// One payload slice in a packed file.
#[derive(Clone, Copy, Debug)]
pub struct Extent {
    pub level: u32,
    pub off: u64,
    pub len: u64,
    pub checksum: u64,
}

/// What `hmatc pack` wrote.
#[derive(Clone, Copy, Debug)]
pub struct PackSummary {
    pub extents: usize,
    pub payload_bytes: usize,
    pub file_bytes: usize,
}

/// A validated, mapped `HMPK` file.
pub struct MappedStore {
    seg: Arc<Segment>,
    payload_base: usize,
    extents: Vec<Extent>,
}

fn read_u32(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(b[off..off + 4].try_into().unwrap())
}

fn read_u64(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(b[off..off + 8].try_into().unwrap())
}

impl MappedStore {
    /// Map and fully validate `path` (see module docs for what is checked).
    pub fn open(path: &str) -> Result<MappedStore, String> {
        let seg = Arc::new(Segment::map_file(path)?);
        let b = seg.as_slice();
        if b.len() < FIXED_HEADER {
            return Err(format!("{path}: truncated header ({} bytes)", b.len()));
        }
        if &b[0..4] != MAGIC {
            return Err(format!("{path}: not an HMPK file (bad magic)"));
        }
        let version = read_u32(b, 4);
        if version != PACK_VERSION {
            return Err(format!("{path}: version mismatch (file v{version}, supported v{PACK_VERSION})"));
        }
        // All offset/length arithmetic below is checked: the header fields
        // are attacker-controlled u64s, and a narrowing `as usize` (32-bit
        // targets) or an unchecked add could wrap, pass the bounds check,
        // and turn a hostile file into out-of-bounds payload reads.
        let n = usize::try_from(read_u64(b, 8)).map_err(|_| format!("{path}: extent count overflow"))?;
        let payload_len = usize::try_from(read_u64(b, 16)).map_err(|_| format!("{path}: payload length overflow"))?;
        let header_len = FIXED_HEADER.checked_add(n.checked_mul(EXTENT_BYTES).ok_or_else(|| format!("{path}: extent count overflow"))?).and_then(|h| h.checked_add(8)).ok_or_else(|| format!("{path}: header length overflow"))?;
        let total = header_len.checked_add(payload_len).ok_or_else(|| format!("{path}: file length overflow"))?;
        if b.len() != total {
            return Err(format!("{path}: truncated or oversized file ({} bytes, header says {total})", b.len()));
        }
        let stored = read_u64(b, header_len - 8);
        if fnv1a(&b[..header_len - 8]) != stored {
            return Err(format!("{path}: header checksum mismatch"));
        }
        let payload_base = header_len;
        let mut extents = Vec::with_capacity(n);
        for i in 0..n {
            let off = FIXED_HEADER + i * EXTENT_BYTES;
            let e = Extent { level: read_u32(b, off), off: read_u64(b, off + 4), len: read_u64(b, off + 12), checksum: read_u64(b, off + 20) };
            let e_off = usize::try_from(e.off).map_err(|_| format!("{path}: extent {i} offset overflow"))?;
            let e_len = usize::try_from(e.len).map_err(|_| format!("{path}: extent {i} length overflow"))?;
            let end = e_off.checked_add(e_len).ok_or_else(|| format!("{path}: extent {i} range overflow"))?;
            if end > payload_len {
                return Err(format!("{path}: extent {i} [{e_off}, {end}) outside payload ({payload_len} bytes)"));
            }
            let start = payload_base.checked_add(e_off).ok_or_else(|| format!("{path}: extent {i} range overflow"))?;
            let stop = payload_base.checked_add(end).ok_or_else(|| format!("{path}: extent {i} range overflow"))?;
            let data = b.get(start..stop).ok_or_else(|| format!("{path}: extent {i} escapes the mapping"))?;
            if fnv1a(data) != e.checksum {
                return Err(format!("{path}: extent {i} checksum mismatch"));
            }
            extents.push(e);
        }
        Ok(MappedStore { seg, payload_base, extents })
    }

    /// Number of payload extents.
    pub fn extents(&self) -> usize {
        self.extents.len()
    }

    /// Total payload bytes.
    pub fn payload_bytes(&self) -> usize {
        self.extents.iter().map(|e| e.len as usize).sum()
    }

    /// The backing segment (prefetch/residency bookkeeping).
    pub fn segment(&self) -> &Arc<Segment> {
        &self.seg
    }

    fn slice(&self, i: usize) -> BlobBytes {
        let e = self.extents[i];
        // open() proved these conversions and the summed range fit — spell
        // them out so a 32-bit build cannot silently wrap here either
        let off = usize::try_from(e.off).expect("validated on open");
        let len = usize::try_from(e.len).expect("validated on open");
        BlobBytes::new(self.seg.clone(), self.payload_base + off, len)
    }

    /// Match the operator's traversal-order `(level, len)` blob shapes
    /// one-to-one against the file's extents: `result[i]` is the extent
    /// index of traversal blob `i`. Errors on any count/level/size mismatch
    /// (= the operator was not built identically to the packed one).
    fn match_extents(&self, sizes: &[(u32, usize)]) -> Result<Vec<usize>, String> {
        if sizes.len() != self.extents.len() {
            return Err(format!("operator/store mismatch: {} blobs vs {} extents", sizes.len(), self.extents.len()));
        }
        // the file was written in traversal order stably sorted by level —
        // replay the same stable argsort to line the two up
        let mut order: Vec<usize> = (0..sizes.len()).collect();
        order.sort_by_key(|&i| sizes[i].0);
        let mut pos = vec![0usize; sizes.len()];
        for (k, &i) in order.iter().enumerate() {
            let (level, len) = sizes[i];
            let e = &self.extents[k];
            if e.level != level || e.len as usize != len {
                return Err(format!("operator/store mismatch at extent {k}: file (level {}, {} bytes) vs operator (level {level}, {len} bytes)", e.level, e.len));
            }
            pos[i] = k;
        }
        Ok(pos)
    }
}

/// Write `items` (traversal order `(level, payload)` pairs) as an `HMPK`
/// file at `path`.
fn write_pack(path: &str, items: &[(u32, BlobBytes)]) -> Result<PackSummary, String> {
    let mut order: Vec<usize> = (0..items.len()).collect();
    order.sort_by_key(|&i| items[i].0);
    let mut header = Vec::with_capacity(FIXED_HEADER + items.len() * EXTENT_BYTES + 8);
    header.extend_from_slice(MAGIC);
    header.extend_from_slice(&PACK_VERSION.to_le_bytes());
    header.extend_from_slice(&(items.len() as u64).to_le_bytes());
    let payload_len: usize = items.iter().map(|(_, b)| b.len()).sum();
    header.extend_from_slice(&(payload_len as u64).to_le_bytes());
    let mut off = 0u64;
    for &i in &order {
        let (level, bytes) = &items[i];
        header.extend_from_slice(&level.to_le_bytes());
        header.extend_from_slice(&off.to_le_bytes());
        header.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
        header.extend_from_slice(&fnv1a(bytes).to_le_bytes());
        off += bytes.len() as u64;
    }
    header.extend_from_slice(&fnv1a(&header).to_le_bytes());

    let file = std::fs::File::create(path).map_err(|e| format!("{path}: {e}"))?;
    let mut w = std::io::BufWriter::new(file);
    w.write_all(&header).map_err(|e| format!("{path}: {e}"))?;
    for &i in &order {
        w.write_all(&items[i].1).map_err(|e| format!("{path}: {e}"))?;
    }
    w.flush().map_err(|e| format!("{path}: {e}"))?;
    Ok(PackSummary { extents: items.len(), payload_bytes: payload_len, file_bytes: header.len() + payload_len })
}

// ---------------------------------------------------------------------------
// Structure walkers (fixed deterministic order, shared by pack and attach)
// ---------------------------------------------------------------------------

fn walk_h(m: &HMatrix, f: &mut dyn FnMut(u32, &Blob)) {
    for (id, data) in m.blocks.iter().enumerate() {
        if let Some(data) = data {
            let level = m.bt.node(id).level as u32;
            data.for_each_blob(&mut |b| f(level, b));
        }
    }
}

fn walk_h_mut(m: &mut HMatrix, f: &mut dyn FnMut(&mut Blob)) {
    for data in m.blocks.iter_mut().flatten() {
        data.for_each_blob_mut(f);
    }
}

fn walk_uh(m: &UniformHMatrix, f: &mut dyn FnMut(u32, &Blob)) {
    for (c, cb) in m.row_basis.iter().enumerate() {
        let level = m.bt.row_ct.node(c).level as u32;
        cb.data.for_each_blob(&mut |b| f(level, b));
    }
    for (c, cb) in m.col_basis.iter().enumerate() {
        let level = m.bt.col_ct.node(c).level as u32;
        cb.data.for_each_blob(&mut |b| f(level, b));
    }
    for (id, data) in m.blocks.iter().enumerate() {
        if let Some(data) = data {
            let level = m.bt.node(id).level as u32;
            data.for_each_blob(&mut |b| f(level, b));
        }
    }
}

fn walk_uh_mut(m: &mut UniformHMatrix, f: &mut dyn FnMut(&mut Blob)) {
    for cb in m.row_basis.iter_mut().chain(m.col_basis.iter_mut()) {
        cb.data.for_each_blob_mut(f);
    }
    for data in m.blocks.iter_mut().flatten() {
        data.for_each_blob_mut(f);
    }
}

fn walk_h2(m: &H2Matrix, f: &mut dyn FnMut(u32, &Blob)) {
    for (basis, ct) in [(&m.row_basis, &m.bt.row_ct), (&m.col_basis, &m.bt.col_ct)] {
        for (c, leaf) in basis.leaf.iter().enumerate() {
            if let Some(bd) = leaf {
                let level = ct.node(c).level as u32;
                bd.for_each_blob(&mut |b| f(level, b));
            }
        }
        for (c, tr) in basis.transfer.iter().enumerate() {
            if let Some(t) = tr {
                let level = ct.node(c).level as u32;
                t.for_each_blob(&mut |b| f(level, b));
            }
        }
    }
    for (id, data) in m.blocks.iter().enumerate() {
        if let Some(data) = data {
            let level = m.bt.node(id).level as u32;
            data.for_each_blob(&mut |b| f(level, b));
        }
    }
}

fn walk_h2_mut(m: &mut H2Matrix, f: &mut dyn FnMut(&mut Blob)) {
    for basis in [&mut m.row_basis, &mut m.col_basis] {
        for bd in basis.leaf.iter_mut().flatten() {
            bd.for_each_blob_mut(f);
        }
        for t in basis.transfer.iter_mut().flatten() {
            t.for_each_blob_mut(f);
        }
    }
    for data in m.blocks.iter_mut().flatten() {
        data.for_each_blob_mut(f);
    }
}

// ---------------------------------------------------------------------------
// pack / attach / residency per format
// ---------------------------------------------------------------------------

fn collect(walk: impl FnOnce(&mut dyn FnMut(u32, &Blob))) -> Vec<(u32, BlobBytes)> {
    let mut items = Vec::new();
    walk(&mut |level, b: &Blob| {
        if !b.bytes.is_empty() {
            items.push((level, b.bytes.clone()));
        }
    });
    items
}

/// First attach phase: the operator's traversal-order `(level, len)` shapes
/// (immutable walk), matched against the file's extents.
fn attach_match(store: &MappedStore, walk: impl FnOnce(&mut dyn FnMut(u32, &Blob))) -> Result<Vec<usize>, String> {
    let mut sizes = Vec::new();
    walk(&mut |level, b: &Blob| {
        if !b.bytes.is_empty() {
            sizes.push((level, b.bytes.len()));
        }
    });
    store.match_extents(&sizes)
}

/// Second attach phase: replay the same traversal mutably and re-point each
/// non-empty blob at its matched extent.
fn attach_repoint(store: &MappedStore, pos: &[usize], walk_mut: impl FnOnce(&mut dyn FnMut(&mut Blob))) {
    let mut i = 0;
    walk_mut(&mut |b: &mut Blob| {
        if !b.bytes.is_empty() {
            b.bytes = store.slice(pos[i]);
            i += 1;
        }
    });
    debug_assert_eq!(i, pos.len(), "mutable walk visited a different blob set");
}

fn residency(walk: impl FnOnce(&mut dyn FnMut(u32, &Blob)), hot: Option<&HotCache>) -> Residency {
    let mut scan = ResidencyScan::default();
    walk(&mut |_, b: &Blob| scan.add(b));
    scan.finish(hot)
}

/// Pack every compressed payload of `m` into an `HMPK` file at `path`.
pub fn pack_h(m: &HMatrix, path: &str) -> Result<PackSummary, String> {
    write_pack(path, &collect(|f| walk_h(m, f)))
}

pub fn pack_uh(m: &UniformHMatrix, path: &str) -> Result<PackSummary, String> {
    write_pack(path, &collect(|f| walk_uh(m, f)))
}

pub fn pack_h2(m: &H2Matrix, path: &str) -> Result<PackSummary, String> {
    write_pack(path, &collect(|f| walk_h2(m, f)))
}

/// Re-point every compressed payload of `m` (which must be built and
/// compressed identically to the packed operator) into the mapping.
pub fn attach_h(m: &mut HMatrix, store: &MappedStore) -> Result<(), String> {
    let pos = attach_match(store, |f| walk_h(m, f))?;
    attach_repoint(store, &pos, |f| walk_h_mut(m, f));
    Ok(())
}

pub fn attach_uh(m: &mut UniformHMatrix, store: &MappedStore) -> Result<(), String> {
    let pos = attach_match(store, |f| walk_uh(m, f))?;
    attach_repoint(store, &pos, |f| walk_uh_mut(m, f));
    Ok(())
}

pub fn attach_h2(m: &mut H2Matrix, store: &MappedStore) -> Result<(), String> {
    let pos = attach_match(store, |f| walk_h2(m, f))?;
    attach_repoint(store, &pos, |f| walk_h2_mut(m, f));
    Ok(())
}

/// Where `m`'s payload bytes live (pass the plan's hot cache to include
/// cache occupancy/hit counters).
pub fn residency_h(m: &HMatrix, hot: Option<&HotCache>) -> Residency {
    residency(|f| walk_h(m, f), hot)
}

pub fn residency_uh(m: &UniformHMatrix, hot: Option<&HotCache>) -> Residency {
    residency(|f| walk_uh(m, f), hot)
}

pub fn residency_h2(m: &H2Matrix, hot: Option<&HotCache>) -> Residency {
    residency(|f| walk_h2(m, f), hot)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> String {
        std::env::temp_dir().join(format!("hmatc_pack_{}_{name}", std::process::id())).to_str().unwrap().to_string()
    }

    #[test]
    fn empty_pack_roundtrips() {
        let path = tmp("empty.hmpk");
        let sum = write_pack(&path, &[]).unwrap();
        assert_eq!(sum.extents, 0);
        let store = MappedStore::open(&path).unwrap();
        assert_eq!(store.extents(), 0);
        assert!(store.match_extents(&[]).unwrap().is_empty());
        assert!(store.match_extents(&[(0, 4)]).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn extents_sorted_by_level_and_matched_back() {
        let path = tmp("sorted.hmpk");
        let items: Vec<(u32, BlobBytes)> = vec![
            (2, vec![1u8, 2, 3].into()),
            (0, vec![4u8; 5].into()),
            (1, vec![6u8; 2].into()),
            (0, vec![7u8; 4].into()),
        ];
        write_pack(&path, &items).unwrap();
        let store = MappedStore::open(&path).unwrap();
        let levels: Vec<u32> = store.extents.iter().map(|e| e.level).collect();
        assert_eq!(levels, vec![0, 0, 1, 2], "level-major layout");
        // traversal order (level, len) maps back to the right extents
        let pos = store.match_extents(&[(2, 3), (0, 5), (1, 2), (0, 4)]).unwrap();
        for (i, (level, bytes)) in items.iter().enumerate() {
            let s = store.slice(pos[i]);
            assert_eq!(&s[..], &bytes[..], "item {i}");
            assert_eq!(store.extents[pos[i]].level, *level);
        }
        // wrong shape → error, not UB
        assert!(store.match_extents(&[(2, 3), (0, 5), (1, 2), (1, 4)]).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn hostile_files_rejected() {
        let path = tmp("hostile.hmpk");
        let items: Vec<(u32, BlobBytes)> = vec![(0, vec![9u8; 64].into()), (1, vec![3u8; 32].into())];
        write_pack(&path, &items).unwrap();
        let good = std::fs::read(&path).unwrap();
        MappedStore::open(&path).unwrap();

        // truncated payload
        std::fs::write(&path, &good[..good.len() - 10]).unwrap();
        let err = MappedStore::open(&path).unwrap_err();
        assert!(err.contains("truncated"), "{err}");

        // truncated mid-header
        std::fs::write(&path, &good[..10]).unwrap();
        assert!(MappedStore::open(&path).is_err());

        // corrupted payload byte
        let mut bad = good.clone();
        *bad.last_mut().unwrap() ^= 0xff;
        std::fs::write(&path, &bad).unwrap();
        let err = MappedStore::open(&path).unwrap_err();
        assert!(err.contains("checksum"), "{err}");

        // version bump (header checksum fixed up to isolate the version check)
        let mut bad = good.clone();
        bad[4] = 99;
        std::fs::write(&path, &bad).unwrap();
        let err = MappedStore::open(&path).unwrap_err();
        assert!(err.contains("version"), "{err}");

        // bad magic
        let mut bad = good.clone();
        bad[0] = b'X';
        std::fs::write(&path, &bad).unwrap();
        let err = MappedStore::open(&path).unwrap_err();
        assert!(err.contains("magic"), "{err}");

        // corrupted extent metadata → header checksum catches it
        let mut bad = good;
        bad[FIXED_HEADER + 4] ^= 0x01; // extent 0 offset
        std::fs::write(&path, &bad).unwrap();
        let err = MappedStore::open(&path).unwrap_err();
        assert!(err.contains("checksum"), "{err}");

        std::fs::remove_file(&path).ok();
    }

    /// Hand-build an `HMPK` file with a VALID header checksum but hostile
    /// field values — the corruption tests above can't reach the arithmetic
    /// checks, because the checksum rejects tampered headers first.
    fn forge(n_extents: u64, payload_len: u64, extents: &[(u32, u64, u64, u64)], payload: &[u8]) -> Vec<u8> {
        let mut h = Vec::new();
        h.extend_from_slice(MAGIC);
        h.extend_from_slice(&PACK_VERSION.to_le_bytes());
        h.extend_from_slice(&n_extents.to_le_bytes());
        h.extend_from_slice(&payload_len.to_le_bytes());
        for &(level, off, len, sum) in extents {
            h.extend_from_slice(&level.to_le_bytes());
            h.extend_from_slice(&off.to_le_bytes());
            h.extend_from_slice(&len.to_le_bytes());
            h.extend_from_slice(&sum.to_le_bytes());
        }
        h.extend_from_slice(&fnv1a(&h).to_le_bytes());
        h.extend_from_slice(payload);
        h
    }

    #[test]
    fn hostile_wraparound_offsets_rejected() {
        let path = tmp("wraparound.hmpk");

        // extent count near u64::MAX: n * EXTENT_BYTES must not wrap into a
        // small header_len that happens to match the file size
        std::fs::write(&path, forge(u64::MAX, 0, &[], &[])).unwrap();
        let err = MappedStore::open(&path).unwrap_err();
        assert!(err.contains("overflow"), "{err}");

        // payload_len = u64::MAX: header_len + payload_len must not wrap
        std::fs::write(&path, forge(0, u64::MAX, &[], &[])).unwrap();
        let err = MappedStore::open(&path).unwrap_err();
        assert!(err.contains("overflow"), "{err}");

        // extent off + len wraps past u64::MAX: with narrowing arithmetic the
        // wrapped end passes `end <= payload_len` and the slice reads out of
        // bounds; the checked math must reject it instead
        let payload = [7u8; 8];
        std::fs::write(&path, forge(1, 8, &[(0, u64::MAX - 3, 8, fnv1a(&payload))], &payload)).unwrap();
        let err = MappedStore::open(&path).unwrap_err();
        assert!(err.contains("overflow") || err.contains("range"), "{err}");

        // in-range arithmetic but the extent pokes past the payload
        std::fs::write(&path, forge(1, 8, &[(0, 4, 8, fnv1a(&payload))], &payload)).unwrap();
        let err = MappedStore::open(&path).unwrap_err();
        assert!(err.contains("outside payload"), "{err}");

        std::fs::remove_file(&path).ok();
    }
}
