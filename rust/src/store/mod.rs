//! The storage tier: compressed blob bytes behind reference-counted
//! segments, packed operator files served by mmap, a level-pipelined
//! prefetcher and a decode-once hot-panel cache.
//!
//! The paper's argument is that FPX/AFLP compression relieves the memory-
//! bandwidth pressure of H-MVM; the production conclusion is to stop
//! requiring the compressed operator to be *resident* at all. This module
//! turns [`crate::compress`] into a storage tier:
//!
//! * **[`Segment`] / [`BlobBytes`]** — every [`crate::compress::Blob`]'s
//!   payload lives in a reference-counted segment: an anonymous heap buffer
//!   (today's default — one private segment per blob, exactly the old
//!   `Vec<u8>` behavior) or a slice of one read-only file mapping shared by
//!   every blob of an operator. [`crate::compress::DecodeCursor`] resolves
//!   straight off the mapped bytes — zero copies, no decode-side branching.
//! * **[`pack`]** — the versioned `HMPK` on-disk layout written by
//!   `hmatc pack`: header + per-level extents ordered level-major (the
//!   plan's task order, so level-pipelined prefetch is sequential I/O),
//!   each extent FNV-1a checksummed. [`MappedStore::open`] validates
//!   magic/version/bounds/checksums with errors — truncated or corrupted
//!   files are rejected, never UB — and `attach_*` re-points an identically
//!   built operator's blobs into the mapping.
//! * **[`prefetch`]** — at each level barrier the plan executors hand the
//!   *next* level's merged extents to a background thread that issues
//!   `madvise(WILLNEED)` plus touch reads, hiding page-in behind the level
//!   currently computing (`HMATC_PREFETCH=0` disables).
//! * **[`hot`]** — a bounded decode-once cache of fully decoded blobs
//!   (second-chance/clock eviction, budget via `HMATC_CACHE_BYTES`): the
//!   hottest small blocks skip decode entirely on repeated serves, while
//!   outputs stay bitwise identical to the streaming-decode path.
//!
//! # Safety contract for mapped segments
//!
//! A [`Segment::Mapped`] region is created from a read-only private file
//! mapping (`PROT_READ`, `MAP_PRIVATE`) and unmapped when the last
//! [`Arc<Segment>`] drops; [`BlobBytes`] hands out `&[u8]` borrows whose
//! lifetime is tied to that `Arc`, so a mapped slice can never outlive its
//! mapping. What Rust cannot guarantee is the *file*: if the packed file is
//! truncated or rewritten while mapped, loads may fault (`SIGBUS`) — the
//! store treats packed files as immutable once written, and `open` verifies
//! every extent checksum up front so post-open corruption of the on-disk
//! bytes is the only remaining window. Decode kernels make **no alignment
//! assumption** on backing bytes: every load is an unaligned byte-copy or an
//! explicitly unaligned SIMD load, pinned by the misaligned-backing
//! regression test in `tests/store_roundtrip.rs`.

pub mod hot;
pub mod pack;
pub mod prefetch;

pub use hot::HotCache;
pub use pack::{attach_h, attach_h2, attach_uh, pack_h, pack_h2, pack_uh, residency_h, residency_h2, residency_uh, MappedStore, PackSummary};
pub use prefetch::PrefetchPlan;

use crate::compress::Blob;
use std::collections::BTreeSet;
use std::ops::{Deref, Range};
use std::sync::Arc;

// ---------------------------------------------------------------------------
// Segments
// ---------------------------------------------------------------------------

/// A read-only mapping of a whole file, unmapped on drop.
pub struct MappedRegion {
    ptr: *const u8,
    len: usize,
}

// SAFETY: the region is immutable after construction (PROT_READ) and the
// pointer is only dereferenced through `as_slice`, so shared access from any
// thread is sound.
unsafe impl Send for MappedRegion {}
unsafe impl Sync for MappedRegion {}

impl MappedRegion {
    fn as_slice(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: ptr..ptr+len is a live PROT_READ mapping owned by self.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl Drop for MappedRegion {
    fn drop(&mut self) {
        #[cfg(unix)]
        if self.len > 0 {
            // SAFETY: exactly the region mmap returned; mapped once, unmapped once.
            unsafe { sys::munmap(self.ptr as *mut std::ffi::c_void, self.len) };
        }
    }
}

#[cfg(unix)]
mod sys {
    use std::ffi::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
    pub const MADV_WILLNEED: c_int = 3;

    extern "C" {
        pub fn mmap(addr: *mut c_void, len: usize, prot: c_int, flags: c_int, fd: c_int, offset: i64) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
    }
}

/// One reference-counted byte store backing any number of [`BlobBytes`]
/// slices: anonymous heap memory (the default — private per blob) or a
/// read-only file mapping shared by every blob of a packed operator.
pub enum Segment {
    /// Heap-backed bytes (today's in-memory behavior).
    Anon(Vec<u8>),
    /// A read-only private file mapping (see the module safety contract).
    Mapped(MappedRegion),
}

impl Segment {
    /// The segment's bytes.
    pub fn as_slice(&self) -> &[u8] {
        match self {
            Segment::Anon(v) => v,
            Segment::Mapped(m) => m.as_slice(),
        }
    }

    /// Whether the segment is a file mapping (vs anonymous memory).
    pub fn is_mapped(&self) -> bool {
        matches!(self, Segment::Mapped(_))
    }

    /// Map `path` read-only. On unix this is a real `mmap(PROT_READ,
    /// MAP_PRIVATE)`; elsewhere the file is read into anonymous memory (same
    /// semantics, no out-of-core benefit). Empty files map to an empty anon
    /// segment.
    pub fn map_file(path: &str) -> Result<Segment, String> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let file = std::fs::File::open(path).map_err(|e| format!("{path}: {e}"))?;
            let len = file.metadata().map_err(|e| format!("{path}: {e}"))?.len() as usize;
            if len == 0 {
                return Ok(Segment::Anon(Vec::new()));
            }
            // SAFETY: fd is open for the duration of the call; a MAP_FAILED
            // return is checked below. The mapping outlives the fd by design
            // (POSIX keeps mappings valid after close).
            let ptr = unsafe { sys::mmap(std::ptr::null_mut(), len, sys::PROT_READ, sys::MAP_PRIVATE, file.as_raw_fd(), 0) };
            if ptr as isize == -1 || ptr.is_null() {
                return Err(format!("{path}: mmap failed"));
            }
            Ok(Segment::Mapped(MappedRegion { ptr: ptr as *const u8, len }))
        }
        #[cfg(not(unix))]
        {
            let bytes = std::fs::read(path).map_err(|e| format!("{path}: {e}"))?;
            Ok(Segment::Anon(bytes))
        }
    }

    /// Prefer NUMA node `node` for pages of this segment that are not yet
    /// resident (`mbind(MPOL_PREFERRED)` via [`crate::par::topology`]).
    /// Advisory and best-effort: already-faulted pages stay put, failures
    /// return `false`, and byte contents are never affected. Only sensible
    /// for a segment consumed by a single node-pinned shard — a `MappedStore`
    /// shared by several shards must NOT be bound to any one node.
    pub fn bind_to_node(&self, node: usize) -> bool {
        let s = self.as_slice();
        if s.is_empty() {
            return false;
        }
        crate::par::topology::bind_region(s.as_ptr(), s.len(), node)
    }

    /// Hint the OS that `range` will be read soon, then touch one byte per
    /// page so the readahead actually happens even where `madvise` is a
    /// no-op. Anonymous segments need neither.
    pub fn advise_willneed(&self, range: Range<usize>) {
        let Segment::Mapped(m) = self else {
            return;
        };
        let start = range.start.min(m.len);
        let end = range.end.min(m.len);
        if start >= end {
            return;
        }
        #[cfg(unix)]
        {
            // page-align downward; madvise is advisory, the result is ignored
            let astart = start & !4095;
            // SAFETY: [astart, end) lies inside the live mapping.
            unsafe { sys::madvise(m.ptr.add(astart) as *mut std::ffi::c_void, end - astart, sys::MADV_WILLNEED) };
        }
        let s = self.as_slice();
        let mut sum = 0u8;
        let mut i = start;
        while i < end {
            // SAFETY: i < end <= len; volatile keeps the touch from being
            // optimized out.
            sum ^= unsafe { std::ptr::read_volatile(s.as_ptr().add(i)) };
            i += 4096;
        }
        std::hint::black_box(sum);
    }
}

// ---------------------------------------------------------------------------
// BlobBytes
// ---------------------------------------------------------------------------

/// The payload bytes of one [`Blob`]: a `[u8]` slice of a reference-counted
/// [`Segment`]. Replaces the per-blob `Vec<u8>` so compressed payloads can
/// live in anonymous memory (default) or inside one shared file mapping;
/// consumers deref to `&[u8]` and never see the difference.
#[derive(Clone)]
pub struct BlobBytes {
    seg: Arc<Segment>,
    off: usize,
    len: usize,
}

impl BlobBytes {
    /// A slice `[off, off + len)` of `seg` (bounds checked once here).
    pub fn new(seg: Arc<Segment>, off: usize, len: usize) -> BlobBytes {
        assert!(off.checked_add(len).is_some_and(|end| end <= seg.as_slice().len()), "BlobBytes: {off}+{len} out of segment ({} bytes)", seg.as_slice().len());
        BlobBytes { seg, off, len }
    }

    /// Whether the backing segment is a file mapping.
    pub fn is_mapped(&self) -> bool {
        self.seg.is_mapped()
    }

    /// The backing segment and the slice's byte range within it (prefetch
    /// extent collection).
    pub fn extent(&self) -> (&Arc<Segment>, Range<usize>) {
        (&self.seg, self.off..self.off + self.len)
    }

    /// Identity of the backing slice — `(segment address, offset)` — used as
    /// the hot-cache key. Stable for the blob's lifetime; cache entries pin
    /// the segment `Arc` so the address cannot be recycled while the entry
    /// lives.
    pub fn key(&self) -> (usize, usize) {
        (Arc::as_ptr(&self.seg) as *const u8 as usize, self.off)
    }

    /// The backing segment (shared-segment accounting).
    pub fn segment(&self) -> &Arc<Segment> {
        &self.seg
    }
}

impl Deref for BlobBytes {
    type Target = [u8];

    #[inline]
    fn deref(&self) -> &[u8] {
        // SAFETY of indexing: bounds were checked at construction and
        // segments are immutable.
        &self.seg.as_slice()[self.off..self.off + self.len]
    }
}

impl From<Vec<u8>> for BlobBytes {
    fn from(v: Vec<u8>) -> BlobBytes {
        let len = v.len();
        BlobBytes { seg: Arc::new(Segment::Anon(v)), off: 0, len }
    }
}

impl Default for BlobBytes {
    fn default() -> BlobBytes {
        Vec::new().into()
    }
}

impl std::fmt::Debug for BlobBytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = if self.is_mapped() { "mapped" } else { "anon" };
        write!(f, "BlobBytes({} bytes, {kind})", self.len)
    }
}

// ---------------------------------------------------------------------------
// Checksums
// ---------------------------------------------------------------------------

/// FNV-1a 64-bit hash (the extent and header checksums of the pack format:
/// no crates, deterministic, good enough to catch truncation/corruption).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Residency
// ---------------------------------------------------------------------------

/// Where an operator's compressed payload bytes live, plus hot-cache
/// occupancy/counters — the store line of `hmatc info`/`serve` and the
/// coordinator metrics.
#[derive(Clone, Debug, Default)]
pub struct Residency {
    /// Distinct backing segments over all blobs.
    pub segments: usize,
    /// Payload bytes resolved from anonymous (heap) segments.
    pub anon_bytes: usize,
    /// Payload bytes resolved from file mappings.
    pub mapped_bytes: usize,
    /// Hot-cache budget in bytes (0 = cache off).
    pub hot_capacity: usize,
    /// Decoded bytes currently resident in the hot cache.
    pub hot_bytes: usize,
    /// Hot-cache entries.
    pub hot_entries: usize,
    /// Hot-cache lookup hits since creation.
    pub hot_hits: u64,
    /// Hot-cache lookup misses since creation.
    pub hot_misses: u64,
}

impl Residency {
    /// Hit fraction of all hot-cache lookups so far (0.0 when none).
    pub fn hot_hit_rate(&self) -> f64 {
        let total = self.hot_hits + self.hot_misses;
        if total == 0 {
            0.0
        } else {
            self.hot_hits as f64 / total as f64
        }
    }

    /// One-line summary for log/banner lines, e.g.
    /// `store 12 segs (anon 1.2 MB, mapped 3.4 MB), hot cache 64.0 KB/1.0 MB (hit 98.2%)`.
    pub fn label(&self) -> String {
        let mb = |b: usize| b as f64 / (1024.0 * 1024.0);
        let mut s = format!("store {} segs (anon {:.2} MB, mapped {:.2} MB)", self.segments, mb(self.anon_bytes), mb(self.mapped_bytes));
        if self.hot_capacity > 0 {
            s += &format!(", hot cache {:.2}/{:.2} MB (hit {:.1}%)", mb(self.hot_bytes), mb(self.hot_capacity), 100.0 * self.hot_hit_rate());
        } else {
            s += ", hot cache off";
        }
        s
    }
}

/// Accumulates [`Residency`] over a blob walk (segments deduplicated by
/// address; cache fields filled in by [`ResidencyScan::finish`]).
#[derive(Default)]
pub struct ResidencyScan {
    seen: BTreeSet<usize>,
    out: Residency,
}

impl ResidencyScan {
    pub fn add(&mut self, blob: &Blob) {
        let (seg, range) = blob.bytes.extent();
        if self.seen.insert(Arc::as_ptr(seg) as *const u8 as usize) {
            self.out.segments += 1;
        }
        if seg.is_mapped() {
            self.out.mapped_bytes += range.len();
        } else {
            self.out.anon_bytes += range.len();
        }
    }

    pub fn finish(mut self, hot: Option<&HotCache>) -> Residency {
        if let Some(c) = hot {
            let (entries, bytes, hits, misses) = c.stats();
            self.out.hot_capacity = c.capacity();
            self.out.hot_entries = entries;
            self.out.hot_bytes = bytes;
            self.out.hot_hits = hits;
            self.out.hot_misses = misses;
        }
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_known_vectors() {
        // standard FNV-1a 64 test vectors
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn blob_bytes_roundtrip_and_sharing() {
        let b: BlobBytes = vec![1u8, 2, 3, 4].into();
        assert_eq!(&b[..], &[1, 2, 3, 4]);
        assert!(!b.is_mapped());
        let seg = Arc::new(Segment::Anon(vec![9u8; 100]));
        let s1 = BlobBytes::new(seg.clone(), 10, 20);
        let s2 = BlobBytes::new(seg.clone(), 30, 5);
        assert_eq!(s1.len(), 20);
        assert_eq!(s2.len(), 5);
        assert_ne!(s1.key(), s2.key());
        assert_eq!(s1.key().0, s2.key().0); // same segment
    }

    #[test]
    #[should_panic(expected = "out of segment")]
    fn blob_bytes_rejects_out_of_bounds() {
        let seg = Arc::new(Segment::Anon(vec![0u8; 8]));
        let _ = BlobBytes::new(seg, 4, 8);
    }

    #[test]
    fn map_file_roundtrip() {
        let path = std::env::temp_dir().join(format!("hmatc_seg_{}.bin", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        let seg = Segment::map_file(&path).unwrap();
        assert_eq!(seg.as_slice(), &data[..]);
        seg.advise_willneed(0..data.len()); // exercise the hint path
        seg.advise_willneed(9_000..20_000); // clamped past the end
        drop(seg);
        std::fs::remove_file(&path).ok();
        assert!(Segment::map_file("/nonexistent/hmatc.bin").is_err());
    }
}
