//! The decode-once hot-panel cache: a bounded, shared cache of fully
//! decoded blobs with second-chance (clock) eviction.
//!
//! [`crate::compress::DecodeCursor::new`] consults the cache installed for
//! the current task scope ([`scope`]); on a hit the cursor serves decoded
//! values straight from the cached panel through kernels that reproduce the
//! fused decode kernels' operation order **bitwise** (see
//! `compress::dispatch`), so caching is purely a speed knob. The budget
//! comes per plan (`PlannedOperator::set_hot_cache`) or from
//! `HMATC_CACHE_BYTES` at plan build; `0`/unset means off.
//!
//! Entries are keyed by `(segment address, offset)` and each entry pins its
//! backing [`Segment`] `Arc`, so a recycled allocation at the same address
//! can never alias a stale entry. Zero-codec blobs (no payload) are never
//! cached.

use super::Segment;
use crate::compress::{Blob, CodecParams};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

struct Entry {
    key: (usize, usize),
    /// Pins the backing segment so `key.0` cannot be recycled while the
    /// entry lives.
    _seg: Arc<Segment>,
    vals: Arc<Vec<f64>>,
    bytes: usize,
    referenced: bool,
}

#[derive(Default)]
struct Inner {
    entries: Vec<Entry>,
    index: HashMap<(usize, usize), usize>,
    bytes: usize,
    hand: usize,
}

/// Bounded decode-once cache (see module docs).
pub struct HotCache {
    budget: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl HotCache {
    /// A cache bounded to `budget` decoded bytes (`budget == 0` is legal but
    /// caches nothing).
    pub fn new(budget: usize) -> Arc<HotCache> {
        Arc::new(HotCache { budget, inner: Mutex::new(Inner::default()), hits: AtomicU64::new(0), misses: AtomicU64::new(0) })
    }

    /// The cache configured by `HMATC_CACHE_BYTES` (unset, unparsable or 0
    /// → `None` = caching off).
    pub fn from_env() -> Option<Arc<HotCache>> {
        let budget: usize = std::env::var("HMATC_CACHE_BYTES").ok()?.trim().parse().ok()?;
        if budget == 0 {
            None
        } else {
            Some(HotCache::new(budget))
        }
    }

    /// Budget in decoded bytes.
    pub fn capacity(&self) -> usize {
        self.budget
    }

    /// `(entries, resident bytes, hits, misses)`.
    pub fn stats(&self) -> (usize, usize, u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.entries.len(), inner.bytes, self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Lifetime hit/miss counters.
    pub fn counters(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// The decoded panel for `blob` — cached, or decoded now and inserted
    /// (evicting second-chance victims until it fits). `None` when the blob
    /// is uncacheable: zero codec, empty, or larger than the whole budget
    /// (those stream-decode as usual).
    pub fn get_or_decode(&self, blob: &Blob) -> Option<Arc<Vec<f64>>> {
        if blob.n == 0 || matches!(blob.params, CodecParams::Zero) {
            return None;
        }
        let need = blob.n * 8;
        if need > self.budget {
            return None;
        }
        let key = blob.bytes.key();
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(&slot) = inner.index.get(&key) {
                inner.entries[slot].referenced = true;
                let vals = inner.entries[slot].vals.clone();
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Some(vals);
            }
        }
        // decode outside the lock: misses from other workers proceed in
        // parallel; a racing insert of the same key keeps the first entry
        self.misses.fetch_add(1, Ordering::Relaxed);
        let vals = Arc::new(blob.to_vec());
        let mut inner = self.inner.lock().unwrap();
        if let Some(&slot) = inner.index.get(&key) {
            return Some(inner.entries[slot].vals.clone());
        }
        while inner.bytes + need > self.budget && !inner.entries.is_empty() {
            // clock sweep: clear referenced bits until an unreferenced
            // victim comes under the hand
            let victim = loop {
                let h = inner.hand % inner.entries.len();
                if inner.entries[h].referenced {
                    inner.entries[h].referenced = false;
                    inner.hand = h + 1;
                } else {
                    break h;
                }
            };
            let gone = inner.entries.swap_remove(victim);
            inner.bytes -= gone.bytes;
            inner.index.remove(&gone.key);
            if victim < inner.entries.len() {
                let moved_key = inner.entries[victim].key;
                inner.index.insert(moved_key, victim);
            }
        }
        let slot = inner.entries.len();
        inner.entries.push(Entry { key, _seg: blob.bytes.segment().clone(), vals: vals.clone(), bytes: need, referenced: false });
        inner.index.insert(key, slot);
        inner.bytes += need;
        Some(vals)
    }
}

thread_local! {
    static CURRENT: RefCell<Option<Arc<HotCache>>> = const { RefCell::new(None) };
}

struct ScopeGuard(Option<Arc<HotCache>>);

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        CURRENT.with(|c| *c.borrow_mut() = self.0.take());
    }
}

/// Run `f` with `cache` installed as this thread's hot cache: every
/// [`crate::compress::DecodeCursor`] created inside consults it. The plan
/// executors wrap each task closure in a scope on the worker thread that
/// runs it. Restores the previous scope on exit (panic included).
pub fn scope<R>(cache: &Arc<HotCache>, f: impl FnOnce() -> R) -> R {
    let prev = CURRENT.with(|c| c.borrow_mut().replace(cache.clone()));
    let _guard = ScopeGuard(prev);
    f()
}

/// The current scope's cached panel for `blob`, if a cache is installed and
/// the blob is cacheable (`DecodeCursor::new`'s hook).
pub(crate) fn cached_decode(blob: &Blob) -> Option<Arc<Vec<f64>>> {
    CURRENT.with(|c| c.borrow().as_ref().map(Arc::clone)).and_then(|cache| cache.get_or_decode(blob))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::util::Rng;

    fn blob(n: usize, seed: u64) -> Blob {
        let mut rng = Rng::new(seed);
        let data: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        Blob::compress(Codec::Aflp, &data, 1e-8)
    }

    #[test]
    fn hit_after_miss_same_values() {
        let cache = HotCache::new(1 << 20);
        let b = blob(100, 1);
        let v1 = cache.get_or_decode(&b).unwrap();
        let v2 = cache.get_or_decode(&b).unwrap();
        assert!(Arc::ptr_eq(&v1, &v2));
        assert_eq!(v1[..], b.to_vec()[..]);
        assert_eq!(cache.counters(), (1, 1));
    }

    #[test]
    fn zero_and_oversized_blobs_bypass() {
        let cache = HotCache::new(400); // 50 values
        let z = Blob::compress(Codec::Fpx, &[0.0; 32], 1e-6);
        assert!(cache.get_or_decode(&z).is_none());
        let big = blob(51, 2);
        assert!(cache.get_or_decode(&big).is_none());
        assert_eq!(cache.stats().0, 0);
    }

    #[test]
    fn eviction_keeps_budget_and_recently_used() {
        let cache = HotCache::new(100 * 8); // room for ~2 of the 3
        let blobs: Vec<Blob> = (0..3).map(|i| blob(40, 10 + i)).collect();
        for b in &blobs {
            cache.get_or_decode(b);
        }
        let (entries, bytes, _, _) = cache.stats();
        assert!(bytes <= 100 * 8, "bytes {bytes}");
        assert!(entries <= 2);
        // hammer blob 2, then insert blob 0 again: 2 must survive the sweep
        for _ in 0..3 {
            cache.get_or_decode(&blobs[2]);
        }
        cache.get_or_decode(&blobs[0]);
        let v = cache.get_or_decode(&blobs[2]).unwrap();
        assert_eq!(v[..], blobs[2].to_vec()[..]);
    }

    #[test]
    fn scope_installs_and_restores() {
        let cache = HotCache::new(1 << 20);
        let b = blob(64, 7);
        assert!(cached_decode(&b).is_none(), "no scope installed");
        scope(&cache, || {
            assert!(cached_decode(&b).is_some());
            let inner = HotCache::new(1 << 20);
            scope(&inner, || {
                assert!(cached_decode(&b).is_some());
                assert_eq!(inner.counters().1, 1, "nested scope uses inner cache");
            });
        });
        assert!(cached_decode(&b).is_none(), "scope restored");
        assert_eq!(cache.counters(), (0, 1));
    }
}
