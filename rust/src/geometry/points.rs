//! 3D points and synthetic point-cloud generators (for covariance-matrix
//! examples and clustering tests).

use crate::util::Rng;

/// A point in R³.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Point3 {
    pub x: f64,
    pub y: f64,
    pub z: f64,
}

impl Point3 {
    pub fn new(x: f64, y: f64, z: f64) -> Self {
        Point3 { x, y, z }
    }

    pub fn zero() -> Self {
        Point3::new(0.0, 0.0, 0.0)
    }

    #[inline]
    pub fn add(self, o: Point3) -> Point3 {
        Point3::new(self.x + o.x, self.y + o.y, self.z + o.z)
    }

    #[inline]
    pub fn sub(self, o: Point3) -> Point3 {
        Point3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }

    #[inline]
    pub fn scale(self, a: f64) -> Point3 {
        Point3::new(a * self.x, a * self.y, a * self.z)
    }

    #[inline]
    pub fn dot(self, o: Point3) -> f64 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    #[inline]
    pub fn cross(self, o: Point3) -> Point3 {
        Point3::new(self.y * o.z - self.z * o.y, self.z * o.x - self.x * o.z, self.x * o.y - self.y * o.x)
    }

    #[inline]
    pub fn norm(self) -> f64 {
        self.dot(self).sqrt()
    }

    #[inline]
    pub fn dist(self, o: Point3) -> f64 {
        self.sub(o).norm()
    }

    /// Normalize to unit length.
    pub fn normalized(self) -> Point3 {
        let n = self.norm();
        if n == 0.0 {
            self
        } else {
            self.scale(1.0 / n)
        }
    }

    /// Coordinate by axis index 0/1/2.
    #[inline]
    pub fn coord(self, axis: usize) -> f64 {
        match axis {
            0 => self.x,
            1 => self.y,
            _ => self.z,
        }
    }
}

/// `n` points quasi-uniform on the unit sphere (Fibonacci lattice).
pub fn fibonacci_sphere(n: usize) -> Vec<Point3> {
    let golden = std::f64::consts::PI * (3.0 - 5.0f64.sqrt());
    (0..n)
        .map(|i| {
            let y = 1.0 - 2.0 * (i as f64 + 0.5) / n as f64;
            let r = (1.0 - y * y).max(0.0).sqrt();
            let th = golden * i as f64;
            Point3::new(r * th.cos(), y, r * th.sin())
        })
        .collect()
}

/// `n` points uniform in the unit cube.
pub fn random_cube(n: usize, rng: &mut Rng) -> Vec<Point3> {
    (0..n).map(|_| Point3::new(rng.uniform(), rng.uniform(), rng.uniform())).collect()
}

/// `n` points on the unit circle in the z=0 plane (1D geometry: produces
/// HODLR-friendly orderings).
pub fn circle_points(n: usize) -> Vec<Point3> {
    (0..n)
        .map(|i| {
            let t = std::f64::consts::TAU * i as f64 / n as f64;
            Point3::new(t.cos(), t.sin(), 0.0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_ops() {
        let a = Point3::new(1.0, 0.0, 0.0);
        let b = Point3::new(0.0, 1.0, 0.0);
        assert_eq!(a.cross(b), Point3::new(0.0, 0.0, 1.0));
        assert_eq!(a.dot(b), 0.0);
        assert!((a.dist(b) - std::f64::consts::SQRT_2).abs() < 1e-15);
    }

    #[test]
    fn fibonacci_on_sphere() {
        for p in fibonacci_sphere(100) {
            assert!((p.norm() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn circle_on_circle() {
        for p in circle_points(64) {
            assert!((p.norm() - 1.0).abs() < 1e-12);
            assert_eq!(p.z, 0.0);
        }
    }

    #[test]
    fn coord_axis() {
        let p = Point3::new(1.0, 2.0, 3.0);
        assert_eq!(p.coord(0), 1.0);
        assert_eq!(p.coord(1), 2.0);
        assert_eq!(p.coord(2), 3.0);
    }
}
