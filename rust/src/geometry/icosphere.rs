//! Recursively subdivided icosahedron ("icosphere") triangulation of the unit
//! sphere — the paper's model geometry Γ = S². Level d has 20·4^d triangles:
//! d = 3 → 1280, d = 4 → 5120, d = 5 → 20480, d = 6 → 81920.

use super::{Geometry, Point3};
use std::collections::HashMap;

/// Build an icosphere triangulation at subdivision level `level`.
pub fn icosphere(level: usize) -> Geometry {
    // Icosahedron vertices from the golden ratio construction.
    let phi = (1.0 + 5.0f64.sqrt()) / 2.0;
    let mut vertices: Vec<Point3> = vec![
        Point3::new(-1.0, phi, 0.0),
        Point3::new(1.0, phi, 0.0),
        Point3::new(-1.0, -phi, 0.0),
        Point3::new(1.0, -phi, 0.0),
        Point3::new(0.0, -1.0, phi),
        Point3::new(0.0, 1.0, phi),
        Point3::new(0.0, -1.0, -phi),
        Point3::new(0.0, 1.0, -phi),
        Point3::new(phi, 0.0, -1.0),
        Point3::new(phi, 0.0, 1.0),
        Point3::new(-phi, 0.0, -1.0),
        Point3::new(-phi, 0.0, 1.0),
    ]
    .into_iter()
    .map(|p| p.normalized())
    .collect();

    let mut triangles: Vec<[usize; 3]> = vec![
        [0, 11, 5],
        [0, 5, 1],
        [0, 1, 7],
        [0, 7, 10],
        [0, 10, 11],
        [1, 5, 9],
        [5, 11, 4],
        [11, 10, 2],
        [10, 7, 6],
        [7, 1, 8],
        [3, 9, 4],
        [3, 4, 2],
        [3, 2, 6],
        [3, 6, 8],
        [3, 8, 9],
        [4, 9, 5],
        [2, 4, 11],
        [6, 2, 10],
        [8, 6, 7],
        [9, 8, 1],
    ];

    for _ in 0..level {
        let mut midpoint_cache: HashMap<(usize, usize), usize> = HashMap::new();
        let mut next = Vec::with_capacity(triangles.len() * 4);
        let mut midpoint = |a: usize, b: usize, vertices: &mut Vec<Point3>| -> usize {
            let key = (a.min(b), a.max(b));
            *midpoint_cache.entry(key).or_insert_with(|| {
                let m = vertices[a].add(vertices[b]).scale(0.5).normalized();
                vertices.push(m);
                vertices.len() - 1
            })
        };
        for t in &triangles {
            let ab = midpoint(t[0], t[1], &mut vertices);
            let bc = midpoint(t[1], t[2], &mut vertices);
            let ca = midpoint(t[2], t[0], &mut vertices);
            next.push([t[0], ab, ca]);
            next.push([t[1], bc, ab]);
            next.push([t[2], ca, bc]);
            next.push([ab, bc, ca]);
        }
        triangles = next;
    }

    Geometry { vertices, triangles, centroids: vec![], areas: vec![] }.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triangle_counts() {
        assert_eq!(icosphere(0).len(), 20);
        assert_eq!(icosphere(1).len(), 80);
        assert_eq!(icosphere(3).len(), 1280);
    }

    #[test]
    fn euler_characteristic() {
        // V - E + F = 2 for a sphere; E = 3F/2 for a closed triangulation.
        let g = icosphere(2);
        let f = g.triangles.len();
        let v = g.vertices.len();
        let e = 3 * f / 2;
        assert_eq!(v as i64 - e as i64 + f as i64, 2);
    }

    #[test]
    fn area_approaches_sphere() {
        // total area → 4π as the triangulation refines
        let a2 = icosphere(2).total_area();
        let a4 = icosphere(4).total_area();
        let sphere = 4.0 * std::f64::consts::PI;
        assert!((a4 - sphere).abs() < (a2 - sphere).abs());
        assert!((a4 - sphere).abs() / sphere < 0.01, "area {a4} vs {sphere}");
    }

    #[test]
    fn vertices_on_sphere() {
        let g = icosphere(2);
        for v in &g.vertices {
            assert!((v.norm() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn centroids_and_areas_positive() {
        let g = icosphere(1);
        assert_eq!(g.centroids.len(), g.len());
        assert!(g.areas.iter().all(|&a| a > 0.0));
    }
}
