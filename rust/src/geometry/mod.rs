//! Geometry for the BEM model problem: icosphere triangulations and generic
//! point clouds.

mod icosphere;
mod points;

pub use icosphere::icosphere;
pub use points::{circle_points, fibonacci_sphere, random_cube, Point3};

/// A triangulated surface with per-triangle centroids and areas — the
/// discrete data the Galerkin matrix generator needs.
#[derive(Clone, Debug)]
pub struct Geometry {
    /// Vertex coordinates.
    pub vertices: Vec<Point3>,
    /// Triangles as vertex index triples.
    pub triangles: Vec<[usize; 3]>,
    /// Per-triangle centroid.
    pub centroids: Vec<Point3>,
    /// Per-triangle area.
    pub areas: Vec<f64>,
}

impl Geometry {
    /// Number of triangles (= degrees of freedom for piecewise-constant
    /// ansatz functions).
    pub fn len(&self) -> usize {
        self.triangles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.triangles.is_empty()
    }

    /// Total surface area.
    pub fn total_area(&self) -> f64 {
        self.areas.iter().sum()
    }

    /// Recompute centroids/areas from vertices+triangles.
    pub(crate) fn finalize(mut self) -> Self {
        self.centroids.clear();
        self.areas.clear();
        for t in &self.triangles {
            let (a, b, c) = (self.vertices[t[0]], self.vertices[t[1]], self.vertices[t[2]]);
            self.centroids.push(Point3::new((a.x + b.x + c.x) / 3.0, (a.y + b.y + c.y) / 3.0, (a.z + b.z + c.z) / 3.0));
            self.areas.push(triangle_area(a, b, c));
        }
        self
    }

    /// The three corner points of triangle `i`.
    pub fn corners(&self, i: usize) -> [Point3; 3] {
        let t = self.triangles[i];
        [self.vertices[t[0]], self.vertices[t[1]], self.vertices[t[2]]]
    }
}

/// Area of a 3D triangle.
pub fn triangle_area(a: Point3, b: Point3, c: Point3) -> f64 {
    let u = b.sub(a);
    let v = c.sub(a);
    u.cross(v).norm() * 0.5
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triangle_area_unit() {
        let a = Point3::new(0.0, 0.0, 0.0);
        let b = Point3::new(1.0, 0.0, 0.0);
        let c = Point3::new(0.0, 1.0, 0.0);
        assert!((triangle_area(a, b, c) - 0.5).abs() < 1e-15);
    }
}
