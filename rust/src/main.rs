//! hmatc CLI — build, compress, multiply and serve hierarchical matrices.
//!
//! ```text
//! hmatc info
//! hmatc build     --level 4 --eps 1e-6 [--fmt h|uh|h2] [--codec aflp|fpx] [--compress]
//! hmatc mvm       --level 4 --eps 1e-6 --fmt h2 --algo "row wise" [--compress --codec aflp]
//! hmatc pack      --level 4 --eps 1e-6 [--fmt h|uh|h2] [--compress] [--shards N] --out operator.hmpk
//! hmatc serve     --level 4 --eps 1e-6 --requests 256 --batch 8 [--fmt h|uh|h2] [--plan]
//!                 [--executor lpt|steal|sharded:K] [--compress] [--costs costs.json]
//!                 [--mmap operator.hmpk] [--shards N --queue-limit Q --shard-queue B]
//!                 [--online 1|key=value,…]
//! hmatc calibrate [--level 3 --eps 1e-6 --fmt h|uh|h2 --rounds 8] [--quick] [--out costs.json]
//! hmatc solve     --level 3 --eps 1e-6 [--compress]
//! hmatc shard-worker --listen 127.0.0.1:7451 [--pack operator.hmpk.shard0] [--exit-after-jobs N]
//!                 (same --level/--eps/--fmt/--compress/--codec flags as serve)
//! hmatc roofline
//! ```
//!
//! `--executor` (default: `HMATC_EXEC`, else `lpt`) picks the plan-execution
//! backend behind `--plan`: static LPT shards, work stealing, or K sharded
//! sub-pools. `calibrate` fits measured per-kernel-class cost coefficients
//! and writes a versioned profile JSON; `--costs` (or `HMATC_COSTS`) loads
//! one back and re-balances the plan schedules with it.
//!
//! `pack` writes every compressed payload into a checksummed `HMPK` file;
//! `serve --mmap` (same build/compress flags) re-points the operator's blobs
//! into the mapping — decode streams straight off the page cache, the plan
//! prefetches the next level's extents at each barrier, and
//! `HMATC_CACHE_BYTES` bounds a decode-once hot-panel cache.
//!
//! `serve --shards N` (or `HMATC_SHARDS=N`) serves through the scatter/gather
//! coordinator tier instead of the single worker: the operator is
//! row-partitioned into N shard plans (implies `--plan`), each with its own
//! executor, arena, and hot cache; `--queue-limit` bounds the pending backlog
//! (admission control, fail-fast rejections) and `--shard-queue` bounds each
//! shard's job queue (dispatcher backpressure). Served results are bitwise
//! identical to the unsharded plan. `pack --shards N` additionally writes N
//! byte-identical `<out>.shardI` replica files, one mapping per shard worker.
//!
//! `serve --online` (or `HMATC_ONLINE=1` / `key=value,…`) turns on the
//! adaptive serving loop (implies `--plan`): continuous per-class batching
//! with deadline-packed panel widths, live per-chunk timing, and a
//! sliding-window online calibrator that re-fits the cost model and swaps
//! re-balanced packings when predicted and measured makespans drift apart
//! (`cost_source` becomes `online`). Served bits are identical to the static
//! loop; composes with `--shards N`.
//!
//! `serve --remote host:port,…` moves the shard workers out of the process:
//! each address is one `hmatc shard-worker` serving its row shard over TCP,
//! couriers carry the scatter/gather frames with heartbeats and
//! capped-backoff reconnects (`--connect-timeout-ms --net-timeout-ms
//! --heartbeat-ms --backoff-ms --backoff-max-ms --net-retries --pipeline`),
//! and after the load a reference request is checked bit-for-bit against the
//! local operator (`remote bitwise ok`). Workers rebuild the same operator
//! from the same flags and may map a `pack --shards N` replica via `--pack`.

use hmatc::bench::{bench_fn, measure_peak_bandwidth};
use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::compress::{Codec, CompressionConfig};
use hmatc::coordinator::{BatchPolicy, MvmServer, OnlineConfig, RemoteConfig};
use hmatc::geometry::icosphere;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::lowrank::AcaOptions;
use hmatc::mvm::{H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
use hmatc::plan::costmodel::CostProfile;
use hmatc::plan::{ExecutorKind, HOperator, PlannedOperator};
use hmatc::solver::cg;
use hmatc::util::args::Args;
use hmatc::util::{fmt_bytes, fmt_secs, Rng, Timer};
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("info");
    match cmd {
        "info" => info(),
        "build" => build_cmd(&args),
        "mvm" => mvm_cmd(&args),
        "pack" => pack_cmd(&args),
        "serve" => serve_cmd(&args),
        "calibrate" => calibrate_cmd(&args),
        "solve" => solve_cmd(&args),
        "shard-worker" => shard_worker_cmd(&args),
        "roofline" => roofline_cmd(),
        other => {
            eprintln!("unknown command '{other}'. Commands: info build mvm pack serve calibrate solve shard-worker roofline");
            std::process::exit(2);
        }
    }
}

fn info() {
    println!("hmatc — compressed hierarchical matrix formats (H / UH / H²)");
    println!("threads: {}", hmatc::par::num_threads() + 1);
    println!("executor: {} (HMATC_EXEC=lpt|steal|sharded:K)", ExecutorKind::from_env());
    println!("topology: {} (HMATC_NUMA=0 disables discovery, HMATC_PIN=0 disables pinning)", hmatc::par::Topology::get().summary());
    println!("simd: {} (runtime dispatch; HMATC_SIMD=scalar forces the portable kernels)", hmatc::compress::dispatch::simd_name());
    // validated: a bad HMATC_COSTS file warns (via costs_from_env) and is
    // reported as the static fallback it actually is
    let costs = hmatc::plan::costmodel::source_label(hmatc::plan::costmodel::costs_from_env().as_ref());
    if costs == "static" {
        println!("costs: static (set HMATC_COSTS=costs.json or pass --costs; fit one with `hmatc calibrate`)");
    } else {
        println!("costs: {costs} (HMATC_COSTS)");
    }
    println!("codec kernels: {} (HMATC_CODEC_KERNELS=fused|blockwise)", hmatc::compress::dispatch::kernel_mode_name());
    // validated the same way serve will: a bad HMATC_ONLINE warns and is off
    match hmatc::coordinator::OnlineConfig::from_env() {
        Some(c) => println!("online adaptation: on ({}) (HMATC_ONLINE)", c.describe()),
        None => println!("online adaptation: off (set HMATC_ONLINE=1 or window=…,min=…,drift=…,hysteresis=…,deadline_us=…,panel=…)"),
    }
    // store tier: residency is per-operator (printed by `serve`); here we
    // report how the environment will configure it
    match hmatc::store::HotCache::from_env() {
        Some(c) => println!("store: hot cache {} budget (HMATC_CACHE_BYTES), prefetch {}", fmt_bytes(c.capacity()), if hmatc::store::prefetch::enabled() { "on" } else { "off (HMATC_PREFETCH=0)" }),
        None => println!("store: hot cache off (set HMATC_CACHE_BYTES to enable), prefetch {}", if hmatc::store::prefetch::enabled() { "on" } else { "off (HMATC_PREFETCH=0)" }),
    }
    #[cfg(feature = "pjrt")]
    {
        match hmatc::runtime::PjrtEngine::new(hmatc::runtime::DEFAULT_ARTIFACTS_DIR) {
            Ok(e) => println!("pjrt: available ({})", e.platform()),
            Err(e) => println!("pjrt: unavailable ({e})"),
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("pjrt: disabled at build time");
}

struct Problem {
    gen: LaplaceSlp,
    bt: Arc<BlockTree>,
}

fn problem(args: &Args) -> Problem {
    problem_with_default_level(args, 3)
}

fn problem_with_default_level(args: &Args, default_level: usize) -> Problem {
    let level = args.num_or("level", default_level);
    let nmin = args.num_or("nmin", 64usize);
    let eta = args.num_or("eta", 2.0f64);
    let t = Timer::start();
    let geom = icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), nmin));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(eta)));
    println!("geometry: n = {} triangles (icosphere level {level}), setup {}", gen.len(), fmt_secs(t.elapsed()));
    Problem { gen, bt }
}

fn build_h(args: &Args, p: &Problem) -> HMatrix {
    let eps = args.num_or("eps", 1e-6f64);
    let t = Timer::start();
    let h = HMatrix::build(&p.bt, &p.gen, &AcaOptions::with_eps(eps));
    let st = h.stats();
    println!(
        "H-matrix: eps = {eps:.0e}, built in {}, {} ({:.1} B/dof), {} dense / {} low-rank blocks, avg rank {:.1}",
        fmt_secs(t.elapsed()),
        fmt_bytes(h.byte_size()),
        h.bytes_per_dof(),
        st.n_dense,
        st.n_lowrank,
        st.avg_rank()
    );
    h
}

fn cfg_from(args: &Args) -> CompressionConfig {
    let codec: Codec = args.str_or("codec", "aflp").parse().unwrap_or(Codec::Aflp);
    let eps = args.num_or("eps", 1e-6f64);
    CompressionConfig { codec, eps, valr: !args.flag("no-valr") }
}

fn build_cmd(args: &Args) {
    let p = problem(args);
    let h = build_h(args, &p);
    let eps = args.num_or("eps", 1e-6f64);
    let fmt = args.str_or("fmt", "h");
    let compress = args.flag("compress");
    let cfg = cfg_from(args);
    match fmt.as_str() {
        "h" => {
            let mut h = h;
            if compress {
                let t = Timer::start();
                h.compress(&cfg);
                println!("compressed ({}): {} ({:.1} B/dof) in {}", cfg.codec.name(), fmt_bytes(h.byte_size()), h.bytes_per_dof(), fmt_secs(t.elapsed()));
            }
        }
        "uh" => {
            let t = Timer::start();
            let mut uh = hmatc::uniform::build_from_h(&h, eps, hmatc::uniform::CouplingKind::Combined);
            println!("UH-matrix: built in {}, {} ({:.1} B/dof)", fmt_secs(t.elapsed()), fmt_bytes(uh.byte_size()), uh.bytes_per_dof());
            if compress {
                uh.compress(&cfg);
                println!("compressed ({}): {} ({:.1} B/dof)", cfg.codec.name(), fmt_bytes(uh.byte_size()), uh.bytes_per_dof());
            }
        }
        "h2" => {
            let t = Timer::start();
            let mut h2 = hmatc::h2::build_from_h(&h, eps);
            println!("H²-matrix: built in {}, {} ({:.1} B/dof)", fmt_secs(t.elapsed()), fmt_bytes(h2.byte_size()), h2.bytes_per_dof());
            if compress {
                h2.compress(&cfg);
                println!("compressed ({}): {} ({:.1} B/dof)", cfg.codec.name(), fmt_bytes(h2.byte_size()), h2.bytes_per_dof());
            }
        }
        other => {
            eprintln!("unknown format '{other}' (h|uh|h2)");
            std::process::exit(2);
        }
    }
}

fn mvm_cmd(args: &Args) {
    let p = problem(args);
    let h = build_h(args, &p);
    let eps = args.num_or("eps", 1e-6f64);
    let fmt = args.str_or("fmt", "h");
    let compress = args.flag("compress");
    let cfg = cfg_from(args);
    let n = h.nrows();
    let mut rng = Rng::new(7);
    let x = rng.vector(n);
    let mut y = vec![0.0; n];

    let report = |name: &str, bytes: usize, median: f64| {
        println!("mvm[{name}]: median {} | {:.2} GB/s effective", fmt_secs(median), bytes as f64 / median / 1e9);
    };

    match fmt.as_str() {
        "h" => {
            let mut h = h;
            if compress {
                h.compress(&cfg);
            }
            let algo_name = args.str_or("algo", "cluster lists");
            let algo = MvmAlgorithm::all().into_iter().find(|a| a.name() == algo_name).unwrap_or(MvmAlgorithm::ClusterLists);
            let r = bench_fn(2, 7, 0.05, || hmatc::mvm::mvm(1.0, &h, &x, &mut y, algo));
            report(algo.name(), h.byte_size(), r.median);
        }
        "uh" => {
            let mut uh = hmatc::uniform::build_from_h(&h, eps, hmatc::uniform::CouplingKind::Combined);
            if compress {
                uh.compress(&cfg);
            }
            let algo_name = args.str_or("algo", "row wise");
            let algo = UniMvmAlgorithm::all().into_iter().find(|a| a.name() == algo_name).unwrap_or(UniMvmAlgorithm::RowWise);
            let r = bench_fn(2, 7, 0.05, || hmatc::mvm::uniform_mvm(1.0, &uh, &x, &mut y, algo));
            report(algo.name(), uh.byte_size(), r.median);
        }
        "h2" => {
            let mut h2 = hmatc::h2::build_from_h(&h, eps);
            if compress {
                h2.compress(&cfg);
            }
            let algo_name = args.str_or("algo", "row wise");
            let algo = H2MvmAlgorithm::all().into_iter().find(|a| a.name() == algo_name).unwrap_or(H2MvmAlgorithm::RowWise);
            let r = bench_fn(2, 7, 0.05, || hmatc::mvm::h2_mvm(1.0, &h2, &x, &mut y, algo));
            report(algo.name(), h2.byte_size(), r.median);
        }
        other => {
            eprintln!("unknown format '{other}'");
            std::process::exit(2);
        }
    }
}

/// `hmatc pack`: build the model problem with the same flags `serve` uses,
/// then write every blob payload into one checksummed HMPK file that
/// `serve --mmap` (with identical flags) maps back in. Without `--compress`
/// there are no blob payloads and the pack is empty — legal, but pointless,
/// so we say so. `--shards N` additionally writes N byte-identical
/// `<out>.shardI` replicas so each shard worker of a sharded deployment can
/// map its own file (own inode, own page-cache stream).
fn pack_cmd(args: &Args) {
    let p = problem(args);
    let h = build_h(args, &p);
    let eps = args.num_or("eps", 1e-6f64);
    let fmt = args.str_or("fmt", "h");
    let compress = args.flag("compress");
    let cfg = cfg_from(args);
    let out = args.str_or("out", "operator.hmpk");
    let res = match fmt.as_str() {
        "h" => {
            let mut h = h;
            if compress {
                h.compress(&cfg);
            }
            hmatc::store::pack_h(&h, &out)
        }
        "uh" => {
            let mut uh = hmatc::uniform::build_from_h(&h, eps, hmatc::uniform::CouplingKind::Combined);
            if compress {
                uh.compress(&cfg);
            }
            hmatc::store::pack_uh(&uh, &out)
        }
        "h2" => {
            let mut h2 = hmatc::h2::build_from_h(&h, eps);
            if compress {
                h2.compress(&cfg);
            }
            hmatc::store::pack_h2(&h2, &out)
        }
        other => {
            eprintln!("unknown format '{other}' (h|uh|h2)");
            std::process::exit(2);
        }
    };
    match res {
        Ok(s) => {
            println!("packed {} extents, payload {}, file {} → {out}", s.extents, fmt_bytes(s.payload_bytes), fmt_bytes(s.file_bytes));
            let shards = args.num_or("shards", 1usize);
            if shards > 1 {
                for i in 0..shards {
                    let sp = format!("{out}.shard{i}");
                    if let Err(e) = std::fs::copy(&out, &sp) {
                        eprintln!("pack: cannot write shard replica {sp}: {e}");
                        std::process::exit(1);
                    }
                }
                println!("wrote {shards} shard replicas: {out}.shard0 … {out}.shard{}", shards - 1);
            }
            if s.extents == 0 {
                println!("note: no compressed payloads (pass --compress); the pack is valid but empty");
            } else {
                println!("serve it with: hmatc serve … --mmap {out} (same --level/--eps/--fmt/--compress/--codec flags)");
            }
        }
        Err(e) => {
            eprintln!("pack: cannot write {out}: {e}");
            std::process::exit(1);
        }
    }
}

fn serve_cmd(args: &Args) {
    let p = problem(args);
    let h = build_h(args, &p);
    let eps = args.num_or("eps", 1e-6f64);
    // any format serves through the HOperator trait; --plan puts the
    // precomputed zero-allocation schedule executor in front of it, and
    // --executor picks the backend the schedules run on
    let fmt = args.str_or("fmt", "h");
    // --shards N (default HMATC_SHARDS) serves through the scatter/gather
    // tier over a row partition of the operator; shard plans slice the
    // planned schedules, so it implies --plan
    let shards = args.num_or("shards", hmatc::plan::env_shard_count());
    // --online beats HMATC_ONLINE; adaptation times planned schedules, so it
    // implies --plan too
    let online: Option<OnlineConfig> = match args.get("online") {
        Some(v) => match OnlineConfig::parse(v) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("--online {v}: {e}");
                std::process::exit(2);
            }
        },
        None if args.flag("online") => Some(OnlineConfig::default()),
        None => OnlineConfig::from_env(),
    };
    // --remote addr,addr,… serves through out-of-process shard workers; the
    // courier tier replaces the in-process shard pool, so it excludes
    // --shards and (workers run static schedules) --online
    let remote: Vec<String> = args
        .str_or("remote", "")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    if !remote.is_empty() && shards > 1 {
        eprintln!("--remote replaces the in-process shard pool; drop --shards (each address is one shard)");
        std::process::exit(2);
    }
    if !remote.is_empty() && online.is_some() {
        eprintln!("--remote serves static schedules; the online adaptation loop is in-process only (drop --online)");
        std::process::exit(2);
    }
    let plan = args.flag("plan") || shards > 1 || online.is_some() || !remote.is_empty();
    let kind = args.parse_or("executor", ExecutorKind::from_env());
    // --costs beats HMATC_COSTS; bad files warn and keep the static costs
    let profile = load_costs(args);
    // the printed source must match what rebalance() will actually apply —
    // an unusable profile (e.g. all-zero coefficients) is ignored
    let cost_src = hmatc::plan::costmodel::source_label(profile.as_ref());
    // sharded serving needs the concrete PlannedOperator back out of the
    // type-erased Arc<dyn HOperator>, so the closure parks a clone aside
    let planned_slot: std::cell::Cell<Option<Arc<PlannedOperator>>> = std::cell::Cell::new(None);
    let planned = |po: PlannedOperator| -> Arc<PlannedOperator> {
        if let Some(p) = &profile {
            po.rebalance(p);
        }
        let po = Arc::new(po);
        planned_slot.set(Some(po.clone()));
        po
    };
    // --mmap re-points every compressed blob into a pack file written by
    // `hmatc pack` with the same build/compress flags; attach failures are
    // fatal because serving a half-mapped operator would be misleading
    let store = args.get("mmap").map(|path| match hmatc::store::MappedStore::open(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("--mmap {path}: {e}");
            std::process::exit(2);
        }
    });
    let attach_or_die = |r: Result<(), String>| {
        if let Err(e) = r {
            eprintln!("--mmap: {e} (pack and serve must use the same build/compress flags)");
            std::process::exit(2);
        }
    };
    let op: Arc<dyn HOperator> = match fmt.as_str() {
        "h" => {
            let mut h = h;
            if args.flag("compress") {
                h.compress(&cfg_from(args));
            }
            if let Some(store) = &store {
                attach_or_die(hmatc::store::attach_h(&mut h, store));
                println!("{}", hmatc::store::residency_h(&h, None).label());
            }
            let h = Arc::new(h);
            if plan {
                planned(PlannedOperator::from_h_with(h, kind))
            } else {
                h
            }
        }
        "uh" => {
            let mut uh = hmatc::uniform::build_from_h(&h, eps, hmatc::uniform::CouplingKind::Combined);
            if args.flag("compress") {
                uh.compress(&cfg_from(args));
            }
            if let Some(store) = &store {
                attach_or_die(hmatc::store::attach_uh(&mut uh, store));
                println!("{}", hmatc::store::residency_uh(&uh, None).label());
            }
            let uh = Arc::new(uh);
            if plan {
                planned(PlannedOperator::from_uniform_with(uh, kind))
            } else {
                uh
            }
        }
        "h2" => {
            let mut h2 = hmatc::h2::build_from_h(&h, eps);
            if args.flag("compress") {
                h2.compress(&cfg_from(args));
            }
            if let Some(store) = &store {
                attach_or_die(hmatc::store::attach_h2(&mut h2, store));
                println!("{}", hmatc::store::residency_h2(&h2, None).label());
            }
            let h2 = Arc::new(h2);
            if plan {
                planned(PlannedOperator::from_h2_with(h2, kind))
            } else {
                h2
            }
        }
        other => {
            eprintln!("unknown format '{other}' (h|uh|h2)");
            std::process::exit(2);
        }
    };
    let kernels = hmatc::compress::dispatch::kernels_label();
    if plan {
        let exec = if !remote.is_empty() {
            format!("remote × {} workers", remote.len())
        } else if shards > 1 {
            format!("{kind} × {shards} shards")
        } else {
            kind.to_string()
        };
        println!("serving {} operator ({}), executor {exec}, codec kernels {kernels}, costs {cost_src}", op.format_name(), fmt_bytes(op.byte_size()));
    } else {
        println!("serving {} operator ({}), codec kernels {kernels}", op.format_name(), fmt_bytes(op.byte_size()));
    }
    let nreq = args.num_or("requests", 256usize);
    let batch = args.num_or("batch", 8usize);
    let n = op.ncols();
    let op_stats = op.clone();
    let policy = BatchPolicy {
        max_batch: batch,
        linger: std::time::Duration::from_micros(args.num_or("linger-us", 200u64)),
        queue_limit: args.num_or("queue-limit", 0usize),
        shard_queue: args.num_or("shard-queue", 2usize),
    };
    // kept aside to report the post-serve cost source of the adaptive loop
    let mut status_op: Option<Arc<PlannedOperator>> = None;
    // kept aside as the local reference the remote fleet is checked against
    let mut remote_ref: Option<Arc<PlannedOperator>> = None;
    let server = if !remote.is_empty() {
        let po = planned_slot.take().expect("--remote implies --plan");
        remote_ref = Some(po.clone());
        let rcfg = RemoteConfig {
            connect_timeout: std::time::Duration::from_millis(args.num_or("connect-timeout-ms", 1_000u64)),
            io_timeout: std::time::Duration::from_millis(args.num_or("net-timeout-ms", 10_000u64)),
            heartbeat: std::time::Duration::from_millis(args.num_or("heartbeat-ms", 500u64)),
            backoff: std::time::Duration::from_millis(args.num_or("backoff-ms", 50u64)),
            backoff_max: std::time::Duration::from_millis(args.num_or("backoff-max-ms", 2_000u64)),
            max_attempts: args.num_or("net-retries", 5u32),
            pipeline: args.num_or("pipeline", 2usize),
        };
        match MvmServer::start_remote(po, &remote, policy, rcfg) {
            Ok(s) => Arc::new(s),
            Err(e) => {
                eprintln!("--remote: {e}");
                std::process::exit(2);
            }
        }
    } else if shards > 1 {
        let po = planned_slot.take().expect("--shards implies --plan");
        if online.is_some() {
            status_op = Some(po.clone());
        }
        let started = match &online {
            Some(cfg) => MvmServer::start_sharded_adaptive(po, shards, kind, policy, cfg.clone()),
            None => MvmServer::start_sharded(po, shards, kind, policy),
        };
        match started {
            Ok(s) => Arc::new(s),
            Err(e) => {
                eprintln!("--shards {shards}: {e}");
                std::process::exit(2);
            }
        }
    } else if let Some(cfg) = &online {
        let po = planned_slot.take().expect("--online implies --plan");
        status_op = Some(po.clone());
        Arc::new(MvmServer::start_adaptive(po, policy, cfg.clone()))
    } else {
        Arc::new(MvmServer::start(op, policy))
    };
    if let Some(cfg) = &online {
        println!("online adaptation: on ({})", cfg.describe());
    }
    let t = Timer::start();
    // closed-loop clients from a few threads
    let nclients = 4usize;
    std::thread::scope(|s| {
        for c in 0..nclients {
            let server = server.clone();
            s.spawn(move || {
                let mut rng = Rng::new(1000 + c as u64);
                for _ in 0..nreq / nclients {
                    let x = rng.vector(n);
                    // rejections (with --queue-limit) land in the metrics
                    let _ = server.try_call(x);
                }
            });
        }
    });
    let wall = t.elapsed();
    let m = server.metrics.snapshot();
    println!(
        "served {} requests in {} ({:.1} req/s) | batches: {} (avg {:.2}) | p50 {} p99 {} | effective {:.2} GB/s",
        m.requests,
        fmt_secs(wall),
        m.requests as f64 / wall,
        m.batches,
        m.avg_batch,
        fmt_secs(m.p50_latency),
        fmt_secs(m.p99_latency),
        m.effective_gbs
    );
    // per-shard hit rates live in the shard summary below; with --remote the
    // hot caches live in the worker processes and are reported there
    if let Some((hits, misses)) = op_stats.cache_counters().filter(|_| shards <= 1 && remote.is_empty()) {
        let total = hits + misses;
        let rate = if total == 0 { 0.0 } else { 100.0 * hits as f64 / total as f64 };
        println!("hot cache: {hits} hits / {misses} misses ({rate:.1}% hit rate)");
    }
    if let Some(line) = server.metrics.shard_summary() {
        println!("{line}");
    }
    if let Some(line) = server.metrics.net_summary() {
        println!("{line}");
    }
    if let Some(line) = m.prefetch_summary() {
        println!("{line}");
    }
    // the remote acceptance gate: one more request through the fleet,
    // checked bit-for-bit against the local operator it was built from
    if let Some(po) = &remote_ref {
        let mut rng = Rng::new(4242);
        let x = rng.vector(n);
        match server.try_call(x.clone()) {
            Ok(r) => {
                let mut want = vec![0.0; po.nrows()];
                po.apply(1.0, &x, &mut want);
                let same = r.y.len() == want.len() && r.y.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
                if same {
                    println!("remote bitwise ok ({} workers)", remote.len());
                } else {
                    eprintln!("remote MISMATCH: fleet result differs from the local reference");
                    std::process::exit(1);
                }
            }
            Err(e) => {
                eprintln!("remote reference check failed: {e}");
                std::process::exit(1);
            }
        }
    }
    if let Some(st) = server.online_status() {
        println!(
            "online: {} observations | {} refits | {} swaps | window {} | last drift {:.2}",
            st.observations, st.refits, st.swaps, st.window_len, st.last_drift
        );
        if let Some(po) = &status_op {
            // `online` once the bootstrap fit swapped the first live profile
            // in; `static` means the window never filled to min_samples
            let st = po.plan_stats();
            println!("cost_source: {}", st.cost_source);
            if !st.pool_cost_sources.is_empty() {
                println!("pool coefficients: [{}]", st.pool_cost_sources.join(", "));
            }
        }
    }
}

/// Cost profile from `--costs` (falling back to `HMATC_COSTS`); invalid
/// files warn and return None so serving continues on the static costs.
fn load_costs(args: &Args) -> Option<CostProfile> {
    match args.get("costs") {
        Some(path) => match CostProfile::load(path) {
            Ok(p) => Some(p),
            Err(e) => {
                eprintln!("--costs {path}: {e}; falling back to static costs");
                None
            }
        },
        None => hmatc::plan::costmodel::costs_from_env(),
    }
}

/// `hmatc shard-worker`: bind `--listen` (SO_REUSEADDR, retried for 10 s so
/// a restarted worker can reclaim the port from its dead predecessor), build
/// the same operator `serve` builds from the same flags, and serve shard
/// jobs over TCP until killed. `--pack <file>` maps a `pack --shards N`
/// replica (the worker's own inode and page-cache stream); `--exit-after-jobs`
/// is the deterministic crash-simulation quota of the fleet tests and the CI
/// smoke. The coordinator assigns the row range over the wire, so one binary
/// invocation serves whichever shard it is handed.
fn shard_worker_cmd(args: &Args) {
    let listen = args.str_or("listen", "127.0.0.1:0");
    // bind before the (slow) operator build: the coordinator's connect then
    // lands in the listen backlog instead of being refused
    let listener = match hmatc::coordinator::bind_listener_retry(&listen, std::time::Duration::from_secs(10)) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("--listen {listen}: {e}");
            std::process::exit(2);
        }
    };
    let local = listener.local_addr().map(|a| a.to_string()).unwrap_or(listen);
    println!("shard-worker listening on {local}");
    // scripts scrape the port from the line above before we spend seconds
    // building — make sure it is out
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    let p = problem(args);
    let h = build_h(args, &p);
    let eps = args.num_or("eps", 1e-6f64);
    let fmt = args.str_or("fmt", "h");
    let compress = args.flag("compress");
    let cfg = cfg_from(args);
    let kind = args.parse_or("executor", ExecutorKind::from_env());
    let store = args.get("pack").map(|path| match hmatc::store::MappedStore::open(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("--pack {path}: {e}");
            std::process::exit(2);
        }
    });
    let attach_or_die = |r: Result<(), String>| {
        if let Err(e) = r {
            eprintln!("--pack: {e} (pack and shard-worker must use the same build/compress flags)");
            std::process::exit(2);
        }
    };
    let op = match fmt.as_str() {
        "h" => {
            let mut h = h;
            if compress {
                h.compress(&cfg);
            }
            if let Some(store) = &store {
                attach_or_die(hmatc::store::attach_h(&mut h, store));
            }
            PlannedOperator::from_h_with(Arc::new(h), kind)
        }
        "uh" => {
            let mut uh = hmatc::uniform::build_from_h(&h, eps, hmatc::uniform::CouplingKind::Combined);
            if compress {
                uh.compress(&cfg);
            }
            if let Some(store) = &store {
                attach_or_die(hmatc::store::attach_uh(&mut uh, store));
            }
            PlannedOperator::from_uniform_with(Arc::new(uh), kind)
        }
        "h2" => {
            let mut h2 = hmatc::h2::build_from_h(&h, eps);
            if compress {
                h2.compress(&cfg);
            }
            if let Some(store) = &store {
                attach_or_die(hmatc::store::attach_h2(&mut h2, store));
            }
            PlannedOperator::from_h2_with(Arc::new(h2), kind)
        }
        other => {
            eprintln!("unknown format '{other}' (h|uh|h2)");
            std::process::exit(2);
        }
    };
    if let Some(profile) = load_costs(args) {
        op.rebalance(&profile);
    }
    let quota = args.num_or("exit-after-jobs", 0u64);
    println!("shard-worker ready: {} operator ({})", op.format_name(), fmt_bytes(op.byte_size()));
    let _ = std::io::stdout().flush();
    match hmatc::coordinator::serve_worker(listener, Arc::new(op), kind, (quota > 0).then_some(quota)) {
        Ok(()) => println!("shard-worker: job quota reached, exiting"),
        Err(e) => {
            eprintln!("shard-worker: {e}");
            std::process::exit(1);
        }
    }
}

/// `hmatc calibrate`: build the model problem, run timed warmup batches
/// through a planned operator, fit per-kernel-class cost coefficients and
/// write the profile JSON (`--out`, default `costs.json`). `--quick` is the
/// CI smoke configuration (small problem, few rounds). Compresses by default
/// — decode coefficients are the point — unless `--no-compress` is given.
fn calibrate_cmd(args: &Args) {
    // the exact model problem every other subcommand uses, just with a
    // smaller default size in --quick (CI smoke)
    let quick = args.flag("quick");
    let p = problem_with_default_level(args, if quick { 2 } else { 3 });
    let h = build_h(args, &p);
    let eps = args.num_or("eps", 1e-6f64);

    let fmt = args.str_or("fmt", "h");
    let compress = !args.flag("no-compress");
    let cfg = cfg_from(args);
    let kind = args.parse_or("executor", ExecutorKind::from_env());
    let op = match fmt.as_str() {
        "h" => {
            let mut h = h;
            if compress {
                h.compress(&cfg);
            }
            PlannedOperator::from_h_with(Arc::new(h), kind)
        }
        "uh" => {
            let mut uh = hmatc::uniform::build_from_h(&h, eps, hmatc::uniform::CouplingKind::Combined);
            if compress {
                uh.compress(&cfg);
            }
            PlannedOperator::from_uniform_with(Arc::new(uh), kind)
        }
        "h2" => {
            let mut h2 = hmatc::h2::build_from_h(&h, eps);
            if compress {
                h2.compress(&cfg);
            }
            PlannedOperator::from_h2_with(Arc::new(h2), kind)
        }
        other => {
            eprintln!("unknown format '{other}' (h|uh|h2)");
            std::process::exit(2);
        }
    };

    let rounds = args.num_or("rounds", if quick { 2usize } else { 8 });
    let t = Timer::start();
    let mut profile = op.calibrate(rounds);
    // stamp the topology fingerprint so a later load on a different machine
    // shape can drop the per-pool overlays instead of mis-applying them
    profile.topology = Some(hmatc::plan::costmodel::TopologyMeta::current());
    if !profile.is_usable() {
        // writing a profile that rebalance() would ignore only misleads the
        // next `--costs` user into believing calibration is active
        eprintln!("calibration fit degenerated (no positive finite coefficient — clock resolution too coarse for this problem size?); not writing a profile");
        std::process::exit(1);
    }
    let st = op.plan_stats();
    println!("calibrated {} on executor {kind} in {} ({rounds} timed rounds, b = 1 and b = {})", op.format_name(), fmt_secs(t.elapsed()), hmatc::plan::exec::CALIB_RHS);
    println!("fitted coefficients (seconds per unit):");
    for (class, coeff) in profile.coeffs() {
        println!("  {:<16} {coeff:.3e}", class.key());
    }
    if profile.has_pool_coeffs() {
        println!("per-pool coefficients: [{}]", profile.pool_source_labels().join(", "));
    }
    println!("cost source: {} | makespan: measured(static packing) {} vs predicted(calibrated packing) {}", st.cost_source, fmt_secs(st.measured_makespan), fmt_secs(st.predicted_makespan));
    let out = args.str_or("out", "costs.json");
    match profile.save(&out) {
        Ok(()) => println!("profile written to {out} (load with --costs {out} or HMATC_COSTS={out})"),
        Err(e) => {
            eprintln!("cannot write {out}: {e}");
            std::process::exit(1);
        }
    }
}

fn solve_cmd(args: &Args) {
    let p = problem(args);
    let mut h = build_h(args, &p);
    if args.flag("compress") {
        h.compress(&cfg_from(args));
        println!("compressed: {}", fmt_bytes(h.byte_size()));
    }
    let n = h.nrows();
    let op = (n, move |x: &[f64], y: &mut [f64]| hmatc::mvm::mvm(1.0, &h, x, y, MvmAlgorithm::ClusterLists));
    let mut rng = Rng::new(3);
    let b = rng.vector(n);
    let (x, stats) = cg(&op, &b, args.num_or("tol", 1e-8f64), args.num_or("max-iter", 500usize));
    println!(
        "CG: {} iterations, residual {:.2e}, {} ({})",
        stats.iterations,
        stats.residual,
        fmt_secs(stats.seconds),
        if stats.converged { "converged" } else { "NOT converged" }
    );
    let _ = x;
}

fn roofline_cmd() {
    println!("measuring peak memory bandwidth (STREAM triad)…");
    let bw = measure_peak_bandwidth();
    println!("peak bandwidth ≈ {bw:.2} GB/s on {} threads", hmatc::par::num_threads() + 1);
}
