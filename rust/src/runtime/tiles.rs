//! Tile engine: offload the dense near-field of an H-matrix MVM to the AOT
//! JAX/Pallas tile kernel through PJRT. Dense leaves are padded into fixed
//! T×T f32 tiles, processed in batches of B by one compiled executable
//! (`artifacts/dense_tile_mvm.hlo.txt`, lowered by python/compile/aot.py),
//! while the low-rank far field stays on the rust kernels.

use super::engine::PjrtEngine;
use crate::hmatrix::{BlockData, HMatrix};
use anyhow::{bail, Result};

/// Tile size the AOT artifact was lowered for (see python/compile/aot.py).
pub const TILE: usize = 64;
/// Batch size of the artifact.
pub const BATCH: usize = 64;

/// Offload engine for uniform dense tiles.
pub struct TileEngine {
    engine: PjrtEngine,
    artifact: String,
}

impl TileEngine {
    /// `artifact` is e.g. "dense_tile_mvm" (without .hlo.txt).
    pub fn new(dir: &str, artifact: &str) -> Result<TileEngine> {
        let mut engine = PjrtEngine::new(dir)?;
        if !engine.has_artifact(artifact) {
            bail!("artifact '{artifact}' not found in {dir} — run `make artifacts`");
        }
        engine.load(artifact)?;
        Ok(TileEngine { engine, artifact: artifact.to_string() })
    }

    /// y += alpha · (dense part of M) · x executed on PJRT; returns the
    /// number of tiles processed. Low-rank blocks are untouched — combine
    /// with [`crate::mvm::mvm`] over a matrix whose dense part is skipped, or
    /// use [`Self::full_mvm`].
    pub fn dense_mvm(&mut self, alpha: f64, m: &HMatrix, x: &[f64], y: &mut [f64]) -> Result<usize> {
        let bt = &m.bt;
        // gather dense leaves
        struct TileJob {
            row_begin: usize,
            nrows: usize,
            ncols: usize,
            leaf: usize,
        }
        let mut jobs: Vec<TileJob> = Vec::new();
        for &leaf in &bt.leaves {
            if let Some(BlockData::Dense(d)) = m.blocks[leaf].as_ref() {
                if d.nrows() > TILE || d.ncols() > TILE {
                    bail!("dense leaf {}x{} exceeds tile size {TILE}", d.nrows(), d.ncols());
                }
                let nd = bt.node(leaf);
                jobs.push(TileJob { row_begin: bt.row_ct.node(nd.row).begin, nrows: d.nrows(), ncols: d.ncols(), leaf });
            }
        }
        let ntiles = jobs.len();

        // process in batches of BATCH
        let mut tiles = vec![0f32; BATCH * TILE * TILE];
        let mut xs = vec![0f32; BATCH * TILE];
        for chunk in jobs.chunks(BATCH) {
            tiles.fill(0.0);
            xs.fill(0.0);
            for (b, job) in chunk.iter().enumerate() {
                let nd = bt.node(job.leaf);
                let d = match m.blocks[job.leaf].as_ref() {
                    Some(BlockData::Dense(d)) => d,
                    _ => unreachable!(),
                };
                // row-major tile layout (jax convention)
                for i in 0..job.nrows {
                    for j in 0..job.ncols {
                        tiles[b * TILE * TILE + i * TILE + j] = d[(i, j)] as f32;
                    }
                }
                let cr = bt.col_ct.node(nd.col).range();
                for (j, &xv) in x[cr].iter().enumerate() {
                    xs[b * TILE + j] = xv as f32;
                }
            }
            let out = self.engine.execute_f32(
                &self.artifact,
                &[(&tiles, &[BATCH, TILE, TILE]), (&xs, &[BATCH, TILE])],
            )?;
            let ys = &out[0]; // [BATCH, TILE]
            for (b, job) in chunk.iter().enumerate() {
                for i in 0..job.nrows {
                    y[job.row_begin + i] += alpha * ys[b * TILE + i] as f64;
                }
            }
        }
        Ok(ntiles)
    }

    /// Full MVM: dense part on PJRT, low-rank part on the rust kernels.
    pub fn full_mvm(&mut self, alpha: f64, m: &HMatrix, x: &[f64], y: &mut [f64]) -> Result<usize> {
        let ntiles = self.dense_mvm(alpha, m, x, y)?;
        // low-rank remainder on the CPU kernels
        let bt = &m.bt;
        for &leaf in &bt.leaves {
            let b = m.blocks[leaf].as_ref().expect("missing leaf");
            if matches!(b, BlockData::Dense(_)) {
                continue;
            }
            let nd = bt.node(leaf);
            let rr = bt.row_ct.node(nd.row).range();
            let cr = bt.col_ct.node(nd.col).range();
            crate::mvm::apply_block(alpha, b, &x[cr], &mut y[rr]);
        }
        Ok(ntiles)
    }
}
