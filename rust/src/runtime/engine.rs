//! PJRT CPU client wrapper: HLO text → compile → execute.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Default artifact directory produced by `make artifacts`.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Loads `*.hlo.txt` artifacts and executes them on the PJRT CPU client.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl PjrtEngine {
    /// Create a CPU engine rooted at an artifact directory.
    pub fn new(dir: &str) -> Result<PjrtEngine> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(PjrtEngine { client, exes: HashMap::new(), dir: PathBuf::from(dir) })
    }

    /// Platform string of the underlying client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Whether an artifact file exists.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.path_of(name).exists()
    }

    fn path_of(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Load + compile an artifact (cached by name).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let path = self.path_of(name);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile artifact '{name}'"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a loaded artifact on f32 input buffers with shapes.
    /// Returns the flattened f32 outputs (the artifact returns a tuple; see
    /// gen_hlo.py — lowered with `return_tuple=True`).
    pub fn execute_f32(&mut self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        self.load(name)?;
        let exe = self.exes.get(name).expect("just loaded");
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data).reshape(&dims).context("reshape input literal")?;
            lits.push(lit);
        }
        let result = exe.execute::<xla::Literal>(&lits).context("execute")?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        let tuple = result.to_tuple().context("untuple result")?;
        let mut out = Vec::with_capacity(tuple.len());
        for lit in tuple {
            out.push(lit.to_vec::<f32>().context("read f32 output")?);
        }
        Ok(out)
    }

    /// Execute an artifact whose inputs include uint32 *packed byte-plane*
    /// tensors (the FPX-compressed tile kernel; the xla crate has no u8
    /// literal type, so 4 bytes are packed little-endian per u32 word and
    /// the kernel unpacks with shifts).
    pub fn execute_mixed(&mut self, name: &str, u32_inputs: &[(&[u32], &[usize])], f32_inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        self.load(name)?;
        let exe = self.exes.get(name).expect("just loaded");
        let mut lits = Vec::new();
        for (data, shape) in u32_inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data).reshape(&dims).context("reshape u32 literal")?;
            lits.push(lit);
        }
        for (data, shape) in f32_inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data).reshape(&dims).context("reshape f32 literal")?;
            lits.push(lit);
        }
        let result = exe.execute::<xla::Literal>(&lits).context("execute")?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        let tuple = result.to_tuple().context("untuple result")?;
        let mut out = Vec::with_capacity(tuple.len());
        for lit in tuple {
            out.push(lit.to_vec::<f32>().context("read f32 output")?);
        }
        Ok(out)
    }

    /// Check whether `path` points at a usable artifacts directory.
    pub fn artifacts_available(dir: &str) -> bool {
        Path::new(dir).is_dir()
    }
}
