//! PJRT runtime (feature `pjrt`): load AOT-lowered JAX/Pallas HLO artifacts
//! and execute them from the rust hot path. Python never runs at request
//! time — `make artifacts` lowers the L2/L1 graphs to HLO *text* once (see
//! `python/compile/aot.py` and /opt/xla-example for the interchange rules).

mod engine;
mod tiles;

pub use engine::{PjrtEngine, DEFAULT_ARTIFACTS_DIR};
pub use tiles::TileEngine;
