//! Minimal JSON value type with serializer and parser.
//!
//! Used for benchmark result files and coordinator metrics output. No serde in
//! the sandbox's vendored crate set, so this is a small hand-rolled
//! implementation covering the full JSON grammar (sufficient for our needs:
//! objects, arrays, strings, numbers, bools, null; UTF-8; \u escapes).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array.
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// Get an object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Interpret as f64 if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Interpret as str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Interpret as array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.is_finite() {
                if *x == x.trunc() && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            } else {
                out.push_str("null"); // JSON has no inf/nan
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, it) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(it, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(val, out);
            }
            out.push('}');
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.pos))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5]).map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.b.len() && (self.b[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| "invalid utf8")?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self.pos < self.b.len() && (self.b[self.pos].is_ascii_digit() || matches!(self.b[self.pos], b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| format!("bad number '{txt}'"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let v = Json::obj(vec![
            ("name", "fig01".into()),
            ("n", 1280usize.into()),
            ("time", 0.00123.into()),
            ("ok", true.into()),
            ("series", Json::arr(vec![1.0.into(), 2.0.into()])),
        ]);
        let s = v.to_string();
        let p = Json::parse(&s).unwrap();
        assert_eq!(v, p);
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2.5, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64().unwrap(), 2.5);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""A\t\"""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\t\"");
    }

    #[test]
    fn reject_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn int_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }
}
