//! Minimal CLI argument parsing (no clap in the sandbox).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and positional
//! arguments. Typed getters with defaults.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Named options: `--key value` or `--key=value`.
    opts: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    flags: Vec<String>,
    /// Positional arguments.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (exclude argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut a = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    a.opts.insert(rest.to_string(), v);
                } else {
                    a.flags.push(rest.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Raw string option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option with default; panics with a clear message on parse
    /// error. Works for any `FromStr` type — numbers, but also enum-like
    /// selectors such as `--executor=sharded:4`.
    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default,
            Some(s) => s.parse().unwrap_or_else(|e| panic!("--{key}={s}: {e}")),
        }
    }

    /// Alias of [`Args::parse_or`] kept for numeric call sites.
    pub fn num_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        self.parse_or(key, default)
    }

    /// List option: comma-separated values.
    pub fn list_or<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default.to_vec(),
            Some(s) => s.split(',').map(|t| t.trim().parse().unwrap_or_else(|e| panic!("--{key}: '{t}': {e}"))).collect(),
        }
    }

    /// Boolean switch: present as `--flag` (or `--flag true/false`).
    pub fn flag(&self, key: &str) -> bool {
        if self.flags.iter().any(|f| f == key) {
            return true;
        }
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn options_and_flags() {
        // positionals come first: a bare `--flag` followed by a non-dash
        // token would consume it as a value (documented CLI convention)
        let a = parse(&["cmd", "--n", "1024", "--eps=1e-6", "--verbose"]);
        assert_eq!(a.num_or("n", 0usize), 1024);
        assert_eq!(a.num_or("eps", 0.0f64), 1e-6);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.positional, vec!["cmd"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.num_or("n", 7usize), 7);
        assert_eq!(a.str_or("fmt", "h"), "h");
    }

    #[test]
    fn lists() {
        let a = parse(&["--sizes", "128,256,512"]);
        assert_eq!(a.list_or("sizes", &[1usize]), vec![128, 256, 512]);
        assert_eq!(a.list_or("eps", &[1e-4]), vec![1e-4]);
    }

    #[test]
    fn flag_with_value() {
        let a = parse(&["--check", "true", "--fast", "false"]);
        assert!(a.flag("check"));
        assert!(!a.flag("fast"));
    }
}
