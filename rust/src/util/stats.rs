//! Summary statistics for benchmark samples.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (interpolated for even length). 0 for empty input.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Percentile in [0, 100] with linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median absolute deviation (robust spread estimate).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

/// Minimum of a slice (NaN-free input assumed).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Maximum of a slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        assert_eq!(median(&xs), 3.0);
        assert!((stddev(&xs) - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 5.0);
    }

    #[test]
    fn median_even() {
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 2.5);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 25.0), 25.0);
    }

    #[test]
    fn mad_robust() {
        let xs = [1.0, 1.0, 1.0, 1.0, 100.0];
        assert_eq!(mad(&xs), 0.0);
        let ys = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mad(&ys), 1.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(mad(&[]), 0.0);
    }
}
