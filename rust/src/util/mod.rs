//! Small self-contained utilities: PRNG, statistics, JSON, CLI args, timing.
//!
//! The sandbox has no access to crates.io beyond the vendored set, so the
//! usual suspects (rand, serde, clap, criterion) are replaced by the minimal
//! implementations in this module.

pub mod args;
pub mod json;
pub mod prng;
pub mod stats;
pub mod timer;

pub use prng::Rng;
pub use timer::Timer;

/// Format a byte count as a human readable string (KiB/MiB/GiB).
pub fn fmt_bytes(b: usize) -> String {
    let b = b as f64;
    if b >= 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
    } else if b >= 1024.0 * 1024.0 {
        format!("{:.2} MiB", b / (1024.0 * 1024.0))
    } else if b >= 1024.0 {
        format!("{:.2} KiB", b / 1024.0)
    } else {
        format!("{b:.0} B")
    }
}

/// Format seconds with an adaptive unit (s/ms/µs).
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(2.5e-3), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.5 µs");
    }
}
