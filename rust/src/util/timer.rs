//! Wall-clock timing helpers.

use std::time::Instant;

/// A simple wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a new timer.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Seconds elapsed since start.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Restart and return the elapsed seconds of the previous lap.
    pub fn lap(&mut self) -> f64 {
        let e = self.elapsed();
        self.start = Instant::now();
        e
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed();
        let b = t.elapsed();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, s) = time_it(|| 40 + 2);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
