//! Deterministic PRNG (xoshiro256** seeded by splitmix64).
//!
//! Used for synthetic workloads, property-style tests and randomized vectors.
//! No external `rand` crate is available in this sandbox.

/// xoshiro256** generator with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box-Muller.
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)], spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal variate (Box-Muller).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (std::f64::consts::TAU * v).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Fill a slice with uniform values in [lo, hi).
    pub fn fill_uniform(&mut self, buf: &mut [f64], lo: f64, hi: f64) {
        for v in buf.iter_mut() {
            *v = self.range(lo, hi);
        }
    }

    /// Fill a slice with standard normal values.
    pub fn fill_normal(&mut self, buf: &mut [f64]) {
        for v in buf.iter_mut() {
            *v = self.normal();
        }
    }

    /// A random vector of length `n`, uniform in [-1, 1).
    pub fn vector(&mut self, n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.fill_uniform(&mut v, -1.0, 1.0);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(123);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(99);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
