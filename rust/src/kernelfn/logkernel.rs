//! 2D Laplace (log) kernel on a curve — a second integral-equation workload
//! with different singular-value decay than the 3D SLP.

use super::MatrixGen;
use crate::geometry::Point3;

/// Nyström-style log-kernel matrix on a closed curve:
/// m_ij = −w² · log‖x_i − x_j‖ (off-diagonal), with the standard
/// self-interaction limit on the diagonal (w = arclength weight).
pub struct LogKernel {
    pts: Vec<Point3>,
    w: f64,
}

impl LogKernel {
    /// Points should lie on a curve (e.g. [`crate::geometry::circle_points`]).
    pub fn new(pts: Vec<Point3>) -> Self {
        let n = pts.len();
        let w = std::f64::consts::TAU / n as f64;
        LogKernel { pts, w }
    }
}

impl MatrixGen for LogKernel {
    fn nrows(&self) -> usize {
        self.pts.len()
    }

    fn ncols(&self) -> usize {
        self.pts.len()
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        if i == j {
            // panel self term: -w^2 (log(w/2) - 1) keeps the diagonal finite
            // and consistent with the panel size.
            return -self.w * self.w * ((self.w / 2.0).ln() - 1.0);
        }
        let d = self.pts[i].dist(self.pts[j]);
        -self.w * self.w * d.ln()
    }

    fn points(&self) -> &[Point3] {
        &self.pts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::circle_points;

    #[test]
    fn symmetric() {
        let k = LogKernel::new(circle_points(64));
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(k.entry(i, j), k.entry(j, i));
            }
        }
    }

    #[test]
    fn diagonal_finite_positive() {
        let k = LogKernel::new(circle_points(128));
        assert!(k.entry(5, 5).is_finite());
        assert!(k.entry(5, 5) > 0.0);
    }
}
