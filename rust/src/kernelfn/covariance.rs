//! Covariance kernels (geostatistics workload, cf. Abdulah et al. [1] in the
//! paper): exponential and Matérn-3/2 over scattered points.

use super::MatrixGen;
use crate::geometry::Point3;

/// Exponential covariance C(r) = σ² exp(−r/ℓ) + nugget δ_ij.
pub struct ExpCovariance {
    pts: Vec<Point3>,
    pub sigma2: f64,
    pub length: f64,
    pub nugget: f64,
}

impl ExpCovariance {
    pub fn new(pts: Vec<Point3>, length: f64) -> Self {
        ExpCovariance { pts, sigma2: 1.0, length, nugget: 1e-4 }
    }
}

impl MatrixGen for ExpCovariance {
    fn nrows(&self) -> usize {
        self.pts.len()
    }

    fn ncols(&self) -> usize {
        self.pts.len()
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        let r = self.pts[i].dist(self.pts[j]);
        let c = self.sigma2 * (-r / self.length).exp();
        if i == j {
            c + self.nugget
        } else {
            c
        }
    }

    fn points(&self) -> &[Point3] {
        &self.pts
    }
}

/// Matérn ν=3/2 covariance C(r) = σ² (1 + √3 r/ℓ) exp(−√3 r/ℓ) + nugget.
pub struct Matern32Covariance {
    pts: Vec<Point3>,
    pub sigma2: f64,
    pub length: f64,
    pub nugget: f64,
}

impl Matern32Covariance {
    pub fn new(pts: Vec<Point3>, length: f64) -> Self {
        Matern32Covariance { pts, sigma2: 1.0, length, nugget: 1e-4 }
    }
}

impl MatrixGen for Matern32Covariance {
    fn nrows(&self) -> usize {
        self.pts.len()
    }

    fn ncols(&self) -> usize {
        self.pts.len()
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        let r = self.pts[i].dist(self.pts[j]);
        let s = 3f64.sqrt() * r / self.length;
        let c = self.sigma2 * (1.0 + s) * (-s).exp();
        if i == j {
            c + self.nugget
        } else {
            c
        }
    }

    fn points(&self) -> &[Point3] {
        &self.pts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::random_cube;
    use crate::util::Rng;

    #[test]
    fn exp_cov_properties() {
        let mut rng = Rng::new(3);
        let pts = random_cube(50, &mut rng);
        let c = ExpCovariance::new(pts, 0.5);
        for i in 0..10 {
            assert!(c.entry(i, i) >= 1.0); // σ² + nugget
            for j in 0..10 {
                assert_eq!(c.entry(i, j), c.entry(j, i));
                if i != j {
                    assert!(c.entry(i, j) < c.entry(i, i));
                }
            }
        }
    }

    #[test]
    fn matern_decays_with_distance() {
        let pts = vec![Point3::zero(), Point3::new(0.1, 0.0, 0.0), Point3::new(2.0, 0.0, 0.0)];
        let c = Matern32Covariance::new(pts, 0.5);
        assert!(c.entry(0, 1) > c.entry(0, 2));
    }
}
