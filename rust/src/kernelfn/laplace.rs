//! Laplace single layer potential on a triangulated surface (paper Eq. 2).
//!
//! Galerkin entries m_ij = ∫_πi ∫_πj 1/(4π‖x−y‖) dx dy with piecewise-constant
//! ansatz functions. The paper uses Sauter-Schwab quadrature; here (documented
//! substitution, DESIGN.md) we use
//!
//! * centroid rule for well-separated pairs: m_ij ≈ A_i·A_j / (4π‖c_i−c_j‖);
//! * recursive subdivision for near pairs (up to `near_depth` levels);
//! * the self-similarity identity for the singular diagonal: subdividing a
//!   planar triangle into 4 similar children of half size gives
//!   I(T,T) = Σ_{k≠l} I(T_k,T_l) + 4·I(T,T)/8, hence I(T,T) = 2·Σ_{k≠l} I(T_k,T_l).
//!
//! This preserves the 1/r kernel structure, symmetry and the singular value
//! decay that drive the paper's rank/compression behaviour.

use super::MatrixGen;
use crate::geometry::{triangle_area, Geometry, Point3};

const FOUR_PI: f64 = 4.0 * std::f64::consts::PI;

/// BEM Laplace SLP generator over a [`Geometry`].
pub struct LaplaceSlp {
    centroids: Vec<Point3>,
    areas: Vec<f64>,
    corners: Vec<[Point3; 3]>,
    diameters: Vec<f64>,
    /// subdivision depth for near (non-singular) pairs
    near_depth: usize,
}

impl LaplaceSlp {
    pub fn new(geom: &Geometry) -> Self {
        let corners: Vec<[Point3; 3]> = (0..geom.len()).map(|i| geom.corners(i)).collect();
        let diameters = corners
            .iter()
            .map(|c| c[0].dist(c[1]).max(c[1].dist(c[2])).max(c[2].dist(c[0])))
            .collect();
        LaplaceSlp { centroids: geom.centroids.clone(), areas: geom.areas.clone(), corners, diameters, near_depth: 2 }
    }

    /// Number of degrees of freedom.
    pub fn len(&self) -> usize {
        self.centroids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.centroids.is_empty()
    }

    /// 1/(4π r) interaction of two triangles by recursive subdivision.
    fn pair_integral(t1: &[Point3; 3], t2: &[Point3; 3], depth: usize) -> f64 {
        let c1 = centroid(t1);
        let c2 = centroid(t2);
        let a1 = triangle_area(t1[0], t1[1], t1[2]);
        let a2 = triangle_area(t2[0], t2[1], t2[2]);
        let d = c1.dist(c2);
        let h = diam(t1).max(diam(t2));
        if depth == 0 || d > 2.0 * h {
            // far enough: centroid rule
            return a1 * a2 / d;
        }
        let mut sum = 0.0;
        for s1 in subdivide(t1) {
            for s2 in subdivide(t2) {
                sum += Self::pair_integral(&s1, &s2, depth - 1);
            }
        }
        sum
    }

    /// Singular self-integral via the self-similarity identity.
    fn self_integral(t: &[Point3; 3]) -> f64 {
        let kids = subdivide(t);
        let mut s = 0.0;
        for k in 0..4 {
            for l in 0..4 {
                if k != l {
                    // one extra subdivision level for the touching child pairs
                    s += Self::pair_integral(&kids[k], &kids[l], 1);
                }
            }
        }
        2.0 * s
    }
}

fn centroid(t: &[Point3; 3]) -> Point3 {
    t[0].add(t[1]).add(t[2]).scale(1.0 / 3.0)
}

fn diam(t: &[Point3; 3]) -> f64 {
    t[0].dist(t[1]).max(t[1].dist(t[2])).max(t[2].dist(t[0]))
}

/// Midpoint subdivision into 4 similar triangles.
fn subdivide(t: &[Point3; 3]) -> [[Point3; 3]; 4] {
    let m01 = t[0].add(t[1]).scale(0.5);
    let m12 = t[1].add(t[2]).scale(0.5);
    let m20 = t[2].add(t[0]).scale(0.5);
    [[t[0], m01, m20], [t[1], m12, m01], [t[2], m20, m12], [m01, m12, m20]]
}

impl MatrixGen for LaplaceSlp {
    fn nrows(&self) -> usize {
        self.len()
    }

    fn ncols(&self) -> usize {
        self.len()
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        if i == j {
            return Self::self_integral(&self.corners[i]) / FOUR_PI;
        }
        let d = self.centroids[i].dist(self.centroids[j]);
        let h = self.diameters[i].max(self.diameters[j]);
        if d > 2.0 * h {
            // well separated: centroid rule
            self.areas[i] * self.areas[j] / (FOUR_PI * d)
        } else {
            Self::pair_integral(&self.corners[i], &self.corners[j], self.near_depth) / FOUR_PI
        }
    }

    fn points(&self) -> &[Point3] {
        &self.centroids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::icosphere;

    #[test]
    fn symmetric_positive_entries() {
        let g = icosphere(1);
        let slp = LaplaceSlp::new(&g);
        for i in 0..10 {
            for j in 0..10 {
                let a = slp.entry(i, j);
                let b = slp.entry(j, i);
                assert!(a > 0.0);
                assert!((a - b).abs() <= 1e-12 * a.abs(), "asym at ({i},{j})");
            }
        }
    }

    #[test]
    fn diagonal_dominates_far_field() {
        let g = icosphere(2);
        let slp = LaplaceSlp::new(&g);
        // the self entry is much larger than a far-field entry of the same row
        let dii = slp.entry(0, 0);
        // triangle far away from 0 (opposite side of the sphere)
        let c0 = g.centroids[0];
        let far = (0..g.len()).max_by(|&a, &b| c0.dist(g.centroids[a]).partial_cmp(&c0.dist(g.centroids[b])).unwrap()).unwrap();
        assert!(dii > 5.0 * slp.entry(0, far));
    }

    #[test]
    fn self_integral_scaling() {
        // I(T,T) scales like h^3 for similar triangles
        let t1 = [Point3::new(0.0, 0.0, 0.0), Point3::new(1.0, 0.0, 0.0), Point3::new(0.0, 1.0, 0.0)];
        let t2 = [Point3::new(0.0, 0.0, 0.0), Point3::new(2.0, 0.0, 0.0), Point3::new(0.0, 2.0, 0.0)];
        let i1 = LaplaceSlp::self_integral(&t1);
        let i2 = LaplaceSlp::self_integral(&t2);
        assert!((i2 / i1 - 8.0).abs() < 1e-6, "ratio {}", i2 / i1);
    }

    #[test]
    fn centroid_rule_agrees_far_field() {
        // for distant triangles the subdivided quadrature equals the centroid rule
        let t1 = [Point3::new(0.0, 0.0, 0.0), Point3::new(0.1, 0.0, 0.0), Point3::new(0.0, 0.1, 0.0)];
        let t2 = [Point3::new(5.0, 5.0, 5.0), Point3::new(5.1, 5.0, 5.0), Point3::new(5.0, 5.1, 5.0)];
        let q = LaplaceSlp::pair_integral(&t1, &t2, 3);
        let a = triangle_area(t1[0], t1[1], t1[2]) * triangle_area(t2[0], t2[1], t2[2]);
        let c = centroid(&t1).dist(centroid(&t2));
        assert!((q - a / c).abs() < 1e-9 * (a / c));
    }
}
