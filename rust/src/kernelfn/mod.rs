//! Matrix generators: entry-wise access to the (never fully assembled) dense
//! system matrix. The BEM Laplace single layer potential is the paper's model
//! problem (§2.1); a log-kernel and covariance kernels serve as additional
//! example applications.

mod covariance;
mod laplace;
mod logkernel;

pub use covariance::{ExpCovariance, Matern32Covariance};
pub use laplace::LaplaceSlp;
pub use logkernel::LogKernel;

use crate::geometry::Point3;
use crate::la::DMatrix;

/// Entry-wise generator for an implicit dense matrix, indexed by *external*
/// (original) indices.
pub trait MatrixGen: Sync {
    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;

    /// Matrix entry m_{ij}, external indexing.
    fn entry(&self, i: usize, j: usize) -> f64;

    /// Geometry used for clustering (row side = column side for all our
    /// generators).
    fn points(&self) -> &[Point3];

    /// Assemble a sub-block for given external row/column index lists.
    fn fill(&self, rows: &[usize], cols: &[usize], out: &mut DMatrix) {
        debug_assert_eq!(out.nrows(), rows.len());
        debug_assert_eq!(out.ncols(), cols.len());
        for (jj, &j) in cols.iter().enumerate() {
            let col = out.col_mut(jj);
            for (ii, &i) in rows.iter().enumerate() {
                col[ii] = self.entry(i, j);
            }
        }
    }

    /// One row restricted to a column list.
    fn fill_row(&self, i: usize, cols: &[usize], out: &mut [f64]) {
        for (jj, &j) in cols.iter().enumerate() {
            out[jj] = self.entry(i, j);
        }
    }

    /// One column restricted to a row list.
    fn fill_col(&self, j: usize, rows: &[usize], out: &mut [f64]) {
        for (ii, &i) in rows.iter().enumerate() {
            out[ii] = self.entry(i, j);
        }
    }
}

/// A fully assembled matrix as a generator (tests, small reference problems).
pub struct DenseGen {
    m: DMatrix,
    pts: Vec<Point3>,
}

impl DenseGen {
    /// Wrap a matrix; `pts` drive the clustering (must have nrows entries).
    pub fn new(m: DMatrix, pts: Vec<Point3>) -> Self {
        assert_eq!(m.nrows(), pts.len());
        DenseGen { m, pts }
    }
}

impl MatrixGen for DenseGen {
    fn nrows(&self) -> usize {
        self.m.nrows()
    }
    fn ncols(&self) -> usize {
        self.m.ncols()
    }
    fn entry(&self, i: usize, j: usize) -> f64 {
        self.m[(i, j)]
    }
    fn points(&self) -> &[Point3] {
        &self.pts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn fill_matches_entry() {
        let mut rng = Rng::new(9);
        let m = DMatrix::random(6, 6, &mut rng);
        let pts = crate::geometry::fibonacci_sphere(6);
        let g = DenseGen::new(m.clone(), pts);
        let rows = [1usize, 3, 5];
        let cols = [0usize, 2];
        let mut out = DMatrix::zeros(3, 2);
        g.fill(&rows, &cols, &mut out);
        for (jj, &j) in cols.iter().enumerate() {
            for (ii, &i) in rows.iter().enumerate() {
                assert_eq!(out[(ii, jj)], m[(i, j)]);
            }
        }
    }
}
