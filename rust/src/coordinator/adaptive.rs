//! Online cost adaptation for the serving coordinator.
//!
//! One-shot calibration (`hmatc calibrate`) models the machine once, cold.
//! Under live mixed traffic the right schedule drifts — what is resident in
//! the decode-once hot cache, which batch widths dominate, which shards run
//! hot — so [`OnlineCalibrator`] continuously folds per-chunk
//! [`crate::plan::TimingSink`] samples harvested from **served batches** into
//! a sliding window, re-runs the least-squares [`costmodel::fit`] when the
//! modeled makespan drifts from the measured one, and atomically swaps
//! re-balanced packings into every registered operator via the existing
//! `Packing` RwLock path. Re-balancing only re-partitions the same task
//! lists (never the task bodies or their summation order), so served outputs
//! stay **bitwise identical** across every re-fit and swap — the same
//! invariant `tests/calibration_invariance.rs` pins for offline rebalancing,
//! extended to mid-stream swaps by `tests/online_adaptation.rs`.
//!
//! Swap-storm protection: a re-fit needs `hysteresis` *consecutive*
//! over-threshold drift observations, the streak resets on every quiet
//! observation and after every re-fit, and the window must hold at least
//! `min_samples` samples. Noisy timings that straddle the threshold
//! therefore trigger at most one swap per `hysteresis` observations, and
//! alternating noise triggers none.
//!
//! The online loop is **in-process only**: [`Sample`] features and the
//! timing sinks are not serialized by the [`super::wire`] protocol, so the
//! cross-process fleet ([`crate::coordinator::MvmServer::start_remote`])
//! serves static schedules — each `shard-worker` can still be launched with
//! its own calibrated cost profile (`HMATC_COSTS`), which only re-balances
//! its local packings and never changes served bits.

use crate::plan::costmodel::{self, Sample};
use crate::plan::PlannedOperator;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Knobs of the adaptive serving loop, from `HMATC_ONLINE` or
/// `hmatc serve --online-*` flags.
#[derive(Clone, Debug)]
pub struct OnlineConfig {
    /// Sliding window of per-chunk samples the re-fit runs over.
    pub window: usize,
    /// Minimum window fill before the first fit (and any re-fit).
    pub min_samples: usize,
    /// Relative drift `|measured − predicted| / predicted` that arms a
    /// re-fit.
    pub drift: f64,
    /// Consecutive over-threshold observations required to re-fit.
    pub hysteresis: usize,
    /// Latency deadline the continuous batcher packs panels against.
    pub deadline: Duration,
    /// Hard cap on the coalesced panel width.
    pub max_panel: usize,
}

impl Default for OnlineConfig {
    fn default() -> OnlineConfig {
        OnlineConfig {
            window: 4096,
            min_samples: 128,
            drift: 0.25,
            hysteresis: 3,
            deadline: Duration::from_millis(2),
            max_panel: 64,
        }
    }
}

impl OnlineConfig {
    /// Parse an `HMATC_ONLINE` value: `1`/`on`/`true` enable the defaults,
    /// `0`/`off`/`false`/empty disable, anything else is a comma list of
    /// `key=value` overrides (`window`, `min`, `drift`, `hysteresis`,
    /// `deadline_us`, `panel`) that also enables. Unknown keys or malformed
    /// values are reported as errors, not ignored.
    pub fn parse(value: &str) -> Result<Option<OnlineConfig>, String> {
        let v = value.trim();
        if v.is_empty() || v == "0" || v.eq_ignore_ascii_case("off") || v.eq_ignore_ascii_case("false") {
            return Ok(None);
        }
        if v == "1" || v.eq_ignore_ascii_case("on") || v.eq_ignore_ascii_case("true") {
            return Ok(Some(OnlineConfig::default()));
        }
        let mut cfg = OnlineConfig::default();
        for part in v.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) = part.split_once('=').ok_or_else(|| format!("expected key=value, got {part:?}"))?;
            let bad = |what: &str| format!("invalid {what} in HMATC_ONLINE: {val:?}");
            match key.trim() {
                "window" => cfg.window = val.trim().parse::<usize>().ok().filter(|&n| n >= 1).ok_or_else(|| bad("window"))?,
                "min" => cfg.min_samples = val.trim().parse::<usize>().ok().filter(|&n| n >= 1).ok_or_else(|| bad("min"))?,
                "drift" => {
                    cfg.drift = val.trim().parse::<f64>().ok().filter(|d| d.is_finite() && *d > 0.0).ok_or_else(|| bad("drift"))?
                }
                "hysteresis" => {
                    cfg.hysteresis = val.trim().parse::<usize>().ok().filter(|&n| n >= 1).ok_or_else(|| bad("hysteresis"))?
                }
                "deadline_us" => {
                    cfg.deadline = Duration::from_micros(val.trim().parse::<u64>().map_err(|_| bad("deadline_us"))?)
                }
                "panel" => cfg.max_panel = val.trim().parse::<usize>().ok().filter(|&n| n >= 1).ok_or_else(|| bad("panel"))?,
                other => return Err(format!("unknown HMATC_ONLINE key {other:?}")),
            }
        }
        cfg.min_samples = cfg.min_samples.min(cfg.window);
        Ok(Some(cfg))
    }

    /// The `HMATC_ONLINE` configuration; `None` when unset/disabled.
    /// Invalid values warn to stderr and disable (serving must not die on a
    /// typo in an env knob).
    pub fn from_env() -> Option<OnlineConfig> {
        let v = std::env::var("HMATC_ONLINE").ok()?;
        match OnlineConfig::parse(&v) {
            Ok(cfg) => cfg,
            Err(e) => {
                eprintln!("hmatc: ignoring HMATC_ONLINE: {e}");
                None
            }
        }
    }

    /// Whether `HMATC_ONLINE` enables adaptation (bench/status labels).
    pub fn enabled_from_env() -> bool {
        OnlineConfig::from_env().is_some()
    }

    /// One-line knob summary for banners/logs.
    pub fn describe(&self) -> String {
        format!(
            "window {} | min {} | drift {:.2} | hysteresis {} | deadline {}us | panel {}",
            self.window,
            self.min_samples,
            self.drift,
            self.hysteresis,
            self.deadline.as_micros(),
            self.max_panel
        )
    }
}

/// Mutable calibrator state, one lock: observations arrive already batched
/// (one `observe` per served batch), so contention is negligible next to the
/// product itself.
struct CalState {
    window: VecDeque<Sample>,
    streak: usize,
    refits: u64,
    swaps: u64,
    observations: u64,
    last_drift: f64,
    bootstrapped: bool,
}

/// Snapshot of the calibrator for status lines and tests.
#[derive(Clone, Debug, Default)]
pub struct OnlineStatus {
    /// Samples currently held in the sliding window.
    pub window_len: usize,
    /// Batches observed so far.
    pub observations: u64,
    /// Fit attempts (bootstrap + drift-armed).
    pub refits: u64,
    /// Successful packing swaps (usable fitted profile applied).
    pub swaps: u64,
    /// Relative drift of the most recent observation.
    pub last_drift: f64,
    /// Current consecutive over-threshold streak.
    pub streak: usize,
}

/// Sliding-window online calibrator: feeds served-batch timings back into
/// the cost model and re-balances every registered operator when the model
/// stops tracking the machine. See the module docs for the drift/hysteresis
/// contract.
pub struct OnlineCalibrator {
    cfg: OnlineConfig,
    ops: Vec<Arc<PlannedOperator>>,
    state: Mutex<CalState>,
}

impl OnlineCalibrator {
    /// A calibrator re-balancing `ops` on every successful re-fit. All
    /// operators of one server (per-class routes included) register here so
    /// a swap keeps their packings consistent with one model.
    pub fn new(cfg: OnlineConfig, ops: Vec<Arc<PlannedOperator>>) -> OnlineCalibrator {
        OnlineCalibrator {
            cfg,
            ops,
            state: Mutex::new(CalState {
                window: VecDeque::new(),
                streak: 0,
                refits: 0,
                swaps: 0,
                observations: 0,
                last_drift: 0.0,
                bootstrapped: false,
            }),
        }
    }

    /// The active knob set.
    pub fn config(&self) -> &OnlineConfig {
        &self.cfg
    }

    /// Fold one served batch into the window: its harvested per-chunk
    /// samples plus the (predicted, measured) makespan of the packing it ran
    /// on. Returns `true` when the observation triggered a packing swap.
    ///
    /// Bootstrap rule: until the first usable fit there is no profile, so
    /// `predicted` is 0.0 and drift is undefined — the first fit fires as
    /// soon as the window holds `min_samples`, which is what turns
    /// `cost_source` to `online` deterministically early in a serve run.
    pub fn observe(&self, samples: &[Sample], predicted: f64, measured: f64) -> bool {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        st.observations += 1;
        st.window.extend(samples.iter().cloned());
        while st.window.len() > self.cfg.window {
            st.window.pop_front();
        }
        let d = costmodel::drift(predicted, measured);
        st.last_drift = d;
        if st.window.len() < self.cfg.min_samples {
            return false;
        }
        if !st.bootstrapped && predicted <= 0.0 {
            return self.refit_locked(&mut st);
        }
        if d > self.cfg.drift {
            st.streak += 1;
            if st.streak >= self.cfg.hysteresis {
                return self.refit_locked(&mut st);
            }
        } else {
            st.streak = 0;
        }
        false
    }

    /// Re-fit from the current window regardless of drift state (tests and
    /// the serve smoke use this to force mid-stream swaps). Returns `true`
    /// when a usable profile was fitted and swapped in.
    pub fn force_refit(&self) -> bool {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        self.refit_locked(&mut st)
    }

    fn refit_locked(&self, st: &mut CalState) -> bool {
        st.streak = 0;
        st.refits += 1;
        let samples: Vec<Sample> = st.window.iter().cloned().collect();
        // Per-pool overlay fits ride along whenever the window carries
        // pool-tagged samples (sharded:K backends); single-pool windows take
        // the plain global fit path inside fit_pools.
        let npools = samples.iter().map(|s| s.pool).max().map_or(1, |m| m + 1);
        let profile = match costmodel::fit_pools(&samples, npools) {
            Ok(p) => p,
            Err(_) => return false,
        };
        if !profile.is_usable() {
            return false;
        }
        for op in &self.ops {
            op.rebalance(&profile);
        }
        st.bootstrapped = true;
        st.swaps += 1;
        true
    }

    /// Current calibrator counters (serve status line / tests).
    pub fn status(&self) -> OnlineStatus {
        let st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        OnlineStatus {
            window_len: st.window.len(),
            observations: st.observations,
            refits: st.refits,
            swaps: st.swaps,
            last_drift: st.last_drift,
            streak: st.streak,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::costmodel::{KernelClass, TaskFeats};

    fn sample(secs: f64) -> Sample {
        let mut feats = TaskFeats::default();
        feats.add(KernelClass::MatBytes, 1024.0);
        feats.add(KernelClass::PanelVec, 64.0);
        Sample { feats, nrhs: 1, pool: 0, secs }
    }

    fn batch(n: usize, secs: f64) -> Vec<Sample> {
        (0..n).map(|_| sample(secs)).collect()
    }

    #[test]
    fn config_parses_switches_and_overrides() {
        assert!(OnlineConfig::parse("0").unwrap().is_none());
        assert!(OnlineConfig::parse("off").unwrap().is_none());
        assert!(OnlineConfig::parse("").unwrap().is_none());
        let d = OnlineConfig::parse("1").unwrap().unwrap();
        assert_eq!(d.window, OnlineConfig::default().window);
        let c = OnlineConfig::parse("window=512,min=32,drift=0.5,hysteresis=2,deadline_us=750,panel=16").unwrap().unwrap();
        assert_eq!(c.window, 512);
        assert_eq!(c.min_samples, 32);
        assert!((c.drift - 0.5).abs() < 1e-12);
        assert_eq!(c.hysteresis, 2);
        assert_eq!(c.deadline, Duration::from_micros(750));
        assert_eq!(c.max_panel, 16);
        // min is clamped to the window so the first fit can ever fire
        let c = OnlineConfig::parse("window=16,min=400").unwrap().unwrap();
        assert_eq!(c.min_samples, 16);
        // malformed values are errors, not silent defaults
        assert!(OnlineConfig::parse("drift=sideways").is_err());
        assert!(OnlineConfig::parse("window=0").is_err());
        assert!(OnlineConfig::parse("warp=9").is_err());
        assert!(OnlineConfig::parse("drift").is_err());
    }

    #[test]
    fn bootstraps_once_window_fills() {
        let cfg = OnlineConfig { min_samples: 8, ..OnlineConfig::default() };
        let cal = OnlineCalibrator::new(cfg, Vec::new());
        // below min_samples: no fit even without a profile
        assert!(!cal.observe(&batch(4, 1e-6), 0.0, 1e-4));
        assert_eq!(cal.status().refits, 0);
        // window fills → bootstrap fit fires exactly once
        assert!(cal.observe(&batch(8, 1e-6), 0.0, 1e-4));
        let st = cal.status();
        assert_eq!(st.refits, 1);
        assert_eq!(st.swaps, 1);
        // bootstrapped: a quiet observation does not re-fit
        assert!(!cal.observe(&batch(4, 1e-6), 1e-4, 1.05e-4));
        assert_eq!(cal.status().refits, 1);
    }

    #[test]
    fn drift_needs_consecutive_hysteresis_streak() {
        let cfg = OnlineConfig { min_samples: 1, hysteresis: 3, drift: 0.25, ..OnlineConfig::default() };
        let cal = OnlineCalibrator::new(cfg, Vec::new());
        assert!(cal.observe(&batch(4, 1e-6), 0.0, 1e-4)); // bootstrap
        // two over-threshold observations, then a quiet one: streak resets
        assert!(!cal.observe(&batch(1, 1e-6), 1e-4, 2e-4));
        assert!(!cal.observe(&batch(1, 1e-6), 1e-4, 2e-4));
        assert!(!cal.observe(&batch(1, 1e-6), 1e-4, 1.01e-4));
        assert_eq!(cal.status().refits, 1); // still only the bootstrap
        // three consecutive over-threshold observations: exactly one re-fit
        assert!(!cal.observe(&batch(1, 1e-6), 1e-4, 2e-4));
        assert!(!cal.observe(&batch(1, 1e-6), 1e-4, 2e-4));
        assert!(cal.observe(&batch(1, 1e-6), 1e-4, 2e-4));
        assert_eq!(cal.status().refits, 2);
    }

    #[test]
    fn noisy_timings_cause_no_swap_storm() {
        let cfg = OnlineConfig { min_samples: 1, hysteresis: 3, drift: 0.25, ..OnlineConfig::default() };
        let cal = OnlineCalibrator::new(cfg, Vec::new());
        cal.observe(&batch(4, 1e-6), 0.0, 1e-4); // bootstrap
        // alternating noise straddling the threshold: streak never reaches 3
        for i in 0..200 {
            let measured = if i % 2 == 0 { 2e-4 } else { 1.0e-4 };
            cal.observe(&batch(1, 1e-6), 1e-4, measured);
        }
        assert_eq!(cal.status().refits, 1, "alternating noise must not swap");
        // sustained drift: swaps bounded by observations / hysteresis
        let before = cal.status().refits;
        for _ in 0..30 {
            cal.observe(&batch(1, 1e-6), 1e-4, 3e-4);
        }
        let extra = cal.status().refits - before;
        assert!(extra <= 10, "at most one swap per hysteresis window, got {extra}");
        assert!(extra >= 1, "sustained drift must eventually swap");
    }

    #[test]
    fn zero_prediction_after_bootstrap_is_quiet() {
        // drift(0, m) is defined as 0 — a swap race that briefly yields no
        // prediction must not arm the trigger
        let cfg = OnlineConfig { min_samples: 1, hysteresis: 1, ..OnlineConfig::default() };
        let cal = OnlineCalibrator::new(cfg, Vec::new());
        cal.observe(&batch(4, 1e-6), 0.0, 1e-4); // bootstrap
        assert!(!cal.observe(&batch(1, 1e-6), 0.0, 1e-4));
        assert_eq!(cal.status().refits, 1);
    }
}
